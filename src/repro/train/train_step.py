"""The jitted training step: loss -> grads (with microbatch accumulation)
-> clip -> AdamW, with explicit in/out shardings and donated buffers.

Distributed-optimization features:
  * microbatch gradient accumulation via ``lax.scan`` (activation memory is
    one microbatch; param all-gathers amortize across microbatches);
  * optional int8 error-feedback gradient compression on the DP reduction
    path (``compress_grads``);
  * remat policy comes from the model config; buffers are donated so the
    update is in-place at the XLA level.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import factory
from repro.optim import compression
from repro.optim.adamw import OptConfig, apply_updates, init_opt_state
from repro.sharding import partition

__all__ = ["make_train_step", "init_train_state", "train_step_fn"]


def init_train_state(cfg: ModelConfig, ocfg: OptConfig, key,
                     compress_grads: bool = False) -> dict:
    params = factory.init_params(cfg, key)
    state = {"params": params, "opt": init_opt_state(ocfg, params)}
    if compress_grads:
        state["ef_error"] = compression.init_error_state(params)
    return state


def _split_microbatches(batch: dict, n: int) -> dict:
    def re(x):
        b = x.shape[0]
        if x.ndim >= 2 and x.shape[0] == 3:  # positions3 (3, B, S)
            return x.reshape(3, n, x.shape[1] // n, *x.shape[2:]
                             ).transpose(1, 0, *range(2, x.ndim + 1))
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(re, batch)


def train_step_fn(cfg: ModelConfig, ocfg: OptConfig, state: dict,
                  batch: dict, microbatches: int = 1,
                  compress_grads: bool = False):
    params = state["params"]

    def loss_of(p, mb):
        loss, metrics = factory.loss_fn(cfg, p, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    if microbatches > 1:
        mbs = _split_microbatches(batch, microbatches)

        def acc(carry, mb):
            g_acc, l_acc = carry
            (loss, _), g = grad_fn(params, mb)
            g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                             g_acc, g)
            return (g, l_acc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (grads, loss_sum), _ = jax.lax.scan(acc, (zeros, 0.0), mbs)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        loss = loss_sum / microbatches
        metrics = {}
    else:
        (loss, metrics), grads = grad_fn(params, batch)

    if compress_grads:
        grads, ef = compression.ef_compress_grads(grads, state["ef_error"])

    new_params, new_opt, opt_metrics = apply_updates(
        ocfg, params, grads, state["opt"])
    out = {"params": new_params, "opt": new_opt}
    if compress_grads:
        out["ef_error"] = ef
    metrics = {"loss": loss, **metrics, **opt_metrics}
    return out, metrics


def make_train_step(cfg: ModelConfig, ocfg: OptConfig, mesh,
                    state_shapes: dict, batch_shapes: dict,
                    microbatches: int = 1, compress_grads: bool = False,
                    donate: bool = True):
    """Build the jitted, sharded train step for a concrete mesh.

    ``state_shapes``/``batch_shapes`` are eval_shape pytrees used to derive
    the PartitionSpecs without touching real data.
    """
    pspecs = param_state_pspecs(state_shapes, mesh)
    bspecs = partition.batch_pspecs(batch_shapes, mesh)

    fn = partial(train_step_fn, cfg, ocfg, microbatches=microbatches,
                 compress_grads=compress_grads)
    return jax.jit(
        fn,
        in_shardings=(partition.named(mesh, pspecs),
                      partition.named(mesh, bspecs)),
        out_shardings=(partition.named(mesh, pspecs), None),
        donate_argnums=(0,) if donate else (),
    ), pspecs, bspecs


def param_state_pspecs(state_shapes: dict, mesh):
    """Specs for the full train state: optimizer mirrors the params."""
    pp = partition.param_pspecs(state_shapes["params"], mesh)
    out = {"params": pp,
           "opt": {"mu": pp, "nu": pp,
                   "step": jax.sharding.PartitionSpec()}}
    if "master" in state_shapes["opt"]:
        out["opt"]["master"] = pp
    if "ef_error" in state_shapes:
        out["ef_error"] = pp
    return out
