"""The training driver: data pipeline + sharded train step + checkpointing
+ fault-tolerance hooks, with exact resume."""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro import compat

from repro.checkpoint import ckpt
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import SyntheticPipeline
from repro.optim.adamw import OptConfig
from repro.runtime.fault_tolerance import StragglerDetector
from repro.sharding import partition
from repro.train import train_step as ts

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_ckpts: int = 3
    log_every: int = 10
    microbatches: int = 1
    compress_grads: bool = False
    async_ckpt: bool = False
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, mesh,
                 ocfg: OptConfig | None = None,
                 tcfg: TrainerConfig | None = None):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.ocfg = ocfg or OptConfig()
        self.tcfg = tcfg or TrainerConfig()
        self.pipe = SyntheticPipeline.for_model(cfg, shape,
                                                seed=self.tcfg.seed)
        self.straggler = StragglerDetector()
        self.step = 0
        self.state = None
        self._build()

    def _build(self):
        key = jax.random.PRNGKey(self.tcfg.seed)
        state_shapes = jax.eval_shape(
            lambda: ts.init_train_state(
                self.cfg, self.ocfg, key,
                compress_grads=self.tcfg.compress_grads))
        batch_shapes = jax.eval_shape(lambda: self.pipe.batch_at(0))
        self.step_fn, self.pspecs, self.bspecs = ts.make_train_step(
            self.cfg, self.ocfg, self.mesh, state_shapes, batch_shapes,
            microbatches=self.tcfg.microbatches,
            compress_grads=self.tcfg.compress_grads)

    def init_or_resume(self):
        latest = ckpt.latest_step(self.tcfg.ckpt_dir)
        if latest is not None:
            state, extra, step = ckpt.restore(
                self.tcfg.ckpt_dir, latest, mesh=self.mesh,
                specs=self.pspecs)
            self.state = state
            self.step = extra.get("data_state", {}).get("step", step)
            return "resumed", self.step
        key = jax.random.PRNGKey(self.tcfg.seed)
        with compat.set_mesh(self.mesh):
            state = ts.init_train_state(
                self.cfg, self.ocfg, key,
                compress_grads=self.tcfg.compress_grads)
        self.state = partition.logical_to_sharding(
            state, self.pspecs, self.mesh)
        self.step = 0
        return "fresh", 0

    def save(self, block: bool = True):
        extra = {"data_state": self.pipe.state(self.step)}
        if self.tcfg.async_ckpt and not block:
            ckpt.save_async(self.tcfg.ckpt_dir, self.step, self.state, extra)
        else:
            ckpt.save(self.tcfg.ckpt_dir, self.step, self.state, extra)
        ckpt.gc_keep_last(self.tcfg.ckpt_dir, self.tcfg.keep_ckpts)

    def train(self, n_steps: int, log=print):
        if self.state is None:
            self.init_or_resume()
        metrics = {}
        with compat.set_mesh(self.mesh):
            for _ in range(n_steps):
                batch = self.pipe.batch_at(self.step)
                batch = partition.logical_to_sharding(
                    batch, self.bspecs, self.mesh)
                t0 = time.time()
                self.state, metrics = self.step_fn(self.state, batch)
                self.step += 1
                if self.step % self.tcfg.log_every == 0 and log:
                    log(f"step {self.step}: "
                        f"loss={float(metrics['loss']):.4f} "
                        f"gnorm={float(metrics['grad_norm']):.3f} "
                        f"{time.time()-t0:.2f}s/step")
                if self.step % self.tcfg.ckpt_every == 0:
                    self.save(block=not self.tcfg.async_ckpt)
        return metrics
