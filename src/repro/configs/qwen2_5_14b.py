"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064, QKV bias.  [hf:Qwen/Qwen2.5-0.5B family; hf]

Note: 40 heads do not divide the 16-way model axis; QKV projections shard
on the flat feature dim (5120 % 16 == 0) and XLA re-shards attention
internals (see DESIGN.md sharding notes).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    activation="silu",
    gated_mlp=True,
    rope_theta=1e6,
    tie_embeddings=False,
)
