"""Config dataclasses: model architecture + input-shape cells.

Every assigned architecture is a ``ModelConfig``; the four assigned input
shapes are ``ShapeConfig``s.  ``smoke(cfg)`` derives the reduced same-family
config used by per-arch CPU smoke tests; the full configs are exercised via
the dry-run only (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "smoke"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | vlm | hybrid | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    activation: str = "silu"
    gated_mlp: bool = True
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 4096  # tokens per dispatch group
    # SSM / hybrid (Mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_kernel: int = 4
    attn_every: int = 0         # zamba2: shared attention block period
    # RWKV6
    rwkv_head_dim: int = 64
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500     # stub conv-frontend output frames
    learned_pos: bool = False
    # VLM
    mrope: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    # numerics / execution
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"  # "int8": per-token-per-head scales
    remat: str = "full"         # none | full | dots
    q_chunk: int = 512
    kv_chunk: int = 1024
    # ESPIM sparsity (serving)
    espim_sparsity: float = 0.0  # 0 = dense serving
    espim_quant: str = "none"    # value-plane encoding: none | int8 | int4

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so embedding tables shard
        cleanly (e.g. granite's 49155).  Models size tables with this;
        labels always index the logical vocab."""
        return -(-self.vocab_size // 256) * 256

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid archs)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    def replace(self, **kw) -> "ShapeConfig":
        return dataclasses.replace(self, **kw)


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small widths, few
    layers/experts, tiny vocab — structure (GQA ratios, MoE top-k, hybrid
    period, enc-dec split) preserved."""
    kw: dict = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        param_dtype="float32",
        compute_dtype="float32",
        q_chunk=64,
        kv_chunk=64,
        moe_group_size=64,
        remat="none",
    )
    if cfg.n_kv_heads == cfg.n_heads:
        kw["n_kv_heads"] = 4  # MHA archs stay MHA
    if cfg.family == "moe":
        kw["n_experts"] = 4
        kw["experts_per_token"] = min(cfg.experts_per_token, 2)
        # no capacity drops at smoke scale: keeps decode/forward parity exact
        kw["capacity_factor"] = 4.0
    if cfg.family in ("hybrid", "ssm"):
        kw["ssm_state"] = min(cfg.ssm_state, 16) or 16
        kw["ssm_head_dim"] = 16
    if cfg.attn_every:
        kw["attn_every"] = 2
        kw["n_layers"] = 6  # three groups -> shared block fires 3x
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
        kw["encoder_seq"] = 32
    return cfg.replace(**kw)
