"""whisper-small [audio] — 12L(dec)+12L(enc) d_model=768 12H (MHA kv=12)
d_ff=3072 vocab=51865; enc-dec with conv frontend STUB (precomputed frame
embeddings).  [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    encoder_layers=12,
    encoder_seq=1500,
    learned_pos=True,
    norm="layernorm",
    activation="gelu",
    gated_mlp=False,
    tie_embeddings=True,
)
