"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (kv=32, MHA) d_ff=10240
vocab=32000, ssm_state=64; Mamba2 stack + shared attention blocks.
[arXiv:2411.15242; hf]

Simplification noted in DESIGN.md: one shared attention+MLP block applied
every ``attn_every`` layers (the reference alternates two shared blocks with
per-application LoRA deltas).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    conv_kernel=4,
    attn_every=6,
    activation="gelu",
    gated_mlp=True,
    rope_theta=1e4,
    tie_embeddings=True,
)
