"""Architecture registry: ``--arch <id>`` resolution for every launcher,
test and benchmark."""
from __future__ import annotations

from repro.configs import (
    dbrx_132b,
    granite_3_2b,
    llama7b_espim,
    nemotron_4_15b,
    phi3_5_moe,
    qwen1_5_110b,
    qwen2_5_14b,
    qwen2_vl_2b,
    rwkv6_1_6b,
    whisper_small,
    zamba2_2_7b,
)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, smoke

__all__ = ["REGISTRY", "ASSIGNED", "get_config", "get_shape", "list_archs",
           "cells"]

REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        qwen1_5_110b.CONFIG,
        nemotron_4_15b.CONFIG,
        granite_3_2b.CONFIG,
        qwen2_5_14b.CONFIG,
        dbrx_132b.CONFIG,
        phi3_5_moe.CONFIG,
        qwen2_vl_2b.CONFIG,
        zamba2_2_7b.CONFIG,
        whisper_small.CONFIG,
        rwkv6_1_6b.CONFIG,
        llama7b_espim.CONFIG,
    ]
}

# The ten assigned architectures (the paper's llama7b is extra).
ASSIGNED = [
    "qwen1.5-110b", "nemotron-4-15b", "granite-3-2b", "qwen2.5-14b",
    "dbrx-132b", "phi3.5-moe-42b-a6.6b", "qwen2-vl-2b", "zamba2-2.7b",
    "whisper-small", "rwkv6-1.6b",
]


def get_config(name: str, *, reduced: bool = False) -> ModelConfig:
    try:
        cfg = REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(REGISTRY)}") from None
    return smoke(cfg) if reduced else cfg


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def list_archs() -> list[str]:
    return list(ASSIGNED)


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    """Spec-mandated skips; None means the cell runs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention arch: long_500k requires sub-quadratic "
                "attention (DESIGN.md section 4)")
    return None


def cells(include_skipped: bool = False):
    """All 40 (arch x shape) cells; skipped cells annotated."""
    out = []
    for arch in ASSIGNED:
        cfg = REGISTRY[arch]
        for shape in SHAPES.values():
            reason = skip_reason(cfg, shape)
            if reason is None or include_skipped:
                out.append((arch, shape.name, reason))
    return out
