"""rwkv6-1.6b "Finch" [ssm] — 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536; data-dependent decay.  [arXiv:2404.05892; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,           # 2048 / rwkv_head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    rwkv_head_dim=64,
    activation="relu2",
    gated_mlp=False,
    tie_embeddings=True,
)
