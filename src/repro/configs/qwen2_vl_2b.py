"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE + dynamic resolution (vision frontend is a stub:
precomputed patch embeddings).  [arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    mrope=True,
    activation="silu",
    gated_mlp=True,
    rope_theta=1e6,
    tie_embeddings=True,
)
