"""LLaMA-7B — the paper's own benchmark model (Table III): 32L d_model=4096
32H (MHA) d_ff=11008 vocab=32000.  The PIM benchmarks prune its projection
matrices to 50-90% sparsity; the serving example runs it through
ESPIMLinear.  ``espim_quant="int8"`` is the serving deployment default:
narrow fixed-point value planes are the paper's own DRAM format, and the
int8 codes keep tiny-LM logits at cosine > 0.999 vs fp (tests/test_quant).
[arXiv:2302.13971]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama7b-espim",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    activation="silu",
    gated_mlp=True,
    rope_theta=1e4,
    tie_embeddings=False,
    espim_sparsity=0.9,
    espim_quant="int8",
)
