"""Pack integrity: build-time fingerprints + load-time bounds validation.

ESPIM's static data-dependent scheduling bets everything on *decoupled*
index and value planes compiled offline: a single flipped bit in an index
plane silently gathers the wrong ``x`` elements and poisons every
downstream token, and a schedule (perm / chunk plan / width buckets)
paired with the wrong pack is undetectable at trace time — the kernels
only see well-shaped int32 arrays.  The serving contract is therefore
"static but verified":

* every offline pack builder (``pack_ell`` / ``chunk_pack`` /
  ``pack_bucketed_stack``) records a **per-plane fingerprint** (sha256
  over dtype + shape + bytes of each index plane, value plane, valid
  mask, perm and quantized codes/scales) plus a **bound pack digest**
  that also covers the SDDS plan, so plane corruption AND
  schedule<->pack mismatch both change the digest;
* every upload path (``ops.pack_to_device``, ``sparsify_model`` /
  ``verify_sparse`` at engine init) recomputes and compares, and
  additionally **bounds-validates** what hashing alone cannot interpret:
  chunk-local column ids against the input dim, perm/inv_perm mutual
  consistency, quantized codes against their per-group bit widths and
  the scale-group layout.

Everything here is host-side numpy — verification runs once per upload,
never on the per-token path.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json

import numpy as np

__all__ = [
    "PackIntegrityError",
    "array_digest",
    "fingerprint_planes",
    "bind_fingerprint",
    "plan_fingerprint",
    "pack_planes",
    "fingerprint_pack",
    "validate_pack",
    "verify_pack",
    "validate_perm_layers",
]


class PackIntegrityError(RuntimeError):
    """A pack failed fingerprint verification or bounds validation."""


# --------------------------------------------------------------------------
# Fingerprints
# --------------------------------------------------------------------------
def array_digest(arr) -> str:
    """sha256 over dtype + shape + raw bytes of one plane (any array-like,
    device arrays included — they round-trip through numpy byte-exact)."""
    a = np.ascontiguousarray(np.asarray(arr))
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(repr(a.shape).encode())
    h.update(a.tobytes())
    return h.hexdigest()


def fingerprint_planes(planes: dict) -> dict:
    return {name: array_digest(a) for name, a in planes.items()
            if a is not None}


def bind_fingerprint(plane_fps: dict, meta: dict | None = None) -> str:
    """Bind per-plane digests + static meta (geometry, quant layout, the
    SDDS plan digest) into one pack digest."""
    doc = {"planes": dict(sorted(plane_fps.items())), "meta": meta or {}}
    return hashlib.sha256(
        json.dumps(doc, sort_keys=True, default=str).encode()).hexdigest()


def plan_fingerprint(plan) -> str:
    """Digest of an SDDS schedule artifact (ChunkPlan / WidthBucketPlan /
    Schedule / PackGroupSpec dataclass) — the schedule side of the
    schedule<->pack binding."""
    if plan is None:
        return "none"
    doc = dataclasses.asdict(plan) if dataclasses.is_dataclass(plan) \
        else dict(plan)
    return hashlib.sha256(
        json.dumps(doc, sort_keys=True, default=str).encode()).hexdigest()


def _qplane_planes(prefix: str, plane) -> dict:
    return {f"{prefix}q": plane.q,
            f"{prefix}scales": plane.scales,
            f"{prefix}group_bits": plane.group_bits}


def pack_planes(pack) -> tuple[dict, dict]:
    """(named planes, static meta) of any offline pack — ``ELLPack``,
    ``ELLChunkedPack`` or ``BucketedStackedPack``, fp or quantized
    (duck-typed so this module imports nothing from the format module)."""
    if hasattr(pack, "buckets"):                    # BucketedStackedPack
        planes = {"perm": pack.perm, "inv_perm": pack.inv_perm}
        for g, b in enumerate(pack.buckets):
            planes[f"b{g}.values"] = b["values"]
            planes[f"b{g}.cols"] = b["cols"]
            planes[f"b{g}.valid"] = b["valid"]
        if pack.qplanes is not None:
            for g, p in enumerate(pack.qplanes):
                planes.update(_qplane_planes(f"b{g}.", p))
        meta = {"kind": "bucketed_stack", "halves": pack.halves,
                "n_rows": pack.n_rows, "n_cols": pack.n_cols,
                "chunk_cols": pack.chunk_cols, "row_tile": pack.row_tile,
                "bucket_rows": list(pack.bucket_rows),
                "plan": plan_fingerprint(pack.plan)}
        return planes, meta
    planes = {"values": pack.values, "cols": pack.cols,
              "valid": pack.valid, "perm": pack.perm}
    qp = getattr(pack, "qplane", None)
    if qp is not None:
        planes.update(_qplane_planes("", qp))
    meta = {"kind": "ell_chunked" if pack.values.ndim == 3 else "ell",
            "n_rows": pack.n_rows, "n_cols": pack.n_cols,
            "row_tile": pack.row_tile,
            "chunk_cols": getattr(pack, "chunk_cols", None),
            "plan": plan_fingerprint(getattr(pack, "plan", None))}
    return planes, meta


def fingerprint_pack(pack) -> dict:
    """{"planes": {name: digest}, "meta": ..., "pack": bound digest}."""
    planes, meta = pack_planes(pack)
    fps = fingerprint_planes(planes)
    return {"planes": fps, "meta": meta, "pack": bind_fingerprint(fps, meta)}


def diverging_planes(expected: dict, got: dict) -> list:
    exp_p = expected.get("planes", {})
    got_p = got.get("planes", {})
    return sorted(k for k in set(exp_p) | set(got_p)
                  if exp_p.get(k) != got_p.get(k))


# --------------------------------------------------------------------------
# Bounds validation (what hashing cannot interpret)
# --------------------------------------------------------------------------
def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise PackIntegrityError(msg)


def validate_chunked_planes(what: str, values, cols, valid,
                            chunk_cols: int, n_cols: int) -> None:
    """Bounds-validate one (..., K, Lc) chunked plane set: chunk-local
    column ids must address real ``x`` elements (the last chunk is
    narrower than ``chunk_cols`` when ``n_cols`` is not a multiple), pad
    slots must be inert, fp values finite."""
    cols = np.asarray(cols)
    valid = np.asarray(valid, bool)
    _check(cols.shape == valid.shape,
           f"{what}: cols/valid shape mismatch {cols.shape} vs {valid.shape}")
    k = cols.shape[-2]
    lim = np.minimum(chunk_cols, n_cols - np.arange(k) * chunk_cols)
    lim = lim.reshape((1,) * (cols.ndim - 2) + (k, 1))
    _check(not (valid & ((cols < 0) | (cols >= lim))).any(),
           f"{what}: index plane out of bounds for input dim {n_cols} "
           f"(chunk_cols={chunk_cols})")
    _check(not cols[~valid].any(),
           f"{what}: pad slots of the index plane must be zero")
    if values is not None:
        values = np.asarray(values)
        _check(values.shape == cols.shape,
               f"{what}: values/cols shape mismatch "
               f"{values.shape} vs {cols.shape}")
        _check(bool(np.isfinite(values).all()),
               f"{what}: non-finite entries in the value plane")
        _check(not values[~valid].any(),
               f"{what}: pad slots of the value plane must be zero")


def validate_qplane(what: str, plane) -> None:
    """Quantized value plane vs its scale-group layout: codes within each
    group's bit width, one finite scale per ``group_rows`` rows."""
    q = np.asarray(plane.q)
    scales = np.asarray(plane.scales)
    gbits = np.asarray(plane.group_bits)
    _check(scales.shape == gbits.shape,
           f"{what}: scales/group_bits shape mismatch")
    _check(q.shape[-3] == plane.group_rows * scales.shape[-1],
           f"{what}: scale-group layout mismatch — {q.shape[-3]} rows vs "
           f"{scales.shape[-1]} groups x group_rows={plane.group_rows}")
    _check(bool(np.isfinite(scales).all()),
           f"{what}: non-finite quant scales")
    _check(bool(np.isin(gbits, (4, 8)).all()),
           f"{what}: group_bits entries must be 4 or 8")
    row_bits = np.repeat(gbits, plane.group_rows, axis=-1)
    qmax = np.where(row_bits == 4, 7, 127)[..., :, None, None]
    _check(bool((np.abs(q.astype(np.int32)) <= qmax).all()),
           f"{what}: quant codes exceed their group's bit width")


def validate_perm_layers(what: str, perm, inv_perm, n_rows: int) -> None:
    """(L, r_pad) perm / (L, n_rows) inv_perm mutual consistency — every
    logical row packed exactly once per layer, and the inverse actually
    inverts (a rolled/mispaired schedule fails here even without a
    recorded fingerprint)."""
    perm = np.asarray(perm)
    inv = np.asarray(inv_perm)
    r_pad = perm.shape[-1]
    _check(inv.shape == perm.shape[:-1] + (n_rows,),
           f"{what}: inv_perm shape {inv.shape} inconsistent with perm "
           f"{perm.shape} over {n_rows} rows")
    _check(bool(((perm >= -1) & (perm < n_rows)).all()),
           f"{what}: perm entries out of range [-1, {n_rows})")
    _check(bool(((perm >= 0).sum(axis=-1) == n_rows).all()),
           f"{what}: perm must pack every logical row exactly once")
    _check(bool(((inv >= 0) & (inv < r_pad)).all()),
           f"{what}: inv_perm entries out of range [0, {r_pad})")
    round_trip = np.take_along_axis(perm, inv, axis=-1)
    _check(bool((round_trip == np.arange(n_rows)).all()),
           f"{what}: inv_perm is not the inverse of perm "
           f"(schedule/pack mismatch)")


def _validate_perm_flat(what: str, perm, n_rows: int) -> None:
    perm = np.asarray(perm)
    _check(bool(((perm >= -1) & (perm < n_rows)).all()),
           f"{what}: perm entries out of range [-1, {n_rows})")
    kept = perm[perm >= 0]
    _check(kept.size == n_rows and np.unique(kept).size == n_rows,
           f"{what}: perm must pack every logical row exactly once")


def validate_pack(pack) -> None:
    """Bounds-validate an offline pack (see ``validate_chunked_planes`` /
    ``validate_qplane`` / the perm checks).  Raises PackIntegrityError."""
    if hasattr(pack, "buckets"):                    # BucketedStackedPack
        for g, b in enumerate(pack.buckets):
            validate_chunked_planes(f"bucket {g}", b["values"], b["cols"],
                                    b["valid"], pack.chunk_cols, pack.n_cols)
            if pack.qplanes is not None:
                validate_qplane(f"bucket {g}", pack.qplanes[g])
                _check(np.asarray(pack.qplanes[g].q).shape
                       == b["values"].shape,
                       f"bucket {g}: quant codes shape diverges from the "
                       f"fp plane")
        validate_perm_layers("pack", pack.perm, pack.inv_perm, pack.n_rows)
        return
    values, cols, valid = pack.values, pack.cols, pack.valid
    if values.ndim == 2:                            # plain ELL: one chunk
        values = values[:, None, :]
        cols = cols[:, None, :]
        valid = valid[:, None, :]
        chunk_cols = pack.n_cols
    else:
        chunk_cols = pack.chunk_cols
    validate_chunked_planes("pack", values, cols, valid, chunk_cols,
                            pack.n_cols)
    qp = getattr(pack, "qplane", None)
    if qp is not None:
        validate_qplane("pack", qp)
    _validate_perm_flat("pack", pack.perm, pack.n_rows)


def verify_pack(pack, expected: dict | None = None) -> dict:
    """The upload-time check: bounds-validate, then (when a build-time
    fingerprint is recorded on the pack — or passed explicitly) recompute
    and compare, naming the diverging planes.  Returns the fresh
    fingerprint."""
    validate_pack(pack)
    got = fingerprint_pack(pack)
    if expected is None:
        expected = getattr(pack, "fingerprint", None)
    if expected is not None and expected["pack"] != got["pack"]:
        raise PackIntegrityError(
            "pack fingerprint mismatch (diverged planes: "
            f"{diverging_planes(expected, got) or ['<meta/schedule>']}) — "
            "the pack was corrupted after build or paired with the wrong "
            "schedule")
    return got
