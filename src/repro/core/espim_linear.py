"""ESPIMLinear — the paper's flexible dense/sparse datapath (Section III-I)
as a first-class JAX projection layer — plus ``ESPIMGroupLinear`` (several
same-input projections packed as ONE fused group, the PackGroup contract
of DESIGN.md section 10) and the cluster-level "bank" distribution of the
sparse MV.

Flexible configuration: a projection holds either a dense weight (Newton's
16-MAC path) or an ESPIM ELL pack (11-MAC + FIFOs + switch path).  The
choice is made offline from the measured weight sparsity, exactly as the
paper power-gates one datapath or the other; the output contract is
identical either way.

Distribution: the paper's banks consume a shared vector broadcast in
lockstep while holding disjoint matrix rows.  One hierarchy level up, the
same structure is ``shard_map`` over the ``model`` mesh axis: each device
holds a disjoint packed row range (equal-sized: SDDS balancing already
equalized work), the dense ``x`` is replicated (the ICI broadcast), and each
device runs the ESPIM kernel over its rows.  The final unscatter is a pure
output-layout permutation.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import pack_ell, pack_ell_chunked, shard_ell
from repro.kernels import ops
from repro.kernels import ref as kref

__all__ = ["ESPIMLinear", "ESPIMGroupLinear", "espim_matvec_sharded",
           "make_sharded_weights"]


@dataclasses.dataclass
class ESPIMLinear:
    """Projection y = W @ x (+ b), W of shape (n_out, n_in).

    ``sparse`` selects the datapath.  ``from_dense`` measures sparsity and
    picks it (optionally pruning first), mirroring Section III-I.
    """

    n_out: int
    n_in: int
    sparse: bool
    weights: object  # EspimWeights if sparse else jnp dense (n_out, n_in)
    bias: jnp.ndarray | None = None
    density: float = 1.0

    @classmethod
    def from_dense(
        cls,
        w: np.ndarray,
        bias: np.ndarray | None = None,
        *,
        prune_sparsity: float | None = None,
        sparse_threshold: float = 0.5,
        row_tile: int = 128,
        chunk_cols: int = ops.DEFAULT_CHUNK_COLS,
        dtype=jnp.float32,
        quant=None,
    ) -> "ESPIMLinear":
        """``quant`` ("int8" | "int4" | a ``repro.quant.QuantSpec``)
        quantizes the pack's value plane on the sparse path (DESIGN.md
        section 9); the dense path ignores it — narrow fixed-point values
        are the compressed format's lever, not the GEMM path's."""
        w = np.asarray(w)
        if prune_sparsity is not None:
            w = magnitude_prune(w, prune_sparsity)
        density = float((w != 0).mean())
        sparse = density < sparse_threshold
        if sparse:
            pack = pack_ell_chunked(w, row_tile=row_tile,
                                    chunk_cols=chunk_cols)
            if quant in ("none",):
                quant = None
            weights = ops.pack_to_device(pack, dtype=dtype, quant=quant)
        else:
            weights = jnp.asarray(w, dtype=dtype)
        b = None if bias is None else jnp.asarray(bias, dtype=jnp.float32)
        return cls(w.shape[0], w.shape[1], sparse, weights, b, density)

    def __call__(self, x: jnp.ndarray, *, impl: str | None = None) -> jnp.ndarray:
        """x: (n_in,) or (..., n_in) -> (n_out,) or (..., n_out)."""
        squeeze = x.ndim == 1
        xb = x.reshape(-1, self.n_in) if not squeeze else x[None, :]
        if self.sparse:
            y = ops.espim_matvec(self.weights, xb.T, impl=impl).T
        else:
            y = xb.astype(jnp.float32) @ self.weights.astype(jnp.float32).T
        if self.bias is not None:
            y = y + self.bias
        y = y.reshape(x.shape[:-1] + (self.n_out,)) if not squeeze else y[0]
        return y


@dataclasses.dataclass
class ESPIMGroupLinear:
    """Several projections sharing one input, packed as ONE fused group —
    the PackGroup contract (DESIGN.md section 10) as a standalone layer.

    The member matrices are row-concatenated (their combined per-row nnz
    drives one shared balance permutation and one set of width buckets)
    and a single SpMV launch computes every member; ``espim_matvec``'s
    unscatter restores logical row order, so ``__call__`` returns a dict
    of per-projection outputs identical to running each member alone —
    at one launch instead of len(names).
    """

    names: tuple
    sizes: tuple          # n_out per projection, in ``names`` order
    n_in: int
    weights: object       # EspimWeights | QuantEspimWeights of the fused pack
    density: float = 1.0

    @classmethod
    def from_dense(
        cls,
        named_ws: dict,
        *,
        prune_sparsity: float | None = None,
        row_tile: int = 128,
        chunk_cols: int = ops.DEFAULT_CHUNK_COLS,
        dtype=jnp.float32,
        quant=None,
    ) -> "ESPIMGroupLinear":
        """``named_ws``: {name: (n_out, n_in)} sharing ``n_in`` (e.g.
        ``{"wq": ..., "wk": ..., "wv": ...}`` — GQA row counts may
        differ).  Prunes each member, row-concatenates, and packs once."""
        names = tuple(named_ws)
        mats = []
        for n in names:
            w = np.asarray(named_ws[n])
            if prune_sparsity is not None:
                w = magnitude_prune(w, prune_sparsity)
            mats.append(w)
        n_in = mats[0].shape[1]
        if any(m.shape[1] != n_in for m in mats):
            raise ValueError("group members must share the input dim")
        cat = np.concatenate(mats, axis=0)
        pack = pack_ell_chunked(cat, row_tile=row_tile,
                                chunk_cols=chunk_cols)
        if quant in ("none",):
            quant = None
        weights = ops.pack_to_device(pack, dtype=dtype, quant=quant)
        return cls(names, tuple(m.shape[0] for m in mats), n_in, weights,
                   float((cat != 0).mean()))

    def __call__(self, x: jnp.ndarray, *, impl: str | None = None) -> dict:
        """x: (n_in,) or (..., n_in) -> {name: (n_out_name,) or
        (..., n_out_name)} — one fused launch for the whole group."""
        squeeze = x.ndim == 1
        xb = x.reshape(-1, self.n_in) if not squeeze else x[None, :]
        y = ops.espim_matvec(self.weights, xb.T, impl=impl).T
        out, r0 = {}, 0
        for name, n_out in zip(self.names, self.sizes):
            seg = y[:, r0:r0 + n_out]
            seg = (seg.reshape(x.shape[:-1] + (n_out,)) if not squeeze
                   else seg[0])
            out[name] = seg
            r0 += n_out
        return out


# --------------------------------------------------------------------------
# Distributed sparse MV (devices as banks)
# --------------------------------------------------------------------------
def make_sharded_weights(
    w: np.ndarray,
    n_shards: int,
    *,
    prune_sparsity: float | None = None,
    row_tile: int = 128,
) -> dict:
    """Offline: prune + pack + re-layout for shard_map over ``model``."""
    w = np.asarray(w)
    if prune_sparsity is not None:
        w = magnitude_prune(w, prune_sparsity)
    pack = pack_ell(w, row_tile=row_tile)
    return shard_ell(pack, n_shards)


def espim_matvec_sharded(
    sharded: dict,
    x: jnp.ndarray,
    mesh,
    axis: str = "model",
    *,
    impl: str | None = "ref",
) -> jnp.ndarray:
    """y (n_rows,) = W @ x with W's packed rows sharded over ``axis``.

    x is replicated (the broadcast); each device computes its packed rows;
    the unscatter runs sharded as well (each device owns a disjoint output
    slice of the packed order; the permutation to original row order is an
    all-to-all the compiler lays out).
    """
    values = jnp.asarray(sharded["values"])   # (S, per, L)
    cols = jnp.asarray(sharded["cols"])       # (S, per, L)
    perm = jnp.asarray(sharded["perm"])       # (S, per)
    n_rows = sharded["n_rows"]

    def bank(values_s, cols_s, x_rep):
        # one "bank": local packed rows x replicated vector
        yp = ops.espim_spmv(values_s[0], cols_s[0], x_rep, impl=impl)
        return yp[None]

    yp = compat.shard_map(
        bank,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P()),
        out_specs=P(axis),
    )(values, cols, x)
    return kref.scatter_rows_ref(yp.reshape(-1), perm.reshape(-1), n_rows)
