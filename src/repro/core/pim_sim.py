"""Cycle-level PIM simulation: ESPIM vs Newton, SpaceA, Ideal Non-PIM, GPU.

Timing follows Section IV (Table II HBM2E-like parameters): one bank column
I/O is 256 bits every t_CCD = 4 DRAM cycles; 16 banks per channel operate in
lockstep; all-bank activation replaces Newton's staggered four-bank groups
(Section II-A), charged t_RCD + t_RP per DRAM row of column reads; the host
pin bus moves ``ext_bus_bytes_per_cycle`` per DRAM core cycle.

Reference-architecture models (Section IV "Methodology"):

* **Newton** — dense PIM; reads the *uncompressed* matrix; one vector-slice
  broadcast rate-matched to each column read; 16 MACs/bank.
* **SpaceA** — equal-area sparse PIM with 3 MACs/bank (CACTI estimate in the
  paper), rate-matched to the column cadence, so its useful throughput is 3
  MACs per t_CCD window; reads the compressed matrix.
* **Ideal Non-PIM** — upper bound on any non-PIM system: execution time is
  exactly the pin-transfer time of the (compressed) matrix + vector +
  results.
* **GPU** — a Titan-X-like host measured by the paper through GPGPUsim +
  Cutlass.  We cannot re-run their simulator, so the GPU is modelled as
  pin-bound on the *uncompressed* matrix with a fixed inefficiency factor
  ``gpu_inefficiency`` calibrated once against Figure 10's anchors
  (Newton ~55x, Ideal Non-PIM ~28x mean over GPU); all ESPIM-vs-Newton /
  vs-Ideal / energy claims are derived from the simulator, never from this
  constant.

Calibration notes (documented, see EXPERIMENTS.md):
  pin bus = 25.6 GB/s per channel (64-bit @ 3.2 Gbps) / 1.2 GHz DRAM core
  = ~21.3 B per DRAM cycle -> ext_bus_bytes_per_cycle = 21.3.
  Ideal Non-PIM compressed cell = 23 bits (FP16 + 7 metadata, Section III-C).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.sdds import ESPIMConfig, Schedule, schedule_matrix

__all__ = [
    "PIMTimingConfig",
    "CycleReport",
    "espim_cycles",
    "newton_cycles",
    "spacea_cycles",
    "ideal_nonpim_cycles",
    "gpu_cycles",
    "simulate_matrix",
    "activation_host_cycles",
]


@dataclasses.dataclass(frozen=True)
class PIMTimingConfig:
    ext_bus_bytes_per_cycle: float = 21.3
    act_overhead_cycles: int = 20          # t_RCD + t_RP per DRAM row
    compressed_bits_per_cell: int = 23     # FP16 value + 7 metadata bits
    dense_bits_per_cell: int = 16
    spacea_macs_per_bank: int = 3          # equal-area CACTI estimate
    gpu_inefficiency: float = 11.0         # calibrated vs Fig 10 anchors
    host_act_cycles_per_elem: float = 2.0  # vectorized softmax/act on host


@dataclasses.dataclass
class CycleReport:
    arch: str
    cycles: float
    breakdown: dict
    schedule: Schedule | None = None

    def speedup_over(self, other: "CycleReport") -> float:
        return other.cycles / self.cycles


# --------------------------------------------------------------------------
# ESPIM
# --------------------------------------------------------------------------
def espim_cycles(
    sched: Schedule, cfg: ESPIMConfig, tcfg: PIMTimingConfig = PIMTimingConfig()
) -> CycleReport:
    """Convert an SDDS command stream into DRAM cycles."""
    col = sched.column_reads * cfg.tccd
    act = sched.all_act * tcfg.act_overhead_cycles
    rd = sched.rdres_elems * 2 / tcfg.ext_bus_bytes_per_cycle
    gb = sched.load_gb_bytes / tcfg.ext_bus_bytes_per_cycle
    total = col + act + rd + gb
    return CycleReport(
        "espim",
        total,
        {
            "column_reads": col,
            "activation": act,
            "result_readout": rd,
            "vector_load": gb,
            "stall_frac": sched.comp_nobr / max(1, sched.compute_slots),
        },
        schedule=sched,
    )


# --------------------------------------------------------------------------
# Newton (dense PIM; also ESPIM's flexible-dense path, Section III-I)
# --------------------------------------------------------------------------
def newton_cycles(
    n_rows: int,
    n_cols: int,
    cfg: ESPIMConfig = ESPIMConfig(),
    tcfg: PIMTimingConfig = PIMTimingConfig(),
) -> CycleReport:
    cells = n_rows * n_cols
    # lockstep column reads: the slowest bank paces the channel
    rows_bank = -(-n_rows // cfg.n_banks)
    slots = rows_bank * -(-n_cols // cfg.dense_macs_per_bank)
    col = slots * cfg.tccd
    acts = -(-slots // cfg.cols_per_dram_row)
    act = acts * tcfg.act_overhead_cycles
    n_vr = max(1, -(-n_cols // cfg.vector_row_elems))
    rd = n_rows * n_vr * 2 / tcfg.ext_bus_bytes_per_cycle  # scalar per row per vector-row
    gb = n_cols * 2 / tcfg.ext_bus_bytes_per_cycle
    total = col + act + rd + gb
    return CycleReport(
        "newton",
        total,
        {"column_reads": col, "activation": act, "result_readout": rd,
         "vector_load": gb, "cells": cells},
    )


# --------------------------------------------------------------------------
# SpaceA (equal-area sparse PIM, Section IV)
# --------------------------------------------------------------------------
def spacea_cycles(
    nnz: int,
    n_rows: int,
    n_cols: int,
    cfg: ESPIMConfig = ESPIMConfig(),
    tcfg: PIMTimingConfig = PIMTimingConfig(),
) -> CycleReport:
    nnz_bank = -(-nnz // cfg.n_banks)  # SpaceA balances by nnz itself
    mac = nnz_bank * cfg.tccd / tcfg.spacea_macs_per_bank
    # compressed column reads through the scratchpad path
    col = (-(-nnz_bank // cfg.macs_per_bank)) * cfg.tccd
    compute = max(mac, col)
    acts = -(-compute // (cfg.cols_per_dram_row * cfg.tccd))
    act = acts * tcfg.act_overhead_cycles
    gb = n_cols * 2 / tcfg.ext_bus_bytes_per_cycle
    rd = n_rows * 2 / tcfg.ext_bus_bytes_per_cycle
    total = compute + act + gb + rd
    return CycleReport(
        "spacea", total,
        {"mac_bound": mac, "column_reads": col, "activation": act,
         "vector_load": gb, "result_readout": rd},
    )


# --------------------------------------------------------------------------
# Ideal Non-PIM (pin-bandwidth bound upper bound on any non-PIM system)
# --------------------------------------------------------------------------
def ideal_nonpim_cycles(
    nnz: int,
    n_rows: int,
    n_cols: int,
    tcfg: PIMTimingConfig = PIMTimingConfig(),
) -> CycleReport:
    mat_bytes = nnz * tcfg.compressed_bits_per_cell / 8
    io_bytes = (n_rows + n_cols) * 2
    total = (mat_bytes + io_bytes) / tcfg.ext_bus_bytes_per_cycle
    return CycleReport(
        "ideal_nonpim", total,
        {"matrix_bytes": mat_bytes, "io_bytes": io_bytes},
    )


# --------------------------------------------------------------------------
# GPU reference (calibrated; see module docstring)
# --------------------------------------------------------------------------
def gpu_cycles(
    n_rows: int,
    n_cols: int,
    tcfg: PIMTimingConfig = PIMTimingConfig(),
) -> CycleReport:
    mat_bytes = n_rows * n_cols * tcfg.dense_bits_per_cell / 8
    total = mat_bytes / tcfg.ext_bus_bytes_per_cycle * tcfg.gpu_inefficiency
    return CycleReport("gpu", total, {"matrix_bytes": mat_bytes})


def activation_host_cycles(
    n_rows: int, tcfg: PIMTimingConfig = PIMTimingConfig()
) -> float:
    """Host-side ML activation-function overhead (Section III-H): simple
    functions hide under result read-out; softmax-like scans are vectorized
    on the host and charged per output element."""
    return n_rows * tcfg.host_act_cycles_per_elem


# --------------------------------------------------------------------------
# One-call comparison for a weight matrix
# --------------------------------------------------------------------------
def simulate_matrix(
    w: np.ndarray,
    cfg: ESPIMConfig = ESPIMConfig(),
    tcfg: PIMTimingConfig = PIMTimingConfig(),
    include_host_act: bool = True,
    archs: tuple = ("espim", "newton", "spacea", "ideal_nonpim", "gpu"),
) -> dict:
    """Simulate one MV on every architecture; returns {arch: CycleReport}."""
    w = np.asarray(w)
    n_rows, n_cols = w.shape
    nnz = int((w != 0).sum())
    out: dict[str, CycleReport] = {}
    host_act = activation_host_cycles(n_rows, tcfg) if include_host_act else 0.0
    if "espim" in archs:
        sched, _ = schedule_matrix(w, cfg)
        rep = espim_cycles(sched, cfg, tcfg)
        rep.cycles += host_act
        rep.breakdown["host_act"] = host_act
        out["espim"] = rep
    if "espim_ideal" in archs:
        # no stalls, no dummies: pure column-bandwidth bound on nnz
        slots = -(-nnz // (cfg.n_banks * cfg.macs_per_bank))
        col = slots * cfg.tccd
        act = -(-slots // cfg.cols_per_dram_row) * tcfg.act_overhead_cycles
        n_vr = max(1, -(-n_cols // cfg.vector_row_elems))
        gb = n_cols * 2 * 1 / tcfg.ext_bus_bytes_per_cycle
        rep = CycleReport("espim_ideal", col + act + gb + host_act,
                          {"column_reads": col, "activation": act,
                           "vector_load": gb, "host_act": host_act})
        out["espim_ideal"] = rep
    if "newton" in archs:
        rep = newton_cycles(n_rows, n_cols, cfg, tcfg)
        rep.cycles += host_act
        rep.breakdown["host_act"] = host_act
        out["newton"] = rep
    if "spacea" in archs:
        rep = spacea_cycles(nnz, n_rows, n_cols, cfg, tcfg)
        rep.cycles += host_act
        out["spacea"] = rep
    if "ideal_nonpim" in archs:
        out["ideal_nonpim"] = ideal_nonpim_cycles(nnz, n_rows, n_cols, tcfg)
    if "gpu" in archs:
        rep = gpu_cycles(n_rows, n_cols, tcfg)
        rep.cycles += host_act
        out["gpu"] = rep
    return out
