"""Static Data-Dependent Scheduling (SDDS) — faithful to Sections III-D/E/F/G.

SDDS is the paper's central mechanism: because the sparsity pattern is static
and known at training time, the *entire cycle-level command stream* of the
sparse MV (which slots broadcast a new vector slice ``COMP-BR``, which stall
and re-use the latched slice ``COMP-NoBR``, where index-only prefetch reads
``LOAD-IDX`` go, and where dummy/invalid cells pad the compressed matrix) is
derived **once, offline**, by simulating the machine.  The host then replays
the stream; the DRAM-side datapath stays headless.

This module implements that offline construction as two slot-stepped
machines, selected by ``ESPIMConfig.prefetch``:

* machine A (Section III-D, no decoupling): each compute slot consumes at
  most one cell per MAC and only if the cell's column falls in the currently
  latched vector slice; otherwise the compressed matrix gets an invalid cell.
* machine B (Sections III-E/F, full ESPIM): per-MAC iFIFO (prefetched
  indices) and eFIFO (extracted vector elements) decouple the column-reads
  from the broadcasts; the 4x11 simplified switch constrains extraction to
  ascending index-range chains within each t_CCD window; SDDS's reorder pass
  permutes same-slice cells into ascending-range chains to dodge conflicts.

Load balance (Section III-G): SparTen's greedy scheme assigns rows to banks
round-robin by density, then co-locates the densest and the sparsest row *on
the same MAC* — their cells intermingled in increasing column order with a
per-cell ``select`` bit steering accumulation into one of two output buffers.
That is why ``rows_per_mac = 2``: each MAC's stream is the column-merged pair,
and the pair's combined nnz is what the greedy sort equalizes.

The broadcast-advance rule is global across banks (the banks run in lockstep
off one broadcast bus): the next slice is broadcast only when no bank has a
pending cell (in an iFIFO or still unread in its stream) matching the current
slice — the paper's "current slice consumed fully across all the banks".
Per-MAC column order is non-decreasing in slice (reorder only permutes within
a slice), which makes this rule sufficient for correctness; ``verify=True``
executes the dataflow and checks it against a numpy dot product.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque

import numpy as np

from repro.core.integrity import plan_fingerprint
from repro.core.pruning import sparten_balance

__all__ = [
    "ESPIMConfig",
    "Schedule",
    "build_bank_streams",
    "schedule_matrix",
    "ChunkPlan",
    "chunk_cells",
    "plan_chunks",
    "WidthBucketPlan",
    "plan_width_buckets",
    "PackGroupSpec",
    "validate_group_specs",
    "decoder_layer_groups",
    "KernelSchedule",
    "DEFAULT_SCHEDULE",
    "schedule_legal",
    "enumerate_schedules",
]


# --------------------------------------------------------------------------
# Configuration (Table I commands, Table II DRAM parameters)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ESPIMConfig:
    n_banks: int = 16
    macs_per_bank: int = 11          # k: sparse cells per 256-bit column read
    dense_macs_per_bank: int = 16    # Newton / flexible-dense path
    slice_elems: int = 16            # vector slice per broadcast (256 bits)
    fifo_depth: int = 8              # iFIFO and eFIFO entries per MAC
    tccd: int = 4                    # DRAM cycles between column reads
    switch_ranges: int = 4           # simplified switch: 4 ranges x 4 elems
    cols_per_dram_row: int = 32      # 8K bits / 256-bit column I/O
    vector_row_elems: int = 512      # 1KB DRAM row / 2B element
    idx_per_mac_idxread: int = 3     # ~23 spare bits/MAC in an idx-only read
    decouple_dist: int = 6           # prefetch depth targeted at stripe start
    rows_per_mac: int = 2            # select bit + 2 output buffers (III-G)
    # DRAM timing (Table II, DRAM cycles)
    t_rcd: int = 10
    t_rp: int = 10
    t_ras: int = 24
    t_rtp: int = 5
    # feature toggles (Figure 11 ablation)
    prefetch: bool = True
    reorder: bool = True
    balance: bool = True
    full_switch: bool = False        # brute-force 16x11 switch

    @property
    def range_width(self) -> int:
        return self.slice_elems // self.switch_ranges

    @property
    def slices_per_vector_row(self) -> int:
        return self.vector_row_elems // self.slice_elems

    def replace(self, **kw) -> "ESPIMConfig":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# Schedule result
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Schedule:
    """Counters of the statically derived command stream (Table I)."""

    comp_br: int = 0        # compute + broadcast slots
    comp_nobr: int = 0      # compute + stalled-broadcast slots
    load_idx: int = 0       # index-only prefetch column reads
    all_act: int = 0        # all-bank activations
    rdres_elems: int = 0    # result elements read out to host
    load_gb_bytes: int = 0  # vector bytes loaded into the global buffer
    mac_ops: int = 0        # real multiply-accumulates executed
    dummy_cells: int = 0    # invalid/placeholder cells in the compressed matrix
    ififo_pushes: int = 0
    efifo_pushes: int = 0
    nnz: int = 0
    n_stripes: int = 0
    vector_rows: int = 0

    @property
    def compute_slots(self) -> int:
        return self.comp_br + self.comp_nobr

    @property
    def column_reads(self) -> int:
        return self.compute_slots + self.load_idx

    @property
    def broadcasts(self) -> int:
        return self.comp_br

    @property
    def stalls(self) -> int:
        return self.comp_nobr

    def merge(self, other: "Schedule") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))

    def fingerprint(self) -> str:
        """Digest of the derived command stream — lets a replayed schedule
        be bound to the pack it was compiled against."""
        return plan_fingerprint(self)


# --------------------------------------------------------------------------
# Bank stream construction (load balance + fine-grained interleaving order)
# --------------------------------------------------------------------------
def build_bank_streams(pattern: np.ndarray, cfg: ESPIMConfig) -> list[list[int]]:
    """Assign matrix rows to banks; returns per-bank row-id lists in
    processing order.  With ``cfg.balance``, SparTen's greedy balance
    (Section III-G); otherwise round-robin original order."""
    pattern = np.asarray(pattern)
    n_rows = pattern.shape[0]
    nnz_per_row = (pattern != 0).sum(axis=1)
    if cfg.balance:
        assign = sparten_balance(nnz_per_row, cfg.n_banks)
        return [list(r) for r in assign.bank_rows]
    return [list(range(b, n_rows, cfg.n_banks)) for b in range(cfg.n_banks)]


def _reorder_in_slice(cols: np.ndarray, tags: np.ndarray, cfg: ESPIMConfig):
    """SDDS's switch-conflict-avoiding reorder (Section III-F).

    Within each vector slice, permute a MAC's cells into ascending-range
    chains: deal one index per range per pass (ranges in ascending order) so
    consecutive cells land in different mux ranges and extract in one t_CCD
    window instead of forcing head-of-line stalls.  Slice order is preserved
    (the broadcast-advance rule relies on per-MAC slice monotonicity).
    """
    if cols.size <= 1:
        return cols, tags
    out_c = np.empty_like(cols)
    out_t = np.empty_like(tags)
    slice_ids = cols // cfg.slice_elems
    pos = 0
    start = 0
    for end in range(1, cols.size + 1):
        if end == cols.size or slice_ids[end] != slice_ids[start]:
            n = end - start
            if n > 1:
                rel = cols[start:end] % cfg.slice_elems
                rng = rel // cfg.range_width
                buckets: list[deque] = [deque() for _ in range(cfg.switch_ranges)]
                for i in range(start, end):
                    buckets[int(rng[i - start])].append(i)
                emitted = []
                while len(emitted) < n:
                    for b in buckets:
                        if b:
                            emitted.append(b.popleft())
                out_c[pos : pos + n] = cols[emitted]
                out_t[pos : pos + n] = tags[emitted]
            else:
                out_c[pos : pos + n] = cols[start:end]
                out_t[pos : pos + n] = tags[start:end]
            pos += n
            start = end
    return out_c, out_t


# --------------------------------------------------------------------------
# Column-chunk grouping (the broadcast-sharing pass restated for VMEM)
# --------------------------------------------------------------------------
def chunk_cells(cols: np.ndarray, chunk_cols: int,
                n_chunks: int | None = None
                ) -> tuple[np.ndarray, np.ndarray]:
    """SDDS pass: stable-bucket one row's cells by column chunk.

    The paper advances one broadcast slice at a time and schedules every
    cell that consumes the latched slice before moving on; on TPU the
    "slice" is a ``chunk_cols``-wide slab of ``x`` resident in VMEM, and
    this pass is the same reorder one level up: permute a row's cells so
    all cells of chunk k are contiguous (and chunks appear in ascending
    order), which lets a (row-tile x col-chunk) kernel block touch exactly
    one ``x`` slab.  Stable, so any finer-grained order (ascending column,
    switch-conflict reorder) survives within each chunk.

    Returns ``(order, counts)``: ``cols[order]`` is chunk-grouped and
    ``counts[k]`` is the number of cells in chunk k.
    """
    cols = np.asarray(cols)
    if chunk_cols <= 0:
        raise ValueError(f"chunk_cols must be positive, got {chunk_cols}")
    chunk_of = cols // chunk_cols
    if n_chunks is None:
        n_chunks = int(chunk_of.max()) + 1 if cols.size else 1
    elif cols.size and int(chunk_of.max()) >= n_chunks:
        raise ValueError(
            f"column {int(cols.max())} falls past chunk {n_chunks - 1} "
            f"(chunk_cols={chunk_cols})")
    order = np.argsort(chunk_of, kind="stable")
    counts = np.bincount(chunk_of, minlength=n_chunks)
    return order, counts


@dataclasses.dataclass(frozen=True)
class ChunkPlan:
    """Static plan for the column-chunked layout of one matrix.

    The analogue of the schedule's broadcast accounting: ``active_blocks``
    counts the (row-tile x col-chunk) blocks holding at least one cell
    (each costs one ``x``-slab load, the COMP-BR analogue), and
    ``chunk_pad_frac`` is the extra static stall padding chunking adds on
    top of plain ELL.  ``x_bytes_per_step`` vs ``x_bytes_full`` is the
    VMEM-residency reduction the layout exists for.
    """

    chunk_cols: int
    n_chunks: int
    row_tile: int
    chunk_width: int        # Lc: padded cells per (row, chunk)
    nnz: int
    active_blocks: int
    total_blocks: int
    chunk_pad_frac: float   # 1 - nnz / (R_pad * n_chunks * Lc)
    x_bytes_full: int       # full-vector VMEM residency (old kernels)
    x_bytes_per_step: int   # one chunk slab (new kernels)

    @property
    def block_occupancy(self) -> float:
        return self.active_blocks / max(1, self.total_blocks)

    def fingerprint(self) -> str:
        """Digest of this plan — part of the pack's bound fingerprint
        (``core.integrity``), so pairing a pack with a foreign chunk plan
        fails verification."""
        return plan_fingerprint(self)


def plan_chunks(counts: np.ndarray, *, chunk_cols: int, row_tile: int,
                n_cols: int, width_multiple: int = 8,
                elem_bytes: int = 4) -> ChunkPlan:
    """Derive the ChunkPlan from per-(row, chunk) cell counts.

    ``counts`` is (R_pad, n_chunks) as produced by ``chunk_cells`` row by
    row; the chunk width Lc is the global max rounded up for sublane
    alignment (uniform width keeps the kernel grid regular — banks in
    lockstep, exactly like the paper's global ELL width).
    """
    counts = np.asarray(counts)
    r_pad, n_chunks = counts.shape
    lc = int(counts.max()) if counts.size else 0
    lc = max(width_multiple,
             -(-max(lc, 1) // width_multiple) * width_multiple)
    nnz = int(counts.sum())
    n_tiles = max(1, r_pad // max(1, row_tile))
    tile_active = counts.reshape(n_tiles, -1, n_chunks).sum(axis=1) > 0
    padded = r_pad * n_chunks * lc
    return ChunkPlan(
        chunk_cols=chunk_cols,
        n_chunks=n_chunks,
        row_tile=row_tile,
        chunk_width=lc,
        nnz=nnz,
        active_blocks=int(tile_active.sum()),
        total_blocks=n_tiles * n_chunks,
        chunk_pad_frac=1.0 - (nnz / padded if padded else 0.0),
        x_bytes_full=n_cols * elem_bytes,
        x_bytes_per_step=chunk_cols * elem_bytes,
    )


# --------------------------------------------------------------------------
# Width bucketing (per-segment ELL widths instead of one global max)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class WidthBucketPlan:
    """Partition of the packed (density-sorted) rows into <= n_buckets
    contiguous segments, each padded to its own ELL width.

    The paper pads every MAC stream to the stripe's lockstep length; one
    global width makes the whole matrix pay for its densest row.  Because
    ``row_tile_balance`` sorts rows by nnz, widths decay monotonically down
    the packed order, so a handful of contiguous segments ("buckets") with
    per-bucket widths recovers most of the padding a single global width
    wastes.  Boundaries are chosen by exact DP over fixed-size row groups,
    minimizing total padded slots; an extra bucket is kept only if it saves
    more than ``slack`` of the single-bucket cost (each bucket is one more
    kernel launch at serving time).
    """

    boundaries: tuple       # ((row_start, row_end, width), ...) packed order
    group: int              # row granularity the DP ran at
    padded_slots: int       # sum over buckets of rows * width (per chunk)
    single_bucket_slots: int  # cost of the global-max-width layout
    widths_per_group: tuple

    @property
    def n_buckets(self) -> int:
        return len(self.boundaries)

    @property
    def savings_frac(self) -> float:
        if not self.single_bucket_slots:
            return 0.0
        return 1.0 - self.padded_slots / self.single_bucket_slots

    def fingerprint(self) -> str:
        """Digest of this plan (see ``ChunkPlan.fingerprint``)."""
        return plan_fingerprint(self)


def _bucket_width(w: int, width_multiple: int) -> int:
    return max(width_multiple, -(-max(int(w), 1) // width_multiple)
               * width_multiple)


def plan_width_buckets(widths, *, rows_per_group: int, n_buckets: int = 4,
                       width_multiple: int = 8,
                       slack: float = 0.02) -> WidthBucketPlan:
    """Choose bucket boundaries over per-group max cell counts.

    ``widths[g]`` is the max per-(row, chunk) cell count over row group
    ``g`` (``rows_per_group`` packed rows).  Exact DP partitions the groups
    into at most ``n_buckets`` contiguous segments minimizing total padded
    slots (each segment pays rows * round_up(segment max)); among bucket
    counts within ``slack`` of the optimum the smallest count wins.
    """
    widths = np.asarray(widths, dtype=np.int64)
    n = widths.size
    if n == 0:
        raise ValueError("empty widths")
    if rows_per_group <= 0:
        raise ValueError(f"rows_per_group must be positive, got {rows_per_group}")
    n_buckets = max(1, min(n_buckets, n))

    # seg_cost[i][j] = padded slots of one bucket spanning groups [i, j)
    seg_max = np.zeros((n, n + 1), dtype=np.int64)
    for i in range(n):
        m = 0
        for j in range(i + 1, n + 1):
            m = max(m, widths[j - 1])
            seg_max[i, j] = _bucket_width(m, width_multiple)

    def seg_cost(i, j):
        return (j - i) * rows_per_group * seg_max[i, j]

    inf = np.iinfo(np.int64).max
    # best[k][j] = min cost covering groups [0, j) with exactly k buckets
    best = np.full((n_buckets + 1, n + 1), inf, dtype=np.int64)
    back = np.zeros((n_buckets + 1, n + 1), dtype=np.int64)
    best[0, 0] = 0
    for k in range(1, n_buckets + 1):
        for j in range(1, n + 1):
            for i in range(k - 1, j):
                if best[k - 1, i] == inf:
                    continue
                c = best[k - 1, i] + seg_cost(i, j)
                if c < best[k, j]:
                    best[k, j] = c
                    back[k, j] = i

    single = seg_cost(0, n)
    optimum = min(int(best[k, n]) for k in range(1, n_buckets + 1))
    chosen_k = next(k for k in range(1, n_buckets + 1)
                    if best[k, n] <= optimum + slack * single)
    cuts = [n]
    j = n
    for k in range(chosen_k, 0, -1):
        j = int(back[k, j])
        cuts.append(j)
    cuts.reverse()
    boundaries = tuple(
        (cuts[i] * rows_per_group, cuts[i + 1] * rows_per_group,
         int(seg_max[cuts[i], cuts[i + 1]]))
        for i in range(chosen_k)
    )
    return WidthBucketPlan(
        boundaries=boundaries,
        group=rows_per_group,
        padded_slots=int(best[chosen_k, n]),
        single_bucket_slots=int(single),
        widths_per_group=tuple(int(w) for w in widths),
    )


# --------------------------------------------------------------------------
# Pack groups (projection-generic SDDS compilation units)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PackGroupSpec:
    """Declarative spec for one *pack group*: a set of same-input
    projections compiled into ONE width-bucketed layer-stacked pack under
    ONE balance permutation and one set of width buckets.

    The paper's format and scheduling are projection-agnostic — every MV
    of the decode step gets fine-grained interleaving, balance permutation
    and decoupled value/index planes — so pack/partition planning is a
    reusable compilation pass over group specs, not per-matrix special
    cases.

    * ``projections``: parameter leaf names under
      ``params["layers"][module]``, row-concatenated in this order (rows
      of the packed matrix are the projections' *output* dims).
    * ``fuse``: how the projections share the pack.

      - ``"concat"``: row-concatenated into one matrix (per-projection
        row counts may differ — QKV under GQA).  The group output is a
        packed-order vector whose logical split points are the recorded
        per-projection row offsets.
      - ``"halves"``: every projection is one *half* of each bucket under
        a SHARED permutation (requires identical shapes); half outputs
        pair up elementwise in packed order, so products between them
        (``act(gate) * up``) need no unscatter.

    * ``compose_with``: name of an upstream group whose packed output
      this group consumes.  The group's column ids are pre-composed
      OFFLINE with the upstream packed order (its gather domain becomes
      the upstream ``r_pad``), deleting the inter-group permutation from
      the per-token path.
    * ``output``: the group's output contract.

      - ``"take"``: one static ``jnp.take`` by ``inv_perm`` restores
        logical row order at runtime.  Required whenever the consumer
        needs logical positions — QKV must unscatter because RoPE pairs
        head dims positionally and the paged KV cache stores logical
        head rows; the O/down projections feed the residual stream.
      - ``"folded"``: the output stays in packed order and exactly one
        downstream group declares ``compose_with`` = this group (gate+up
        feeding down).
    """

    name: str
    projections: tuple
    module: str = "mlp"          # params["layers"][<module>][<projection>]
    fuse: str = "concat"         # "concat" | "halves"
    compose_with: str | None = None
    output: str = "take"         # "take" | "folded"

    def __post_init__(self):
        if not self.projections:
            raise ValueError(f"group {self.name!r} lists no projections")
        if self.fuse not in ("concat", "halves"):
            raise ValueError(f"group {self.name!r}: unknown fuse "
                             f"{self.fuse!r}")
        if self.output not in ("take", "folded"):
            raise ValueError(f"group {self.name!r}: unknown output "
                             f"{self.output!r}")

    def fingerprint(self) -> str:
        """Digest of this spec (see ``ChunkPlan.fingerprint``)."""
        return plan_fingerprint(self)


def validate_group_specs(specs) -> dict:
    """Check a group-spec list's fold/compose contract; returns
    ``{name: spec}`` in compilation order.

    * names and projection leaves are unique;
    * ``compose_with`` must reference an *earlier* group (packs compile
      in order, the composed group needs the upstream packed order);
    * ``output="folded"`` requires exactly one downstream consumer
      composing with the group (a folded output that nobody composes
      with would never return to logical order), and ``output="take"``
      requires none (the take would double-unscatter).
    """
    by_name: dict = {}
    seen_proj: set = set()
    for s in specs:
        if s.name in by_name:
            raise ValueError(f"duplicate group name {s.name!r}")
        for p in s.projections:
            key = (s.module, p)
            if key in seen_proj:
                raise ValueError(
                    f"projection {s.module}/{p} appears in two groups")
            seen_proj.add(key)
        by_name[s.name] = s
    consumers: dict = {}
    for s in specs:
        if s.compose_with is not None:
            if s.compose_with not in by_name:
                raise ValueError(
                    f"group {s.name!r} composes with unknown group "
                    f"{s.compose_with!r}")
            if list(by_name).index(s.compose_with) >= list(by_name).index(
                    s.name):
                raise ValueError(
                    f"group {s.name!r} composes with {s.compose_with!r}, "
                    f"which must be compiled earlier")
            consumers.setdefault(s.compose_with, []).append(s.name)
    for s in specs:
        n = len(consumers.get(s.name, ()))
        if s.output == "folded" and n != 1:
            raise ValueError(
                f"group {s.name!r} has output='folded' but {n} composing "
                f"consumers (need exactly 1)")
        if s.output == "take" and n != 0:
            raise ValueError(
                f"group {s.name!r} has output='take' but downstream "
                f"groups compose with its packed order")
    return by_name


def decoder_layer_groups(gated: bool = True, attn: bool = True,
                         mlp: bool = True) -> tuple:
    """The standard decoder-layer group set.

    MLP: gate+up as shared-perm halves folding into the perm-composed
    down projection.  Attention: q/k/v row-concatenated (one SpMV, output
    unscattered by one static take so RoPE head pairing and KV-cache
    writes see logical order) and the O projection feeding the residual.
    """
    specs: list = []
    if attn:
        specs += [
            PackGroupSpec("qkv", ("wq", "wk", "wv"), module="attn",
                          fuse="concat", output="take"),
            PackGroupSpec("attn_out", ("wo",), module="attn",
                          fuse="concat", output="take"),
        ]
    if mlp:
        gu = ("w_gate", "w_up") if gated else ("w_up",)
        specs += [
            PackGroupSpec("gateup", gu, module="mlp", fuse="halves",
                          output="folded"),
            PackGroupSpec("down", ("w_down",), module="mlp", fuse="concat",
                          compose_with="gateup", output="take"),
        ]
    return tuple(specs)


# --------------------------------------------------------------------------
# Kernel schedule space (the autotuner's candidate set — DESIGN.md §15)
#
# SDDS's premise is that every scheduling decision can be made offline
# because the sparsity is static.  The TPU adaptation has four such
# decisions left as hand-picked constants: the column-chunk width (x-slab
# VMEM residency and the chunk pass itself), the kernel's row/width block
# sizes, and the gather formulation.  ``KernelSchedule`` names one point in
# that space; ``enumerate_schedules`` + ``schedule_legal`` produce the
# candidate set the autotuner ranks and benchmarks.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class KernelSchedule:
    """One candidate SDDS kernel schedule for the chunked-ELL SpMV.

    ``chunk_cols`` is the offline chunk pass's slab width (re-chunking the
    pack is part of applying the schedule); ``block_r``/``block_l`` are the
    Pallas grid block sizes; ``gather`` picks the vectorized block-wide
    gather or the serial per-l loop.  On the ``ref`` lowering only
    ``chunk_cols`` is live — the rest ride along so one plan record covers
    both backends.
    """

    chunk_cols: int = 512
    block_r: int = 128
    block_l: int = 128
    gather: str = "block"

    def fingerprint(self) -> str:
        return plan_fingerprint(self)

    def effective_key(self, impl: str) -> tuple:
        """The knobs that actually change the launched computation for
        ``impl`` — candidates identical under this key are deduplicated
        before benchmarking (the ref lowering ignores the block sizes)."""
        if impl == "ref":
            return ("ref", self.chunk_cols)
        return ("pallas", self.chunk_cols, self.block_r, self.block_l,
                self.gather)


DEFAULT_SCHEDULE = KernelSchedule()


def schedule_legal(s: KernelSchedule, *, r_pad: int, n_cols: int,
                   quant: str | None = None) -> bool:
    """Candidate legality for a pack of ``r_pad`` packed rows over
    ``n_cols`` input columns, mirroring the kernels' own constraints:

    * the row block must shrink to a sublane-aligned divisor of R_pad
      (``_pad_inputs`` raises below gcd 8);
    * ``chunk_cols`` must be positive and is capped at ``n_cols`` by the
      chunk pass, so wider candidates collapse onto the single-chunk one;
    * nibble-packed int4 planes need an even ``block_l`` so nibble pairs
      never straddle blocks (the kernel rounds up — an odd candidate is
      just a duplicate of its even neighbour, so reject it);
    * ``gather`` must name a kernel formulation.
    """
    if s.chunk_cols <= 0 or s.block_r <= 0 or s.block_l <= 0:
        return False
    if s.gather not in ("block", "loop"):
        return False
    if math.gcd(r_pad, s.block_r) < 8:
        return False
    if s.chunk_cols > max(1, n_cols):
        return False        # collapses onto the chunk_cols == n_cols point
    if quant == "int4" and s.block_l % 2:
        return False
    return True


def enumerate_schedules(*, r_pad: int, n_cols: int, quant: str | None = None,
                        chunk_cols_options=(256, 512, 1024),
                        block_r_options=(64, 128),
                        block_l_options=(64, 128, 256),
                        gathers=("block", "loop")) -> list:
    """All legal candidates over the knob grid, default schedule first.
    ``chunk_cols == n_cols`` (single chunk) is always included — on small
    matrices it is often the only legal slab width."""
    ccs = sorted({min(cc, max(1, n_cols))
                  for cc in (*chunk_cols_options, n_cols)})
    out = []
    for cc in ccs:
        for br in block_r_options:
            for bl in block_l_options:
                for g in gathers:
                    s = KernelSchedule(chunk_cols=cc, block_r=br,
                                       block_l=bl, gather=g)
                    if schedule_legal(s, r_pad=r_pad, n_cols=n_cols,
                                      quant=quant):
                        out.append(s)
    default = DEFAULT_SCHEDULE
    if schedule_legal(default, r_pad=r_pad, n_cols=n_cols, quant=quant):
        out = [default] + [s for s in out if s != default]
    return out


# --------------------------------------------------------------------------
# Slot machines
# --------------------------------------------------------------------------
class _MacState:
    """Per-(bank, MAC) stream + FIFO state for one (vector-row, stripe).

    ``cols`` is the column-merged stream of this MAC's ``rows_per_mac`` rows
    (relative to the vector-row base); ``tags`` is the per-cell select bit;
    ``rows`` maps tag -> original matrix row id (or None).
    """

    __slots__ = ("cols", "tags", "rows", "slices", "ranges", "ip", "vp",
                 "ififo", "efifo")

    def __init__(self, cols: np.ndarray, tags: np.ndarray, rows, cfg: ESPIMConfig):
        self.cols = cols
        self.tags = tags
        self.rows = rows
        self.slices = cols // cfg.slice_elems
        self.ranges = (cols % cfg.slice_elems) // cfg.range_width
        self.ip = 0  # next index to load into the iFIFO
        self.vp = 0  # next value to multiply (paired with eFIFO head)
        self.ififo: deque = deque()
        self.efifo: deque = deque()

    @property
    def n(self) -> int:
        return len(self.cols)

    def done(self) -> bool:
        return self.vp >= self.n


class _ExecCtx:
    """Optional dataflow execution for verify mode."""

    __slots__ = ("x_row", "values", "lo", "acc")

    def __init__(self, x_row, values, lo, n_macs, rows_per_mac):
        self.x_row = x_row
        self.values = values
        self.lo = lo
        self.acc = np.zeros((n_macs, rows_per_mac), dtype=np.float64)

    def fire(self, mi: int, m: _MacState) -> None:
        c = m.cols[m.vp]
        t = m.tags[m.vp]
        row = m.rows[t]
        self.acc[mi, t] += self.values[row, self.lo + c] * self.x_row[c]


def _machine_prefetch(
    macs: list[_MacState], cfg: ESPIMConfig, sched: Schedule, ctx: _ExecCtx | None
) -> None:
    """Machine B: full ESPIM with decoupled prefetch + simplified switch."""
    n_slices = cfg.slices_per_vector_row
    total = sum(m.n for m in macs)
    if total == 0:
        return
    # --- prologue LOAD-IDX reads establish the decoupling distance -------
    need = -(-min(cfg.decouple_dist, cfg.fifo_depth)
             // max(1, cfg.idx_per_mac_idxread))
    for _ in range(max(0, need)):
        pushed_any = False
        for m in macs:
            for _ in range(cfg.idx_per_mac_idxread):
                if m.ip < m.n and len(m.ififo) < cfg.fifo_depth:
                    m.ififo.append(m.ip)
                    m.ip += 1
                    sched.ififo_pushes += 1
                    pushed_any = True
        if pushed_any:
            sched.load_idx += 1

    cur = -1  # latched slice id; first COMP-BR latches slice 0
    guard, max_slots = 0, 64 * (total + n_slices * len(macs) + 64)
    while not all(m.done() for m in macs):
        guard += 1
        if guard > max_slots:  # pragma: no cover - safety net
            raise RuntimeError("SDDS prefetch machine failed to converge (bug)")
        # ---- broadcast-advance decision (global across banks) -----------
        blocked = False
        for m in macs:
            if m.ififo:
                if m.slices[m.ififo[0]] <= cur:
                    blocked = True
                    break
            elif m.ip < m.n:
                # empty iFIFO with unread indices: conservative stall
                # (Section III-E case 1) once something is latched.
                if cur >= 0 and m.slices[m.ip] <= cur:
                    blocked = True
                    break
        if blocked or cur + 1 >= n_slices:
            sched.comp_nobr += 1
        else:
            sched.comp_br += 1
            cur += 1
        # ---- compute: column-read values x eFIFO heads -------------------
        for mi, m in enumerate(macs):
            if m.vp < m.n and m.efifo:
                m.efifo.popleft()
                if ctx is not None:
                    ctx.fire(mi, m)
                m.vp += 1
                sched.mac_ops += 1
            else:
                sched.dummy_cells += 1
        # ---- index side of the normal column read ------------------------
        for m in macs:
            if m.ip < m.n:
                if len(m.ififo) < cfg.fifo_depth:
                    m.ififo.append(m.ip)
                    m.ip += 1
                    sched.ififo_pushes += 1
                else:
                    sched.dummy_cells += 1  # placeholder, dropped at the bank
        # ---- switch: extract matching elements into eFIFOs ---------------
        if cur >= 0:
            for m in macs:
                last_range, pulled = -1, 0
                while (
                    m.ififo
                    and m.slices[m.ififo[0]] == cur
                    and len(m.efifo) < cfg.fifo_depth
                ):
                    head = m.ififo[0]
                    if cfg.full_switch:
                        if pulled >= cfg.tccd:
                            break
                    else:
                        r = m.ranges[head]
                        if r <= last_range:
                            break
                        last_range = r
                    m.ififo.popleft()
                    m.efifo.append(head)
                    pulled += 1
                    sched.efifo_pushes += 1


def _machine_basic(
    macs: list[_MacState], cfg: ESPIMConfig, sched: Schedule, ctx: _ExecCtx | None
) -> None:
    """Machine A (Section III-D): no decoupling; one cell per MAC per slot,
    and only when it matches the latched slice."""
    n_slices = cfg.slices_per_vector_row
    if sum(m.n for m in macs) == 0:
        return
    cur = -1
    guard, max_slots = 0, 64 * (sum(m.n for m in macs) + n_slices * len(macs) + 64)
    while not all(m.done() for m in macs):
        guard += 1
        if guard > max_slots:  # pragma: no cover
            raise RuntimeError("SDDS basic machine failed to converge (bug)")
        blocked = any(
            (not m.done()) and cur >= 0 and m.slices[m.vp] <= cur for m in macs
        )
        if blocked or cur + 1 >= n_slices:
            sched.comp_nobr += 1
        else:
            sched.comp_br += 1
            cur += 1
        for mi, m in enumerate(macs):
            if not m.done() and m.slices[m.vp] == cur:
                if ctx is not None:
                    ctx.fire(mi, m)
                m.vp += 1
                sched.mac_ops += 1
            else:
                sched.dummy_cells += 1


# --------------------------------------------------------------------------
# Whole-matrix scheduling
# --------------------------------------------------------------------------
def schedule_matrix(
    pattern: np.ndarray,
    cfg: ESPIMConfig = ESPIMConfig(),
    values: np.ndarray | None = None,
    x: np.ndarray | None = None,
    verify: bool = False,
) -> tuple[Schedule, np.ndarray | None]:
    """Run SDDS over a full matrix.

    ``pattern`` is the (R, C) sparse weight matrix (or boolean pattern).
    With ``verify=True`` the machines also execute the dataflow — each MAC
    accumulates value*element exactly when the schedule fires it, through
    the select-bit output buffers — and the resulting ``y`` is returned for
    comparison against ``values @ x``.

    Returns ``(Schedule, y_or_None)``.
    """
    pattern = np.asarray(pattern)
    n_rows, n_cols = pattern.shape
    if verify:
        if values is None:
            values = pattern.astype(np.float64)
        if x is None:
            rng = np.random.default_rng(0)
            x = rng.standard_normal(n_cols)
        values = np.asarray(values, dtype=np.float64)
        x = np.asarray(x, dtype=np.float64)

    bank_rows = build_bank_streams(pattern, cfg)
    cols_by_row = [np.nonzero(pattern[r])[0].astype(np.int64)
                   for r in range(n_rows)]

    k = cfg.macs_per_bank
    rpm = cfg.rows_per_mac
    rows_per_stripe = k * rpm
    n_stripes = max(
        (-(-len(rows) // rows_per_stripe) for rows in bank_rows if rows),
        default=0,
    )
    n_vr = max(1, -(-n_cols // cfg.vector_row_elems))
    sched = Schedule(nnz=int((pattern != 0).sum()), n_stripes=n_stripes,
                     vector_rows=n_vr)
    y = np.zeros(n_rows, dtype=np.float64) if verify else None
    machine = _machine_prefetch if cfg.prefetch else _machine_basic

    for vr in range(n_vr):
        lo = vr * cfg.vector_row_elems
        hi = min(n_cols, lo + cfg.vector_row_elems)
        sched.load_gb_bytes += (hi - lo) * 2
        x_row = x[lo:hi] if verify else None
        for s in range(n_stripes):
            slots_before = sched.column_reads
            macs: list[_MacState] = []
            for b in range(cfg.n_banks):
                window = bank_rows[b][s * rows_per_stripe : (s + 1) * rows_per_stripe]
                for j in range(k):
                    pair = window[j * rpm : (j + 1) * rpm]
                    segs, tags = [], []
                    rows_of_mac: list = [None] * rpm
                    for t, r in enumerate(pair):
                        rows_of_mac[t] = r
                        c = cols_by_row[r]
                        seg = c[(c >= lo) & (c < hi)] - lo
                        segs.append(seg)
                        tags.append(np.full(seg.size, t, dtype=np.int8))
                    if segs:
                        cat = np.concatenate(segs)
                        tag = np.concatenate(tags)
                        order = np.argsort(cat, kind="stable")
                        cat, tag = cat[order], tag[order]
                    else:
                        cat = np.empty(0, np.int64)
                        tag = np.empty(0, np.int8)
                    if cfg.reorder and cfg.prefetch:
                        cat, tag = _reorder_in_slice(cat, tag, cfg)
                    macs.append(_MacState(cat, tag, rows_of_mac, cfg))
            ctx = (
                _ExecCtx(x_row, values, lo, len(macs), rpm) if verify else None
            )
            machine(macs, cfg, sched, ctx)
            if verify:
                for mi, m in enumerate(macs):
                    for t, r in enumerate(m.rows):
                        if r is not None:
                            y[r] += ctx.acc[mi, t]
            slots = sched.column_reads - slots_before
            sched.all_act += -(-max(slots, 1) // cfg.cols_per_dram_row)
            sched.rdres_elems += sum(
                1 for m in macs for r in m.rows if r is not None
            )
    return sched, y
