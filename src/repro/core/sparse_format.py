"""ESPIM packed sparse formats — the TPU adaptation of Section III-B/C.

The paper packs k=11 consecutive sparse rows per DRAM row (fine-grained
interleaving) so one 16-element vector-slice broadcast is reused by all k
rows, and lets SDDS pad the compressed matrix with invalid cells where the
schedule stalls.  On TPU the equivalent packing is a *row-tile ELL* layout:

  values[R_pad, L], cols[R_pad, L]   (L = padded nnz per row)

where a row-tile of 128 rows (lane width) shares the VMEM residency of the
dense activation vector ``x`` — the broadcast analogue — and the ELL padding
slots are the static stalls.  SparTen balancing (``row_tile_balance``)
permutes rows so every tile's max nnz, and therefore L, is near the mean:
this is the load-balance contribution doing exactly its original job of
minimizing dead slots.

The *column-chunked* refinement (``pack_ell_chunked``, DESIGN.md section 3)
applies the paper's broadcast-slice discipline to ``x`` itself: each row's
cells are grouped by ``chunk_cols``-wide column chunk (the SDDS pass
``repro.core.sdds.chunk_cells``), stored chunk-major with *chunk-local*
column ids, so a (row-tile x col-chunk) kernel block only ever reads one
``x`` slab — bounding VMEM residency at ``chunk_cols`` elements instead of
the whole activation vector.

The serving stack consumes the *width-bucketed, layer-stacked* form
(``pack_bucketed_stack``, DESIGN.md section 8): all layers of a projection
group — optionally two row-concatenated halves (gate+up) under one shared
balance permutation — packed to uniform per-bucket shapes so a
``lax.scan`` over layers consumes them directly, with 2-4 per-bucket ELL
widths (the SDDS ``plan_width_buckets`` pass) instead of one stack-global
max.

All packing is offline host-side numpy (it is part of SDDS compilation);
kernels consume the arrays as jnp inputs.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import integrity
from repro.core.pruning import row_tile_balance
from repro.core.sdds import (ChunkPlan, WidthBucketPlan, chunk_cells,
                             plan_chunks, plan_width_buckets)

__all__ = [
    "PackStats",
    "ELLPack",
    "ELLChunkedPack",
    "BucketedStackedPack",
    "pack_ell",
    "pack_ell_chunked",
    "chunk_pack",
    "pack_bucketed_stack",
    "pack_group",
    "compose_cols_with_pack",
    "projection_padded_slots",
    "ell_to_dense",
    "ell_chunked_to_dense",
    "bucketed_stack_to_dense",
    "shard_ell",
]

LANE = 128  # TPU lane width: the adaptation of the paper's 16-elt slice


@dataclasses.dataclass(frozen=True)
class PackStats:
    n_rows: int
    n_cols: int
    nnz: int
    ell_width: int          # L
    padded_slots: int       # R_pad * L
    padding_frac: float     # 1 - nnz / padded_slots  (the "stall" fraction)
    density: float
    tile_widths: tuple      # per-tile max nnz before global padding
    # value-plane storage override: None = fp32 (4 bytes per slot); a
    # quantized pack replaces it with the packed size (repro.quant.qpack)
    value_bytes: int | None = None

    @property
    def value_plane_bytes(self) -> int:
        """Bytes the value plane occupies in the stored format."""
        return (4 * self.padded_slots if self.value_bytes is None
                else self.value_bytes)

    @property
    def index_plane_bytes(self) -> int:
        """Bytes the index plane occupies (int32 chunk-local col ids) —
        untouched by quantization, per the paper's value/index decoupling."""
        return 4 * self.padded_slots

    @property
    def bits_per_nnz(self) -> float:
        """Value-plane bits per useful cell — the bytes/nnz crossing the
        pin that the paper's narrow fixed-point values optimize (padding
        slots and scale overhead charged to the nnz they serve)."""
        return 8.0 * self.value_plane_bytes / max(1, self.nnz)

    def __str__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"PackStats({self.n_rows}x{self.n_cols}, nnz={self.nnz}, "
            f"L={self.ell_width}, pad={self.padding_frac:.3f}, "
            f"bits/nnz={self.bits_per_nnz:.1f})"
        )


@dataclasses.dataclass
class ELLPack:
    """Row-tile ELL pack of a sparse matrix W (n_rows x n_cols).

    Rows are permuted by ``perm`` (packed position -> original row id;
    -1 marks pad rows added to round up to the row tile).  ``cols`` is
    column-ascending per row (the paper's slice order); pad slots have
    ``valid == False``, ``values == 0``, ``cols == 0``.
    """

    values: np.ndarray  # (R_pad, L) float32
    cols: np.ndarray    # (R_pad, L) int32
    valid: np.ndarray   # (R_pad, L) bool
    perm: np.ndarray    # (R_pad,) int64
    n_rows: int
    n_cols: int
    row_tile: int
    stats: PackStats
    qplane: object = None   # QuantizedValuePlane (repro.quant.qpack)
    # build-time per-plane digests + bound pack digest (core.integrity);
    # None only for hand-assembled packs that bypass the builders
    fingerprint: dict | None = None

    @property
    def r_pad(self) -> int:
        return self.values.shape[0]

    @property
    def ell_width(self) -> int:
        return self.values.shape[1]

    def scatter_rows(self, y_packed: np.ndarray) -> np.ndarray:
        """Map packed-row outputs back to original row order."""
        return _scatter_packed_rows(self.perm, self.n_rows, y_packed)

    def gather_perm(self) -> np.ndarray:
        """Inverse permutation: original row id -> packed position."""
        inv = np.full(self.n_rows, -1, dtype=np.int64)
        keep = self.perm >= 0
        inv[self.perm[keep]] = np.nonzero(keep)[0]
        return inv


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _scatter_packed_rows(perm: np.ndarray, n_rows: int,
                         y_packed: np.ndarray) -> np.ndarray:
    """Packed-order outputs -> original row order (perm < 0 = pad row)."""
    out_shape = (n_rows,) + tuple(y_packed.shape[1:])
    y = np.zeros(out_shape, dtype=y_packed.dtype)
    keep = perm >= 0
    y[perm[keep]] = y_packed[keep]
    return y


def pack_ell(
    w: np.ndarray,
    row_tile: int = LANE,
    balance: bool = True,
    width_multiple: int = 8,
) -> ELLPack:
    """Pack a (possibly sparse) dense-storage matrix into row-tile ELL.

    ``width_multiple`` rounds L up for sublane-aligned VMEM tiles (the
    analogue of the paper's column-granular reads).
    """
    w = np.asarray(w)
    if w.ndim != 2:
        raise ValueError(f"expected 2-D matrix, got shape {w.shape}")
    n_rows, n_cols = w.shape
    nnz_per_row = (w != 0).sum(axis=1)
    nnz = int(nnz_per_row.sum())

    if balance and n_rows > 1:
        perm_rows = row_tile_balance(nnz_per_row, row_tile)
    else:
        perm_rows = np.arange(n_rows, dtype=np.int64)

    r_pad = _round_up(max(n_rows, 1), row_tile)
    perm = np.full(r_pad, -1, dtype=np.int64)
    perm[:n_rows] = perm_rows

    ell_w = int(nnz_per_row.max()) if n_rows else 0
    ell_w = max(width_multiple, _round_up(max(ell_w, 1), width_multiple))

    values = np.zeros((r_pad, ell_w), dtype=np.float32)
    cols = np.zeros((r_pad, ell_w), dtype=np.int32)
    valid = np.zeros((r_pad, ell_w), dtype=bool)

    tile_widths = []
    for t in range(0, r_pad, row_tile):
        tile_max = 0
        for i in range(t, min(t + row_tile, r_pad)):
            src = perm[i]
            if src < 0:
                continue
            (nz,) = np.nonzero(w[src])
            tile_max = max(tile_max, nz.size)
            values[i, : nz.size] = w[src, nz]
            cols[i, : nz.size] = nz
            valid[i, : nz.size] = True
        tile_widths.append(tile_max)

    padded = r_pad * ell_w
    stats = PackStats(
        n_rows=n_rows,
        n_cols=n_cols,
        nnz=nnz,
        ell_width=ell_w,
        padded_slots=padded,
        padding_frac=1.0 - (nnz / padded if padded else 0.0),
        density=nnz / max(1, n_rows * n_cols),
        tile_widths=tuple(tile_widths),
    )
    pack = ELLPack(
        values=values,
        cols=cols,
        valid=valid,
        perm=perm,
        n_rows=n_rows,
        n_cols=n_cols,
        row_tile=row_tile,
        stats=stats,
    )
    pack.fingerprint = integrity.fingerprint_pack(pack)
    return pack


@dataclasses.dataclass
class ELLChunkedPack:
    """Column-chunked row-tile ELL pack (the fused-kernel layout).

    ``values``/``cols``/``valid`` are (R_pad, n_chunks, chunk_width); cell
    (i, k, l) belongs to column chunk k and ``cols`` holds the
    *chunk-local* column id in [0, chunk_cols), so a kernel block gathers
    straight into the k-th ``x`` slab.  Within a chunk, cells keep
    ascending column order (``chunk_cells`` is stable).  Pad slots have
    ``valid == False``, ``values == 0``, ``cols == 0``.
    """

    values: np.ndarray      # (R_pad, K, Lc) float32
    cols: np.ndarray        # (R_pad, K, Lc) int32, chunk-local
    valid: np.ndarray       # (R_pad, K, Lc) bool
    perm: np.ndarray        # (R_pad,) int64, -1 = pad row
    n_rows: int
    n_cols: int
    row_tile: int
    chunk_cols: int
    stats: PackStats
    plan: ChunkPlan
    qplane: object = None   # QuantizedValuePlane (repro.quant.qpack)
    fingerprint: dict | None = None     # see ELLPack.fingerprint
    # The tuned kernel schedule this layout was chunked under (a
    # repro.autotune.TunedPlan), or None for the hand-picked default.
    # Advisory metadata: integrity fingerprints deliberately exclude it
    # (the pack bytes are what they are regardless of who chose Lc), so
    # carrying a plan never invalidates an existing fingerprint.
    schedule: object = None

    @property
    def r_pad(self) -> int:
        return self.values.shape[0]

    @property
    def n_chunks(self) -> int:
        return self.values.shape[1]

    @property
    def chunk_width(self) -> int:
        return self.values.shape[2]

    def scatter_rows(self, y_packed: np.ndarray) -> np.ndarray:
        """Map packed-row outputs back to original row order."""
        return _scatter_packed_rows(self.perm, self.n_rows, y_packed)


def chunk_pack(pack: ELLPack, chunk_cols: int,
               width_multiple: int = 8,
               schedule=None) -> ELLChunkedPack:
    """Re-layout a row-tile ELL pack into the column-chunked format.

    Runs the SDDS chunk pass (``chunk_cells``) per packed row: cells are
    grouped chunk-major, column ids are rebased to the chunk, and the
    uniform chunk width Lc is the global max per-(row, chunk) count
    rounded to ``width_multiple`` (the lockstep-width discipline of the
    plain pack, applied per chunk).

    ``schedule`` optionally records the autotuned plan that picked this
    ``chunk_cols`` (carried on the pack as advisory metadata, excluded
    from the integrity fingerprint).
    """
    if chunk_cols <= 0:
        raise ValueError(f"chunk_cols must be positive, got {chunk_cols}")
    chunk_cols = min(chunk_cols, max(1, pack.n_cols))
    n_chunks = -(-max(pack.n_cols, 1) // chunk_cols)
    r_pad = pack.r_pad

    row_cols = []
    row_vals = []
    counts = np.zeros((r_pad, n_chunks), dtype=np.int64)
    for i in range(r_pad):
        sel = pack.valid[i]
        c = pack.cols[i, sel].astype(np.int64)
        v = pack.values[i, sel]
        order, cnt = chunk_cells(c, chunk_cols, n_chunks)
        row_cols.append(c[order])
        row_vals.append(v[order])
        counts[i] = cnt

    plan = plan_chunks(counts, chunk_cols=chunk_cols,
                       row_tile=pack.row_tile, n_cols=pack.n_cols,
                       width_multiple=width_multiple)
    lc = plan.chunk_width
    values = np.zeros((r_pad, n_chunks, lc), dtype=np.float32)
    cols = np.zeros((r_pad, n_chunks, lc), dtype=np.int32)
    valid = np.zeros((r_pad, n_chunks, lc), dtype=bool)
    for i in range(r_pad):
        off = 0
        for k in range(n_chunks):
            n = counts[i, k]
            if n:
                seg = slice(off, off + n)
                values[i, k, :n] = row_vals[i][seg]
                cols[i, k, :n] = row_cols[i][seg] - k * chunk_cols
                valid[i, k, :n] = True
                off += n

    stats = dataclasses.replace(
        pack.stats,
        ell_width=n_chunks * lc,
        padded_slots=r_pad * n_chunks * lc,
        padding_frac=plan.chunk_pad_frac,
    )
    out = ELLChunkedPack(
        values=values,
        cols=cols,
        valid=valid,
        perm=pack.perm.copy(),
        n_rows=pack.n_rows,
        n_cols=pack.n_cols,
        row_tile=pack.row_tile,
        chunk_cols=chunk_cols,
        stats=stats,
        plan=plan,
        schedule=schedule,
    )
    out.fingerprint = integrity.fingerprint_pack(out)
    return out


def pack_ell_chunked(
    w: np.ndarray,
    row_tile: int = LANE,
    chunk_cols: int = 512,
    balance: bool = True,
    width_multiple: int = 8,
) -> ELLChunkedPack:
    """Pack a dense-storage matrix straight into column-chunked ELL.

    ``chunk_cols`` is the VMEM slab of ``x`` one kernel block consumes —
    the TPU analogue of the paper's 16-element broadcast slice (scaled up
    to amortize DMA, default 512 = 2KB f32 per lane).
    """
    return chunk_pack(
        pack_ell(w, row_tile=row_tile, balance=balance,
                 width_multiple=width_multiple),
        chunk_cols,
        width_multiple=width_multiple,
    )


@dataclasses.dataclass
class BucketedStackedPack:
    """Width-bucketed, layer-stacked, (optionally) half-fused chunked ELL.

    The serving-stack layout: all ``L`` layers of one projection group are
    packed into uniform arrays (so a ``lax.scan`` over layers consumes them
    directly) and the packed rows are split into <= ``n_buckets``
    contiguous segments, each padded to its own ELL width (the SDDS
    ``plan_width_buckets`` pass) instead of one stack-global max.

    ``halves > 1`` row-concatenates several same-shape matrices (gate and
    up) that share one balance permutation: bucket ``g`` stores
    ``halves * bucket_rows[g]`` packed rows ordered half-major
    ([gate rows of the bucket; up rows of the bucket]), so one SpMV launch
    computes both projections and their outputs pair up elementwise in
    packed order — no unscatter between gate*up and the down projection.

    * ``buckets[g]['values'|'cols'|'valid']``: (L, halves*Rg, K, Lc_g);
      ``cols`` chunk-local as in ``ELLChunkedPack``.
    * ``perm``: (L, r_pad) packed position -> logical row (-1 = pad),
      shared by every half of a layer.
    * ``inv_perm``: (L, n_rows) logical row -> packed position.
    """

    buckets: list           # [{values, cols, valid} ...] numpy arrays
    bucket_rows: tuple      # Rg per bucket (per half); sums to r_pad
    halves: int
    perm: np.ndarray        # (L, r_pad) int64
    inv_perm: np.ndarray    # (L, n_rows) int64
    n_rows: int             # logical rows per half
    n_cols: int             # gather domain (x length the pack consumes)
    chunk_cols: int
    row_tile: int
    plan: WidthBucketPlan
    nnz_per_layer: np.ndarray       # (L,) over all halves
    nnz_per_half: np.ndarray        # (halves, L)
    qplanes: list | None = None     # per-bucket QuantizedValuePlane
    fingerprint: dict | None = None  # see ELLPack.fingerprint

    @property
    def n_layers(self) -> int:
        return self.perm.shape[0]

    @property
    def r_pad(self) -> int:
        return self.perm.shape[1]

    @property
    def n_chunks(self) -> int:
        return self.buckets[0]["values"].shape[2]

    @property
    def widths(self) -> tuple:
        return tuple(b["values"].shape[3] for b in self.buckets)

    @property
    def padded_slots_per_layer(self) -> int:
        return sum(self.halves * rg * self.n_chunks * lc
                   for rg, lc in zip(self.bucket_rows, self.widths))

    @property
    def nnz(self) -> int:
        return int(self.nnz_per_layer.sum())

    @property
    def pad_frac(self) -> float:
        padded = self.padded_slots_per_layer * self.n_layers
        return 1.0 - (self.nnz / padded if padded else 0.0)

    def pad_frac_layer(self, l: int) -> float:
        padded = self.padded_slots_per_layer
        return 1.0 - (float(self.nnz_per_layer[l]) / padded if padded else 0.0)


def pack_bucketed_stack(
    mats: list,
    row_tile: int = LANE,
    chunk_cols: int = 512,
    n_buckets: int = 4,
    width_multiple: int = 8,
    balance: bool = True,
    group_rows: int = 32,
) -> BucketedStackedPack:
    """Pack ``mats[half][layer]`` (each (n_rows, n_cols)) into one
    width-bucketed stack.

    Per layer the halves are balanced on their *combined* per-row nnz (one
    shared permutation, the gate+up fusion contract); cells are grouped by
    column chunk with local ids (``chunk_cells``); bucket boundaries are
    chosen once for the whole stack by ``plan_width_buckets`` over per-row-
    group max cell counts taken across layers, halves and chunks.
    """
    halves = len(mats)
    n_layers = len(mats[0])
    if any(len(h) != n_layers for h in mats):
        raise ValueError("every half must hold the same number of layers")
    n_rows, n_cols = np.asarray(mats[0][0]).shape
    for h in mats:
        for m in h:
            if np.asarray(m).shape != (n_rows, n_cols):
                raise ValueError("all matrices in a stack must share shape")

    r_pad = _round_up(max(n_rows, 1), row_tile)
    cc = min(chunk_cols, max(1, n_cols))
    n_chunks = -(-max(n_cols, 1) // cc)
    group = math.gcd(r_pad, group_rows) or 1

    perm = np.full((n_layers, r_pad), -1, dtype=np.int64)
    inv_perm = np.zeros((n_layers, n_rows), dtype=np.int64)
    counts = np.zeros((n_layers, halves, r_pad, n_chunks), dtype=np.int64)
    cells: list = [[[None] * r_pad for _ in range(halves)]
                   for _ in range(n_layers)]
    nnz_per_half = np.zeros((halves, n_layers), dtype=np.int64)

    for l in range(n_layers):
        ms = [np.asarray(mats[h][l]) for h in range(halves)]
        joint_nnz = sum((m != 0).sum(axis=1) for m in ms)
        if balance and n_rows > 1:
            perm_rows = row_tile_balance(joint_nnz, row_tile)
        else:
            perm_rows = np.arange(n_rows, dtype=np.int64)
        perm[l, :n_rows] = perm_rows
        inv_perm[l, perm_rows] = np.arange(n_rows, dtype=np.int64)
        for h, m in enumerate(ms):
            nnz_per_half[h, l] = int((m != 0).sum())
            for i in range(n_rows):
                src = perm_rows[i]
                (nz,) = np.nonzero(m[src])
                order, cnt = chunk_cells(nz, cc, n_chunks)
                cells[l][h][i] = (nz[order], m[src, nz][order])
                counts[l, h, i] = cnt

    widths = counts.reshape(
        n_layers, halves, r_pad // group, group, n_chunks).max(axis=(0, 1, 3, 4))
    plan = plan_width_buckets(widths, rows_per_group=group,
                              n_buckets=n_buckets,
                              width_multiple=width_multiple)

    buckets = []
    for (row0, row1, lc) in plan.boundaries:
        rg = row1 - row0
        values = np.zeros((n_layers, halves * rg, n_chunks, lc), np.float32)
        cols = np.zeros((n_layers, halves * rg, n_chunks, lc), np.int32)
        valid = np.zeros((n_layers, halves * rg, n_chunks, lc), bool)
        for l in range(n_layers):
            for h in range(halves):
                for i in range(row0, min(row1, n_rows)):
                    c, v = cells[l][h][i]
                    r = h * rg + (i - row0)
                    off = 0
                    for k in range(n_chunks):
                        n = int(counts[l, h, i, k])
                        if n:
                            seg = slice(off, off + n)
                            values[l, r, k, :n] = v[seg]
                            cols[l, r, k, :n] = c[seg] - k * cc
                            valid[l, r, k, :n] = True
                            off += n
        buckets.append({"values": values, "cols": cols, "valid": valid})

    pack = BucketedStackedPack(
        buckets=buckets,
        bucket_rows=tuple(b1 - b0 for b0, b1, _ in plan.boundaries),
        halves=halves,
        perm=perm,
        inv_perm=inv_perm,
        n_rows=n_rows,
        n_cols=n_cols,
        chunk_cols=cc,
        row_tile=row_tile,
        plan=plan,
        nnz_per_layer=nnz_per_half.sum(axis=0),
        nnz_per_half=nnz_per_half,
    )
    pack.fingerprint = integrity.fingerprint_pack(pack)
    return pack


# --------------------------------------------------------------------------
# Projection-generic pack groups (the PackGroupSpec compilation step)
# --------------------------------------------------------------------------
def pack_group(
    mats_by_proj: dict,
    fuse: str = "concat",
    row_tile: int = LANE,
    chunk_cols: int = 512,
    n_buckets: int = 4,
    width_multiple: int = 8,
    balance: bool = True,
) -> tuple:
    """Compile one pack group: ``mats_by_proj[name][layer]`` are the
    transposed per-layer matrices (rows = the projection's output dim).

    ``fuse="halves"`` packs each projection as one half under the shared
    permutation (identical shapes required — gate+up); ``fuse="concat"``
    row-concatenates the projections into one matrix per layer (row
    counts may differ — fused QKV under GQA).

    Returns ``(BucketedStackedPack, row_offsets)`` where
    ``row_offsets[name] = (half, r0, r1)`` locates the projection's rows
    in the group's logical (pre-permutation) row domain.
    """
    names = list(mats_by_proj)
    n_layers = len(mats_by_proj[names[0]])
    if fuse == "halves":
        halves = [list(mats_by_proj[n]) for n in names]
        n_rows = np.asarray(halves[0][0]).shape[0]
        offsets = {n: (h, 0, n_rows) for h, n in enumerate(names)}
    elif fuse == "concat":
        offsets = {}
        r0 = 0
        for n in names:
            rows = np.asarray(mats_by_proj[n][0]).shape[0]
            offsets[n] = (0, r0, r0 + rows)
            r0 += rows
        halves = [[np.concatenate([np.asarray(mats_by_proj[n][l])
                                   for n in names], axis=0)
                   for l in range(n_layers)]]
    else:
        raise ValueError(f"unknown fuse {fuse!r}")
    pack = pack_bucketed_stack(halves, row_tile=row_tile,
                               chunk_cols=chunk_cols, n_buckets=n_buckets,
                               width_multiple=width_multiple,
                               balance=balance)
    return pack, offsets


def compose_cols_with_pack(mats: list, upstream: BucketedStackedPack) -> list:
    """Offline column pre-composition: permute each layer matrix's columns
    to the upstream group's *packed* row order (pad positions become zero
    columns), so the upstream packed output feeds this group's pack with
    zero runtime permutation.  The returned matrices' gather domain is the
    upstream ``r_pad``."""
    out = []
    for l, m in enumerate(mats):
        m = np.asarray(m)
        mp = np.zeros((m.shape[0], upstream.r_pad), np.float32)
        mp[:, upstream.inv_perm[l]] = m
        out.append(mp)
    return out


def projection_padded_slots(pack: BucketedStackedPack,
                            row_offsets: dict) -> dict:
    """Exact per-projection padded-slot counts, (L,) per projection.

    A logical row's slots are set by the width bucket its packed position
    landed in (``n_chunks * Lc_bucket``); the balance permutation scatters
    a projection's rows across buckets, so this walks ``inv_perm``.
    Bucket widths are shared by every half, so the count is
    half-independent.
    """
    slots_per_pos = np.repeat(
        [pack.n_chunks * lc for lc in pack.widths],
        [rg for rg in pack.bucket_rows]).astype(np.int64)
    out = {}
    for name, (_, r0, r1) in row_offsets.items():
        pos = pack.inv_perm[:, r0:r1]                  # (L, rows)
        out[name] = slots_per_pos[pos].sum(axis=1)     # (L,)
    return out


def bucketed_stack_to_dense(pack: BucketedStackedPack, layer: int,
                            half: int) -> np.ndarray:
    """Inverse of ``pack_bucketed_stack`` for one (layer, half) — the
    property-test oracle."""
    w = np.zeros((pack.n_rows, pack.n_cols), dtype=np.float32)
    row0 = 0
    for b, rg in zip(pack.buckets, pack.bucket_rows):
        for r in range(rg):
            src = pack.perm[layer, row0 + r]
            if src < 0:
                continue
            i = half * rg + r
            for k in range(b["values"].shape[2]):
                sel = b["valid"][layer, i, k]
                w[src, b["cols"][layer, i, k, sel] + k * pack.chunk_cols] = \
                    b["values"][layer, i, k, sel]
        row0 += rg
    return w


def ell_to_dense(pack: ELLPack) -> np.ndarray:
    """Inverse of ``pack_ell`` (property-test oracle)."""
    w = np.zeros((pack.n_rows, pack.n_cols), dtype=pack.values.dtype)
    for i in range(pack.r_pad):
        src = pack.perm[i]
        if src < 0:
            continue
        sel = pack.valid[i]
        w[src, pack.cols[i, sel]] = pack.values[i, sel]
    return w


def ell_chunked_to_dense(pack: ELLChunkedPack) -> np.ndarray:
    """Inverse of ``pack_ell_chunked`` (property-test oracle)."""
    w = np.zeros((pack.n_rows, pack.n_cols), dtype=pack.values.dtype)
    for i in range(pack.r_pad):
        src = pack.perm[i]
        if src < 0:
            continue
        for k in range(pack.n_chunks):
            sel = pack.valid[i, k]
            w[src, pack.cols[i, k, sel] + k * pack.chunk_cols] = \
                pack.values[i, k, sel]
    return w


def shard_ell(pack: ELLPack, n_shards: int) -> dict:
    """Re-layout an ELLPack for ``shard_map`` over the ``model`` axis.

    Devices are the cluster-level "banks": each holds a contiguous packed
    row range; the dense x is replicated (the ICI broadcast).  Returns
    stacked arrays with a leading shard dim and a uniform per-shard width
    (the global L — banks operate in lockstep, exactly as in the paper).
    """
    r_pad = pack.r_pad
    if r_pad % n_shards != 0:
        # pad packed rows up to a multiple of n_shards * row_tile
        new_rpad = _round_up(r_pad, n_shards * pack.row_tile)
        pad = new_rpad - r_pad
        pack = ELLPack(
            values=np.pad(pack.values, ((0, pad), (0, 0))),
            cols=np.pad(pack.cols, ((0, pad), (0, 0))),
            valid=np.pad(pack.valid, ((0, pad), (0, 0))),
            perm=np.pad(pack.perm, (0, pad), constant_values=-1),
            n_rows=pack.n_rows,
            n_cols=pack.n_cols,
            row_tile=pack.row_tile,
            stats=pack.stats,
        )
        r_pad = new_rpad
    per = r_pad // n_shards
    return {
        "values": pack.values.reshape(n_shards, per, pack.ell_width),
        "cols": pack.cols.reshape(n_shards, per, pack.ell_width),
        "perm": pack.perm.reshape(n_shards, per),
        "n_rows": pack.n_rows,
        "n_cols": pack.n_cols,
        "pack": pack,
    }
