"""ESPIM packed sparse formats — the TPU adaptation of Section III-B/C.

The paper packs k=11 consecutive sparse rows per DRAM row (fine-grained
interleaving) so one 16-element vector-slice broadcast is reused by all k
rows, and lets SDDS pad the compressed matrix with invalid cells where the
schedule stalls.  On TPU the equivalent packing is a *row-tile ELL* layout:

  values[R_pad, L], cols[R_pad, L]   (L = padded nnz per row)

where a row-tile of 128 rows (lane width) shares the VMEM residency of the
dense activation vector ``x`` — the broadcast analogue — and the ELL padding
slots are the static stalls.  SparTen balancing (``row_tile_balance``)
permutes rows so every tile's max nnz, and therefore L, is near the mean:
this is the load-balance contribution doing exactly its original job of
minimizing dead slots.

All packing is offline host-side numpy (it is part of SDDS compilation);
kernels consume the arrays as jnp inputs.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.pruning import row_tile_balance

__all__ = ["PackStats", "ELLPack", "pack_ell", "ell_to_dense", "shard_ell"]

LANE = 128  # TPU lane width: the adaptation of the paper's 16-elt slice


@dataclasses.dataclass(frozen=True)
class PackStats:
    n_rows: int
    n_cols: int
    nnz: int
    ell_width: int          # L
    padded_slots: int       # R_pad * L
    padding_frac: float     # 1 - nnz / padded_slots  (the "stall" fraction)
    density: float
    tile_widths: tuple      # per-tile max nnz before global padding

    def __str__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"PackStats({self.n_rows}x{self.n_cols}, nnz={self.nnz}, "
            f"L={self.ell_width}, pad={self.padding_frac:.3f})"
        )


@dataclasses.dataclass
class ELLPack:
    """Row-tile ELL pack of a sparse matrix W (n_rows x n_cols).

    Rows are permuted by ``perm`` (packed position -> original row id;
    -1 marks pad rows added to round up to the row tile).  ``cols`` is
    column-ascending per row (the paper's slice order); pad slots have
    ``valid == False``, ``values == 0``, ``cols == 0``.
    """

    values: np.ndarray  # (R_pad, L) float32
    cols: np.ndarray    # (R_pad, L) int32
    valid: np.ndarray   # (R_pad, L) bool
    perm: np.ndarray    # (R_pad,) int64
    n_rows: int
    n_cols: int
    row_tile: int
    stats: PackStats

    @property
    def r_pad(self) -> int:
        return self.values.shape[0]

    @property
    def ell_width(self) -> int:
        return self.values.shape[1]

    def scatter_rows(self, y_packed: np.ndarray) -> np.ndarray:
        """Map packed-row outputs back to original row order."""
        out_shape = (self.n_rows,) + tuple(y_packed.shape[1:])
        y = np.zeros(out_shape, dtype=y_packed.dtype)
        keep = self.perm >= 0
        y[self.perm[keep]] = y_packed[keep]
        return y

    def gather_perm(self) -> np.ndarray:
        """Inverse permutation: original row id -> packed position."""
        inv = np.full(self.n_rows, -1, dtype=np.int64)
        keep = self.perm >= 0
        inv[self.perm[keep]] = np.nonzero(keep)[0]
        return inv


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pack_ell(
    w: np.ndarray,
    row_tile: int = LANE,
    balance: bool = True,
    width_multiple: int = 8,
) -> ELLPack:
    """Pack a (possibly sparse) dense-storage matrix into row-tile ELL.

    ``width_multiple`` rounds L up for sublane-aligned VMEM tiles (the
    analogue of the paper's column-granular reads).
    """
    w = np.asarray(w)
    if w.ndim != 2:
        raise ValueError(f"expected 2-D matrix, got shape {w.shape}")
    n_rows, n_cols = w.shape
    nnz_per_row = (w != 0).sum(axis=1)
    nnz = int(nnz_per_row.sum())

    if balance and n_rows > 1:
        perm_rows = row_tile_balance(nnz_per_row, row_tile)
    else:
        perm_rows = np.arange(n_rows, dtype=np.int64)

    r_pad = _round_up(max(n_rows, 1), row_tile)
    perm = np.full(r_pad, -1, dtype=np.int64)
    perm[:n_rows] = perm_rows

    ell_w = int(nnz_per_row.max()) if n_rows else 0
    ell_w = max(width_multiple, _round_up(max(ell_w, 1), width_multiple))

    values = np.zeros((r_pad, ell_w), dtype=np.float32)
    cols = np.zeros((r_pad, ell_w), dtype=np.int32)
    valid = np.zeros((r_pad, ell_w), dtype=bool)

    tile_widths = []
    for t in range(0, r_pad, row_tile):
        tile_max = 0
        for i in range(t, min(t + row_tile, r_pad)):
            src = perm[i]
            if src < 0:
                continue
            (nz,) = np.nonzero(w[src])
            tile_max = max(tile_max, nz.size)
            values[i, : nz.size] = w[src, nz]
            cols[i, : nz.size] = nz
            valid[i, : nz.size] = True
        tile_widths.append(tile_max)

    padded = r_pad * ell_w
    stats = PackStats(
        n_rows=n_rows,
        n_cols=n_cols,
        nnz=nnz,
        ell_width=ell_w,
        padded_slots=padded,
        padding_frac=1.0 - (nnz / padded if padded else 0.0),
        density=nnz / max(1, n_rows * n_cols),
        tile_widths=tuple(tile_widths),
    )
    return ELLPack(
        values=values,
        cols=cols,
        valid=valid,
        perm=perm,
        n_rows=n_rows,
        n_cols=n_cols,
        row_tile=row_tile,
        stats=stats,
    )


def ell_to_dense(pack: ELLPack) -> np.ndarray:
    """Inverse of ``pack_ell`` (property-test oracle)."""
    w = np.zeros((pack.n_rows, pack.n_cols), dtype=pack.values.dtype)
    for i in range(pack.r_pad):
        src = pack.perm[i]
        if src < 0:
            continue
        sel = pack.valid[i]
        w[src, pack.cols[i, sel]] = pack.values[i, sel]
    return w


def shard_ell(pack: ELLPack, n_shards: int) -> dict:
    """Re-layout an ELLPack for ``shard_map`` over the ``model`` axis.

    Devices are the cluster-level "banks": each holds a contiguous packed
    row range; the dense x is replicated (the ICI broadcast).  Returns
    stacked arrays with a leading shard dim and a uniform per-shard width
    (the global L — banks operate in lockstep, exactly as in the paper).
    """
    r_pad = pack.r_pad
    if r_pad % n_shards != 0:
        # pad packed rows up to a multiple of n_shards * row_tile
        new_rpad = _round_up(r_pad, n_shards * pack.row_tile)
        pad = new_rpad - r_pad
        pack = ELLPack(
            values=np.pad(pack.values, ((0, pad), (0, 0))),
            cols=np.pad(pack.cols, ((0, pad), (0, 0))),
            valid=np.pad(pack.valid, ((0, pad), (0, 0))),
            perm=np.pad(pack.perm, (0, pad), constant_values=-1),
            n_rows=pack.n_rows,
            n_cols=pack.n_cols,
            row_tile=pack.row_tile,
            stats=pack.stats,
        )
        r_pad = new_rpad
    per = r_pad // n_shards
    return {
        "values": pack.values.reshape(n_shards, per, pack.ell_width),
        "cols": pack.cols.reshape(n_shards, per, pack.ell_width),
        "perm": pack.perm.reshape(n_shards, per),
        "n_rows": pack.n_rows,
        "n_cols": pack.n_cols,
        "pack": pack,
    }
