"""Energy and area models (Figure 14, Table IV).

Units: one open-row 256-bit column read = 1.0 energy unit.  Anchors taken
from the paper:

* PIM compute for a full column's worth of MACs costs ~4x a column read
  (Section IV "Energy and area") -> e_mac = 4/16 per MAC operation.
* Pin I/O per 256 bits costs ~0.8 units, chosen so dense Newton lands at
  ~2.8x the conventional-DRAM (GPU) energy (Section V-E: "Newton's dense
  matrix energy overhead of around 1.8x is almost entirely due to its
  compute", on top of the 1.0 access).
* Newton gates its MACs on zero values (Section V-E) but still pays access
  for the full uncompressed matrix.
* ESPIM's "rest" = iFIFO/eFIFO flip-flop pushes + switch traversals; the
  paper notes its flip-flop FIFOs make this conservative.

Area (Table IV): per-MAC area = 25%/16 of a DRAM die; FIFO area scales with
bit count calibrated on the eFIFO row (11 FIFOs x 8 entries x 16 bits =
7.1%); switch + other logic constants from the table.
"""
from __future__ import annotations

import dataclasses

from repro.core.sdds import ESPIMConfig, Schedule

__all__ = ["EnergyConfig", "EnergyReport", "espim_energy", "newton_energy",
           "gpu_dram_energy", "AreaModel", "area_table"]


@dataclasses.dataclass(frozen=True)
class EnergyConfig:
    e_col: float = 1.0            # 256-bit open-row column read
    e_pin_256b: float = 0.8       # host<->DRAM pin transfer per 256 bits
    e_mac: float = 4.0 / 16.0     # per MAC op (4x col read per 16-MAC column)
    e_bcast: float = 0.25         # vector-slice broadcast to all banks
    e_fifo_push: float = 0.012    # flip-flop FIFO push (iFIFO or eFIFO)
    e_switch: float = 0.008       # one 4-to-1 mux traversal


@dataclasses.dataclass
class EnergyReport:
    arch: str
    access: float
    compute: float
    rest: float

    @property
    def total(self) -> float:
        return self.access + self.compute + self.rest

    def normalized(self, baseline: float) -> "EnergyReport":
        return EnergyReport(
            self.arch,
            self.access / baseline,
            self.compute / baseline,
            self.rest / baseline,
        )


def _pin_energy(n_bytes: float, ecfg: EnergyConfig) -> float:
    return n_bytes * 8 / 256 * ecfg.e_pin_256b


def gpu_dram_energy(
    n_rows: int, n_cols: int, cfg: ESPIMConfig = ESPIMConfig(),
    ecfg: EnergyConfig = EnergyConfig(),
) -> EnergyReport:
    """Conventional-DRAM energy for the GPU reading the full dense matrix
    (the Figure 14 normalizer).  Compute energy on the GPU side is
    conservatively ignored, as in the paper."""
    cells = n_rows * n_cols
    col_reads = cells / cfg.dense_macs_per_bank
    access = col_reads * ecfg.e_col + _pin_energy(cells * 2, ecfg)
    return EnergyReport("gpu", access, 0.0, 0.0)


def newton_energy(
    n_rows: int, n_cols: int, nnz: int,
    cfg: ESPIMConfig = ESPIMConfig(), ecfg: EnergyConfig = EnergyConfig(),
) -> EnergyReport:
    """Newton on an (uncompressed) sparse matrix with zero-gated MACs."""
    cells = n_rows * n_cols
    col_reads = cells / cfg.dense_macs_per_bank
    n_vr = max(1, -(-n_cols // cfg.vector_row_elems))
    access = (
        col_reads * ecfg.e_col
        + col_reads * ecfg.e_bcast           # one broadcast per column read
        + _pin_energy(n_cols * 2, ecfg)      # vector load
        + _pin_energy(n_rows * n_vr * 2, ecfg)  # partial-result readout
    )
    compute = nnz * ecfg.e_mac               # zero-gated
    return EnergyReport("newton", access, compute, 0.0)


def espim_energy(
    sched: Schedule, cfg: ESPIMConfig = ESPIMConfig(),
    ecfg: EnergyConfig = EnergyConfig(),
) -> EnergyReport:
    # column_reads are global lockstep *slots*: every bank reads one column
    # per slot, so access energy scales by n_banks.  The broadcast is one
    # shared-bus drive per COMP-BR slot.
    access = (
        sched.column_reads * cfg.n_banks * ecfg.e_col
        + sched.broadcasts * ecfg.e_bcast
        + _pin_energy(sched.load_gb_bytes, ecfg)
        + _pin_energy(sched.rdres_elems * 2, ecfg)
    )
    compute = sched.mac_ops * ecfg.e_mac
    rest = (
        (sched.ififo_pushes + sched.efifo_pushes) * ecfg.e_fifo_push
        + sched.efifo_pushes * ecfg.e_switch
    )
    return EnergyReport("espim", access, compute, rest)


# --------------------------------------------------------------------------
# Area (Table IV)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AreaModel:
    """Component areas as fractions of a conventional DRAM die."""

    mac_area: float = 0.25 / 16          # one MAC (from Newton's 25% / 16)
    fifo_area_per_bit: float = 0.071 / (11 * 8 * 16)  # eFIFO row calibration
    ififo_ctl_per_fifo: float = 0.0004   # valid/start handling (iFIFO only)
    switch_other_sparse: float = 0.030   # 11x 16b 4-1 mux + other logic
    switch_other_flex: float = 0.041     # + dense/sparse input muxing

    def espim(self, cfg: ESPIMConfig = ESPIMConfig(), flexible: bool = False) -> dict:
        k = cfg.macs_per_bank
        n_macs = cfg.dense_macs_per_bank if flexible else k
        ififo_bits = k * cfg.fifo_depth * 7    # idx(4) + valid + start + select
        efifo_bits = k * cfg.fifo_depth * 16   # FP16 elements
        comp = {
            "macs": n_macs * self.mac_area,
            "ififo": ififo_bits * self.fifo_area_per_bit
            + k * self.ififo_ctl_per_fifo,
            "efifo": efifo_bits * self.fifo_area_per_bit,
            "switch_other": (
                self.switch_other_flex if flexible else self.switch_other_sparse
            ),
        }
        comp["total"] = sum(comp.values())
        return comp

    def newton(self, cfg: ESPIMConfig = ESPIMConfig()) -> dict:
        return {"macs": cfg.dense_macs_per_bank * self.mac_area,
                "total": cfg.dense_macs_per_bank * self.mac_area}


def area_table(cfg: ESPIMConfig = ESPIMConfig()) -> dict:
    """Reproduce Table IV: area over conventional DRAM for Newton, ESPIM
    sparse-only, and the flexible sparse+dense configuration."""
    m = AreaModel()
    newton = m.newton(cfg)
    sparse = m.espim(cfg, flexible=False)
    flex = m.espim(cfg, flexible=True)
    return {
        "newton": newton,
        "espim_sparse_only": sparse,
        "espim_flexible": flex,
        "espim_over_newton_sparse_only": sparse["total"] - newton["total"],
        "espim_over_newton_flexible": flex["total"] - newton["total"],
    }
