"""ESPIM-format sparse serving of a whole dense-family LM.

The paper's deployment (Section IV): take a trained model, magnitude-prune
the projection matrices, and serve MV decode from the compressed format.
This module converts a dense LM's stacked MLP weights into stacked ELL
packs (the offline SDDS-analogue pipeline: prune -> balance -> chunk ->
width-bucket) and runs the decode step with the sparse kernels in place of
the dense matmuls — attention stays dense (its per-layer matrices are small
relative to the MLPs, which hold ~2/3 of LLaMA-class weights).

The decode datapath is fully fused (DESIGN.md section 8):

* one ``jax.lax.scan`` over the layer stack — the packs are padded to
  uniform per-bucket shapes for exactly this;
* gate and up are row-concatenated into ONE pack per bucket sharing one
  balance permutation (the paper's vector-broadcast sharing applied across
  projections): a single SpMV launch yields both halves, and
  ``silu(gate) * up`` runs directly in packed order;
* the down projection's column ids are pre-composed offline with the
  gate/up packed order, so the intermediate never needs unscattering; the
  only runtime permutation left is one ``take`` by ``inv_perm`` on the
  down output (``scatter_rows_ref`` is gone from the per-token path);
* ``x`` stays in (in, B) layout across the whole MLP — one transpose in,
  one out, per layer.

Quantized serving (``quant="int8"|"int4"``, DESIGN.md section 9): only the
packs' *value planes* are re-encoded (repro.quant) — per-bucket-row-group
scales ride the layer scan as one more stacked leaf and the fused SpMV
launches dispatch to the quantized kernels; cols/perms/plans and the whole
datapath shape are untouched.  The pruned dense copies are replaced by the
*dequantized* reconstructions, so the GEMM prefill path and every parity
test see exactly the weights the quantized kernels compute with.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import (BucketedStackedPack,
                                      bucketed_stack_to_dense,
                                      pack_bucketed_stack)
from repro.kernels import ops
from repro.models import transformer as T

__all__ = ["sparsify_mlps", "decode_step_sparse", "prefill_chunk_sparse",
           "sparse_stats"]

_MLP_NAMES = ("w_gate", "w_up", "w_down")


def _to_device(pack: BucketedStackedPack) -> dict:
    """BucketedStackedPack -> the jnp dict the serving step consumes.
    ``valid`` masks, nnz stats and the host QuantizedValuePlanes stay
    host-side (stats/tests only); quantized packs upload per-bucket codes
    (``q``) + pre-expanded per-row scales (``srow``, stacked over layers
    like every other scan leaf) in place of the fp ``values``, and record
    static ``quant`` meta (bits / effective group_rows / storage family)
    per bucket."""
    if pack.qplanes is None:
        buckets = [
            {"values": jnp.asarray(b["values"]),
             "cols": jnp.asarray(b["cols"], jnp.int32),
             "valid": b["valid"]}
            for b in pack.buckets
        ]
        quant_meta = None
    else:
        # quantized serving never touches the fp plane: upload ONLY the
        # codes and the per-row scales (expanded offline so the fused
        # path folds the whole dequant into ONE multiply per bucket) —
        # uploading the fp32 values just to drop them would transiently
        # hold 4-8x the quantized footprint on device
        buckets = [
            {"q": jnp.asarray(plane.device_codes()),     # (L, HR, K, Lc[/2])
             "cols": jnp.asarray(b["cols"], jnp.int32),
             "srow": jnp.asarray(
                 np.repeat(plane.scales, plane.group_rows, axis=-1)),
             "valid": b["valid"]}
            for b, plane in zip(pack.buckets, pack.qplanes)
        ]
        quant_meta = tuple(
            {"bits": p.bits, "group_rows": p.group_rows, "storage": p.storage}
            for p in pack.qplanes)
    return {
        "halves": pack.halves,
        "n_rows": pack.n_rows,
        "n_cols": pack.n_cols,
        "r_pad": pack.r_pad,
        "chunk_cols": pack.chunk_cols,
        "bucket_rows": pack.bucket_rows,
        "widths": pack.widths,
        "buckets": buckets,
        "perm": jnp.asarray(pack.perm, jnp.int32),
        "inv_perm": jnp.asarray(pack.inv_perm, jnp.int32),
        "nnz": pack.nnz,
        "nnz_per_layer": np.asarray(pack.nnz_per_layer),
        "nnz_per_half": np.asarray(pack.nnz_per_half),
        "padded_per_layer": pack.padded_slots_per_layer,
        "plan": pack.plan,
        "quant": quant_meta,
        "qplanes": pack.qplanes,
    }


def _dequantized_halves(pack: BucketedStackedPack) -> list:
    """Reconstruct the dense (transposed) matrices the quantized pack
    actually encodes: dequantize each bucket plane and unscatter — these
    replace the pruned copies so the dense prefill datapath (Section
    III-I) and the parity tests run the *same* effective weights as the
    quantized kernels."""
    deq = dataclasses.replace(pack, buckets=[
        dict(b, values=plane.dequantize())
        for b, plane in zip(pack.buckets, pack.qplanes)])
    return [[bucketed_stack_to_dense(deq, l, h)
             for l in range(pack.n_layers)]
            for h in range(pack.halves)]


def sparsify_mlps(cfg: ModelConfig, params: dict, sparsity: float,
                  row_tile: int = 128,
                  chunk_cols: int = ops.DEFAULT_CHUNK_COLS,
                  n_buckets: int = 4,
                  quant: str | None = None,
                  quant_spec=None) -> dict:
    """Offline pipeline: prune + fuse + pack (+ quantize) every MLP
    projection.

    Returns the fused serving packs plus pruned dense copies for
    verification:

    * ``"gateup"``: gate and up row-concatenated per bucket under one
      shared permutation (``halves == 2``; just up for non-gated MLPs);
    * ``"down"``: w_down with its column ids pre-composed with the gateup
      packed order (its gather domain is the gateup ``r_pad``).

    ``quant`` ("int8" | "int4"; or pass an explicit
    ``repro.quant.QuantSpec`` via ``quant_spec``) re-encodes the packs'
    value planes per bucket row group and swaps the pruned dense copies
    for their dequantized reconstructions — decode then serves from the
    narrow codes while the GEMM prefill path stays weight-consistent.
    """
    quant = None if quant in (None, "none") else quant
    out: dict = {"sparsity": sparsity, "format": "espim-fused-bucketed/v2",
                 "gated": bool(cfg.gated_mlp), "quant": quant or "none"}
    mlp = params["layers"]["mlp"]
    required = _MLP_NAMES if cfg.gated_mlp else ("w_up", "w_down")
    missing = [n for n in required if n not in mlp]
    if missing:
        raise ValueError(f"params missing MLP projection(s) {missing} "
                         f"(gated_mlp={cfg.gated_mlp})")
    pruned = {}
    for name in required:
        w = np.asarray(mlp[name], np.float32)          # (L, in, out)
        pruned[name] = np.stack([magnitude_prune(w[i], sparsity)
                                 for i in range(w.shape[0])])
        out[f"{name}_pruned"] = jnp.asarray(pruned[name], mlp[name].dtype)

    # y = x @ W  ->  rows of the packed matrix are W^T's rows (out dim)
    up_t = [m.T for m in pruned["w_up"]]
    halves = ([[m.T for m in pruned["w_gate"]], up_t] if cfg.gated_mlp
              else [up_t])
    gu = pack_bucketed_stack(halves, row_tile=row_tile,
                             chunk_cols=chunk_cols, n_buckets=n_buckets)

    if quant is not None or quant_spec is not None:
        from repro.quant import (QuantSpec, default_spec,
                                 quantize_bucketed_stack)
        spec = (quant_spec if isinstance(quant_spec, QuantSpec)
                else default_spec(quant))
        out["quant"] = quant or f"int{spec.bits}"
        out["quant_spec"] = spec
        quantize_bucketed_stack(gu, spec)
        # the dequantized halves are the weights decode actually applies:
        # make them the dense copies (prefill GEMMs + parity references)
        deq_halves = _dequantized_halves(gu)
        names = ("w_gate", "w_up") if cfg.gated_mlp else ("w_up",)
        for h, name in enumerate(names):
            pruned[name] = np.stack([m.T for m in deq_halves[h]])
            out[f"{name}_pruned"] = jnp.asarray(pruned[name],
                                                mlp[name].dtype)

    # Fold the gate/up permutation into w_down offline: permute w_down's
    # columns to the gateup *packed* order (pad positions stay zero
    # columns), so at runtime the packed intermediate feeds it directly.
    down_remapped = []
    for l, m in enumerate(pruned["w_down"]):
        wd = m.T                                        # (d_model, d_ff)
        wd_p = np.zeros((wd.shape[0], gu.r_pad), np.float32)
        wd_p[:, gu.inv_perm[l]] = wd
        down_remapped.append(wd_p)
    dn = pack_bucketed_stack([down_remapped], row_tile=row_tile,
                             chunk_cols=chunk_cols, n_buckets=n_buckets)

    if quant is not None or quant_spec is not None:
        quantize_bucketed_stack(dn, out["quant_spec"])
        deq_down = _dequantized_halves(dn)[0]           # (d_model, gu_r_pad)
        wdq = np.stack([m[:, gu.inv_perm[l]].T          # back to logical cols
                        for l, m in enumerate(deq_down)])
        pruned["w_down"] = wdq
        out["w_down_pruned"] = jnp.asarray(wdq, mlp["w_down"].dtype)

    out["gateup"] = _to_device(gu)
    out["down"] = _to_device(dn)
    return out


# --------------------------------------------------------------------------
# Fused runtime path
# --------------------------------------------------------------------------
def _scan_bufs(sparse: dict):
    """The per-layer arrays threaded through the layer scan (everything
    else about the packs is static geometry closed over by the step).
    Quantized packs thread (codes, cols, scales) triples — the stacked
    (L, G) scales are just one more scan leaf."""

    def bufs(p):
        if p["quant"] is not None:
            return [(b["q"], b["cols"], b["srow"]) for b in p["buckets"]]
        return [(b["values"], b["cols"]) for b in p["buckets"]]

    return {
        "gu": bufs(sparse["gateup"]),
        "dn": bufs(sparse["down"]),
        "dn_inv": sparse["down"]["inv_perm"],
    }


def _bucket_spmv(pack: dict, buf: tuple, g: int, xt: jnp.ndarray,
                 impl: str) -> jnp.ndarray:
    """One bucket's SpMV launch, fp or quantized per the pack's meta.
    Quantized launches return the code-domain accumulator and dequantize
    with one multiply by the pre-expanded per-row scales."""
    if pack["quant"] is not None:
        codes, cols, srow = buf
        yp = ops.espim_spmv_batched_quant(
            codes, cols, None, xt, chunk_cols=pack["chunk_cols"],
            group_rows=pack["quant"][g]["group_rows"], impl=impl)
        return yp * srow[:, None]
    vals, cols = buf
    return ops.espim_spmv_batched(vals, cols, xt,
                                  chunk_cols=pack["chunk_cols"], impl=impl)


def _fused_mlp(cfg: ModelConfig, sparse: dict, bufs: dict, hn: jnp.ndarray,
               impl: str) -> jnp.ndarray:
    """One layer's MLP through the fused packs.

    hn (B, T, d_model) -> (B, T, d_model).  Decode runs T=1 (the hot
    path); chunked prefill feeds T=chunk tokens — the kernels see B*T
    columns either way, and x stays in (in, B*T) layout throughout.
    """
    from repro.models.layers import act_fn
    act = act_fn(cfg.activation)
    gu, dn = sparse["gateup"], sparse["down"]
    b, t = hn.shape[0], hn.shape[1]
    xt = hn.reshape(-1, hn.shape[-1]).T.astype(jnp.float32)   # (in, B*T)

    parts = []
    for g, (buf, rg) in enumerate(zip(bufs["gu"], gu["bucket_rows"])):
        yp = _bucket_spmv(gu, buf, g, xt, impl)
        if sparse["gated"]:
            # gate rows and up rows of the bucket share packed order: the
            # product needs no unscatter (act(0)*0 == 0 on pad rows)
            parts.append(act(yp[:rg]) * yp[rg:])
        else:
            parts.append(act(yp))
    inter = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)

    outs = [_bucket_spmv(dn, buf, g, inter, impl)
            for g, buf in enumerate(bufs["dn"])]
    yd = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
    y = jnp.take(yd, bufs["dn_inv"], axis=0)                  # (d_model, B*T)
    return y.T.reshape(b, t, -1).astype(hn.dtype)


def _pruned_mlp(cfg: ModelConfig, sparse: dict, wl: dict, hn: jnp.ndarray
                ) -> jnp.ndarray:
    """The flexible *dense* datapath (Section III-I) over the pruned
    copies: the same matrices the packs hold, applied as GEMMs.  Prefill
    is compute-bound GEMM work where the MXU/BLAS path wins; the packs own
    the memory-bound single-token MV decode."""
    from repro.models import layers as L
    if sparse["gated"]:
        return L.mlp_gated(hn, wl["w_gate"], wl["w_up"], wl["w_down"],
                           cfg.activation)
    return L.mlp_relu2(hn, wl["w_up"], wl["w_down"], cfg.activation)


def _mlp_xs(sparse: dict, mlp_path: str):
    """Per-layer MLP inputs threaded through the scan for either path."""
    if mlp_path == "kernel":
        return _scan_bufs(sparse)
    if mlp_path != "dense":
        raise ValueError(f"unknown mlp_path {mlp_path!r}")
    names = (("w_gate", "w_up", "w_down") if sparse["gated"]
             else ("w_up", "w_down"))
    return {n: sparse[f"{n}_pruned"] for n in names}


def _layer_stack(cfg: ModelConfig, params: dict, sparse: dict, cache: dict,
                 h, attn_step, impl: str, unroll: bool,
                 mlp_path: str = "kernel"):
    """Shared layer loop for decode/prefill: scan by default; ``unroll``
    keeps the per-layer Python loop as the parity reference."""

    def body(h, xs):
        lp, kc, vc, mx = xs
        a, kc, vc, _, _ = attn_step(lp, T._norm(cfg, lp["ln1"], h), kc, vc)
        h = h + a
        hn = T._norm(cfg, lp["ln2"], h)
        if mlp_path == "kernel":
            h = h + _fused_mlp(cfg, sparse, mx, hn, impl)
        else:
            h = h + _pruned_mlp(cfg, sparse, mx, hn)
        return h, (kc, vc)

    xs = (params["layers"], cache["k"], cache["v"],
          _mlp_xs(sparse, mlp_path))
    if unroll:
        k_new, v_new = [], []
        for i in range(cfg.n_layers):
            h, (kc, vc) = body(h, jax.tree.map(lambda x: x[i], xs))
            k_new.append(kc)
            v_new.append(vc)
        return h, jnp.stack(k_new), jnp.stack(v_new)
    h, (k_new, v_new) = jax.lax.scan(body, h, xs)
    return h, k_new, v_new


def decode_step_sparse(cfg: ModelConfig, params: dict, sparse: dict,
                       cache: dict, batch: dict, impl: str = "ref",
                       unroll: bool = False):
    """transformer.decode_step with ESPIM-format MLPs (dense attention)."""
    tokens = batch["tokens"]
    h = T.embed_tokens(cfg, params, tokens)

    def attn_step(lp, hn, kc, vc):
        return T.attn_decode_apply(cfg, lp["attn"], hn, kc, vc, cache["len"])

    h, k_new, v_new = _layer_stack(cfg, params, sparse, cache, h, attn_step,
                                   impl, unroll)
    logits = T.logits_from_hidden(cfg, params, h)
    new_cache = {"k": k_new, "v": v_new, "len": cache["len"] + 1}
    return logits, new_cache


def prefill_chunk_sparse(cfg: ModelConfig, params: dict, sparse: dict,
                         cache: dict, batch: dict, impl: str = "ref",
                         unroll: bool = False, mlp_path: str = "dense"):
    """transformer.prefill_chunk for the ESPIM-format engine (dense
    attention): a C-token chunk lands at cache["len"]..  Same contract as
    ``factory.prefill_chunk``.

    ``mlp_path`` picks the projection datapath — the paper's flexible
    dense/sparse configuration (Section III-I) applied per serving phase:
    ``"dense"`` (default) runs the GEMM-shaped chunk through the pruned
    dense copies (bit-identical matrices, compute-bound phase);
    ``"kernel"`` feeds the fused packs with B*C columns (the MV datapath,
    used by the parity tests and on PIM-like backends)."""
    tokens = batch["tokens"]
    start = cache["len"]
    n_valid = batch.get("n_valid")
    if n_valid is None:
        n_valid = jnp.full_like(start, tokens.shape[1])
    h = T.embed_tokens(cfg, params, tokens)

    def attn_step(lp, hn, kc, vc):
        return T.attn_prefill_apply(cfg, lp["attn"], hn, kc, vc, start)

    h, k_new, v_new = _layer_stack(cfg, params, sparse, cache, h, attn_step,
                                   impl, unroll, mlp_path=mlp_path)
    logits = T.logits_from_hidden(cfg, params, h)
    new_cache = {"k": k_new, "v": v_new, "len": start + n_valid}
    return logits, new_cache


# --------------------------------------------------------------------------
# Stats
# --------------------------------------------------------------------------
def _plane_bytes(p: dict) -> tuple:
    """(value_bytes_total, index_bytes_total, per-layer value bytes) for a
    pack dict: fp32 planes cost 4 bytes/slot; quantized planes use the
    packed accounting (codes at their group's bit width + scales + the
    int4 fallback map).  The index plane is int32 and quant-invariant —
    the paper's value/index decoupling in byte form."""
    n_layers = len(p["nnz_per_layer"])
    index_total = 4 * p["padded_per_layer"] * n_layers
    if p["qplanes"] is not None:
        per_layer = np.sum([pl.value_bytes_by_lead() for pl in p["qplanes"]],
                           axis=0)
        return int(per_layer.sum()), index_total, [int(b) for b in per_layer]
    per = 4 * p["padded_per_layer"]
    return per * n_layers, index_total, [per] * n_layers


def _pack_stats(p: dict) -> dict:
    n_layers = len(p["nnz_per_layer"])
    padded = p["padded_per_layer"] * n_layers
    vbytes, ibytes, vbytes_layer = _plane_bytes(p)
    return {
        "nnz": int(p["nnz"]),
        "padded_slots": int(padded),
        "pad_frac": 1 - p["nnz"] / padded,
        "pad_frac_per_layer": [
            1 - int(n) / p["padded_per_layer"]
            for n in p["nnz_per_layer"]
        ],
        "bucket_rows": list(p["bucket_rows"]),
        "bucket_widths": list(p["widths"]),
        "single_bucket_pad_frac": 1 - p["nnz"] / max(
            1, p["plan"].single_bucket_slots * p["buckets"][0]["cols"].shape[2]
            * p["halves"] * n_layers),
        "value_plane_bytes": vbytes,
        "index_plane_bytes": ibytes,
        "value_plane_bytes_per_layer": vbytes_layer,
        "bits_per_nnz": 8.0 * vbytes / max(1, int(p["nnz"])),
        "bits_per_nnz_per_layer": [
            8.0 * b / max(1, int(n))
            for b, n in zip(vbytes_layer, p["nnz_per_layer"])
        ],
    }


def sparse_stats(sparse: dict) -> dict:
    """Aggregate + per-projection + per-layer padding AND byte-plane stats.

    The fused gateup pack reports per-half (per-projection) nnz under the
    original projection names; padding (and the value/index planes) is a
    property of the fused pack, so per-projection figures split the fused
    pack's slots evenly between the halves (they share every bucket
    width).  ``value_plane_bytes`` / ``index_plane_bytes`` /
    ``bits_per_nnz`` report the stored (possibly quantized) format — the
    bytes a decode token streams across the pin per layer/projection."""
    gu, dn = sparse["gateup"], sparse["down"]
    n_layers = len(gu["nnz_per_layer"])
    out = {"quant": sparse.get("quant", "none"),
           "gateup": _pack_stats(gu), "down": _pack_stats(dn)}
    half_names = ("w_gate", "w_up") if sparse["gated"] else ("w_up",)
    half_padded = gu["padded_per_layer"] * n_layers // gu["halves"]
    for h, name in enumerate(half_names):
        nnz_h = int(gu["nnz_per_half"][h].sum())
        out[name] = {
            "nnz": nnz_h,
            "padded_slots": half_padded,
            "pad_frac": 1 - nnz_h / half_padded,
            "pad_frac_per_layer": [
                1 - int(n) / (gu["padded_per_layer"] // gu["halves"])
                for n in gu["nnz_per_half"][h]
            ],
            "value_plane_bytes": out["gateup"]["value_plane_bytes"]
            // gu["halves"],
            "index_plane_bytes": out["gateup"]["index_plane_bytes"]
            // gu["halves"],
            "bits_per_nnz": 8.0 * (out["gateup"]["value_plane_bytes"]
                                   / gu["halves"]) / max(1, nnz_h),
        }
    out["w_down"] = dict(out["down"])
    total_nnz = gu["nnz"] + dn["nnz"]
    total_padded = (gu["padded_per_layer"] + dn["padded_per_layer"]) * n_layers
    total_value = (out["gateup"]["value_plane_bytes"]
                   + out["down"]["value_plane_bytes"])
    total_index = (out["gateup"]["index_plane_bytes"]
                   + out["down"]["index_plane_bytes"])
    out["total"] = {
        "nnz": int(total_nnz),
        "padded_slots": int(total_padded),
        "pad_frac": 1 - total_nnz / total_padded,
        "value_plane_bytes": int(total_value),
        "index_plane_bytes": int(total_index),
        "bits_per_nnz": 8.0 * total_value / max(1, total_nnz),
        # every decode token streams each layer's planes once: the
        # weight-side bytes-moved-per-token the serve bench records
        "bytes_per_token": int(total_value + total_index),
    }
    return out
