"""ESPIM-format sparse serving of a whole dense-family LM.

The paper's deployment (Section IV): take a trained model, magnitude-prune
the projection matrices, and serve MV decode from the compressed format.
This module converts a dense LM's stacked MLP weights into stacked ELL
packs (the offline SDDS-analogue pipeline: prune -> balance -> chunk ->
width-bucket) and runs the decode step with the sparse kernels in place of
the dense matmuls — attention stays dense (its per-layer matrices are small
relative to the MLPs, which hold ~2/3 of LLaMA-class weights).

The decode datapath is fully fused (DESIGN.md section 8):

* one ``jax.lax.scan`` over the layer stack — the packs are padded to
  uniform per-bucket shapes for exactly this;
* gate and up are row-concatenated into ONE pack per bucket sharing one
  balance permutation (the paper's vector-broadcast sharing applied across
  projections): a single SpMV launch yields both halves, and
  ``silu(gate) * up`` runs directly in packed order;
* the down projection's column ids are pre-composed offline with the
  gate/up packed order, so the intermediate never needs unscattering; the
  only runtime permutation left is one ``take`` by ``inv_perm`` on the
  down output (``scatter_rows_ref`` is gone from the per-token path);
* ``x`` stays in (in, B) layout across the whole MLP — one transpose in,
  one out, per layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import BucketedStackedPack, pack_bucketed_stack
from repro.kernels import ops
from repro.models import transformer as T

__all__ = ["sparsify_mlps", "decode_step_sparse", "prefill_chunk_sparse",
           "sparse_stats"]

_MLP_NAMES = ("w_gate", "w_up", "w_down")


def _to_device(pack: BucketedStackedPack) -> dict:
    """BucketedStackedPack -> the jnp dict the serving step consumes.
    ``valid`` masks and nnz stats stay host-side (stats/tests only)."""
    return {
        "halves": pack.halves,
        "n_rows": pack.n_rows,
        "n_cols": pack.n_cols,
        "r_pad": pack.r_pad,
        "chunk_cols": pack.chunk_cols,
        "bucket_rows": pack.bucket_rows,
        "widths": pack.widths,
        "buckets": [
            {"values": jnp.asarray(b["values"]),
             "cols": jnp.asarray(b["cols"], jnp.int32),
             "valid": b["valid"]}
            for b in pack.buckets
        ],
        "perm": jnp.asarray(pack.perm, jnp.int32),
        "inv_perm": jnp.asarray(pack.inv_perm, jnp.int32),
        "nnz": pack.nnz,
        "nnz_per_layer": np.asarray(pack.nnz_per_layer),
        "nnz_per_half": np.asarray(pack.nnz_per_half),
        "padded_per_layer": pack.padded_slots_per_layer,
        "plan": pack.plan,
    }


def sparsify_mlps(cfg: ModelConfig, params: dict, sparsity: float,
                  row_tile: int = 128,
                  chunk_cols: int = ops.DEFAULT_CHUNK_COLS,
                  n_buckets: int = 4) -> dict:
    """Offline pipeline: prune + fuse + pack every MLP projection.

    Returns the fused serving packs plus pruned dense copies for
    verification:

    * ``"gateup"``: gate and up row-concatenated per bucket under one
      shared permutation (``halves == 2``; just up for non-gated MLPs);
    * ``"down"``: w_down with its column ids pre-composed with the gateup
      packed order (its gather domain is the gateup ``r_pad``).
    """
    out: dict = {"sparsity": sparsity, "format": "espim-fused-bucketed/v2",
                 "gated": bool(cfg.gated_mlp)}
    mlp = params["layers"]["mlp"]
    required = _MLP_NAMES if cfg.gated_mlp else ("w_up", "w_down")
    missing = [n for n in required if n not in mlp]
    if missing:
        raise ValueError(f"params missing MLP projection(s) {missing} "
                         f"(gated_mlp={cfg.gated_mlp})")
    pruned = {}
    for name in required:
        w = np.asarray(mlp[name], np.float32)          # (L, in, out)
        pruned[name] = np.stack([magnitude_prune(w[i], sparsity)
                                 for i in range(w.shape[0])])
        out[f"{name}_pruned"] = jnp.asarray(pruned[name], mlp[name].dtype)

    # y = x @ W  ->  rows of the packed matrix are W^T's rows (out dim)
    up_t = [m.T for m in pruned["w_up"]]
    halves = ([[m.T for m in pruned["w_gate"]], up_t] if cfg.gated_mlp
              else [up_t])
    gu = pack_bucketed_stack(halves, row_tile=row_tile,
                             chunk_cols=chunk_cols, n_buckets=n_buckets)

    # Fold the gate/up permutation into w_down offline: permute w_down's
    # columns to the gateup *packed* order (pad positions stay zero
    # columns), so at runtime the packed intermediate feeds it directly.
    down_remapped = []
    for l, m in enumerate(pruned["w_down"]):
        wd = m.T                                        # (d_model, d_ff)
        wd_p = np.zeros((wd.shape[0], gu.r_pad), np.float32)
        wd_p[:, gu.inv_perm[l]] = wd
        down_remapped.append(wd_p)
    dn = pack_bucketed_stack([down_remapped], row_tile=row_tile,
                             chunk_cols=chunk_cols, n_buckets=n_buckets)

    out["gateup"] = _to_device(gu)
    out["down"] = _to_device(dn)
    return out


# --------------------------------------------------------------------------
# Fused runtime path
# --------------------------------------------------------------------------
def _scan_bufs(sparse: dict):
    """The per-layer arrays threaded through the layer scan (everything
    else about the packs is static geometry closed over by the step)."""
    return {
        "gu": [(b["values"], b["cols"]) for b in sparse["gateup"]["buckets"]],
        "dn": [(b["values"], b["cols"]) for b in sparse["down"]["buckets"]],
        "dn_inv": sparse["down"]["inv_perm"],
    }


def _fused_mlp(cfg: ModelConfig, sparse: dict, bufs: dict, hn: jnp.ndarray,
               impl: str) -> jnp.ndarray:
    """One layer's MLP through the fused packs.

    hn (B, T, d_model) -> (B, T, d_model).  Decode runs T=1 (the hot
    path); chunked prefill feeds T=chunk tokens — the kernels see B*T
    columns either way, and x stays in (in, B*T) layout throughout.
    """
    from repro.models.layers import act_fn
    act = act_fn(cfg.activation)
    gu, dn = sparse["gateup"], sparse["down"]
    b, t = hn.shape[0], hn.shape[1]
    xt = hn.reshape(-1, hn.shape[-1]).T.astype(jnp.float32)   # (in, B*T)

    parts = []
    for (vals, cols), rg in zip(bufs["gu"], gu["bucket_rows"]):
        yp = ops.espim_spmv_batched(vals, cols, xt,
                                    chunk_cols=gu["chunk_cols"], impl=impl)
        if sparse["gated"]:
            # gate rows and up rows of the bucket share packed order: the
            # product needs no unscatter (act(0)*0 == 0 on pad rows)
            parts.append(act(yp[:rg]) * yp[rg:])
        else:
            parts.append(act(yp))
    inter = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)

    outs = [ops.espim_spmv_batched(vals, cols, inter,
                                   chunk_cols=dn["chunk_cols"], impl=impl)
            for (vals, cols) in bufs["dn"]]
    yd = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
    y = jnp.take(yd, bufs["dn_inv"], axis=0)                  # (d_model, B*T)
    return y.T.reshape(b, t, -1).astype(hn.dtype)


def _pruned_mlp(cfg: ModelConfig, sparse: dict, wl: dict, hn: jnp.ndarray
                ) -> jnp.ndarray:
    """The flexible *dense* datapath (Section III-I) over the pruned
    copies: the same matrices the packs hold, applied as GEMMs.  Prefill
    is compute-bound GEMM work where the MXU/BLAS path wins; the packs own
    the memory-bound single-token MV decode."""
    from repro.models import layers as L
    if sparse["gated"]:
        return L.mlp_gated(hn, wl["w_gate"], wl["w_up"], wl["w_down"],
                           cfg.activation)
    return L.mlp_relu2(hn, wl["w_up"], wl["w_down"], cfg.activation)


def _mlp_xs(sparse: dict, mlp_path: str):
    """Per-layer MLP inputs threaded through the scan for either path."""
    if mlp_path == "kernel":
        return _scan_bufs(sparse)
    if mlp_path != "dense":
        raise ValueError(f"unknown mlp_path {mlp_path!r}")
    names = (("w_gate", "w_up", "w_down") if sparse["gated"]
             else ("w_up", "w_down"))
    return {n: sparse[f"{n}_pruned"] for n in names}


def _layer_stack(cfg: ModelConfig, params: dict, sparse: dict, cache: dict,
                 h, attn_step, impl: str, unroll: bool,
                 mlp_path: str = "kernel"):
    """Shared layer loop for decode/prefill: scan by default; ``unroll``
    keeps the per-layer Python loop as the parity reference."""

    def body(h, xs):
        lp, kc, vc, mx = xs
        a, kc, vc, _, _ = attn_step(lp, T._norm(cfg, lp["ln1"], h), kc, vc)
        h = h + a
        hn = T._norm(cfg, lp["ln2"], h)
        if mlp_path == "kernel":
            h = h + _fused_mlp(cfg, sparse, mx, hn, impl)
        else:
            h = h + _pruned_mlp(cfg, sparse, mx, hn)
        return h, (kc, vc)

    xs = (params["layers"], cache["k"], cache["v"],
          _mlp_xs(sparse, mlp_path))
    if unroll:
        k_new, v_new = [], []
        for i in range(cfg.n_layers):
            h, (kc, vc) = body(h, jax.tree.map(lambda x: x[i], xs))
            k_new.append(kc)
            v_new.append(vc)
        return h, jnp.stack(k_new), jnp.stack(v_new)
    h, (k_new, v_new) = jax.lax.scan(body, h, xs)
    return h, k_new, v_new


def decode_step_sparse(cfg: ModelConfig, params: dict, sparse: dict,
                       cache: dict, batch: dict, impl: str = "ref",
                       unroll: bool = False):
    """transformer.decode_step with ESPIM-format MLPs (dense attention)."""
    tokens = batch["tokens"]
    h = T.embed_tokens(cfg, params, tokens)

    def attn_step(lp, hn, kc, vc):
        return T.attn_decode_apply(cfg, lp["attn"], hn, kc, vc, cache["len"])

    h, k_new, v_new = _layer_stack(cfg, params, sparse, cache, h, attn_step,
                                   impl, unroll)
    logits = T.logits_from_hidden(cfg, params, h)
    new_cache = {"k": k_new, "v": v_new, "len": cache["len"] + 1}
    return logits, new_cache


def prefill_chunk_sparse(cfg: ModelConfig, params: dict, sparse: dict,
                         cache: dict, batch: dict, impl: str = "ref",
                         unroll: bool = False, mlp_path: str = "dense"):
    """transformer.prefill_chunk for the ESPIM-format engine (dense
    attention): a C-token chunk lands at cache["len"]..  Same contract as
    ``factory.prefill_chunk``.

    ``mlp_path`` picks the projection datapath — the paper's flexible
    dense/sparse configuration (Section III-I) applied per serving phase:
    ``"dense"`` (default) runs the GEMM-shaped chunk through the pruned
    dense copies (bit-identical matrices, compute-bound phase);
    ``"kernel"`` feeds the fused packs with B*C columns (the MV datapath,
    used by the parity tests and on PIM-like backends)."""
    tokens = batch["tokens"]
    start = cache["len"]
    n_valid = batch.get("n_valid")
    if n_valid is None:
        n_valid = jnp.full_like(start, tokens.shape[1])
    h = T.embed_tokens(cfg, params, tokens)

    def attn_step(lp, hn, kc, vc):
        return T.attn_prefill_apply(cfg, lp["attn"], hn, kc, vc, start)

    h, k_new, v_new = _layer_stack(cfg, params, sparse, cache, h, attn_step,
                                   impl, unroll, mlp_path=mlp_path)
    logits = T.logits_from_hidden(cfg, params, h)
    new_cache = {"k": k_new, "v": v_new, "len": start + n_valid}
    return logits, new_cache


# --------------------------------------------------------------------------
# Stats
# --------------------------------------------------------------------------
def _pack_stats(p: dict) -> dict:
    n_layers = len(p["nnz_per_layer"])
    padded = p["padded_per_layer"] * n_layers
    return {
        "nnz": int(p["nnz"]),
        "padded_slots": int(padded),
        "pad_frac": 1 - p["nnz"] / padded,
        "pad_frac_per_layer": [
            1 - int(n) / p["padded_per_layer"]
            for n in p["nnz_per_layer"]
        ],
        "bucket_rows": list(p["bucket_rows"]),
        "bucket_widths": list(p["widths"]),
        "single_bucket_pad_frac": 1 - p["nnz"] / max(
            1, p["plan"].single_bucket_slots * p["buckets"][0]["cols"].shape[2]
            * p["halves"] * n_layers),
    }


def sparse_stats(sparse: dict) -> dict:
    """Aggregate + per-projection + per-layer padding stats.

    The fused gateup pack reports per-half (per-projection) nnz under the
    original projection names; padding is a property of the fused pack, so
    per-projection ``pad_frac`` splits the fused pack's dead slots evenly
    between the halves (they share every bucket width)."""
    gu, dn = sparse["gateup"], sparse["down"]
    n_layers = len(gu["nnz_per_layer"])
    out = {"gateup": _pack_stats(gu), "down": _pack_stats(dn)}
    half_names = ("w_gate", "w_up") if sparse["gated"] else ("w_up",)
    half_padded = gu["padded_per_layer"] * n_layers // gu["halves"]
    for h, name in enumerate(half_names):
        nnz_h = int(gu["nnz_per_half"][h].sum())
        out[name] = {
            "nnz": nnz_h,
            "padded_slots": half_padded,
            "pad_frac": 1 - nnz_h / half_padded,
            "pad_frac_per_layer": [
                1 - int(n) / (gu["padded_per_layer"] // gu["halves"])
                for n in gu["nnz_per_half"][h]
            ],
        }
    out["w_down"] = dict(out["down"])
    total_nnz = gu["nnz"] + dn["nnz"]
    total_padded = (gu["padded_per_layer"] + dn["padded_per_layer"]) * n_layers
    out["total"] = {
        "nnz": int(total_nnz),
        "padded_slots": int(total_padded),
        "pad_frac": 1 - total_nnz / total_padded,
    }
    return out
