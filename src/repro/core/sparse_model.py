"""ESPIM-format sparse serving of a whole dense-family LM.

The paper's deployment (Section IV): take a trained model, magnitude-prune
the projection matrices, and serve MV decode from the compressed format.
This module converts a dense LM's stacked MLP weights into stacked ELL
packs (the offline SDDS-analogue pipeline: prune -> SparTen row balance ->
pack) and runs the decode step with the sparse kernels in place of the
dense matmuls — attention stays dense (its per-layer matrices are small
relative to the MLPs, which hold ~2/3 of LLaMA-class weights; per-cell the
paper's Table III is dominated by the three FFN matrices).

Layer packs are padded to the max ELL width across layers so the whole
stack stays a single scanned array.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import pack_ell_chunked
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.models import transformer as T

__all__ = ["sparsify_mlps", "decode_step_sparse", "prefill_chunk_sparse",
           "sparse_stats"]

_MLP_NAMES = ("w_gate", "w_up", "w_down")


def _pack_stack(mats: list[np.ndarray], row_tile: int,
                chunk_cols: int) -> dict:
    """Pack a list of per-layer (out, in) matrices into stacked
    column-chunked ELL arrays (values/cols padded to the max chunk width;
    perm per layer).  All layers of one projection share n_cols, so the
    chunk grid (K, chunk_cols) is uniform across the stack."""
    packs = [pack_ell_chunked(m, row_tile=row_tile, chunk_cols=chunk_cols)
             for m in mats]
    lmax = max(p.chunk_width for p in packs)
    rpad = max(p.r_pad for p in packs)
    k = packs[0].n_chunks
    assert all(p.n_chunks == k for p in packs), "uniform n_cols per stack"

    def pad(p, arr):
        out = np.zeros((rpad, k, lmax), arr.dtype)
        out[: arr.shape[0], :, : arr.shape[2]] = arr
        return out

    return {
        "values": jnp.asarray(np.stack([pad(p, p.values) for p in packs])),
        "cols": jnp.asarray(np.stack(
            [pad(p, p.cols) for p in packs]), jnp.int32),
        "perm": jnp.asarray(np.stack(
            [np.pad(p.perm, (0, rpad - p.r_pad), constant_values=-1)
             for p in packs]), jnp.int32),
        "n_rows": packs[0].n_rows,
        "chunk_cols": packs[0].chunk_cols,
        "nnz": sum(p.stats.nnz for p in packs),
        "padded": rpad * k * lmax * len(packs),
    }


def sparsify_mlps(cfg: ModelConfig, params: dict, sparsity: float,
                  row_tile: int = 128,
                  chunk_cols: int = ops.DEFAULT_CHUNK_COLS) -> dict:
    """Offline pipeline: prune + pack every MLP projection of a dense LM.

    Returns {name: stacked chunked pack} with per-layer leading dims, plus
    pruned dense copies for verification."""
    out: dict = {"sparsity": sparsity}
    mlp = params["layers"]["mlp"]
    for name in _MLP_NAMES:
        if name not in mlp:
            continue
        w = np.asarray(mlp[name], np.float32)          # (L, in, out)
        pruned = np.stack([magnitude_prune(w[i], sparsity)
                           for i in range(w.shape[0])])
        # y = x @ W  ->  rows of the packed matrix are W^T's rows (out dim)
        out[name] = _pack_stack([m.T for m in pruned], row_tile, chunk_cols)
        out[f"{name}_pruned"] = jnp.asarray(pruned, mlp[name].dtype)
    return out


def _sparse_proj(pack_l: dict, x: jnp.ndarray, impl: str) -> jnp.ndarray:
    """x (B, T, in) -> (B, T, out) through one layer's chunked ELL pack,
    via the fused batched kernel.  Decode runs T=1 (the hot path); chunked
    prefill feeds T=chunk tokens — the kernel sees B*T columns either way.
    """
    b, t = x.shape[0], x.shape[1]
    xt = x.reshape(-1, x.shape[-1]).T.astype(jnp.float32)  # (in, B*T)
    yp = ops.espim_spmv_batched(pack_l["values"], pack_l["cols"], xt,
                                chunk_cols=pack_l["chunk_cols"],
                                impl=impl)             # (R_pad, B*T)
    y = kref.scatter_rows_ref(yp, pack_l["perm"], pack_l["n_rows"])
    return y.T.reshape(b, t, -1).astype(x.dtype)


def decode_step_sparse(cfg: ModelConfig, params: dict, sparse: dict,
                       cache: dict, batch: dict, impl: str = "ref"):
    """transformer.decode_step with ESPIM-format MLPs (dense attention)."""
    tokens = batch["tokens"]
    h = T.embed_tokens(cfg, params, tokens)

    # explicit python loop over layers: the packs are per-layer arrays of
    # uniform width, so a scan also works; the loop keeps this reference
    # serving implementation shape-transparent
    k_new, v_new = [], []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda x: x[i], params["layers"])
        a, kc, vc, _, _ = T.attn_decode_apply(
            cfg, lp["attn"], T._norm(cfg, lp["ln1"], h),
            cache["k"][i], cache["v"][i], cache["len"])
        h = h + a
        hn = T._norm(cfg, lp["ln2"], h)
        h = h + _sparse_mlp(cfg, sparse, i, hn, impl)
        k_new.append(kc)
        v_new.append(vc)

    logits = T.logits_from_hidden(cfg, params, h)
    new_cache = {"k": jnp.stack(k_new), "v": jnp.stack(v_new),
                 "len": cache["len"] + 1}
    return logits, new_cache


def _sparse_mlp(cfg: ModelConfig, sparse: dict, i: int, hn, impl: str):
    """One layer's MLP through the ESPIM packs (shared by decode/prefill)."""
    def layer_pack(name):
        p = sparse[name]
        return {"values": p["values"][i], "cols": p["cols"][i],
                "perm": p["perm"][i], "n_rows": p["n_rows"],
                "chunk_cols": p["chunk_cols"]}

    if cfg.gated_mlp:
        gate = jax.nn.silu(_sparse_proj(layer_pack("w_gate"), hn, impl))
        up = _sparse_proj(layer_pack("w_up"), hn, impl)
        return _sparse_proj(layer_pack("w_down"), gate * up, impl)
    from repro.models.layers import act_fn
    up = _sparse_proj(layer_pack("w_up"), hn, impl)
    return _sparse_proj(layer_pack("w_down"), act_fn(cfg.activation)(up),
                        impl)


def prefill_chunk_sparse(cfg: ModelConfig, params: dict, sparse: dict,
                         cache: dict, batch: dict, impl: str = "ref"):
    """transformer.prefill_chunk with ESPIM-format MLPs (dense attention):
    a C-token chunk lands at cache["len"].., the MLP projections run
    through the batched chunked-ELL kernel with B*C columns.  Same
    contract as ``factory.prefill_chunk``."""
    tokens = batch["tokens"]
    start = cache["len"]
    n_valid = batch.get("n_valid")
    if n_valid is None:
        n_valid = jnp.full_like(start, tokens.shape[1])
    h = T.embed_tokens(cfg, params, tokens)

    k_new, v_new = [], []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda x: x[i], params["layers"])
        a, kc, vc, _, _ = T.attn_prefill_apply(
            cfg, lp["attn"], T._norm(cfg, lp["ln1"], h),
            cache["k"][i], cache["v"][i], start)
        h = h + a
        hn = T._norm(cfg, lp["ln2"], h)
        h = h + _sparse_mlp(cfg, sparse, i, hn, impl)
        k_new.append(kc)
        v_new.append(vc)

    logits = T.logits_from_hidden(cfg, params, h)
    new_cache = {"k": jnp.stack(k_new), "v": jnp.stack(v_new),
                 "len": start + n_valid}
    return logits, new_cache


def sparse_stats(sparse: dict) -> dict:
    out = {}
    for name in _MLP_NAMES:
        if name in sparse:
            p = sparse[name]
            out[name] = {
                "nnz": int(p["nnz"]),
                "padded_slots": int(p["padded"]),
                "pad_frac": 1 - p["nnz"] / p["padded"],
            }
    return out
