"""ESPIM-format sparse serving of a whole dense-family LM.

The paper's deployment (Section IV): take a trained model, magnitude-prune
the projection matrices, and serve MV decode from the compressed format.
ESPIM's format and SDDS scheduling are projection-agnostic — the paper
applies fine-grained interleaving, balance permutation and decoupled
value/index planes to EVERY MV of the decode step — so the offline
pipeline here is a projection-generic **pack-group compiler**
(``sparsify_model``): a list of declarative ``PackGroupSpec``s
(repro.core.sdds) is compiled, group by group, into width-bucketed
layer-stacked packs (prune -> fuse -> balance -> chunk -> width-bucket ->
[quantize]), and the decode step runs every per-token MV — q/k/v/o AND
gate/up/down — through the packed kernels.

The default decoder-layer group set (DESIGN.md section 10):

* ``qkv``: q, k, v row-concatenated into ONE pack under one balance perm
  (one SpMV launch per bucket for all three projections; per-projection
  row counts may differ — GQA).  Output contract ``take``: one static
  ``jnp.take`` by ``inv_perm`` restores logical row order, because RoPE
  pairs head dims positionally and the KV cache stores logical head rows.
* ``attn_out``: the O projection, feeding the residual (``take``).
* ``gateup``: gate+up as shared-perm *halves* — ``silu(gate) * up`` runs
  directly in packed order (output contract ``folded``).
* ``down``: column ids pre-composed offline with the gateup packed order
  (``compose_with="gateup"``), output restored by one ``take``.

The decode datapath is fully fused (DESIGN.md section 8): one
``jax.lax.scan`` over the layer stack, packs padded to uniform per-bucket
shapes, activations kept in ``(features, B)`` layout between launches.
``sparsify_mlps`` survives as a thin MLP-only preset of
``sparsify_model`` (attention stays dense — the pre-PR5 behavior).

Quantized serving (``quant="int8"|"int4"``, DESIGN.md section 9): only
the packs' *value planes* are re-encoded (repro.quant) — per-bucket-row-
group scales ride the layer scan as one more stacked leaf and the fused
SpMV launches dispatch to the quantized kernels; cols/perms/plans and the
whole datapath shape are untouched.  The pruned dense copies are replaced
by the *dequantized* reconstructions, so the GEMM prefill path and every
parity test see exactly the weights the quantized kernels compute with.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import integrity
from repro.core.pruning import magnitude_prune
from repro.core.sdds import (PackGroupSpec, decoder_layer_groups,
                             validate_group_specs)
from repro.core.sparse_format import (BucketedStackedPack,
                                      bucketed_stack_to_dense,
                                      compose_cols_with_pack, pack_group,
                                      projection_padded_slots)
from repro.kernels import ops
from repro.models import transformer as T

__all__ = ["sparsify_model", "sparsify_mlps", "pruned_param_tree",
           "decode_step_sparse", "prefill_chunk_sparse", "sparse_stats",
           "verify_sparse"]

# the standard decoder-layer projections NOT covered by a group still
# stream their dense bytes every decode token — sparse_stats charges them
_DENSE_MODULES = ("attn", "mlp")


def _to_device(pack: BucketedStackedPack) -> dict:
    """BucketedStackedPack -> the jnp dict the serving step consumes.
    ``valid`` masks, nnz stats and the host QuantizedValuePlanes stay
    host-side (stats/tests only); quantized packs upload per-bucket codes
    (``q``) + pre-expanded per-row scales (``srow``, stacked over layers
    like every other scan leaf) in place of the fp ``values``, and record
    static ``quant`` meta (bits / effective group_rows / storage family)
    per bucket."""
    if pack.qplanes is None:
        buckets = [
            {"values": jnp.asarray(b["values"]),
             "cols": jnp.asarray(b["cols"], jnp.int32),
             "valid": b["valid"]}
            for b in pack.buckets
        ]
        quant_meta = None
    else:
        # quantized serving never touches the fp plane: upload ONLY the
        # codes and the per-row scales (expanded offline so the fused
        # path folds the whole dequant into ONE multiply per bucket) —
        # uploading the fp32 values just to drop them would transiently
        # hold 4-8x the quantized footprint on device
        buckets = [
            {"q": jnp.asarray(plane.device_codes()),     # (L, HR, K, Lc[/2])
             "cols": jnp.asarray(b["cols"], jnp.int32),
             "srow": jnp.asarray(plane.row_scales()),
             "valid": b["valid"]}
            for b, plane in zip(pack.buckets, pack.qplanes)
        ]
        quant_meta = tuple(
            {"bits": p.bits, "group_rows": p.group_rows, "storage": p.storage}
            for p in pack.qplanes)
    g = {
        "halves": pack.halves,
        "n_rows": pack.n_rows,
        "n_cols": pack.n_cols,
        "r_pad": pack.r_pad,
        "chunk_cols": pack.chunk_cols,
        "bucket_rows": pack.bucket_rows,
        "widths": pack.widths,
        "buckets": buckets,
        "perm": jnp.asarray(pack.perm, jnp.int32),
        "inv_perm": jnp.asarray(pack.inv_perm, jnp.int32),
        "nnz": pack.nnz,
        "nnz_per_layer": np.asarray(pack.nnz_per_layer),
        "nnz_per_half": np.asarray(pack.nnz_per_half),
        "padded_per_layer": pack.padded_slots_per_layer,
        "plan": pack.plan,
        "quant": quant_meta,
        "qplanes": pack.qplanes,
    }
    # fingerprint the *device* form — nibble-packed quant codes, expanded
    # srow scales and int32 perms differ byte-wise from the host pack, so
    # the build-time pack fingerprint cannot stand in for the upload check
    g["plane_fingerprints"], g["fingerprint"] = _group_fingerprint(g)
    return g


def _group_fingerprint(g: dict) -> tuple[dict, str]:
    """Per-plane digests + bound digest over exactly the arrays the jitted
    decode gathers (plus the host valid masks and the SDDS plan meta)."""
    planes = {}
    for gi, b in enumerate(g["buckets"]):
        for nm in ("values", "q", "cols", "srow", "valid"):
            if nm in b:
                planes[f"b{gi}.{nm}"] = np.asarray(b[nm])
    planes["perm"] = np.asarray(g["perm"])
    planes["inv_perm"] = np.asarray(g["inv_perm"])
    meta = {
        "halves": g["halves"], "n_rows": g["n_rows"], "n_cols": g["n_cols"],
        "r_pad": g["r_pad"], "chunk_cols": g["chunk_cols"],
        "bucket_rows": list(g["bucket_rows"]), "widths": list(g["widths"]),
        "quant": ([dict(q) for q in g["quant"]] if g["quant"] else None),
        "plan": integrity.plan_fingerprint(g["plan"]),
    }
    fps = integrity.fingerprint_planes(planes)
    return fps, integrity.bind_fingerprint(fps, meta)


def _validate_group(name: str, g: dict) -> None:
    """Bounds-validate one serving group's device planes: chunk-local
    column ids against the gather domain, perm/inv_perm consistency, and
    quantized planes against their scale-group layout."""
    err = integrity.PackIntegrityError
    cc, n_cols = g["chunk_cols"], g["n_cols"]
    for gi, b in enumerate(g["buckets"]):
        cols = np.asarray(b["cols"])
        valid = np.asarray(b["valid"], bool)
        what = f"group {name!r} bucket {gi}"
        if cols.shape != valid.shape:
            raise err(f"{what}: cols/valid shape mismatch")
        k = cols.shape[-2]
        lim = np.minimum(cc, n_cols - np.arange(k) * cc)
        lim = lim.reshape((1,) * (cols.ndim - 2) + (k, 1))
        if (valid & ((cols < 0) | (cols >= lim))).any():
            raise err(f"{what}: index plane out of bounds for input dim "
                      f"{n_cols} (chunk_cols={cc})")
        if "values" in b:
            if not bool(np.isfinite(np.asarray(b["values"])).all()):
                raise err(f"{what}: non-finite entries in the value plane")
        if "srow" in b:
            srow = np.asarray(b["srow"])
            if not bool(np.isfinite(srow).all()):
                raise err(f"{what}: non-finite quant scales")
            if srow.shape != cols.shape[:2]:
                raise err(f"{what}: srow scale layout {srow.shape} does not "
                          f"cover the packed rows {cols.shape[:2]}")
            qm = g["quant"][gi]
            if cols.shape[1] % max(1, qm["group_rows"]):
                raise err(f"{what}: rows not divisible by scale "
                          f"group_rows={qm['group_rows']}")
            q = np.asarray(b["q"])
            if qm["storage"] == "nib4":
                want = cols.shape[:-1] + ((cols.shape[-1] + 1) // 2,)
                if q.dtype != np.uint8 or q.shape != want:
                    raise err(f"{what}: nibble-packed codes layout "
                              f"{q.dtype}{q.shape} != uint8{want}")
            elif q.dtype != np.int8 or q.shape != cols.shape:
                raise err(f"{what}: int8 codes layout {q.dtype}{q.shape} "
                          f"diverges from the index plane {cols.shape}")
    integrity.validate_perm_layers(f"group {name!r}", g["perm"],
                                   g["inv_perm"], g["n_rows"])


def verify_sparse(sparse: dict) -> dict:
    """The serving-side upload check (engine init, benches): every group's
    device planes are bounds-validated and re-fingerprinted against the
    digests ``sparsify_model`` recorded.  Raises ``PackIntegrityError``
    naming the group and diverging planes; returns ``{group: digest}``."""
    out = {}
    for name, g in sparse.get("groups", {}).items():
        _validate_group(name, g)
        fps, bound = _group_fingerprint(g)
        recorded = g.get("fingerprint")
        if recorded is not None and recorded != bound:
            diverged = integrity.diverging_planes(
                {"planes": g.get("plane_fingerprints", {})}, {"planes": fps})
            raise integrity.PackIntegrityError(
                f"group {name!r}: device plane fingerprint mismatch "
                f"(diverged: {diverged or ['<meta/schedule>']}) — the pack "
                "was corrupted after build or paired with the wrong "
                "schedule")
        out[name] = bound
    return out


def _dequantized_projs(pack: BucketedStackedPack, offsets: dict,
                       upstream: BucketedStackedPack | None) -> dict:
    """Reconstruct the dense (L, in, out) matrices a quantized group
    actually encodes: dequantize each bucket plane, unscatter, slice each
    projection's rows, and (for composed groups) map the columns back to
    the logical order — these replace the pruned copies so the dense
    prefill datapath (Section III-I) and the parity tests run the *same*
    effective weights as the quantized kernels."""
    deq = dataclasses.replace(pack, buckets=[
        dict(b, values=plane.dequantize())
        for b, plane in zip(pack.buckets, pack.qplanes)])
    out = {}
    for name, (hf, r0, r1) in offsets.items():
        mats = []
        for l in range(pack.n_layers):
            m = bucketed_stack_to_dense(deq, l, hf)[r0:r1]
            if upstream is not None:
                m = m[:, upstream.inv_perm[l]]       # back to logical cols
            mats.append(m.T)                         # (in, out)
        out[name] = np.stack(mats)
    return out


def _uncovered_dense_bytes(params: dict, covered: set) -> int:
    """Per-token weight bytes of the standard decoder projections NOT
    compiled into a pack group (stacked 2-D weights only; biases/norms are
    negligible).  This is what an MLP-only deployment still streams
    densely for attention every decode token."""
    total = 0
    for module in _DENSE_MODULES:
        sub = params.get("layers", {}).get(module, {})
        for name, w in sub.items():
            if (module, name) in covered or np.ndim(w) != 3:
                continue
            total += int(np.size(w)) * jnp.dtype(w.dtype).itemsize
    return total


def _resolve_specs(cfg: ModelConfig, projections) -> dict:
    if projections == "all":
        specs = decoder_layer_groups(cfg.gated_mlp, attn=True, mlp=True)
    elif projections == "mlp":
        specs = decoder_layer_groups(cfg.gated_mlp, attn=False, mlp=True)
    elif projections == "attn":
        specs = decoder_layer_groups(cfg.gated_mlp, attn=True, mlp=False)
    elif isinstance(projections, str):
        raise ValueError(f"unknown projections preset {projections!r} "
                         "(all | mlp | attn | explicit PackGroupSpec list)")
    else:
        specs = tuple(projections)
    by_name = validate_group_specs(specs)
    # the fused decode runtime drives each module through its canonical
    # group names and projection sets — enforce the coupling HERE so a
    # custom spec list that the runtime cannot serve (or, worse, would
    # silently bypass, running attention from the unpruned params while
    # the stats claim it is packed) fails at build, not at trace
    runtime = {"attn": {"qkv": {"wq", "wk", "wv"}, "attn_out": {"wo"}},
               "mlp": {"gateup": ({"w_gate", "w_up"} if cfg.gated_mlp
                                  else {"w_up"}),
                       "down": {"w_down"}}}
    for module, req in runtime.items():
        covering = {s.name: set(s.projections) for s in by_name.values()
                    if s.module == module}
        if covering and covering != req:
            raise ValueError(
                f"the fused decode runtime serves {module} via groups "
                f"{ {n: sorted(p) for n, p in req.items()} }; "
                f"got { {n: sorted(p) for n, p in covering.items()} }")
    return by_name


def sparsify_model(cfg: ModelConfig, params: dict, sparsity: float, *,
                   projections="all",
                   row_tile: int = 128,
                   chunk_cols: int = ops.DEFAULT_CHUNK_COLS,
                   n_buckets: int = 4,
                   quant: str | None = None,
                   quant_spec=None) -> dict:
    """Offline pack-group compiler: prune + fuse + pack (+ quantize) the
    decoder layer's projections per a declarative group-spec list.

    ``projections``: ``"all"`` (default — fused QKV + O + gate/up + down:
    the whole decoder layer serves from the compressed format),
    ``"mlp"``/``"attn"`` presets (the uncovered side runs dense from the
    layer params), or an explicit ``PackGroupSpec`` tuple.

    Returns the serving dict: per-group device packs under ``"groups"``
    (also aliased at the top level by group name), pruned dense copies
    per projection (``"pruned"`` + ``"<name>_pruned"`` aliases) for the
    GEMM prefill path and verification, and the compiled ``"specs"``.

    ``quant`` ("int8" | "int4"; or pass an explicit
    ``repro.quant.QuantSpec`` via ``quant_spec``) re-encodes every group's
    value planes per bucket row group and swaps the pruned dense copies
    for their dequantized reconstructions — decode then serves from the
    narrow codes while the GEMM prefill path stays weight-consistent.
    """
    quant = None if quant in (None, "none") else quant
    by_name = _resolve_specs(cfg, projections)
    n_layers = cfg.n_layers

    qspec = None
    if quant is not None or quant_spec is not None:
        from repro.quant import QuantSpec, default_spec
        qspec = (quant_spec if isinstance(quant_spec, QuantSpec)
                 else default_spec(quant))
        quant = quant or f"int{qspec.bits}"

    # ---- prune every covered projection ---------------------------------
    pruned: dict = {}
    dtypes: dict = {}
    for spec in by_name.values():
        sub = params["layers"].get(spec.module, {})
        missing = [n for n in spec.projections if n not in sub]
        if missing:
            raise ValueError(
                f"params missing {spec.module} projection(s) {missing} "
                f"for group {spec.name!r} (gated_mlp={cfg.gated_mlp})")
        for name in spec.projections:
            w = np.asarray(sub[name], np.float32)        # (L, in, out)
            pruned[name] = np.stack([magnitude_prune(w[l], sparsity)
                                     for l in range(n_layers)])
            dtypes[name] = sub[name].dtype

    # ---- compile the groups in spec order -------------------------------
    host_packs: dict = {}
    groups: dict = {}
    for spec in by_name.values():
        # rows of the packed matrix are W^T's rows (the output dim)
        mats = {n: [pruned[n][l].T for l in range(n_layers)]
                for n in spec.projections}
        proj_nnz = {n: np.asarray([(pruned[n][l] != 0).sum()
                                   for l in range(n_layers)], np.int64)
                    for n in spec.projections}
        upstream = host_packs.get(spec.compose_with)
        if upstream is not None:
            mats = {n: compose_cols_with_pack(ms, upstream)
                    for n, ms in mats.items()}
        pack, offsets = pack_group(mats, fuse=spec.fuse, row_tile=row_tile,
                                   chunk_cols=chunk_cols,
                                   n_buckets=n_buckets)
        if qspec is not None:
            from repro.quant import quantize_bucketed_stack
            quantize_bucketed_stack(pack, qspec)
            # the dequantized matrices are the weights decode actually
            # applies: make them the pruned copies (prefill GEMMs +
            # parity references)
            for name, arr in _dequantized_projs(pack, offsets,
                                                upstream).items():
                pruned[name] = arr
        host_packs[spec.name] = pack
        g = _to_device(pack)
        g.update({
            "name": spec.name,
            "module": spec.module,
            "projections": tuple(spec.projections),
            "fuse": spec.fuse,
            "output": spec.output,
            "compose_with": spec.compose_with,
            "row_offsets": offsets,
            "proj_nnz": proj_nnz,
            "proj_padded": projection_padded_slots(pack, offsets),
        })
        groups[spec.name] = g

    covered = {(s.module, n) for s in by_name.values()
               for n in s.projections}
    out: dict = {
        "format": "espim-packgroups/v3",
        "sparsity": sparsity,
        "gated": bool(cfg.gated_mlp),
        "quant": quant or "none",
        "attn_sparse": "qkv" in groups,
        "mlp_sparse": "gateup" in groups,
        "specs": tuple(by_name.values()),
        "groups": groups,
        "dense_proj_bytes": _uncovered_dense_bytes(params, covered),
        "pruned": {n: jnp.asarray(w, dtypes[n]) for n, w in pruned.items()},
    }
    if qspec is not None:
        out["quant_spec"] = qspec
    # one model-level digest binding every group's device fingerprint —
    # what provenance records and what a restored sparse dict verifies
    out["fingerprint"] = integrity.bind_fingerprint(
        {n: g["fingerprint"] for n, g in groups.items()},
        meta={"format": out["format"], "sparsity": sparsity,
              "quant": out["quant"]})
    for name, g in groups.items():             # legacy top-level aliases
        out[name] = g
    for name, w in out["pruned"].items():
        out[f"{name}_pruned"] = w
    return out


def sparsify_mlps(cfg: ModelConfig, params: dict, sparsity: float,
                  row_tile: int = 128,
                  chunk_cols: int = ops.DEFAULT_CHUNK_COLS,
                  n_buckets: int = 4,
                  quant: str | None = None,
                  quant_spec=None) -> dict:
    """MLP-only preset of ``sparsify_model``: gate+up fused halves + the
    perm-composed down projection; attention stays on the dense path (the
    pre-PR5 serving mode, kept for the attn=dense benchmark dimension)."""
    return sparsify_model(cfg, params, sparsity, projections="mlp",
                          row_tile=row_tile, chunk_cols=chunk_cols,
                          n_buckets=n_buckets, quant=quant,
                          quant_spec=quant_spec)


def pruned_param_tree(params: dict, sparse: dict) -> dict:
    """A params tree with every covered projection's weights replaced by
    the sparse dict's pruned (or dequantized) copies — the dense
    reference model the parity tests and smoke benches decode with."""
    pruned = jax.tree.map(lambda x: x, params)
    for module in _DENSE_MODULES:
        sub = params.get("layers", {}).get(module, {})
        for name in sub:
            if name in sparse["pruned"]:
                pruned["layers"][module][name] = sparse["pruned"][name]
    return pruned


# --------------------------------------------------------------------------
# Fused runtime path
# --------------------------------------------------------------------------
def _scan_bufs(sparse: dict):
    """The per-layer arrays threaded through the layer scan, one entry per
    pack group (everything else about the packs is static geometry closed
    over by the step).  Quantized packs thread (codes, cols, scales)
    triples — the stacked (L, G) scales are just one more scan leaf;
    ``take``-output groups also thread their (L, n_rows) ``inv_perm``."""

    def bufs(g):
        if g["quant"] is not None:
            b = [(b["q"], b["cols"], b["srow"]) for b in g["buckets"]]
        else:
            b = [(b["values"], b["cols"]) for b in g["buckets"]]
        entry = {"bufs": b}
        if g["output"] == "take":
            entry["inv"] = g["inv_perm"]
        return entry

    return {name: bufs(g) for name, g in sparse["groups"].items()}


def _bucket_spmv(pack: dict, buf: tuple, g: int, xt: jnp.ndarray,
                 impl: str, epilogue: str | None = None,
                 act: str = "silu") -> jnp.ndarray:
    """One bucket's SpMV launch, fp or quantized per the pack's meta.
    Quantized launches return the code-domain accumulator and dequantize
    with one multiply by the pre-expanded per-row scales.

    ``epilogue="glu"`` fuses act(gate)·up into the launch (half-major
    gate+up bucket, DESIGN.md §15): the fused lowerings replay the exact
    op order of the unfused path — dequant-once then gate — so the output
    is bit-identical, in one launch instead of three ops."""
    if pack["quant"] is not None:
        codes, cols, srow = buf
        if epilogue == "glu":
            return ops.espim_spmv_batched_quant(
                codes, cols, None, xt, chunk_cols=pack["chunk_cols"],
                group_rows=pack["quant"][g]["group_rows"], impl=impl,
                epilogue="glu", act=act, srow=srow)
        yp = ops.espim_spmv_batched_quant(
            codes, cols, None, xt, chunk_cols=pack["chunk_cols"],
            group_rows=pack["quant"][g]["group_rows"], impl=impl)
        return yp * srow[:, None]
    vals, cols = buf
    if epilogue == "glu":
        return ops.espim_spmv_batched(vals, cols, xt,
                                      chunk_cols=pack["chunk_cols"],
                                      impl=impl, epilogue="glu", act=act)
    return ops.espim_spmv_batched(vals, cols, xt,
                                  chunk_cols=pack["chunk_cols"], impl=impl)


def _group_apply(pack: dict, gb: dict, xt: jnp.ndarray, impl: str) -> list:
    """All of one group's bucket launches -> per-bucket packed outputs."""
    return [_bucket_spmv(pack, buf, g, xt, impl)
            for g, buf in enumerate(gb["bufs"])]


def _group_take(gb: dict, parts: list) -> jnp.ndarray:
    """Concatenate bucket outputs and restore logical row order with the
    group's one static ``take`` (the ``output="take"`` contract)."""
    yp = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
    return jnp.take(yp, gb["inv"], axis=0)


def _fused_qkv(cfg: ModelConfig, sparse: dict, bufs: dict, attn_p: dict,
               hn: jnp.ndarray, impl: str):
    """The fused QKV pack: hn (B, T, D) -> q (B, T, H, hd), k/v
    (B, T, KV, hd) in *logical* head order.

    One SpMV launch per bucket computes all three projections; the single
    static ``take`` by ``inv_perm`` unscatters the packed rows so RoPE's
    positional head-dim pairing and the KV-cache writes see exactly the
    rows the dense path produces.  QKV biases (qwen-style) are added
    post-take — biases are never packed."""
    g = sparse["groups"]["qkv"]
    gb = bufs["qkv"]
    b, t = hn.shape[0], hn.shape[1]
    xt = hn.reshape(-1, hn.shape[-1]).T.astype(jnp.float32)   # (D, B*T)
    y = _group_take(gb, _group_apply(g, gb, xt, impl))        # (rows, B*T)

    def cut(name: str, n_heads: int) -> jnp.ndarray:
        _, r0, r1 = g["row_offsets"][name]
        seg = y[r0:r1]
        bias = attn_p.get("b" + name[1])                      # wq -> bq
        if bias is not None:
            seg = seg + bias.astype(jnp.float32)[:, None]
        return seg.T.reshape(b, t, n_heads, cfg.hd).astype(hn.dtype)

    return (cut("wq", cfg.n_heads), cut("wk", cfg.n_kv_heads),
            cut("wv", cfg.n_kv_heads))


def _fused_o(cfg: ModelConfig, sparse: dict, bufs: dict,
             out_h: jnp.ndarray, impl: str) -> jnp.ndarray:
    """The packed O projection: attention heads (B, T, H, hd) -> residual
    contribution (B, T, D) via one bucketed SpMV + the static take."""
    g = sparse["groups"]["attn_out"]
    gb = bufs["attn_out"]
    b, t = out_h.shape[0], out_h.shape[1]
    xt = out_h.reshape(b * t, -1).T.astype(jnp.float32)       # (H*hd, B*T)
    y = _group_take(gb, _group_apply(g, gb, xt, impl))        # (D, B*T)
    return y.T.reshape(b, t, -1).astype(out_h.dtype)


def _pruned_qkv(cfg: ModelConfig, px: dict, attn_p: dict, hn: jnp.ndarray):
    """Dense-path QKV from the pruned copies (GEMM prefill, Section
    III-I): same matrices the packs hold, applied as GEMMs; biases come
    from the layer params (they are never pruned)."""
    p = {"wq": px["wq"], "wk": px["wk"], "wv": px["wv"]}
    for bn in ("bq", "bk", "bv"):
        if bn in attn_p:
            p[bn] = attn_p[bn]
    return T._qkv(cfg, p, hn)


def _fused_mlp(cfg: ModelConfig, sparse: dict, bufs: dict, hn: jnp.ndarray,
               impl: str, epilogue: bool = True) -> jnp.ndarray:
    """One layer's MLP through the fused packs.

    hn (B, T, d_model) -> (B, T, d_model).  Decode runs T=1 (the hot
    path); chunked prefill feeds T=chunk tokens — the kernels see B*T
    columns either way, and x stays in (in, B*T) layout throughout.

    ``epilogue=True`` (default) folds act(gate)·up into the gate+up SpMV
    launch itself (the ``fuse="halves"`` contract makes this legal: both
    halves share one balance perm, so the product is an in-kernel
    elementwise at a fixed row offset).  ``epilogue=False`` keeps the
    op-level epilogue as the parity reference — the two are bit-identical
    by construction.
    """
    from repro.models.layers import act_fn
    act = act_fn(cfg.activation)
    gu = sparse["groups"]["gateup"]
    dn = sparse["groups"]["down"]
    b, t = hn.shape[0], hn.shape[1]
    xt = hn.reshape(-1, hn.shape[-1]).T.astype(jnp.float32)   # (in, B*T)

    parts = []
    if sparse["gated"] and epilogue:
        for g, buf in enumerate(bufs["gateup"]["bufs"]):
            parts.append(_bucket_spmv(gu, buf, g, xt, impl,
                                      epilogue="glu", act=cfg.activation))
    else:
        for yp, rg in zip(_group_apply(gu, bufs["gateup"], xt, impl),
                          gu["bucket_rows"]):
            if sparse["gated"]:
                # gate rows and up rows of the bucket share packed order:
                # the product needs no unscatter (act(0)*0 == 0 on pad rows)
                parts.append(act(yp[:rg]) * yp[rg:])
            else:
                parts.append(act(yp))
    inter = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)

    y = _group_take(bufs["down"],
                    _group_apply(dn, bufs["down"], inter, impl))
    return y.T.reshape(b, t, -1).astype(hn.dtype)             # (B, T, D)


def _pruned_mlp(cfg: ModelConfig, sparse: dict, wl: dict, hn: jnp.ndarray
                ) -> jnp.ndarray:
    """The flexible *dense* datapath (Section III-I) over the pruned
    copies: the same matrices the packs hold, applied as GEMMs.  Prefill
    is compute-bound GEMM work where the MXU/BLAS path wins; the packs own
    the memory-bound single-token MV decode."""
    from repro.models import layers as L
    if sparse["gated"]:
        return L.mlp_gated(hn, wl["w_gate"], wl["w_up"], wl["w_down"],
                           cfg.activation)
    return L.mlp_relu2(hn, wl["w_up"], wl["w_down"], cfg.activation)


def _proj_xs(sparse: dict, proj_path: str):
    """Per-layer projection inputs threaded through the scan: the pack
    buffers for the kernel path, the pruned dense copies for the GEMM
    path."""
    if proj_path == "kernel":
        return _scan_bufs(sparse)
    if proj_path != "dense":
        raise ValueError(f"unknown proj_path {proj_path!r}")
    return dict(sparse["pruned"])


def _layer_stack(cfg: ModelConfig, params: dict, sparse: dict, cache: dict,
                 h, attn_step, attn_core, impl: str, unroll: bool,
                 proj_path: str = "kernel", epilogue: bool = True):
    """Shared layer loop for decode/prefill: scan by default; ``unroll``
    keeps the per-layer Python loop as the parity reference.

    ``attn_step`` is the whole-attention closure used when the sparse
    dict does not cover attention (dense weights from the layer params);
    ``attn_core`` is the projection-free middle (RoPE + cache +
    attention) wrapped by the packed QKV / O groups when it does.  The
    MLP is symmetric: uncovered (``projections="attn"``) it runs dense
    from the layer params on both proj paths.
    """
    attn_sparse = sparse.get("attn_sparse", False)
    mlp_sparse = sparse.get("mlp_sparse", "gateup" in sparse["groups"])

    def body(h, xs):
        lp, kc, vc, px = xs
        hn = T._norm(cfg, lp["ln1"], h)
        if attn_sparse:
            if proj_path == "kernel":
                q, k, v = _fused_qkv(cfg, sparse, px, lp["attn"], hn, impl)
            else:
                q, k, v = _pruned_qkv(cfg, px, lp["attn"], hn)
            a_h, kc, vc = attn_core(q, k, v, kc, vc)
            if proj_path == "kernel":
                a = _fused_o(cfg, sparse, px, a_h, impl)
            else:
                from repro.models import layers as L
                b, t = hn.shape[0], hn.shape[1]
                a = L.dense(a_h.reshape(b, t, -1), px["wo"])
        else:
            a, kc, vc, _, _ = attn_step(lp, hn, kc, vc)
        h = h + a
        hn = T._norm(cfg, lp["ln2"], h)
        if not mlp_sparse:
            h = h + T.mlp_apply(cfg, lp["mlp"], hn)
        elif proj_path == "kernel":
            h = h + _fused_mlp(cfg, sparse, px, hn, impl, epilogue=epilogue)
        else:
            h = h + _pruned_mlp(cfg, sparse, px, hn)
        return h, (kc, vc)

    xs = (params["layers"], cache["k"], cache["v"],
          _proj_xs(sparse, proj_path))
    if unroll:
        k_new, v_new = [], []
        for i in range(cfg.n_layers):
            h, (kc, vc) = body(h, jax.tree.map(lambda x: x[i], xs))
            k_new.append(kc)
            v_new.append(vc)
        return h, jnp.stack(k_new), jnp.stack(v_new)
    h, (k_new, v_new) = jax.lax.scan(body, h, xs)
    return h, k_new, v_new


def decode_step_sparse(cfg: ModelConfig, params: dict, sparse: dict,
                       cache: dict, batch: dict, impl: str = "ref",
                       unroll: bool = False, epilogue: bool = True):
    """transformer.decode_step with ESPIM-format projections — every
    per-token MV runs through the packed kernels when ``sparse`` covers
    the whole layer (``sparsify_model``), or just the MLPs when it was
    built by the ``sparsify_mlps`` preset (dense attention).

    ``epilogue=True`` (default) runs the gate+up MLP buckets with the
    act(gate)·up epilogue fused into the SpMV launch; ``epilogue=False``
    is the bit-identical unfused reference (tests assert the parity)."""
    tokens = batch["tokens"]
    h = T.embed_tokens(cfg, params, tokens)

    def attn_step(lp, hn, kc, vc):
        return T.attn_decode_apply(cfg, lp["attn"], hn, kc, vc, cache["len"])

    def attn_core(q, k, v, kc, vc):
        out, kc, vc, _, _ = T.attn_decode_core(cfg, q, k, v, kc, vc,
                                               cache["len"])
        return out, kc, vc

    h, k_new, v_new = _layer_stack(cfg, params, sparse, cache, h, attn_step,
                                   attn_core, impl, unroll,
                                   epilogue=epilogue)
    logits = T.logits_from_hidden(cfg, params, h)
    new_cache = {"k": k_new, "v": v_new, "len": cache["len"] + 1}
    return logits, new_cache


def prefill_chunk_sparse(cfg: ModelConfig, params: dict, sparse: dict,
                         cache: dict, batch: dict, impl: str = "ref",
                         unroll: bool = False, proj_path: str = "dense",
                         epilogue: bool = True):
    """transformer.prefill_chunk for the ESPIM-format engine: a C-token
    chunk lands at cache["len"]..  Same contract as
    ``factory.prefill_chunk``.

    ``proj_path`` picks the projection datapath — the paper's flexible
    dense/sparse configuration (Section III-I) applied per serving phase:
    ``"dense"`` (default) runs the GEMM-shaped chunk through the pruned
    dense copies (bit-identical matrices, compute-bound phase) for every
    covered projection — attention included when the group set covers it;
    ``"kernel"`` feeds the fused packs with B*C columns (the MV datapath,
    used by the parity tests and on PIM-like backends)."""
    tokens = batch["tokens"]
    start = cache["len"]
    n_valid = batch.get("n_valid")
    if n_valid is None:
        n_valid = jnp.full_like(start, tokens.shape[1])
    h = T.embed_tokens(cfg, params, tokens)

    def attn_step(lp, hn, kc, vc):
        return T.attn_prefill_apply(cfg, lp["attn"], hn, kc, vc, start)

    def attn_core(q, k, v, kc, vc):
        out, kc, vc, _, _ = T.attn_prefill_core(cfg, q, k, v, kc, vc, start)
        return out, kc, vc

    h, k_new, v_new = _layer_stack(cfg, params, sparse, cache, h, attn_step,
                                   attn_core, impl, unroll,
                                   proj_path=proj_path, epilogue=epilogue)
    logits = T.logits_from_hidden(cfg, params, h)
    new_cache = {"k": k_new, "v": v_new, "len": start + n_valid}
    return logits, new_cache


# --------------------------------------------------------------------------
# Stats
# --------------------------------------------------------------------------
def _plane_bytes(p: dict) -> tuple:
    """(value_bytes_total, index_bytes_total, per-layer value bytes) for a
    pack dict: fp32 planes cost 4 bytes/slot; quantized planes use the
    packed accounting (codes at their group's bit width + scales + the
    int4 fallback map).  The index plane is int32 and quant-invariant —
    the paper's value/index decoupling in byte form."""
    n_layers = len(p["nnz_per_layer"])
    index_total = 4 * p["padded_per_layer"] * n_layers
    if p["qplanes"] is not None:
        per_layer = np.sum([pl.value_bytes_by_lead() for pl in p["qplanes"]],
                           axis=0)
        return int(per_layer.sum()), index_total, [int(b) for b in per_layer]
    per = 4 * p["padded_per_layer"]
    return per * n_layers, index_total, [per] * n_layers


def _pack_stats(p: dict) -> dict:
    n_layers = len(p["nnz_per_layer"])
    padded = p["padded_per_layer"] * n_layers
    vbytes, ibytes, vbytes_layer = _plane_bytes(p)
    return {
        "nnz": int(p["nnz"]),
        "padded_slots": int(padded),
        "pad_frac": 1 - p["nnz"] / padded,
        "pad_frac_per_layer": [
            1 - int(n) / p["padded_per_layer"]
            for n in p["nnz_per_layer"]
        ],
        "bucket_rows": list(p["bucket_rows"]),
        "bucket_widths": list(p["widths"]),
        "single_bucket_pad_frac": 1 - p["nnz"] / max(
            1, p["plan"].single_bucket_slots * p["buckets"][0]["cols"].shape[2]
            * p["halves"] * n_layers),
        "value_plane_bytes": vbytes,
        "index_plane_bytes": ibytes,
        "value_plane_bytes_per_layer": vbytes_layer,
        "bits_per_nnz": 8.0 * vbytes / max(1, int(p["nnz"])),
        "bits_per_nnz_per_layer": [
            8.0 * b / max(1, int(n))
            for b, n in zip(vbytes_layer, p["nnz_per_layer"])
        ],
    }


def _proj_stats(g: dict, group_stats: dict, proj: str) -> dict:
    """Per-projection stats inside a group.  nnz and padded slots are
    exact (the balance perm scatters a projection's rows across width
    buckets — ``projection_padded_slots`` walks ``inv_perm``); the
    quantized value plane is attributed by padded-slot share (scale
    groups can straddle projections)."""
    n_layers = len(g["nnz_per_layer"])
    nnz_l = g["proj_nnz"][proj]
    padded_l = g["proj_padded"][proj]
    nnz, padded = int(nnz_l.sum()), int(padded_l.sum())
    share = padded / max(1, g["padded_per_layer"] * n_layers)
    vbytes = (int(round(group_stats["value_plane_bytes"] * share))
              if g["qplanes"] is not None else 4 * padded)
    return {
        "nnz": nnz,
        "padded_slots": padded,
        "pad_frac": 1 - nnz / max(1, padded),
        "pad_frac_per_layer": [1 - int(n) / max(1, int(p))
                               for n, p in zip(nnz_l, padded_l)],
        "value_plane_bytes": vbytes,
        "index_plane_bytes": 4 * padded,
        "bits_per_nnz": 8.0 * vbytes / max(1, nnz),
    }


def sparse_stats(sparse: dict) -> dict:
    """Aggregate + per-group + per-projection + per-layer padding AND
    byte-plane stats for every compiled pack group.

    Group entries carry the pack-level figures (padding is a property of
    the fused pack); each projection additionally reports its own exact
    nnz/padded split under its original name (``w_gate``, ``wq``, ...).
    ``value_plane_bytes`` / ``index_plane_bytes`` / ``bits_per_nnz``
    report the stored (possibly quantized) format — the bytes a decode
    token streams across the pin per layer/projection.

    ``total.bytes_per_token`` is the WHOLE-MODEL per-token projection
    traffic: the packed planes plus the dense bytes of every standard
    decoder projection the group set does not cover
    (``dense_proj_bytes_per_token`` — attention, in an MLP-only
    deployment).  Before PR 5 this silently reported the MLP-only packed
    totals as if they were the model."""
    out: dict = {"quant": sparse.get("quant", "none"),
                 "attn_sparse": sparse.get("attn_sparse", False)}
    tot_nnz = tot_padded = tot_value = tot_index = 0
    for name, g in sparse["groups"].items():
        gs = _pack_stats(g)
        out[name] = gs
        for proj in g["projections"]:
            out[proj] = _proj_stats(g, gs, proj)
        n_layers = len(g["nnz_per_layer"])
        tot_nnz += g["nnz"]
        tot_padded += g["padded_per_layer"] * n_layers
        tot_value += gs["value_plane_bytes"]
        tot_index += gs["index_plane_bytes"]
    dense_bytes = int(sparse.get("dense_proj_bytes", 0))
    out["total"] = {
        "nnz": int(tot_nnz),
        "padded_slots": int(tot_padded),
        "pad_frac": 1 - tot_nnz / max(1, tot_padded),
        "value_plane_bytes": int(tot_value),
        "index_plane_bytes": int(tot_index),
        "bits_per_nnz": 8.0 * tot_value / max(1, tot_nnz),
        # every decode token streams each layer's planes once — plus the
        # dense weights of any projection left outside the group set
        "packed_bytes_per_token": int(tot_value + tot_index),
        "dense_proj_bytes_per_token": dense_bytes,
        "bytes_per_token": int(tot_value + tot_index + dense_bytes),
    }
    return out
