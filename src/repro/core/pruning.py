"""Magnitude pruning and SparTen-style greedy load balancing.

The paper (Section IV "Benchmarks") prunes LLaMA-7B weight matrices with
magnitude thresholds per Han et al. [20] to reach target sparsities; it does
not retrain (cycle counts depend only on the sparsity *pattern*).  Section
III-G adopts SparTen's greedy balance: sort rows by density, deal them
round-robin across banks, and within each bank co-locate the densest row with
the sparsest so paired rows have near-uniform combined work.

Everything here is *offline* (host-side, numpy) — it is part of the SDDS
compilation pipeline, not the device program.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "magnitude_prune",
    "prune_to_pattern",
    "BankAssignment",
    "sparten_balance",
    "row_tile_balance",
]


def magnitude_prune(w: np.ndarray, sparsity: float) -> np.ndarray:
    """Zero out the smallest-|w| fraction ``sparsity`` of entries.

    Returns a new array; the induced pattern is what SDDS schedules.
    ``sparsity`` is the fraction of *zeros* (0.9 == 90% zeros).
    """
    if not 0.0 <= sparsity < 1.0:
        raise ValueError(f"sparsity must be in [0, 1), got {sparsity}")
    if sparsity == 0.0:
        return np.array(w, copy=True)
    flat = np.abs(np.asarray(w)).ravel()
    k = int(round(sparsity * flat.size))
    if k == 0:
        return np.array(w, copy=True)
    if k >= flat.size:
        return np.zeros_like(w)
    # Threshold at the k-th smallest magnitude (Han et al. style).
    thresh = np.partition(flat, k - 1)[k - 1]
    out = np.array(w, copy=True)
    out[np.abs(out) <= thresh] = 0.0
    # Tie-breaking at the threshold can overshoot; that is fine (the paper's
    # thresholds are approximate too), but never undershoot badly.
    return out


def prune_to_pattern(w: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """Apply an externally supplied keep-mask (1 = keep)."""
    if mask.shape != w.shape:
        raise ValueError(f"mask shape {mask.shape} != weight shape {w.shape}")
    return np.where(mask.astype(bool), w, np.zeros_like(w))


@dataclasses.dataclass(frozen=True)
class BankAssignment:
    """Result of SparTen greedy balance.

    ``bank_rows[b]`` lists original-matrix row ids assigned to bank ``b`` in
    *processing order* (densest/sparsest co-located pairs, intermingled in
    logically-increasing index order as Section III-G requires).
    ``select_bit[b]`` carries the per-row output-buffer select bit (two
    output buffers per bank).
    """

    bank_rows: tuple  # tuple[tuple[int, ...], ...]
    select_bit: tuple  # tuple[tuple[int, ...], ...]
    n_banks: int

    def max_rows_per_bank(self) -> int:
        return max((len(r) for r in self.bank_rows), default=0)


def sparten_balance(nnz_per_row: Sequence[int], n_banks: int) -> BankAssignment:
    """SparTen greedy balance (Section III-G).

    1. Sort rows by density (nnz) descending.
    2. Deal sorted rows round-robin to banks -> each bank holds a density-
       sorted list.
    3. Within each bank, pair densest with sparsest (first/last, second/
       second-last, ...) so synchronous stripes have near-equal work; the
       pair members keep logically-increasing row order and are tagged with
       alternating select bits for the two output buffers.
    """
    nnz = np.asarray(nnz_per_row, dtype=np.int64)
    order = np.argsort(-nnz, kind="stable")  # densest first
    per_bank: list[list[int]] = [[] for _ in range(n_banks)]
    for i, row in enumerate(order):
        per_bank[i % n_banks].append(int(row))

    bank_rows: list[tuple[int, ...]] = []
    select_bit: list[tuple[int, ...]] = []
    for rows in per_bank:
        # rows is densest..sparsest; fold: d0, s0, d1, s1 ...
        folded: list[int] = []
        sel: list[int] = []
        lo, hi = 0, len(rows) - 1
        take_dense = True
        while lo <= hi:
            if take_dense:
                pick = rows[lo]
                lo += 1
                sel.append(0)
            else:
                pick = rows[hi]
                hi -= 1
                sel.append(1)
            folded.append(pick)
            take_dense = not take_dense
        # "intermingled in logically-increasing index order": within each
        # co-located pair keep the smaller original index first, preserving
        # the select-bit association with the row (not the slot).
        for j in range(0, len(folded) - 1, 2):
            if folded[j] > folded[j + 1]:
                folded[j], folded[j + 1] = folded[j + 1], folded[j]
                sel[j], sel[j + 1] = sel[j + 1], sel[j]
        bank_rows.append(tuple(folded))
        select_bit.append(tuple(sel))
    return BankAssignment(
        bank_rows=tuple(bank_rows), select_bit=tuple(select_bit), n_banks=n_banks
    )


def row_tile_balance(nnz_per_row: Sequence[int], tile: int) -> np.ndarray:
    """TPU adaptation of SparTen balance: permute rows to minimize ELL
    padding (the padding slots play the role of SDDS stall/dummy cells).

    A tile's padded width is its *max* nnz, so rows of similar density must
    be CLUSTERED, not spread: sort by nnz descending and chunk
    consecutively — each tile's max is then as close to its mean as the
    distribution allows.  (This is the dual of the paper's bank balance,
    which equalizes *sums* across lockstep banks; that variant lives in
    ``sparten_balance`` and drives the cycle simulator.)

    Returns ``perm`` with ``perm[i]`` = original row id at packed position
    ``i``.
    """
    nnz = np.asarray(nnz_per_row, dtype=np.int64)
    return np.argsort(-nnz, kind="stable")
