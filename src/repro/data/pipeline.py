"""Deterministic synthetic token pipeline with exact-resume semantics.

Batches are a pure function of (seed, step): resuming from a checkpoint
needs only the step counter — no iterator state to lose on preemption, and
every data-parallel host computes exactly its own shard (host sharding by
slicing the global batch).  This is the property a production loader must
provide (tf.data checkpointing / grain index); here it holds by
construction.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["PipelineConfig", "SyntheticPipeline"]


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    seed: int = 0
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8


class SyntheticPipeline:
    """Zipf-ish synthetic LM stream; labels are next-token shifted."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg

    @classmethod
    def for_model(cls, mcfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
        return cls(PipelineConfig(seed=seed, vocab_size=mcfg.vocab_size,
                                  seq_len=shape.seq_len,
                                  global_batch=shape.global_batch))

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
        # heavier-tailed than uniform: square a uniform draw
        u = jax.random.uniform(key, (c.global_batch, c.seq_len + 1))
        tokens = (jnp.square(u) * c.vocab_size).astype(jnp.int32)
        tokens = jnp.clip(tokens, 0, c.vocab_size - 1)
        return {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
        }

    # --- exact-resume state ------------------------------------------------
    def state(self, step: int) -> dict:
        return {"seed": self.cfg.seed, "step": int(step)}

    @classmethod
    def restore(cls, mcfg: ModelConfig, shape: ShapeConfig, state: dict):
        pipe = cls.for_model(mcfg, shape, seed=state["seed"])
        return pipe, state["step"]
