"""Batched serving engine with continuous batching.

Fixed B decode slots over one shared KV cache; finished sequences free
their slot, queued requests claim it (cache rows reset via per-slot length
= 0 and prompt replay).  Prefill here is token-by-token replay through the
decode path — correct by the decode/forward parity tests; a production
deployment would use ``prefill_fn`` + cache splice, which the engine
exposes as an upgrade point.

Pass ``sparse`` (from ``sparsify_mlps``) to serve from the ESPIM
column-chunked format: every decode tick then runs the MLP projections
through the fused batched SpMV across all active slots at once — the
batched kernel IS the continuous-batching hot path.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import factory
from repro.serve.serve_step import serve_step_fn, serve_step_sparse_fn

__all__ = ["Request", "EngineStats", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never stops early
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_generated: int = 0
    requests_completed: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 max_len: int, temperature: float = 0.0,
                 sparse: dict | None = None, impl: str = "ref"):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.max_len = max_len
        self.temperature = temperature
        self.sparse = sparse
        self.cache = factory.init_cache(cfg, batch_slots, max_len)
        self.slots: list[Request | None] = [None] * batch_slots
        self.pending: deque[Request] = deque()
        self.prompt_cursor = [0] * batch_slots
        self.cur_token = np.zeros((batch_slots, 1), np.int32)
        self.stats = EngineStats()
        if sparse is None:
            self._step = jax.jit(
                lambda p, c, b: serve_step_fn(cfg, p, c, b,
                                              temperature=temperature))
        else:
            # ESPIM-format decode: the packs are closure constants so the
            # fused kernel sees static chunk geometry
            self._step = jax.jit(
                lambda p, c, b: serve_step_sparse_fn(
                    cfg, p, sparse, c, b, temperature=temperature,
                    impl=impl))

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _reset_slot(self, i: int) -> None:
        # zero the slot's cache length; stale K/V beyond len is masked out
        self.cache = dict(self.cache)
        self.cache["len"] = self.cache["len"].at[i].set(0)
        for key in ("ssm", "conv", "wkv", "tm_x", "cm_x"):
            if key in self.cache:
                self.cache[key] = self.cache[key].at[:, i].set(0)

    def _fill_slots(self) -> None:
        for i in range(self.b):
            if self.slots[i] is None and self.pending:
                req = self.pending.popleft()
                self.slots[i] = req
                self.prompt_cursor[i] = 0
                self._reset_slot(i)
                self.cur_token[i, 0] = req.prompt[0]

    def step(self) -> None:
        """One engine tick: decode every active slot by one token."""
        self._fill_slots()
        if all(s is None for s in self.slots):
            return
        batch = {"tokens": jnp.asarray(self.cur_token)}
        nxt, _, self.cache = self._step(self.params, self.cache, batch)
        nxt = np.asarray(nxt)
        self.stats.steps += 1
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.prompt_cursor[i] += 1
            if self.prompt_cursor[i] < len(req.prompt):
                # still prefilling: feed the next prompt token
                self.cur_token[i, 0] = req.prompt[self.prompt_cursor[i]]
                continue
            tok = int(nxt[i, 0])
            req.output.append(tok)
            self.stats.tokens_generated += 1
            self.cur_token[i, 0] = tok
            seq_len = self.prompt_cursor[i] + len(req.output)
            if (tok == req.eos_id or len(req.output) >= req.max_new_tokens
                    or seq_len >= self.max_len - 1):
                req.done = True
                self.stats.requests_completed += 1
                self.slots[i] = None

    def run(self, max_steps: int = 10_000) -> EngineStats:
        for _ in range(max_steps):
            if not self.pending and all(s is None for s in self.slots):
                break
            self.step()
        return self.stats
