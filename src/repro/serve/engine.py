"""Production serving engine: paged KV cache + chunked prefill + scheduler.

Fixed B decode slots over one block-pool KV arena (``serve/paged_cache``).
Each engine tick is either one chunked-prefill call for a single slot
(``serve/prefill`` — TTFT in ceil(prompt_len/chunk) jitted calls instead
of prompt_len decode steps) or one batched decode step across every
decode-ready slot; the interleave, admission order (FCFS / SJF) and
per-request latency metrics are owned by ``serve/scheduler``.  Finished
sequences return their blocks to the pool; queued requests are admitted
only once their worst-case block count is reservable, so the arena can
never deadlock mid-flight.

Pass ``sparse`` (from ``sparsify_model`` — whole decoder layer: fused
QKV + O + gate/up/down pack groups; or the ``sparsify_mlps`` MLP-only
preset) to serve from the ESPIM column-chunked format: decode ticks run
every covered projection through the fused batched SpMV across all
active slots at once, and prefill chunks run the same pruned matrices as
GEMMs (Section III-I per phase) — the batched kernel IS the
continuous-batching hot path (the paper's deployment: decode from the
compressed format).

Families without a chunked ``prefill_chunk`` (moe / vlm / audio) fall back
to the seed behavior: token-by-token prompt replay through the decode
path (``prefill_mode="replay"``).

Telemetry (DESIGN.md §12): every tick is traced — ``engine.step`` spans
with scheduler / prefill / decode / host_sync children, device work
fenced at span boundaries so async dispatch is billed to the span that
launched it — and mirrored into a metrics registry (TTFT/TPOT/queue
histograms, terminal-state and fault counters, arena occupancy gauges,
per-plane bytes/token).  Both default to ~no-ops: the tracer hands out
one shared null span and the registry's counters are plain attribute
increments, so the instrumented hot path *is* the production hot path.

Overload hardening (DESIGN.md §13): admission is token-budget based
(worst-case prompt + max_new blocks reserved against the paged arena
before a slot is taken), the wait queue is bounded with a configurable
shed policy (``reject`` / ``shed-oldest`` / ``shed-largest`` — shed
requests end in the ``shed`` terminal state, never in a latency
percentile), and optional arena high/low watermarks pause admission with
hysteresis before the pool is exhausted.  Under pressure the engine
**preempts-to-recompute**: the longest-remaining slot releases its KV
blocks back to the pool and re-enters the queue head; because ESPIM's
sparsity is static (all per-request state is replayable from the prompt
plus committed tokens), the victim later resumes by re-prefilling its
committed history through the chunked prefiller and its remaining greedy
tokens are bit-for-bit identical to a never-preempted run.  The same
replayability powers ``snapshot()`` / ``restore()``: a versioned,
digest- and pack-fingerprint-bound serialization of all scheduler and
request state (KV planes are recomputed, not saved) from which a fresh
engine completes every in-flight request with exact parity
(``serve/snapshot.py``, crash drill in ``serve/faults.py``).

Fault tolerance (DESIGN.md §11): sparse packs are fingerprint-verified
at engine construction (``verify_packs`` — a corrupted or mismatched
pack fails loudly at load, or degrades the whole engine to the pruned
dense copy with ``on_verify_failure="degrade"``); every decode tick
returns a per-slot ``isfinite`` flag so a poisoned slot is quarantined
alone (its KV write is dropped, its next tick runs the dense fallback)
while healthy slots continue bit-identically; per-request TTFT and
wall-clock deadlines, an explicit ``cancel()``, capped-backoff retry for
transient step failures, and a ``LatencyWatchdog`` on the decode loop
round out the ladder.  Every exit — finish, cancel, deadline, failure —
funnels through one ``_teardown`` so no path can leak paged blocks;
``check_arena()`` (optionally per-step via ``validate_arena``) proves it.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import sparse_model
from repro.core.integrity import PackIntegrityError
from repro.models import factory
from repro.serve.paged_cache import make_kv_cache
from repro.serve.prefill import ChunkedPrefiller
from repro.serve.scheduler import Scheduler
from repro.serve.serve_step import (sample_tokens, serve_step_fn,
                                    serve_step_sparse_fn)
from repro.telemetry import flightrec
from repro.telemetry import metrics as tm
from repro.telemetry import trace as tt

__all__ = ["Request", "EngineStats", "ServeEngine", "TransientStepError"]


class TransientStepError(RuntimeError):
    """A decode step failed for a reason worth retrying (device hiccup,
    injected fault).  The engine retries with capped exponential backoff;
    exhaustion tears the stepping slots down as ``failed`` instead of
    crashing the engine."""


def _finite_step(step):
    """Wrap a serve-step fn so the jitted closure returns per-slot finite
    flags instead of raw logits: the poison guard reads one (B,) bool
    vector per tick on the host — the logits themselves never leave the
    device, so the guard is free on the hot path."""
    def fn(p, c, b):
        nxt, logits, cache = step(p, c, b)
        ok = jnp.all(jnp.isfinite(logits.astype(jnp.float32)), axis=(1, 2))
        return nxt, ok, cache
    return fn


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never stops early
    deadline_s: float | None = None       # total wall clock from submit
    ttft_deadline_s: float | None = None  # first token from submit
    output: list = dataclasses.field(default_factory=list)
    done: bool = False

    def worst_case_tokens(self, max_len: int) -> int:
        """Cache rows this request can ever occupy — the admission
        reservation AND the submit-time feasibility check both use this,
        so they can never diverge (the allocator's ``ensure`` is
        infallible only while they agree)."""
        return min(len(self.prompt) + self.max_new_tokens + 1, max_len)


@dataclasses.dataclass
class EngineStats:
    steps: int = 0                 # jitted calls (prefill + decode)
    decode_steps: int = 0
    prefill_chunks: int = 0
    tokens_generated: int = 0
    requests_completed: int = 0    # full output: completed + degraded
    slot_occupancy: float = 0.0    # mean fraction of slots active per tick
    quarantines: int = 0           # per-slot non-finite guard trips
    retries: int = 0               # transient step failures retried
    preempts: int = 0              # slots released to recompute later
    requests_shed: int = 0         # dropped by overload admission control
    restored_requests: int = 0     # requests re-admitted by restore()
    watchdog_flags: int = 0        # LatencyWatchdog trips (stuck decode)
    degraded_tokens: int = 0       # tokens emitted by the dense fallback
    requests_degraded: int = 0     # completed, but via the dense fallback
    requests_cancelled: int = 0
    requests_deadline_expired: int = 0
    requests_failed: int = 0       # no datapath produced finite logits
    degraded_to_dense: bool = False  # whole engine fell back at load
    requests: list = dataclasses.field(default_factory=list)
    # the scheduler's streaming latency histograms (telemetry) — summary
    # percentiles come from these in O(buckets), never a full sort
    hists: dict | None = dataclasses.field(default=None, repr=False)

    def latency_summary(self) -> dict:
        from repro.serve.scheduler import latency_summary
        return latency_summary(self.requests, hists=self.hists)


class _Slot:
    """Per-slot serving state (the request plus its progress)."""
    __slots__ = ("req", "metrics", "phase", "pos", "cursor", "cur_token",
                 "pf_cache", "degraded", "emitted_degraded", "feed",
                 "resumed")

    def __init__(self, req, metrics):
        self.req = req
        self.metrics = metrics
        self.phase = "prefill"     # "prefill" | "decode"
        self.pos = 0               # prompt tokens prefilled (chunked mode)
        self.cursor = None         # replay cursor (replay mode)
        self.cur_token = 0
        self.pf_cache = None
        self.degraded = False          # decoding via the dense fallback
        self.emitted_degraded = False  # at least one fallback token out
        # tokens the prefill/replay phase feeds: the prompt for a fresh
        # request, prompt + committed output for a preempt/restore resume
        self.feed = req.prompt
        self.resumed = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 max_len: int, temperature: float = 0.0,
                 sparse: dict | None = None, impl: str = "ref", *,
                 paged: bool = True, block_size: int = 16,
                 num_blocks: int | None = None, prefill_chunk: int = 16,
                 prefill_mode: str = "auto", policy: str = "fcfs",
                 max_prefill_streak: int = 2, seed: int = 0,
                 verify_packs: bool = True, on_verify_failure: str = "raise",
                 max_retries: int = 2, retry_backoff: float = 0.05,
                 retry_backoff_cap: float = 1.0, watchdog=None,
                 validate_arena: bool = False, tracer: tt.Tracer | None = None,
                 metrics: tm.Registry | None = None, flight=None,
                 max_queue_depth: int | None = None,
                 shed_policy: str = "reject", preempt: bool = True,
                 watermark_high: float | None = None,
                 watermark_low: float | None = None):
        if on_verify_failure not in ("raise", "degrade"):
            raise ValueError(
                f"unknown on_verify_failure {on_verify_failure!r}; "
                f"use 'raise' or 'degrade'")
        if watermark_high is not None:
            if watermark_low is None:
                watermark_low = max(0.0, watermark_high - 0.25)
            if not (0.0 <= watermark_low < watermark_high <= 1.0):
                raise ValueError(
                    f"watermarks need 0 <= low < high <= 1, got "
                    f"low={watermark_low} high={watermark_high}")
        # telemetry first, so even load-time verification is observable:
        # a disabled tracer hands out one shared null span (no hot-path
        # allocations); the registry is always live (counter increments
        # are plain attribute adds — see tests/test_telemetry.py)
        self.tracer = tracer if tracer is not None else tt.get_tracer()
        # the always-on flight recorder (DESIGN.md §14): fed regardless
        # of tracer state, dumped by the fault ladder on incidents
        self.flight = (flight if flight is not None
                       else flightrec.get_recorder())
        self.metrics = metrics if metrics is not None else tm.Registry({
            "model": cfg.name,
            "impl": impl,
            "quant": (sparse or {}).get("quant", "none"),
            "attn": ("sparse" if (sparse or {}).get("attn_sparse")
                     else "dense"),
        })
        self._c_verify_fail = self.metrics.counter(
            "serve_verify_failures_total",
            "pack integrity verifications that failed at engine load")
        # pack integrity gate FIRST: a bit-flipped plane or a pack whose
        # SDDS schedule no longer matches its fingerprint must never reach
        # a decode closure (DESIGN.md §11) — either fail the load or serve
        # the pruned dense copy instead
        self.verified_packs: dict | None = None
        degraded_to_dense = False
        if sparse is not None and verify_packs:
            try:
                with self.tracer.span("pack.verify", cat="pack"):
                    self.verified_packs = sparse_model.verify_sparse(sparse)
            except PackIntegrityError:
                self._c_verify_fail.inc()
                if on_verify_failure != "degrade":
                    raise
                params = sparse_model.pruned_param_tree(params, sparse)
                sparse = None
                degraded_to_dense = True

        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.max_len = max_len
        self.temperature = temperature
        self.sparse = sparse
        self.impl = impl
        self.cache = make_kv_cache(cfg, batch_slots, max_len, paged=paged,
                                   block_size=block_size,
                                   num_blocks=num_blocks)
        self.paged = paged
        self.slots: list[_Slot | None] = [None] * batch_slots
        self.seq_len = np.zeros(batch_slots, np.int32)
        self.scheduler = Scheduler(policy=policy,
                                   max_prefill_streak=max_prefill_streak,
                                   metrics=self.metrics,
                                   max_queue_depth=max_queue_depth,
                                   shed_policy=shed_policy,
                                   tracer=self.tracer, flight=self.flight)
        self.scheduler.on_shed = self._on_shed
        self.preempt = preempt
        self._wm_high = watermark_high
        self._wm_low = watermark_low
        self._backpressure = False
        self.stats = EngineStats(requests=self.scheduler.completed,
                                 degraded_to_dense=degraded_to_dense,
                                 hists=self.scheduler.hists)
        self._init_metrics(sparse)
        self._key = jax.random.PRNGKey(seed)
        self._occ_accum = 0.0
        self.max_retries = max(0, max_retries)
        self.retry_backoff = retry_backoff
        self.retry_backoff_cap = retry_backoff_cap
        self.validate_arena = validate_arena
        self._watchdog = watchdog

        if prefill_mode == "auto":
            chunked = (factory.supports_chunked_prefill(cfg)
                       if sparse is None else cfg.family == "dense")
        elif prefill_mode == "chunked":
            chunked = True
        elif prefill_mode == "replay":
            chunked = False
        else:
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        self.chunked_prefill = chunked
        self._prefiller = None
        if chunked:
            self._prefiller = ChunkedPrefiller(
                cfg, prefill_chunk, max_len, self.cache.seq_names,
                self.cache.state_names, sparse=sparse, impl=impl)

        if sparse is None:
            self._decode = jax.jit(_finite_step(
                lambda p, c, b: serve_step_fn(cfg, p, c, b,
                                              temperature=temperature)))
        else:
            # ESPIM-format decode: the packs are closure constants so the
            # fused kernel sees static chunk geometry
            self._decode = jax.jit(_finite_step(
                lambda p, c, b: serve_step_sparse_fn(
                    cfg, p, sparse, c, b, temperature=temperature,
                    impl=impl)))
        # lazily-built dense fallback for quarantined slots: jitted over
        # the pruned dense copy of the same weights, so its greedy tokens
        # match the sparse path's (PR3-5 parity) — degraded is slower,
        # never different
        self._dense_decode = None
        self._dense_params = None

    # ------------------------------------------------------------ telemetry
    def _init_metrics(self, sparse: dict | None) -> None:
        """Register the engine's instruments once and keep direct
        references — the hot path increments attributes, it never does a
        registry lookup.  Static facts about the packs (bytes/token by
        plane, pad_frac by width bucket) are published as gauges here:
        they are properties of the loaded model, not of any one step."""
        reg = self.metrics
        h = tm.LATENCY_BUCKETS_S
        self._h_step = {
            "prefill": reg.histogram("serve_step_seconds", buckets=h,
                                     phase="prefill"),
            "decode": reg.histogram("serve_step_seconds", buckets=h,
                                    phase="decode"),
        }
        self._c_tokens = reg.counter(
            "serve_tokens_total", "tokens emitted, all datapaths")
        self._c_degraded_tokens = reg.counter(
            "serve_degraded_tokens_total", "tokens from the dense fallback")
        self._c_quarantines = reg.counter(
            "serve_quarantines_total", "per-slot non-finite guard trips")
        self._c_retries = reg.counter(
            "serve_retries_total", "transient step failures retried")
        self._c_watchdog = reg.counter(
            "serve_watchdog_flags_total", "stuck-decode watchdog trips")
        self._c_arena_checks = reg.counter(
            "serve_arena_checks_total", "leaked-block invariant sweeps run")
        self._c_preempts = reg.counter(
            "serve_preempts_total", "slots released to recompute later")
        self._c_shed = reg.counter(
            "serve_shed_total", "requests dropped by overload admission")
        self._c_restores = reg.counter(
            "serve_restores_total", "requests re-admitted from a snapshot")
        self._g_queue_depth = reg.gauge(
            "serve_queue_depth", "requests waiting for admission")
        self._g_headroom = reg.gauge(
            "serve_arena_headroom_blocks",
            "free arena blocks not covered by admission reservations")
        self._g_slot_occ = reg.gauge(
            "serve_slot_occupancy", "mean fraction of slots decoding")
        self._g_arena = {
            s: reg.gauge("serve_arena_blocks", state=s)
            for s in ("used", "free", "quarantined")}
        self._g_arena_occ = reg.gauge(
            "serve_arena_occupancy", "fraction of arena blocks in use")
        self._g_arena_frag = reg.gauge(
            "serve_arena_fragmentation",
            "1 - largest contiguous free run / free blocks")
        if sparse is None:
            return
        from repro.core.sparse_model import sparse_stats
        st = sparse_stats(sparse)
        tot = st["total"]
        for plane, nbytes in (("value", tot["value_plane_bytes"]),
                              ("index", tot["index_plane_bytes"]),
                              ("dense", tot["dense_proj_bytes_per_token"])):
            reg.gauge("espim_bytes_per_token", plane=plane).set(nbytes)
        # pad_frac per width bucket: the padding each SDDS bucket's ELL
        # width actually costs, from the pack's own validity mask
        for gname, g in sparse["groups"].items():
            for i, (b, width) in enumerate(zip(g["buckets"], g["widths"])):
                valid = np.asarray(b["valid"])
                reg.gauge("espim_pad_frac", group=gname, bucket=str(i),
                          width=str(int(width))).set(
                    1.0 - float(valid.sum()) / max(1, valid.size))

    def _update_arena_gauges(self) -> None:
        self._g_queue_depth.set(self.scheduler.queue_depth)
        nb = getattr(self.cache, "num_blocks", 0)
        if not nb:
            return
        free = self.cache.free_blocks
        self._g_headroom.set(free - int(self.cache._resv.sum()))
        quarantined = len(getattr(self.cache, "_quarantined", ()))
        self._g_arena["used"].set(nb - free - quarantined)
        self._g_arena["free"].set(free)
        self._g_arena["quarantined"].set(quarantined)
        self._g_arena_occ.set((nb - free - quarantined) / nb)
        # fragmentation: how broken-up the free pool is physically —
        # 1 - (largest contiguous free run / free blocks)
        if free:
            run = best = 1
            ids = sorted(self.cache._free)
            for a, b in zip(ids, ids[1:]):
                run = run + 1 if b == a + 1 else 1
                best = max(best, run)
            self._g_arena_frag.set(1.0 - best / free)
        else:
            self._g_arena_frag.set(0.0)

    # ------------------------------------------------------------ lifecycle
    def reset_stats(self) -> None:
        """Zero every counter and the per-request metrics — e.g. after a
        jit-warmup request, so a benchmark measures steady state only."""
        self.scheduler.completed.clear()
        self.scheduler.reset_metrics()
        self._occ_accum = 0.0
        self.stats = EngineStats(
            requests=self.scheduler.completed,
            degraded_to_dense=self.stats.degraded_to_dense,
            hists=self.scheduler.hists)

    def submit(self, req: Request) -> bool:
        """Enqueue a request.  Infeasible requests (cannot ever fit the
        arena or max_len) raise; a feasible request may still be shed by
        the bounded-queue overload policy — returns False in that case
        (the request is terminal in state ``shed``), True when queued."""
        worst = req.worst_case_tokens(self.max_len)
        if self.paged and self.cache.blocks_needed(worst) > self.cache.num_blocks:
            raise ValueError(
                f"request {req.rid} needs {self.cache.blocks_needed(worst)} "
                f"blocks but the arena holds {self.cache.num_blocks}")
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"request {req.rid} prompt ({len(req.prompt)}) exceeds "
                f"max_len ({self.max_len})")
        admitted = self.scheduler.add(req) is not None
        self._g_queue_depth.set(self.scheduler.queue_depth)
        return admitted

    def _on_shed(self, req) -> None:
        """Scheduler shed hook: one request dropped by overload policy."""
        self.stats.requests_shed += 1
        self._c_shed.inc()
        info = {"rid": req.rid}
        self.tracer.instant("fault.shed", cat="fault", args=info)
        self.flight.record("fault", "fault.shed", info)
        if self.flight.pressure():
            self.flight.trip("shed_storm", registry=self.metrics)

    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it lives: an in-flight slot is torn
        down through the one teardown path (blocks back to the pool,
        scheduler state finalized as ``cancelled``), a queued request is
        retired by the scheduler.  Returns False for unknown/finished."""
        for i, st in enumerate(self.slots):
            if st is not None and st.req.rid == rid:
                self._teardown(i, "cancelled")
                return True
        if self.scheduler.cancel_pending(rid):
            self.stats.requests_cancelled += 1
            return True
        return False

    def snapshot(self) -> dict:
        """Versioned, digest- and pack-fingerprint-bound serialization of
        the engine's control plane (queues, committed tokens, slot map).
        KV planes are recomputed on restore, never saved.  Call at a step
        boundary (between ``step()`` calls)."""
        from repro.serve import snapshot as snapmod
        with self.tracer.span("snapshot.save", cat="snapshot") as sp:
            snap = snapmod.snapshot_engine(self)
            sp.set("requests", len(snap["requests"]))
        self.flight.record("snapshot", "snapshot.save",
                           {"requests": len(snap["requests"])})
        return snap

    def restore(self, snap: dict, requests: dict | None = None) -> list:
        """Re-admit every request from a snapshot into this (idle)
        engine; each resumes by re-prefilling its committed history, so
        remaining greedy tokens match the uninterrupted run bit-for-bit.
        Raises ``SnapshotIntegrityError`` on digest/version/pack
        mismatch.  Returns the restored Request objects."""
        from repro.serve import snapshot as snapmod
        with self.tracer.span("snapshot.restore", cat="snapshot") as sp:
            reqs = snapmod.restore_engine(self, snap, requests)
            sp.set("requests", len(reqs))
        self.flight.record("snapshot", "snapshot.restore",
                           {"requests": len(reqs)})
        return reqs

    def _arena_pressure(self) -> float:
        """Fraction of the arena that is used or spoken for (allocated +
        quarantined + outstanding reservations) — the watermark signal."""
        nb = getattr(self.cache, "num_blocks", 0)
        if not nb:
            return 0.0
        used = nb - self.cache.free_blocks
        return (used + int(self.cache._resv.sum())) / nb

    def _admit(self) -> None:
        if self._wm_high is not None and self.paged:
            # hysteresis backpressure: past the high watermark admission
            # pauses (headroom is kept for in-flight growth + restores)
            # and resumes only once pressure falls below the low mark
            occ = self._arena_pressure()
            if self._backpressure:
                if occ <= self._wm_low:
                    self._backpressure = False
            elif occ >= self._wm_high:
                self._backpressure = True
            if self._backpressure:
                return
        for i in range(self.b):
            if self.slots[i] is not None:
                continue
            if not self.scheduler.has_pending:
                break

            def can_admit(r, slot=i):
                return self.cache.reserve(
                    slot, r.worst_case_tokens(self.max_len))

            picked = self.scheduler.pick(can_admit)
            if picked is None:
                break
            req, metrics = picked
            st = _Slot(req, metrics)
            adm = {"rid": req.rid, "slot": i,
                   "resumed": bool(req.output)}
            self.tracer.instant("req.admit", cat="request", args=adm)
            self.flight.record("request", "req.admit", adm)
            self.seq_len[i] = 0
            # a request with committed output resumes (preempt/restore):
            # its per-request state is replayed from prompt + committed
            # tokens — the SDDS planes are static, so the recompute is
            # bit-identical to the original prefill + decode history
            hist = list(req.prompt) + [int(t) for t in req.output]
            st.resumed = bool(req.output)
            if st.resumed:
                res = {"slot": i, "rid": req.rid,
                       "committed": len(req.output)}
                self.tracer.instant("fault.resume", cat="fault", args=res)
                self.flight.record("fault", "fault.resume", res)
            if self.chunked_prefill:
                st.phase = "prefill"
                st.pf_cache = self._prefiller.proto
                # the last committed token is the next decode's input, so
                # prefill re-feeds everything before it
                st.feed = hist[:-1] if st.resumed else hist
            else:
                st.phase = "decode"
                st.cursor = 0
                st.feed = hist
                st.cur_token = st.feed[0]
            self.slots[i] = st

    # ----------------------------------------------------------- preemption
    def _remaining_tokens(self, st: _Slot) -> int:
        """Tokens this slot still has to serve: unfed prefill/replay rows
        plus undecoded output — the longest-remaining-first victim key."""
        rem = st.req.max_new_tokens - len(st.req.output)
        if st.phase == "prefill":
            rem += len(st.feed) - st.pos
        elif st.cursor is not None and st.cursor < len(st.feed):
            rem += len(st.feed) - st.cursor
        return rem

    def _preempt_slot(self, i: int) -> _Slot:
        """Release one slot's KV blocks back to the pool, keeping the
        request's committed tokens for later recompute.  NOT a terminal
        exit — the caller requeues the request."""
        st = self.slots[i]
        self.stats.preempts += 1
        self._c_preempts.inc()
        info = {"slot": i, "rid": st.req.rid,
                "committed": len(st.req.output)}
        self.tracer.instant("fault.preempt", cat="fault", args=info)
        self.flight.record("fault", "fault.preempt", info)
        if self.flight.pressure():
            self.flight.trip("preempt_storm", registry=self.metrics)
        self.cache.free_slot(i)
        self.slots[i] = None
        self.seq_len[i] = 0
        return st

    def _maybe_preempt(self) -> None:
        """Preempt-to-recompute: when the next queued request has a free
        slot waiting but is blocked on ARENA space (its worst-case block
        reservation fails) and some slot has strictly more work left than
        the candidate's whole footprint, release that slot (longest
        remaining first), admit the candidate into the freed blocks in
        the same tick, and requeue the victim at the queue head.  The
        strict ordering (victim remaining > candidate total) makes the
        policy well-founded — every preemption serves strictly shorter
        work, so chains terminate and no pair can flip-flop.  Slot
        shortage alone (all slots busy, arena fine) never preempts: that
        is ordinary queueing, not pressure."""
        if (not self.preempt or not self.paged or self._backpressure
                or not self.scheduler.has_pending
                or all(s is not None for s in self.slots)):
            return
        cand = self.scheduler.peek()
        if cand is None:
            return
        req, _m = cand
        cand_rem = (len(req.prompt) + req.max_new_tokens
                    - len(req.output))
        victim, victim_rem = None, cand_rem
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            rem = self._remaining_tokens(st)
            if rem > victim_rem:
                victim, victim_rem = i, rem
        if victim is None:
            return
        # pointless-preemption guard: only evict when the victim's slot +
        # blocks actually let the candidate reserve
        need = self.cache.blocks_needed(req.worst_case_tokens(self.max_len))
        avail = self.cache.free_blocks - int(self.cache._resv.sum())
        freed = (int(self.cache.n_blocks[victim])
                 + int(self.cache._resv[victim]))
        if avail + freed < need:
            return
        st = self._preempt_slot(victim)
        self._admit()                     # candidate takes the freed space
        self.scheduler.requeue(st.req, st.metrics)

    def _teardown(self, i: int, state: str = "completed") -> None:
        """The single exit path for every slot, whatever the reason —
        finish, cancel, deadline, failure.  One path means one place that
        must release the paged blocks and finalize scheduler state, so no
        exit class can leak (``check_arena`` proves it)."""
        st = self.slots[i]
        if state == "completed" and st.emitted_degraded:
            state = "degraded"      # full output, but not all-sparse-path
        st.req.done = True
        self.scheduler.finish(st.metrics, state)
        if state == "failed":
            # no datapath produced finite logits — worth a post-mortem
            self.flight.trip("failure", registry=self.metrics)
        if state in ("completed", "degraded"):
            self.stats.requests_completed += 1
            if state == "degraded":
                self.stats.requests_degraded += 1
        elif state == "cancelled":
            self.stats.requests_cancelled += 1
        elif state == "deadline_expired":
            self.stats.requests_deadline_expired += 1
        else:
            self.stats.requests_failed += 1
        self.cache.free_slot(i)
        self.slots[i] = None
        self.seq_len[i] = 0

    def _expire(self) -> None:
        """Deadline sweep: queued requests past their limit are retired by
        the scheduler; in-flight slots past total wall clock (or past the
        TTFT deadline with no first token yet) are torn down."""
        now = time.monotonic()
        self.stats.requests_deadline_expired += len(
            self.scheduler.expire_pending(now))
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            dl = st.req.deadline_s
            if dl is not None and now - st.metrics.t_submit > dl:
                self._teardown(i, "deadline_expired")
                continue
            tdl = st.req.ttft_deadline_s
            if (tdl is not None and st.metrics.t_first is None
                    and now - st.metrics.t_submit > tdl):
                self._teardown(i, "deadline_expired")

    def _emit_token(self, i: int, tok: int) -> None:
        st = self.slots[i]
        if st.metrics.t_first is None:
            st.metrics.t_first = time.monotonic()
            ft = {"rid": st.req.rid, "slot": i}
            self.tracer.instant("req.first_token", cat="request", args=ft)
            self.flight.record("request", "req.first_token", ft)
        st.req.output.append(tok)
        st.metrics.n_out += 1
        self.stats.tokens_generated += 1
        self._c_tokens.inc()
        st.cur_token = tok
        seq_len = len(st.req.prompt) + len(st.req.output)
        if (tok == st.req.eos_id
                or len(st.req.output) >= st.req.max_new_tokens
                or seq_len >= self.max_len - 1):
            self._teardown(i)

    def _next_key(self):
        if self.temperature <= 0.0:
            return None  # greedy sampling never reads the key: skip the
            # per-tick jax.random.split dispatch on the hot path
        self._key, sub = jax.random.split(self._key)
        return sub

    # ---------------------------------------------------------- degradation
    def _dense_fallback(self):
        """Jitted dense decode over the pruned dense copy of the sparse
        weights — built on first quarantine, shared by every degraded
        slot after."""
        if self._dense_decode is None:
            self._dense_params = sparse_model.pruned_param_tree(
                self.params, self.sparse)
            cfg, temperature = self.cfg, self.temperature
            self._dense_decode = jax.jit(_finite_step(
                lambda p, c, b: serve_step_fn(cfg, p, c, b,
                                              temperature=temperature)))
        return self._dense_decode, self._dense_params

    def _retry(self, fn, *args):
        """Run one jitted step, retrying transient failures with capped
        exponential backoff; re-raises after ``max_retries`` retries."""
        delay = self.retry_backoff
        for attempt in range(self.max_retries + 1):
            try:
                return fn(*args)
            except TransientStepError:
                if attempt >= self.max_retries:
                    raise
                self.stats.retries += 1
                self._c_retries.inc()
                info = {"attempt": attempt, "backoff_s": delay}
                self.tracer.instant("fault.retry", cat="fault", args=info)
                self.flight.record("fault", "fault.retry", info)
                time.sleep(delay)
                delay = min(delay * 2.0, self.retry_backoff_cap)

    def check_arena(self) -> dict:
        """Arena invariant after any step: every physical block in exactly
        one owner, and empty slots own nothing.  Raises on violation."""
        self._c_arena_checks.inc()
        acct = self.cache.arena_check()
        n_blocks = getattr(self.cache, "n_blocks", None)
        if n_blocks is not None:
            for i, st in enumerate(self.slots):
                if st is None and int(n_blocks[i]) != 0:
                    raise RuntimeError(
                        f"empty slot {i} still owns {int(n_blocks[i])} "
                        f"paged blocks — teardown leak")
        return acct

    # ----------------------------------------------------------- tick kinds
    def _prefill_tick(self, i: int) -> None:
        st = self.slots[i]
        plen = len(st.feed)
        with self.tracer.span("prefill.launch", cat="prefill",
                              args=None) as sp:
            sp.set("slot", i).set("pos", st.pos)
            logits, st.pf_cache, n_valid = self._prefiller.run_chunk(
                self.params, st.pf_cache, st.feed, st.pos)
            self.tracer.fence(logits)
        with self.tracer.span("cache.scatter", cat="prefill"):
            self.cache.ensure(i, st.pos + n_valid)
            self.cache.scatter_chunk(
                i, self._prefiller.chunk_rows(st.pf_cache, st.pos),
                st.pos, n_valid)
        st.pos += n_valid
        self.stats.steps += 1
        self.stats.prefill_chunks += 1
        if st.pos >= plen:
            if st.resumed:
                # resume recompute: the feed ends just before the last
                # committed token, which becomes the next decode input —
                # the final chunk's logits are history, never re-sampled
                self.cache.set_slot_state(
                    i, self._prefiller.state_rows(st.pf_cache))
                st.pf_cache = None
                self.seq_len[i] = plen
                st.cur_token = int(st.req.output[-1])
                st.phase = "decode"
                return
            # prompt fully prefilled: install recurrent states and sample
            # the first token straight from the final chunk's logits
            with self.tracer.span("host.sample", cat="host_sync"):
                last = logits[:, n_valid - 1]
                finite = bool(np.isfinite(np.asarray(last, np.float32)).all())
            if not finite:
                # a poisoned prefill has already contaminated this slot's
                # KV history — no fallback can recompute it, so the slot
                # ends here rather than ever emit a wrong token
                self.stats.quarantines += 1
                self._c_quarantines.inc()
                q = {"slot": i, "rid": st.req.rid, "phase": "prefill"}
                self.tracer.instant("fault.quarantine", cat="fault", args=q)
                self.flight.record("fault", "fault.quarantine", q)
                self.flight.trip("quarantine", registry=self.metrics)
                self._teardown(i, "failed")
                return
            self.cache.set_slot_state(
                i, self._prefiller.state_rows(st.pf_cache))
            st.pf_cache = None
            self.seq_len[i] = plen
            tok = int(sample_tokens(self.cfg, last, self.temperature,
                                    self._next_key())[0])
            st.phase = "decode"
            self._emit_token(i, tok)

    def _decode_tick(self, decoding: list[int]) -> None:
        with self.tracer.span("decode.prepare", cat="decode"):
            cur = np.zeros((self.b, 1), np.int32)
            lens = np.zeros(self.b, np.int32)
            for i in decoding:
                st = self.slots[i]
                if st.cursor is not None and st.cursor < len(st.feed):
                    cur[i, 0] = st.feed[st.cursor]   # replay prefill/resume
                else:
                    cur[i, 0] = st.cur_token
                lens[i] = self.seq_len[i]
                self.cache.ensure(i, int(self.seq_len[i]) + 1)
            healthy = [i for i in decoding if not self.slots[i].degraded]
            degraded = [i for i in decoding if self.slots[i].degraded]

        with self.tracer.span("cache.gather", cat="decode"):
            view = self.cache.gather_view(lens)
            batch = {"tokens": jnp.asarray(cur), "rng": self._next_key()}
            self.tracer.fence(view)
        t0 = time.monotonic()
        results: dict[int, int] = {}   # slot -> sampled token this tick
        n_applies = 0
        any_drop = False

        def _commit(ok, new_cache, group):
            # commit only the finite slots' KV writes: a poisoned row is
            # dropped at the arena (OOB scatter) so it never needs
            # scrubbing — the slot's position is simply re-decoded by the
            # dense fallback next tick
            nonlocal n_applies
            commit = np.zeros(self.b, bool)
            for i in group:
                commit[i] = bool(ok[i])
            self.cache.apply_decode(new_cache, lens, commit)
            n_applies += 1

        if healthy:
            try:
                with self.tracer.span("decode.launch", cat="decode"):
                    nxt, ok, new_cache = self._retry(
                        self._decode, self.params, view, batch)
                    self.tracer.fence(ok)
            except TransientStepError:
                for i in list(healthy):
                    self._teardown(i, "failed")
            else:
                with self.tracer.span("host.sync", cat="host_sync"):
                    nxt, ok = np.asarray(nxt), np.asarray(ok)
                with self.tracer.span("cache.scatter", cat="decode"):
                    _commit(ok, new_cache, healthy)
                for i in healthy:
                    if ok[i]:
                        results[i] = int(nxt[i, 0])
                        continue
                    any_drop = True
                    self.stats.quarantines += 1
                    self._c_quarantines.inc()
                    q = {"slot": i, "rid": self.slots[i].req.rid,
                         "phase": "decode"}
                    self.tracer.instant("fault.quarantine", cat="fault",
                                        args=q)
                    self.flight.record("fault", "fault.quarantine", q)
                    self.flight.trip("quarantine", registry=self.metrics)
                    if self.sparse is None:
                        # dense engine: no lower rung on the ladder
                        self._teardown(i, "failed")
                    else:
                        # quarantine: no emit, no advance — next tick this
                        # slot decodes the same position densely
                        self.slots[i].degraded = True

        degraded = [i for i in degraded if self.slots[i] is not None]
        if degraded:
            fn, dparams = self._dense_fallback()
            try:
                with self.tracer.span("decode.launch_degraded",
                                      cat="decode"):
                    nxt, ok, new_cache = self._retry(fn, dparams, view,
                                                     batch)
                    self.tracer.fence(ok)
            except TransientStepError:
                for i in list(degraded):
                    self._teardown(i, "failed")
            else:
                with self.tracer.span("host.sync", cat="host_sync"):
                    nxt, ok = np.asarray(nxt), np.asarray(ok)
                with self.tracer.span("cache.scatter", cat="decode"):
                    _commit(ok, new_cache, degraded)
                for i in degraded:
                    if ok[i]:
                        results[i] = int(nxt[i, 0])
                    else:
                        # dense couldn't produce finite logits either: the
                        # poison is in this slot's history, not the sparse
                        # weights — no rung left
                        any_drop = True
                        self._teardown(i, "failed")

        if n_applies != 1 or any_drop:
            # two closures (or a dropped write) each left a partial cached
            # view behind — force the next gather to rebuild from pages
            self.cache.invalidate_view()

        self.stats.steps += 1
        self.stats.decode_steps += 1
        self._occ_accum += len(decoding) / self.b
        self.stats.slot_occupancy = self._occ_accum / self.stats.decode_steps
        self._g_slot_occ.set(self.stats.slot_occupancy)
        if (self._watchdog is not None
                and self._watchdog.observe(time.monotonic() - t0)):
            self.stats.watchdog_flags += 1
            self._c_watchdog.inc()
            self.tracer.instant("fault.watchdog_flag", cat="fault")
            self.flight.record("fault", "fault.watchdog_flag", None)

        with self.tracer.span("decode.emit", cat="decode"):
            for i in decoding:
                st = self.slots[i]
                if st is None or i not in results:
                    continue  # torn down or quarantined: no emit/advance
                self.seq_len[i] += 1
                if st.cursor is not None and st.cursor < len(st.feed):
                    st.cursor += 1
                    if st.cursor < len(st.feed):
                        continue        # still replaying: output ignored
                if st.degraded:
                    st.emitted_degraded = True
                    self.stats.degraded_tokens += 1
                    self._c_degraded_tokens.inc()
                self._emit_token(i, results[i])

    # ------------------------------------------------------------- stepping
    def step(self) -> None:
        """One engine tick: a prefill chunk for one slot, or one decode
        step across all decode-ready slots.  A fully idle engine (queue
        drained, every slot empty) is a no-op — no wasted jitted call.

        Traced as one ``engine.step`` span whose direct children are the
        per-phase breakdown (scheduler / prefill / decode / host_sync /
        bookkeeping) — ``span_coverage`` over these is asserted >= 95%
        in tests, so the breakdown IS the step, not a sample of it."""
        with self.tracer.span("engine.step", cat="engine"):
            with self.tracer.span("scheduler.expire", cat="scheduler"):
                self._expire()
            with self.tracer.span("scheduler.admit", cat="scheduler"):
                self._admit()
                self._maybe_preempt()
            with self.tracer.span("scheduler.plan", cat="scheduler"):
                prefilling = [i for i, s in enumerate(self.slots)
                              if s is not None and s.phase == "prefill"]
                decoding = [i for i, s in enumerate(self.slots)
                            if s is not None and s.phase == "decode"]
                action, target = self.scheduler.next_action(prefilling,
                                                            decoding)
            if action == "prefill":
                t0 = time.monotonic()
                # work spans carry their owning request(s) so the
                # timeline builder can attribute every tick to a rid
                pf_args = {"rid": self.slots[target].req.rid,
                           "slot": target}
                self.flight.record("step", "prefill.chunk", pf_args)
                with self.tracer.span("prefill.chunk", cat="prefill",
                                      args=pf_args):
                    self._prefill_tick(target)
                self._h_step["prefill"].observe(time.monotonic() - t0)
            elif action == "decode":
                t0 = time.monotonic()
                d_args = {"rids": [self.slots[i].req.rid
                                   for i in decoding]}
                self.flight.record("step", "decode.step", d_args)
                with self.tracer.span("decode.step", cat="decode",
                                      args=d_args):
                    self._decode_tick(decoding)
                self._h_step["decode"].observe(time.monotonic() - t0)
            with self.tracer.span("metrics.update", cat="scheduler"):
                if self.validate_arena:
                    self.check_arena()
                self._update_arena_gauges()

    def run(self, max_steps: int = 10_000) -> EngineStats:
        with self.tracer.span("engine.run", cat="engine"):
            for _ in range(max_steps):
                if (not self.scheduler.has_pending
                        and all(s is None for s in self.slots)):
                    break
                self.step()
        return self.stats
