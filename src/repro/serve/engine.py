"""Production serving engine: paged KV cache + chunked prefill + scheduler.

Fixed B decode slots over one block-pool KV arena (``serve/paged_cache``).
Each engine tick is either one chunked-prefill call for a single slot
(``serve/prefill`` — TTFT in ceil(prompt_len/chunk) jitted calls instead
of prompt_len decode steps) or one batched decode step across every
decode-ready slot; the interleave, admission order (FCFS / SJF) and
per-request latency metrics are owned by ``serve/scheduler``.  Finished
sequences return their blocks to the pool; queued requests are admitted
only once their worst-case block count is reservable, so the arena can
never deadlock mid-flight.

Pass ``sparse`` (from ``sparsify_model`` — whole decoder layer: fused
QKV + O + gate/up/down pack groups; or the ``sparsify_mlps`` MLP-only
preset) to serve from the ESPIM column-chunked format: decode ticks run
every covered projection through the fused batched SpMV across all
active slots at once, and prefill chunks run the same pruned matrices as
GEMMs (Section III-I per phase) — the batched kernel IS the
continuous-batching hot path (the paper's deployment: decode from the
compressed format).

Families without a chunked ``prefill_chunk`` (moe / vlm / audio) fall back
to the seed behavior: token-by-token prompt replay through the decode
path (``prefill_mode="replay"``).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import factory
from repro.serve.paged_cache import make_kv_cache
from repro.serve.prefill import ChunkedPrefiller
from repro.serve.scheduler import Scheduler
from repro.serve.serve_step import (sample_tokens, serve_step_fn,
                                    serve_step_sparse_fn)

__all__ = ["Request", "EngineStats", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never stops early
    output: list = dataclasses.field(default_factory=list)
    done: bool = False

    def worst_case_tokens(self, max_len: int) -> int:
        """Cache rows this request can ever occupy — the admission
        reservation AND the submit-time feasibility check both use this,
        so they can never diverge (the allocator's ``ensure`` is
        infallible only while they agree)."""
        return min(len(self.prompt) + self.max_new_tokens + 1, max_len)


@dataclasses.dataclass
class EngineStats:
    steps: int = 0                 # jitted calls (prefill + decode)
    decode_steps: int = 0
    prefill_chunks: int = 0
    tokens_generated: int = 0
    requests_completed: int = 0
    slot_occupancy: float = 0.0    # mean fraction of slots active per tick
    requests: list = dataclasses.field(default_factory=list)

    def latency_summary(self) -> dict:
        from repro.serve.scheduler import latency_summary
        return latency_summary(self.requests)


class _Slot:
    """Per-slot serving state (the request plus its progress)."""
    __slots__ = ("req", "metrics", "phase", "pos", "cursor", "cur_token",
                 "pf_cache")

    def __init__(self, req, metrics):
        self.req = req
        self.metrics = metrics
        self.phase = "prefill"     # "prefill" | "decode"
        self.pos = 0               # prompt tokens prefilled (chunked mode)
        self.cursor = None         # replay cursor (replay mode)
        self.cur_token = 0
        self.pf_cache = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 max_len: int, temperature: float = 0.0,
                 sparse: dict | None = None, impl: str = "ref", *,
                 paged: bool = True, block_size: int = 16,
                 num_blocks: int | None = None, prefill_chunk: int = 16,
                 prefill_mode: str = "auto", policy: str = "fcfs",
                 max_prefill_streak: int = 2, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.max_len = max_len
        self.temperature = temperature
        self.sparse = sparse
        self.cache = make_kv_cache(cfg, batch_slots, max_len, paged=paged,
                                   block_size=block_size,
                                   num_blocks=num_blocks)
        self.paged = paged
        self.slots: list[_Slot | None] = [None] * batch_slots
        self.seq_len = np.zeros(batch_slots, np.int32)
        self.scheduler = Scheduler(policy=policy,
                                   max_prefill_streak=max_prefill_streak)
        self.stats = EngineStats(requests=self.scheduler.completed)
        self._key = jax.random.PRNGKey(seed)
        self._occ_accum = 0.0

        if prefill_mode == "auto":
            chunked = (factory.supports_chunked_prefill(cfg)
                       if sparse is None else cfg.family == "dense")
        elif prefill_mode == "chunked":
            chunked = True
        elif prefill_mode == "replay":
            chunked = False
        else:
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        self.chunked_prefill = chunked
        self._prefiller = None
        if chunked:
            self._prefiller = ChunkedPrefiller(
                cfg, prefill_chunk, max_len, self.cache.seq_names,
                self.cache.state_names, sparse=sparse, impl=impl)

        if sparse is None:
            self._decode = jax.jit(
                lambda p, c, b: serve_step_fn(cfg, p, c, b,
                                              temperature=temperature))
        else:
            # ESPIM-format decode: the packs are closure constants so the
            # fused kernel sees static chunk geometry
            self._decode = jax.jit(
                lambda p, c, b: serve_step_sparse_fn(
                    cfg, p, sparse, c, b, temperature=temperature,
                    impl=impl))

    # ------------------------------------------------------------ lifecycle
    def reset_stats(self) -> None:
        """Zero every counter and the per-request metrics — e.g. after a
        jit-warmup request, so a benchmark measures steady state only."""
        self.scheduler.completed.clear()
        self._occ_accum = 0.0
        self.stats = EngineStats(requests=self.scheduler.completed)

    def submit(self, req: Request) -> None:
        worst = req.worst_case_tokens(self.max_len)
        if self.paged and self.cache.blocks_needed(worst) > self.cache.num_blocks:
            raise ValueError(
                f"request {req.rid} needs {self.cache.blocks_needed(worst)} "
                f"blocks but the arena holds {self.cache.num_blocks}")
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"request {req.rid} prompt ({len(req.prompt)}) exceeds "
                f"max_len ({self.max_len})")
        self.scheduler.add(req)

    def _admit(self) -> None:
        for i in range(self.b):
            if self.slots[i] is not None:
                continue
            if not self.scheduler.has_pending:
                break

            def can_admit(r, slot=i):
                return self.cache.reserve(
                    slot, r.worst_case_tokens(self.max_len))

            picked = self.scheduler.pick(can_admit)
            if picked is None:
                break
            req, metrics = picked
            st = _Slot(req, metrics)
            self.seq_len[i] = 0
            if self.chunked_prefill:
                st.phase = "prefill"
                st.pf_cache = self._prefiller.proto
            else:
                st.phase = "decode"
                st.cursor = 0
                st.cur_token = req.prompt[0]
            self.slots[i] = st

    def _finish(self, i: int) -> None:
        st = self.slots[i]
        st.req.done = True
        self.scheduler.finish(st.metrics)
        self.stats.requests_completed += 1
        self.cache.free_slot(i)
        self.slots[i] = None
        self.seq_len[i] = 0

    def _emit_token(self, i: int, tok: int) -> None:
        st = self.slots[i]
        if st.metrics.t_first is None:
            st.metrics.t_first = time.monotonic()
        st.req.output.append(tok)
        st.metrics.n_out += 1
        self.stats.tokens_generated += 1
        st.cur_token = tok
        seq_len = len(st.req.prompt) + len(st.req.output)
        if (tok == st.req.eos_id
                or len(st.req.output) >= st.req.max_new_tokens
                or seq_len >= self.max_len - 1):
            self._finish(i)

    def _next_key(self):
        if self.temperature <= 0.0:
            return None  # greedy sampling never reads the key: skip the
            # per-tick jax.random.split dispatch on the hot path
        self._key, sub = jax.random.split(self._key)
        return sub

    # ----------------------------------------------------------- tick kinds
    def _prefill_tick(self, i: int) -> None:
        st = self.slots[i]
        plen = len(st.req.prompt)
        logits, st.pf_cache, n_valid = self._prefiller.run_chunk(
            self.params, st.pf_cache, st.req.prompt, st.pos)
        self.cache.ensure(i, st.pos + n_valid)
        self.cache.scatter_chunk(
            i, self._prefiller.chunk_rows(st.pf_cache, st.pos),
            st.pos, n_valid)
        st.pos += n_valid
        self.stats.steps += 1
        self.stats.prefill_chunks += 1
        if st.pos >= plen:
            # prompt fully prefilled: install recurrent states and sample
            # the first token straight from the final chunk's logits
            self.cache.set_slot_state(
                i, self._prefiller.state_rows(st.pf_cache))
            st.pf_cache = None
            self.seq_len[i] = plen
            last = logits[:, n_valid - 1]
            tok = int(sample_tokens(self.cfg, last, self.temperature,
                                    self._next_key())[0])
            st.phase = "decode"
            self._emit_token(i, tok)

    def _decode_tick(self, decoding: list[int]) -> None:
        cur = np.zeros((self.b, 1), np.int32)
        lens = np.zeros(self.b, np.int32)
        active = np.zeros(self.b, bool)
        for i in decoding:
            st = self.slots[i]
            if st.cursor is not None and st.cursor < len(st.req.prompt):
                cur[i, 0] = st.req.prompt[st.cursor]   # replay prefill
            else:
                cur[i, 0] = st.cur_token
            lens[i] = self.seq_len[i]
            active[i] = True
            self.cache.ensure(i, int(self.seq_len[i]) + 1)
        view = self.cache.gather_view(lens)
        batch = {"tokens": jnp.asarray(cur), "rng": self._next_key()}
        nxt, _, new_cache = self._decode(self.params, view, batch)
        self.cache.apply_decode(new_cache, lens, active)
        nxt = np.asarray(nxt)
        self.stats.steps += 1
        self.stats.decode_steps += 1
        self._occ_accum += len(decoding) / self.b
        self.stats.slot_occupancy = self._occ_accum / self.stats.decode_steps
        for i in decoding:
            st = self.slots[i]
            self.seq_len[i] += 1
            if st.cursor is not None and st.cursor < len(st.req.prompt):
                st.cursor += 1
                if st.cursor < len(st.req.prompt):
                    continue        # still replaying: output ignored
            self._emit_token(i, int(nxt[i, 0]))

    # ------------------------------------------------------------- stepping
    def step(self) -> None:
        """One engine tick: a prefill chunk for one slot, or one decode
        step across all decode-ready slots.  A fully idle engine (queue
        drained, every slot empty) is a no-op — no wasted jitted call."""
        self._admit()
        prefilling = [i for i, s in enumerate(self.slots)
                      if s is not None and s.phase == "prefill"]
        decoding = [i for i, s in enumerate(self.slots)
                    if s is not None and s.phase == "decode"]
        action, target = self.scheduler.next_action(prefilling, decoding)
        if action == "idle":
            return
        if action == "prefill":
            self._prefill_tick(target)
        else:
            self._decode_tick(decoding)

    def run(self, max_steps: int = 10_000) -> EngineStats:
        for _ in range(max_steps):
            if (not self.scheduler.has_pending
                    and all(s is None for s in self.slots)):
                break
            self.step()
        return self.stats
