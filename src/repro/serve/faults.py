"""Deterministic fault injection for the serving engine (DESIGN.md §11).

Every injector is seeded and pure-functional over the sparse serving
dict (the original is never mutated — corrupted copies share unaffected
planes), so a fault drill is reproducible bit-for-bit.  Two fault
families:

* **load faults** — corruption that must be *rejected at engine
  construction* by the pack-integrity layer: a single bit flip anywhere
  in an index or value plane (fp, int8 or nibble-packed int4), or a
  schedule/pack mismatch (the perm planes rolled one layer — internally
  consistent, so only the bound fingerprint can catch it).
* **runtime faults** — degradation the engine must survive *without ever
  emitting a silent wrong token*: a NaN-poisoned decode closure
  (quarantine -> dense fallback), a mid-decode abort (``cancel``), arena
  OOM pressure (admission pushback via quarantined blocks), latency
  spikes (watchdog flags) and transient step errors (capped-backoff
  retry).

``run_fault_drill`` runs one engine per fault class against a no-fault
baseline and reports goodput, recovery time, degraded-token fraction and
leak counts per class; ``check_drill`` asserts the contract (reject at
load, or complete with unaffected slots bit-identical to the baseline
and zero leaked blocks).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.integrity import PackIntegrityError
from repro.runtime.fault_tolerance import LatencyWatchdog
from repro.serve.engine import (Request, ServeEngine, TransientStepError,
                                _finite_step)
from repro.serve.serve_step import serve_step_sparse_fn

__all__ = ["FAULT_KINDS", "LOAD_FAULTS", "flip_bit", "corrupt_group_plane",
           "mismatch_schedule", "poison_values", "inject_poisoned_decode",
           "force_nonfinite_flag", "arm_latency_spike",
           "arm_transient_errors", "run_fault_drill", "check_drill",
           "run_crash_drill", "check_crash_drill",
           "run_overload_drill", "check_overload_drill"]

FAULT_KINDS = ("index_bitflip", "value_bitflip", "schedule_mismatch",
               "nonfinite_logits", "abort_mid_decode", "arena_oom",
               "latency_spike", "transient_step_error")
# corruption the integrity layer must reject at engine construction
LOAD_FAULTS = ("index_bitflip", "value_bitflip", "schedule_mismatch")


# --------------------------------------------------------------- injectors
def flip_bit(arr, rng) -> np.ndarray:
    """Flip one uniformly-random bit of an array's byte buffer."""
    a = np.array(np.asarray(arr), copy=True)
    flat = a.view(np.uint8).reshape(-1)
    bit = int(rng.integers(flat.size * 8))
    flat[bit // 8] ^= np.uint8(1 << (bit % 8))
    return a


def _clone_sparse(sparse: dict) -> dict:
    """Shallow structural copy (dicts/lists new, arrays shared) so an
    injector can swap one plane without touching the caller's dict; the
    legacy top-level group aliases are re-pointed at the clones."""
    out = dict(sparse)
    out["groups"] = {}
    for name, g in sparse["groups"].items():
        g2 = dict(g)
        g2["buckets"] = [dict(b) for b in g["buckets"]]
        out["groups"][name] = g2
        out[name] = g2
    return out


def corrupt_group_plane(sparse: dict, plane: str, rng,
                        group: str | None = None) -> dict:
    """One bit flip in a group's index plane (``plane="index"``) or value
    plane (``plane="value"`` — the fp values, or the quantized codes when
    the pack is int8/int4)."""
    out = _clone_sparse(sparse)
    name = group or next(iter(out["groups"]))
    b = out["groups"][name]["buckets"][0]
    if plane == "index":
        key = "cols"
    elif plane == "value":
        key = "values" if "values" in b else "q"
    else:
        raise ValueError(f"unknown plane {plane!r}; use 'index' or 'value'")
    b[key] = jnp.asarray(flip_bit(b[key], rng))
    return out


def mismatch_schedule(sparse: dict, group: str | None = None) -> dict:
    """Pair a group's packs with the *wrong layer's* balance permutation:
    perm and inv_perm are rolled one layer together, so each layer's pair
    stays internally consistent (bounds/involution validation passes) —
    only the bound fingerprint, which ties the planes to the SDDS
    schedule they were built under, can catch it."""
    out = _clone_sparse(sparse)
    name = group or next(iter(out["groups"]))
    g = out["groups"][name]
    perm = np.asarray(g["perm"])
    if perm.shape[0] < 2:
        raise ValueError("schedule mismatch needs >= 2 layers to roll")
    g["perm"] = jnp.asarray(np.roll(perm, 1, axis=0))
    g["inv_perm"] = jnp.asarray(np.roll(np.asarray(g["inv_perm"]), 1,
                                        axis=0))
    return out


def poison_values(sparse: dict, rng, group: str | None = None) -> dict:
    """NaN one *retained* cell of a group's value plane (or one quant
    scale) — the runtime poison that must trip the per-slot finite guard,
    never reach an emitted token."""
    out = _clone_sparse(sparse)
    name = group or next(iter(out["groups"]))
    b = out["groups"][name]["buckets"][0]
    key = "values" if "values" in b else "srow"
    arr = np.array(np.asarray(b[key], np.float32), copy=True)
    if key == "values":
        idxs = np.argwhere(np.asarray(b["valid"], bool))
        pick = idxs[int(rng.integers(len(idxs)))]
        arr[tuple(pick)] = np.nan
    else:
        arr.reshape(-1)[int(rng.integers(arr.size))] = np.nan
    b[key] = jnp.asarray(arr)
    return out


def inject_poisoned_decode(eng: ServeEngine, sparse_bad: dict) -> None:
    """Swap the engine's decode closure for one built over a corrupted
    sparse dict — runtime corruption *after* the load-time verification
    passed (the engine's own ``sparse`` stays clean, so its dense
    fallback reconstructs uncontaminated weights)."""
    cfg, temperature, impl = eng.cfg, eng.temperature, eng.impl
    eng._decode = jax.jit(_finite_step(
        lambda p, c, b: serve_step_sparse_fn(cfg, p, sparse_bad, c, b,
                                             temperature=temperature,
                                             impl=impl)))


def force_nonfinite_flag(eng: ServeEngine, slots, n_calls: int = 1):
    """Mark the given slots non-finite for the next ``n_calls`` decode
    calls (the guard-path injector for dense engines, where there is no
    sparse plane to poison)."""
    inner = eng._decode
    state = {"left": n_calls}

    def wrapped(p, c, b):
        nxt, ok, cache = inner(p, c, b)
        if state["left"] > 0:
            state["left"] -= 1
            ok = np.asarray(ok).copy()
            for s in slots:
                ok[s] = False
        return nxt, ok, cache

    eng._decode = wrapped
    return state


def arm_latency_spike(eng: ServeEngine, at_call: int, n_calls: int,
                      sleep_s: float):
    """Stall decode calls ``at_call .. at_call+n_calls-1`` by ``sleep_s``
    — the watchdog-visible stuck-decode simulation."""
    inner = eng._decode
    state = {"calls": 0}

    def wrapped(p, c, b):
        state["calls"] += 1
        if at_call <= state["calls"] < at_call + n_calls:
            time.sleep(sleep_s)
        return inner(p, c, b)

    eng._decode = wrapped
    return state


def arm_transient_errors(eng: ServeEngine, at_call: int, n_failures: int):
    """From decode call ``at_call`` on, raise ``TransientStepError`` for
    the next ``n_failures`` calls, then heal — exercises the engine's
    capped-backoff retry (each retry re-enters the wrapper and counts)."""
    inner = eng._decode
    state = {"calls": 0, "fails": 0}

    def wrapped(p, c, b):
        state["calls"] += 1
        if state["calls"] >= at_call and state["fails"] < n_failures:
            state["fails"] += 1
            raise TransientStepError(
                f"injected transient failure #{state['fails']}")
        return inner(p, c, b)

    eng._decode = wrapped
    return state


# ------------------------------------------------------------------- drill
def _drill_requests(cfg, rng, n_requests: int, max_new_tokens: int):
    return [Request(rid=r,
                    prompt=[int(t) for t in rng.integers(
                        1, cfg.vocab_size, 5 + int(rng.integers(4)))],
                    max_new_tokens=max_new_tokens)
            for r in range(n_requests)]


def _drain(eng: ServeEngine, reqs, on_step=None, max_steps: int = 4000):
    for r in reqs:
        eng.submit(r)
    steps = 0
    while steps < max_steps and (eng.scheduler.has_pending
                                 or any(s is not None for s in eng.slots)):
        eng.step()
        steps += 1
        if on_step is not None:
            on_step(eng, steps)
    return steps


def run_fault_drill(cfg, params, sparse: dict, sparse_alt: dict | None = None,
                    seed: int = 0, kinds=None, *, impl: str = "ref",
                    batch_slots: int = 2, max_len: int = 64,
                    block_size: int = 8, prefill_chunk: int = 8,
                    n_requests: int = 4, max_new_tokens: int = 8,
                    tracer=None) -> dict:
    """One engine per fault class against a shared no-fault baseline.

    ``sparse`` must be an fp pack dict (``sparsify_model``); pass a
    quantized dict as ``sparse_alt`` to aim the value-plane bit flip at
    the narrow codes instead of fp values.  Greedy decode is
    batching-independent, so per-request outputs are comparable
    bit-for-bit across engines — "unaffected slots identical to the
    no-fault run" is an exact assertion, not a tolerance.

    ``tracer`` (a telemetry ``Tracer``) is threaded into every drill
    engine, so a traced drill's export carries the quarantine / retry /
    watchdog instants next to the step spans that absorbed them.
    """
    kinds = tuple(kinds) if kinds is not None else FAULT_KINDS
    rng = np.random.default_rng(seed)
    reqs = _drill_requests(cfg, rng, n_requests, max_new_tokens)
    prompts = {r.rid: list(r.prompt) for r in reqs}

    def _fresh_reqs():
        return [Request(rid=rid, prompt=list(p),
                        max_new_tokens=max_new_tokens)
                for rid, p in prompts.items()]

    def _mk_engine(sparse_arg, **kw):
        return ServeEngine(
            cfg, params, batch_slots, max_len, sparse=sparse_arg, impl=impl,
            block_size=block_size, prefill_chunk=prefill_chunk,
            validate_arena=True, tracer=tracer,
            watchdog=LatencyWatchdog(threshold=3.0, patience=2,
                                     min_samples=4), **kw)

    # ---- no-fault baseline ---------------------------------------------
    base_reqs = _fresh_reqs()
    eng = _mk_engine(sparse)
    t0 = time.monotonic()
    _drain(eng, base_reqs)
    base_wall = time.monotonic() - t0
    baseline = {r.rid: list(r.output) for r in base_reqs}
    out = {"seed": seed,
           "scale": {"batch_slots": batch_slots, "max_len": max_len,
                     "block_size": block_size, "n_requests": n_requests,
                     "max_new_tokens": max_new_tokens},
           "baseline": {
               "goodput_tok_s": eng.stats.tokens_generated / max(base_wall,
                                                                 1e-9),
               "tokens": eng.stats.tokens_generated,
               "wall_s": base_wall},
           "faults": {}}

    for kind in kinds:
        out["faults"][kind] = _drill_one(
            kind, _mk_engine, _fresh_reqs, baseline, sparse, sparse_alt,
            np.random.default_rng(seed + 1))
    return out


def _drill_one(kind, _mk_engine, _fresh_reqs, baseline, sparse, sparse_alt,
               rng) -> dict:
    res = {"rejected_at_load": False}

    if kind in LOAD_FAULTS:
        if kind == "index_bitflip":
            bad = corrupt_group_plane(sparse, "index", rng)
        elif kind == "value_bitflip":
            bad = corrupt_group_plane(sparse_alt or sparse, "value", rng)
        else:
            bad = mismatch_schedule(sparse)
        try:
            _mk_engine(bad)
        except PackIntegrityError as e:
            res["rejected_at_load"] = True
            res["error"] = str(e)[:200]
        return res

    reqs = _fresh_reqs()
    kw = {"max_retries": 3} if kind == "transient_step_error" else {}
    eng = _mk_engine(sparse, **kw)
    affected: set = set()
    t_fault = [None]

    def _mark(now=None):
        if t_fault[0] is None:
            t_fault[0] = time.monotonic()

    if kind == "latency_spike":
        arm_latency_spike(eng, at_call=10, n_calls=4, sleep_s=0.25)
    elif kind == "transient_step_error":
        arm_transient_errors(eng, at_call=6, n_failures=2)

    def on_step(e, step):
        if kind == "nonfinite_logits" and step == 6 and t_fault[0] is None:
            _mark()
            inject_poisoned_decode(e, poison_values(sparse, rng))
        elif kind == "abort_mid_decode" and step == 4 and t_fault[0] is None:
            occupied = [s for s in e.slots if s is not None]
            if occupied:
                _mark()
                affected.add(occupied[0].req.rid)
                e.cancel(occupied[0].req.rid)
        elif kind == "arena_oom":
            if step == 2 and t_fault[0] is None:
                _mark()
                e.cache.quarantine_blocks(e.cache.free_blocks // 2)
            elif step == 12:
                e.cache.release_quarantined()

    t0 = time.monotonic()
    _drain(eng, reqs, on_step=on_step)
    wall = time.monotonic() - t0
    eng.cache.release_quarantined()   # idempotent; guards early drains
    eng.check_arena()

    st = eng.stats
    parity = all(
        (r.output == baseline[r.rid])
        for r in reqs if r.rid not in affected)
    states = st.latency_summary()["states"]
    res.update({
        "affected_rids": sorted(affected),
        "states": states,
        "tokens": st.tokens_generated,
        "degraded_tokens": st.degraded_tokens,
        "degraded_token_fraction":
            st.degraded_tokens / max(1, st.tokens_generated),
        "quarantines": st.quarantines,
        "retries": st.retries,
        "watchdog_flags": st.watchdog_flags,
        "leaked_blocks": eng.cache.num_blocks - eng.cache.free_blocks,
        "unaffected_parity": bool(parity),
        "goodput_tok_s": st.tokens_generated / max(wall, 1e-9),
        "recovery_s": (None if t_fault[0] is None
                       else time.monotonic() - t_fault[0]),
        "wall_s": wall,
    })
    return res


def run_crash_drill(cfg, params, sparse: dict | None = None, seed: int = 0,
                    *, impl: str = "ref", batch_slots: int = 2,
                    max_len: int = 64, block_size: int = 8,
                    prefill_chunk: int = 8, n_requests: int = 4,
                    max_new_tokens: int = 8, kill_step: int | None = None,
                    tracer=None) -> dict:
    """Crash-consistency drill (DESIGN.md §13): run a trace to completion
    for a baseline, then run a second engine and *kill it* at an
    arbitrary step boundary — snapshot, discard the engine, restore the
    snapshot into a fresh engine and drain.  The contract: every request
    finishes with greedy output bit-identical to the uninterrupted run,
    and the restored engine leaks zero blocks.  The snapshot round-trips
    through its JSON text form, so what is asserted is what a crash
    handler would actually write to disk."""
    from repro.serve import snapshot as snapmod

    rng = np.random.default_rng(seed)
    reqs = _drill_requests(cfg, rng, n_requests, max_new_tokens)
    prompts = {r.rid: list(r.prompt) for r in reqs}

    def _fresh_reqs():
        return [Request(rid=rid, prompt=list(p),
                        max_new_tokens=max_new_tokens)
                for rid, p in prompts.items()]

    def _mk_engine():
        return ServeEngine(
            cfg, params, batch_slots, max_len, sparse=sparse, impl=impl,
            block_size=block_size, prefill_chunk=prefill_chunk,
            validate_arena=True, tracer=tracer)

    # ---- uninterrupted baseline ----------------------------------------
    base_reqs = _fresh_reqs()
    eng = _mk_engine()
    total_steps = _drain(eng, base_reqs)
    baseline = {r.rid: list(r.output) for r in base_reqs}

    # ---- the run that dies ---------------------------------------------
    if kill_step is None:
        kill_step = int(rng.integers(1, max(2, total_steps)))
    victim_reqs = _fresh_reqs()
    eng = _mk_engine()
    for r in victim_reqs:
        eng.submit(r)
    for _ in range(kill_step):
        if (not eng.scheduler.has_pending
                and all(s is None for s in eng.slots)):
            break
        eng.step()
    snap_text = snapmod.dumps(eng.snapshot())
    in_flight = sum(1 for r in victim_reqs if not r.done)
    # the crash is exactly when a post-mortem needs the flight ring: dump
    # it (when the process recorder opted into autodump) before the
    # engine object disappears
    eng.flight.record("fault", "crash_drill",
                      {"kill_step": kill_step, "in_flight": in_flight})
    flight_dump = eng.flight.trip("crash_drill", registry=eng.metrics)
    del eng                                 # the "crash": engine is gone

    # ---- restore into a fresh engine and drain -------------------------
    t0 = time.monotonic()
    eng2 = _mk_engine()
    snap = snapmod.loads(snap_text)
    restored = eng2.restore(snap, {r.rid: r for r in victim_reqs})
    toks_at_restore = eng2.stats.tokens_generated
    t_first_new = [None]

    def on_step(e, step):
        if (t_first_new[0] is None
                and e.stats.tokens_generated > toks_at_restore):
            t_first_new[0] = time.monotonic() - t0

    _drain(eng2, [], on_step=on_step)
    recovery_s = time.monotonic() - t0
    eng2.check_arena()

    parity = {r.rid: r.output == baseline[r.rid] for r in victim_reqs}
    return {
        "seed": seed,
        "kill_step": kill_step,
        "total_steps": total_steps,
        "snapshot_bytes": len(snap_text),
        "in_flight_at_kill": in_flight,
        "restored_requests": len(restored),
        "parity": parity,
        "exact_parity": all(parity.values()),
        "leaked_blocks": eng2.cache.num_blocks - eng2.cache.free_blocks,
        "first_new_token_s": t_first_new[0],
        "recovery_s": recovery_s,
        "states": eng2.stats.latency_summary()["states"],
        "flight_dump": flight_dump,
    }


def check_crash_drill(drill: dict) -> None:
    """Assert the crash-drill contract: bit-exact parity with the
    uninterrupted run for every request, zero leaked blocks."""
    ctx = (f"crash drill (kill_step={drill['kill_step']}/"
           f"{drill['total_steps']}): {drill['parity']}")
    assert drill["exact_parity"], f"{ctx} — restored output diverged"
    assert drill["leaked_blocks"] == 0, f"{ctx} — leaked paged blocks"
    assert drill["restored_requests"] == drill["in_flight_at_kill"], \
        f"{ctx} — snapshot lost or duplicated in-flight requests"


def run_overload_drill(cfg, params, sparse: dict | None = None,
                       seed: int = 0, *, impl: str = "ref",
                       batch_slots: int = 2, max_len: int = 64,
                       block_size: int = 8, prefill_chunk: int = 8,
                       n_requests: int = 16, factor: float = 2.0,
                       max_queue_depth: int = 3,
                       shed_policy: str = "shed-largest",
                       ttft_slo_s: float = 2.0, num_blocks: int | None = None,
                       tracer=None, max_steps: int = 6000) -> dict:
    """Poisson overload burst at ``factor``x the engine's service rate.

    Arrivals are drawn per *step* from a seeded Poisson process (so the
    shed/preempt decision sequence is reproducible — only wall-clock
    latency varies run to run).  The request mix is bimodal: long
    generations that occupy the tight arena next to short ones that
    arrive blocked, which is exactly the shape where preempt-to-recompute
    pays off.  Reports goodput-under-SLO (tokens from requests whose
    TTFT met ``ttft_slo_s``, per wall second), shed/preempt counts, and
    the terminal-state census.  The contract (``check_overload_drill``):
    overload is absorbed by *policy* — shed and/or preempt — with zero
    failed requests, zero leaked blocks and no OOM."""
    rng = np.random.default_rng(seed)
    # bimodal mix: heavy generations + short ones (rids interleaved)
    reqs = []
    for r in range(n_requests):
        if r % 2 == 0:
            mnew = 12 + int(rng.integers(5))        # long: 12-16 new
            plen = 6 + int(rng.integers(4))
        else:
            mnew = 3 + int(rng.integers(3))         # short: 3-5 new
            plen = 4 + int(rng.integers(3))
        reqs.append(Request(
            rid=r, max_new_tokens=mnew,
            prompt=[int(t) for t in rng.integers(1, cfg.vocab_size, plen)]))
    mean_steps = float(np.mean(
        [len(r.prompt) / prefill_chunk + r.max_new_tokens for r in reqs]))
    lam = factor * batch_slots / mean_steps     # requests per engine step
    if num_blocks is None:
        # arena sized so one long resident starves a short arrival (a
        # blocked short next to a long-remaining resident is the shape
        # preempt-to-recompute exists for), while still admitting every
        # request on its own
        worst = max(r.worst_case_tokens(max_len) for r in reqs)
        num_blocks = (worst + block_size - 1) // block_size + 1
    eng = ServeEngine(
        cfg, params, batch_slots, max_len, sparse=sparse, impl=impl,
        block_size=block_size, num_blocks=num_blocks,
        prefill_chunk=prefill_chunk, validate_arena=True, tracer=tracer,
        max_queue_depth=max_queue_depth, shed_policy=shed_policy,
        preempt=True, watermark_high=0.97)

    submitted = 0
    max_queue = 0
    t0 = time.monotonic()
    steps = 0
    while steps < max_steps:
        if submitted < n_requests:
            for _ in range(int(rng.poisson(lam))):
                if submitted >= n_requests:
                    break
                eng.submit(reqs[submitted])
                submitted += 1
        elif (not eng.scheduler.has_pending
                and all(s is None for s in eng.slots)):
            break
        eng.step()
        steps += 1
        max_queue = max(max_queue, eng.scheduler.queue_depth)
    wall = time.monotonic() - t0
    eng.check_arena()

    st = eng.stats
    states = st.latency_summary()["states"]
    good_tokens = sum(
        m.n_out for m in eng.scheduler.completed
        if m.state in ("completed", "degraded")
        and m.ttft is not None and m.ttft <= ttft_slo_s)
    return {
        "seed": seed,
        "factor": factor,
        "shed_policy": shed_policy,
        "scale": {"batch_slots": batch_slots, "num_blocks": num_blocks,
                  "max_queue_depth": max_queue_depth,
                  "n_requests": n_requests, "lambda_per_step": lam},
        "steps": steps,
        "wall_s": wall,
        "states": states,
        "tokens": st.tokens_generated,
        "sheds": st.requests_shed,
        "preempts": st.preempts,
        "max_queue_depth_seen": max_queue,
        "goodput_tokens_under_slo": good_tokens,
        "goodput_tok_s_under_slo": good_tokens / max(wall, 1e-9),
        "leaked_blocks": eng.cache.num_blocks - eng.cache.free_blocks,
        "drained": steps < max_steps,
    }


def check_overload_drill(drill: dict) -> None:
    """Assert the overload contract: the burst is absorbed by policy
    (shedding and/or preemption engaged), nothing fails or leaks, and
    the engine drains — overload degrades goodput, never correctness."""
    ctx = f"overload drill: {drill}"
    assert drill["drained"], f"{ctx} — engine never drained (livelock?)"
    assert drill["leaked_blocks"] == 0, f"{ctx} — leaked paged blocks"
    assert drill["states"].get("failed", 0) == 0, f"{ctx} — requests failed"
    assert drill["sheds"] + drill["preempts"] >= 1, \
        f"{ctx} — 2x overload absorbed without any policy action"
    served = (drill["states"].get("completed", 0)
              + drill["states"].get("degraded", 0))
    assert served >= 1, f"{ctx} — nothing completed under overload"


def check_drill(drill: dict) -> None:
    """Assert the fault-drill contract: every load fault rejected at
    construction; every runtime fault drains with zero leaked blocks,
    bit-identical unaffected slots and the expected counters — a failed
    assertion here means a fault class could have produced a silent
    wrong token or a resource leak."""
    for kind, r in drill["faults"].items():
        ctx = f"fault drill {kind!r}: {r}"
        if kind in LOAD_FAULTS:
            assert r["rejected_at_load"], f"{ctx} — corruption not rejected"
            continue
        assert r["leaked_blocks"] == 0, f"{ctx} — leaked paged blocks"
        assert r["unaffected_parity"], \
            f"{ctx} — unaffected slot diverged from the no-fault run"
        states = r["states"]
        if kind == "nonfinite_logits":
            assert r["quarantines"] >= 1, f"{ctx} — guard never tripped"
            assert r["degraded_tokens"] >= 1, \
                f"{ctx} — no dense-fallback tokens"
            assert states.get("failed", 0) == 0, f"{ctx} — slots failed"
        elif kind == "abort_mid_decode":
            assert states.get("cancelled", 0) >= 1, f"{ctx} — no cancel"
        elif kind == "arena_oom":
            assert states.get("failed", 0) == 0, f"{ctx} — slots failed"
        elif kind == "latency_spike":
            assert r["watchdog_flags"] >= 1, f"{ctx} — watchdog silent"
        elif kind == "transient_step_error":
            assert r["retries"] >= 1, f"{ctx} — retry path never ran"
            assert states.get("failed", 0) == 0, f"{ctx} — retry exhausted"
