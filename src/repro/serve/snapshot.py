"""Crash-consistent engine snapshot/restore (DESIGN.md §13).

ESPIM's sparsity plan is static and verified before inference, so every
per-request serving state is a pure function of (model pack, prompt,
committed tokens).  A snapshot therefore saves only the *control plane*
— scheduler queue, per-request committed token history, slot residency,
block-table shape — and none of the KV planes: on restore each request
re-enters the queue and the engine recomputes its KV history through the
ordinary resume path (re-prefill of prompt + committed tokens), emitting
remaining greedy tokens bit-for-bit identical to a never-interrupted
run.  That keeps snapshots a few KB regardless of arena size, and makes
restore trivially crash-consistent: there is no moment where half a KV
plane is on disk.

Format: a plain JSON-ready dict —

    {"version": 1,
     "model": cfg.name, "max_len": ..., "temperature": ...,
     "pack_fingerprint": <model-level pack digest or "dense">,
     "rng_key": [..],                     # engine PRNG key words
     "geometry": {slots, block_size, num_blocks},
     "requests": [{rid, prompt, output, max_new_tokens, eos_id,
                   deadline_s, ttft_deadline_s, origin, slot,
                   preempts}, ...],       # slot residents first, then
                                          # wait queue in queue order
     "stats": {tokens_generated, preempts, requests_shed},   # info only
     "digest": sha256(canonical JSON of everything above)}

Two bindings gate a restore: the ``digest`` (bit-rot / truncation of the
snapshot itself) and the ``pack_fingerprint`` (the snapshot must be
restored against the *same* verified pack — restoring a token history
onto different weights would silently complete requests with the wrong
model).  Both raise ``SnapshotIntegrityError`` (a ``PackIntegrityError``
subclass, so existing fault handling catches it).

Snapshots are taken at step boundaries (``ServeEngine.snapshot()``
between ``step()`` calls); a snapshot mid-step would be torn by
definition.  The crash drill in ``serve/faults.py`` exercises the whole
loop: kill at an arbitrary step, restore, assert exact output parity and
zero leaked blocks.
"""
from __future__ import annotations

import hashlib
import json
import time

from repro.core.integrity import PackIntegrityError
from repro.serve.scheduler import RequestMetrics

__all__ = ["SNAPSHOT_VERSION", "SnapshotIntegrityError", "snapshot_engine",
           "restore_engine", "snapshot_digest", "validate_snapshot",
           "dumps", "loads"]

SNAPSHOT_VERSION = 1


class SnapshotIntegrityError(PackIntegrityError):
    """A snapshot failed digest verification, version, or pack binding."""


def _engine_fingerprint(eng) -> str:
    """The model identity a snapshot binds to: the model-level pack
    digest for a sparse engine, a named dense marker otherwise."""
    if eng.sparse is not None and "fingerprint" in eng.sparse:
        return str(eng.sparse["fingerprint"])
    return f"dense:{eng.cfg.name}"


def snapshot_digest(doc: dict) -> str:
    """sha256 over the canonical JSON of everything except ``digest``."""
    body = {k: v for k, v in doc.items() if k != "digest"}
    return hashlib.sha256(
        json.dumps(body, sort_keys=True, default=str).encode()).hexdigest()


def _request_entry(req, m: RequestMetrics, origin: str,
                   slot: int | None) -> dict:
    return {
        "rid": int(req.rid),
        "prompt": [int(t) for t in req.prompt],
        "output": [int(t) for t in req.output],
        "max_new_tokens": int(req.max_new_tokens),
        "eos_id": int(req.eos_id),
        "deadline_s": req.deadline_s,
        "ttft_deadline_s": req.ttft_deadline_s,
        "origin": origin,              # "slot" | "queue"
        "slot": slot,
        "preempts": int(m.preempts),
    }


def snapshot_engine(eng) -> dict:
    """Serialize the engine's control plane at a step boundary.  KV
    planes are deliberately NOT captured — they are recomputed on
    restore from each request's committed history."""
    import numpy as np
    requests = []
    for i, st in enumerate(eng.slots):
        if st is None:
            continue
        requests.append(_request_entry(st.req, st.metrics, "slot", i))
    for req, m in eng.scheduler.pending:
        requests.append(_request_entry(req, m, "queue", None))
    doc = {
        "version": SNAPSHOT_VERSION,
        "model": eng.cfg.name,
        "max_len": int(eng.max_len),
        "temperature": float(eng.temperature),
        "pack_fingerprint": _engine_fingerprint(eng),
        "rng_key": [int(w) for w in np.asarray(eng._key).ravel()],
        "geometry": {
            "slots": int(eng.b),
            "block_size": int(getattr(eng.cache, "block_size", 0)),
            "num_blocks": int(getattr(eng.cache, "num_blocks", 0)),
        },
        # per-slot block counts at capture time: restore recomputes KV,
        # so these are recorded for observability/validation only
        "block_tables": {
            str(i): int(eng.cache.n_blocks[i])
            for i in range(eng.b)
            if getattr(eng.cache, "n_blocks", None) is not None
            and int(eng.cache.n_blocks[i])
        } if eng.paged else {},
        "requests": requests,
        "stats": {
            "tokens_generated": int(eng.stats.tokens_generated),
            "preempts": int(eng.stats.preempts),
            "requests_shed": int(eng.stats.requests_shed),
        },
    }
    doc["digest"] = snapshot_digest(doc)
    return doc


def dumps(snap: dict) -> str:
    return json.dumps(snap, sort_keys=True)


def loads(text: str) -> dict:
    snap = json.loads(text)
    validate_snapshot(snap)
    return snap


def validate_snapshot(snap: dict) -> None:
    """Structural + digest validation (no engine needed)."""
    if not isinstance(snap, dict) or "version" not in snap:
        raise SnapshotIntegrityError("not an engine snapshot")
    if snap["version"] != SNAPSHOT_VERSION:
        raise SnapshotIntegrityError(
            f"snapshot version {snap['version']} not supported "
            f"(expected {SNAPSHOT_VERSION})")
    want = snap.get("digest")
    got = snapshot_digest(snap)
    if want != got:
        raise SnapshotIntegrityError(
            f"snapshot digest mismatch: recorded {want!r}, "
            f"recomputed {got!r} — truncated or bit-rotted snapshot")


def restore_engine(eng, snap: dict, requests: dict | None = None) -> list:
    """Re-admit every request from ``snap`` into a fresh engine.

    The engine must be idle (no resident slots, empty queue) and must be
    serving the same pack (fingerprint-bound) with the same ``max_len``
    (the max-length stop condition is part of greedy parity).  Requests
    re-enter the wait queue in snapshot order — slot residents first —
    bypassing the shed policy (restored work is not new load); any
    request with committed output is shielded from future shedding the
    same way preempted requests are, and resumes through the engine's
    recompute path.  ``requests`` optionally maps rid -> caller-held
    ``Request`` objects to reattach (so a driver's handles keep
    receiving tokens); otherwise fresh Request objects are built.
    Returns the restored Request list in admission order.
    """
    from repro.serve.engine import Request

    validate_snapshot(snap)
    fp = _engine_fingerprint(eng)
    if snap["pack_fingerprint"] != fp:
        raise SnapshotIntegrityError(
            f"snapshot is bound to pack {snap['pack_fingerprint'][:16]}…, "
            f"engine is serving {fp[:16]}… — refusing to resume a token "
            f"history onto different weights")
    if snap["model"] != eng.cfg.name:
        raise SnapshotIntegrityError(
            f"snapshot from model {snap['model']!r}, engine is "
            f"{eng.cfg.name!r}")
    if int(snap["max_len"]) != int(eng.max_len):
        raise SnapshotIntegrityError(
            f"snapshot max_len {snap['max_len']} != engine "
            f"{eng.max_len} — the length stop is part of greedy parity")
    if any(s is not None for s in eng.slots) or eng.scheduler.has_pending:
        raise RuntimeError("restore() needs an idle engine: drain or "
                           "build a fresh one first")

    import jax.numpy as jnp
    key = snap.get("rng_key")
    if key:
        eng._key = jnp.asarray(key, dtype=jnp.uint32)

    restored = []
    now = time.monotonic()
    for entry in snap["requests"]:
        rid = entry["rid"]
        req = (requests or {}).get(rid)
        if req is None:
            req = Request(rid=rid, prompt=list(entry["prompt"]),
                          max_new_tokens=entry["max_new_tokens"],
                          eos_id=entry["eos_id"],
                          deadline_s=entry["deadline_s"],
                          ttft_deadline_s=entry["ttft_deadline_s"])
        elif list(req.prompt) != list(entry["prompt"]):
            raise SnapshotIntegrityError(
                f"reattached request {rid} prompt differs from snapshot")
        req.output = list(entry["output"])
        req.done = False
        m = RequestMetrics(rid=rid, prompt_len=len(req.prompt),
                           t_submit=now)
        m.preempts = entry["preempts"]
        if req.output and m.preempts == 0:
            m.preempts = 1      # committed tokens: never sheddable
        # deliberate pending.append, not scheduler.add(): restore
        # bypasses the bounded-queue shed policy — so it emits its own
        # queued mark to keep every lifecycle reconstructable
        eng.scheduler._mark("req.queued", {"rid": rid,
                                           "prompt_len": m.prompt_len,
                                           "restored": True})
        eng.scheduler.pending.append((req, m))
        eng.stats.restored_requests += 1
        eng._c_restores.inc()
        info = {"rid": rid, "committed": len(req.output),
                "origin": entry["origin"]}
        eng.tracer.instant("fault.restore", cat="fault", args=info)
        eng.flight.record("fault", "fault.restore", info)
        restored.append(req)
    eng._g_queue_depth.set(eng.scheduler.queue_depth)
    return restored
