"""Latency-aware request scheduling for the serving engine.

SparseP's lesson — static, balance-aware assignment of sparse work onto
fixed execution units — maps onto serving: requests of wildly different
prompt/output lengths must be assigned to a fixed set of decode slots
without letting one long prompt monopolize the engine.  The scheduler
owns three decisions:

* **admission** — which pending request takes a freed slot.  ``fcfs``
  (arrival order) or ``sjf`` (shortest-prompt-first, which minimizes mean
  TTFT under load, at the cost of tail latency for long prompts).
  Admission is gated on the paged cache's worst-case block reservation,
  so an admitted request can never deadlock the arena mid-flight.
* **prefill/decode interleave** — each engine tick is either one prefill
  chunk (for one slot) or one batched decode step (for every decode-ready
  slot).  At most ``max_prefill_streak`` consecutive prefill ticks run
  while any slot is decode-ready, so decode (TPOT) is never starved by a
  long prompt; with no decode-ready slots, prefill runs back-to-back.
* **metrics** — per-request queue delay, TTFT (submit -> first generated
  token) and TPOT (mean inter-token time after the first), aggregated
  into p50/p95 summaries for the engine's ``EngineStats``.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

__all__ = ["RequestMetrics", "Scheduler", "percentiles",
           "latency_summary", "TERMINAL_STATES"]

POLICIES = ("fcfs", "sjf")

# every request ends in exactly one of these (the robustness contract:
# "fast" and "fast because we dropped it" are different states):
#   completed        — full output, healthy datapath throughout
#   degraded         — full output, but some tokens came from the dense
#                      fallback after a quarantine (still greedy-correct)
#   cancelled        — torn down by an explicit cancel()
#   deadline_expired — torn down by a TTFT / wall-clock deadline
#   failed           — torn down because no datapath could produce finite
#                      logits (or retries exhausted)
TERMINAL_STATES = ("completed", "degraded", "cancelled",
                   "deadline_expired", "failed")


@dataclasses.dataclass
class RequestMetrics:
    rid: int
    prompt_len: int
    t_submit: float
    t_admit: float | None = None
    t_first: float | None = None
    t_done: float | None = None
    n_out: int = 0
    state: str = "in_flight"

    @property
    def queue_delay(self) -> float | None:
        if self.t_admit is None:
            return None
        return self.t_admit - self.t_submit

    @property
    def ttft(self) -> float | None:
        if self.t_first is None:
            return None
        return self.t_first - self.t_submit

    @property
    def tpot(self) -> float | None:
        """Mean time-per-output-token after the first."""
        if self.t_done is None or self.t_first is None or self.n_out < 2:
            return None
        return (self.t_done - self.t_first) / (self.n_out - 1)


def percentiles(xs, qs=(50, 95)) -> dict:
    xs = [x for x in xs if x is not None]
    if not xs:
        return {f"p{q}": None for q in qs}
    return {f"p{q}": float(np.percentile(np.asarray(xs), q)) for q in qs}


def latency_summary(done: list[RequestMetrics]) -> dict:
    """p50/p95 report over finished requests (shared by the scheduler's
    summary and the engine's EngineStats).  ``states`` counts the
    terminal state of every finished request, so the latency percentiles
    can never silently mix dropped requests into "fast"."""
    states: dict = {}
    for m in done:
        states[m.state] = states.get(m.state, 0) + 1
    return {
        "requests": len(done),
        "ttft_s": percentiles([m.ttft for m in done]),
        "tpot_s": percentiles([m.tpot for m in done]),
        "queue_delay_s": percentiles([m.queue_delay for m in done]),
        "states": states,
    }


class Scheduler:
    def __init__(self, policy: str = "fcfs", max_prefill_streak: int = 2):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; use {POLICIES}")
        self.policy = policy
        self.max_prefill_streak = max(1, max_prefill_streak)
        self.pending: list = []       # [(request, RequestMetrics)]
        self.completed: list[RequestMetrics] = []
        self._streak = 0

    # ----------------------------------------------------------- admission
    def add(self, request) -> RequestMetrics:
        m = RequestMetrics(rid=request.rid, prompt_len=len(request.prompt),
                           t_submit=time.monotonic())
        self.pending.append((request, m))
        return m

    @property
    def has_pending(self) -> bool:
        return bool(self.pending)

    def pick(self, can_admit) -> tuple | None:
        """Choose the next request for a free slot per policy; ``can_admit``
        (request -> bool) is the cache's reservation gate.  FCFS respects
        head-of-line order (a blocked head blocks the queue — its
        reservation will succeed as slots drain); SJF scans by prompt
        length."""
        if not self.pending:
            return None
        if self.policy == "sjf":
            order = sorted(range(len(self.pending)),
                           key=lambda i: (len(self.pending[i][0].prompt), i))
        else:
            order = range(len(self.pending))
        for i in order:
            req, m = self.pending[i]
            if can_admit(req):
                self.pending.pop(i)
                m.t_admit = time.monotonic()
                return req, m
            if self.policy == "fcfs":
                return None     # head-of-line blocking by design
        return None

    # ---------------------------------------------------------- interleave
    def next_action(self, prefilling: list[int],
                    decoding: list[int]) -> tuple[str, int | None]:
        """One engine tick: ('prefill', slot) | ('decode', None) |
        ('idle', None).  Decode is forced after ``max_prefill_streak``
        consecutive prefill ticks whenever any slot is decode-ready."""
        if not prefilling and not decoding:
            return "idle", None
        if prefilling and (not decoding
                           or self._streak < self.max_prefill_streak):
            self._streak += 1
            return "prefill", prefilling[0]
        self._streak = 0
        return "decode", None

    # ------------------------------------------------------------- metrics
    def finish(self, metrics: RequestMetrics,
               state: str = "completed") -> None:
        if state not in TERMINAL_STATES:
            raise ValueError(f"unknown terminal state {state!r}; "
                             f"use {TERMINAL_STATES}")
        metrics.t_done = time.monotonic()
        metrics.state = state
        self.completed.append(metrics)

    def cancel_pending(self, rid: int) -> bool:
        """Cancel a not-yet-admitted request; returns True if found."""
        for i, (req, m) in enumerate(self.pending):
            if req.rid == rid:
                self.pending.pop(i)
                req.done = True
                self.finish(m, "cancelled")
                return True
        return False

    def expire_pending(self, now: float) -> list:
        """Retire queued requests whose deadline passed while waiting for
        admission; returns their rids."""
        out = []
        keep = []
        for req, m in self.pending:
            dl = getattr(req, "deadline_s", None)
            tdl = getattr(req, "ttft_deadline_s", None)
            limit = min(x for x in (dl, tdl, float("inf")) if x is not None)
            if now - m.t_submit > limit:
                req.done = True
                self.finish(m, "deadline_expired")
                out.append(req.rid)
            else:
                keep.append((req, m))
        self.pending = keep
        return out

    def summary(self) -> dict:
        return latency_summary(self.completed)
