"""Latency-aware request scheduling for the serving engine.

SparseP's lesson — static, balance-aware assignment of sparse work onto
fixed execution units — maps onto serving: requests of wildly different
prompt/output lengths must be assigned to a fixed set of decode slots
without letting one long prompt monopolize the engine.  The scheduler
owns three decisions:

* **admission** — which pending request takes a freed slot.  ``fcfs``
  (arrival order) or ``sjf`` (shortest-prompt-first, which minimizes mean
  TTFT under load, at the cost of tail latency for long prompts).
  Admission is gated on the paged cache's worst-case block reservation,
  so an admitted request can never deadlock the arena mid-flight.
* **overload policy** (DESIGN.md §13) — the wait queue is bounded
  (``max_queue_depth``); a submit past the bound is resolved by
  ``shed_policy``: ``reject`` (refuse the newcomer), ``shed-oldest``
  (drop the longest-waiting queued request) or ``shed-largest`` (drop
  whichever of queue+newcomer has the largest worst-case token
  footprint).  Shed requests end in the ``shed`` terminal state — "we
  dropped it under load" is never reported as latency.  Under arena
  pressure the scheduler also nominates a **preemption** victim
  (longest-remaining generation first): the engine releases the victim's
  KV blocks and ``requeue``-s it; because ESPIM's sparsity plan is
  static, the victim resumes later by re-prefilling its prompt +
  committed tokens and its remaining greedy tokens are bit-identical to
  a never-preempted run.
* **prefill/decode interleave** — each engine tick is either one prefill
  chunk (for one slot) or one batched decode step (for every decode-ready
  slot).  At most ``max_prefill_streak`` consecutive prefill ticks run
  while any slot is decode-ready, so decode (TPOT) is never starved by a
  long prompt; with no decode-ready slots, prefill runs back-to-back.
* **metrics** — per-request queue delay, TTFT (submit -> first generated
  token) and TPOT (mean inter-token time after the first), aggregated
  into p50/p95 summaries for the engine's ``EngineStats``.

Latency percentiles are served from the telemetry histograms' streaming
quantile estimate: ``finish`` observes each request's TTFT/TPOT/queue
delay into fixed log-bucket histograms once, and ``summary`` reads
p50/p95 in O(buckets) — the pre-PR 7 path re-sorted every sample on
every ``latency_summary()`` call, O(n log n) per report tick.  The
module-level ``percentiles``/``latency_summary(done)`` helpers keep the
exact-sort semantics for ad-hoc lists.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.telemetry import flightrec
from repro.telemetry.metrics import Histogram, Registry
from repro.telemetry.trace import NULL_TRACER

__all__ = ["RequestMetrics", "Scheduler", "percentiles",
           "latency_summary", "TERMINAL_STATES", "SHED_POLICIES"]

POLICIES = ("fcfs", "sjf")
SHED_POLICIES = ("reject", "shed-oldest", "shed-largest")

# every request ends in exactly one of these (the robustness contract:
# "fast" and "fast because we dropped it" are different states):
#   completed        — full output, healthy datapath throughout
#   degraded         — full output, but some tokens came from the dense
#                      fallback after a quarantine (still greedy-correct)
#   cancelled        — torn down by an explicit cancel()
#   deadline_expired — torn down by a TTFT / wall-clock deadline
#   failed           — torn down because no datapath could produce finite
#                      logits (or retries exhausted)
#   shed             — dropped by overload admission control before (or
#                      instead of) ever running (bounded wait queue)
TERMINAL_STATES = ("completed", "degraded", "cancelled",
                   "deadline_expired", "failed", "shed")


@dataclasses.dataclass
class RequestMetrics:
    rid: int
    prompt_len: int
    t_submit: float
    t_admit: float | None = None
    t_first: float | None = None
    t_done: float | None = None
    n_out: int = 0
    state: str = "in_flight"
    preempts: int = 0       # times this request was preempted + requeued

    @property
    def queue_delay(self) -> float | None:
        if self.t_admit is None:
            return None
        return self.t_admit - self.t_submit

    @property
    def ttft(self) -> float | None:
        if self.t_first is None:
            return None
        return self.t_first - self.t_submit

    @property
    def tpot(self) -> float | None:
        """Mean time-per-output-token after the first."""
        if self.t_done is None or self.t_first is None or self.n_out < 2:
            return None
        return (self.t_done - self.t_first) / (self.n_out - 1)


def percentiles(xs, qs=(50, 95)) -> dict:
    xs = [x for x in xs if x is not None]
    if not xs:
        return {f"p{q}": None for q in qs}
    return {f"p{q}": float(np.percentile(np.asarray(xs), q)) for q in qs}


LATENCY_HISTS = ("ttft_s", "tpot_s", "queue_delay_s")
_HIST_METRIC = {"ttft_s": "serve_ttft_seconds",
                "tpot_s": "serve_tpot_seconds",
                "queue_delay_s": "serve_queue_delay_seconds"}


def latency_summary(done: list[RequestMetrics],
                    hists: dict | None = None) -> dict:
    """p50/p95 report over finished requests (shared by the scheduler's
    summary and the engine's EngineStats).  ``states`` counts the
    terminal state of every finished request, so the latency percentiles
    can never silently mix dropped requests into "fast".

    With ``hists`` (the scheduler's streaming histograms, one per
    LATENCY_HISTS key) the percentiles are the histograms' O(buckets)
    quantile estimates; without, the exact full-sort path runs — kept
    for ad-hoc metric lists, but NOT the engine report path."""
    states: dict = {}
    for m in done:
        states[m.state] = states.get(m.state, 0) + 1
    if hists is not None:
        lat = {k: hists[k].percentile_summary() for k in LATENCY_HISTS}
    else:
        lat = {
            "ttft_s": percentiles([m.ttft for m in done]),
            "tpot_s": percentiles([m.tpot for m in done]),
            "queue_delay_s": percentiles([m.queue_delay for m in done]),
        }
    return {"requests": len(done), **lat, "states": states}


class Scheduler:
    def __init__(self, policy: str = "fcfs", max_prefill_streak: int = 2,
                 metrics: Registry | None = None,
                 max_queue_depth: int | None = None,
                 shed_policy: str = "reject",
                 tracer=None, flight=None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; use {POLICIES}")
        if shed_policy not in SHED_POLICIES:
            raise ValueError(f"unknown shed_policy {shed_policy!r}; "
                             f"use {SHED_POLICIES}")
        self.policy = policy
        self.max_prefill_streak = max(1, max_prefill_streak)
        self.max_queue_depth = max_queue_depth
        self.shed_policy = shed_policy
        self.on_shed = None           # callback(request) — engine hook
        # request-scoped lifecycle marks (DESIGN.md §14) go to both the
        # opt-in tracer and the always-on flight recorder
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.flight = (flight if flight is not None
                       else flightrec.get_recorder())
        self.pending: list = []       # [(request, RequestMetrics)]
        self.completed: list[RequestMetrics] = []
        self._streak = 0
        # streaming latency histograms: observed once per finished
        # request, read in O(buckets) by every summary — registered in
        # the engine's registry when one is supplied, private otherwise
        if metrics is not None:
            self.hists = {k: metrics.histogram(_HIST_METRIC[k])
                          for k in LATENCY_HISTS}
            self._c_requests = {
                s: metrics.counter("serve_requests_total", state=s)
                for s in TERMINAL_STATES}
        else:
            self.hists = {k: Histogram(_HIST_METRIC[k], {})
                          for k in LATENCY_HISTS}
            self._c_requests = None

    def reset_metrics(self) -> None:
        """Zero the streaming latency histograms (per-repeat benches)."""
        for h in self.hists.values():
            h.reset()

    def _mark(self, name: str, args: dict) -> None:
        """One rid-keyed lifecycle mark, mirrored to tracer + flight."""
        self.tracer.instant(name, cat="request", args=args)
        self.flight.record("request", name, args)

    # ----------------------------------------------------------- admission
    @staticmethod
    def _footprint(req) -> int:
        """Worst-case token footprint — the shed-largest ordering key."""
        return len(req.prompt) + getattr(req, "max_new_tokens", 0)

    def _shed(self, req, m) -> None:
        req.done = True
        self.finish(m, "shed")
        if self.on_shed is not None:
            self.on_shed(req)

    def add(self, request) -> RequestMetrics | None:
        """Enqueue a request, or shed per ``shed_policy`` when the wait
        queue is at ``max_queue_depth``.  Returns the new request's
        metrics, or None when the newcomer itself was shed.  Preempted
        requests waiting to resume are never shed — their committed
        tokens were already delivered, so dropping them would turn a
        partial stream into a lie."""
        m = RequestMetrics(rid=request.rid, prompt_len=len(request.prompt),
                           t_submit=time.monotonic())
        # queued mark BEFORE the shed decision: even a request shed at
        # the door gets a reconstructable queued -> terminal lifecycle
        self._mark("req.queued", {"rid": request.rid,
                                  "prompt_len": m.prompt_len})
        if (self.max_queue_depth is not None
                and len(self.pending) >= self.max_queue_depth):
            sheddable = [i for i, (r, pm) in enumerate(self.pending)
                         if pm.preempts == 0]
            if self.shed_policy == "reject" or not sheddable:
                self._shed(request, m)
                return None
            if self.shed_policy == "shed-oldest":
                victim = sheddable[0]
            else:                       # shed-largest: biggest worst-case
                victim = max(sheddable,  # footprint of queue + newcomer
                             key=lambda i: self._footprint(
                                 self.pending[i][0]))
                if (self._footprint(request)
                        > self._footprint(self.pending[victim][0])):
                    self._shed(request, m)
                    return None
            vreq, vm = self.pending.pop(victim)
            self._shed(vreq, vm)
        self.pending.append((request, m))
        return m

    def requeue(self, request, m: RequestMetrics) -> None:
        """Put a preempted request back at the head of the wait queue: it
        is the oldest admitted work (FCFS order preserved; SJF re-sorts
        at pick time anyway).  Requeueing bypasses the queue bound — the
        request already held a slot, so this is not new load."""
        m.preempts += 1
        m.t_admit = None
        self._mark("req.requeue", {"rid": request.rid,
                                   "preempts": m.preempts})
        self.pending.insert(0, (request, m))

    @property
    def has_pending(self) -> bool:
        return bool(self.pending)

    @property
    def queue_depth(self) -> int:
        return len(self.pending)

    def peek(self) -> tuple | None:
        """The (request, metrics) admission would try next per policy —
        the preemption candidate when its reservation is what's blocked."""
        if not self.pending:
            return None
        if self.policy == "sjf":
            i = min(range(len(self.pending)),
                    key=lambda i: (len(self.pending[i][0].prompt), i))
            return self.pending[i]
        return self.pending[0]

    def pick(self, can_admit) -> tuple | None:
        """Choose the next request for a free slot per policy; ``can_admit``
        (request -> bool) is the cache's reservation gate.  FCFS respects
        head-of-line order (a blocked head blocks the queue — its
        reservation will succeed as slots drain); SJF scans by prompt
        length."""
        if not self.pending:
            return None
        if self.policy == "sjf":
            order = sorted(range(len(self.pending)),
                           key=lambda i: (len(self.pending[i][0].prompt), i))
        else:
            order = range(len(self.pending))
        for i in order:
            req, m = self.pending[i]
            if can_admit(req):
                self.pending.pop(i)
                m.t_admit = time.monotonic()
                return req, m
            if self.policy == "fcfs":
                return None     # head-of-line blocking by design
        return None

    # ---------------------------------------------------------- interleave
    def next_action(self, prefilling: list[int],
                    decoding: list[int]) -> tuple[str, int | None]:
        """One engine tick: ('prefill', slot) | ('decode', None) |
        ('idle', None).  Decode is forced after ``max_prefill_streak``
        consecutive prefill ticks whenever any slot is decode-ready."""
        if not prefilling and not decoding:
            return "idle", None
        if prefilling and (not decoding
                           or self._streak < self.max_prefill_streak):
            self._streak += 1
            return "prefill", prefilling[0]
        self._streak = 0
        return "decode", None

    # ------------------------------------------------------------- metrics
    def finish(self, metrics: RequestMetrics,
               state: str = "completed") -> None:
        if state not in TERMINAL_STATES:
            raise ValueError(f"unknown terminal state {state!r}; "
                             f"use {TERMINAL_STATES}")
        metrics.t_done = time.monotonic()
        metrics.state = state
        # single choke point for ALL terminal transitions (teardown,
        # shed, cancel, expire) — the timeline's terminal mark
        self._mark("req.terminal", {"rid": metrics.rid, "state": state,
                                    "n_out": metrics.n_out})
        self.completed.append(metrics)
        for key, value in (("ttft_s", metrics.ttft),
                           ("tpot_s", metrics.tpot),
                           ("queue_delay_s", metrics.queue_delay)):
            if value is not None:
                self.hists[key].observe(value)
        if self._c_requests is not None:
            self._c_requests[state].inc()

    def cancel_pending(self, rid: int) -> bool:
        """Cancel a not-yet-admitted request; returns True if found."""
        for i, (req, m) in enumerate(self.pending):
            if req.rid == rid:
                self.pending.pop(i)
                req.done = True
                self.finish(m, "cancelled")
                return True
        return False

    def expire_pending(self, now: float) -> list:
        """Retire queued requests whose deadline passed while waiting for
        admission; returns their rids."""
        out = []
        keep = []
        for req, m in self.pending:
            dl = getattr(req, "deadline_s", None)
            tdl = getattr(req, "ttft_deadline_s", None)
            limit = min(x for x in (dl, tdl, float("inf")) if x is not None)
            if now - m.t_submit > limit:
                req.done = True
                self.finish(m, "deadline_expired")
                out.append(req.rid)
            else:
                keep.append((req, m))
        self.pending = keep
        return out

    def summary(self) -> dict:
        return latency_summary(self.completed, hists=self.hists)
