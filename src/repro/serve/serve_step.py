"""The jitted serving step: one decode step + greedy/temperature sampling,
with KV-cache shardings.  ``serve_step_fn`` is what the decode-shape dry-run
cells lower (one new token against a seq_len-deep cache);
``serve_step_sparse_fn`` is the ESPIM-format variant whose MLP projections
run through the fused batched chunked-ELL kernel (the paper's deployment:
decode from the compressed format)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import sparse_model
from repro.models import factory
from repro.sharding import partition

__all__ = ["serve_step_fn", "serve_step_sparse_fn", "make_serve_step",
           "prefill_fn", "sample_tokens"]


def sample_tokens(cfg: ModelConfig, last, temperature: float, rng=None):
    """Greedy/temperature sampling over one position's logits (B, V),
    vocab padding masked.  Returns (B,) int32.

    The caller owns the key: the engine splits a fresh subkey per step
    (``batch["rng"]``), so temperature sampling draws an independent
    perturbation every tick instead of replaying PRNGKey(0) forever.
    """
    last = last.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad = cfg.padded_vocab - cfg.vocab_size
        last = jnp.concatenate(
            [last[:, : cfg.vocab_size],
             jnp.full((last.shape[0], pad), -1e30)], axis=-1)
    if temperature > 0.0:
        key = rng if rng is not None else jax.random.PRNGKey(0)
        nxt = jax.random.categorical(key, last / temperature, axis=-1)
    else:
        nxt = jnp.argmax(last, axis=-1)
    return nxt.astype(jnp.int32)


def _sample_next(cfg: ModelConfig, logits, batch: dict, temperature: float):
    """Sampling over the final position of decode logits (B, 1, V)."""
    nxt = sample_tokens(cfg, logits[:, -1, :], temperature,
                        batch.get("rng"))
    return nxt[:, None]


def serve_step_fn(cfg: ModelConfig, params, cache: dict, batch: dict,
                  temperature: float = 0.0):
    """Returns (next_tokens (B, 1), logits (B, 1, V), new_cache)."""
    logits, cache = factory.decode_step(cfg, params, cache, batch)
    return _sample_next(cfg, logits, batch, temperature), logits, cache


def serve_step_sparse_fn(cfg: ModelConfig, params, sparse: dict,
                         cache: dict, batch: dict,
                         temperature: float = 0.0, impl: str = "ref"):
    """ESPIM-format decode step: one scanned layer stack whose covered
    projections run from the width-bucketed pack groups — the fused QKV
    launch + static take, the packed O projection, the fused gate+up
    SpMV with its packed-order product, and the perm-composed down
    projection (``sparse`` from ``sparsify_model``; the
    ``sparsify_mlps`` preset keeps attention dense — DESIGN.md sections
    8/10).  When the packs were built with ``quant="int8"|"int4"`` the
    same scan consumes the quantized value planes (codes + per-row-group
    scale leaves) through the quantized kernels — section 9.

    Same contract as ``serve_step_fn``: (next_tokens, logits, new_cache).
    """
    logits, cache = sparse_model.decode_step_sparse(
        cfg, params, sparse, cache, batch, impl=impl)
    return _sample_next(cfg, logits, batch, temperature), logits, cache


def prefill_fn(cfg: ModelConfig, params, batch: dict):
    """Full-sequence forward (the prefill-shape cells lower this).  The
    serving TTFT path instead jits ``factory.prefill_chunk`` directly —
    see ``serve/prefill.ChunkedPrefiller``."""
    logits, _ = factory.apply_train(cfg, params, batch)
    return logits


def make_serve_step(cfg: ModelConfig, mesh, params_shapes, cache_shapes,
                    batch_shapes, donate_cache: bool = True):
    pspecs = partition.serve_param_pspecs(params_shapes, mesh)
    cspecs = partition.cache_pspecs(cache_shapes, mesh)
    bspecs = partition.batch_pspecs(batch_shapes, mesh)
    fn = partial(serve_step_fn, cfg)
    return jax.jit(
        fn,
        in_shardings=(partition.named(mesh, pspecs),
                      partition.named(mesh, cspecs),
                      partition.named(mesh, bspecs)),
        out_shardings=(None, None, partition.named(mesh, cspecs)),
        donate_argnums=(1,) if donate_cache else (),
    ), pspecs, cspecs, bspecs
