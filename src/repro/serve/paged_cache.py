"""Paged KV cache: a block-pool arena with per-slot block tables.

The contiguous decode cache (``factory.init_cache``) charges every slot
``max_len`` rows up front, so B slots of wildly different sequence lengths
pay B * max_len.  Here the sequence-indexed leaves (k / v and their int8
scales) live in one shared arena of ``num_blocks`` fixed-size blocks, and
each slot owns an ordered block table mapping logical block -> physical
block.  Blocks are allocated lazily as a slot's length grows and returned
to the pool when the request finishes, so the arena can be sized for the
*expected* total tokens in flight instead of the worst case per slot.

Admission control is reservation-based: a request reserves its worst-case
block count (prompt + max_new tokens) before taking a slot, and ``ensure``
then draws from the free list as the sequence actually grows — the
invariant ``free >= outstanding reservations`` means a mid-flight
allocation can never fail.

The decode/prefill steps keep the existing contiguous cache contract of
``models/factory.py``: ``gather_view`` materializes a (Lx, B, S_view, ...)
view from the pages (one jitted take per leaf, cached between decode ticks
and invalidated when block tables change), ``apply_decode`` scatters each
active slot's newly written row back into its page, and ``scatter_chunk``
splices a prefill chunk's rows.  A production Pallas paged-attention
kernel would consume the block table directly; the view keeps every model
family working unmodified.

Recurrent per-slot states (ssm / conv / wkv / tm_x / cm_x, whisper's cross
caches) are O(1) per slot and stay slot-dense; ``len`` is host-managed by
the engine.

``ContiguousKVCache`` wraps the classic single-arena cache behind the same
interface so the engine has one code path and the benchmark can check
bit-parity between the two.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import factory

__all__ = ["classify_cache", "PagedKVCache", "ContiguousKVCache",
           "make_kv_cache"]

# leaves indexed (Lx, B, S, ...) along the decode sequence — pageable
_SEQ_NAMES = ("k", "v", "k_scale", "v_scale")


def classify_cache(proto: dict, max_len: int):
    """Split a ``factory.init_cache`` pytree into sequence-indexed leaves
    (pageable) and per-slot state leaves.  Whisper's cross_k/cross_v are
    encoder-length and never paged."""
    seq, state = [], []
    for name, leaf in proto.items():
        if name == "len":
            continue
        if (name in _SEQ_NAMES and leaf.ndim >= 3
                and leaf.shape[2] == max_len):
            seq.append(name)
        else:
            state.append(name)
    return seq, state


class _KVCacheBase:
    """Shared bookkeeping: leaf classification and slot-state splicing."""

    def __init__(self, cfg: ModelConfig, batch_slots: int, max_len: int):
        self.cfg = cfg
        self.b = batch_slots
        self.max_len = max_len
        # shapes only — the full contiguous cache is never materialized in
        # paged mode (it is the allocation the block pool exists to avoid)
        proto = jax.eval_shape(
            lambda: factory.init_cache(cfg, batch_slots, max_len))
        self.seq_names, self.state_names = classify_cache(proto, max_len)
        self.seq_shapes = {n: proto[n] for n in self.seq_names}
        self.state = {n: jnp.zeros(proto[n].shape, proto[n].dtype)
                      for n in self.state_names}

    def set_slot_state(self, slot: int, state_rows: dict) -> None:
        """Install a finished prefill's recurrent states for one slot.
        state_rows: {name: (Lx, ...)} with the batch dim squeezed out."""
        for name in self.state_names:
            if name in state_rows:
                self.state[name] = self.state[name].at[:, slot].set(
                    state_rows[name])

    def zero_slot_state(self, slot: int) -> None:
        for name in self.state_names:
            self.state[name] = self.state[name].at[:, slot].set(0)


class PagedKVCache(_KVCacheBase):
    def __init__(self, cfg: ModelConfig, batch_slots: int, max_len: int,
                 block_size: int = 16, num_blocks: int | None = None):
        super().__init__(cfg, batch_slots, max_len)
        self.block_size = block_size
        self.blocks_per_slot = -(-max_len // block_size)
        if num_blocks is None:
            num_blocks = batch_slots * self.blocks_per_slot
        self.num_blocks = num_blocks
        self.view_len = self.blocks_per_slot * block_size
        # arenas: (Lx, B, S, ...) -> (Lx, num_blocks, block_size, ...)
        self.pages = {
            n: jnp.zeros(
                (s.shape[0], num_blocks, block_size) + s.shape[3:],
                s.dtype)
            for n, s in self.seq_shapes.items()
        }
        # host-side allocator
        self.block_tables = np.zeros((batch_slots, self.blocks_per_slot),
                                     np.int32)
        self.n_blocks = np.zeros(batch_slots, np.int32)
        self._resv = np.zeros(batch_slots, np.int64)
        self._free = list(range(num_blocks - 1, -1, -1))
        self._quarantined: list = []    # fault-drill OOM pressure pool
        self._view = None
        self._view_dirty = True
        self._build_jits()

    # ---------------------------------------------------------------- jits
    def _build_jits(self):
        lx = {n: self.pages[n].shape[0] for n in self.seq_names}
        b, nb = self.b, self.num_blocks
        mb, bs = self.blocks_per_slot, self.block_size

        @jax.jit
        def gather(pages, bt_flat):
            out = {}
            for n, arena in pages.items():
                v = jnp.take(arena, bt_flat, axis=1)
                out[n] = v.reshape((lx[n], b, mb * bs) + arena.shape[3:])
            return out

        @jax.jit
        def scatter_decode(pages, view, idx):
            # idx: (3, B) int32 rows = (lens, phys, off) — one device_put
            # per tick instead of three
            lens, phys, off = idx[0], idx[1], idx[2]
            iota = jnp.arange(b)
            out = {}
            for n, arena in pages.items():
                row = view[n][:, iota, lens]          # (Lx, B, ...)
                out[n] = arena.at[:, phys, off].set(row, mode="drop")
            return out

        @jax.jit
        def scatter_chunk(pages, rows, phys, off):
            return {n: pages[n].at[:, phys, off].set(rows[n], mode="drop")
                    for n in pages}

        @jax.jit
        def mask_state(old, new, active):
            def leaf(o, nw):
                m = active.reshape((1, b) + (1,) * (o.ndim - 2))
                return jnp.where(m, nw.astype(o.dtype), o)
            return jax.tree.map(leaf, old, new)

        @jax.jit
        def scrub(pages, idx):
            # idx: (2,) int32 = (phys, off) — zero one row of every arena
            return {n: arena.at[:, idx[0], idx[1]].set(0)
                    for n, arena in pages.items()}

        self._gather = gather
        self._scatter_decode = scatter_decode
        self._scatter_chunk = scatter_chunk
        self._mask_state = mask_state
        self._scrub = scrub

    # ----------------------------------------------------------- allocator
    def blocks_needed(self, n_tokens: int) -> int:
        return min(-(-n_tokens // self.block_size), self.blocks_per_slot)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def reserve(self, slot: int, n_tokens: int) -> bool:
        """Admission control: reserve the worst-case block count for a
        request.  False when the unreserved pool cannot cover it."""
        need = self.blocks_needed(n_tokens) - int(self.n_blocks[slot])
        avail = len(self._free) - int(self._resv.sum())
        if need > avail:
            return False
        self._resv[slot] = max(need, 0)
        return True

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow the slot's block table to address ``n_tokens`` tokens
        (draws from the reservation, so it cannot fail post-admission)."""
        need = self.blocks_needed(n_tokens)
        while self.n_blocks[slot] < need:
            if not self._free:
                raise RuntimeError(
                    "paged KV cache exhausted despite reservation — "
                    "allocator invariant violated")
            phys = self._free.pop()
            self.block_tables[slot, self.n_blocks[slot]] = phys
            self.n_blocks[slot] += 1
            if self._resv[slot] > 0:
                self._resv[slot] -= 1
            self._view_dirty = True

    def free_slot(self, slot: int) -> None:
        for j in range(int(self.n_blocks[slot])):
            self._free.append(int(self.block_tables[slot, j]))
        self.n_blocks[slot] = 0
        self._resv[slot] = 0
        self.block_tables[slot] = 0
        self.zero_slot_state(slot)
        self._view_dirty = True

    def quarantine_blocks(self, n: int) -> int:
        """Fault drill: withhold up to ``n`` free blocks to simulate arena
        pressure.  Only blocks beyond the outstanding reservations are
        taken — admitted requests keep their "ensure cannot fail"
        guarantee; the pressure lands on *admission* (reserve), which is
        the contract's pushback point.  Returns how many were taken."""
        take = max(0, min(n, len(self._free) - int(self._resv.sum())))
        for _ in range(take):
            self._quarantined.append(self._free.pop())
        return take

    def release_quarantined(self) -> int:
        n = len(self._quarantined)
        self._free.extend(self._quarantined)
        self._quarantined = []
        return n

    def arena_check(self) -> dict:
        """Allocator invariant: every physical block is in exactly one of
        {free, quarantined, some slot's table}, reservations never exceed
        the free pool.  Raises RuntimeError on violation (the leak-class
        tripwire the engine can run after every step); returns the
        accounting."""
        allocated = []
        for slot in range(self.b):
            allocated.extend(int(x) for x in
                             self.block_tables[slot, :int(self.n_blocks[slot])])
        every = allocated + [int(x) for x in self._free] + \
            [int(x) for x in self._quarantined]
        acct = {"allocated": len(allocated), "free": len(self._free),
                "quarantined": len(self._quarantined),
                "reserved": int(self._resv.sum()),
                "num_blocks": self.num_blocks}
        if len(every) != self.num_blocks or len(set(every)) != len(every) \
                or any(x < 0 or x >= self.num_blocks for x in every):
            raise RuntimeError(
                f"paged arena accounting violated (leaked or double-owned "
                f"blocks): {acct}")
        if acct["reserved"] > acct["free"]:
            raise RuntimeError(
                f"outstanding reservations exceed the free pool: {acct}")
        return acct

    def scrub_row(self, slot: int, pos: int) -> None:
        """Zero one committed KV row (every layer/leaf) of a slot — the
        quarantine path's cleanup for a row written by a poisoned decode.
        Attention masks scores beyond ``len``, but a NaN row still poisons
        ``sum(p * v)`` through ``0 * NaN``, so the row must be physically
        zeroed, not just masked."""
        if not self.pages or pos >= self.view_len:
            return
        logical = min(pos // self.block_size, self.blocks_per_slot - 1)
        if logical >= int(self.n_blocks[slot]):
            return
        phys = int(self.block_tables[slot, logical])
        off = pos % self.block_size
        self.pages = self._scrub(self.pages,
                                 jnp.asarray([phys, off], jnp.int32))
        self._view_dirty = True

    def invalidate_view(self) -> None:
        """Force the next ``gather_view`` to rebuild from the pages —
        needed when a tick ran more than one decode closure (healthy +
        degraded), because ``apply_decode`` caches the *last* closure's
        view which holds the other population's uncommitted rows."""
        self._view_dirty = True

    # --------------------------------------------------------------- views
    def gather_view(self, lens) -> dict:
        """Contiguous (Lx, B, view_len, ...) cache view for the jitted
        decode step.  Rebuilt only when block tables changed; rows past a
        slot's ``len`` may hold stale pool data — masked by attention."""
        if self._view_dirty or self._view is None:
            bt = jnp.asarray(self.block_tables.reshape(-1))
            self._view = self._gather(self.pages, bt)
            self._view_dirty = False
        cache = dict(self._view)
        cache.update(self.state)
        cache["len"] = jnp.asarray(lens, jnp.int32)
        return cache

    def apply_decode(self, new_cache: dict, lens, active) -> None:
        """Commit one decode tick: for each active slot, scatter the row
        written at ``lens[i]`` into its page; inactive slots' writes are
        dropped (OOB physical block) and their states restored."""
        lens = np.asarray(lens)
        active = np.asarray(active)
        logical = np.minimum(lens // self.block_size,
                             self.blocks_per_slot - 1)
        phys = np.where(active,
                        self.block_tables[np.arange(self.b), logical],
                        self.num_blocks)                 # OOB -> dropped
        off = lens % self.block_size
        if self.pages:
            idx = jnp.asarray(np.stack([lens, phys, off]).astype(np.int32))
            self.pages = self._scatter_decode(
                self.pages, {n: new_cache[n] for n in self.seq_names}, idx)
            # the view already contains this tick's writes for every slot;
            # inactive slots' garbage rows sit beyond their len (masked)
            # and tables are marked dirty whenever they change
            self._view = {n: new_cache[n] for n in self.seq_names}
        if self.state_names:
            self.state = self._mask_state(
                self.state, {n: new_cache[n] for n in self.state_names},
                jnp.asarray(active.reshape(-1)))

    def scatter_chunk(self, slot: int, rows: dict, start: int,
                      count: int) -> None:
        """Splice a prefill chunk's rows (Lx, C, ...) into the slot's pages
        at positions start..start+count-1 (the C-count pad rows drop)."""
        if not self.pages:
            return
        c = next(iter(rows.values())).shape[1]
        positions = start + np.arange(c)
        valid = np.arange(c) < count
        logical = np.minimum(positions // self.block_size,
                             self.blocks_per_slot - 1)
        phys = np.where(valid, self.block_tables[slot, logical],
                        self.num_blocks)
        off = positions % self.block_size
        self.pages = self._scatter_chunk(
            self.pages, {n: rows[n] for n in self.seq_names},
            jnp.asarray(phys), jnp.asarray(off))
        self._view_dirty = True


class ContiguousKVCache(_KVCacheBase):
    """The classic one-arena-per-slot cache behind the paged interface."""

    def __init__(self, cfg: ModelConfig, batch_slots: int, max_len: int,
                 **_):
        super().__init__(cfg, batch_slots, max_len)
        self.view_len = max_len
        self.store = {n: jnp.zeros(s.shape, s.dtype)
                      for n, s in self.seq_shapes.items()}
        b = batch_slots

        @jax.jit
        def apply_decode(store, state, new_cache, lens, active):
            s_out = {}
            for n, old in store.items():
                s = old.shape[2]
                at_pos = ((jnp.arange(s)[None, :] == lens[:, None])
                          & active[:, None])             # (B, S)
                m = at_pos.reshape((1, b, s) + (1,) * (old.ndim - 3))
                s_out[n] = jnp.where(m, new_cache[n].astype(old.dtype), old)
            st_out = {}
            for n, old in state.items():
                m = active.reshape((1, b) + (1,) * (old.ndim - 2))
                st_out[n] = jnp.where(m, new_cache[n].astype(old.dtype),
                                      old)
            return s_out, st_out

        self._apply = apply_decode

    def blocks_needed(self, n_tokens: int) -> int:
        return 0

    def reserve(self, slot: int, n_tokens: int) -> bool:
        return True

    def ensure(self, slot: int, n_tokens: int) -> None:
        pass

    def free_slot(self, slot: int) -> None:
        # stale K/V rows beyond len are masked out; states must be zeroed
        self.zero_slot_state(slot)

    def quarantine_blocks(self, n: int) -> int:
        return 0                      # no arena to pressure

    def release_quarantined(self) -> int:
        return 0

    def arena_check(self) -> dict:
        return {"allocated": 0, "free": 0, "quarantined": 0,
                "reserved": 0, "num_blocks": 0}

    def scrub_row(self, slot: int, pos: int) -> None:
        for n in self.seq_names:
            self.store[n] = self.store[n].at[:, slot, pos].set(0)

    def invalidate_view(self) -> None:
        pass                          # gather_view reads the store directly

    def gather_view(self, lens) -> dict:
        cache = dict(self.store)
        cache.update(self.state)
        cache["len"] = jnp.asarray(lens, jnp.int32)
        return cache

    def apply_decode(self, new_cache: dict, lens, active) -> None:
        self.store, self.state = self._apply(
            self.store, self.state, new_cache,
            jnp.asarray(np.asarray(lens)),
            jnp.asarray(np.asarray(active).reshape(-1)))

    def scatter_chunk(self, slot: int, rows: dict, start: int,
                      count: int) -> None:
        for n in self.seq_names:
            self.store[n] = self.store[n].at[
                :, slot, start : start + count].set(rows[n][:, :count])


def make_kv_cache(cfg: ModelConfig, batch_slots: int, max_len: int,
                  paged: bool = True, block_size: int = 16,
                  num_blocks: int | None = None):
    if paged:
        return PagedKVCache(cfg, batch_slots, max_len,
                            block_size=block_size, num_blocks=num_blocks)
    return ContiguousKVCache(cfg, batch_slots, max_len)
