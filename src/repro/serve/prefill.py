"""True chunked prefill: C prompt tokens per jitted call.

The seed engine prefills by replaying the prompt token-by-token through
the decode path — TTFT scales as O(prompt_len) jitted decode steps.  The
prefiller instead runs the family's ``prefill_chunk`` (or the ESPIM-format
sparse variant) over fixed-width chunks: ceil(prompt_len / C) jitted calls
to first token, with the final partial chunk padded up to C (pad positions
are masked so every recurrent/attention state lands exactly where replay
would put it — see the per-family ``prefill_chunk`` docstrings).

The ESPIM engine applies the paper's flexible dense/sparse datapath
(Section III-I) per serving phase: the GEMM-shaped prefill chunk runs the
pruned *dense* copies of every covered projection — attention included
when the pack groups cover the whole layer (``sparsify_model``) —
while decode runs the packed MV kernels (memory-bound phase, the
format's whole point) — see DESIGN.md sections 8/10.

Each slot prefills into a private (B=1) scratch cache; after every chunk
the freshly written K/V rows are sliced out for the engine to splice into
the slot's pages (paged) or cache rows (contiguous).  The scratch cache
starts from one shared zero prototype — jax arrays are immutable, so
"resetting" a slot's scratch cache is a pointer copy, not an allocation.
The final chunk also yields the recurrent state leaves (ssm / conv / wkv /
token-shift) and the last valid position's logits, from which the engine
samples the first generated token — TTFT therefore needs no extra decode
step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import sparse_model
from repro.models import factory

__all__ = ["ChunkedPrefiller"]


class ChunkedPrefiller:
    def __init__(self, cfg: ModelConfig, chunk: int, max_len: int,
                 seq_names, state_names, sparse: dict | None = None,
                 impl: str = "ref"):
        self.cfg = cfg
        self.chunk = chunk
        # scratch length rounded up so the last chunk's pad rows fit
        self.scratch_len = -(-max_len // chunk) * chunk
        self.proto = factory.init_cache(cfg, 1, self.scratch_len)
        self.seq_names = list(seq_names)
        self.state_names = list(state_names)
        if sparse is None:
            self._fn = jax.jit(
                lambda p, c, b: factory.prefill_chunk(cfg, p, c, b))
        else:
            self._fn = jax.jit(
                lambda p, c, b: sparse_model.prefill_chunk_sparse(
                    cfg, p, sparse, c, b, impl=impl))

    def run_chunk(self, params, pf_cache, prompt, pos: int):
        """Prefill one chunk starting at ``pos``.  Returns (full-chunk
        logits (1, C, V), new scratch cache, n_valid)."""
        c = self.chunk
        n_valid = min(c, len(prompt) - pos)
        tokens = np.zeros((1, c), np.int32)
        tokens[0, :n_valid] = prompt[pos : pos + n_valid]
        batch = {"tokens": jnp.asarray(tokens),
                 "n_valid": jnp.asarray([n_valid], jnp.int32)}
        logits, pf_cache = self._fn(params, pf_cache, batch)
        return logits, pf_cache, n_valid

    def chunk_rows(self, pf_cache: dict, pos: int) -> dict:
        """The K/V rows the chunk just wrote: {name: (Lx, C, ...)}."""
        return {n: pf_cache[n][:, 0, pos : pos + self.chunk]
                for n in self.seq_names}

    def state_rows(self, pf_cache: dict) -> dict:
        """Recurrent state leaves after the final chunk: {name: (Lx, ...)}
        with the B=1 dim squeezed out."""
        return {n: pf_cache[n][:, 0] for n in self.state_names}
