"""Fault-tolerant checkpointing.

Properties a 1000-node run needs, all implemented here:
  * **atomicity** — writes go to ``step_NNN.tmp`` and are renamed only
    after the manifest (with per-leaf SHA-256) is fsync'd; a crash mid-write
    can never produce a "latest" pointer at a torn checkpoint;
  * **async** — the serialize+write happens on a background thread from a
    host copy, the train loop does not block;
  * **keep-k GC** — bounded disk;
  * **exact resume** — train state + data-pipeline state + RNG key are one
    bundle, and resume is bitwise (tested);
  * **elastic reshard** — checkpoints store full (unsharded) arrays plus
    the spec tree; ``restore(..., mesh=new_mesh)`` device_puts onto any
    mesh shape, which is how a shrunk/grown cluster resumes.  (A multi-host
    deployment writes per-host shards + a global index; the reshard path is
    identical from the reader's side.)
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import shutil
import threading

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "list_steps",
           "gc_keep_last"]

_MANIFEST = "manifest.json"
_DATA = "state.pkl"


def _to_host(tree):
    return jax.tree.map(lambda x: np.asarray(x), tree)


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def save(root: str, step: int, state: dict, extra: dict | None = None) -> str:
    """Synchronous atomic save.  ``state`` is any pytree of arrays;
    ``extra`` is JSON-serializable metadata (data-pipeline state etc.)."""
    os.makedirs(root, exist_ok=True)
    host = _to_host(state)
    final = _step_dir(root, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    blob = pickle.dumps(host, protocol=4)
    digest = hashlib.sha256(blob).hexdigest()
    with open(os.path.join(tmp, _DATA), "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    manifest = {"step": step, "sha256": digest, "bytes": len(blob),
                "extra": extra or {}}
    mpath = os.path.join(tmp, _MANIFEST)
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def save_async(root: str, step: int, state: dict,
               extra: dict | None = None) -> threading.Thread:
    """Non-blocking save: snapshots to host memory on the caller thread
    (cheap), serializes + writes on a daemon thread."""
    host = _to_host(state)
    t = threading.Thread(target=save, args=(root, step, host, extra),
                         daemon=True)
    t.start()
    return t


def list_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            mpath = os.path.join(root, name, _MANIFEST)
            if os.path.exists(mpath):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(root: str) -> int | None:
    steps = list_steps(root)
    return steps[-1] if steps else None


def restore(root: str, step: int | None = None, mesh=None, specs=None):
    """Load a checkpoint; verify integrity; optionally device_put onto a
    (possibly different) mesh via ``specs`` — the elastic-reshard path.

    Returns (state, extra, step)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = _step_dir(root, step)
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    with open(os.path.join(d, _DATA), "rb") as f:
        blob = f.read()
    digest = hashlib.sha256(blob).hexdigest()
    if digest != manifest["sha256"]:
        raise IOError(f"checkpoint {d} corrupt: sha mismatch")
    state = pickle.loads(blob)
    if mesh is not None and specs is not None:
        from repro.sharding.partition import logical_to_sharding
        state = logical_to_sharding(state, specs, mesh)
    return state, manifest.get("extra", {}), step


def gc_keep_last(root: str, keep: int = 3) -> None:
    steps = list_steps(root)
    for s in steps[:-keep]:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)
