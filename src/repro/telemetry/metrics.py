"""Metrics registry: counters, gauges, log-bucketed histograms (DESIGN §12).

Absorbs and extends the engine's ``EngineStats``: TTFT/TPOT/queue-delay
live in fixed log-spaced-bucket histograms (streaming p50/p95 in
O(buckets), not a full sort per summary — the PR 7 bugfix), bytes/token
is reported *by plane* (value vs index vs uncovered dense, straight from
``sparse_stats``), tokens and requests count by terminal state, and the
fault-tolerance ladder (quarantines / retries / verify failures /
leaked-block checks) is first-class.

Instruments are labeled; a ``Registry`` carries base labels
(model / impl / quant / attn) merged into every instrument.  Snapshots
are plain dicts (stable keys — CI validates a traced smoke run's
snapshot against ``REQUIRED_SERVE_METRICS``) and the whole registry
renders to Prometheus text exposition format.

Zero dependencies (stdlib only).
"""
from __future__ import annotations

import bisect
import math

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "log_buckets",
           "LATENCY_BUCKETS_S", "THROUGHPUT_BUCKETS", "US_BUCKETS",
           "REQUIRED_SERVE_METRICS", "validate_snapshot"]


def log_buckets(lo: float, hi: float, n: int) -> tuple[float, ...]:
    """n log-spaced upper-bound edges from lo to hi (inclusive).  Fixed
    at construction: observe() is one bisect, quantile() one O(n) scan."""
    if not (lo > 0 and hi > lo and n >= 2):
        raise ValueError(f"bad bucket spec lo={lo} hi={hi} n={n}")
    ratio = (hi / lo) ** (1.0 / (n - 1))
    return tuple(lo * ratio ** i for i in range(n))


# shared presets: ~9% resolution over 8-9 decades
LATENCY_BUCKETS_S = log_buckets(1e-6, 1e3, 240)      # 1us .. 1000s
US_BUCKETS = log_buckets(1e-1, 1e8, 240)             # 0.1us .. 100s (in us)
THROUGHPUT_BUCKETS = log_buckets(1e-2, 1e7, 240)     # tok/s etc.


class Counter:
    """Monotonic count.  ``inc`` only; negative increments are a bug."""
    __slots__ = ("name", "labels", "value")
    kind = "counter"

    def __init__(self, name, labels):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int | float = 1):
        if n < 0:
            raise ValueError(f"counter {self.name} decremented by {n}")
        self.value += n
        return self

    def snapshot(self):
        return self.value


class Gauge:
    """Point-in-time value (occupancy, fragmentation, bytes/token)."""
    __slots__ = ("name", "labels", "value")
    kind = "gauge"

    def __init__(self, name, labels):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)
        return self

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed log-spaced-bucket histogram with streaming quantiles.

    ``edges`` are upper bounds; one implicit +Inf overflow bucket.
    ``observe`` is O(log buckets) (bisect); ``quantile`` is O(buckets):
    walk the cumulative counts to the target rank, then log-interpolate
    inside the bucket.  Exact count/sum/min/max ride along so means and
    totals are not bucket-quantized.
    """
    __slots__ = ("name", "labels", "edges", "counts", "count", "sum",
                 "min", "max")
    kind = "histogram"

    def __init__(self, name, labels, edges=LATENCY_BUCKETS_S):
        self.name = name
        self.labels = labels
        self.edges = tuple(float(e) for e in edges)
        if list(self.edges) != sorted(self.edges):
            raise ValueError("histogram edges must be sorted")
        self.counts = [0] * (len(self.edges) + 1)   # [+Inf overflow]
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, x: float):
        x = float(x)
        self.counts[bisect.bisect_left(self.edges, x)] += 1
        self.count += 1
        self.sum += x
        self.min = min(self.min, x)
        self.max = max(self.max, x)
        return self

    def reset(self) -> None:
        self.counts = [0] * (len(self.edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def quantile(self, q: float) -> float | None:
        """Streaming quantile estimate, O(buckets).  None when empty.
        Clamped to the exact observed [min, max] so tiny samples do not
        report a bucket edge outside the data."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q={q} outside [0, 1]")
        if self.count == 0:
            return None
        rank = q * (self.count - 1) + 1         # 1-based target rank
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= rank:
                # log-interpolate within bucket i: edges[i-1] .. edges[i]
                lo = self.edges[i - 1] if i > 0 else (
                    self.edges[0] / (self.edges[1] / self.edges[0])
                    if len(self.edges) > 1 else self.edges[0])
                hi = self.edges[i] if i < len(self.edges) else self.max
                frac = (rank - cum) / c
                if lo > 0 and hi > 0:
                    est = lo * (hi / lo) ** frac
                else:
                    est = lo + (hi - lo) * frac
                return min(max(est, self.min), self.max)
            cum += c
        return self.max

    def percentile_summary(self, qs=(50, 95)) -> dict:
        return {f"p{q}": self.quantile(q / 100.0) for q in qs}

    def snapshot(self):
        out = {"count": self.count,
               "sum": self.sum,
               "min": None if self.count == 0 else self.min,
               "max": None if self.count == 0 else self.max,
               "mean": self.sum / self.count if self.count else None}
        out.update(self.percentile_summary())
        return out


def _label_key(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return "{" + inner + "}"


class Registry:
    """A named, labeled instrument store.

    ``base_labels`` (model / impl / quant / attn for the engine) merge
    into every instrument; per-call labels distinguish series under one
    metric name.  Getting an existing (name, labels) pair returns the
    same instrument — instruments are create-once, mutate-forever, so
    hot-path callers can hold direct references and skip the lookup.
    """

    def __init__(self, base_labels: dict | None = None):
        self.base_labels = dict(base_labels or {})
        self._metrics: dict[str, dict] = {}   # name -> {labelkey: inst}
        self._kinds: dict[str, str] = {}
        self._help: dict[str, str] = {}

    def _get(self, cls, name, help, labels, **kw):
        merged = {**self.base_labels, **labels}
        key = _label_key(merged)
        fam = self._metrics.setdefault(name, {})
        if key not in fam:
            if name in self._kinds and self._kinds[name] != cls.kind:
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{self._kinds[name]}, not {cls.kind}")
            self._kinds[name] = cls.kind
            if help:
                self._help[name] = help
            fam[key] = cls(name, merged, **kw)
        return fam[key]

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets=LATENCY_BUCKETS_S, **labels) -> Histogram:
        return self._get(Histogram, name, help, labels, edges=buckets)

    # ------------------------------------------------------------ exporters
    def snapshot(self) -> dict:
        """{"metric_name{labels}": value-or-histogram-summary} — flat,
        deterministic key order, JSON-ready."""
        out = {}
        for name in sorted(self._metrics):
            for key in sorted(self._metrics[name]):
                out[name + key] = self._metrics[name][key].snapshot()
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format v0.0.4."""
        lines = []
        for name in sorted(self._metrics):
            kind = self._kinds[name]
            if name in self._help:
                lines.append(f"# HELP {name} {self._help[name]}")
            lines.append(f"# TYPE {name} {kind}")
            for key in sorted(self._metrics[name]):
                inst = self._metrics[name][key]
                if kind in ("counter", "gauge"):
                    lines.append(f"{name}{key} {_fmt(inst.value)}")
                    continue
                # histogram: cumulative le buckets + sum + count
                base = dict(inst.labels)
                cum = 0
                for edge, c in zip(inst.edges, inst.counts):
                    cum += c
                    lbl = _label_key({**base, "le": _fmt(edge)})
                    lines.append(f"{name}_bucket{lbl} {cum}")
                lbl = _label_key({**base, "le": "+Inf"})
                lines.append(f"{name}_bucket{lbl} {inst.count}")
                lines.append(f"{name}_sum{_label_key(base)} "
                             f"{_fmt(inst.sum)}")
                lines.append(f"{name}_count{_label_key(base)} {inst.count}")
        return "\n".join(lines) + "\n"


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == int(v) and abs(v) < 1e15:
            return str(int(v))
        return repr(v)
    return str(v)


# --------------------------------------------------------------------------
# The checked-in key list a traced serving run must emit (CI telemetry
# smoke): base metric names — label sets vary with the engine config, the
# *names* must not silently disappear when code paths are refactored.
REQUIRED_SERVE_METRICS = (
    "serve_ttft_seconds",
    "serve_tpot_seconds",
    "serve_queue_delay_seconds",
    "serve_step_seconds",
    "serve_requests_total",
    "serve_tokens_total",
    "serve_degraded_tokens_total",
    "serve_quarantines_total",
    "serve_retries_total",
    "serve_verify_failures_total",
    "serve_watchdog_flags_total",
    "serve_preempts_total",
    "serve_shed_total",
    "serve_restores_total",
    "serve_queue_depth",
    "serve_arena_headroom_blocks",
    "serve_arena_checks_total",
    "serve_arena_blocks",
    "serve_arena_occupancy",
    "serve_arena_fragmentation",
    "serve_slot_occupancy",
    "espim_bytes_per_token",
    "espim_pad_frac",
)


def validate_snapshot(snapshot: dict, required=REQUIRED_SERVE_METRICS,
                      sparse: bool = True) -> None:
    """Assert every required metric family appears in a snapshot.  The
    espim_* families only exist on a sparse engine."""
    have = set()
    for key in snapshot:
        have.add(key.split("{", 1)[0])
    need = [m for m in required
            if sparse or not m.startswith("espim_")]
    missing = [m for m in need if m not in have]
    if missing:
        raise AssertionError(
            f"metrics snapshot missing families {missing}; "
            f"present: {sorted(have)}")
