"""Always-on flight recorder for the serving stack (DESIGN.md §14).

The span tracer (``telemetry/trace.py``) is opt-in: it fences device
work for exact attribution, so production engines run with it disabled
and a fault caught in the wild used to mean "re-run with ``--trace`` and
hope it reproduces".  The flight recorder closes that gap: a bounded
ring buffer of recent request/fault/step events that every engine feeds
*unconditionally* — no fencing, no clock discipline beyond one
``perf_counter_ns`` read, O(capacity) memory forever — which the fault
ladder dumps to ``FLIGHT_<reason>.json`` the moment something trips
(nonfinite quarantine, retry exhaustion, shed/preempt storm, crash
drill).  A post-mortem therefore always has the last ~thousand events
leading up to the incident, with the same ``rid``-keyed event names the
tracer emits, plus a full metrics snapshot at dump time.

Cost contract (pinned in ``tests/test_flightrec.py``):

* ``enabled=False`` → ``record()`` is a constant-time early return that
  allocates nothing.
* enabled → one tuple per event into a preallocated ring; memory is
  O(capacity) no matter how long the engine runs (the ring overwrites,
  it never grows).
* files are written ONLY by ``trip()``/``dump()``, and ``trip()`` is a
  no-op unless ``autodump`` is set — library code and tests never
  litter the working directory; benches opt in.

Like the tracer, a process-default recorder (``get_recorder`` /
``set_recorder``) lets engines pick one up without threading an
argument through every constructor.  The default is enabled (the whole
point is always-on) but never auto-dumps.
"""
from __future__ import annotations

import json
import threading
import time

__all__ = ["FlightRecorder", "get_recorder", "set_recorder"]


class FlightRecorder:
    def __init__(self, capacity: int = 2048, enabled: bool = True, *,
                 autodump: bool = False, dump_dir: str = ".",
                 storm_threshold: int = 8, storm_window_s: float = 1.0,
                 min_dump_interval_s: float = 5.0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.enabled = enabled
        self.autodump = autodump
        self.dump_dir = dump_dir
        self.storm_threshold = max(1, storm_threshold)
        self.storm_window_s = storm_window_s
        self.min_dump_interval_s = min_dump_interval_s
        self._ring: list = [None] * capacity   # preallocated, overwritten
        self._i = 0                            # next write index
        self._n = 0                            # total events ever recorded
        self._lock = threading.Lock()
        self._pressure_ns: list[int] = []      # recent shed/preempt marks
        self._last_dump_ns: dict[str, int] = {}  # reason -> last trip time
        self.dumps: list[str] = []             # every file this recorder wrote

    # ------------------------------------------------------------- recording
    def record(self, kind: str, name: str, args=None) -> None:
        """Append one event to the ring.  ``kind`` groups the event class
        ("request" / "fault" / "step" / "snapshot" / ...), ``name`` is the
        tracer-compatible event name, ``args`` any JSON-ready payload."""
        if not self.enabled:
            return
        with self._lock:
            self._ring[self._i] = (time.perf_counter_ns(), kind, name, args)
            self._i = (self._i + 1) % self.capacity
            self._n += 1

    def pressure(self) -> bool:
        """Note one shed/preempt pressure mark; True when the recorder has
        seen ``storm_threshold`` marks inside ``storm_window_s`` — the
        caller's cue to ``trip()`` a storm dump."""
        if not self.enabled:
            return False
        now = time.perf_counter_ns()
        horizon = now - int(self.storm_window_s * 1e9)
        with self._lock:
            self._pressure_ns.append(now)
            self._pressure_ns = [t for t in self._pressure_ns if t >= horizon]
            return len(self._pressure_ns) >= self.storm_threshold

    def clear(self) -> None:
        with self._lock:
            self._ring = [None] * self.capacity
            self._i = 0
            self._n = 0
            self._pressure_ns.clear()

    # -------------------------------------------------------------- reading
    @property
    def recorded(self) -> int:
        """Total events ever recorded (including overwritten ones)."""
        return self._n

    @property
    def dropped(self) -> int:
        """Events the ring has overwritten."""
        return max(0, self._n - self.capacity)

    def events(self) -> list[dict]:
        """Ring contents oldest-first, as JSON-ready dicts."""
        with self._lock:
            if self._n < self.capacity:
                raw = self._ring[:self._n]
            else:
                raw = self._ring[self._i:] + self._ring[:self._i]
        return [{"t_ns": t, "kind": k, "name": n, "args": a}
                for t, k, n, a in raw]

    # -------------------------------------------------------------- dumping
    def dump(self, path: str | None = None, *, reason: str = "manual",
             registry=None, provenance: dict | None = None) -> str:
        """Write the ring (plus an optional metrics snapshot) to a JSON
        file and return its path.  Unconditional — cooldown and the
        ``autodump`` gate live in ``trip()``."""
        if path is None:
            path = f"{self.dump_dir}/FLIGHT_{reason}.json"
        doc = {
            "flight": True,
            "reason": reason,
            "t_dump_ns": time.perf_counter_ns(),
            "clock": "perf_counter_ns",
            "capacity": self.capacity,
            "recorded": self._n,
            "dropped": self.dropped,
            "events": self.events(),
            "metrics": registry.snapshot() if registry is not None else None,
            "provenance": provenance,
        }
        with open(path, "w") as f:
            json.dump(doc, f, indent=2)
        self.dumps.append(path)
        return path

    def trip(self, reason: str, *, registry=None,
             provenance: dict | None = None) -> str | None:
        """The fault ladder's dump hook: writes ``FLIGHT_<reason>.json``
        when ``autodump`` is on and the per-reason cooldown has passed
        (a quarantine storm must not write a thousand files).  Returns
        the path written, or None when suppressed."""
        if not (self.enabled and self.autodump):
            return None
        now = time.perf_counter_ns()
        last = self._last_dump_ns.get(reason)
        if last is not None and now - last < self.min_dump_interval_s * 1e9:
            return None
        self._last_dump_ns[reason] = now
        return self.dump(reason=reason, registry=registry,
                         provenance=provenance)


# the process default: always-on ring, never writes files on its own
_default = FlightRecorder()


def get_recorder() -> FlightRecorder:
    """The process-default flight recorder every engine feeds unless one
    is passed explicitly.  Enabled by default (the recorder exists to be
    always-on) but ``autodump`` is off — only benches/drills that opt in
    via ``set_recorder`` produce FLIGHT_*.json files."""
    return _default


def set_recorder(rec: FlightRecorder | None) -> FlightRecorder:
    """Install (or, with None, reset to a fresh default) the process
    recorder; returns the previous one so callers can restore it."""
    global _default
    prev = _default
    _default = rec if rec is not None else FlightRecorder()
    return prev
