"""Structured span tracing for the serving stack (DESIGN.md §12).

ESPIM's argument is an accounting argument — bytes, cycles, bank
utilization — and the serving reproduction needs the software analogue:
where do a token's microseconds go?  The tracer records *nested spans*
(SpMV launch vs epilogue vs scheduler vs host sync) with monotonic
nanosecond timestamps so per-phase attribution is exact, and exports
both Perfetto/Chrome ``trace_event`` JSON (open in https://ui.perfetto.dev)
and a plain JSONL event log whose header carries the kernels'
``Provenance`` block.

Design constraints:

* **~no-op when disabled.**  ``Tracer(enabled=False).span(...)`` returns
  one shared ``_NullSpan`` singleton — no object allocation, no clock
  read, no lock — so the serving hot path can stay permanently
  instrumented (asserted by a counting shim in ``tests/test_telemetry.py``).
  The call signature takes an *explicit* ``args`` dict instead of
  ``**kwargs`` for the same reason: a disabled call must not even build
  an empty dict.
* **thread-safe.**  Span stacks are per-thread (``threading.local``);
  the finished-event list is guarded by one lock.  Span ids are globally
  unique so parent/child links survive interleaved threads.
* **explicit device fencing.**  JAX dispatch is async: without a fence,
  device work queued inside a span is billed to whichever *later* span
  happens to block.  ``tracer.fence(x)`` calls ``jax.block_until_ready``
  at a span boundary **only while tracing** — with tracing disabled it
  is a no-op, so instrumentation never changes the untraced pipeline's
  overlap behavior.

This module is dependency-free (stdlib only; jax is imported lazily and
only inside ``fence``).
"""
from __future__ import annotations

import itertools
import json
import threading
import time

__all__ = ["Span", "Tracer", "NULL_TRACER", "get_tracer", "set_tracer",
           "validate_chrome_trace", "span_coverage", "phase_breakdown",
           "BREAKDOWN_SCHEMA_KEYS"]


class _NullSpan:
    """The shared do-nothing span a disabled tracer hands out."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, key, value):        # parity with Span.set
        return self


_NULL_SPAN = _NullSpan()
_ids = itertools.count(1)


class Span:
    """One closed interval on one thread.  Durations are exact
    (perf_counter_ns at enter/exit); ``parent_id`` links the enclosing
    span on the same thread at enter time."""
    __slots__ = ("name", "cat", "t0_ns", "t1_ns", "tid", "sid",
                 "parent_id", "depth", "args", "_tracer")

    def __init__(self, tracer, name, cat, tid, parent_id, depth, args):
        self.name = name
        self.cat = cat
        self.tid = tid
        self.sid = next(_ids)
        self.parent_id = parent_id
        self.depth = depth
        self.args = args
        self.t0_ns = 0
        self.t1_ns = 0
        self._tracer = tracer

    def set(self, key, value):
        """Attach one attribute (rendered into trace_event ``args``)."""
        if self.args is None:
            self.args = {}
        self.args[key] = value
        return self

    @property
    def dur_ns(self) -> int:
        return self.t1_ns - self.t0_ns

    def __enter__(self):
        self._tracer._push(self)
        self.t0_ns = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        self.t1_ns = time.perf_counter_ns()
        self._tracer._pop(self)
        return False


class Tracer:
    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.events: list[Span] = []     # closed spans, completion order
        self.instants: list[tuple] = []  # (name, cat, t_ns, tid, args)
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._t_origin_ns = time.perf_counter_ns()

    # ------------------------------------------------------------ recording
    def span(self, name: str, cat: str | None = None, args: dict | None = None):
        """Context manager for one nested span.  Disabled tracers return
        the shared null span: zero allocations on the hot path."""
        if not self.enabled:
            return _NULL_SPAN
        stack = self._stack()
        parent = stack[-1] if stack else None
        return Span(self, name, cat, threading.get_ident(),
                    parent.sid if parent else 0,
                    len(stack), args)

    def instant(self, name: str, cat: str | None = None,
                args: dict | None = None) -> None:
        """A point event (trace_event ``ph:"i"``) — quarantines, retries,
        watchdog flags: things with a moment but no duration."""
        if not self.enabled:
            return
        with self._lock:
            self.instants.append((name, cat, time.perf_counter_ns(),
                                  threading.get_ident(), args))

    def wrap(self, name: str, cat: str | None = None):
        """Decorator form of ``span``."""
        def deco(fn):
            def inner(*a, **kw):
                with self.span(name, cat):
                    return fn(*a, **kw)
            inner.__name__ = getattr(fn, "__name__", name)
            return inner
        return deco

    def fence(self, x):
        """Block on device work at a span boundary so async dispatch is
        billed to the span that launched it.  No-op (and no sync!) when
        tracing is disabled — instrumentation must not change the
        untraced pipeline's host/device overlap."""
        if self.enabled and x is not None:
            import jax
            jax.block_until_ready(x)
        return x

    def clear(self) -> None:
        with self._lock:
            self.events.clear()
            self.instants.clear()
        self._t_origin_ns = time.perf_counter_ns()

    # ------------------------------------------------------------- internal
    def _stack(self):
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _push(self, span: Span) -> None:
        self._stack().append(span)

    def _pop(self, span: Span) -> None:
        stack = self._stack()
        if not stack or stack[-1] is not span:
            raise RuntimeError(
                f"span {span.name!r} closed out of order (open stack: "
                f"{[s.name for s in stack]})")
        stack.pop()
        with self._lock:
            self.events.append(span)

    # ------------------------------------------------------------ analysis
    def spans(self, name: str | None = None) -> list[Span]:
        with self._lock:
            ev = list(self.events)
        if name is None:
            return ev
        return [s for s in ev if s.name == name]

    # ------------------------------------------------------------ exporters
    def _ts_us(self, t_ns: int) -> float:
        return (t_ns - self._t_origin_ns) / 1e3

    def chrome_trace(self, provenance: dict | None = None) -> dict:
        """Perfetto/Chrome ``trace_event`` JSON object format: complete
        ("X") events for spans, instant ("i") events for point marks."""
        events = []
        with self._lock:
            spans = list(self.events)
            instants = list(self.instants)
        for s in spans:
            ev = {"name": s.name, "ph": "X", "pid": 1, "tid": s.tid,
                  "ts": self._ts_us(s.t0_ns), "dur": s.dur_ns / 1e3,
                  "cat": s.cat or "default"}
            if s.args:
                ev["args"] = dict(s.args)
            events.append(ev)
        for name, cat, t_ns, tid, args in instants:
            ev = {"name": name, "ph": "i", "pid": 1, "tid": tid,
                  "ts": self._ts_us(t_ns), "s": "t",
                  "cat": cat or "default"}
            if args:
                ev["args"] = dict(args)
            events.append(ev)
        events.sort(key=lambda e: e["ts"])
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        if provenance is not None:
            doc["otherData"] = {"provenance": provenance}
        return doc

    def write_chrome_trace(self, path: str,
                           provenance: dict | None = None) -> dict:
        doc = self.chrome_trace(provenance)
        validate_chrome_trace(doc)
        with open(path, "w") as f:
            json.dump(doc, f)
        return doc

    def write_jsonl(self, path: str, provenance: dict | None = None) -> int:
        """Plain event log: one JSON object per line, header first.  The
        header's ``provenance`` is the same ``ops.Provenance.to_dict()``
        the benches embed — a trace is always tied to what actually ran."""
        with self._lock:
            spans = list(self.events)
            instants = list(self.instants)
        n = 0
        with open(path, "w") as f:
            f.write(json.dumps({"type": "header", "clock": "perf_counter_ns",
                                "origin_ns": self._t_origin_ns,
                                "provenance": provenance}) + "\n")
            for s in sorted(spans, key=lambda s: s.t0_ns):
                f.write(json.dumps({
                    "type": "span", "name": s.name, "cat": s.cat,
                    "t0_ns": s.t0_ns, "t1_ns": s.t1_ns, "tid": s.tid,
                    "sid": s.sid, "parent": s.parent_id, "depth": s.depth,
                    "args": s.args}) + "\n")
                n += 1
            for name, cat, t_ns, tid, args in instants:
                f.write(json.dumps({"type": "instant", "name": name,
                                    "cat": cat, "t_ns": t_ns, "tid": tid,
                                    "args": args}) + "\n")
                n += 1
        return n


NULL_TRACER = Tracer(enabled=False)
_default = NULL_TRACER


def get_tracer() -> Tracer:
    """The process-default tracer — disabled unless a bench/example
    installed a live one.  Library code (``ops.pack_to_device``) traces
    through this so build-time work is captured without threading a
    tracer argument through every call chain."""
    return _default


def set_tracer(tracer: Tracer | None) -> Tracer:
    """Install (or, with None, reset) the process-default tracer;
    returns the previous one so callers can restore it."""
    global _default
    prev = _default
    _default = tracer if tracer is not None else NULL_TRACER
    return prev


# ---------------------------------------------------------------- validation
def validate_chrome_trace(doc: dict) -> None:
    """Schema check for the ``trace_event`` JSON object format (the
    subset Perfetto/chrome://tracing consume).  Raises ValueError with
    the first violation — CI runs this on every smoke trace so a code
    path that emits malformed events fails loudly."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace doc must be an object with 'traceEvents'")
    evs = doc["traceEvents"]
    if not isinstance(evs, list) or not evs:
        raise ValueError("traceEvents must be a non-empty list")
    for i, ev in enumerate(evs):
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"traceEvents[{i}] missing {key!r}: {ev}")
        if ev["ph"] not in ("X", "B", "E", "i", "M", "C"):
            raise ValueError(f"traceEvents[{i}] unknown phase {ev['ph']!r}")
        if ev["ph"] == "X":
            if "dur" not in ev:
                raise ValueError(f"traceEvents[{i}] 'X' event missing dur")
            if ev["dur"] < 0:
                raise ValueError(f"traceEvents[{i}] negative dur {ev['dur']}")
        if not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"traceEvents[{i}] non-numeric ts")


def span_coverage(spans: list[Span], parent: str) -> dict:
    """How much of each ``parent`` span its direct children account for.

    Returns {"coverage": fraction of total parent time covered by direct
    children, "overlap_errors": sibling pairs that overlap in time,
    "parents": n, "uncovered_us": host time inside the parent no child
    claims}.  The engine test asserts coverage >= 0.95 and zero overlap
    errors — the guarantee that the breakdown's phases *are* the step,
    not a sample of it.
    """
    by_parent: dict[int, list[Span]] = {}
    for s in spans:
        by_parent.setdefault(s.parent_id, []).append(s)
    parents = [s for s in spans if s.name == parent]
    total_ns = covered_ns = 0
    overlaps = []
    for p in parents:
        kids = sorted(by_parent.get(p.sid, ()), key=lambda s: s.t0_ns)
        total_ns += p.dur_ns
        covered_ns += sum(k.dur_ns for k in kids)
        for a, b in zip(kids, kids[1:]):
            if b.t0_ns < a.t1_ns:
                overlaps.append((a.name, b.name,
                                 (a.t1_ns - b.t0_ns) / 1e3))
    return {
        "parents": len(parents),
        "coverage": covered_ns / total_ns if total_ns else 0.0,
        "uncovered_us": (total_ns - covered_ns) / 1e3,
        "overlap_errors": overlaps,
    }


# per-phase breakdown schema shared by serve_bench and kernels_bench —
# identical keys, whatever the bench (the acceptance criterion)
BREAKDOWN_SCHEMA_KEYS = ("wall_us", "coverage", "phases")
_PHASE_KEYS = ("total_us", "count", "frac")


def phase_breakdown(tracer: Tracer, parent: str | None = None) -> dict:
    """Aggregate spans into a per-phase breakdown keyed by category.

    With ``parent`` given (e.g. "engine.step"), only *direct children*
    of that span are aggregated and ``wall_us`` is the summed parent
    time — the serving shape: prefill vs decode vs scheduler vs
    host_sync as fractions of engine step wall.  Without it, root spans
    (parent_id == 0) are aggregated — the kernel-bench shape: warmup vs
    timed launches.  Both emit the same schema (BREAKDOWN_SCHEMA_KEYS).
    """
    spans = tracer.spans()
    if parent is None:
        sel = [s for s in spans if s.parent_id == 0]
        wall_ns = sum(s.dur_ns for s in sel)
    else:
        pids = {s.sid for s in spans if s.name == parent}
        sel = [s for s in spans if s.parent_id in pids]
        wall_ns = sum(s.dur_ns for s in spans if s.name == parent)
    phases: dict[str, dict] = {}
    for s in sel:
        ph = phases.setdefault(s.cat or "other",
                               {"total_us": 0.0, "count": 0, "frac": 0.0})
        ph["total_us"] += s.dur_ns / 1e3
        ph["count"] += 1
    for ph in phases.values():
        ph["total_us"] = round(ph["total_us"], 1)
        ph["frac"] = round(ph["total_us"] / max(wall_ns / 1e3, 1e-9), 4)
    return {
        "wall_us": round(wall_ns / 1e3, 1),
        "coverage": round(sum(p["total_us"] for p in phases.values())
                          / max(wall_ns / 1e3, 1e-9), 4),
        "phases": phases,
    }
