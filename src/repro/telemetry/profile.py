"""Kernel launch profiling (DESIGN §12): the one timing harness both
benches consume instead of their ad-hoc best-of-N loops.

``time_launch`` runs a jitted callable with explicit warmup discard
(compile + cache effects never pollute the sample), records every timed
iteration into a telemetry ``Histogram`` (fixed log-spaced buckets), and
returns best / p50 / p95 microseconds plus — when the caller passes the
pack's streamed plane bytes — the effective GB/s the launch sustained
and its fraction of the *dense roofline* (the bandwidth the dense matmul
achieved on the same device: the paper's own yardstick, Section IV).

``KernelProfiler`` accumulates launches keyed by (shape, impl, quant, B)
so a bench or a serving process can dump one per-kernel report.
"""
from __future__ import annotations

import dataclasses
import time

from repro.telemetry.metrics import US_BUCKETS, Histogram
from repro.telemetry.trace import NULL_TRACER

__all__ = ["LaunchTiming", "time_launch", "KernelProfiler"]


@dataclasses.dataclass
class LaunchTiming:
    """One profiled launch site.  Times in microseconds."""
    iters: int
    warmup: int
    best_us: float
    p50_us: float
    p95_us: float
    mean_us: float
    bytes_moved: int | None = None       # value+index plane bytes per call
    gbps_best: float | None = None       # bytes_moved at best_us
    roofline_frac: float | None = None   # vs dense GB/s on same device

    def to_dict(self) -> dict:
        d = {"iters": self.iters, "warmup": self.warmup,
             "best_us": round(self.best_us, 1),
             "p50_us": round(self.p50_us, 1),
             "p95_us": round(self.p95_us, 1),
             "mean_us": round(self.mean_us, 1)}
        if self.bytes_moved is not None:
            d["bytes_moved"] = int(self.bytes_moved)
            d["gbps_best"] = round(self.gbps_best, 3)
        if self.roofline_frac is not None:
            d["roofline_frac"] = round(self.roofline_frac, 3)
        return d


def _block(x):
    # works for jax arrays and pytrees of them; tolerates plain numpy
    blocker = getattr(x, "block_until_ready", None)
    if blocker is not None:
        blocker()
        return
    import jax
    jax.block_until_ready(x)


def time_launch(fn, *args, iters: int = 5, warmup: int = 1,
                bytes_moved: int | None = None,
                dense_bytes: int | None = None,
                dense_us: float | None = None,
                tracer=NULL_TRACER, label: str = "launch") -> LaunchTiming:
    """Profile ``fn(*args)``: ``warmup`` discarded calls (compile), then
    ``iters`` timed calls, each fenced with block_until_ready so async
    dispatch cannot smear across iterations.  Timed iterations land in a
    log-bucket Histogram — p50/p95 are its streaming quantiles, ``best``
    is exact (the benches' historic best-of figure, kept byte-compatible).

    ``bytes_moved`` (the pack's value+index plane bytes per call) turns
    the best time into effective GB/s; adding ``dense_bytes``+``dense_us``
    (the dense matmul on the same shapes) expresses it as a fraction of
    the dense roofline.
    """
    if iters < 1 or warmup < 0:
        raise ValueError(f"bad iters={iters} warmup={warmup}")
    for _ in range(max(1, warmup)):
        with tracer.span(label, cat="warmup"):
            out = fn(*args)
            _block(out)
    hist = Histogram("launch_us", {}, edges=US_BUCKETS)
    best = float("inf")
    for _ in range(iters):
        with tracer.span(label, cat="timed"):
            t0 = time.perf_counter()
            out = fn(*args)
            _block(out)
            us = (time.perf_counter() - t0) * 1e6
        hist.observe(us)
        best = min(best, us)
    t = LaunchTiming(iters=iters, warmup=max(1, warmup), best_us=best,
                     p50_us=hist.quantile(0.50), p95_us=hist.quantile(0.95),
                     mean_us=hist.sum / hist.count)
    if bytes_moved is not None:
        t.bytes_moved = int(bytes_moved)
        t.gbps_best = bytes_moved / max(best * 1e-6, 1e-12) / 1e9
        if dense_bytes is not None and dense_us is not None and dense_us > 0:
            dense_gbps = dense_bytes / (dense_us * 1e-6) / 1e9
            t.roofline_frac = t.gbps_best / max(dense_gbps, 1e-12)
    return t


class KernelProfiler:
    """Accumulates launch profiles keyed by (shape, impl, quant, B)."""

    def __init__(self, tracer=NULL_TRACER):
        self.tracer = tracer
        self.records: dict[tuple, LaunchTiming] = {}

    def profile(self, fn, *args, shape: str, impl: str = "ref",
                quant: str = "fp", B: int = 1, **kw) -> LaunchTiming:
        key = (shape, impl, quant, B)
        t = time_launch(fn, *args, tracer=self.tracer,
                        label=f"kernel:{shape}/{quant}/B{B}", **kw)
        self.records[key] = t
        return t

    def report(self) -> dict:
        return {
            f"{shape}|impl={impl}|quant={quant}|B={b}": t.to_dict()
            for (shape, impl, quant, b), t in sorted(self.records.items())}
