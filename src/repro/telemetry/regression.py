"""Noise-aware perf-regression sentinel (DESIGN.md §14).

The bench artifacts (``BENCH_*.json``) record headline metrics with
repeat statistics (best / p50 / p95 from interleaved round-robin
repeats), but until this module nothing *gated* on them — a kernel
regression only surfaced when a human eyeballed the JSON.  The sentinel
compares an observed bench run against a checked-in baseline with
tolerance semantics that match how each metric can legitimately move:

* ``exact``         — determinism invariants (bytes/token, bits/nnz):
  the value is a function of the pack geometry, not the host, so any
  drift beyond float slop is a real change.  ``rel_tol`` is 0 (or tiny).
* ``higher_better`` — throughput.  Host noise moves timing runs both
  ways, so the bound is one-sided and windowed: observed must stay
  above ``baseline.lo / (1 + rel_tol)`` where ``lo`` is the baseline's
  p50 (its *pessimistic* side).  A generous ``rel_tol`` (~2.0, i.e. a
  3x band) keeps CI quiet across machines while still catching the
  order-of-magnitude cliffs that matter (a dropped fusion, an
  accidental dense fallback, a host sync in the decode loop).
* ``lower_better``  — latency (TTFT/TPOT p95, µs/call).  Observed must
  stay below ``baseline.hi * (1 + rel_tol)`` where ``hi`` is the
  baseline's p95.

Baselines are plain dicts ``{metric: {"value", "lo", "hi"}}`` (see
``benchmarks/bench_history.py`` for extraction from bench docs); the
metric *policy* (kind + tolerance) lives in code so tightening a band
never requires regenerating baselines.  A metric present in the
baseline but missing from the observed run is itself a failure — a
silently dropped bench section must not pass the gate.
"""
from __future__ import annotations

import dataclasses

__all__ = ["MetricSpec", "PerfRegressionError", "compare",
           "format_findings", "assert_no_regression"]

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    key: str
    kind: str            # "exact" | "higher_better" | "lower_better"
    rel_tol: float = 0.0

    def __post_init__(self):
        if self.kind not in ("exact", "higher_better", "lower_better"):
            raise ValueError(f"unknown metric kind {self.kind!r}")
        if self.rel_tol < 0:
            raise ValueError("rel_tol must be >= 0")


class PerfRegressionError(AssertionError):
    """Raised by ``assert_no_regression``; carries the findings list."""

    def __init__(self, message: str, findings: list):
        super().__init__(message)
        self.findings = findings


def _entry(raw) -> dict:
    """Normalize a baseline entry: bare numbers mean a degenerate
    window (value == lo == hi)."""
    if isinstance(raw, dict):
        v = float(raw["value"])
        return {"value": v, "lo": float(raw.get("lo", v)),
                "hi": float(raw.get("hi", v))}
    v = float(raw)
    return {"value": v, "lo": v, "hi": v}


def compare(baseline: dict, observed: dict, specs: list[MetricSpec]) -> list:
    """Evaluate every spec; returns one finding per metric:
    ``{"metric", "kind", "ok", "baseline", "observed", "bound",
    "rel_tol", "detail"}``.  Specs whose key is absent from the
    *baseline* are skipped (new metrics phase in by refreshing the
    baseline); absent from *observed* while present in baseline fails.
    """
    findings = []
    for spec in specs:
        if spec.key not in baseline:
            continue
        b = _entry(baseline[spec.key])
        if spec.key not in observed or observed[spec.key] is None:
            findings.append({
                "metric": spec.key, "kind": spec.kind, "ok": False,
                "baseline": b, "observed": None, "bound": None,
                "rel_tol": spec.rel_tol,
                "detail": "metric missing from observed run"})
            continue
        o = float(_entry(observed[spec.key])["value"]) \
            if isinstance(observed[spec.key], dict) \
            else float(observed[spec.key])
        if spec.kind == "exact":
            bound = spec.rel_tol * max(abs(b["value"]), _EPS)
            ok = abs(o - b["value"]) <= max(bound, _EPS)
            detail = (f"|{o:g} - {b['value']:g}| <= {max(bound, _EPS):g}"
                      if ok else
                      f"exact metric drifted: {b['value']:g} -> {o:g}")
        elif spec.kind == "higher_better":
            bound = b["lo"] / (1.0 + spec.rel_tol)
            ok = o >= bound
            detail = (f"{o:g} >= floor {bound:g}" if ok else
                      f"{o:g} fell below floor {bound:g} "
                      f"(baseline window [{b['lo']:g}, {b['hi']:g}])")
        else:  # lower_better
            bound = b["hi"] * (1.0 + spec.rel_tol)
            ok = o <= bound
            detail = (f"{o:g} <= ceiling {bound:g}" if ok else
                      f"{o:g} exceeded ceiling {bound:g} "
                      f"(baseline window [{b['lo']:g}, {b['hi']:g}])")
        findings.append({"metric": spec.key, "kind": spec.kind, "ok": ok,
                         "baseline": b, "observed": o, "bound": bound,
                         "rel_tol": spec.rel_tol, "detail": detail})
    return findings


def format_findings(findings: list, *, only_bad: bool = False) -> str:
    """Human-readable table — CI prints this on failure so the offending
    metric, its baseline window, and the observed value are in the log."""
    lines = []
    for f in findings:
        if only_bad and f["ok"]:
            continue
        mark = "ok  " if f["ok"] else "FAIL"
        b = f["baseline"]
        obs = "MISSING" if f["observed"] is None else f"{f['observed']:g}"
        lines.append(
            f"  [{mark}] {f['metric']} ({f['kind']}, rel_tol="
            f"{f['rel_tol']:g}): observed {obs} vs baseline "
            f"{b['value']:g} [{b['lo']:g}, {b['hi']:g}] — {f['detail']}")
    return "\n".join(lines)


def assert_no_regression(baseline: dict, observed: dict,
                         specs: list[MetricSpec],
                         *, label: str = "bench") -> list:
    """``compare`` + raise ``PerfRegressionError`` listing every failed
    metric (name, baseline window, observed value).  Returns the full
    findings list when everything passes."""
    findings = compare(baseline, observed, specs)
    bad = [f for f in findings if not f["ok"]]
    if bad:
        raise PerfRegressionError(
            f"perf regression in {label}: {len(bad)}/{len(findings)} "
            f"gated metric(s) out of band\n"
            + format_findings(bad), findings)
    return findings
