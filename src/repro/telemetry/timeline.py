"""Per-request timeline reconstruction from traces (DESIGN.md §14).

ESPIM's sparsity plan is static (SDDS), so every per-request cost is
attributable — but the PR 7 telemetry aggregated everything into
engine-level histograms.  This module closes the gap: the engine and
scheduler emit ``rid``-keyed lifecycle instants (``req.queued`` /
``req.admit`` / ``req.first_token`` / ``req.requeue`` / ``req.terminal``,
plus the existing ``fault.*`` marks) and tag the work spans that serve a
request (``prefill.chunk`` carries ``rid``, ``decode.step`` carries the
``rids`` of every slot it batched), and ``build_timelines`` folds them
back into one ``RequestTimeline`` per request: an exact partition of the
request's wall clock (queued → prefill chunks → decode ticks → terminal
state) whose segment sum IS the request's latency, with TTFT/TPOT
derivable from the same marks the engine's ``RequestMetrics`` record.

Timelines reconstruct from any of the tracer's three forms — the live
``Tracer``, an exported Perfetto/Chrome ``trace_event`` doc, or the
JSONL event log — so a post-mortem needs only the artifact, never the
process that wrote it.

Segment kinds (a partition of ``t_queued .. t_terminal``):

* ``queued``  — waiting for admission (initial queue, or re-queued after
  a preemption: the request holds no slot).
* ``prefill`` — inside a ``prefill.chunk`` span that fed this request.
* ``decode``  — inside a ``decode.step`` span whose batch included it.
* ``wait``    — resident in a slot but not inside its own work span
  (other slots' prefill ticks, scheduler/bookkeeping time).

Clock caveat: timeline timestamps are the tracer's ``perf_counter_ns``;
the engine's ``RequestMetrics`` use ``time.monotonic()``.  Durations
(TTFT, TPOT, segment sums) are comparable across the two on mainstream
platforms (both are CLOCK_MONOTONIC on Linux); absolute values are not.
``check_timelines`` asserts the cross-clock agreement within tolerance.
"""
from __future__ import annotations

import dataclasses
import json

__all__ = ["Segment", "RequestTimeline", "build_timelines",
           "timelines_from_tracer", "timelines_from_chrome",
           "timelines_from_jsonl", "check_timelines", "format_timeline",
           "LIFECYCLE_INSTANTS"]

# the rid-keyed lifecycle marks the scheduler/engine emit (cat "request")
LIFECYCLE_INSTANTS = ("req.queued", "req.admit", "req.first_token",
                      "req.requeue", "req.terminal")
# fault-ladder instants that carry a rid and land in timeline.events
_FAULT_MARKS = ("fault.shed", "fault.preempt", "fault.resume",
                "fault.quarantine", "fault.restore")
_WORK_SPANS = ("prefill.chunk", "decode.step")


@dataclasses.dataclass
class Segment:
    kind: str          # "queued" | "prefill" | "decode" | "wait"
    t0_ns: int
    t1_ns: int

    @property
    def dur_s(self) -> float:
        return (self.t1_ns - self.t0_ns) / 1e9


@dataclasses.dataclass
class RequestTimeline:
    rid: int
    state: str | None = None          # terminal state, None if unfinished
    t_queued_ns: int | None = None
    t_admit_ns: int | None = None     # first admission
    t_first_ns: int | None = None     # first emitted token
    t_terminal_ns: int | None = None
    n_out: int = 0
    preempts: int = 0
    quarantines: int = 0
    segments: list = dataclasses.field(default_factory=list)
    # (t_ns, name, args) lifecycle + fault marks, time order
    events: list = dataclasses.field(default_factory=list)

    @property
    def complete(self) -> bool:
        """A reconstructable lifecycle: queued + terminal marks present,
        and — for states that delivered output — a first-token mark."""
        if self.t_queued_ns is None or self.state is None:
            return False
        if self.state in ("completed", "degraded"):
            return self.t_first_ns is not None
        return True

    @property
    def wall_s(self) -> float | None:
        if self.t_queued_ns is None or self.t_terminal_ns is None:
            return None
        return (self.t_terminal_ns - self.t_queued_ns) / 1e9

    @property
    def ttft_s(self) -> float | None:
        if self.t_queued_ns is None or self.t_first_ns is None:
            return None
        return (self.t_first_ns - self.t_queued_ns) / 1e9

    @property
    def tpot_s(self) -> float | None:
        """Mean time-per-output-token after the first — same definition
        as ``RequestMetrics.tpot``."""
        if (self.t_first_ns is None or self.t_terminal_ns is None
                or self.n_out < 2):
            return None
        return ((self.t_terminal_ns - self.t_first_ns) / 1e9
                / (self.n_out - 1))

    def segment_sum_s(self) -> float:
        return sum(s.dur_s for s in self.segments)

    def by_kind(self) -> dict:
        out: dict = {}
        for s in self.segments:
            out[s.kind] = out.get(s.kind, 0.0) + s.dur_s
        return out


# ------------------------------------------------------------ event sources
def _norm_span(name, cat, t0_ns, t1_ns, args):
    return {"type": "span", "name": name, "cat": cat,
            "t0_ns": int(t0_ns), "t1_ns": int(t1_ns), "args": args or {}}


def _norm_instant(name, cat, t_ns, args):
    return {"type": "instant", "name": name, "cat": cat,
            "t_ns": int(t_ns), "args": args or {}}


def timelines_from_tracer(tracer) -> dict:
    """Reconstruct straight from a live ``Tracer`` (absolute ns)."""
    events = [_norm_span(s.name, s.cat, s.t0_ns, s.t1_ns, s.args)
              for s in tracer.spans()]
    events += [_norm_instant(name, cat, t_ns, args)
               for name, cat, t_ns, _tid, args in list(tracer.instants)]
    return build_timelines(events)


def timelines_from_chrome(doc: dict) -> dict:
    """Reconstruct from an exported Perfetto/Chrome ``trace_event`` doc
    (timestamps are relative microseconds; converted to ns)."""
    events = []
    for ev in doc["traceEvents"]:
        if ev["ph"] == "X":
            t0 = round(ev["ts"] * 1e3)
            events.append(_norm_span(ev["name"], ev.get("cat"), t0,
                                     t0 + round(ev["dur"] * 1e3),
                                     ev.get("args")))
        elif ev["ph"] == "i":
            events.append(_norm_instant(ev["name"], ev.get("cat"),
                                        round(ev["ts"] * 1e3),
                                        ev.get("args")))
    return build_timelines(events)


def timelines_from_jsonl(path: str) -> dict:
    """Reconstruct from the tracer's JSONL event log (header skipped)."""
    events = []
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            if rec["type"] == "span":
                events.append(_norm_span(rec["name"], rec.get("cat"),
                                         rec["t0_ns"], rec["t1_ns"],
                                         rec.get("args")))
            elif rec["type"] == "instant":
                events.append(_norm_instant(rec["name"], rec.get("cat"),
                                            rec["t_ns"], rec.get("args")))
    return build_timelines(events)


# ----------------------------------------------------------------- builder
def build_timelines(events: list[dict]) -> dict:
    """Fold normalized events into ``{rid: RequestTimeline}``.

    Robust to partial traces (a killed engine's requests simply stay
    incomplete) and to duplicate lifecycle marks across engines sharing
    one tracer (crash drill: restore re-queues the same rid — the first
    ``req.queued`` and the last ``req.terminal`` win)."""
    tls: dict[int, RequestTimeline] = {}

    def tl(rid) -> RequestTimeline:
        rid = int(rid)
        if rid not in tls:
            tls[rid] = RequestTimeline(rid=rid)
        return tls[rid]

    work: dict[int, list] = {}       # rid -> [(t0, t1, kind)]
    resident: dict[int, list] = {}   # rid -> residency change marks

    for ev in events:
        args = ev["args"]
        if ev["type"] == "instant":
            name, t_ns = ev["name"], ev["t_ns"]
            rid = args.get("rid")
            if rid is None:
                continue
            t = tl(rid)
            if name in LIFECYCLE_INSTANTS or name in _FAULT_MARKS:
                t.events.append((t_ns, name, args))
            if name == "req.queued":
                if t.t_queued_ns is None or t_ns < t.t_queued_ns:
                    t.t_queued_ns = t_ns
            elif name == "req.admit":
                if t.t_admit_ns is None:
                    t.t_admit_ns = t_ns
                resident.setdefault(int(rid), []).append((t_ns, True))
            elif name == "req.first_token":
                if t.t_first_ns is None:
                    t.t_first_ns = t_ns
            elif name == "req.terminal":
                t.t_terminal_ns = t_ns
                t.state = args.get("state")
                t.n_out = int(args.get("n_out", t.n_out))
            elif name in ("fault.preempt", "req.requeue"):
                if name == "fault.preempt":
                    t.preempts += 1
                    resident.setdefault(int(rid), []).append((t_ns, False))
            elif name == "fault.quarantine":
                t.quarantines += 1
        else:  # span
            name = ev["name"]
            if name == "prefill.chunk" and "rid" in args:
                work.setdefault(int(args["rid"]), []).append(
                    (ev["t0_ns"], ev["t1_ns"], "prefill"))
            elif name == "decode.step":
                for rid in args.get("rids", ()):
                    work.setdefault(int(rid), []).append(
                        (ev["t0_ns"], ev["t1_ns"], "decode"))

    for rid, t in tls.items():
        t.events.sort(key=lambda e: e[0])
        t.segments = _segments(t, sorted(work.get(rid, ())),
                               sorted(resident.get(rid, ())))
    return tls


def _segments(t: RequestTimeline, work: list, resident: list) -> list:
    """Exact partition of [t_queued, t_terminal]: work spans clipped to
    the window, gaps classified queued/wait by slot residency."""
    if t.t_queued_ns is None:
        return []
    t1 = t.t_terminal_ns
    if t1 is None:
        t1 = max([t.t_queued_ns]
                 + [w[1] for w in work]
                 + [m[0] for m in resident])
    segs: list[Segment] = []

    def resident_at(ts: int) -> bool:
        on = False
        for m_ts, m_on in resident:
            if m_ts > ts:
                break
            on = m_on
        return on

    def fill_gap(a: int, b: int) -> None:
        if b <= a:
            return
        # split the gap at residency flips so queued vs wait is exact
        cuts = [a] + [m_ts for m_ts, _ in resident if a < m_ts < b] + [b]
        for lo, hi in zip(cuts, cuts[1:]):
            if hi <= lo:
                continue
            kind = "wait" if resident_at(lo) else "queued"
            if segs and segs[-1].kind == kind and segs[-1].t1_ns == lo:
                segs[-1].t1_ns = hi
            else:
                segs.append(Segment(kind, lo, hi))

    cursor = t.t_queued_ns
    for w0, w1, kind in work:
        w0, w1 = max(w0, t.t_queued_ns), min(w1, t1)
        if w1 <= cursor:
            continue
        w0 = max(w0, cursor)
        fill_gap(cursor, w0)
        segs.append(Segment(kind, w0, w1))
        cursor = w1
    fill_gap(cursor, t1)
    return segs


# -------------------------------------------------------------- validation
def check_timelines(timelines: dict, metrics_by_rid: dict | None = None,
                    tol_s: float = 0.05) -> dict:
    """Assert the reconstruction contract over a traced run:

    * every timeline is ``complete`` (queued + terminal, first token when
      output was delivered);
    * segments partition the request's wall exactly (sum == wall);
    * with ``metrics_by_rid`` (rid -> the engine's ``RequestMetrics``),
      the timeline's TTFT/TPOT agree with the engine's within ``tol_s``
      — a cross-clock, cross-codepath consistency check.

    Returns a summary report (requests / complete / states / max errors).
    """
    states: dict = {}
    max_ttft_err = max_tpot_err = 0.0
    n_complete = 0
    for rid, t in timelines.items():
        if t.complete:
            n_complete += 1
        else:
            raise AssertionError(
                f"rid {rid}: incomplete timeline (state={t.state}, "
                f"queued={t.t_queued_ns is not None}, "
                f"first={t.t_first_ns is not None}) — events: "
                f"{[(n, a) for _, n, a in t.events]}")
        states[t.state] = states.get(t.state, 0) + 1
        wall = t.wall_s
        if wall is not None and t.segments:
            gap = abs(t.segment_sum_s() - wall)
            assert gap < 1e-6, (
                f"rid {rid}: segments sum {t.segment_sum_s():.6f}s != "
                f"wall {wall:.6f}s — not a partition")
        if metrics_by_rid is None or rid not in metrics_by_rid:
            continue
        m = metrics_by_rid[rid]
        for label, mine, theirs in (("ttft", t.ttft_s, m.ttft),
                                    ("tpot", t.tpot_s, m.tpot)):
            if mine is None or theirs is None:
                continue
            err = abs(mine - theirs)
            assert err <= tol_s, (
                f"rid {rid}: timeline {label} {mine:.4f}s vs engine "
                f"{theirs:.4f}s (|err| {err:.4f}s > tol {tol_s}s)")
            if label == "ttft":
                max_ttft_err = max(max_ttft_err, err)
            else:
                max_tpot_err = max(max_tpot_err, err)
    return {"requests": len(timelines), "complete": n_complete,
            "states": states,
            "max_ttft_err_s": round(max_ttft_err, 6),
            "max_tpot_err_s": round(max_tpot_err, 6)}


# --------------------------------------------------------------- rendering
def format_timeline(t: RequestTimeline, width: int = 48) -> str:
    """One-request ASCII strip: lifecycle header plus a proportional
    segment bar (q=queued, p=prefill, d=decode, .=wait)."""
    glyph = {"queued": "q", "prefill": "p", "decode": "d", "wait": "."}
    wall = t.wall_s or 0.0
    bar = ""
    if wall > 0 and t.segments:
        for s in t.segments:
            bar += glyph[s.kind] * max(1, round(s.dur_s / wall * width))
    parts = [f"rid {t.rid}: {t.state or 'in_flight'}"]
    if wall:
        parts.append(f"{wall * 1e3:.1f}ms wall")
    if t.ttft_s is not None:
        parts.append(f"ttft {t.ttft_s * 1e3:.1f}ms")
    if t.tpot_s is not None:
        parts.append(f"tpot {t.tpot_s * 1e3:.2f}ms")
    if t.preempts:
        parts.append(f"preempts {t.preempts}")
    if t.quarantines:
        parts.append(f"quarantines {t.quarantines}")
    head = ", ".join(parts)
    kinds = t.by_kind()
    detail = " ".join(f"{k}={v * 1e3:.1f}ms"
                      for k, v in sorted(kinds.items()))
    return f"{head}\n  [{bar}]\n  {detail}" if bar else head
