"""Zero-dependency observability for the sparse serving stack (DESIGN §12).

Three layers, threaded through the whole pipeline:

* ``trace``   — nested span tracer (thread-safe, ~no-op when disabled)
  with Perfetto/Chrome ``trace_event`` and JSONL exporters, span
  coverage analysis, and the shared per-phase breakdown schema.
* ``metrics`` — counters / gauges / log-bucket histograms with labels,
  dict snapshots, Prometheus text format, and the streaming-quantile
  summaries that replaced the full-sort percentile path.
* ``profile`` — kernel launch profiling (warmup discard, best/p50/p95,
  effective GB/s vs the dense roofline) consumed by both benches.
"""
from repro.telemetry.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                                     LATENCY_BUCKETS_S,
                                     REQUIRED_SERVE_METRICS, Registry,
                                     THROUGHPUT_BUCKETS, US_BUCKETS,
                                     log_buckets, validate_snapshot)
from repro.telemetry.profile import (KernelProfiler,  # noqa: F401
                                     LaunchTiming, time_launch)
from repro.telemetry.trace import (BREAKDOWN_SCHEMA_KEYS,  # noqa: F401
                                   NULL_TRACER, Span, Tracer, get_tracer,
                                   phase_breakdown, set_tracer,
                                   span_coverage, validate_chrome_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "log_buckets",
    "LATENCY_BUCKETS_S", "THROUGHPUT_BUCKETS", "US_BUCKETS",
    "REQUIRED_SERVE_METRICS", "validate_snapshot",
    "KernelProfiler", "LaunchTiming", "time_launch",
    "Span", "Tracer", "NULL_TRACER", "get_tracer", "set_tracer",
    "span_coverage", "phase_breakdown", "validate_chrome_trace",
    "BREAKDOWN_SCHEMA_KEYS",
]
