"""Zero-dependency observability for the sparse serving stack (DESIGN §12).

Three layers, threaded through the whole pipeline:

* ``trace``   — nested span tracer (thread-safe, ~no-op when disabled)
  with Perfetto/Chrome ``trace_event`` and JSONL exporters, span
  coverage analysis, and the shared per-phase breakdown schema.
* ``metrics`` — counters / gauges / log-bucket histograms with labels,
  dict snapshots, Prometheus text format, and the streaming-quantile
  summaries that replaced the full-sort percentile path.
* ``profile`` — kernel launch profiling (warmup discard, best/p50/p95,
  effective GB/s vs the dense roofline) consumed by both benches.

Second layer (DESIGN §14), request-scoped and always-on:

* ``flightrec``  — bounded ring of recent request/fault events every
  engine feeds unconditionally; the fault ladder dumps it to
  ``FLIGHT_*.json`` so post-mortems never require a traced re-run.
* ``timeline``   — reconstructs per-request lifecycles (queued →
  prefill chunks → decode ticks → terminal state) from a live tracer,
  a Chrome trace, or a JSONL event log.
* ``regression`` — noise-aware perf-regression sentinel (exact vs
  windowed one-sided tolerance bands) gated by CI via
  ``benchmarks/bench_history.py``.
"""
from repro.telemetry.flightrec import (FlightRecorder,  # noqa: F401
                                       get_recorder, set_recorder)
from repro.telemetry.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                                     LATENCY_BUCKETS_S,
                                     REQUIRED_SERVE_METRICS, Registry,
                                     THROUGHPUT_BUCKETS, US_BUCKETS,
                                     log_buckets, validate_snapshot)
from repro.telemetry.profile import (KernelProfiler,  # noqa: F401
                                     LaunchTiming, time_launch)
from repro.telemetry.regression import (MetricSpec,  # noqa: F401
                                        PerfRegressionError,
                                        assert_no_regression, compare,
                                        format_findings)
from repro.telemetry.timeline import (RequestTimeline, Segment,  # noqa: F401
                                      build_timelines, check_timelines,
                                      format_timeline,
                                      timelines_from_chrome,
                                      timelines_from_jsonl,
                                      timelines_from_tracer)
from repro.telemetry.trace import (BREAKDOWN_SCHEMA_KEYS,  # noqa: F401
                                   NULL_TRACER, Span, Tracer, get_tracer,
                                   phase_breakdown, set_tracer,
                                   span_coverage, validate_chrome_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "log_buckets",
    "LATENCY_BUCKETS_S", "THROUGHPUT_BUCKETS", "US_BUCKETS",
    "REQUIRED_SERVE_METRICS", "validate_snapshot",
    "KernelProfiler", "LaunchTiming", "time_launch",
    "Span", "Tracer", "NULL_TRACER", "get_tracer", "set_tracer",
    "span_coverage", "phase_breakdown", "validate_chrome_trace",
    "BREAKDOWN_SCHEMA_KEYS",
    "FlightRecorder", "get_recorder", "set_recorder",
    "Segment", "RequestTimeline", "build_timelines",
    "timelines_from_tracer", "timelines_from_chrome",
    "timelines_from_jsonl", "check_timelines", "format_timeline",
    "MetricSpec", "PerfRegressionError", "compare",
    "assert_no_regression", "format_findings",
]
