"""Partitioning rules: param/batch/cache pytrees -> PartitionSpec trees.

Strategy (DESIGN.md section 5):
  * TP on ``model`` for head/ffn/vocab dims (column-parallel up/QKV,
    row-parallel down/out projections, EP for MoE experts);
  * FSDP on ``data`` for the non-TP weight dim (XLA all-gathers per layer
    inside the scan — ZeRO-3 with overlap);
  * batch dims on ``('pod', 'data')`` when the pod axis exists;
  * every rule degrades gracefully: an axis is only used if the dim is
    divisible by its mesh extent (e.g. qwen2.5's 40 heads shard on the flat
    5120 feature dim; granite's 49155 vocab shards via the padded table).

Optimizer state inherits the param spec leaf-for-leaf.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = [
    "batch_axes", "mesh_axis_size", "param_pspecs", "batch_pspecs",
    "cache_pspecs", "paged_cache_pspecs", "sparse_pack_pspecs", "named",
    "logical_to_sharding",
]


def mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def batch_axes(mesh: Mesh):
    """The composed data-parallel axis: ('pod','data') on multi-pod."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _fit(mesh: Mesh, dim: int, axis):
    """axis if dim divides by its extent, else None (replicate)."""
    if axis is None:
        return None
    return axis if dim % mesh_axis_size(mesh, axis) == 0 else None


def _spec(mesh: Mesh, shape, axes):
    """Build a PartitionSpec, dropping axes that do not divide."""
    return P(*(_fit(mesh, d, a) for d, a in zip(shape, axes)))


# Rules match on exact leaf names / path suffixes (NOT substrings: "u" is a
# real RWKV leaf and must not swallow "w_up").  Leading layer-stack dims are
# never sharded (the scan slices them).
_ROW_PARALLEL = ("w_down", "out_proj", "attn/wo", "self_attn/wo",
                 "cross_attn/wo", "tm/wo", "cm/wv")
_REPLICATED_LEAVES = {"w", "b", "a_log", "d_skip", "dt_bias", "mix", "w0",
                      "u", "conv_b", "norm_w", "ln_x", "router"}


def _param_rule(path: str, shape, mesh: Mesh, fsdp: bool, tp):
    dp = "data" if fsdp else None
    nd = len(shape)
    leaf = path.rsplit("/", 1)[-1]
    stacked = "layers/" in path  # leading dim is the scan axis

    def tail(*axes):
        return _spec(mesh, shape, (None,) * (nd - len(axes)) + tuple(axes))

    if leaf in _REPLICATED_LEAVES:
        return P(*(None,) * nd)
    # head-structured weights never take the wide TP axis: splitting a
    # head_dim across devices turns every QK/PV contraction into a
    # partial-sum all-reduce of full score tensors (refuted iter 4,
    # EXPERIMENTS.md Perf)
    headed = any(k in path for k in
                 ("attn/", "tm/", "mamba/", "conv_w"))
    wtp = "model" if headed else tp
    # an axis may appear once per spec: FSDP yields to a wide TP that
    # already uses 'data'
    wide_uses_data = isinstance(wtp, (tuple, list)) and "data" in wtp
    dpw = None if wide_uses_data else dp
    tp_uses_data = isinstance(tp, (tuple, list)) and "data" in tp
    dpt = None if tp_uses_data else dp
    # MoE experts: EP on 'model'; the FFN dim takes 'data' — via FSDP on
    # d_model when training, via TP on d_ff when serving (fsdp=False), so
    # expert weights never sit replicated across the data axis
    if "moe/w_gate" in path or "moe/w_up" in path:    # (L, E, D, F)
        return tail("model", dp, None if fsdp else "data")
    if "moe/w_down" in path:                          # (L, E, F, D)
        return tail("model", None if fsdp else "data", dp)
    if path.endswith("pos_embed") or path.endswith("embed"):  # (V|S, D)
        return tail(tp, None)  # vocab-sharded: logits stay V-sharded
    if path.endswith("lm_head"):                      # (D, V)
        return tail(dpt, tp)
    if "conv_w" in path:                              # (L, K, C)
        return tail(None, wtp)
    if any(path.endswith(k) or f"{k}/" in path for k in _ROW_PARALLEL):
        return tail(wtp, dpw)                         # (L, F_in, D)
    if nd >= 3 or (nd == 2 and not stacked):          # column-parallel default
        return tail(dpw, wtp)
    if nd == 2:                                       # stacked bias (L, F)
        return tail(wtp)
    return P(*(None,) * nd)                           # scalars / 1-D


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_pspecs(params_or_shapes, mesh: Mesh, fsdp: bool = True,
                 tp="model"):
    """PartitionSpec tree matching a params (or eval_shape) pytree.

    ``tp`` is the tensor-parallel axis (or axis tuple).  Serving uses
    ``tp=('data','model')`` — "2D TP": decode is a pin-bandwidth-bound MV
    (the paper's workload), so every chip becomes an ESPIM "bank" holding a
    weight slice and the per-device weight stream shrinks by the data-axis
    extent; the idle batch axis costs nothing (hillclimb iter 4).
    MoE experts stay on 'model' (EP) in either mode.
    """
    def leaf_spec(path, leaf):
        return _param_rule(_path_str(path), leaf.shape, mesh, fsdp, tp)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_or_shapes)


def serve_param_pspecs(params_or_shapes, mesh: Mesh,
                       global_batch: int | None = None):
    """Decode-time param layout: no FSDP, TP over (data x model).

    At global_batch == 1 (long-context single-stream decode) the
    contraction dim additionally shards over 'data': partial-sum outputs
    are KBs, so XLA picks psum over weight all-gathers and the per-device
    weight stream drops by the data extent (hillclimb iter 8)."""
    tp = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    fsdp = global_batch == 1
    return param_pspecs(params_or_shapes, mesh, fsdp=fsdp, tp=tp)


def batch_pspecs(batch_tree, mesh: Mesh):
    """Shard every leading batch dim over ('pod','data') when divisible."""
    ba = batch_axes(mesh)

    def leaf_spec(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        if leaf.shape[0] == 3 and nd == 3:  # positions3 (3, B, S)
            return _spec(mesh, leaf.shape, (None, ba, None))
        return _spec(mesh, leaf.shape, (ba,) + (None,) * (nd - 1))

    return jax.tree_util.tree_map(leaf_spec, batch_tree)


def cache_pspecs(cache_tree, mesh: Mesh):
    """Decode caches: (L, B, S, KV, hd) and friends.

    B -> ('pod','data') when divisible; heads -> 'model' when divisible,
    else the sequence/state dim picks up 'model' (length-sharded cache with
    partial-softmax collectives).
    """
    ba = batch_axes(mesh)

    def leaf_spec(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if nd <= 1:
            return P(*(None,) * nd)
        if name.endswith("len"):
            return P(None)
        if nd == 5 or name.endswith("_scale"):
            # (L, B, S, KV, hd) kv cache / (L, B, H, K, V) wkv state /
            # (L, B, S, KV) int8-cache scales — same layout logic
            l_, b, s, kv = leaf.shape[:4]
            b_ax = _fit(mesh, b, ba)
            kv_ax = _fit(mesh, kv, "model")
            # sequence parallelism over whatever is left: idle batch axes
            # (B=1 long-context) and, when heads cannot shard, 'model'
            leftover = [a for a in ("pod", "data")
                        if a in mesh.axis_names and b_ax is None]
            if kv_ax is None and "model" in mesh.axis_names:
                leftover.append("model")
            s_ax = _fit(mesh, s, tuple(leftover)) if leftover else None
            axes = (None, b_ax, s_ax, kv_ax) + ((None,) if nd == 5 else ())
            return P(*axes)
        if nd == 4:  # (L, B, K-1, C) conv state
            axes = [None, _fit(mesh, leaf.shape[1], ba), None,
                    _fit(mesh, leaf.shape[3], "model")]
            return P(*axes)
        if nd >= 2:
            return _spec(mesh, leaf.shape,
                         (None, ba) + (None,) * (nd - 2))
        return P(*(None,) * nd)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)


def paged_cache_pspecs(pages_tree, mesh: Mesh):
    """Block-pool KV arenas: (Lx, num_blocks, block_size, KV[, hd]).

    A block is the paging unit, so it must live wholly on one shard: the
    *blocks* axis shards over the batch axes (pages of concurrent slots
    spread across the data-parallel devices — the slot -> block-table
    indirection is position-free, so any block placement is legal), KV
    heads take 'model' as in ``cache_pspecs``, and the intra-block
    sequence axis is never split.  Block tables are host-side numpy and
    need no spec.
    """
    ba = batch_axes(mesh)

    def leaf_spec(leaf):
        nd = len(leaf.shape)
        if nd < 4:
            return P(*(None,) * nd)
        axes = (None, _fit(mesh, leaf.shape[1], ba), None,
                _fit(mesh, leaf.shape[3], "model")) + (None,) * (nd - 4)
        return P(*axes)

    return jax.tree_util.tree_map(leaf_spec, pages_tree)


def sparse_pack_pspecs(sparse: dict, mesh: Mesh):
    """PartitionSpecs for the device arrays of a ``sparsify_model`` dict.

    The packed-row dim is the paper's bank dim: each device holds a
    contiguous packed row range of every bucket (values/codes, cols and
    the per-row ``srow`` scales shard together on it, when divisible by
    'model'), the dense activation stays replicated (the ICI broadcast),
    and the per-bucket SpMV runs bank-local.  ``perm``/``inv_perm`` are
    replicated — the static output ``take`` is a cross-bank gather the
    compiler lays out.  Layer-stack and chunk dims are never split (the
    scan slices the former; a chunk is one VMEM slab).

    Returns ``{group: {"buckets": [...], "perm": P, "inv_perm": P}}``
    matching the jnp leaves of ``sparse["groups"]``.
    """
    def bucket_spec(b):
        out = {}
        for key in ("values", "q", "cols", "srow"):
            if key in b:
                shape = b[key].shape
                axes = (None, _fit(mesh, shape[1], "model"))
                out[key] = P(*axes, *(None,) * (len(shape) - 2))
        return out

    return {
        name: {
            "buckets": [bucket_spec(b) for b in g["buckets"]],
            "perm": P(None, None),
            "inv_perm": P(None, None),
        }
        for name, g in sparse["groups"].items()
    }


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def logical_to_sharding(tree, specs, mesh: Mesh):
    """Device-put a pytree according to a spec tree."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)
