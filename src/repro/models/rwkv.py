"""RWKV6 ("Finch") — attention-free LM with data-dependent decay.

Faithful structure: token-shift lerp mixes for (r, k, v, w, g), LoRA-style
data-dependent decay ``w = exp(-exp(w0 + tanh(x @ A) @ B))``, per-head bonus
``u``, grouped head-norm, squared-ReLU channel mix.  The WKV recurrence runs
as a ``lax.scan`` over time for train/prefill and as an O(1) state update for
decode — which is why rwkv6 runs the ``long_500k`` cell.

state per head: (K, V) outer-product accumulator;
  y_t = r_t . (state + (u * k_t) v_t^T);  state' = diag(w_t) state + k_t v_t^T
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T

__all__ = ["init_params", "forward", "init_cache", "decode_step",
           "prefill_chunk"]

LORA_W = 64  # decay LoRA rank


def _heads(cfg: ModelConfig):
    hd = cfg.rwkv_head_dim
    return cfg.d_model // hd, hd


def init_time_mix(key, cfg: ModelConfig):
    d = cfg.d_model
    h, hd = _heads(cfg)
    ks = jax.random.split(key, 8)
    return {
        "mix": 0.5 * jnp.ones((5, d), cfg.dtype),  # r, k, v, w, g lerps
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "w_a": L.init_dense(ks[0], d, LORA_W, cfg.dtype, scale=0.01),
        "w_b": L.init_dense(ks[1], LORA_W, d, cfg.dtype, scale=0.01),
        "u": (jax.random.normal(ks[2], (h, hd), jnp.float32) * 0.1),
        "wr": L.init_dense(ks[3], d, d, cfg.dtype),
        "wk": L.init_dense(ks[4], d, d, cfg.dtype),
        "wv": L.init_dense(ks[5], d, d, cfg.dtype),
        "wg": L.init_dense(ks[6], d, d, cfg.dtype),
        "wo": L.init_dense(ks[7], d, d, cfg.dtype),
        "ln_x": jnp.ones((d,), cfg.dtype),
    }


def init_channel_mix(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mix": 0.5 * jnp.ones((2, d), cfg.dtype),  # k, r lerps
        "wk": L.init_dense(k1, d, f, cfg.dtype),
        "wv": L.init_dense(k2, f, d, cfg.dtype),
        "wr": L.init_dense(k3, d, d, cfg.dtype),
    }


def _shift(x, last):
    """Token shift: x_{t-1} with ``last`` filling position 0.
    x: (B, S, D); last: (B, D)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _head_norm(y, w, h, hd, eps):
    """Per-head RMS norm (group-norm analogue). y: (B, S, H, hd)."""
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + eps)
    b, s = y.shape[:2]
    return (yf.reshape(b, s, h * hd) * w.astype(jnp.float32)).astype(y.dtype)


def time_mix_apply(cfg: ModelConfig, p, x, last_x, state, valid=None):
    """x: (B, S, D); last_x: (B, D); state: (B, H, K, V) f32.
    Returns (out, new_last_x, new_state).

    ``valid`` (B, S) bool marks real tokens (chunked prefill pads a partial
    final chunk): invalid positions force k -> 0 and w -> 1, so the WKV
    state passes through them unchanged.
    """
    b, s, d = x.shape
    h, hd = _heads(cfg)
    xs = _shift(x, last_x)
    mix = p["mix"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + mix[i] * (xs - x) for i in range(5))
    r = L.dense(xr, p["wr"]).reshape(b, s, h, hd)
    k = L.dense(xk, p["wk"]).reshape(b, s, h, hd)
    v = L.dense(xv, p["wv"]).reshape(b, s, h, hd)
    g = L.dense(xg, p["wg"])
    w = jnp.exp(-jnp.exp(
        p["w0"]
        + L.dense(jnp.tanh(L.dense(xw, p["w_a"])), p["w_b"]).astype(jnp.float32)
    )).reshape(b, s, h, hd)  # (0, 1) decay per channel
    if valid is not None:
        k = jnp.where(valid[:, :, None, None], k, 0.0)
        w = jnp.where(valid[:, :, None, None], w, 1.0)
    u = p["u"]

    def step(st, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, hd) each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                        v_t.astype(jnp.float32))
        y = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                       st + u[None, :, :, None] * kv)
        st = w_t.astype(jnp.float32)[..., None] * st + kv
        return st, y

    hint = lambda t: L.shard_hint(t, None, "batch", "model", None)
    seq = (hint(r.transpose(1, 0, 2, 3)), hint(k.transpose(1, 0, 2, 3)),
           hint(v.transpose(1, 0, 2, 3)), hint(w.transpose(1, 0, 2, 3)))
    state = L.shard_hint(state, "batch", "model", None, None)
    state, ys = jax.lax.scan(step, state, seq)
    y = hint(ys).transpose(1, 0, 2, 3)  # (B, S, H, hd)
    y = _head_norm(y, p["ln_x"], h, hd, cfg.norm_eps).astype(x.dtype)
    y = y * jax.nn.silu(g)
    return L.dense(y, p["wo"]).astype(x.dtype), x[:, -1, :], state


def channel_mix_apply(cfg: ModelConfig, p, x, last_x):
    xs = _shift(x, last_x)
    mix = p["mix"].astype(x.dtype)
    xk = x + mix[0] * (xs - x)
    xr = x + mix[1] * (xs - x)
    k = jnp.square(jax.nn.relu(L.dense(xk, p["wk"])))
    out = jax.nn.sigmoid(L.dense(xr, p["wr"])) * L.dense(k, p["wv"])
    return out.astype(x.dtype), x[:, -1, :]


def _init_layer(key, cfg: ModelConfig):
    kt, kc = jax.random.split(key)
    return {
        "ln1": T.init_norm(cfg),
        "tm": init_time_mix(kt, cfg),
        "ln2": T.init_norm(cfg),
        "cm": init_channel_mix(kc, cfg),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kl, kh = jax.random.split(key, 3)
    params = {
        "embed": L.init_dense(ke, cfg.padded_vocab, cfg.d_model, cfg.dtype,
                              scale=0.02),
        "layers": T.stack_layer_init(_init_layer, kl, cfg.n_layers, cfg),
        "final_norm": T.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(kh, cfg.d_model, cfg.padded_vocab,
                                         cfg.dtype)
    return params


def _zero_states(cfg: ModelConfig, b):
    h, hd = _heads(cfg)
    return {
        "tm_x": jnp.zeros((cfg.n_layers, b, cfg.d_model), cfg.cdtype),
        "cm_x": jnp.zeros((cfg.n_layers, b, cfg.d_model), cfg.cdtype),
        "wkv": jnp.zeros((cfg.n_layers, b, h, hd, hd), jnp.float32),
    }


def forward(cfg: ModelConfig, params, batch: dict) -> jnp.ndarray:
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = T.embed_tokens(cfg, params, tokens)
    states = _zero_states(cfg, b)

    def body(carry, xs):
        h = carry
        lp, tm_x, cm_x, wkv = xs
        a, _, _ = time_mix_apply(cfg, lp["tm"], T._norm(cfg, lp["ln1"], h),
                                 tm_x, wkv)
        h = h + a
        c, _ = channel_mix_apply(cfg, lp["cm"], T._norm(cfg, lp["ln2"], h),
                                 cm_x)
        return h + c, None

    h, _ = jax.lax.scan(
        T.remat_wrap(cfg, body), h,
        (params["layers"], states["tm_x"], states["cm_x"], states["wkv"]))
    return T.logits_from_hidden(cfg, params, h)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int) -> dict:
    st = _zero_states(cfg, batch_size)
    st["len"] = jnp.zeros((batch_size,), jnp.int32)
    return st


def decode_step(cfg: ModelConfig, params, cache: dict, batch: dict):
    tokens = batch["tokens"]
    h = T.embed_tokens(cfg, params, tokens)

    def body(carry, xs):
        h = carry
        lp, tm_x, cm_x, wkv = xs
        a, tm_x, wkv = time_mix_apply(
            cfg, lp["tm"], T._norm(cfg, lp["ln1"], h), tm_x, wkv)
        h = h + a
        c, cm_x = channel_mix_apply(
            cfg, lp["cm"], T._norm(cfg, lp["ln2"], h), cm_x)
        return h + c, (tm_x, cm_x, wkv)

    h, (tm_x, cm_x, wkv) = jax.lax.scan(
        body, h, (params["layers"], cache["tm_x"], cache["cm_x"],
                  cache["wkv"]))
    logits = T.logits_from_hidden(cfg, params, h)
    return logits, {"tm_x": tm_x, "cm_x": cm_x, "wkv": wkv,
                    "len": cache["len"] + 1}


def prefill_chunk(cfg: ModelConfig, params, cache: dict, batch: dict):
    """Chunked prefill: run the WKV recurrence over a C-token slab from the
    cached (tm_x, cm_x, wkv) states — same contract as
    ``transformer.prefill_chunk``.  The token-shift states advance to the
    last *valid* token of the chunk, and pad positions leave the WKV
    accumulator untouched (k -> 0, w -> 1 inside ``time_mix_apply``).
    """
    tokens = batch["tokens"]
    b, c = tokens.shape
    start = cache["len"]
    n_valid = batch.get("n_valid")
    if n_valid is None:
        n_valid = jnp.full_like(start, c)
    valid = jnp.arange(c, dtype=jnp.int32)[None, :] < n_valid[:, None]
    last_idx = jnp.maximum(n_valid - 1, 0)[:, None, None]  # (B, 1, 1)
    h = T.embed_tokens(cfg, params, tokens)

    def body(carry, xs):
        h = carry
        lp, tm_x, cm_x, wkv = xs
        xn1 = T._norm(cfg, lp["ln1"], h)
        a, _, wkv = time_mix_apply(cfg, lp["tm"], xn1, tm_x, wkv,
                                   valid=valid)
        tm_x = jnp.take_along_axis(
            xn1, jnp.broadcast_to(last_idx, (b, 1, xn1.shape[-1])),
            axis=1)[:, 0]
        h = h + a
        xn2 = T._norm(cfg, lp["ln2"], h)
        cmo, _ = channel_mix_apply(cfg, lp["cm"], xn2, cm_x)
        cm_x = jnp.take_along_axis(
            xn2, jnp.broadcast_to(last_idx, (b, 1, xn2.shape[-1])),
            axis=1)[:, 0]
        return h + cmo, (tm_x, cm_x, wkv)

    h, (tm_x, cm_x, wkv) = jax.lax.scan(
        body, h, (params["layers"], cache["tm_x"], cache["cm_x"],
                  cache["wkv"]))
    logits = T.logits_from_hidden(cfg, params, h)
    return logits, {"tm_x": tm_x, "cm_x": cm_x, "wkv": wkv,
                    "len": start + n_valid}
