"""Mamba2 (SSD) blocks and the zamba2-2.7b hybrid LM.

The SSD scan uses the chunked (block-parallel) formulation from the Mamba2
paper: intra-chunk quadratic attention-like term + inter-chunk state
recurrence via ``lax.scan`` — sub-quadratic in sequence length, which is why
zamba2 runs the ``long_500k`` cell.

zamba2 structure (per arXiv:2411.15242, simplified as noted in DESIGN.md):
a stack of Mamba2 layers with a single *shared* attention+MLP block applied
every ``attn_every`` layers (weight reuse across applications; each
application keeps its own KV cache slot).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T

__all__ = ["init_params", "forward", "init_cache", "decode_step",
           "prefill_chunk", "ssd_chunked", "ssd_step", "mamba2_apply",
           "mamba2_step", "mamba2_prefill"]

GROUPS = 1  # B/C projection groups


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state


# --------------------------------------------------------------------------
# SSD core
# --------------------------------------------------------------------------
def ssd_chunked(x, dt, a, bmat, cmat, chunk: int = 128, init_state=None):
    """Chunked selective-state-space scan.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); a: (H,) negative;
    bmat/cmat: (B, S, G, N).  Returns (y (B, S, H, P), final_state
    (B, H, P, N)).
    """
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk
    rep = h // g

    xc = x.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    bc = bmat.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    xc = L.shard_hint(xc, "batch", None, None, "model", None)
    dtc = L.shard_hint(dtc, "batch", None, None, "model")

    da = dtc * a.astype(jnp.float32)              # (B, nc, Lc, H)
    cs = jnp.cumsum(da, axis=2)                   # inclusive cumsum
    # intra-chunk: y[t] += sum_{j<=t} exp(cs[t]-cs[j]) (C_t.B_j) dt_j x_j
    cb = jnp.einsum("bctgn,bcjgn->bcgtj", cc, bc)  # (B, nc, G, Lc, Lc)
    cb = jnp.repeat(cb, rep, axis=2)               # (B, nc, H, Lc, Lc)
    # build decay matrix L[t, j] = exp(cs[t] - cs[j]) for j <= t
    cst = cs.transpose(0, 1, 3, 2)                 # (B, nc, H, Lc)
    dec = jnp.exp(cst[..., :, None] - cst[..., None, :])
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    dec = jnp.where(tri, dec, 0.0)
    dx = dtc[..., None] * xc                        # (B, nc, Lc, H, P)
    y_intra = jnp.einsum("bchtj,bcjhp->bcthp", cb * dec, dx)

    # chunk states: S_c = sum_j exp(cs[last]-cs[j]) dt_j x_j (x) B_j
    decay_to_end = jnp.exp(cst[..., -1:] - cst)     # (B, nc, H, Lc)
    bfull = jnp.repeat(bc, rep, axis=3)             # (B, nc, Lc, H? ) wrong axis
    bfull = jnp.repeat(bc.reshape(b, nc, chunk, g, 1, n), rep, axis=4
                       ).reshape(b, nc, chunk, h, n)
    states = jnp.einsum("bchl,bclhp,bclhn->bchpn",
                        decay_to_end, dx, bfull)    # (B, nc, H, P, N)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cst[..., -1])             # (B, nc, H)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def scan_fn(state, inp):
        st_c, dec_c = inp                           # (B,H,P,N), (B,H)
        out = state
        state = state * dec_c[..., None, None] + st_c
        return state, out

    states = L.shard_hint(states, "batch", None, "model", None, None)
    final, prev_states = jax.lax.scan(
        scan_fn, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B, nc, H, P, N)
    prev_states = L.shard_hint(prev_states, "batch", None, "model", None,
                               None)

    # y_inter[t] = exp(cs[t]) * C_t . prev_state
    cfull = jnp.repeat(cc.reshape(b, nc, chunk, g, 1, n), rep, axis=4
                       ).reshape(b, nc, chunk, h, n)
    y_inter = jnp.einsum("bclhn,bchpn,bchl->bclhp",
                         cfull, prev_states, jnp.exp(cst))
    y = (y_intra + y_inter).reshape(b, sp, h, p)[:, :s]
    return y, final


def ssd_step(state, x_t, dt_t, a, b_t, c_t):
    """One-token SSD update.  state: (B, H, P, N); x_t: (B, H, P);
    dt_t: (B, H); b_t/c_t: (B, G, N)."""
    bsz, h, p = x_t.shape
    g = b_t.shape[1]
    rep = h // g
    bf = jnp.repeat(b_t, rep, axis=1)  # (B, H, N)
    cf = jnp.repeat(c_t, rep, axis=1)
    da = jnp.exp(dt_t.astype(jnp.float32) * a.astype(jnp.float32))
    state = (state * da[..., None, None]
             + jnp.einsum("bhp,bhn->bhpn", dt_t[..., None] * x_t, bf))
    y = jnp.einsum("bhpn,bhn->bhp", state, cf)
    return y, state


# --------------------------------------------------------------------------
# Mamba2 layer
# --------------------------------------------------------------------------
def init_mamba_layer(key, cfg: ModelConfig):
    d_inner, n_heads, n = _dims(cfg)
    conv_ch = d_inner + 2 * GROUPS * n
    k1, k2, k3 = jax.random.split(key, 3)
    proj_out = 2 * d_inner + 2 * GROUPS * n + n_heads
    return {
        "in_proj": L.init_dense(k1, cfg.d_model, proj_out, cfg.dtype),
        "conv_w": (jax.random.normal(k2, (cfg.conv_kernel, conv_ch),
                                     jnp.float32) * 0.1).astype(cfg.dtype),
        "conv_b": jnp.zeros((conv_ch,), cfg.dtype),
        "a_log": jnp.zeros((n_heads,), jnp.float32),       # A = -exp(0) = -1
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), cfg.dtype),
        "out_proj": L.init_dense(k3, d_inner, cfg.d_model, cfg.dtype),
    }


def _split_proj(cfg: ModelConfig, z):
    d_inner, n_heads, n = _dims(cfg)
    zg = z[..., :d_inner]
    xbc = z[..., d_inner : 2 * d_inner + 2 * GROUPS * n]
    dt = z[..., 2 * d_inner + 2 * GROUPS * n :]
    return zg, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over sequence.  xbc: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :]
        for i in range(k)
    )
    return out + b[None, None, :]


def mamba2_apply(cfg: ModelConfig, p, x, init_state=None):
    """x: (B, S, D) -> (y, final_ssm_state)."""
    d_inner, n_heads, n = _dims(cfg)
    b, s, _ = x.shape
    zg, xbc, dt = _split_proj(cfg, L.dense(x, p["in_proj"]))
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :d_inner].reshape(b, s, n_heads, cfg.ssm_head_dim)
    bmat = xbc[..., d_inner : d_inner + GROUPS * n].reshape(b, s, GROUPS, n)
    cmat = xbc[..., d_inner + GROUPS * n :].reshape(b, s, GROUPS, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    y, state = ssd_chunked(xs, dt, a, bmat, cmat)
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(zg), p["norm_w"], cfg.norm_eps)
    return L.dense(y, p["out_proj"]), state


def mamba2_step(cfg: ModelConfig, p, x, conv_state, ssm_state):
    """One-token step.  x: (B, 1, D); conv_state: (B, K-1, C);
    ssm_state: (B, H, P, N)."""
    d_inner, n_heads, n = _dims(cfg)
    b = x.shape[0]
    zg, xbc, dt = _split_proj(cfg, L.dense(x, p["in_proj"]))
    window = jnp.concatenate([conv_state, xbc], axis=1)  # (B, K, C)
    conv_state = window[:, 1:]
    out = jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(jnp.float32)
                     ) + p["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(out)[:, None, :].astype(x.dtype)
    xs = xbc[..., :d_inner].reshape(b, n_heads, cfg.ssm_head_dim)
    bmat = xbc[..., d_inner : d_inner + GROUPS * n].reshape(b, GROUPS, n)
    cmat = xbc[..., d_inner + GROUPS * n :].reshape(b, GROUPS, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]
    a = -jnp.exp(p["a_log"])
    y, ssm_state = ssd_step(ssm_state, xs.astype(jnp.float32), dt, a,
                            bmat.astype(jnp.float32),
                            cmat.astype(jnp.float32))
    y = y + p["d_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(zg), p["norm_w"], cfg.norm_eps)
    return L.dense(y, p["out_proj"]), conv_state, ssm_state


def mamba2_prefill(cfg: ModelConfig, p, x, conv_state, ssm_state, valid,
                   n_valid):
    """Chunked-prefill step: a C-token slab continuing from cached state.

    x: (B, C, D); conv_state: (B, K-1, Cch) raw (pre-activation) xbc
    window; ssm_state: (B, H, P, N); valid: (B, C) bool; n_valid: (B,).
    Invalid (pad) positions pass state through exactly: dt is forced to 0
    there, so the SSD decay is exp(0)=1 and the input contribution dt*x
    vanishes; the new conv window is sliced to end at the last *valid*
    token.  Returns (y (B, C, D), new_conv_state, new_ssm_state).
    """
    d_inner, n_heads, n = _dims(cfg)
    b, c, _ = x.shape
    k = p["conv_w"].shape[0]
    zg, xbc, dt = _split_proj(cfg, L.dense(x, p["in_proj"]))
    # causal conv seeded with the cached window instead of zero padding —
    # f32 accumulation matching mamba2_step's einsum path
    ext = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    ext_f = ext.astype(jnp.float32)
    w_f = p["conv_w"].astype(jnp.float32)
    conv = sum(ext_f[:, i : i + c, :] * w_f[i][None, None, :]
               for i in range(k)) + p["conv_b"].astype(jnp.float32)
    xbc_act = jax.nn.silu(conv).astype(x.dtype)
    # new window = raw xbc rows n_valid-(K-1)..n_valid-1 of the stream,
    # i.e. ext rows n_valid..n_valid+K-2
    idx = n_valid[:, None] + jnp.arange(k - 1, dtype=jnp.int32)[None, :]
    new_conv = jnp.take_along_axis(ext, idx[..., None],
                                   axis=1).astype(conv_state.dtype)

    xs = xbc_act[..., :d_inner].reshape(b, c, n_heads, cfg.ssm_head_dim)
    bmat = xbc_act[..., d_inner : d_inner + GROUPS * n].reshape(
        b, c, GROUPS, n)
    cmat = xbc_act[..., d_inner + GROUPS * n :].reshape(b, c, GROUPS, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dt = jnp.where(valid[:, :, None], dt, 0.0)
    a = -jnp.exp(p["a_log"])
    y, ssm_state = ssd_chunked(xs, dt, a, bmat, cmat,
                               init_state=ssm_state.astype(jnp.float32))
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, c, d_inner).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(zg), p["norm_w"], cfg.norm_eps)
    return L.dense(y, p["out_proj"]), new_conv, ssm_state


# --------------------------------------------------------------------------
# zamba2 hybrid LM
# --------------------------------------------------------------------------
def _n_apps(cfg: ModelConfig) -> int:
    if not cfg.attn_every:
        return 0
    return -(-cfg.n_layers // cfg.attn_every)


def _init_layer(key, cfg: ModelConfig):
    return {"ln": T.init_norm(cfg), "mamba": init_mamba_layer(key, cfg)}


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kl, ks, km, kh = jax.random.split(key, 5)
    params = {
        "embed": L.init_dense(ke, cfg.padded_vocab, cfg.d_model, cfg.dtype,
                              scale=0.02),
        "layers": T.stack_layer_init(_init_layer, kl, cfg.n_layers, cfg),
        "final_norm": T.init_norm(cfg),
    }
    if cfg.attn_every:
        params["shared_attn"] = {
            "ln1": T.init_norm(cfg),
            "attn": T.init_attn_layer(ks, cfg),
            "ln2": T.init_norm(cfg),
            "mlp": T.init_mlp_layer(km, cfg),
        }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(kh, cfg.d_model, cfg.padded_vocab,
                                         cfg.dtype)
    return params


def _group_params(cfg: ModelConfig, stacked):
    """Reshape stacked layer params (L, ...) -> (G, attn_every, ...).

    The shared attention block fires at the start of each group, so the
    hybrid is a clean nested scan — no per-layer conditional (which would
    both bloat the HLO and defeat cost analysis)."""
    g = cfg.n_layers // cfg.attn_every
    if g * cfg.attn_every != cfg.n_layers:
        raise ValueError("n_layers must be a multiple of attn_every")
    return jax.tree.map(
        lambda x: x.reshape((g, cfg.attn_every) + x.shape[1:]), stacked)


def forward(cfg: ModelConfig, params, batch: dict) -> jnp.ndarray:
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = T.embed_tokens(cfg, params, tokens)
    shared = params.get("shared_attn")

    def mamba_body(h, lp):
        m, _ = mamba2_apply(cfg, lp["mamba"], T._norm(cfg, lp["ln"], h))
        return h + m, None

    if shared is None:
        h, _ = jax.lax.scan(T.remat_wrap(cfg, mamba_body), h,
                            params["layers"])
    else:
        grouped = _group_params(cfg, params["layers"])

        def group_body(h, gp):
            a = T.attn_apply(cfg, shared["attn"],
                             T._norm(cfg, shared["ln1"], h), positions)
            h = h + a
            h = h + T.mlp_apply(cfg, shared["mlp"],
                                T._norm(cfg, shared["ln2"], h))
            h, _ = jax.lax.scan(mamba_body, h, gp)
            return h, None

        h, _ = jax.lax.scan(T.remat_wrap(cfg, group_body), h, grouped)
    return T.logits_from_hidden(cfg, params, h)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int) -> dict:
    d_inner, n_heads, n = _dims(cfg)
    conv_ch = d_inner + 2 * GROUPS * n
    cache = {
        "conv": jnp.zeros(
            (cfg.n_layers, batch_size, cfg.conv_kernel - 1, conv_ch),
            cfg.cdtype),
        "ssm": jnp.zeros(
            (cfg.n_layers, batch_size, n_heads, cfg.ssm_head_dim, n),
            jnp.float32),
        "len": jnp.zeros((batch_size,), jnp.int32),
    }
    napp = _n_apps(cfg)
    if napp:
        cache["k"] = jnp.zeros(
            (napp, batch_size, max_len, cfg.n_kv_heads, cfg.hd), cfg.cdtype)
        cache["v"] = jnp.zeros_like(cache["k"])
    return cache


def decode_step(cfg: ModelConfig, params, cache: dict, batch: dict):
    tokens = batch["tokens"]
    h = T.embed_tokens(cfg, params, tokens)
    shared = params.get("shared_attn")
    napp = _n_apps(cfg)

    def mamba_body(h, xs):
        lp, conv, ssm = xs
        m, conv, ssm = mamba2_step(cfg, lp["mamba"],
                                   T._norm(cfg, lp["ln"], h), conv, ssm)
        return h + m, (conv, ssm)

    if shared is None:
        h, (conv_new, ssm_new) = jax.lax.scan(
            mamba_body, h, (params["layers"], cache["conv"], cache["ssm"]))
        logits = T.logits_from_hidden(cfg, params, h)
        return logits, {"conv": conv_new, "ssm": ssm_new,
                        "len": cache["len"] + 1}

    grouped = _group_params(cfg, params["layers"])
    conv_g = cache["conv"].reshape((napp, cfg.attn_every)
                                   + cache["conv"].shape[1:])
    ssm_g = cache["ssm"].reshape((napp, cfg.attn_every)
                                 + cache["ssm"].shape[1:])

    def group_body(h, xs):
        gp, conv, ssm, kc, vc = xs
        a, kc, vc, _, _ = T.attn_decode_apply(
            cfg, shared["attn"], T._norm(cfg, shared["ln1"], h),
            kc, vc, cache["len"])
        h = h + a
        h = h + T.mlp_apply(cfg, shared["mlp"],
                            T._norm(cfg, shared["ln2"], h))
        h, (conv, ssm) = jax.lax.scan(mamba_body, h, (gp, conv, ssm))
        return h, (conv, ssm, kc, vc)

    h, (conv_new, ssm_new, k_new, v_new) = jax.lax.scan(
        group_body, h, (grouped, conv_g, ssm_g, cache["k"], cache["v"]))
    logits = T.logits_from_hidden(cfg, params, h)
    return logits, {
        "conv": conv_new.reshape(cache["conv"].shape),
        "ssm": ssm_new.reshape(cache["ssm"].shape),
        "k": k_new, "v": v_new, "len": cache["len"] + 1,
    }


def prefill_chunk(cfg: ModelConfig, params, cache: dict, batch: dict):
    """Chunked prefill for the (hybrid) Mamba2 LM — same contract as
    ``transformer.prefill_chunk``: tokens (B, C) at cache["len"].., pad
    tokens beyond batch["n_valid"] leave every recurrent state untouched.
    """
    tokens = batch["tokens"]
    b, c = tokens.shape
    start = cache["len"]
    n_valid = batch.get("n_valid")
    if n_valid is None:
        n_valid = jnp.full_like(start, c)
    valid = jnp.arange(c, dtype=jnp.int32)[None, :] < n_valid[:, None]
    h = T.embed_tokens(cfg, params, tokens)
    shared = params.get("shared_attn")
    napp = _n_apps(cfg)

    def mamba_body(h, xs):
        lp, conv, ssm = xs
        m, conv, ssm = mamba2_prefill(
            cfg, lp["mamba"], T._norm(cfg, lp["ln"], h), conv, ssm,
            valid, n_valid)
        return h + m, (conv, ssm)

    if shared is None:
        h, (conv_new, ssm_new) = jax.lax.scan(
            mamba_body, h, (params["layers"], cache["conv"], cache["ssm"]))
        logits = T.logits_from_hidden(cfg, params, h)
        return logits, {"conv": conv_new, "ssm": ssm_new,
                        "len": start + n_valid}

    grouped = _group_params(cfg, params["layers"])
    conv_g = cache["conv"].reshape((napp, cfg.attn_every)
                                   + cache["conv"].shape[1:])
    ssm_g = cache["ssm"].reshape((napp, cfg.attn_every)
                                 + cache["ssm"].shape[1:])

    def group_body(h, xs):
        gp, conv, ssm, kc, vc = xs
        a, kc, vc, _, _ = T.attn_prefill_apply(
            cfg, shared["attn"], T._norm(cfg, shared["ln1"], h),
            kc, vc, start)
        h = h + a
        h = h + T.mlp_apply(cfg, shared["mlp"],
                            T._norm(cfg, shared["ln2"], h))
        h, (conv, ssm) = jax.lax.scan(mamba_body, h, (gp, conv, ssm))
        return h, (conv, ssm, kc, vc)

    h, (conv_new, ssm_new, k_new, v_new) = jax.lax.scan(
        group_body, h, (grouped, conv_g, ssm_g, cache["k"], cache["v"]))
    logits = T.logits_from_hidden(cfg, params, h)
    return logits, {
        "conv": conv_new.reshape(cache["conv"].shape),
        "ssm": ssm_new.reshape(cache["ssm"].shape),
        "k": k_new, "v": v_new, "len": start + n_valid,
    }
