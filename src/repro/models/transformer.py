"""Dense decoder-only transformer (GQA) — qwen1.5-110b, qwen2.5-14b,
nemotron-4-15b, granite-3-2b — and the shared attention building blocks
reused by the MoE / VLM / hybrid / enc-dec families.

Layer params are stacked along a leading layer axis and applied with
``jax.lax.scan`` (compile-time and HLO-size critical for the 80-layer
dry-runs); remat policy wraps the scan body.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

__all__ = [
    "init_params", "forward", "init_cache", "decode_step", "prefill_chunk",
    "init_attn_layer", "attn_apply", "attn_decode_apply",
    "attn_decode_core", "attn_prefill_apply", "attn_prefill_core",
    "splice_rows",
    "init_mlp_layer", "mlp_apply", "remat_wrap", "stack_layer_init",
    "embed_tokens", "logits_from_hidden",
]


# --------------------------------------------------------------------------
# Shared building blocks
# --------------------------------------------------------------------------
def stack_layer_init(layer_init, key, n_layers: int, *args, **kw):
    """vmap a per-layer init over a split key -> stacked params."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(lambda k: layer_init(k, *args, **kw))(keys)


def init_attn_layer(key, cfg: ModelConfig):
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    d, hd = cfg.d_model, cfg.hd
    p = {
        "wq": L.init_dense(kq, d, cfg.n_heads * hd, cfg.dtype),
        "wk": L.init_dense(kk, d, cfg.n_kv_heads * hd, cfg.dtype),
        "wv": L.init_dense(kv, d, cfg.n_kv_heads * hd, cfg.dtype),
        "wo": L.init_dense(ko, cfg.n_heads * hd, d, cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.dtype)
    return p


def _qkv(cfg: ModelConfig, p, x):
    b, s, _ = x.shape
    q = L.dense(x, p["wq"], p.get("bq")).reshape(b, s, cfg.n_heads, cfg.hd)
    k = L.dense(x, p["wk"], p.get("bk")).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = L.dense(x, p["wv"], p.get("bv")).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    return q, k, v


def attn_apply(cfg: ModelConfig, p, x, positions, *, causal=True,
               positions3=None, kv_x=None):
    """Full attention over a sequence (train / prefill / cross).

    ``kv_x`` switches to cross-attention (keys/values from the encoder);
    RoPE is skipped for cross-attention and for learned-positions models.
    """
    b, s, _ = x.shape
    if kv_x is None:
        q, k, v = _qkv(cfg, p, x)
        if cfg.mrope and positions3 is not None:
            q, k = L.apply_mrope(q, k, positions3, cfg.rope_theta)
        elif not cfg.learned_pos:
            q, k = L.apply_rope(q, k, positions, cfg.rope_theta)
    else:
        bk, sk, _ = kv_x.shape
        q = L.dense(x, p["wq"], p.get("bq")).reshape(b, s, cfg.n_heads, cfg.hd)
        k = L.dense(kv_x, p["wk"], p.get("bk")).reshape(
            bk, sk, cfg.n_kv_heads, cfg.hd)
        v = L.dense(kv_x, p["wv"], p.get("bv")).reshape(
            bk, sk, cfg.n_kv_heads, cfg.hd)
    out = L.flash_attention(
        q, k, v, causal=causal, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk
    )
    return L.dense(out.reshape(b, s, cfg.n_heads * cfg.hd), p["wo"])


def _quantize_kv(x):
    """(B, 1, KV, hd) -> (int8 values, (B, 1, KV) scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def attn_decode_core(cfg: ModelConfig, q, k, v, k_cache, v_cache, cache_len,
                     positions3=None, k_scale=None, v_scale=None):
    """RoPE + cache update + attention for one decode token, on
    *precomputed* q/k/v heads — the projection-agnostic middle of the
    attention step, shared by the dense path (``attn_decode_apply``) and
    the ESPIM packed-QKV path (``sparse_model``), which computes q/k/v
    through the fused QKV pack and applies the O projection itself.

    q: (B, 1, H, hd); k/v: (B, 1, KV, hd); caches (B, S_max, KV, hd).
    Returns (out (B, 1, H, hd) — pre-O-projection, k_cache, v_cache,
    k_scale, v_scale).
    """
    pos = cache_len.astype(jnp.int32)
    if cfg.mrope and positions3 is not None:
        q, k = L.apply_mrope(q, k, positions3, cfg.rope_theta)
    elif not cfg.learned_pos:
        q, k = L.apply_rope(q, k, pos[:, None], cfg.rope_theta)

    # Masked elementwise update instead of vmap(dynamic_update_slice):
    # shardable along every cache dim (batch, sequence, heads) with zero
    # resharding — a per-batch DUS on a sequence-sharded cache triggers
    # XLA's "involuntary full rematerialization" copies (hillclimb iter 1,
    # EXPERIMENTS.md section Perf).
    s_max = k_cache.shape[1]
    at_pos = (jnp.arange(s_max, dtype=jnp.int32)[None, :]
              == pos[:, None])[..., None, None]          # (B, S, 1, 1)
    if k_scale is not None:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        k_cache = jnp.where(at_pos, kq, k_cache)
        v_cache = jnp.where(at_pos, vq, v_cache)
        k_scale = jnp.where(at_pos[..., 0], ks, k_scale)
        v_scale = jnp.where(at_pos[..., 0], vs, v_scale)
    else:
        k_cache = jnp.where(at_pos, k.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(at_pos, v.astype(v_cache.dtype), v_cache)
    out = L.attention_decode(q, k_cache, v_cache, pos + 1,
                             k_scale=k_scale, v_scale=v_scale)
    return out, k_cache, v_cache, k_scale, v_scale


def attn_decode_apply(cfg: ModelConfig, p, x, k_cache, v_cache, cache_len,
                      positions3=None, k_scale=None, v_scale=None):
    """One-token decode: update caches at ``cache_len``, attend over cache.

    x: (B, 1, D); k/v_cache: (B, S_max, KV, hd); cache_len: (B,) int32.
    With an int8 cache, (B, S_max, KV) scales ride along and fold into
    scores/probs exactly (hillclimb iter 6).
    Returns (out (B,1,D), k_cache, v_cache[, k_scale, v_scale]).
    """
    b = x.shape[0]
    q, k, v = _qkv(cfg, p, x)
    out, k_cache, v_cache, k_scale, v_scale = attn_decode_core(
        cfg, q, k, v, k_cache, v_cache, cache_len, positions3=positions3,
        k_scale=k_scale, v_scale=v_scale)
    out = L.dense(out.reshape(b, 1, cfg.n_heads * cfg.hd), p["wo"])
    return out, k_cache, v_cache, k_scale, v_scale


def splice_rows(cache, rows, start):
    """Write ``rows`` (B, C, ...) into ``cache`` (B, S, ...) at sequence
    rows start..start+C-1 (per-batch ``start`` (B,) int32).

    Masked gather + where rather than dynamic_update_slice for the same
    reason as the decode update: shardable along every cache dim with zero
    resharding.
    """
    s_max, c = cache.shape[1], rows.shape[1]
    pos = jnp.arange(s_max, dtype=jnp.int32)[None, :]
    in_chunk = (pos >= start[:, None]) & (pos < start[:, None] + c)
    idx = jnp.clip(pos - start[:, None], 0, c - 1)
    extra = (1,) * (cache.ndim - 2)
    gathered = jnp.take_along_axis(rows, idx.reshape(idx.shape + extra),
                                   axis=1)
    return jnp.where(in_chunk.reshape(in_chunk.shape + extra), gathered,
                     cache)


def attn_prefill_core(cfg: ModelConfig, q, k, v, k_cache, v_cache, start,
                      positions3=None, k_scale=None, v_scale=None):
    """RoPE + cache splice + attention for a prefill chunk on precomputed
    q/k/v heads — the prefill twin of ``attn_decode_core`` (same contract:
    the caller owns the QKV and O projections).

    q: (B, C, H, hd); k/v: (B, C, KV, hd); start: (B,) int32.  Returns
    (out (B, C, H, hd) — pre-O-projection, caches, scales).
    """
    c = q.shape[1]
    pos = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    if cfg.mrope and positions3 is not None:
        q, k = L.apply_mrope(q, k, positions3, cfg.rope_theta)
    elif not cfg.learned_pos:
        q, k = L.apply_rope(q, k, pos, cfg.rope_theta)
    if k_scale is not None:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        k_cache = splice_rows(k_cache, kq, start)
        v_cache = splice_rows(v_cache, vq, start)
        k_scale = splice_rows(k_scale, ks, start)
        v_scale = splice_rows(v_scale, vs, start)
    else:
        k_cache = splice_rows(k_cache, k.astype(k_cache.dtype), start)
        v_cache = splice_rows(v_cache, v.astype(v_cache.dtype), start)
    out = L.attention_prefill(q, k_cache, v_cache, pos,
                              k_scale=k_scale, v_scale=v_scale)
    return out, k_cache, v_cache, k_scale, v_scale


def attn_prefill_apply(cfg: ModelConfig, p, x, k_cache, v_cache, start,
                       positions3=None, k_scale=None, v_scale=None):
    """Chunked prefill: C tokens at absolute positions start..start+C-1.

    x: (B, C, D); k/v_cache: (B, S_max, KV, hd); start: (B,) int32.  The
    chunk's K/V are spliced into the caches and the chunk attends causally
    over the whole cache (earlier chunks included).  Trailing pad tokens of
    a partial final chunk write rows past the valid length — harmless: the
    causal mask hides them from valid queries and the engine drops them at
    page-splice time.  Returns (out (B, C, D), caches[, scales]).
    """
    b, c, _ = x.shape
    q, k, v = _qkv(cfg, p, x)
    out, k_cache, v_cache, k_scale, v_scale = attn_prefill_core(
        cfg, q, k, v, k_cache, v_cache, start, positions3=positions3,
        k_scale=k_scale, v_scale=v_scale)
    out = L.dense(out.reshape(b, c, cfg.n_heads * cfg.hd), p["wo"])
    return out, k_cache, v_cache, k_scale, v_scale


def init_mlp_layer(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.gated_mlp:
        return {
            "w_gate": L.init_dense(k1, d, f, cfg.dtype),
            "w_up": L.init_dense(k2, d, f, cfg.dtype),
            "w_down": L.init_dense(k3, f, d, cfg.dtype),
        }
    return {
        "w_up": L.init_dense(k1, d, f, cfg.dtype),
        "w_down": L.init_dense(k2, f, d, cfg.dtype),
    }


def mlp_apply(cfg: ModelConfig, p, x):
    if cfg.gated_mlp:
        return L.mlp_gated(x, p["w_gate"], p["w_up"], p["w_down"],
                           cfg.activation)
    return L.mlp_relu2(x, p["w_up"], p["w_down"], cfg.activation)


def _norm(cfg: ModelConfig, p, x):
    if cfg.norm == "layernorm":
        return L.layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return L.rms_norm(x, p["w"], cfg.norm_eps)


def init_norm(cfg: ModelConfig):
    p = {"w": jnp.ones((cfg.d_model,), cfg.dtype)}
    if cfg.norm == "layernorm":
        p["b"] = jnp.zeros((cfg.d_model,), cfg.dtype)
    return p


def remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# --------------------------------------------------------------------------
# Dense decoder LM
# --------------------------------------------------------------------------
def _init_layer(key, cfg: ModelConfig):
    ka, km = jax.random.split(key)
    return {
        "ln1": init_norm(cfg),
        "attn": init_attn_layer(ka, cfg),
        "ln2": init_norm(cfg),
        "mlp": init_mlp_layer(km, cfg),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kl, kh = jax.random.split(key, 3)
    params = {
        "embed": L.init_dense(ke, cfg.padded_vocab, cfg.d_model, cfg.dtype,
                              scale=0.02),
        "layers": stack_layer_init(_init_layer, kl, cfg.n_layers, cfg),
        "final_norm": init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(kh, cfg.d_model, cfg.padded_vocab,
                                         cfg.dtype)
    return params


def embed_tokens(cfg: ModelConfig, params, tokens):
    return jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)


def logits_from_hidden(cfg: ModelConfig, params, h):
    h = _norm(cfg, params["final_norm"], h)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    return L.dense(h, params["lm_head"])


def forward(cfg: ModelConfig, params, batch: dict) -> jnp.ndarray:
    """Train/prefill forward -> logits (B, S, V).

    batch: tokens (B, S) [+ positions (B, S)], optionally
    embeddings/vis_mask/positions3 for the VLM flavour.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    positions3 = batch.get("positions3")
    h = embed_tokens(cfg, params, tokens)
    if "embeddings" in batch:  # VLM stub frontend: splice patch embeddings
        vis = batch["embeddings"].astype(h.dtype)
        vis_mask = batch["vis_mask"][..., None]
        h = jnp.where(vis_mask, vis, h)

    def body(h, lp):
        out = h + attn_apply(cfg, lp["attn"], _norm(cfg, lp["ln1"], h),
                             positions, positions3=positions3)
        out = out + mlp_apply(cfg, lp["mlp"], _norm(cfg, lp["ln2"], out))
        return out, None

    h, _ = jax.lax.scan(remat_wrap(cfg, body), h, params["layers"])
    return logits_from_hidden(cfg, params, h)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int) -> dict:
    shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
            "v_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
            "len": jnp.zeros((batch_size,), jnp.int32),
        }
    return {
        "k": jnp.zeros(shape, cfg.cdtype),
        "v": jnp.zeros(shape, cfg.cdtype),
        "len": jnp.zeros((batch_size,), jnp.int32),
    }


def decode_step(cfg: ModelConfig, params, cache: dict, batch: dict):
    """One decode step: tokens (B, 1) -> logits (B, 1, V), updated cache."""
    tokens = batch["tokens"]
    h = embed_tokens(cfg, params, tokens)
    positions3 = batch.get("positions3")
    quant = "k_scale" in cache
    dummy = jnp.zeros((cfg.n_layers,), jnp.bfloat16)

    def body(carry, xs):
        h = carry
        lp, kc, vc, ks, vs = xs
        a, kc, vc, ks, vs = attn_decode_apply(
            cfg, lp["attn"], _norm(cfg, lp["ln1"], h), kc, vc, cache["len"],
            positions3=positions3,
            k_scale=ks if quant else None,
            v_scale=vs if quant else None)
        out = h + a
        out = out + mlp_apply(cfg, lp["mlp"], _norm(cfg, lp["ln2"], out))
        return out, (kc, vc, ks, vs)

    h, (k_new, v_new, ks_new, vs_new) = jax.lax.scan(
        body, h, (params["layers"], cache["k"], cache["v"],
                  cache.get("k_scale", dummy), cache.get("v_scale", dummy))
    )
    logits = logits_from_hidden(cfg, params, h)
    new_cache = {"k": k_new, "v": v_new, "len": cache["len"] + 1}
    if quant:
        new_cache["k_scale"] = ks_new
        new_cache["v_scale"] = vs_new
    return logits, new_cache


def prefill_chunk(cfg: ModelConfig, params, cache: dict, batch: dict):
    """One chunked-prefill step: tokens (B, C) land at absolute positions
    cache["len"]..cache["len"]+C-1.

    ``batch["n_valid"]`` (B,) marks how many leading chunk tokens are real
    (a partial final chunk is padded up to the fixed jit'd width C); ``len``
    advances by ``n_valid`` only.  Returns full-chunk logits (B, C, V) and
    the updated cache — the caller reads logits at n_valid-1 for the first
    generated token.
    """
    tokens = batch["tokens"]
    start = cache["len"]
    n_valid = batch.get("n_valid")
    if n_valid is None:
        n_valid = jnp.full_like(start, tokens.shape[1])
    h = embed_tokens(cfg, params, tokens)
    positions3 = batch.get("positions3")
    quant = "k_scale" in cache
    dummy = jnp.zeros((cfg.n_layers,), jnp.bfloat16)

    def body(carry, xs):
        h = carry
        lp, kc, vc, ks, vs = xs
        a, kc, vc, ks, vs = attn_prefill_apply(
            cfg, lp["attn"], _norm(cfg, lp["ln1"], h), kc, vc, start,
            positions3=positions3,
            k_scale=ks if quant else None,
            v_scale=vs if quant else None)
        out = h + a
        out = out + mlp_apply(cfg, lp["mlp"], _norm(cfg, lp["ln2"], out))
        return out, (kc, vc, ks, vs)

    h, (k_new, v_new, ks_new, vs_new) = jax.lax.scan(
        body, h, (params["layers"], cache["k"], cache["v"],
                  cache.get("k_scale", dummy), cache.get("v_scale", dummy))
    )
    logits = logits_from_hidden(cfg, params, h)
    new_cache = {"k": k_new, "v": v_new, "len": start + n_valid}
    if quant:
        new_cache["k_scale"] = ks_new
        new_cache["v_scale"] = vs_new
    return logits, new_cache
