"""Family dispatch: one uniform model API over the six families.

  init_params(cfg, key)            -> params pytree (stacked layers)
  apply_train(cfg, params, batch)  -> (logits, aux_loss)
  init_cache(cfg, B, max_len)      -> decode cache pytree
  decode_step(cfg, params, cache, batch) -> (logits, cache)
  loss_fn(cfg, params, batch)      -> (loss, metrics)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba, moe, rwkv, transformer, vlm, whisper

__all__ = ["get_family", "init_params", "apply_train", "init_cache",
           "decode_step", "prefill_chunk", "supports_chunked_prefill",
           "loss_fn", "cross_entropy"]

_FAMILIES = {
    "dense": transformer,
    "moe": moe,
    "vlm": vlm,
    "hybrid": mamba,
    "ssm": rwkv,
    "audio": whisper,
}

MOE_AUX_WEIGHT = 0.01


def get_family(cfg: ModelConfig):
    try:
        return _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown model family {cfg.family!r}") from None


def init_params(cfg: ModelConfig, key):
    return get_family(cfg).init_params(cfg, key)


def apply_train(cfg: ModelConfig, params, batch: dict):
    mod = get_family(cfg)
    out = mod.forward(cfg, params, batch)
    if isinstance(out, tuple):
        return out  # (logits, aux) — MoE
    return out, jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    return get_family(cfg).init_cache(cfg, batch_size, max_len)


def decode_step(cfg: ModelConfig, params, cache: dict, batch: dict):
    return get_family(cfg).decode_step(cfg, params, cache, batch)


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """True when the family prefills C tokens per jitted call (dense /
    hybrid / ssm); the others fall back to token replay in the engine."""
    return hasattr(get_family(cfg), "prefill_chunk")


def prefill_chunk(cfg: ModelConfig, params, cache: dict, batch: dict):
    """Chunked prefill: batch["tokens"] (B, C) lands at cache["len"].. and
    only batch["n_valid"] leading tokens are real.  Returns full-chunk
    logits (B, C, V) and the updated cache (len advanced by n_valid)."""
    mod = get_family(cfg)
    if not hasattr(mod, "prefill_chunk"):
        raise NotImplementedError(
            f"family {cfg.family!r} has no chunked prefill; "
            "use token replay")
    return mod.prefill_chunk(cfg, params, cache, batch)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None):
    """Token-mean CE in f32.  logits (B, S, V); labels (B, S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(cfg: ModelConfig, params, batch: dict):
    logits, aux = apply_train(cfg, params, batch)
    ce = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    loss = ce + MOE_AUX_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}
