"""Shared model layers: norms, rotary embeddings (incl. M-RoPE), GQA
attention with an online-softmax chunked (flash-style) implementation,
and the MLP variants used by the assigned architectures.

Conventions:
  activations x: (B, S, D);  q: (B, S, H, hd);  k/v: (B, S, KV, hd).
  Computation in ``compute_dtype`` (bf16 by default) with f32 softmax/norm
  statistics and f32 attention accumulators.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm", "layer_norm", "init_dense", "dense",
    "rope_angles", "apply_rope", "apply_mrope",
    "flash_attention", "attention_decode", "attention_prefill", "repeat_kv",
    "mlp_gated", "mlp_relu2", "act_fn",
]

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Sharding hints
# --------------------------------------------------------------------------
def shard_hint(x: jnp.ndarray, *logical) -> jnp.ndarray:
    """Re-anchor sharding propagation inside scans.

    XLA's propagation through while loops sometimes replicates loop-carried
    activations (e.g. the q-block accumulator in chunked attention),
    silently multiplying per-device FLOPs.  This helper pins logical dims
    ("batch" -> the ambient mesh's ('pod','data') axes, "model" -> 'model',
    None -> unspecified) wherever an ambient mesh exists; it is a no-op
    otherwise, and skips any axis whose extent does not divide the dim.
    """
    from repro.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None:
        return x
    names = mesh.axis_names
    spec = []
    for dim, kind in zip(x.shape, logical):
        if kind == "batch":
            axes = tuple(a for a in ("pod", "data") if a in names)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            spec.append(axes if (axes and dim % size == 0) else None)
        elif kind == "model":
            ok = "model" in names and dim % mesh.shape["model"] == 0
            spec.append("model" if ok else None)
        else:
            spec.append(None)
    spec += [None] * (x.ndim - len(spec))
    from jax.sharding import PartitionSpec as _P
    return jax.lax.with_sharding_constraint(x, _P(*spec))


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# Dense / init
# --------------------------------------------------------------------------
def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
            ).astype(dtype)


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None):
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------
def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions (...,) -> cos/sin of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x (..., S, H, hd); cos/sin (..., S, hd//2) -> rotated x (half style)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def apply_rope(q, k, positions, theta: float = 1e4):
    """Standard RoPE. positions: (B, S)."""
    cos, sin = rope_angles(positions, q.shape[-1], theta)
    return _rotate(q, cos, sin), _rotate(k, cos, sin)


def apply_mrope(q, k, positions3, theta: float, sections=(16, 24, 24)):
    """Qwen2-VL multimodal RoPE: the rotary half-dim is split into
    (temporal, height, width) sections, each driven by its own position id.

    positions3: (3, B, S).  ``sections`` are half-dim section widths and
    must sum to head_dim // 2 (default matches head_dim=128: 16+24+24=64).
    """
    half = q.shape[-1] // 2
    if sum(sections) != half:
        # derive proportional sections
        base = half // 8
        sections = (2 * base, 3 * base, half - 5 * base)
    cos_parts, sin_parts = [], []
    for i, width in enumerate(sections):
        lo = sum(sections[:i])
        freqs = 1.0 / (
            theta ** (jnp.arange(lo, lo + width, dtype=jnp.float32) / half)
        )
        ang = positions3[i].astype(jnp.float32)[..., None] * freqs
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
    cos = jnp.concatenate(cos_parts, axis=-1)
    sin = jnp.concatenate(sin_parts, axis=-1)
    return _rotate(q, cos, sin), _rotate(k, cos, sin)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------
def repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, s, kv, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, d)).reshape(
        b, s, kv * n_rep, d
    )


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    bias: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Online-softmax chunked attention (pure JAX; O(S * chunk) memory).

    q: (B, Sq, H, hd); k/v: (B, Skv, KV, hd) with H % KV == 0.
    Returns (B, Sq, H, hd) in q.dtype.  ``causal`` aligns the *ends* of the
    q and kv sequences (standard for Sq == Skv; decode uses
    ``attention_decode``).
    """
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    k = repeat_kv(k, h // kvh)
    v = repeat_kv(v, h // kvh)
    scale = 1.0 / np.sqrt(hd)

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    pad_q = (-sq) % q_chunk
    pad_kv = (-skv) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    sq_p, skv_p = sq + pad_q, skv + pad_kv
    n_q, n_kv = sq_p // q_chunk, skv_p // kv_chunk

    # (B, H, S, hd) layout for matmuls; pin shardings so the q-block scan
    # cannot replicate batch/heads (see shard_hint)
    qt = q.transpose(0, 2, 1, 3).reshape(b, h, n_q, q_chunk, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(b, h, n_kv, kv_chunk, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(b, h, n_kv, kv_chunk, hd)
    qt = shard_hint(qt, "batch", "model", None, None, None)
    kt = shard_hint(kt, "batch", "model", None, None, None)
    vt = shard_hint(vt, "batch", "model", None, None, None)

    q_pos_base = jnp.arange(q_chunk)
    kv_pos_base = jnp.arange(kv_chunk)
    offset = skv - sq  # align sequence ends for causal masking

    def q_block(carry, qi):
        qb = jax.lax.dynamic_index_in_dim(qt, qi, axis=2, keepdims=False)
        # mixed precision: operands stream at the model dtype (bf16 on the
        # big configs), accumulation in f32 — the native TPU matmul mode;
        # halves the QK/PV operand traffic on every train/prefill cell
        qb = (qb.astype(jnp.float32) * scale).astype(q.dtype)
        q_pos = qi * q_chunk + q_pos_base

        def kv_block(state, ki):
            m, l, acc = state
            kb = jax.lax.dynamic_index_in_dim(kt, ki, axis=2, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vt, ki, axis=2, keepdims=False)
            s_ = jnp.einsum("bhqd,bhkd->bhqk", qb, kb,
                            preferred_element_type=jnp.float32)
            kv_pos = ki * kv_chunk + kv_pos_base
            mask = kv_pos[None, :] < skv  # kv padding
            if causal:
                mask = mask & (
                    q_pos[:, None] + offset >= kv_pos[None, :]
                )
            s_ = jnp.where(mask[None, None], s_, NEG_INF)
            if bias is not None:
                s_ = s_ + jax.lax.dynamic_slice(
                    bias,
                    (0, 0, qi * q_chunk, ki * kv_chunk),
                    (1, bias.shape[1], q_chunk, kv_chunk),
                ).astype(jnp.float32)
            m_new = jnp.maximum(m, s_.max(axis=-1))
            p = jnp.exp(s_ - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(q.dtype), vb,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, h, q_chunk), NEG_INF, jnp.float32),
            jnp.zeros((b, h, q_chunk), jnp.float32),
            shard_hint(jnp.zeros((b, h, q_chunk, hd), jnp.float32),
                       "batch", "model", None, None),
        )
        (m, l, acc), _ = jax.lax.scan(kv_block, init, jnp.arange(n_kv))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return carry, shard_hint(out.astype(q.dtype),
                                 "batch", "model", None, None)

    _, blocks = jax.lax.scan(q_block, None, jnp.arange(n_q))
    blocks = shard_hint(blocks, None, "batch", "model", None, None)
    # blocks: (n_q, B, H, qc, hd) -> (B, Sq, H, hd)
    out = blocks.transpose(1, 0, 3, 2, 4).reshape(b, sq_p, h, hd)
    return out[:, :sq]


def attention_decode(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    cache_len,
    k_scale: jnp.ndarray | None = None,
    v_scale: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Single-step decode attention over a KV cache.

    q: (B, 1, H, hd); k/v_cache: (B, S_max, KV, hd) (bf16 or int8 with
    (B, S_max, KV) scales); cache_len: scalar or
    (B,) valid lengths (entries at index >= cache_len are masked).

    Grouped-query form: the cache is contracted UN-repeated.  Materializing
    repeat_kv(k_cache) at H heads forces SPMD to reshard the (huge) cache
    to the q projection's head sharding — GBs of collective-permute per
    layer; contracting against (KV, rep)-factored q makes the tiny q side
    carry the reshard instead (hillclimb iter 2, EXPERIMENTS.md Perf).
    """
    b, _, h, hd = q.shape
    _, s_max, kvh, _ = k_cache.shape
    rep = h // kvh
    qg = q.reshape(b, 1, kvh, rep, hd).astype(jnp.float32) / np.sqrt(hd)
    s_ = jnp.einsum("bqkrd,bskd->bkrqs", qg,
                    k_cache.astype(jnp.float32))  # (B, KV, rep, 1, S)
    if k_scale is not None:
        # int8 cache: q.(k*s) == (q.k_int8)*s — the dot streams int8 and
        # the per-token-per-head scale folds into the scores exactly
        s_ = s_ * k_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None,
                                                                None, :]
    pos = jnp.arange(s_max)
    lens = jnp.asarray(cache_len)
    lens = lens[:, None] if lens.ndim == 1 else lens[None, None]
    mask = pos[None, :] < lens  # (B, S) or (1, S)
    s_ = jnp.where(mask[:, None, None, None, :], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    if v_scale is not None:
        # fold v scales into the probabilities: sum_s (p*s_v) . v_int8
        p = p * v_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None,
                                                               None, :]
    out = jnp.einsum("bkrqs,bskd->bqkrd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def attention_prefill(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    q_pos: jnp.ndarray,
    k_scale: jnp.ndarray | None = None,
    v_scale: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Chunked-prefill attention: a C-token query block over a KV cache.

    q: (B, C, H, hd); k/v_cache: (B, S_max, KV, hd) with the chunk's K/V
    already written at positions ``q_pos`` (B, C) int32.  Key j is visible
    to query i iff j <= q_pos[i] — causal over absolute cache positions, so
    earlier chunks are fully visible and later rows (pad garbage, stale
    pages) are masked.  Same un-repeated GQA contraction and f32 softmax as
    ``attention_decode`` so a chunked prefill followed by decode steps is
    numerically aligned with pure decode replay.
    """
    b, c, h, hd = q.shape
    s_max, kvh = k_cache.shape[1], k_cache.shape[2]
    rep = h // kvh
    qg = q.reshape(b, c, kvh, rep, hd).astype(jnp.float32) / np.sqrt(hd)
    s_ = jnp.einsum("bqkrd,bskd->bkrqs", qg,
                    k_cache.astype(jnp.float32))  # (B, KV, rep, C, S)
    if k_scale is not None:
        s_ = s_ * k_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None,
                                                                 None, :]
    pos = jnp.arange(s_max)
    mask = pos[None, None, :] <= q_pos[:, :, None]        # (B, C, S)
    s_ = jnp.where(mask[:, None, None], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    if v_scale is not None:
        p = p * v_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None,
                                                               None, :]
    out = jnp.einsum("bkrqs,bskd->bqkrd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, c, h, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return partial(jax.nn.gelu, approximate=True)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "relu":
        return jax.nn.relu
    raise ValueError(f"unknown activation {name!r}")


def mlp_gated(x, w_gate, w_up, w_down, activation: str = "silu"):
    """LLaMA-style gated MLP: down( act(x@gate) * (x@up) )."""
    act = act_fn(activation)
    return dense(act(dense(x, w_gate)) * dense(x, w_up), w_down)


def mlp_relu2(x, w_up, w_down, activation: str = "relu2"):
    """Non-gated MLP (nemotron-4: squared-ReLU)."""
    return dense(act_fn(activation)(dense(x, w_up)), w_down)
