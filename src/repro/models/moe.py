"""Mixture-of-Experts decoder LMs — dbrx-132b (16e top-4), phi3.5-moe
(16e top-2).

Dispatch is group-wise with static capacity (MaxText-style): tokens are
processed in groups of ``moe_group_size``; within a group a one-hot
dispatch/combine pair routes at most ``capacity`` tokens to each expert
(overflow drops, standard for capacity-based MoE).  The expert dimension is
the EP shard axis (experts sharded over ``model``); the einsum formulation
keeps every tensor static-shaped for pjit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T

__all__ = ["init_params", "forward", "init_cache", "decode_step", "moe_block"]


def init_moe_layer(key, cfg: ModelConfig):
    kr, kg, ku, kd = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": L.init_dense(kr, d, e, jnp.float32),  # router in f32
        "w_gate": (jax.random.normal(kg, (e, d, f), jnp.float32)
                   / jnp.sqrt(d)).astype(cfg.dtype),
        "w_up": (jax.random.normal(ku, (e, d, f), jnp.float32)
                 / jnp.sqrt(d)).astype(cfg.dtype),
        "w_down": (jax.random.normal(kd, (e, f, d), jnp.float32)
                   / jnp.sqrt(f)).astype(cfg.dtype),
    }


def _group_moe(cfg: ModelConfig, p, x):
    """One dispatch group: x (Tg, D) -> (y (Tg, D), aux_loss)."""
    tg = x.shape[0]
    e, k = cfg.n_experts, cfg.experts_per_token
    gate_logits = x.astype(jnp.float32) @ p["router"]          # (Tg, E)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (Tg, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    capacity = max(4, int(tg * k / e * cfg.capacity_factor) + 3 & ~3)
    sel = jax.nn.one_hot(top_e, e, dtype=jnp.float32)          # (Tg, k, E)
    # position of each (token, slot) within its expert queue
    pos_in_e = (jnp.cumsum(sel.reshape(tg * k, e), axis=0)
                .reshape(tg, k, e) - 1.0) * sel
    keep = sel * (pos_in_e < capacity)
    pos_oh = jax.nn.one_hot(pos_in_e.astype(jnp.int32), capacity,
                            dtype=jnp.float32) * keep[..., None]
    dispatch = pos_oh.sum(axis=1)                              # (Tg, E, C)
    combine = jnp.einsum("tkec,tk->tec", pos_oh, top_p)        # (Tg, E, C)

    xe = jnp.einsum("tec,td->ecd", dispatch.astype(cfg.cdtype),
                    x.astype(cfg.cdtype))                      # (E, C, D)
    xe = L.shard_hint(xe, "model", None, None)  # EP: experts on 'model'
    h = L.act_fn(cfg.activation)(
        jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(cfg.cdtype))
    ) * jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(cfg.cdtype))
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(cfg.cdtype))
    ye = L.shard_hint(ye, "model", None, None)
    y = jnp.einsum("tec,ecd->td", combine.astype(cfg.cdtype), ye)
    y = L.shard_hint(y, "batch", None)

    # Switch-style load-balance aux loss
    me = probs.mean(axis=0)                                    # (E,)
    ce = sel.sum(axis=1).mean(axis=0)                          # fraction routed
    aux = e * jnp.sum(me * ce) / k
    return y.astype(x.dtype), aux


def moe_block(cfg: ModelConfig, p, x):
    """x (B, S, D) -> (y, aux).  Groups tokens, scans groups under remat."""
    b, s, d = x.shape
    t = b * s
    tg = min(cfg.moe_group_size, t)
    pad = (-t) % tg
    flat = x.reshape(t, d)
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    groups = flat.reshape(-1, tg, d)

    def body(carry, xg):
        y, aux = _group_moe(cfg, p, xg)
        return carry + aux, y

    aux, ys = jax.lax.scan(body, jnp.zeros((), jnp.float32), groups)
    y = ys.reshape(-1, d)[:t].reshape(b, s, d)
    return y, aux / groups.shape[0]


def _init_layer(key, cfg: ModelConfig):
    ka, km = jax.random.split(key)
    return {
        "ln1": T.init_norm(cfg),
        "attn": T.init_attn_layer(ka, cfg),
        "ln2": T.init_norm(cfg),
        "moe": init_moe_layer(km, cfg),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kl, kh = jax.random.split(key, 3)
    params = {
        "embed": L.init_dense(ke, cfg.padded_vocab, cfg.d_model, cfg.dtype,
                              scale=0.02),
        "layers": T.stack_layer_init(_init_layer, kl, cfg.n_layers, cfg),
        "final_norm": T.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(kh, cfg.d_model, cfg.padded_vocab,
                                         cfg.dtype)
    return params


def forward(cfg: ModelConfig, params, batch: dict):
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = T.embed_tokens(cfg, params, tokens)

    def body(carry, lp):
        h, aux = carry
        hn = T._norm(cfg, lp["ln1"], h)
        h = h + T.attn_apply(cfg, lp["attn"], hn, positions)
        y, a = moe_block(cfg, lp["moe"], T._norm(cfg, lp["ln2"], h))
        return (h + y, aux + a), None

    (h, aux), _ = jax.lax.scan(
        T.remat_wrap(cfg, body), (h, jnp.zeros((), jnp.float32)),
        params["layers"])
    return T.logits_from_hidden(cfg, params, h), aux / cfg.n_layers


init_cache = T.init_cache


def decode_step(cfg: ModelConfig, params, cache: dict, batch: dict):
    tokens = batch["tokens"]
    h = T.embed_tokens(cfg, params, tokens)

    def body(carry, xs):
        h = carry
        lp, kc, vc = xs
        a, kc, vc, _, _ = T.attn_decode_apply(
            cfg, lp["attn"], T._norm(cfg, lp["ln1"], h), kc, vc, cache["len"])
        h = h + a
        y, _ = moe_block(cfg, lp["moe"], T._norm(cfg, lp["ln2"], h))
        return h + y, (kc, vc)

    h, (k_new, v_new) = jax.lax.scan(
        body, h, (params["layers"], cache["k"], cache["v"]))
    logits = T.logits_from_hidden(cfg, params, h)
    return logits, {"k": k_new, "v": v_new, "len": cache["len"] + 1}
