"""Whisper-small — encoder-decoder audio transformer (backbone only).

Per the assignment the conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, T_enc, D) directly (the two conv+GELU
layers of the real model are not the evaluated backbone).  Encoder:
bidirectional self-attention with sinusoidal positions.  Decoder: causal
self-attention + cross-attention with learned positions; LayerNorm and
non-gated GELU MLPs throughout; tied output embedding (as in the original).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T

__all__ = ["init_params", "forward", "encode", "init_cache", "prime_cross",
           "decode_step"]


def _sinusoid(n_pos: int, d: int) -> np.ndarray:
    pos = np.arange(n_pos)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    out = np.zeros((n_pos, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out


def _init_enc_layer(key, cfg: ModelConfig):
    ka, km = jax.random.split(key)
    return {
        "ln1": T.init_norm(cfg),
        "attn": T.init_attn_layer(ka, cfg),
        "ln2": T.init_norm(cfg),
        "mlp": T.init_mlp_layer(km, cfg),
    }


def _init_dec_layer(key, cfg: ModelConfig):
    ka, kc, km = jax.random.split(key, 3)
    return {
        "ln1": T.init_norm(cfg),
        "self_attn": T.init_attn_layer(ka, cfg),
        "ln_cross": T.init_norm(cfg),
        "cross_attn": T.init_attn_layer(kc, cfg),
        "ln2": T.init_norm(cfg),
        "mlp": T.init_mlp_layer(km, cfg),
    }


def init_params(cfg: ModelConfig, key, max_pos: int = 32768) -> dict:
    ke, kl, kd, kp = jax.random.split(key, 4)
    return {
        "embed": L.init_dense(ke, cfg.padded_vocab, cfg.d_model, cfg.dtype,
                              scale=0.02),
        "pos_embed": L.init_dense(kp, max_pos, cfg.d_model, cfg.dtype,
                                  scale=0.02),
        "enc_layers": T.stack_layer_init(_init_enc_layer, kl,
                                         cfg.encoder_layers, cfg),
        "enc_norm": T.init_norm(cfg),
        "dec_layers": T.stack_layer_init(_init_dec_layer, kd, cfg.n_layers,
                                         cfg),
        "final_norm": T.init_norm(cfg),
    }


def encode(cfg: ModelConfig, params, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, T_enc, D) stub conv-frontend output -> encoder states."""
    b, t, d = frames.shape
    h = frames.astype(cfg.cdtype) + jnp.asarray(
        _sinusoid(t, d), cfg.cdtype)[None]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    def body(h, lp):
        h = h + T.attn_apply(cfg, lp["attn"], T._norm(cfg, lp["ln1"], h),
                             positions, causal=False)
        h = h + T.mlp_apply(cfg, lp["mlp"], T._norm(cfg, lp["ln2"], h))
        return h, None

    h, _ = jax.lax.scan(T.remat_wrap(cfg, body), h, params["enc_layers"])
    return T._norm(cfg, params["enc_norm"], h)


def forward(cfg: ModelConfig, params, batch: dict) -> jnp.ndarray:
    """Teacher-forced decode over the full target sequence.
    batch: frames (B, T_enc, D), tokens (B, S)."""
    enc = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = (T.embed_tokens(cfg, params, tokens)
         + jnp.take(params["pos_embed"], jnp.arange(s), axis=0
                    ).astype(cfg.cdtype)[None])
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(h, lp):
        h = h + T.attn_apply(cfg, lp["self_attn"],
                             T._norm(cfg, lp["ln1"], h), positions)
        h = h + T.attn_apply(cfg, lp["cross_attn"],
                             T._norm(cfg, lp["ln_cross"], h), positions,
                             causal=False, kv_x=enc)
        h = h + T.mlp_apply(cfg, lp["mlp"], T._norm(cfg, lp["ln2"], h))
        return h, None

    h, _ = jax.lax.scan(T.remat_wrap(cfg, body), h, params["dec_layers"])
    return T.logits_from_hidden(cfg, params, h)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int) -> dict:
    kv_shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.hd)
    cross_shape = (cfg.n_layers, batch_size, cfg.encoder_seq,
                   cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(kv_shape, cfg.cdtype),
        "v": jnp.zeros(kv_shape, cfg.cdtype),
        "cross_k": jnp.zeros(cross_shape, cfg.cdtype),
        "cross_v": jnp.zeros(cross_shape, cfg.cdtype),
        "len": jnp.zeros((batch_size,), jnp.int32),
    }


def prime_cross(cfg: ModelConfig, params, cache: dict, frames: jnp.ndarray
                ) -> dict:
    """Run the encoder once and precompute every decoder layer's
    cross-attention K/V (decode-time cross-attn is then cache-only)."""
    enc = encode(cfg, params, frames)
    b, t, _ = enc.shape

    def per_layer(lp):
        p = lp["cross_attn"]
        k = L.dense(enc, p["wk"], p.get("bk")).reshape(
            b, t, cfg.n_kv_heads, cfg.hd)
        v = L.dense(enc, p["wv"], p.get("bv")).reshape(
            b, t, cfg.n_kv_heads, cfg.hd)
        return k, v

    ck, cv = jax.vmap(per_layer)(params["dec_layers"])
    return {**cache, "cross_k": ck, "cross_v": cv}


def decode_step(cfg: ModelConfig, params, cache: dict, batch: dict):
    tokens = batch["tokens"]
    b = tokens.shape[0]
    pos = cache["len"]
    h = (T.embed_tokens(cfg, params, tokens)
         + jnp.take(params["pos_embed"],
                    jnp.clip(pos, 0, params["pos_embed"].shape[0] - 1),
                    axis=0).astype(cfg.cdtype)[:, None, :])
    t_enc = cache["cross_k"].shape[2]

    def body(carry, xs):
        h = carry
        lp, kc, vc, ck, cv = xs
        a, kc, vc, _, _ = T.attn_decode_apply(
            cfg, lp["self_attn"], T._norm(cfg, lp["ln1"], h), kc, vc, pos)
        h = h + a
        # cross attention over the fixed encoder cache
        hn = T._norm(cfg, lp["ln_cross"], h)
        p = lp["cross_attn"]
        q = L.dense(hn, p["wq"], p.get("bq")).reshape(
            b, 1, cfg.n_heads, cfg.hd)
        x = L.attention_decode(q, ck, cv, jnp.full((b,), t_enc, jnp.int32))
        h = h + L.dense(x.reshape(b, 1, cfg.n_heads * cfg.hd), p["wo"])
        h = h + T.mlp_apply(cfg, lp["mlp"], T._norm(cfg, lp["ln2"], h))
        return h, (kc, vc)

    h, (k_new, v_new) = jax.lax.scan(
        body, h, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    logits = T.logits_from_hidden(cfg, params, h)
    return logits, {**cache, "k": k_new, "v": v_new, "len": cache["len"] + 1}
