"""Qwen2-VL-2b — VLM backbone with M-RoPE and dynamic-resolution stub.

Per the assignment the vision frontend (ViT patch encoder) is a STUB:
``input_specs()`` supplies precomputed patch embeddings (B, S, D) plus a
``vis_mask`` marking which sequence positions are visual; the backbone
splices them over the token embeddings.  M-RoPE drives rotary sections
(temporal, height, width) from a (3, B, S) position tensor — for text
positions all three components coincide (as in the reference model).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T

__all__ = ["init_params", "forward", "init_cache", "decode_step",
           "default_positions3"]

init_params = T.init_params
init_cache = T.init_cache


def default_positions3(b: int, s: int, start: int = 0) -> jnp.ndarray:
    pos = jnp.broadcast_to(
        jnp.arange(start, start + s, dtype=jnp.int32), (b, s))
    return jnp.broadcast_to(pos[None], (3, b, s))


def forward(cfg: ModelConfig, params, batch: dict) -> jnp.ndarray:
    tokens = batch["tokens"]
    b, s = tokens.shape
    if "positions3" not in batch:
        batch = dict(batch, positions3=default_positions3(b, s))
    return T.forward(cfg, params, batch)


def decode_step(cfg: ModelConfig, params, cache: dict, batch: dict):
    tokens = batch["tokens"]
    b = tokens.shape[0]
    if "positions3" not in batch:
        pos = cache["len"].astype(jnp.int32)[None, :, None]  # (1, B, 1)
        batch = dict(batch, positions3=jnp.broadcast_to(pos, (3, b, 1)))
    return T.decode_step(cfg, params, cache, batch)
