"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**specs).compile()`` must succeed on the
16x16 single-pod mesh AND the 2x16x16 multi-pod mesh for every cell, and
the compiled artifact yields the memory/cost/collective numbers the
roofline analysis (benchmarks/roofline.py) consumes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
Results are cached as JSON under experiments/dryrun/.
"""
# The VERY FIRST lines, before ANY other import: jax locks the device
# count on first init.  Do NOT set this anywhere global (conftest etc.).
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from functools import partial  # noqa: E402

import jax  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402
from repro.configs.registry import (  # noqa: E402
    ASSIGNED, get_config, skip_reason)
from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402
from repro.launch import specs as S  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.optim.adamw import OptConfig  # noqa: E402
from repro.serve.serve_step import prefill_fn, serve_step_fn  # noqa: E402
from repro.sharding import partition  # noqa: E402
from repro.train import train_step as ts  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

def _entry_fn_and_specs(cfg, shape, mesh, ocfg):
    """(callable, kwargs-of-ShapeDtypeStruct, in_shardings, donate)."""
    sp = S.input_specs(cfg, shape, ocfg)
    if shape.kind == "train":
        fn = partial(ts.train_step_fn, cfg, ocfg)
        in_sh = (partition.named(
                     mesh, ts.param_state_pspecs(sp["state"], mesh)),
                 partition.named(
                     mesh, partition.batch_pspecs(sp["batch"], mesh)))
        return fn, (sp["state"], sp["batch"]), in_sh, (0,)
    if shape.kind == "prefill":
        fn = partial(prefill_fn, cfg)
        in_sh = (partition.named(
                     mesh, partition.param_pspecs(sp["params"], mesh)),
                 partition.named(
                     mesh, partition.batch_pspecs(sp["batch"], mesh)))
        return fn, (sp["params"], sp["batch"]), in_sh, ()
    fn = partial(serve_step_fn, cfg)
    in_sh = (partition.named(
                 mesh, partition.serve_param_pspecs(
                     sp["params"], mesh, global_batch=shape.global_batch)),
             partition.named(
                 mesh, partition.cache_pspecs(sp["cache"], mesh)),
             partition.named(
                 mesh, partition.batch_pspecs(sp["batch"], mesh)))
    return fn, (sp["params"], sp["cache"], sp["batch"]), in_sh, (1,)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             ocfg: OptConfig | None = None, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "decode" and cfg.family not in ("ssm",):
        # serving deployment default: int8 KV cache with exact score-folded
        # scales (hillclimb iter 6; EXPERIMENTS.md section Perf)
        cfg = cfg.replace(kv_cache_dtype="int8")
    ocfg = ocfg or OptConfig()
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, in_sh, donate = _entry_fn_and_specs(cfg, shape, mesh, ocfg)
    t0 = time.time()
    with compat.set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=in_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    hcost = analyze_hlo(hlo).as_dict()
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "mesh_shape": list(mesh.devices.shape),
        "n_devices": int(mesh.devices.size),
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # raw XLA cost analysis (per called-computation, loops NOT scaled)
        "xla_flops_per_device": float(cost.get("flops", -1)),
        "xla_bytes_per_device": float(cost.get("bytes accessed", -1)),
        # trip-count-scaled HLO analysis (per-device program)
        "hlo_cost": hcost,
        "memory": {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        },
        "hlo_bytes": len(hlo),
    }
    if verbose:
        print(f"[{arch} x {shape_name} x "
              f"{'multi' if multi_pod else 'single'}] OK "
              f"compile={t_compile:.0f}s "
              f"dotflops/dev={hcost['dot_flops']:.3g} "
              f"dotbytes/dev={hcost['dot_bytes']:.3g} "
              f"coll/dev={hcost['collective_total_bytes']:.3g}B "
              f"temp={result['memory'].get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
        print("  memory_analysis:", {k: f"{v/2**30:.2f}GiB"
                                     for k, v in result["memory"].items()})
    return result


def cell_path(arch, shape_name, mesh_name):
    return os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_name}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(OUT_DIR, exist_ok=True)
    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mesh_name in meshes:
                path = cell_path(arch, shape_name, mesh_name)
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[{arch} x {shape_name} x {mesh_name}] cached "
                              f"({prev['status']})")
                        continue
                try:
                    res = run_cell(arch, shape_name, mesh_name == "multi")
                except Exception as e:  # noqa: BLE001 - report, keep sweeping
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    failures.append((arch, shape_name, mesh_name, str(e)))
                    print(f"[{arch} x {shape_name} x {mesh_name}] FAILED: "
                          f"{type(e).__name__}: {str(e)[:300]}")
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f4 in failures:
            print("  ", f4[:3], f4[3][:150])
        raise SystemExit(1)
    print("\nAll requested dry-run cells passed.")


if __name__ == "__main__":
    main()
