"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real device count).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: ``data`` (batch / FSDP), ``model`` (TP/EP), and ``pod`` (the
    second data-parallel tier across ICI-islands) when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Whatever this host has (tests / examples)."""
    n = jax.device_count()
    mp = min(model_parallel, n)
    return make_mesh((n // mp, mp), ("data", "model"))
