"""Training launcher: ``python -m repro.launch.train --arch granite-3-2b
--steps 200 [--reduced] [--microbatches N] [--compress-grads]``.

On this CPU container use ``--reduced`` (the smoke-scale config); the full
configs are exercised through the dry-run.  On a real cluster the same
entry point runs under ``jax.distributed.initialize()`` with the
production mesh.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.optim.adamw import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (requires 256 devices)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    shape = ShapeConfig("cli", args.seq_len, args.global_batch, "train")
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_local_mesh())
    ocfg = OptConfig(peak_lr=args.lr, warmup_steps=min(20, args.steps // 5),
                     decay_steps=args.steps)
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         log_every=10, microbatches=args.microbatches,
                         compress_grads=args.compress_grads)
    tr = Trainer(cfg, shape, mesh, ocfg, tcfg)
    kind, step = tr.init_or_resume()
    print(f"{kind} at step {step}; devices={jax.device_count()} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    tr.train(args.steps - step)
    tr.save()
    print(f"done at step {tr.step}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
