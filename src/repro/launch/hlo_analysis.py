"""Post-partitioning HLO cost analysis.

``compiled.cost_analysis()`` on XLA counts each called computation ONCE —
scan/while bodies are not scaled by their trip counts, which undercounts an
80-layer scanned transformer by ~80x.  This module parses the optimized HLO
text (operand types resolved through the instruction table), builds the
call graph, and propagates costs with:

  * dot FLOPs = 2 * numel(result) * prod(lhs contracting dims)  (exact);
  * elementwise FLOPs = numel(result) (minor term);
  * while bodies scaled by ``known_trip_count`` from backend_config;
  * conditionals charged at the max over branches (upper bound; models in
    this repo avoid conditionals on hot paths);
  * collective bytes = sum of *operand* sizes per op (per-device shard
    shapes — the per-chip traffic convention used by the roofline);
  * memory bytes = 2x result-buffer bytes (write + read) of every
    materialized top-level instruction; fusion bodies contribute FLOPs but
    no traffic (they live in registers/VMEM).
"""
from __future__ import annotations

import dataclasses
import json
import re

__all__ = ["analyze_hlo", "HLOCost"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "iota", "after-all", "partition-id", "replica-id"}


@dataclasses.dataclass
class HLOCost:
    flops: float = 0.0
    dot_flops: float = 0.0
    dot_bytes: float = 0.0   # dot operand+result streams (TPU-fusion bound)
    bytes: float = 0.0
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    collective_counts: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def add(self, other: "HLOCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.dot_flops += other.dot_flops * mult
        self.dot_bytes += other.dot_bytes * mult
        self.bytes += other.bytes * mult
        for k in _COLLECTIVES:
            self.collective_bytes[k] += other.collective_bytes[k] * mult
            self.collective_counts[k] += int(
                other.collective_counts[k] * mult)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "dot_flops": self.dot_flops,
            "dot_bytes": self.dot_bytes,
            "bytes": self.bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "collective_total_bytes": self.total_collective_bytes,
        }


def _type_info(type_str: str):
    """'(bf16[2,3]{...}, f32[4])' or 'f32[2,3]{1,0}' -> (numel, bytes)."""
    numel = 0
    nbytes = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        numel += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return numel, nbytes


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    operands: list
    attrs: str


# tuple types contain /*index=N*/ comments (hence [^)]* not [^=]*)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?)|\w+)\s+"
    r"([\w\-]+)\(")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->")


def _balanced(s: str, start: int) -> int:
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s)


def parse_hlo(text: str):
    comps: dict[str, dict] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and "->" in line and line.rstrip().endswith("{"):
            m = _HEADER_RE.match(line.strip())
            if m:
                cur = m.group(2)
                comps[cur] = {"instrs": {}, "order": []}
                if m.group(1):
                    entry = cur
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        paren = line.index("(", m.end() - 1)
        close = _balanced(line, paren)
        operand_str = line[paren + 1 : close]
        operands = re.findall(r"%([\w\.\-]+)", operand_str)
        attrs = line[close + 1 :]
        if op == "parameter":
            # keep the parameter index in attrs for fusion-body lookups
            attrs = f"param_index={operand_str.strip()} " + attrs
        comps[cur]["instrs"][name] = _Instr(name, type_str, op, operands,
                                            attrs)
        comps[cur]["order"].append(name)
    return comps, entry


def _trip_count(attrs: str) -> float:
    m = re.search(r'known_trip_count\\?":\s*{\\?"n\\?":\\?"(\d+)', attrs)
    if m:
        return float(m.group(1))
    m = re.search(r'known_trip_count":\{"n":"(\d+)"', attrs)
    if m:
        return float(m.group(1))
    return 1.0


def _called(attrs: str, *keys) -> list:
    out = []
    for key in keys:
        for m in re.finditer(rf"{key}=%?([\w\.\-]+)", attrs):
            out.append(m.group(1))
        m = re.search(rf"{key}=\{{([^}}]*)\}}", attrs)
        if m:
            out.extend(re.findall(r"%?([\w\.\-]+)", m.group(1)))
    return out


def _fusion_param_read(body: dict, index: int, fallback: float) -> float:
    """Bytes a fusion body actually reads of parameter ``index``: the
    dynamic-slice output when the param is only sliced, else ``fallback``."""
    pname = None
    for iname in body["order"]:
        ins = body["instrs"][iname]
        if ins.op == "parameter" and f"param_index={index} " in ins.attrs:
            pname = iname
            break
    if pname is None:
        return fallback
    best = fallback
    for ins in body["instrs"].values():
        if pname in ins.operands:
            if ins.op == "dynamic-slice":
                best = min(best, _type_info(ins.type_str)[1])
            else:
                return fallback  # consumed whole somewhere
    return best


def analyze_hlo(text: str) -> HLOCost:
    comps, entry = parse_hlo(text)
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO")

    # fusion bodies: computations referenced by calls= from fusion ops
    fusion_bodies = set()
    for c in comps.values():
        for ins in c["instrs"].values():
            if ins.op == "fusion":
                fusion_bodies.update(_called(ins.attrs, "calls"))

    memo: dict[str, HLOCost] = {}

    _PASSTHRU = {"convert", "copy", "bitcast", "transpose", "reshape"}

    def source_bytes(comp, name, depth=0) -> float:
        """HBM bytes behind a dot operand.  XLA:CPU widens bf16/int8 dot
        inputs to f32 through converts and dequant *fusions*; on TPU the
        narrow source is what HBM streams (converts fuse into the matmul),
        so follow pass-through chains and fusions and charge the smaller
        of output vs summed-input bytes."""
        ins = comp["instrs"].get(name)
        if ins is None:
            return 0.0
        out_b = _type_info(ins.type_str)[1]
        if depth >= 4:
            return out_b
        if ins.op in _PASSTHRU and ins.operands:
            return min(out_b, source_bytes(comp, ins.operands[0], depth + 1))
        if ins.op == "fusion" and ins.operands:
            bodies = _called(ins.attrs, "calls")
            body = comps.get(bodies[0]) if bodies else None
            in_b = 0.0
            for i, o in enumerate(ins.operands):
                full = source_bytes(comp, o, depth + 1)
                # a scan xs (stacked-layer array) enters the fusion whole,
                # but a dynamic-slice inside reads one layer: charge the
                # slice, not the stack
                if body is not None:
                    full = min(full, _fusion_param_read(body, i, full))
                in_b += full
            return min(out_b, in_b)
        return out_b

    def operand_bytes(comp, ins) -> float:
        return sum(source_bytes(comp, o) for o in ins.operands)

    def lhs_shape(comp, ins) -> list:
        if not ins.operands:
            return []
        lhs = comp["instrs"].get(ins.operands[0])
        if lhs is None:
            return []
        m = _SHAPE_RE.search(lhs.type_str)
        if not m:
            return []
        dims = m.group(2)
        return [int(d) for d in dims.split(",")] if dims else []

    def cost_of(name: str) -> HLOCost:
        if name in memo:
            return memo[name]
        memo[name] = HLOCost()  # break cycles defensively
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        c = HLOCost()
        in_fusion = name in fusion_bodies
        for iname in comp["order"]:
            ins = comp["instrs"][iname]
            numel, nbytes = _type_info(ins.type_str)
            op = ins.op
            if op == "dot":
                k = 1
                m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
                ldims = lhs_shape(comp, ins)
                if m and ldims:
                    for d in m.group(1).split(","):
                        if d:
                            k *= ldims[int(d)]
                fl = 2.0 * numel * k
                c.flops += fl
                c.dot_flops += fl
                c.dot_bytes += operand_bytes(comp, ins) + nbytes
                c.bytes += 2.0 * nbytes
            elif op == "while":
                trip = _trip_count(ins.attrs)
                for sub in _called(ins.attrs, "body", "condition"):
                    c.add(cost_of(sub), trip)
            elif op == "conditional":
                branches = _called(ins.attrs, "branch_computations",
                                   "true_computation", "false_computation")
                if branches:
                    best = None
                    for b in branches:
                        cb = cost_of(b)
                        if best is None or cb.flops > best.flops:
                            best = cb
                    c.add(best)
            elif op in ("call", "custom-call", "fusion", "map", "reduce",
                        "sort", "scatter", "select-and-scatter"):
                for sub in _called(ins.attrs, "calls", "to_apply"):
                    c.add(cost_of(sub))
                if op != "fusion":
                    c.flops += numel
                if not in_fusion:
                    c.bytes += 2.0 * nbytes
            else:
                base = op.rsplit("-start", 1)[0]
                if base in _COLLECTIVES:
                    if op.endswith("-done"):
                        continue
                    opb = 0
                    for o in ins.operands:
                        src = comp["instrs"].get(o)
                        if src is not None:
                            opb += _type_info(src.type_str)[1]
                    c.collective_bytes[base] += opb
                    c.collective_counts[base] += 1
                    continue
                if op not in _NO_TRAFFIC:
                    c.flops += numel
                    if not in_fusion:
                        c.bytes += 2.0 * nbytes
        memo[name] = c
        return c

    # entry parameters count as read traffic once
    total = HLOCost()
    total.add(cost_of(entry))
    for ins in comps[entry]["instrs"].values():
        if ins.op == "parameter":
            total.bytes += _type_info(ins.type_str)[1]
    return total


if __name__ == "__main__":  # small CLI for debugging
    import sys

    with open(sys.argv[1]) as f:
        cost = analyze_hlo(f.read())
    print(json.dumps(cost.as_dict(), indent=1))
