"""ShapeDtypeStruct stand-ins for every model input — the dry-run contract.

``input_specs`` returns weak-type-correct, shardable specs with **no device
allocation** for each (arch, shape) cell:
  train   -> the full train-state + batch for ``train_step``
  prefill -> params + batch for ``prefill_fn``
  decode  -> params + KV-cache + one-token batch for ``serve_step``
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import factory
from repro.optim.adamw import OptConfig
from repro.train import train_step as ts

__all__ = ["train_batch_specs", "prefill_batch_specs", "decode_batch_specs",
           "cache_specs", "params_specs", "state_specs", "input_specs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((b, s), jnp.int32),
             "labels": _sds((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["embeddings"] = _sds((b, s, cfg.d_model), cfg.cdtype)
        batch["vis_mask"] = _sds((b, s), jnp.bool_)
        batch["positions3"] = _sds((3, b, s), jnp.int32)
    if cfg.family == "audio":
        batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), cfg.cdtype)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    batch = train_batch_specs(cfg, shape)
    batch.pop("labels")
    return batch


def decode_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    return {"tokens": _sds((shape.global_batch, 1), jnp.int32)}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Decode cache at depth seq_len (the cache the new token attends to)."""
    return jax.eval_shape(
        lambda: factory.init_cache(cfg, shape.global_batch, shape.seq_len))


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: factory.init_params(cfg, jax.random.PRNGKey(0)))


def state_specs(cfg: ModelConfig, ocfg: OptConfig | None = None):
    ocfg = ocfg or OptConfig()
    return jax.eval_shape(
        lambda: ts.init_train_state(cfg, ocfg, jax.random.PRNGKey(0)))


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                ocfg: OptConfig | None = None) -> dict:
    """Everything the cell's entry point consumes, as specs."""
    if shape.kind == "train":
        return {"state": state_specs(cfg, ocfg),
                "batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": params_specs(cfg),
                "batch": prefill_batch_specs(cfg, shape)}
    if shape.kind == "decode":
        return {"params": params_specs(cfg),
                "cache": cache_specs(cfg, shape),
                "batch": decode_batch_specs(cfg, shape)}
    raise ValueError(f"unknown shape kind {shape.kind!r}")
