"""Serving launcher: batched continuous decoding, optionally with ESPIM
sparse weights (the paper's deployment scenario).

``python -m repro.launch.serve --arch granite-3-2b --reduced
    --requests 8 --espim-sparsity 0.9``
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs.registry import get_config
from repro.models import factory
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params = factory.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      max_len=args.max_len, temperature=args.temperature)
    rng = jax.random.PRNGKey(1)
    for rid in range(args.requests):
        rng, k = jax.random.split(rng)
        prompt = jax.random.randint(
            k, (4,), 0, cfg.vocab_size).tolist()
        eng.submit(Request(rid=rid, prompt=prompt,
                           max_new_tokens=args.max_new_tokens))
    t0 = time.time()
    stats = eng.run()
    dt = time.time() - t0
    print(f"completed {stats.requests_completed} requests, "
          f"{stats.tokens_generated} tokens in {dt:.2f}s "
          f"({stats.tokens_generated / max(dt, 1e-9):.1f} tok/s, "
          f"{stats.steps} engine steps)")


if __name__ == "__main__":
    main()
