"""Version-compatibility shims for the jax APIs this repo uses.

The codebase targets the current jax mesh/sharding surface (``jax.set_mesh``,
``jax.shard_map``, ``jax.sharding.AxisType``, ``get_abstract_mesh``); older
runtimes (0.4.x) expose the same functionality under different names or not
at all.  Everything here degrades gracefully: on old jax the helpers fall
back to the experimental/legacy spellings, and purely-advisory features
(axis types, ambient-mesh hints) become no-ops rather than hard errors.
"""
from __future__ import annotations

import contextlib

import jax

__all__ = [
    "AXIS_TYPE_AUTO",
    "make_mesh",
    "set_mesh",
    "shard_map",
    "get_abstract_mesh",
]

AXIS_TYPE_AUTO = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)


def make_mesh(shape, axis_names, *, devices=None):
    """``jax.make_mesh`` with Auto axis types where the runtime supports
    them (newer jax requires explicit types for shard_map interop; old jax
    has no such concept)."""
    kwargs = {} if devices is None else {"devices": devices}
    if AXIS_TYPE_AUTO is not None:
        try:
            return jax.make_mesh(
                shape, axis_names,
                axis_types=(AXIS_TYPE_AUTO,) * len(axis_names), **kwargs)
        except TypeError:  # make_mesh predates axis_types
            pass
    return jax.make_mesh(shape, axis_names, **kwargs)


@contextlib.contextmanager
def set_mesh(mesh):
    """Ambient-mesh context: ``jax.set_mesh`` when available, else the
    legacy ``Mesh.__enter__`` context (same scoping semantics)."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` (check_vma) or the experimental fallback
    (check_rep) — the flag is the same knob under both names."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)


def get_abstract_mesh():
    """The ambient abstract mesh, or None when the runtime predates the
    concept (callers treat None as "no ambient mesh, skip the hint")."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        return None
    mesh = fn()
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return None
    return mesh
