"""Weight quantization for the ESPIM value planes (DESIGN.md section 9).

The paper stores narrow fixed-point cell *values* in DRAM, decoupled from
the cell *indices* (contribution 3) — the bytes/nnz crossing the pin is the
metric its architecture optimizes.  This package is that value-plane
discipline for the packed formats: ``calibrate`` turns a pack's fp value
plane into per-row-group scales + int8/int4 codes (indices, perms and SDDS
schedules untouched), ``qpack`` carries the quantized plane through
(de)quantization, serialization and bytes accounting.
"""
from repro.quant.calibrate import (QuantSpec, default_spec, group_scales,
                                   quantize_codes)
from repro.quant.qpack import (QuantizedValuePlane, dequantize_plane,
                               quantize_bucketed_stack, quantize_pack,
                               quantize_plane)

__all__ = [
    "QuantSpec",
    "default_spec",
    "group_scales",
    "quantize_codes",
    "QuantizedValuePlane",
    "quantize_plane",
    "quantize_pack",
    "quantize_bucketed_stack",
    "dequantize_plane",
]
