"""QuantizedValuePlane — the narrow value plane of a packed sparse matrix.

Mirrors the paper's value/index decoupling (contribution 3): only the cell
*values* of a pack are re-encoded; ``cols``, ``perm`` and the SDDS chunk /
width-bucket schedules are untouched, so every kernel keeps its gather
geometry and swaps the fp value block for int8 codes (or nibble-packed
int4) plus one scale per row group.

Storage forms:

* **codes container** (``q``): int8, same shape as the fp plane — what the
  CPU/ref lowerings and the int8 Pallas kernel consume.  int4 codes live
  in [-7, 7] inside the same container; fallback groups hold int8 codes.
* **nibble-packed** (``device_codes()`` when the plane is uniformly int4):
  uint8 with the last dim halved — two codes per byte, low nibble = even
  slot — consumed by the int4 Pallas kernel.
* **serialized** (``to_bytes()``): the honest pin-bytes form — per group,
  4-bit groups are nibble-packed, fallback groups raw int8 — round-trips
  via ``from_bytes`` and is what ``value_bytes`` accounts.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.quant.calibrate import (QMAX, QuantSpec, group_rel_error,
                                   group_scales, quantize_codes)

__all__ = [
    "QuantizedValuePlane",
    "quantize_plane",
    "quantize_pack",
    "quantize_bucketed_stack",
    "dequantize_plane",
    "nibble_pack",
    "nibble_unpack",
]

_MAGIC = b"ESPIMQVP1"


def nibble_pack(codes: np.ndarray) -> np.ndarray:
    """int4 codes (int8 container, last dim even) -> uint8, last dim
    halved.  Slot 2j lands in the low nibble of byte j, slot 2j+1 in the
    high nibble (two's-complement nibbles)."""
    if codes.shape[-1] % 2:
        raise ValueError(f"last dim must be even, got {codes.shape}")
    u = codes.astype(np.uint8) & 0xF
    lo, hi = u[..., 0::2], u[..., 1::2]
    return (lo | (hi << 4)).astype(np.uint8)


def nibble_unpack(packed: np.ndarray) -> np.ndarray:
    """Inverse of ``nibble_pack``: uint8 (..., P) -> int8 (..., 2P)."""
    lo = (packed & 0xF).astype(np.int16)
    hi = (packed >> 4).astype(np.int16)
    lo = np.where(lo > 7, lo - 16, lo)
    hi = np.where(hi > 7, hi - 16, hi)
    out = np.empty(packed.shape[:-1] + (2 * packed.shape[-1],), np.int8)
    out[..., 0::2] = lo
    out[..., 1::2] = hi
    return out


@dataclasses.dataclass
class QuantizedValuePlane:
    """Quantized value plane of shape (..., R, K, Lc) (leading dims stack
    layers); scales/group_bits are (..., G) with G = R // group_rows."""

    q: np.ndarray            # int8 codes container, plane shape
    scales: np.ndarray       # float32 (..., G)
    group_bits: np.ndarray   # uint8 (..., G), entries in {4, 8}
    group_rows: int          # effective rows per scale group
    bits: int                # requested mode: 8 | 4
    nnz: int                 # valid (non-pad) cells in the plane
    spec: QuantSpec | None = None   # the spec that produced this plane
    # (None for hand-built / deserialized planes: consumers that cache by
    # spec — pack_to_device — then requantize rather than trust a match)

    @property
    def plane_shape(self) -> tuple:
        return self.q.shape

    @property
    def n_slots(self) -> int:
        return int(np.prod(self.q.shape))

    @property
    def slots_per_group(self) -> int:
        return self.group_rows * self.q.shape[-2] * self.q.shape[-1]

    @property
    def n_groups(self) -> int:
        return int(np.prod(self.scales.shape))

    @property
    def n_fallback_groups(self) -> int:
        return int((self.group_bits == 8).sum()) if self.bits == 4 else 0

    @property
    def uniform_int4(self) -> bool:
        return self.bits == 4 and bool((self.group_bits == 4).all())

    @property
    def storage(self) -> str:
        """Device storage family: ``"nib4"`` iff every group is 4-bit (the
        nibble kernel needs one uniform byte layout); else ``"i8"``."""
        return "nib4" if self.uniform_int4 else "i8"

    # ------------------------------------------------------------ accounting
    @property
    def value_bytes(self) -> int:
        """Serialized value-plane bytes: per-group packed codes + one f32
        scale per group + (int4 mode) a 1-bit-per-group fallback map."""
        return int(self.value_bytes_by_lead().sum())

    @property
    def bits_per_nnz(self) -> float:
        """Value-plane bits per *useful* cell — the paper's pin metric
        (padding slots and scale overhead charged to the nnz they serve)."""
        return 8.0 * self.value_bytes / max(1, self.nnz)

    def value_bytes_by_lead(self) -> np.ndarray:
        """``value_bytes`` split over the leading (layer-stack) dims:
        shape ``scales.shape[:-1]`` (scalar array for a single plane)."""
        s = self.slots_per_group
        gb = self.group_bits.astype(np.int64)
        code = ((s * gb + 7) // 8).sum(axis=-1)
        meta = 4 * gb.shape[-1]
        if self.bits == 4:
            meta += (gb.shape[-1] + 7) // 8
        return code + meta

    # ------------------------------------------------------------ transforms
    def row_scales(self) -> np.ndarray:
        """Per-row scales, pre-expanded from the per-group table
        (``np.repeat`` over the row axis).  This is the ``srow`` operand
        of the fused serving path and of the kernel GLU epilogue
        (``ops.espim_spmv_batched_quant(..., epilogue="glu", srow=...)``):
        expanding once offline folds the whole dequant into a single
        multiply per launch."""
        return np.repeat(self.scales, self.group_rows, axis=-1)

    # backwards-compatible private alias (pre-PR-10 name)
    _row_scales = row_scales

    def dequantize(self) -> np.ndarray:
        """Reconstruct the fp32 value plane: q * scale per row group."""
        return (self.q.astype(np.float32)
                * self.row_scales()[..., :, None, None])

    def device_codes(self) -> np.ndarray:
        """The array the kernels gather: nibble-packed uint8 (last dim
        halved) for uniformly-int4 planes, else the int8 container."""
        if self.storage != "nib4":
            return self.q
        q = self.q
        if q.shape[-1] % 2:
            q = np.concatenate([q, np.zeros(q.shape[:-1] + (1,), np.int8)],
                               axis=-1)
        return nibble_pack(q)

    # ---------------------------------------------------------- serialization
    def to_bytes(self) -> bytes:
        """Compact on-disk / on-pin form (see module docstring)."""
        head = json.dumps({
            "shape": list(self.q.shape),
            "scales_shape": list(self.scales.shape),
            "group_rows": self.group_rows,
            "bits": self.bits,
            "nnz": self.nnz,
        }).encode()
        gb = self.group_bits.reshape(-1)
        # group-major walk: (..., G, slots_per_group) is a pure reshape
        gview = self.q.reshape(-1, self.scales.shape[-1], self.slots_per_group)
        chunks = []
        for n in range(gview.shape[0]):
            for g in range(gview.shape[1]):
                codes = gview[n, g]
                if gb[n * gview.shape[1] + g] == 4:
                    if codes.shape[-1] % 2:
                        codes = np.concatenate([codes, np.zeros(1, np.int8)])
                    chunks.append(nibble_pack(codes).tobytes())
                else:
                    chunks.append(codes.astype(np.int8).tobytes())
        return b"".join([
            _MAGIC, len(head).to_bytes(4, "little"), head,
            gb.astype(np.uint8).tobytes(),
            self.scales.astype(np.float32).tobytes(),
            *chunks,
        ])

    @classmethod
    def from_bytes(cls, buf: bytes) -> "QuantizedValuePlane":
        if buf[:len(_MAGIC)] != _MAGIC:
            raise ValueError("not a serialized QuantizedValuePlane")
        off = len(_MAGIC)
        hlen = int.from_bytes(buf[off:off + 4], "little")
        off += 4
        meta = json.loads(buf[off:off + hlen].decode())
        off += hlen
        shape = tuple(meta["shape"])
        sshape = tuple(meta["scales_shape"])
        n_groups = int(np.prod(sshape))
        gb = np.frombuffer(buf, np.uint8, n_groups, off).copy()
        off += n_groups
        scales = np.frombuffer(buf, np.float32, n_groups, off).copy()
        off += 4 * n_groups
        spg = meta["group_rows"] * shape[-2] * shape[-1]
        groups = []
        for g in range(n_groups):
            if gb[g] == 4:
                nb = (spg + 1) // 2
                packed = np.frombuffer(buf, np.uint8, nb, off)
                off += nb
                groups.append(nibble_unpack(packed)[:spg])
            else:
                groups.append(np.frombuffer(buf, np.int8, spg, off).copy())
                off += spg
        q = np.stack(groups).reshape(shape)
        return cls(q=q, scales=scales.reshape(sshape),
                   group_bits=gb.reshape(sshape),
                   group_rows=meta["group_rows"], bits=meta["bits"],
                   nnz=meta["nnz"])


def dequantize_plane(q: np.ndarray, scales: np.ndarray,
                     group_rows: int) -> np.ndarray:
    """Free-function dequant for raw arrays (the test oracle)."""
    s = np.repeat(np.asarray(scales, np.float32), group_rows, axis=-1)
    return np.asarray(q, np.float32) * s[..., :, None, None]


def quantize_plane(values: np.ndarray, valid: np.ndarray,
                   spec: QuantSpec) -> QuantizedValuePlane:
    """Quantize a (..., R, K, Lc) value plane per ``spec``.

    int4 mode applies the per-group fallback: groups whose relative L2
    reconstruction error exceeds ``spec.err_bound`` are re-calibrated and
    re-coded at int8 (their scale shrinks by ~qmax8/qmax4, their codes
    widen) — mixed planes keep the int8 container on device, uniformly
    4-bit planes nibble-pack (``storage``).
    """
    values = np.asarray(values, np.float32)
    valid = np.asarray(valid, bool)
    if values.ndim < 3:
        raise ValueError(f"plane must be (..., R, K, Lc), got {values.shape}")
    if values.shape != valid.shape:
        raise ValueError("values/valid shape mismatch")
    group = spec.effective_group(values.shape[-3])
    scales = group_scales(values, valid, spec)
    q = quantize_codes(values, scales, spec.bits, group)
    group_bits = np.full(scales.shape, spec.bits, np.uint8)

    if spec.bits == 4 and spec.err_bound is not None:
        deq = dequantize_plane(q, scales, group)
        err = group_rel_error(values, deq, valid, group)
        fb = err > spec.err_bound
        if fb.any():
            # fallback groups re-calibrate at int8 *absmax* so they carry
            # the LSB guarantee (|err| <= scale/2) whatever the int4 calib
            spec8 = dataclasses.replace(spec, calib="absmax")
            scales8 = group_scales(values, valid, spec8, bits=8)
            q8 = quantize_codes(values, scales8, 8, group)
            sel = np.repeat(fb, group, axis=-1)[..., :, None, None]
            q = np.where(sel, q8, q)
            scales = np.where(fb, scales8, scales).astype(np.float32)
            group_bits = np.where(fb, 8, group_bits).astype(np.uint8)

    return QuantizedValuePlane(q=q, scales=scales, group_bits=group_bits,
                               group_rows=group, bits=spec.bits,
                               nnz=int(valid.sum()), spec=spec)


def quantize_pack(pack, spec: QuantSpec, attach: bool = True
                  ) -> QuantizedValuePlane:
    """Quantize the value plane of an ``ELLPack`` (viewed as K=1) or an
    ``ELLChunkedPack``; ``attach=True`` stores it as ``pack.qplane`` and
    rewrites ``pack.stats`` with the quantized byte accounting."""
    values, valid = pack.values, pack.valid
    if values.ndim == 2:                       # plain ELL: one full-width chunk
        values = values[:, None, :]
        valid = valid[:, None, :]
    plane = quantize_plane(values, valid, spec)
    if attach:
        pack.qplane = plane
        pack.stats = dataclasses.replace(pack.stats,
                                         value_bytes=plane.value_bytes)
        _refresh_fingerprint(pack)
    return plane


def quantize_bucketed_stack(pack, spec: QuantSpec, attach: bool = True
                            ) -> list:
    """Quantize every bucket of a ``BucketedStackedPack``: one plane per
    bucket of shape (L, halves*Rg, K, Lc_g) — scales stack over layers
    exactly like the value arrays, so they scan as one more leaf.  The
    effective group per bucket is gcd(spec.group_rows, halves*Rg)."""
    planes = [quantize_plane(b["values"], b["valid"], spec)
              for b in pack.buckets]
    if attach:
        pack.qplanes = planes
        _refresh_fingerprint(pack)
    return planes


def _refresh_fingerprint(pack) -> None:
    """Attaching quant planes changes the pack's plane set, so the bound
    fingerprint recorded at build must be recomputed (only for packs the
    builders fingerprinted — hand-assembled packs stay unfingerprinted)."""
    if getattr(pack, "fingerprint", None) is not None:
        from repro.core.integrity import fingerprint_pack
        pack.fingerprint = fingerprint_pack(pack)
