"""Calibration: per-row-group scales for the packed value planes.

The unit of calibration is a *row group* — ``group_rows`` consecutive
packed rows, aligned to the ELL row tile so one kernel block covers whole
groups and its scales load once per grid step (the TPU analogue of the
paper's per-bank fixed-point format registers).  All cells of a group —
across every column chunk and every ELL slot — share one symmetric scale:

    q = clip(round(v / scale), -qmax, qmax),     v_hat = q * scale

* ``absmax``: scale = max|v| / qmax — lossless range, LSB-bounded error
  (|v_hat - v| <= scale / 2 for every cell);
* ``percentile``: scale = P-th percentile of |v| over the group's *valid*
  cells / qmax — clips outliers for a smaller step on the bulk (pad slots
  are excluded so the ELL stalls cannot drag the percentile down).

int4 groups whose relative reconstruction error exceeds ``err_bound`` are
re-calibrated at int8 (the per-group fallback rule, DESIGN.md section 9):
narrow values win bytes only where they do not cost accuracy.
"""
from __future__ import annotations

import dataclasses
import math
import warnings

import numpy as np

__all__ = ["QMAX", "QuantSpec", "default_spec", "group_scales",
           "quantize_codes", "group_rel_error"]

QMAX = {8: 127, 4: 7}

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """How to quantize one value plane.

    ``group_rows`` is the requested scale-group height; the effective
    height is ``gcd(group_rows, n_packed_rows)`` so groups always tile the
    plane exactly (packs keep rows a multiple of the row tile, so the
    default 128 degrades only on narrow test packs).  ``err_bound`` is the
    per-group relative L2 reconstruction bound that triggers the int4 ->
    int8 fallback; int8 mode never falls back.
    """

    bits: int = 8                 # 8 | 4 (4 = nibble-packed, int8 fallback)
    group_rows: int = 128         # aligned to the ELL row tile
    calib: str = "absmax"         # absmax | percentile
    percentile: float = 99.9
    err_bound: float = 0.12       # int4 -> int8 fallback threshold

    def __post_init__(self):
        if self.bits not in QMAX:
            raise ValueError(f"bits must be one of {sorted(QMAX)}, "
                             f"got {self.bits}")
        if self.calib not in ("absmax", "percentile"):
            raise ValueError(f"unknown calib {self.calib!r}")
        if self.group_rows <= 0:
            raise ValueError("group_rows must be positive")

    def effective_group(self, n_rows: int) -> int:
        return math.gcd(self.group_rows, n_rows) or 1


def default_spec(mode: str) -> QuantSpec:
    """The serving presets: ``"int8"`` (absmax — LSB-exact range) and
    ``"int4"`` (99.9th-percentile clip: on magnitude-pruned planes the
    surviving values are the top-|v| tail, where a light clip roughly
    halves the int4 step and keeps groups under the fallback bound)."""
    if mode == "int8":
        return QuantSpec(bits=8)
    if mode == "int4":
        return QuantSpec(bits=4, calib="percentile", percentile=99.9)
    raise ValueError(f"unknown quant mode {mode!r} (int8 | int4)")


def _group_view(plane: np.ndarray, group: int) -> np.ndarray:
    """(..., R, K, Lc) -> (..., G, group * K * Lc): one row per scale group."""
    *lead, r, k, lc = plane.shape
    return plane.reshape(*lead, r // group, group * k * lc)


def group_scales(values: np.ndarray, valid: np.ndarray, spec: QuantSpec,
                 bits: int | None = None) -> np.ndarray:
    """Per-group scales for a (..., R, K, Lc) plane -> (..., G) float32.

    All-zero (or all-pad) groups get scale 1.0 so dequantization is always
    a plain multiply with no zero-guard on the hot path.
    """
    bits = spec.bits if bits is None else bits
    qmax = QMAX[bits]
    group = spec.effective_group(values.shape[-3])
    av = np.abs(_group_view(values, group)).astype(np.float64)
    if spec.calib == "absmax":
        amax = av.max(axis=-1)
    else:
        masked = np.where(_group_view(valid, group), av, np.nan)
        with np.errstate(all="ignore"), warnings.catch_warnings():
            # all-pad groups are legal: they resolve to scale 1.0 below
            warnings.simplefilter("ignore", RuntimeWarning)
            amax = np.nanpercentile(masked, spec.percentile, axis=-1)
        amax = np.where(np.isfinite(amax), amax, 0.0)
        # never clip below the group's own resolution floor
        amax = np.maximum(amax, av.max(axis=-1) / (2.0 * qmax))
    scales = amax / qmax
    return np.where(scales > 0, scales, 1.0).astype(np.float32)


def quantize_codes(values: np.ndarray, scales: np.ndarray, bits: int,
                   group: int) -> np.ndarray:
    """Symmetric round-to-nearest codes: (..., R, K, Lc) int8 in
    [-qmax, qmax] (int4 codes occupy the same int8 container; nibble
    packing is a storage transform — ``qpack.nibble_pack``)."""
    qmax = QMAX[bits]
    s = np.repeat(scales, group, axis=-1)[..., :, None, None]
    q = np.rint(values.astype(np.float64) / s)
    return np.clip(q, -qmax, qmax).astype(np.int8)


def group_rel_error(values: np.ndarray, deq: np.ndarray, valid: np.ndarray,
                    group: int) -> np.ndarray:
    """Per-group relative L2 reconstruction error over valid cells."""
    v = _group_view(np.where(valid, values, 0.0), group).astype(np.float64)
    e = _group_view(np.where(valid, deq - values, 0.0), group)
    return (np.linalg.norm(e, axis=-1)
            / (np.linalg.norm(v, axis=-1) + _EPS))
