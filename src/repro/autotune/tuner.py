"""Candidate ranking + measured search for SDDS kernel schedules.

The pipeline (DESIGN.md §15): enumerate the legal schedule space for the
pack's shape (``core.sdds.enumerate_schedules``), deduplicate candidates
that lower identically for the chosen impl, rank all of them with the
cost model below, benchmark only the ``max_candidates`` cheapest with
``telemetry.profile.time_launch`` on the real uploaded planes, and keep
the measured winner.

Cost model — three transparent terms, no fitted constants:

* **traffic**: bytes the launch actually moves — value plane (narrowed by
  the quant mode), index plane, one x slab per chunk, the accumulator —
  inflated by the candidate's chunk pad fraction (pad slots move bytes
  and multiply zeros);
* **launch count**: the 3-D grid size (row tiles x chunks x l-blocks),
  charged a fixed per-step overhead equivalent (``LAUNCH_COST_BYTES``) —
  the per-token launch overhead PR 3 measured is linear in grid steps;
* **VMEM pressure**: candidates whose per-step working set (value+index
  blocks, the x slab, the accumulator) exceeds ``VMEM_BUDGET_BYTES`` are
  charged quadratically — they thrash the very residency bound
  ``chunk_cols`` exists to enforce.

``search_stats`` counts candidate benchmarks performed; the warm-cache
contract (second ``pack_to_device`` of an identical pack performs ZERO
candidate benchmarks) is asserted against it in tests and ci.sh.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.autotune.cache import PlanCache, pack_cache_key
from repro.core.sdds import (DEFAULT_SCHEDULE, KernelSchedule,
                             enumerate_schedules)
from repro.core.sparse_format import ELLPack, chunk_pack
from repro.telemetry.profile import time_launch

__all__ = ["TunedPlan", "autotune_pack", "schedule_cost", "search_stats",
           "reset_search_stats", "LAUNCH_COST_BYTES", "VMEM_BUDGET_BYTES"]

LAUNCH_COST_BYTES = 4096          # fixed per-grid-step overhead equivalent
VMEM_BUDGET_BYTES = 8 << 20       # per-step working-set budget

search_stats = {"searches": 0, "benchmarks": 0, "hits": 0, "misses": 0}


def reset_search_stats() -> None:
    for k in search_stats:
        search_stats[k] = 0


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    """The autotuner's verdict for one (pack, launch context).

    ``source`` records how the plan was obtained — ``"search"`` (measured
    now), ``"cache"`` (fingerprint-keyed hit, zero benchmarks) or
    ``"default"`` (tuning skipped / nothing legal beyond the default) —
    and rides into ``Provenance.schedule`` so bench rows distinguish
    tuned runs.
    """

    schedule: KernelSchedule
    source: str                    # "search" | "cache" | "default"
    key: str
    best_us: float | None = None
    candidates: int = 0            # benchmarks performed for this plan

    def to_provenance(self) -> dict:
        return {
            "source": self.source,
            "tuned": self.source != "default",
            "cache_key": self.key,
            "chunk_cols": self.schedule.chunk_cols,
            "block_r": self.schedule.block_r,
            "block_l": self.schedule.block_l,
            "gather": self.schedule.gather,
            "best_us": self.best_us,
            "candidates": self.candidates,
        }


def _value_bytes(quant) -> float:
    bits = getattr(quant, "bits", None)
    if bits is None and isinstance(quant, str):
        bits = {"int8": 8, "int4": 4}.get(quant)
    return {8: 1.0, 4: 0.5}.get(bits, 4.0)


def schedule_cost(s: KernelSchedule, *, r_pad: int, n_chunks: int,
                  chunk_width: int, b: int, quant=None,
                  pad_frac: float = 0.0) -> float:
    """Rank-only cost in byte equivalents (lower is better)."""
    eff_br = math.gcd(r_pad, s.block_r)
    eff_bl = min(s.block_l, max(8, chunk_width))
    lc_pad = -(-chunk_width // eff_bl) * eff_bl
    grid = (r_pad // eff_br) * n_chunks * (lc_pad // eff_bl)
    vb = _value_bytes(quant)
    cells = r_pad * n_chunks * lc_pad
    traffic = (cells * (vb + 4.0)                 # value + index planes
               + n_chunks * s.chunk_cols * b * 4.0  # one x slab per chunk
               + r_pad * b * 4.0)                 # accumulator
    traffic *= 1.0 + pad_frac
    vmem = (eff_br * eff_bl * (vb + 4.0)
            + s.chunk_cols * b * 4.0 + eff_br * b * 4.0)
    over = max(0.0, vmem / VMEM_BUDGET_BYTES - 1.0)
    return traffic + LAUNCH_COST_BYTES * grid + traffic * over * over


def _quant_name(quant) -> str | None:
    if quant is None:
        return None
    if isinstance(quant, str):
        return quant
    return {8: "int8", 4: "int4"}.get(getattr(quant, "bits", None))


def _chunked_for(pack, cc: int, chunk_cache: dict):
    if cc not in chunk_cache:
        chunk_cache[cc] = (chunk_pack(pack, cc)
                           if isinstance(pack, ELLPack) else pack)
    return chunk_cache[cc]


def _launch_fn(cp, x, s: KernelSchedule, impl: str, quant):
    """The benchmarked closure: the SAME ops-layer call the serving path
    makes, with the candidate schedule applied."""
    from repro.kernels import ops
    cols = jnp.asarray(cp.cols, jnp.int32)
    if quant is None:
        vals = jnp.asarray(cp.values)

        def fn():
            return ops.espim_spmv_batched(
                vals, cols, x, chunk_cols=cp.chunk_cols, impl=impl,
                schedule=s)
    else:
        from repro.quant import QuantSpec, default_spec, quantize_pack
        spec = quant if isinstance(quant, QuantSpec) else default_spec(quant)
        plane = cp.qplane
        if plane is None or plane.spec != spec:
            plane = quantize_pack(cp, spec)
        codes = jnp.asarray(plane.device_codes())
        scales = jnp.asarray(plane.scales)
        group_rows = plane.group_rows

        def fn():
            return ops.espim_spmv_batched_quant(
                codes, cols, scales, x, chunk_cols=cp.chunk_cols,
                group_rows=group_rows, impl=impl, schedule=s)
    return fn


def autotune_pack(pack, *, b: int = 8, quant=None, impl: str | None = None,
                  cache: PlanCache | None = None,
                  max_candidates: int = 3, iters: int = 3,
                  warmup: int = 1) -> TunedPlan:
    """Pick a kernel schedule for ``pack`` under the given launch context.

    ``pack`` is a plain ``ELLPack`` (full search: the chunk pass is part
    of the schedule) or an ``ELLChunkedPack`` (``chunk_cols`` pinned by
    the artifact; block/gather knobs only).  ``cache`` short-circuits the
    whole search on a fingerprint hit.  ``max_candidates`` bounds how many
    cost-ranked candidates are actually benchmarked (the ci.sh smoke runs
    with 2).
    """
    from repro.kernels import ops
    impl = ops._resolve(impl)
    backend = jax.default_backend()
    qname = _quant_name(quant)
    key = pack_cache_key(pack, b=b, quant=qname, impl=impl, backend=backend)

    if cache is not None:
        entry = cache.get(key)
        if entry is not None:
            search_stats["hits"] += 1
            return TunedPlan(schedule=KernelSchedule(**entry["schedule"]),
                             source="cache", key=key,
                             best_us=entry.get("best_us"),
                             candidates=0)
        search_stats["misses"] += 1

    search_stats["searches"] += 1
    r_pad = pack.r_pad
    n_cols = pack.n_cols
    if isinstance(pack, ELLPack):
        cands = enumerate_schedules(r_pad=r_pad, n_cols=n_cols, quant=qname)
    else:
        cands = [dataclasses.replace(s, chunk_cols=pack.chunk_cols)
                 for s in enumerate_schedules(
                     r_pad=r_pad, n_cols=n_cols, quant=qname,
                     chunk_cols_options=(pack.chunk_cols,))
                 if s.chunk_cols == pack.chunk_cols]
    seen: set = set()
    deduped = []
    for s in cands:
        ek = s.effective_key(impl)
        if ek not in seen:
            seen.add(ek)
            deduped.append(s)
    if not deduped:
        return TunedPlan(schedule=DEFAULT_SCHEDULE, source="default",
                         key=key)

    chunk_cache: dict = {}
    ranked = []
    for s in deduped:
        cp = _chunked_for(pack, s.chunk_cols, chunk_cache)
        ranked.append((schedule_cost(
            s, r_pad=r_pad, n_chunks=cp.n_chunks,
            chunk_width=cp.chunk_width, b=b, quant=quant,
            pad_frac=cp.plan.chunk_pad_frac), s))
    ranked.sort(key=lambda t: t[0])
    top = [s for _, s in ranked[:max(1, max_candidates)]]

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n_cols, b)), jnp.float32)
    best = None
    for s in top:
        cp = _chunked_for(pack, s.chunk_cols, chunk_cache)
        fn = _launch_fn(cp, x, s, impl, quant)
        t = time_launch(fn, iters=iters, warmup=warmup,
                        label=f"autotune.{s.chunk_cols}.{s.block_r}."
                              f"{s.block_l}.{s.gather}")
        search_stats["benchmarks"] += 1
        if best is None or t.best_us < best[0]:
            best = (t.best_us, s)

    plan = TunedPlan(schedule=best[1], source="search", key=key,
                     best_us=best[0], candidates=len(top))
    if cache is not None:
        cache.put(key, {"schedule": dataclasses.asdict(plan.schedule),
                        "best_us": plan.best_us,
                        "candidates": plan.candidates,
                        "created_by": "search"})
    return plan
