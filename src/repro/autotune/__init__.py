"""Per-shape SDDS schedule autotuning (DESIGN.md §15).

ESPIM's bet is that the sparsity is static and known offline, so every
scheduling decision can be made before inference.  The TPU adaptation left
four kernel-schedule knobs as hand-picked constants (chunk width, row/width
block sizes, gather formulation); SparseP shows the partitioning choice
dominates PIM SpMV performance across shapes and sparsities.  This package
closes that gap:

* ``core.sdds.enumerate_schedules`` is the candidate space, filtered by
  the kernels' own legality constraints;
* a transparent cost model (VMEM footprint, pad fraction, launch count)
  ranks the candidates;
* the top-k are benchmarked for real with ``telemetry.profile.time_launch``
  on the actual uploaded planes;
* the winner persists in a JSON plan cache keyed by the pack's
  plan-independent integrity fingerprint (``core.integrity``) plus the
  launch context (batch, quant mode, impl, backend) — retune happens the
  moment the pack bytes change, and a warm cache makes
  ``ops.pack_to_device`` skip the search entirely (asserted via
  ``search_stats`` in the tests and the ci.sh smoke).
"""
from repro.autotune.cache import (PlanCache, default_cache, pack_cache_key,
                                  reset_default_cache)
from repro.autotune.tuner import (TunedPlan, autotune_pack, reset_search_stats,
                                  schedule_cost, search_stats)

__all__ = [
    "PlanCache",
    "default_cache",
    "reset_default_cache",
    "pack_cache_key",
    "TunedPlan",
    "autotune_pack",
    "schedule_cost",
    "search_stats",
    "reset_search_stats",
]
