"""Fingerprint-keyed plan cache for tuned SDDS kernel schedules.

The cache key must be *plan-independent*: the bound pack digest of a
chunked pack covers its ChunkPlan (schedule<->pack binding, the integrity
contract), so a plan chosen by the autotuner would change the digest it is
keyed under.  The key therefore derives from content that does not move
when the schedule does:

* a plain ``ELLPack`` (the offline artifact *before* the SDDS chunk pass)
  is plan-free by construction — its bound digest covers the value/index
  planes, perm and geometry only, so the same weight content maps to the
  same key no matter which chunk width the tuner later picks;
* an already-chunked pack keys off its per-plane digests + meta minus the
  plan entry; its ``chunk_cols`` is fixed by the artifact, so the search
  is restricted to the block/gather knobs (documented in DESIGN.md §15).

The launch context (batch width, quant mode, impl, backend) is folded into
the key too — a plan tuned for int4 decode at B=8 says nothing about fp
prefill at B=256.

Entries are ``{"schedule": {...}, "best_us": float|None, "candidates":
int, "created_by": "search"}``; ``PlanCache(path=...)`` persists the table
as JSON (atomic tmp+rename on every put) so a second process starts warm.
``ESPIM_PLAN_CACHE`` names the default on-disk location; unset, the
default cache is in-memory only.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile

from repro.core.integrity import bind_fingerprint, fingerprint_pack

__all__ = ["PlanCache", "pack_cache_key", "default_cache",
           "reset_default_cache", "ENV_PLAN_CACHE"]

ENV_PLAN_CACHE = "ESPIM_PLAN_CACHE"

CACHE_SCHEMA = "espim-plan-cache/v1"


def _plan_free_digest(pack) -> str:
    """A digest of the pack that is invariant to the SDDS chunk plan."""
    fp = getattr(pack, "fingerprint", None)
    if fp is None:
        fp = fingerprint_pack(pack)
    meta = {k: v for k, v in fp["meta"].items()
            if k not in ("plan", "chunk_cols")}
    if fp["meta"].get("kind") == "ell":
        # the un-chunked artifact: planes are chunk-invariant already
        return bind_fingerprint(fp["planes"], meta)
    # chunked artifact: planes moved with the chunk pass; the key pins the
    # exact planes (so re-chunking retunes) but drops the plan digest so
    # block/gather retuning of the same layout stays one entry
    return bind_fingerprint(fp["planes"], meta)


def pack_cache_key(pack, *, b: int, quant: str | None, impl: str,
                   backend: str) -> str:
    """sha256 cache key: plan-free pack digest + launch context."""
    doc = {
        "pack": _plan_free_digest(pack),
        "b": int(b),
        "quant": quant or "none",
        "impl": impl,
        "backend": backend,
    }
    return hashlib.sha256(
        json.dumps(doc, sort_keys=True).encode()).hexdigest()[:32]


class PlanCache:
    """JSON-backed table of tuned plans: key -> plan record.

    ``path=None`` keeps the table in memory; with a path, the table loads
    lazily on first access and every ``put`` rewrites the file atomically.
    ``hits``/``misses`` count lookups for the warm-cache assertions.
    """

    def __init__(self, path: str | None = None):
        self.path = path
        self.hits = 0
        self.misses = 0
        self._table: dict | None = None

    def _load(self) -> dict:
        if self._table is None:
            self._table = {}
            if self.path and os.path.exists(self.path):
                try:
                    doc = json.load(open(self.path))
                    if doc.get("schema") == CACHE_SCHEMA:
                        self._table = dict(doc.get("plans", {}))
                except (OSError, ValueError):
                    pass        # corrupt/foreign file: start empty
        return self._table

    def __len__(self) -> int:
        return len(self._load())

    def get(self, key: str) -> dict | None:
        entry = self._load().get(key)
        if entry is None:
            self.misses += 1
        else:
            self.hits += 1
        return entry

    def put(self, key: str, entry: dict) -> None:
        table = self._load()
        table[key] = dict(entry)
        if self.path:
            self._save(table)

    def _save(self, table: dict) -> None:
        doc = {"schema": CACHE_SCHEMA, "plans": table}
        d = os.path.dirname(os.path.abspath(self.path)) or "."
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def clear(self) -> None:
        self._table = {}
        if self.path and os.path.exists(self.path):
            os.unlink(self.path)


_DEFAULT: PlanCache | None = None


def default_cache() -> PlanCache:
    """The process-wide cache ``pack_to_device(autotune=True)`` uses —
    on-disk when ``ESPIM_PLAN_CACHE`` names a path, else in-memory."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = PlanCache(os.environ.get(ENV_PLAN_CACHE) or None)
    return _DEFAULT


def reset_default_cache() -> None:
    """Drop the process-wide cache (tests; env re-reads on next use)."""
    global _DEFAULT
    _DEFAULT = None
