"""Public jit'd wrappers for the kernels package.

Dispatch policy: Pallas kernels run natively on TPU and in ``interpret=True``
mode elsewhere (this container is CPU-only; interpret mode executes the
kernel body in Python for correctness validation).  ``impl="ref"`` forces
the pure-jnp oracle — used by the tests and as the lowering path inside
large jitted graphs where a Python-interpreted kernel would be wasteful.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_format import ELLPack
from repro.kernels import ref as _ref
from repro.kernels.dense_mv import dense_mv_pallas
from repro.kernels.espim_spmv import espim_spmv_batched_pallas, espim_spmv_pallas

__all__ = [
    "on_tpu",
    "espim_spmv",
    "espim_spmv_batched",
    "dense_mv",
    "espim_matvec",
    "EspimWeights",
    "pack_to_device",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str | None) -> str:
    if impl is None:
        return "pallas"
    if impl not in ("pallas", "ref"):
        raise ValueError(f"unknown impl {impl!r}")
    return impl


def espim_spmv(values, cols, x, *, impl: str | None = None) -> jnp.ndarray:
    """ELL sparse MV: (R_pad, L) x (M,) -> (R_pad,) f32."""
    if _resolve(impl) == "ref":
        return _ref.espim_spmv_ref(values, cols, x)
    return espim_spmv_pallas(values, cols, x, interpret=not on_tpu())


def espim_spmv_batched(values, cols, x, *, impl: str | None = None) -> jnp.ndarray:
    """Batched ELL sparse MV: (R_pad, L) x (M, B) -> (R_pad, B) f32."""
    if _resolve(impl) == "ref":
        return _ref.espim_spmv_batched_ref(values, cols, x)
    return espim_spmv_batched_pallas(values, cols, x, interpret=not on_tpu())


def dense_mv(w, x, *, impl: str | None = None) -> jnp.ndarray:
    """Dense MV (Newton-analogue path)."""
    if _resolve(impl) == "ref":
        return _ref.dense_mv_ref(w, x)
    return dense_mv_pallas(w, x, interpret=not on_tpu())


# --------------------------------------------------------------------------
# High-level packed-weights API
# --------------------------------------------------------------------------
class EspimWeights:
    """Device-resident ESPIM pack of one weight matrix (W @ x semantics,
    W of shape (n_out, n_in))."""

    def __init__(self, values, cols, perm, n_rows: int, n_cols: int):
        self.values = values          # (R_pad, L)
        self.cols = cols              # (R_pad, L) int32
        self.perm = perm              # (R_pad,) int32, -1 = pad row
        self.n_rows = n_rows
        self.n_cols = n_cols

    def tree_flatten(self):
        return (self.values, self.cols, self.perm), (self.n_rows, self.n_cols)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


jax.tree_util.register_pytree_node(
    EspimWeights,
    lambda w: w.tree_flatten(),
    lambda aux, ch: EspimWeights.tree_unflatten(aux, ch),
)


def pack_to_device(pack: ELLPack, dtype=jnp.float32) -> EspimWeights:
    """Move an offline ELLPack onto the device arrays the kernels consume."""
    return EspimWeights(
        values=jnp.asarray(pack.values, dtype=dtype),
        cols=jnp.asarray(pack.cols, dtype=jnp.int32),
        perm=jnp.asarray(np.asarray(pack.perm), dtype=jnp.int32),
        n_rows=pack.n_rows,
        n_cols=pack.n_cols,
    )


def espim_matvec(w: EspimWeights, x: jnp.ndarray, *, impl: str | None = None
                 ) -> jnp.ndarray:
    """y (n_rows,) or (n_rows, B) = W @ x with packed-row unscatter."""
    if x.ndim == 1:
        yp = espim_spmv(w.values, w.cols, x, impl=impl)
    elif x.ndim == 2:
        yp = espim_spmv_batched(w.values, w.cols, x, impl=impl)
    else:
        raise ValueError(f"x must be 1-D or 2-D, got {x.shape}")
    return _ref.scatter_rows_ref(yp, w.perm, w.n_rows)
