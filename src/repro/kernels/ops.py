"""Public jit'd wrappers for the kernels package.

Dispatch policy: Pallas kernels run natively on TPU and in ``interpret=True``
mode elsewhere (this container is CPU-only; interpret mode executes the
kernel body in Python for correctness validation).  ``impl="ref"`` forces
the pure-jnp lowering — used by the tests and as the path inside large
jitted graphs where a Python-interpreted kernel would be wasteful.  For the
chunked layout the "ref" lowering of the batched op is itself the fused
per-chunk gather-accumulate (same schedule as the kernel, no
(R_pad, L, B) materialization).

Both the seed (R_pad, L) ELL layout and the column-chunked (R_pad, K, Lc)
layout are accepted; the array rank selects the family.  Only the chunked
family has Pallas kernels — the plain layout survives for the sharded
matvec path and lowers through the einsum reference.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sdds import KernelSchedule
from repro.core.sparse_format import ELLChunkedPack, ELLPack, chunk_pack
from repro.kernels import ref as _ref
from repro.kernels.dense_mv import dense_mv_pallas
from repro.kernels.espim_spmv import (espim_spmv_batched_glu_pallas,
                                      espim_spmv_batched_pallas,
                                      espim_spmv_batched_quant_glu_pallas,
                                      espim_spmv_batched_quant_pallas,
                                      espim_spmv_batched_res_pallas,
                                      espim_spmv_pallas)
from repro.telemetry.trace import get_tracer

__all__ = [
    "on_tpu",
    "espim_spmv",
    "espim_spmv_batched",
    "espim_spmv_batched_quant",
    "dense_mv",
    "espim_matvec",
    "EspimWeights",
    "QuantEspimWeights",
    "pack_to_device",
    "Provenance",
    "provenance",
    "DEFAULT_CHUNK_COLS",
    "ENV_IMPL",
    "ENV_INTERPRET",
]

DEFAULT_CHUNK_COLS = 512

# Environment overrides for the dispatch policy, so CI and benches can pin
# the implementation explicitly instead of inferring it from the backend:
#   ESPIM_IMPL=ref|pallas        force the lowering everywhere (wins over
#                                per-call ``impl=`` arguments — that is the
#                                point: pin the whole process)
#   ESPIM_FORCE_INTERPRET=1|0    force Pallas interpret mode on (1) or off
#                                (0) regardless of the detected backend
ENV_IMPL = "ESPIM_IMPL"
ENV_INTERPRET = "ESPIM_FORCE_INTERPRET"


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str | None) -> str:
    env = os.environ.get(ENV_IMPL, "").strip()
    if env:
        impl = env
    if impl is None:
        impl = "pallas"
    if impl not in ("pallas", "ref"):
        raise ValueError(f"unknown impl {impl!r}")
    return impl


def _interpret() -> bool:
    env = os.environ.get(ENV_INTERPRET, "").strip()
    if env:
        return env not in ("0", "false", "False")
    return not on_tpu()


@dataclasses.dataclass(frozen=True)
class Provenance:
    """Where a kernel call would run right now — recorded by the benches
    and trace headers so every result carries its backend/impl context.

    Before PR 7 this was a kwarg-sprawl dict rebuilt ad-hoc at each call
    site; now one frozen dataclass with a stable ``to_dict()`` (the dict
    shape the BENCH_*.json provenance blocks have carried since PR 2).

    ``quant`` names the value-plane encoding the caller is timing
    (none/int8/int4); ``attn`` names the attention projection datapath
    (dense = MLP-only packs, sparse = whole-layer fused QKV + O packs,
    sweep = both); ``packs`` maps a label to the bound pack fingerprint
    the run served (``core.integrity``), so a result is tied to the
    exact plane bytes.
    """
    backend: str
    impl: str
    quant: str
    attn: str
    pallas_interpret: bool
    packs: dict | None
    env: dict
    # the chosen kernel schedule (PR 10): ``None`` = pre-autotune caller;
    # else {"source": "default"|"search"|"cache", "tuned": bool,
    # "chunk_cols"/"block_r"/"block_l"/"gather", "epilogue": ...} — bench
    # rows and trace headers carry it so history windows can distinguish
    # tuned/fused runs from default-schedule ones
    schedule: dict | None = None

    @classmethod
    def collect(cls, impl: str | None = None, quant: str | None = None,
                attn: str | None = None, packs: dict | None = None,
                schedule: dict | None = None) -> "Provenance":
        return cls(
            backend=jax.default_backend(),
            impl=_resolve(impl),
            quant=quant or "none",
            attn=attn or "dense",
            pallas_interpret=_interpret(),
            packs=dict(packs) if packs else None,
            env={ENV_IMPL: os.environ.get(ENV_IMPL) or None,
                 ENV_INTERPRET: os.environ.get(ENV_INTERPRET) or None},
            schedule=dict(schedule) if schedule else None,
        )

    def to_dict(self) -> dict:
        """Stable key order, JSON-ready — byte-compatible with the dict
        ``provenance()`` has always returned."""
        return {
            "backend": self.backend,
            "impl": self.impl,
            "quant": self.quant,
            "attn": self.attn,
            "pallas_interpret": self.pallas_interpret,
            "packs": dict(self.packs) if self.packs else None,
            "schedule": dict(self.schedule) if self.schedule else None,
            "env": dict(self.env),
        }


def provenance(impl: str | None = None, quant: str | None = None,
               attn: str | None = None, packs: dict | None = None,
               schedule: dict | None = None) -> dict:
    """Backward-compatible functional form: ``Provenance.collect(...)
    .to_dict()`` (see the dataclass for field semantics)."""
    return Provenance.collect(impl=impl, quant=quant, attn=attn,
                              packs=packs, schedule=schedule).to_dict()


def _block_kw(schedule: KernelSchedule | None, gather: bool = False) -> dict:
    """Pallas block/gather kwargs from a tuned schedule (``None`` keeps
    the kernel defaults — the pre-autotune behaviour)."""
    if schedule is None:
        return {}
    kw = {"block_r": schedule.block_r, "block_l": schedule.block_l}
    if gather:
        kw["gather"] = schedule.gather
    return kw


def _check_chunk_cols(cols, x, chunk_cols) -> int:
    if chunk_cols is None:
        raise ValueError(
            "chunk_cols is required for the chunked (R_pad, K, Lc) layout; "
            f"got cols of shape {cols.shape}")
    cc = int(chunk_cols)
    n_chunks = cols.shape[1]
    if n_chunks > 1 and n_chunks * cc - x.shape[0] >= cc:
        # the last chunk would sit entirely past x: chunk_cols cannot be
        # the width this pack was built with (silent-corruption guard)
        raise ValueError(
            f"chunk_cols={cc} inconsistent with pack: {n_chunks} chunks x "
            f"{cc} cols span past x of length {x.shape[0]}")
    return cc


def _dispatch_spmv(values, cols, x, chunk_cols, impl,
                   plain_ref, chunked_ref, pallas_kernel,
                   pallas_kw: dict | None = None) -> jnp.ndarray:
    """Layout/impl dispatch shared by the (un)batched ops: plain
    (R_pad, L) packs lower through the reference only; chunked
    (R_pad, K, Lc) packs pick the Pallas kernel or the chunked ref."""
    impl = _resolve(impl)
    if values.ndim == 2:
        if impl == "pallas":
            raise ValueError(
                "the Pallas kernels consume the column-chunked layout; "
                "re-pack with pack_ell_chunked (plain ELL is ref-only)")
        return plain_ref(values, cols, x)
    cc = _check_chunk_cols(cols, x, chunk_cols)
    if impl == "ref":
        return chunked_ref(values, cols, x, cc)
    return pallas_kernel(values, cols, x, chunk_cols=cc,
                         interpret=_interpret(), **(pallas_kw or {}))


def espim_spmv(values, cols, x, *, chunk_cols: int | None = None,
               impl: str | None = None,
               schedule: KernelSchedule | None = None) -> jnp.ndarray:
    """ELL sparse MV -> (R_pad,) f32.

    Chunked layout: values/cols (R_pad, K, Lc) + ``chunk_cols``.
    Plain layout: values/cols (R_pad, L), reference lowering only.
    """
    return _dispatch_spmv(values, cols, x, chunk_cols, impl,
                          _ref.espim_spmv_ref, _ref.espim_spmv_chunked_ref,
                          espim_spmv_pallas, _block_kw(schedule))


def espim_spmv_batched(values, cols, x, *, chunk_cols: int | None = None,
                       impl: str | None = None,
                       schedule: KernelSchedule | None = None,
                       epilogue: str | None = None, act: str = "silu",
                       residual=None) -> jnp.ndarray:
    """Batched ELL sparse MV: x (M, B) -> (R_pad, B) f32 (see espim_spmv).

    ``schedule`` applies a tuned ``core.sdds.KernelSchedule``'s block and
    gather choices to the Pallas lowering (``chunk_cols`` stays the
    pack's — re-chunking is an offline transform, not a launch knob).

    ``epilogue`` fuses a decode epilogue into the launch (DESIGN.md §15):

    * ``"glu"`` — values/cols hold a half-major (2*Rg, K, Lc) gate+up
      group sharing one balance perm; returns act(gate) * up (Rg, B) in
      packed order (legal under the ``fuse="halves"`` contract).
    * ``"residual"`` — adds ``residual`` (R_pad, B), ALREADY in packed row
      order, at the kernel's last accumulate step (legal for
      ``output="take"`` groups: the add commutes with the static take
      when the caller permutes the residual once, offline).
    """
    if epilogue is None:
        return _dispatch_spmv(values, cols, x, chunk_cols, impl,
                              _ref.espim_spmv_batched_ref,
                              _ref.espim_spmv_batched_chunked_ref,
                              espim_spmv_batched_pallas,
                              _block_kw(schedule, gather=True))
    impl = _resolve(impl)
    if values.ndim != 3:
        raise ValueError(
            f"epilogue={epilogue!r} needs the column-chunked layout; got "
            f"values of shape {values.shape}")
    cc = _check_chunk_cols(cols, x, chunk_cols)
    if epilogue == "glu":
        if impl == "ref":
            return _ref.espim_spmv_batched_chunked_glu_ref(
                values, cols, x, cc, act)
        return espim_spmv_batched_glu_pallas(
            values, cols, x, chunk_cols=cc, act=act,
            interpret=_interpret(), **_block_kw(schedule))
    if epilogue == "residual":
        if residual is None:
            raise ValueError("epilogue='residual' needs the residual "
                             "operand (packed row order)")
        if impl == "ref":
            return _ref.espim_spmv_batched_chunked_ref(
                values, cols, x, cc) + residual
        return espim_spmv_batched_res_pallas(
            values, cols, x, residual, chunk_cols=cc,
            interpret=_interpret(), **_block_kw(schedule))
    raise ValueError(f"unknown epilogue {epilogue!r}")


def espim_spmv_batched_quant(values, cols, scales, x, *,
                             chunk_cols: int | None = None,
                             group_rows: int = 1,
                             impl: str | None = None,
                             schedule: KernelSchedule | None = None,
                             epilogue: str | None = None, act: str = "silu",
                             srow=None, residual=None) -> jnp.ndarray:
    """Quantized batched ELL sparse MV: int8 codes (or nibble-packed uint8
    — inferred from the width mismatch vs ``cols``) + one f32 scale per
    ``group_rows`` packed rows; x (M, B) -> (R_pad, B) f32.

    ``scales=None`` returns the UNSCALED code-domain accumulator — the
    fused serving path folds its per-row scales into one precomputed
    multiply per bucket instead of one repeat+multiply per launch.

    ``schedule`` applies a tuned schedule's block sizes to the Pallas
    lowering.  ``epilogue="glu"`` fuses dequant + act(gate)·up: the
    half-major (2*Rg, K, Lc) code plane accumulates in the code domain,
    the pre-expanded per-row scales ``srow`` (2*Rg,) dequantize both
    halves ONCE after the reduce, then the gated product — the exact op
    order of the unfused path, one launch.  ``epilogue="residual"`` adds
    the packed-order residual to the scaled output (op-level for the
    quant family — the scale multiply dominates the epilogue).

    Same dispatch policy as the fp ops (``ESPIM_IMPL`` pin wins); the
    plain (R_pad, L) layout lowers through the reference as a one-chunk
    plane.
    """
    impl = _resolve(impl)
    if epilogue == "glu":
        if srow is None:
            raise ValueError("epilogue='glu' needs srow (pre-expanded "
                             "per-row scales, half-major)")
        if cols.ndim != 3:
            raise ValueError(
                "epilogue='glu' needs the column-chunked layout; got "
                f"cols of shape {cols.shape}")
        cc = _check_chunk_cols(cols, x, chunk_cols)
        if impl == "ref":
            return _ref.espim_spmv_batched_chunked_quant_glu_ref(
                values, cols, srow, x, cc, act)
        return espim_spmv_batched_quant_glu_pallas(
            values, cols, srow, x, chunk_cols=cc, act=act,
            interpret=_interpret(), **_block_kw(schedule))
    if epilogue == "residual":
        if residual is None:
            raise ValueError("epilogue='residual' needs the residual "
                             "operand (packed row order)")
        y = espim_spmv_batched_quant(
            values, cols, scales, x, chunk_cols=chunk_cols,
            group_rows=group_rows, impl=impl, schedule=schedule)
        if scales is None and srow is not None:
            y = y * srow[:, None]
        return y + residual
    if epilogue is not None:
        raise ValueError(f"unknown epilogue {epilogue!r}")
    if scales is None and impl != "ref":
        # unit scales through the kernel's own scaling path (exact)
        scales = jnp.ones(1, jnp.float32)
        group_rows = cols.shape[0]
    if cols.ndim == 2:
        if impl == "pallas":
            raise ValueError(
                "the Pallas kernels consume the column-chunked layout; "
                "re-pack with pack_ell_chunked (plain ELL is ref-only)")
        return _ref.espim_spmv_batched_chunked_quant_ref(
            values[:, None, :], cols[:, None, :], scales, x,
            x.shape[0], group_rows)
    cc = _check_chunk_cols(cols, x, chunk_cols)
    if impl == "ref":
        return _ref.espim_spmv_batched_chunked_quant_ref(
            values, cols, scales, x, cc, group_rows)
    return espim_spmv_batched_quant_pallas(
        values, cols, scales, x, chunk_cols=cc, group_rows=group_rows,
        interpret=_interpret(), **_block_kw(schedule))


def dense_mv(w, x, *, impl: str | None = None) -> jnp.ndarray:
    """Dense MV (Newton-analogue path)."""
    if _resolve(impl) == "ref":
        return _ref.dense_mv_ref(w, x)
    return dense_mv_pallas(w, x, interpret=_interpret())


# --------------------------------------------------------------------------
# High-level packed-weights API
# --------------------------------------------------------------------------
class EspimWeights:
    """Device-resident column-chunked ESPIM pack of one weight matrix
    (W @ x semantics, W of shape (n_out, n_in))."""

    def __init__(self, values, cols, perm, n_rows: int, n_cols: int,
                 chunk_cols: int):
        self.values = values          # (R_pad, K, Lc)
        self.cols = cols              # (R_pad, K, Lc) int32, chunk-local
        self.perm = perm              # (R_pad,) int32, -1 = pad row
        self.n_rows = n_rows
        self.n_cols = n_cols
        self.chunk_cols = chunk_cols

    def tree_flatten(self):
        return ((self.values, self.cols, self.perm),
                (self.n_rows, self.n_cols, self.chunk_cols))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


jax.tree_util.register_pytree_node(
    EspimWeights,
    lambda w: w.tree_flatten(),
    lambda aux, ch: EspimWeights.tree_unflatten(aux, ch),
)


class QuantEspimWeights:
    """Device-resident column-chunked pack with a quantized value plane
    (repro.quant): int8 codes or nibble-packed uint8 + per-row-group
    scales; indices and perm identical to ``EspimWeights``."""

    def __init__(self, values, cols, perm, scales, n_rows: int, n_cols: int,
                 chunk_cols: int, group_rows: int, bits: int):
        self.values = values          # (R_pad, K, Lc) i8 | (R_pad, K, Lc/2) u8
        self.cols = cols              # (R_pad, K, Lc) int32, chunk-local
        self.perm = perm              # (R_pad,) int32, -1 = pad row
        self.scales = scales          # (R_pad // group_rows,) f32
        self.n_rows = n_rows
        self.n_cols = n_cols
        self.chunk_cols = chunk_cols
        self.group_rows = group_rows
        self.bits = bits

    def tree_flatten(self):
        return ((self.values, self.cols, self.perm, self.scales),
                (self.n_rows, self.n_cols, self.chunk_cols, self.group_rows,
                 self.bits))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


jax.tree_util.register_pytree_node(
    QuantEspimWeights,
    lambda w: w.tree_flatten(),
    lambda aux, ch: QuantEspimWeights.tree_unflatten(aux, ch),
)


def pack_to_device(pack: ELLPack | ELLChunkedPack, dtype=jnp.float32,
                   chunk_cols: int = DEFAULT_CHUNK_COLS,
                   quant=None, verify: bool = True,
                   autotune: bool = False, tune: dict | None = None
                   ) -> EspimWeights | QuantEspimWeights:
    """Move an offline pack onto the device arrays the kernels consume.

    A plain ELLPack is run through the SDDS chunk pass first (with
    ``chunk_cols``); an ELLChunkedPack is uploaded as-is.  ``quant``
    ("int8" | "int4" | a ``repro.quant.QuantSpec``) quantizes the value
    plane on the way up (or reuses an already-attached ``pack.qplane``)
    and returns ``QuantEspimWeights``.

    ``autotune=True`` asks ``repro.autotune`` for a schedule first: a
    plan-cache hit (keyed by the pack's plan-free fingerprint + launch
    context) skips the search entirely; a miss benchmarks the cost-ranked
    candidates and persists the winner.  The tuned ``chunk_cols`` replaces
    the argument for the chunk pass, and the ``TunedPlan`` rides on the
    returned weights as a non-pytree ``.schedule`` attribute so serving
    code and bench provenance can report it.  ``tune`` forwards extra
    ``autotune_pack`` kwargs (``b``, ``max_candidates``, ``iters``,
    ``cache``, ...).

    ``verify=True`` (default) runs ``core.integrity.verify_pack`` on the
    host pack before upload: bounds validation always, plus a fingerprint
    recompute when the builders recorded one — corruption between build
    and upload raises ``PackIntegrityError`` here instead of gathering
    garbage at decode.
    """
    tr = get_tracer()
    with tr.span("pack.to_device", cat="pack",
                 args={"quant": getattr(quant, "bits", quant) or "none",
                       "verify": verify, "autotune": autotune}):
        plan = None
        if autotune:
            from repro.autotune import autotune_pack, default_cache
            kw = dict(tune or {})
            kw.setdefault("cache", default_cache())
            with tr.span("pack.autotune", cat="pack"):
                plan = autotune_pack(pack, quant=quant, **kw)
            if isinstance(pack, ELLPack):
                chunk_cols = plan.schedule.chunk_cols
        w = _pack_to_device(pack, dtype, chunk_cols, quant, verify, tr)
        w.schedule = plan          # aux metadata, invisible to the pytree
        return w


def _pack_to_device(pack, dtype, chunk_cols, quant, verify, tr):
    if verify:
        from repro.core.integrity import verify_pack
        with tr.span("pack.verify", cat="pack"):
            verify_pack(pack)
    if isinstance(pack, ELLPack):
        pack = chunk_pack(pack, chunk_cols)
    if quant is None:
        return EspimWeights(
            values=jnp.asarray(pack.values, dtype=dtype),
            cols=jnp.asarray(pack.cols, dtype=jnp.int32),
            perm=jnp.asarray(np.asarray(pack.perm), dtype=jnp.int32),
            n_rows=pack.n_rows,
            n_cols=pack.n_cols,
            chunk_cols=pack.chunk_cols,
        )
    from repro.quant import QuantSpec, default_spec, quantize_pack
    spec = quant if isinstance(quant, QuantSpec) else default_spec(quant)
    plane = pack.qplane
    # reuse the attached plane only when it was produced by this exact
    # spec — a same-bits plane with different calib/group/err_bound would
    # silently serve the wrong encoding
    if plane is None or plane.spec != spec:
        plane = quantize_pack(pack, spec)
    return QuantEspimWeights(
        values=jnp.asarray(plane.device_codes()),
        cols=jnp.asarray(pack.cols, dtype=jnp.int32),
        perm=jnp.asarray(np.asarray(pack.perm), dtype=jnp.int32),
        scales=jnp.asarray(plane.scales),
        n_rows=pack.n_rows,
        n_cols=pack.n_cols,
        chunk_cols=pack.chunk_cols,
        group_rows=plane.group_rows,
        bits=plane.bits,
    )


def espim_matvec(w: EspimWeights | QuantEspimWeights, x: jnp.ndarray, *,
                 impl: str | None = None) -> jnp.ndarray:
    """y (n_rows,) or (n_rows, B) = W @ x with packed-row unscatter."""
    if x.ndim not in (1, 2):
        raise ValueError(f"x must be 1-D or 2-D, got {x.shape}")
    if isinstance(w, QuantEspimWeights):
        xb = x[:, None] if x.ndim == 1 else x
        yp = espim_spmv_batched_quant(w.values, w.cols, w.scales, xb,
                                      chunk_cols=w.chunk_cols,
                                      group_rows=w.group_rows, impl=impl)
        yp = yp[:, 0] if x.ndim == 1 else yp
    elif x.ndim == 1:
        yp = espim_spmv(w.values, w.cols, x, chunk_cols=w.chunk_cols,
                        impl=impl)
    else:
        yp = espim_spmv_batched(w.values, w.cols, x,
                                chunk_cols=w.chunk_cols, impl=impl)
    return _ref.scatter_rows_ref(yp, w.perm, w.n_rows)
