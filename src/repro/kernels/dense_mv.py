"""Dense tiled MV Pallas kernel — the Newton-datapath analogue.

Used as (a) the dense half of the flexible dense/sparse configuration
(Section III-I) and (b) the baseline the sparse kernel is compared against
in the benchmarks.  MXU-aligned (128-multiple) tiles; accumulation across
the C-chunk grid dimension in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dense_mv_pallas"]


def _dense_mv_kernel(w_ref, x_ref, out_ref):
    j = pl.program_id(1)
    w = w_ref[...].astype(jnp.float32)        # (RT, CT)
    x = x_ref[...].astype(jnp.float32)        # (CT,)
    partial = jnp.dot(w, x)                   # (RT,) on the MXU

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "interpret"))
def dense_mv_pallas(
    w: jnp.ndarray,
    x: jnp.ndarray,
    *,
    block_r: int = 128,
    block_c: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """y (R,) f32 = w (R, C) @ x (C,).  R, C padded to tile multiples."""
    r, c = w.shape
    pad_r = (-r) % block_r
    block_c = min(block_c, c)
    pad_c = (-c) % block_c
    if pad_r or pad_c:
        w = jnp.pad(w, ((0, pad_r), (0, pad_c)))
        x = jnp.pad(x, (0, pad_c))
    rp, cp = w.shape

    out = pl.pallas_call(
        _dense_mv_kernel,
        grid=(rp // block_r, cp // block_c),
        in_specs=[
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_c,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_r,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((rp,), jnp.float32),
        interpret=interpret,
    )(w, x)
    return out[:r]
