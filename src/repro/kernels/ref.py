"""Pure-jnp oracles for every kernel in this package.

These are the ground truth the Pallas kernels are swept against in
``tests/test_kernels.py`` (shapes x dtypes, ``assert_allclose``).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["espim_spmv_ref", "espim_spmv_batched_ref", "dense_mv_ref",
           "scatter_rows_ref"]


def espim_spmv_ref(values: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray
                   ) -> jnp.ndarray:
    """ELL sparse matrix-vector product.

    values, cols: (R_pad, L); x: (M,).  Pad slots carry value 0 (their col
    id is arbitrary but in-range), so they contribute nothing.
    Returns y_packed: (R_pad,) in f32.
    """
    xv = jnp.take(x, cols, axis=0)                      # (R_pad, L)
    return jnp.sum(values.astype(jnp.float32) * xv.astype(jnp.float32), axis=1)


def espim_spmv_batched_ref(values: jnp.ndarray, cols: jnp.ndarray,
                           x: jnp.ndarray) -> jnp.ndarray:
    """Batched ELL MV: x is (M, B); returns (R_pad, B) f32."""
    xv = jnp.take(x, cols, axis=0)                      # (R_pad, L, B)
    return jnp.einsum(
        "rl,rlb->rb", values.astype(jnp.float32), xv.astype(jnp.float32)
    )


def dense_mv_ref(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Dense MV oracle (Newton's datapath analogue): w (R, C) @ x (C,)."""
    return jnp.dot(w.astype(jnp.float32), x.astype(jnp.float32))


def scatter_rows_ref(y_packed: jnp.ndarray, perm: jnp.ndarray, n_rows: int
                     ) -> jnp.ndarray:
    """Map packed-row outputs back to original row ids (perm < 0 = pad)."""
    keep = perm >= 0
    safe = jnp.where(keep, perm, 0)
    out_shape = (n_rows,) + tuple(y_packed.shape[1:])
    zeros = jnp.zeros(out_shape, dtype=y_packed.dtype)
    contrib = jnp.where(
        keep.reshape(keep.shape + (1,) * (y_packed.ndim - 1)), y_packed, 0
    )
    return zeros.at[safe].add(contrib)
