"""Pure-jnp oracles for every kernel in this package.

These are the ground truth the Pallas kernels are swept against in
``tests/test_kernels.py`` (shapes x dtypes, ``assert_allclose``).

Two families:

* plain row-tile ELL ((R_pad, L) + global column ids) — the seed layout,
  still used by the sharded matvec path;
* column-chunked ELL ((R_pad, K, Lc) + chunk-local ids) — the fused-kernel
  layout.  ``espim_spmv_batched_chunked_ref`` is written as the same
  per-chunk gather-accumulate the Pallas kernel runs (one (R, Lc, B) slab
  live at a time), so it doubles as the fast lowering path inside jitted
  serving graphs on hosts where interpret-mode Pallas would be wasteful;
  ``espim_spmv_chunked_ref`` is the simple global-gather oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "espim_spmv_ref",
    "espim_spmv_batched_ref",
    "espim_spmv_chunked_ref",
    "espim_spmv_batched_chunked_ref",
    "espim_spmv_batched_chunked_quant_ref",
    "nibble_unpack_ref",
    "dequantize_plane_ref",
    "dense_mv_ref",
    "scatter_rows_ref",
    "epilogue_act",
    "glu_epilogue_ref",
    "espim_spmv_batched_chunked_glu_ref",
    "espim_spmv_batched_chunked_quant_glu_ref",
]


def epilogue_act(name: str):
    """Activation for the fused kernel epilogues.  A local map (instead of
    ``repro.models.layers.act_fn``) keeps the kernels package free of a
    models dependency; entries must stay bit-identical to ``act_fn``'s."""
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        import functools
        return functools.partial(jax.nn.gelu, approximate=True)
    if name == "relu":
        return jax.nn.relu
    if name == "relu2":
        return lambda v: jnp.square(jax.nn.relu(v))
    raise ValueError(f"unknown epilogue activation {name!r}")


def glu_epilogue_ref(acc: jnp.ndarray, act: str) -> jnp.ndarray:
    """act(gate) * up over a half-major (2*Rg, ...) packed accumulator —
    gate rows first, up rows second, halves sharing one balance perm so
    the product stays in packed order (act(0) * 0 == 0 on pad rows)."""
    rg = acc.shape[0] // 2
    return epilogue_act(act)(acc[:rg]) * acc[rg:]


def espim_spmv_ref(values: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray
                   ) -> jnp.ndarray:
    """ELL sparse matrix-vector product.

    values, cols: (R_pad, L); x: (M,).  Pad slots carry value 0 (their col
    id is arbitrary but in-range), so they contribute nothing.
    Returns y_packed: (R_pad,) in f32.
    """
    xv = jnp.take(x, cols, axis=0)                      # (R_pad, L)
    return jnp.sum(values.astype(jnp.float32) * xv.astype(jnp.float32), axis=1)


def espim_spmv_batched_ref(values: jnp.ndarray, cols: jnp.ndarray,
                           x: jnp.ndarray) -> jnp.ndarray:
    """Batched ELL MV: x is (M, B); returns (R_pad, B) f32."""
    xv = jnp.take(x, cols, axis=0)                      # (R_pad, L, B)
    return jnp.einsum(
        "rl,rlb->rb", values.astype(jnp.float32), xv.astype(jnp.float32)
    )


def _pad_x_to_chunks(x: jnp.ndarray, n_chunks: int, chunk_cols: int
                     ) -> jnp.ndarray:
    pad = n_chunks * chunk_cols - x.shape[0]
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x


def espim_spmv_chunked_ref(values: jnp.ndarray, cols: jnp.ndarray,
                           x: jnp.ndarray, chunk_cols: int) -> jnp.ndarray:
    """Chunked-ELL sparse MV oracle.

    values, cols: (R_pad, K, Lc) with chunk-local ids; x: (M,).
    Rebases ids to global and gathers once — the simple ground truth.
    Returns y_packed: (R_pad,) f32.
    """
    k = values.shape[1]
    xp = _pad_x_to_chunks(x, k, chunk_cols)
    glob = cols + (jnp.arange(k, dtype=cols.dtype) * chunk_cols)[None, :, None]
    xv = jnp.take(xp, glob, axis=0)                     # (R_pad, K, Lc)
    return jnp.sum(values.astype(jnp.float32) * xv.astype(jnp.float32),
                   axis=(1, 2))


def espim_spmv_batched_chunked_ref(values: jnp.ndarray, cols: jnp.ndarray,
                                   x: jnp.ndarray, chunk_cols: int
                                   ) -> jnp.ndarray:
    """Fused batched chunked-ELL MV: x is (M, B); returns (R_pad, B) f32.

    Mirrors the Pallas kernel's schedule in jnp: an unrolled loop over
    column chunks, each step gathering from one ``(chunk_cols, B)`` slab
    and reducing immediately — the live intermediate is (R_pad, Lc, B)
    for a single chunk, never the full (R_pad, K*Lc, B) the seed einsum
    path materialized.
    """
    r_pad, k, _lc = values.shape
    b = x.shape[1]
    xp = _pad_x_to_chunks(x, k, chunk_cols)
    acc = jnp.zeros((r_pad, b), jnp.float32)
    for i in range(k):
        xk = jax.lax.slice_in_dim(xp, i * chunk_cols, (i + 1) * chunk_cols,
                                  axis=0)
        g = jnp.take(xk, cols[:, i], axis=0)            # (R_pad, Lc, B)
        acc = acc + jnp.einsum("rl,rlb->rb", values[:, i].astype(jnp.float32),
                               g.astype(jnp.float32))
    return acc


def nibble_unpack_ref(packed: jnp.ndarray) -> jnp.ndarray:
    """uint8 (..., P) -> int4 codes in an int8 container (..., 2P); slot 2j
    is the low nibble of byte j (``repro.quant.qpack.nibble_pack``).
    Sign extension is two arithmetic shifts on the int8 bit pattern — no
    compares, no widening."""
    b = jax.lax.bitcast_convert_type(packed, jnp.int8)
    lo = jnp.right_shift(jnp.left_shift(b, 4), 4)      # low nibble, signed
    hi = jnp.right_shift(b, 4)                         # high nibble, signed
    inter = jnp.stack([lo, hi], axis=-1)               # (..., P, 2)
    return inter.reshape(*packed.shape[:-1], 2 * packed.shape[-1])


def dequantize_plane_ref(codes: jnp.ndarray, scales: jnp.ndarray,
                         group_rows: int) -> jnp.ndarray:
    """fp32 value plane from int8 codes (..., R, K, Lc) + per-row-group
    scales (..., R // group_rows) — the quant-kernel oracle."""
    s = jnp.repeat(scales, group_rows, axis=-1)
    return codes.astype(jnp.float32) * s[..., :, None, None]


# Formulation switch for the quantized lowering: a materialized dot
# (einsum) forces XLA-CPU to materialize the f32-converted codes plane,
# erasing the narrow plane's byte win; the fused multiply-reduce (the
# Pallas quant kernel's own schedule) keeps the int8 -> f32 convert inside
# the reduction fusion, so the decode-regime read traffic really is 1/4.
# The dot still wins once the gathered (Lc, B) block is large, so the
# lowering switches on the static per-chunk block size.
MULRED_MAX_BLOCK = 256  # Lc * B at or under this -> fused multiply-reduce


def espim_spmv_batched_chunked_quant_ref(codes: jnp.ndarray,
                                         cols: jnp.ndarray,
                                         scales: jnp.ndarray,
                                         x: jnp.ndarray, chunk_cols: int,
                                         group_rows: int) -> jnp.ndarray:
    """Quantized fused batched chunked-ELL MV: x (M, B) -> (R_pad, B) f32.

    Same per-chunk gather-accumulate schedule as the fp lowering, run on
    the int8 codes (nibble-packed uint8 planes are unpacked first); the
    per-row-group scale multiplies the accumulated (R_pad, B) output
    ONCE.  Decode-shaped blocks (``Lc * B <= MULRED_MAX_BLOCK``) use the
    fused multiply-reduce — the Pallas quant kernel's schedule, and on
    those shapes bit-identical to it; larger blocks use the same einsum
    as the fp lowering, with which the unit-scale path is bit-identical
    (the parity contracts ``tests/test_quant.py`` asserts).
    """
    r_pad, k, _lc = codes.shape
    if codes.shape[-1] != cols.shape[-1]:              # nibble-packed plane
        codes = nibble_unpack_ref(codes)[..., :cols.shape[-1]]
    b = x.shape[1]
    mulred = cols.shape[-1] * b <= MULRED_MAX_BLOCK
    xp = _pad_x_to_chunks(x, k, chunk_cols)
    acc = jnp.zeros((r_pad, b), jnp.float32)
    for i in range(k):
        xk = jax.lax.slice_in_dim(xp, i * chunk_cols, (i + 1) * chunk_cols,
                                  axis=0)
        g = jnp.take(xk, cols[:, i], axis=0)           # (R_pad, Lc, B)
        ci = codes[:, i].astype(jnp.float32)
        if mulred:
            acc = acc + jnp.sum(ci[:, :, None] * g.astype(jnp.float32),
                                axis=1)
        else:
            acc = acc + jnp.einsum("rl,rlb->rb", ci, g.astype(jnp.float32))
    if scales is None:                                 # caller owns scaling
        return acc
    srow = jnp.repeat(scales, group_rows, axis=-1)
    return acc * srow[:, None]


def espim_spmv_batched_chunked_glu_ref(values: jnp.ndarray,
                                       cols: jnp.ndarray, x: jnp.ndarray,
                                       chunk_cols: int, act: str
                                       ) -> jnp.ndarray:
    """Epilogue-fused gated MV: the half-major (2*Rg, K, Lc) gate+up pack
    through the SAME per-chunk gather-accumulate as the unfused lowering,
    with act(gate) * up applied to the (2*Rg, B) accumulator in the same
    jitted graph — returns (Rg, B) f32 in packed order.  Identical
    accumulation order means the fused output is bit-identical to running
    the unfused op and the epilogue separately."""
    acc = espim_spmv_batched_chunked_ref(values, cols, x, chunk_cols)
    return glu_epilogue_ref(acc, act)


def espim_spmv_batched_chunked_quant_glu_ref(codes: jnp.ndarray,
                                             cols: jnp.ndarray,
                                             srow: jnp.ndarray,
                                             x: jnp.ndarray, chunk_cols: int,
                                             act: str) -> jnp.ndarray:
    """Quantized epilogue-fused gated MV: code-domain accumulate (scales
    owned by the caller as pre-expanded per-row ``srow``), dequantize the
    (2*Rg, B) accumulator with ONE multiply, then act(gate) * up — the
    exact op sequence the unfused serving path runs, fused into one call.
    Returns (Rg, B) f32 in packed order."""
    acc = espim_spmv_batched_chunked_quant_ref(codes, cols, None, x,
                                               chunk_cols, 1)
    return glu_epilogue_ref(acc * srow[:, None], act)


def dense_mv_ref(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Dense MV oracle (Newton's datapath analogue): w (R, C) @ x (C,)."""
    return jnp.dot(w.astype(jnp.float32), x.astype(jnp.float32))


def scatter_rows_ref(y_packed: jnp.ndarray, perm: jnp.ndarray, n_rows: int
                     ) -> jnp.ndarray:
    """Map packed-row outputs back to original row ids (perm < 0 = pad)."""
    keep = perm >= 0
    safe = jnp.where(keep, perm, 0)
    out_shape = (n_rows,) + tuple(y_packed.shape[1:])
    zeros = jnp.zeros(out_shape, dtype=y_packed.dtype)
    contrib = jnp.where(
        keep.reshape(keep.shape + (1,) * (y_packed.ndim - 1)), y_packed, 0
    )
    return zeros.at[safe].add(contrib)
