"""ESPIM sparse MV as Pallas TPU kernels over the column-chunked ELL pack.

TPU adaptation of the paper's datapath (see DESIGN.md sections 2b/3):

* the grid is 3-D ``(row_tile, col_chunk, l_chunk)``: a step processes a
  128-row tile of the row-balanced pack against ONE ``chunk_cols``-wide
  slab of the activation vector ``x`` — the analogue of a bank's k-MAC
  group consuming one broadcast slice.  The ``x`` BlockSpec indexes the
  slab by the chunk coordinate, so VMEM residency is bounded at
  ``chunk_cols`` elements (x B for the batched kernel) no matter how wide
  the matrix is; the old kernels pinned the *entire* vector per tile;
* the (values, cols) blocks for the next grid step are DMA'd while the
  current one computes (Pallas grid pipelining) — the decoupled
  iFIFO/eFIFO prefetch;
* ``cols`` ids are *chunk-local* (the offline SDDS pass
  ``repro.core.sdds.chunk_cells`` groups cells and rebases ids), so the
  per-cell select is an in-VMEM gather into the active slab: the VPU's
  dynamic-gather path as the t_CCD-amortized equivalent of the paper's
  simplified 4x11 switch.  (A one-hot MXU "switch" was napkin-mathed and
  rejected: at 90% sparsity it costs ~16x the *dense* FLOPs — DESIGN.md.)
* the batched kernel gathers the whole ``(row_tile, l_chunk)`` col block
  in ONE vectorized ``take`` and multiply-reduces it; the gathered
  ``(row_tile, l_chunk, B)`` slab is bounded by ``block_l`` via the grid's
  l dimension, so it stays O(block_l * B) — unlike the seed einsum path,
  whose working set scaled with the full ELL width.  (The pre-fusion
  serial per-l ``fori_loop`` variant survives as ``gather="loop"`` for
  parity tests.)

The chunk padding slots carry value 0 and local col 0; they are the
statically scheduled stalls (SDDS dummy cells) and contribute nothing.

Kernels are validated in interpret mode on CPU against ``ref.py``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["espim_spmv_pallas", "espim_spmv_batched_pallas",
           "espim_spmv_batched_quant_pallas",
           "espim_spmv_batched_glu_pallas",
           "espim_spmv_batched_quant_glu_pallas",
           "espim_spmv_batched_res_pallas"]


def _check_chunked(values: jnp.ndarray, cols: jnp.ndarray) -> None:
    if values.ndim != 3 or cols.ndim != 3:
        raise ValueError(
            "kernels consume the column-chunked ELL layout (R_pad, "
            f"n_chunks, Lc); got values {values.shape}, cols {cols.shape}. "
            "Pack with pack_ell_chunked / chunk_pack.")


def _pad_inputs(values, cols, x, chunk_cols, block_r, block_l):
    """Common host-side prep: validate shapes, pad Lc to a block_l multiple
    and x up to n_chunks * chunk_cols (zero slots contribute nothing)."""
    _check_chunked(values, cols)
    r_pad, n_chunks, lc = values.shape
    if r_pad % block_r:
        # packs narrower than the default tile (small matrices, small
        # row_tile): shrink to the largest compatible row block
        block_r = math.gcd(r_pad, block_r)
        if block_r < 8:
            raise ValueError(
                f"R_pad={r_pad} has no sublane-aligned row block "
                f"(gcd with requested block_r gives {block_r})")
    block_l = min(block_l, max(8, lc))
    pad_l = (-lc) % block_l
    if pad_l:
        values = jnp.pad(values, ((0, 0), (0, 0), (0, pad_l)))
        cols = jnp.pad(cols, ((0, 0), (0, 0), (0, pad_l)))
        lc += pad_l
    m_pad = n_chunks * chunk_cols - x.shape[0]
    if m_pad < 0:
        raise ValueError(
            f"x has {x.shape[0]} rows > n_chunks*chunk_cols = "
            f"{n_chunks * chunk_cols}")
    if m_pad:
        x = jnp.pad(x, ((0, m_pad),) + ((0, 0),) * (x.ndim - 1))
    grid = (r_pad // block_r, n_chunks, lc // block_l)
    return values, cols, x, grid, block_r, block_l


def _spmv_kernel(values_ref, cols_ref, x_ref, out_ref):
    """One (row-tile, col-chunk, l-chunk) step: out[tile] += v * x_k[cols]."""
    k = pl.program_id(1)
    j = pl.program_id(2)
    vals = values_ref[...].astype(jnp.float32)          # (RT, LC)
    cols = cols_ref[...]                                # (RT, LC) local ids
    x = x_ref[...]                                      # (CC,) active slab
    gathered = jnp.take(x, cols, axis=0).astype(jnp.float32)
    partial = jnp.sum(vals * gathered, axis=1)          # (RT,)

    @pl.when((k == 0) & (j == 0))
    def _init():
        out_ref[...] = partial

    @pl.when((k != 0) | (j != 0))
    def _acc():
        out_ref[...] = out_ref[...] + partial


@functools.partial(
    jax.jit,
    static_argnames=("chunk_cols", "block_r", "block_l", "interpret"),
)
def espim_spmv_pallas(
    values: jnp.ndarray,
    cols: jnp.ndarray,
    x: jnp.ndarray,
    *,
    chunk_cols: int,
    block_r: int = 128,
    block_l: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """y_packed (R_pad,) f32 = chunked-ELL(values, cols) @ x.

    ``values``/``cols`` are (R_pad, n_chunks, Lc) with chunk-local column
    ids; ``block_r`` shrinks to the largest divisor of R_pad when needed.
    Lc is padded here to a multiple of ``block_l`` and x to
    ``n_chunks * chunk_cols`` (cheap: zeros contribute nothing).
    """
    values, cols, x, grid, block_r, block_l = _pad_inputs(
        values, cols, x, chunk_cols, block_r, block_l)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, None, block_l), lambda i, k, j: (i, k, j)),
            pl.BlockSpec((block_r, None, block_l), lambda i, k, j: (i, k, j)),
            pl.BlockSpec((chunk_cols,), lambda i, k, j: (k,)),  # one slab
        ],
        out_specs=pl.BlockSpec((block_r,), lambda i, k, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((values.shape[0],), jnp.float32),
        interpret=interpret,
    )(values, cols, x)


def _spmv_batched_kernel(values_ref, cols_ref, x_ref, out_ref):
    """Batched decode step: ONE block-wide gather over the (RT, LC) col
    block, then a vectorized multiply-reduce.  The (RT, LC, B) gathered
    slab is bounded by ``block_l`` (the l-chunk grid dimension), so unlike
    the seed einsum path the working set never scales with the full ELL
    width."""
    k = pl.program_id(1)
    j = pl.program_id(2)
    vals = values_ref[...].astype(jnp.float32)           # (RT, LC)
    cols = cols_ref[...]                                 # (RT, LC) local ids
    x = x_ref[...]                                       # (CC, B) active slab
    gathered = jnp.take(x, cols, axis=0).astype(jnp.float32)  # (RT, LC, B)
    partial = jnp.sum(vals[..., None] * gathered, axis=1)     # (RT, B)

    @pl.when((k == 0) & (j == 0))
    def _init():
        out_ref[...] = partial

    @pl.when((k != 0) | (j != 0))
    def _acc():
        out_ref[...] = out_ref[...] + partial


def _spmv_batched_kernel_looped(values_ref, cols_ref, x_ref, out_ref):
    """The pre-fusion schedule (PR 2): a serial per-l ``fori_loop`` gather
    over (RT, B) partials.  Kept as the parity reference for the
    vectorized kernel above."""
    k = pl.program_id(1)
    j = pl.program_id(2)
    vals = values_ref[...].astype(jnp.float32)           # (RT, LC)
    cols = cols_ref[...]                                 # (RT, LC) local ids
    x = x_ref[...]                                       # (CC, B) active slab

    def body(l, acc):
        xl = jnp.take(x, cols[:, l], axis=0).astype(jnp.float32)  # (RT, B)
        return acc + vals[:, l][:, None] * xl

    partial = jax.lax.fori_loop(
        0, vals.shape[1], body, jnp.zeros(out_ref.shape, jnp.float32))

    @pl.when((k == 0) & (j == 0))
    def _init():
        out_ref[...] = partial

    @pl.when((k != 0) | (j != 0))
    def _acc():
        out_ref[...] = out_ref[...] + partial


# --------------------------------------------------------------------------
# Quantized value planes (DESIGN.md section 9)
#
# The paper stores narrow fixed-point cell values in DRAM; here the value
# block a grid step DMAs is int8 codes (or nibble-packed int4 — two codes
# per byte) instead of fp32, and dequantization is in-register: the gather
# geometry (cols, grid, BlockSpecs) is IDENTICAL to the fp kernel — only
# the value plane narrows, exactly the paper's value/index decoupling.
# One scale per ``group_rows`` packed rows rides in as a tiny side input
# whose block is (block_r // group_rows,) — it loads once per grid step
# and multiplies the (RT, B) partial AFTER the reduce, so the per-cell
# inner loop is integer-code * activation with no extra multiplies.
# --------------------------------------------------------------------------
def _row_scales(scales_ref, group_rows: int):
    """(block_r // group_rows,) scale block -> per-row (block_r,) f32."""
    s = scales_ref[...]
    return jnp.broadcast_to(s[:, None], (s.shape[0], group_rows)).reshape(-1)


def _quant_step(codes, cols_ref, scales_ref, x_ref, out_ref, group_rows):
    """Shared quant decode step body: gather as the fp kernel, multiply-
    reduce the f32 codes, dequantize the (RT, B) partial by the per-row-
    group scale AFTER the reduce, init/accumulate across grid steps."""
    k = pl.program_id(1)
    j = pl.program_id(2)
    cols = cols_ref[...]                                 # (RT, LC) local ids
    x = x_ref[...]                                       # (CC, B) active slab
    gathered = jnp.take(x, cols, axis=0).astype(jnp.float32)  # (RT, LC, B)
    partial = jnp.sum(codes[..., None] * gathered, axis=1)    # (RT, B)
    srow = _row_scales(scales_ref, group_rows)
    partial = partial * srow[:, None]

    @pl.when((k == 0) & (j == 0))
    def _init():
        out_ref[...] = partial

    @pl.when((k != 0) | (j != 0))
    def _acc():
        out_ref[...] = out_ref[...] + partial


def _spmv_batched_quant_kernel(values_ref, cols_ref, scales_ref, x_ref,
                               out_ref, *, group_rows):
    """int8-code decode step: the value block is int8 codes."""
    _quant_step(values_ref[...].astype(jnp.float32), cols_ref, scales_ref,
                x_ref, out_ref, group_rows)


def _spmv_batched_q4_kernel(values_ref, cols_ref, scales_ref, x_ref,
                            out_ref, *, group_rows):
    """Nibble-packed int4 decode step: the value block is uint8 with TWO
    codes per byte (half the bytes of int8, a quarter of fp32); unpack
    in-register — slot 2j is the low nibble of byte j (the same
    ``nibble_unpack_ref`` helper the jnp lowering uses) — then proceed as
    the int8 kernel."""
    from repro.kernels.ref import nibble_unpack_ref
    codes = nibble_unpack_ref(values_ref[...]).astype(jnp.float32)
    _quant_step(codes, cols_ref, scales_ref, x_ref, out_ref, group_rows)


@functools.partial(
    jax.jit,
    static_argnames=("chunk_cols", "group_rows", "block_r", "block_l",
                     "interpret"),
)
def espim_spmv_batched_quant_pallas(
    values: jnp.ndarray,
    cols: jnp.ndarray,
    scales: jnp.ndarray,
    x: jnp.ndarray,
    *,
    chunk_cols: int,
    group_rows: int,
    block_r: int = 128,
    block_l: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """y_packed (R_pad, B) f32 = dequant(chunked-ELL codes) @ x (M, B).

    ``values`` is the quantized value plane: int8 codes (R_pad, K, Lc), or
    nibble-packed uint8 (R_pad, K, ceil(Lc/2)) — the storage family is
    inferred from the width mismatch vs ``cols``.  ``scales`` is one f32
    per ``group_rows`` packed rows ((R_pad // group_rows,)); if the row
    block cannot cover whole groups the scales are pre-expanded per-row.
    """
    _check_chunked(values, cols)
    r_pad, n_chunks, lc = cols.shape
    packed = values.shape[-1] != lc
    if packed:
        if lc % 2:                     # odd width: one pad col slot (id 0,
            cols = jnp.pad(cols, ((0, 0), (0, 0), (0, 1)))  # code 0)
            lc += 1
        if 2 * values.shape[-1] != lc:
            raise ValueError(
                f"nibble-packed values width {values.shape[-1]} does not "
                f"match cols width {cols.shape[-1]}")
    if r_pad % block_r:
        block_r = math.gcd(r_pad, block_r)
        if block_r < 8:
            raise ValueError(
                f"R_pad={r_pad} has no sublane-aligned row block "
                f"(gcd with requested block_r gives {block_r})")
    if r_pad % group_rows or block_r % group_rows:
        # scale groups must tile the row block; expand to per-row scales
        scales = jnp.repeat(scales, group_rows)[:r_pad]
        group_rows = 1
    block_l = min(block_l, max(8, lc))
    if packed:
        block_l += block_l % 2         # nibble pairs never straddle blocks
    pad_l = (-lc) % block_l
    if pad_l:
        cols = jnp.pad(cols, ((0, 0), (0, 0), (0, pad_l)))
        pad_v = pad_l // 2 if packed else pad_l
        values = jnp.pad(values, ((0, 0), (0, 0), (0, pad_v)))
        lc += pad_l
    m_pad = n_chunks * chunk_cols - x.shape[0]
    if m_pad < 0:
        raise ValueError(
            f"x has {x.shape[0]} rows > n_chunks*chunk_cols = "
            f"{n_chunks * chunk_cols}")
    if m_pad:
        x = jnp.pad(x, ((0, m_pad), (0, 0)))
    grid = (r_pad // block_r, n_chunks, lc // block_l)
    b = x.shape[1]
    block_v = block_l // 2 if packed else block_l
    kernel = functools.partial(
        _spmv_batched_q4_kernel if packed else _spmv_batched_quant_kernel,
        group_rows=group_rows)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, None, block_v), lambda i, k, j: (i, k, j)),
            pl.BlockSpec((block_r, None, block_l), lambda i, k, j: (i, k, j)),
            pl.BlockSpec((block_r // group_rows,), lambda i, k, j: (i,)),
            pl.BlockSpec((chunk_cols, b), lambda i, k, j: (k, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, b), lambda i, k, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_pad, b), jnp.float32),
        interpret=interpret,
    )(values, cols, scales, x)


# --------------------------------------------------------------------------
# Fused decode epilogues (DESIGN.md §15)
#
# PR 3 measured the residual cost of losing to dense as per-token launch
# overhead BETWEEN SpMV calls: act(gate)·up and the residual add run as
# separate XLA ops over the (R_pad, B) accumulator.  Both fold into the
# kernel's own partial-accumulate epilogue:
#
# * GLU — the gate+up group packs its halves half-major ((2, Rg) row
#   blocks) under ONE balance perm, so gate row r and up row r sit at the
#   same packed position of their halves and act(gate)·up needs no
#   unscatter.  The kernel views the value/index planes as (2, Rg, K, Lc),
#   accumulates BOTH halves' (RT, B) partials in the out block, and the
#   LAST grid step rewrites half 0 with act(acc_g)·acc_u in-register —
#   zero extra memory traffic, one launch instead of launch + two
#   elementwise passes.
# * residual — an extra (RT, B) operand block rides in and is added once
#   at the last grid step (legal for ``output="take"`` groups when the
#   caller supplies the residual pre-permuted to packed order).
#
# The quantized GLU variants dequantize the two halves' accumulators with
# the per-row scales at the same last step — after the reduce, before the
# activation, the exact order the unfused serving path uses.
# --------------------------------------------------------------------------
def _epilogue_act(name: str):
    from repro.kernels.ref import epilogue_act
    return epilogue_act(name)


def _acc_step(partial, out_ref):
    k = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((k == 0) & (j == 0))
    def _init():
        out_ref[...] = partial

    @pl.when((k != 0) | (j != 0))
    def _acc():
        out_ref[...] = out_ref[...] + partial


def _is_last_step():
    k = pl.program_id(1)
    j = pl.program_id(2)
    return ((k == pl.num_programs(1) - 1)
            & (j == pl.num_programs(2) - 1))


def _glu_kernel(values_ref, cols_ref, x_ref, out_ref, *, act):
    """Half-major gated step: values/cols blocks are (2, RT, LC) — gate
    half 0, up half 1 — accumulated into a (2, RT, B) out block; the last
    grid step rewrites half 0 with act(gate) * up (half 1 is scratch the
    host-side wrapper drops)."""
    vals = values_ref[...].astype(jnp.float32)           # (2, RT, LC)
    cols = cols_ref[...]
    x = x_ref[...]                                       # (CC, B)
    gathered = jnp.take(x, cols, axis=0).astype(jnp.float32)
    _acc_step(jnp.sum(vals[..., None] * gathered, axis=2), out_ref)

    @pl.when(_is_last_step())
    def _epilogue():
        acc = out_ref[...]
        out_ref[0] = _epilogue_act(act)(acc[0]) * acc[1]


def _glu_quant_kernel(values_ref, cols_ref, srow_ref, x_ref, out_ref, *,
                      act, packed):
    """Quantized half-major gated step: int8 codes (or nibble-packed
    uint8) accumulate in the code domain; the last grid step dequantizes
    both halves with the per-row scales, THEN applies act(gate) * up —
    the unfused path's exact op order."""
    from repro.kernels.ref import nibble_unpack_ref
    vals = values_ref[...]
    if packed:
        vals = nibble_unpack_ref(vals)
    vals = vals.astype(jnp.float32)                      # (2, RT, LC)
    cols = cols_ref[...]
    x = x_ref[...]                                       # (CC, B)
    gathered = jnp.take(x, cols, axis=0).astype(jnp.float32)
    _acc_step(jnp.sum(vals[..., None] * gathered, axis=2), out_ref)

    @pl.when(_is_last_step())
    def _epilogue():
        y = out_ref[...] * srow_ref[...][..., None]      # (2, RT, B)
        out_ref[0] = _epilogue_act(act)(y[0]) * y[1]


def _spmv_batched_res_kernel(values_ref, cols_ref, x_ref, res_ref, out_ref):
    """The batched kernel with a fused residual add: the pre-permuted
    (RT, B) residual block is added once at the last grid step."""
    vals = values_ref[...].astype(jnp.float32)           # (RT, LC)
    cols = cols_ref[...]
    x = x_ref[...]                                       # (CC, B)
    gathered = jnp.take(x, cols, axis=0).astype(jnp.float32)
    _acc_step(jnp.sum(vals[..., None] * gathered, axis=1), out_ref)

    @pl.when(_is_last_step())
    def _epilogue():
        out_ref[...] = out_ref[...] + res_ref[...]


def _halve(arr: jnp.ndarray) -> jnp.ndarray:
    """(2*Rg, ...) half-major plane -> (2, Rg, ...)."""
    if arr.shape[0] % 2:
        raise ValueError(
            f"GLU epilogue needs a half-major (2*Rg, ...) pack; got "
            f"{arr.shape[0]} rows")
    return arr.reshape(2, arr.shape[0] // 2, *arr.shape[1:])


@functools.partial(
    jax.jit,
    static_argnames=("chunk_cols", "act", "block_r", "block_l", "interpret"),
)
def espim_spmv_batched_glu_pallas(
    values: jnp.ndarray,
    cols: jnp.ndarray,
    x: jnp.ndarray,
    *,
    chunk_cols: int,
    act: str = "silu",
    block_r: int = 128,
    block_l: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """act(gate) * up (Rg, B) f32 from a half-major (2*Rg, K, Lc) gate+up
    pack — the epilogue-fused gated-MLP launch."""
    _check_chunked(values, cols)
    values = _halve(values)
    cols = _halve(cols)
    _, rg, n_chunks, lc = values.shape
    if rg % block_r:
        block_r = math.gcd(rg, block_r)
        if block_r < 8:
            raise ValueError(
                f"Rg={rg} has no sublane-aligned row block "
                f"(gcd with requested block_r gives {block_r})")
    block_l = min(block_l, max(8, lc))
    pad_l = (-lc) % block_l
    if pad_l:
        values = jnp.pad(values, ((0, 0), (0, 0), (0, 0), (0, pad_l)))
        cols = jnp.pad(cols, ((0, 0), (0, 0), (0, 0), (0, pad_l)))
        lc += pad_l
    m_pad = n_chunks * chunk_cols - x.shape[0]
    if m_pad < 0:
        raise ValueError(
            f"x has {x.shape[0]} rows > n_chunks*chunk_cols = "
            f"{n_chunks * chunk_cols}")
    if m_pad:
        x = jnp.pad(x, ((0, m_pad), (0, 0)))
    grid = (rg // block_r, n_chunks, lc // block_l)
    b = x.shape[1]
    out = pl.pallas_call(
        functools.partial(_glu_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2, block_r, None, block_l),
                         lambda i, k, j: (0, i, k, j)),
            pl.BlockSpec((2, block_r, None, block_l),
                         lambda i, k, j: (0, i, k, j)),
            pl.BlockSpec((chunk_cols, b), lambda i, k, j: (k, 0)),
        ],
        out_specs=pl.BlockSpec((2, block_r, b), lambda i, k, j: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((2, rg, b), jnp.float32),
        interpret=interpret,
    )(values, cols, x)
    return out[0]


@functools.partial(
    jax.jit,
    static_argnames=("chunk_cols", "act", "block_r", "block_l", "interpret"),
)
def espim_spmv_batched_quant_glu_pallas(
    values: jnp.ndarray,
    cols: jnp.ndarray,
    srow: jnp.ndarray,
    x: jnp.ndarray,
    *,
    chunk_cols: int,
    act: str = "silu",
    block_r: int = 128,
    block_l: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Quantized epilogue-fused gated launch: int8 codes or nibble-packed
    uint8 (width mismatch vs ``cols``), pre-expanded per-row f32 scales
    ``srow`` (2*Rg,); returns act(gate) * up (Rg, B) f32."""
    _check_chunked(values, cols)
    r2, n_chunks, lc = cols.shape
    packed = values.shape[-1] != lc
    if packed:
        if lc % 2:
            cols = jnp.pad(cols, ((0, 0), (0, 0), (0, 1)))
            lc += 1
        if 2 * values.shape[-1] != lc:
            raise ValueError(
                f"nibble-packed values width {values.shape[-1]} does not "
                f"match cols width {cols.shape[-1]}")
    values = _halve(values)
    cols = _halve(cols)
    srow = _halve(srow)
    rg = values.shape[1]
    if rg % block_r:
        block_r = math.gcd(rg, block_r)
        if block_r < 8:
            raise ValueError(
                f"Rg={rg} has no sublane-aligned row block "
                f"(gcd with requested block_r gives {block_r})")
    block_l = min(block_l, max(8, lc))
    if packed:
        block_l += block_l % 2
    pad_l = (-lc) % block_l
    if pad_l:
        cols = jnp.pad(cols, ((0, 0), (0, 0), (0, 0), (0, pad_l)))
        pad_v = pad_l // 2 if packed else pad_l
        values = jnp.pad(values, ((0, 0), (0, 0), (0, 0), (0, pad_v)))
        lc += pad_l
    m_pad = n_chunks * chunk_cols - x.shape[0]
    if m_pad < 0:
        raise ValueError(
            f"x has {x.shape[0]} rows > n_chunks*chunk_cols = "
            f"{n_chunks * chunk_cols}")
    if m_pad:
        x = jnp.pad(x, ((0, m_pad), (0, 0)))
    grid = (rg // block_r, n_chunks, lc // block_l)
    b = x.shape[1]
    block_v = block_l // 2 if packed else block_l
    out = pl.pallas_call(
        functools.partial(_glu_quant_kernel, act=act, packed=packed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2, block_r, None, block_v),
                         lambda i, k, j: (0, i, k, j)),
            pl.BlockSpec((2, block_r, None, block_l),
                         lambda i, k, j: (0, i, k, j)),
            pl.BlockSpec((2, block_r), lambda i, k, j: (0, i)),
            pl.BlockSpec((chunk_cols, b), lambda i, k, j: (k, 0)),
        ],
        out_specs=pl.BlockSpec((2, block_r, b), lambda i, k, j: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((2, rg, b), jnp.float32),
        interpret=interpret,
    )(values, cols, srow, x)
    return out[0]


@functools.partial(
    jax.jit,
    static_argnames=("chunk_cols", "block_r", "block_l", "interpret"),
)
def espim_spmv_batched_res_pallas(
    values: jnp.ndarray,
    cols: jnp.ndarray,
    x: jnp.ndarray,
    residual: jnp.ndarray,
    *,
    chunk_cols: int,
    block_r: int = 128,
    block_l: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """y_packed (R_pad, B) f32 = chunked-ELL @ x + residual, the residual
    add fused into the last grid step (``residual`` already in packed row
    order — the ``output="take"`` contract lets the caller permute it
    once, statically)."""
    values, cols, x, grid, block_r, block_l = _pad_inputs(
        values, cols, x, chunk_cols, block_r, block_l)
    b = x.shape[1]
    return pl.pallas_call(
        _spmv_batched_res_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, None, block_l), lambda i, k, j: (i, k, j)),
            pl.BlockSpec((block_r, None, block_l), lambda i, k, j: (i, k, j)),
            pl.BlockSpec((chunk_cols, b), lambda i, k, j: (k, 0)),
            pl.BlockSpec((block_r, b), lambda i, k, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, b), lambda i, k, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((values.shape[0], b), jnp.float32),
        interpret=interpret,
    )(values, cols, x, residual)


@functools.partial(
    jax.jit,
    static_argnames=("chunk_cols", "block_r", "block_l", "interpret",
                     "gather"),
)
def espim_spmv_batched_pallas(
    values: jnp.ndarray,
    cols: jnp.ndarray,
    x: jnp.ndarray,
    *,
    chunk_cols: int,
    block_r: int = 128,
    block_l: int = 128,
    interpret: bool = True,
    gather: str = "block",
) -> jnp.ndarray:
    """y_packed (R_pad, B) f32 = chunked-ELL(values, cols) @ x (M, B).

    ``gather="block"`` (default) runs one vectorized (RT, LC)-wide gather
    per grid step; ``gather="loop"`` keeps the old serial per-l gather for
    parity testing.  ``block_l`` bounds the gathered (RT, LC, B) slab.
    """
    if gather not in ("block", "loop"):
        raise ValueError(f"unknown gather mode {gather!r}")
    values, cols, x, grid, block_r, block_l = _pad_inputs(
        values, cols, x, chunk_cols, block_r, block_l)
    b = x.shape[1]
    kernel = (_spmv_batched_kernel if gather == "block"
              else _spmv_batched_kernel_looped)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, None, block_l), lambda i, k, j: (i, k, j)),
            pl.BlockSpec((block_r, None, block_l), lambda i, k, j: (i, k, j)),
            pl.BlockSpec((chunk_cols, b), lambda i, k, j: (k, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, b), lambda i, k, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((values.shape[0], b), jnp.float32),
        interpret=interpret,
    )(values, cols, x)
