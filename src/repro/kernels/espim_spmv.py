"""ESPIM sparse MV as a Pallas TPU kernel.

TPU adaptation of the paper's datapath (see DESIGN.md section 2b):

* a grid step processes a 128-row *tile* of the row-balanced ELL pack — the
  analogue of a bank's k-MAC group sharing one vector broadcast;
* the dense activation vector ``x`` lives in VMEM for the whole tile (the
  "global buffer" + broadcast latch), so each element is fetched from HBM
  once per tile rather than once per row;
* the (values, cols) blocks for grid step i+1 are DMA'd while step i
  computes (Pallas grid pipelining) — the decoupled iFIFO/eFIFO prefetch;
* the per-cell select of the matching vector element is an in-VMEM gather:
  the VPU's dynamic-gather path is the t_CCD-amortized equivalent of the
  paper's simplified 4x11 switch.  (A one-hot MXU "switch" was napkin-mathed
  and rejected: at 90% sparsity it costs ~16x the *dense* FLOPs — see
  DESIGN.md.)

The ELL padding slots carry value 0 and col 0; they are the statically
scheduled stalls (SDDS dummy cells) and contribute nothing to the output.

Kernels are validated in interpret mode on CPU against ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["espim_spmv_pallas", "espim_spmv_batched_pallas"]


def _spmv_kernel(values_ref, cols_ref, x_ref, out_ref):
    """One (row-tile, L-chunk) grid step: out[tile] += sum_l v * x[cols]."""
    j = pl.program_id(1)
    vals = values_ref[...].astype(jnp.float32)          # (RT, LC)
    cols = cols_ref[...]                                # (RT, LC) int32
    x = x_ref[...]                                      # (M,) resident slice
    gathered = jnp.take(x, cols, axis=0).astype(jnp.float32)
    partial = jnp.sum(vals * gathered, axis=1)          # (RT,)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("block_r", "block_l", "interpret"))
def espim_spmv_pallas(
    values: jnp.ndarray,
    cols: jnp.ndarray,
    x: jnp.ndarray,
    *,
    block_r: int = 128,
    block_l: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """y_packed (R_pad,) f32 = ELL(values, cols) @ x.

    R_pad must be a multiple of ``block_r``; L is padded here to a multiple
    of ``block_l`` (cheap: zeros contribute nothing).
    """
    r_pad, ell_l = values.shape
    if r_pad % block_r:
        raise ValueError(f"R_pad={r_pad} not a multiple of block_r={block_r}")
    block_l = min(block_l, max(8, ell_l))
    pad_l = (-ell_l) % block_l
    if pad_l:
        values = jnp.pad(values, ((0, 0), (0, pad_l)))
        cols = jnp.pad(cols, ((0, 0), (0, pad_l)))
        ell_l += pad_l
    m = x.shape[0]

    grid = (r_pad // block_r, ell_l // block_l)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_l), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, block_l), lambda i, j: (i, j)),
            pl.BlockSpec((m,), lambda i, j: (0,)),  # x resident across tile
        ],
        out_specs=pl.BlockSpec((block_r,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((r_pad,), jnp.float32),
        interpret=interpret,
    )(values, cols, x)


def _spmv_batched_kernel(values_ref, cols_ref, x_ref, out_ref):
    """Batched decode variant: x (M, B) resident; out (RT, B)."""
    j = pl.program_id(1)
    vals = values_ref[...].astype(jnp.float32)           # (RT, LC)
    cols = cols_ref[...]                                 # (RT, LC)
    x = x_ref[...]                                       # (M, B)
    gathered = jnp.take(x, cols, axis=0).astype(jnp.float32)  # (RT, LC, B)
    partial = jnp.einsum("rl,rlb->rb", vals, gathered)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_l", "interpret")
)
def espim_spmv_batched_pallas(
    values: jnp.ndarray,
    cols: jnp.ndarray,
    x: jnp.ndarray,
    *,
    block_r: int = 128,
    block_l: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """y_packed (R_pad, B) f32 = ELL(values, cols) @ x (M, B)."""
    r_pad, ell_l = values.shape
    m, b = x.shape
    if r_pad % block_r:
        raise ValueError(f"R_pad={r_pad} not a multiple of block_r={block_r}")
    block_l = min(block_l, max(8, ell_l))
    pad_l = (-ell_l) % block_l
    if pad_l:
        values = jnp.pad(values, ((0, 0), (0, pad_l)))
        cols = jnp.pad(cols, ((0, 0), (0, pad_l)))
        ell_l += pad_l

    grid = (r_pad // block_r, ell_l // block_l)
    return pl.pallas_call(
        _spmv_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_l), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, block_l), lambda i, j: (i, j)),
            pl.BlockSpec((m, b), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, b), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_pad, b), jnp.float32),
        interpret=interpret,
    )(values, cols, x)
