"""Flash attention as a Pallas TPU kernel (prefill path).

The roofline table (EXPERIMENTS.md) shows long-sequence prefill cells
memory-dominated by f32 score materialization between the QK and PV
matmuls of the chunked-JAX attention.  This kernel keeps the online-
softmax state (m, l, acc) and the score tile in VMEM scratch across the
KV grid dimension, so HBM sees only Q/K/V/O streams — the standard TPU
remedy, validated here in interpret mode against the pure-JAX oracle.

Layout: q/k/v as (BH, S, hd); grid (BH, n_q, n_kv) with kv innermost;
out block revisited across kv steps; causal masking from program ids.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, scale: float, blk_q: int, blk_k: int,
                  seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (blk_q, hd)
    k = k_ref[0].astype(jnp.float32)                  # (blk_k, hd)
    v = v_ref[0].astype(jnp.float32)
    s = q @ k.T                                       # (blk_q, blk_k)

    q_pos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32,
                                                  (blk_q, blk_k), 0)
    k_pos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32,
                                                  (blk_q, blk_k), 1)
    mask = k_pos < seq_len
    if causal:
        mask = mask & (q_pos >= k_pos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + p @ v
    m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "blk_q", "blk_k", "interpret"))
def flash_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """q/k/v: (BH, S, hd) -> out (BH, S, hd).  S padded to block size;
    GQA repeat and (B, S, H, hd) reshapes live in the caller."""
    bh, s, hd = q.shape
    blk_q = min(blk_q, s)
    blk_k = min(blk_k, s)
    pad_q = (-s) % blk_q
    pad_k = (-s) % blk_k
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    sq, sk = q.shape[1], k.shape[1]
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, causal=causal, scale=scale, blk_q=blk_q,
        blk_k=blk_k, seq_len=s)
    out = pl.pallas_call(
        kernel,
        grid=(bh, sq // blk_q, sk // blk_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :s]
