"""Cluster-runtime policies: heartbeat failure detection, straggler
mitigation, and elastic re-meshing.

On real hardware these hooks sit in the launcher (GKE/Borg restarts, the
JAX coordination service surfaces missing hosts); the *policy* layer is
hardware-independent and fully implemented + tested here:

  * ``HeartbeatMonitor`` — per-worker liveness with a configurable timeout;
    failed workers are reported to the elastic planner.
  * ``StragglerDetector`` — per-step worker timings vs. rolling median;
    persistent stragglers (> threshold x median for k consecutive steps)
    are treated as soft failures (the cure at scale: drop the node and
    re-mesh, not wait).
  * ``plan_elastic_mesh`` — given surviving device count, picks the largest
    valid (pod, data, model) mesh that preserves the model axis (TP degree
    is fixed by the weight shapes) and shrinks data parallelism; the
    checkpoint reshard path (checkpoint/ckpt.py) re-lays the state onto it.
"""
from __future__ import annotations

import dataclasses
import statistics

__all__ = ["HeartbeatMonitor", "StragglerDetector", "plan_elastic_mesh",
           "ElasticPlan"]


class HeartbeatMonitor:
    def __init__(self, workers: list, timeout: float = 30.0):
        self.timeout = timeout
        self.last_seen: dict = {w: 0.0 for w in workers}
        self._failed: set = set()

    def beat(self, worker, now: float) -> None:
        if worker in self._failed:
            return
        self.last_seen[worker] = now

    def failed(self, now: float) -> list:
        out = [w for w, t in self.last_seen.items()
               if w not in self._failed and now - t > self.timeout]
        self._failed.update(out)
        return sorted(self._failed)

    def healthy(self, now: float) -> list:
        self.failed(now)
        return [w for w in self.last_seen if w not in self._failed]


class StragglerDetector:
    def __init__(self, threshold: float = 2.0, patience: int = 3):
        self.threshold = threshold
        self.patience = patience
        self._strikes: dict = {}

    def observe_step(self, timings: dict) -> list:
        """timings: worker -> step seconds.  Returns persistent stragglers."""
        if len(timings) < 2:
            return []
        med = statistics.median(timings.values())
        out = []
        for w, t in timings.items():
            if t > self.threshold * max(med, 1e-9):
                self._strikes[w] = self._strikes.get(w, 0) + 1
                if self._strikes[w] >= self.patience:
                    out.append(w)
            else:
                self._strikes[w] = 0
        return sorted(out)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple
    axis_names: tuple
    dropped_devices: int
    note: str


def plan_elastic_mesh(n_healthy: int, model_parallel: int,
                      pod_size: int | None = None) -> ElasticPlan:
    """Largest usable mesh after failures.

    TP degree (``model_parallel``) is pinned by the sharded weight shapes;
    data parallelism absorbs the loss.  With ``pod_size`` set, whole pods
    are the elastic unit (a failed node sidelines its pod's stragglers to
    the spare pool — the standard multi-pod policy)."""
    if n_healthy < model_parallel:
        raise ValueError(
            f"cannot re-mesh: {n_healthy} devices < TP degree "
            f"{model_parallel}")
    if pod_size:
        pods = n_healthy // pod_size
        if pods >= 2:
            data = pod_size // model_parallel
            used = pods * pod_size
            return ElasticPlan((pods, data, model_parallel),
                               ("pod", "data", "model"),
                               n_healthy - used,
                               f"{pods} full pods, data axis {data}")
        n_healthy = min(n_healthy, pod_size)
    data = n_healthy // model_parallel
    used = data * model_parallel
    return ElasticPlan((data, model_parallel), ("data", "model"),
                       n_healthy - used,
                       f"single pod, data axis shrunk to {data}")
