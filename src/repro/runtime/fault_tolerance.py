"""Cluster-runtime policies: heartbeat failure detection, straggler
mitigation, and elastic re-meshing.

On real hardware these hooks sit in the launcher (GKE/Borg restarts, the
JAX coordination service surfaces missing hosts); the *policy* layer is
hardware-independent and fully implemented + tested here:

  * ``StrikePolicy`` — the shared k-consecutive-strikes escalation rule:
    a key trips only after ``patience`` uninterrupted strikes (one clean
    observation resets it).  Both the training-cluster straggler detector
    and the serving engine's stuck-decode watchdog run on this one policy.
  * ``HeartbeatMonitor`` — per-worker liveness with a configurable timeout;
    failed workers are reported to the elastic planner.
  * ``StragglerDetector`` — per-step worker timings vs. rolling median;
    persistent stragglers (> threshold x median for k consecutive steps)
    are treated as soft failures (the cure at scale: drop the node and
    re-mesh, not wait).
  * ``LatencyWatchdog`` — the single-stream form for the serving engine:
    one step-time series vs its own rolling median; a spike streak flags
    a stuck decode loop without any cross-worker comparison.
  * ``plan_elastic_mesh`` — given surviving device count, picks the largest
    valid (pod, data, model) mesh that preserves the model axis (TP degree
    is fixed by the weight shapes) and shrinks data parallelism; the
    checkpoint reshard path (checkpoint/ckpt.py) re-lays the state onto it.
"""
from __future__ import annotations

import dataclasses
import statistics
from collections import deque

__all__ = ["StrikePolicy", "HeartbeatMonitor", "StragglerDetector",
           "LatencyWatchdog", "plan_elastic_mesh", "ElasticPlan"]


class StrikePolicy:
    """k-consecutive-strikes escalation, keyed by an arbitrary id.

    ``strike(key)`` records one violation and returns True when the key
    has accumulated ``patience`` consecutive strikes; ``clear(key)``
    resets it (one clean observation forgives the streak — transient
    blips never escalate, only persistent misbehavior does)."""

    def __init__(self, patience: int = 3):
        self.patience = max(1, patience)
        self._strikes: dict = {}

    def strike(self, key) -> bool:
        self._strikes[key] = self._strikes.get(key, 0) + 1
        return self._strikes[key] >= self.patience

    def clear(self, key) -> None:
        self._strikes[key] = 0

    def strikes(self, key) -> int:
        return self._strikes.get(key, 0)


class HeartbeatMonitor:
    def __init__(self, workers: list, timeout: float = 30.0):
        self.timeout = timeout
        self.last_seen: dict = {w: 0.0 for w in workers}
        self._failed: set = set()

    def beat(self, worker, now: float) -> None:
        if worker in self._failed:
            return
        self.last_seen[worker] = now

    def failed(self, now: float) -> list:
        out = [w for w, t in self.last_seen.items()
               if w not in self._failed and now - t > self.timeout]
        self._failed.update(out)
        return sorted(self._failed)

    def healthy(self, now: float) -> list:
        self.failed(now)
        return [w for w in self.last_seen if w not in self._failed]


class StragglerDetector:
    def __init__(self, threshold: float = 2.0, patience: int = 3):
        self.threshold = threshold
        self.policy = StrikePolicy(patience)

    @property
    def patience(self) -> int:
        return self.policy.patience

    def observe_step(self, timings: dict) -> list:
        """timings: worker -> step seconds.  Returns persistent stragglers."""
        if len(timings) < 2:
            return []
        med = statistics.median(timings.values())
        out = []
        for w, t in timings.items():
            if t > self.threshold * max(med, 1e-9):
                if self.policy.strike(w):
                    out.append(w)
            else:
                self.policy.clear(w)
        return sorted(out)


class LatencyWatchdog:
    """Stuck-decode watchdog for a single step-time stream (the serving
    engine's decode loop): each observation is compared against the
    rolling median of the last ``window`` steps; ``patience`` consecutive
    spikes (> ``threshold`` x median) trip the same ``StrikePolicy`` the
    cluster straggler detector escalates through.

    ``observe(dt)`` returns True exactly when the streak trips — callers
    count flags / surface them in stats; the watchdog itself never kills
    anything (the engine owns the response ladder)."""

    def __init__(self, threshold: float = 3.0, patience: int = 3,
                 window: int = 32, min_samples: int = 4):
        self.threshold = threshold
        self.policy = StrikePolicy(patience)
        self.min_samples = max(1, min_samples)
        self._times: deque = deque(maxlen=max(self.min_samples, window))

    def observe(self, dt: float) -> bool:
        baseline = (statistics.median(self._times)
                    if len(self._times) >= self.min_samples else None)
        spiked = (baseline is not None
                  and dt > self.threshold * max(baseline, 1e-9))
        if spiked:
            tripped = self.policy.strike("decode")
        else:
            self.policy.clear("decode")
            tripped = False
            # only clean steps feed the baseline — a spike streak must not
            # drag the median up and grant itself amnesty
            self._times.append(dt)
        return tripped


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple
    axis_names: tuple
    dropped_devices: int
    note: str


def plan_elastic_mesh(n_healthy: int, model_parallel: int,
                      pod_size: int | None = None) -> ElasticPlan:
    """Largest usable mesh after failures.

    TP degree (``model_parallel``) is pinned by the sharded weight shapes;
    data parallelism absorbs the loss.  With ``pod_size`` set, whole pods
    are the elastic unit (a failed node sidelines its pod's stragglers to
    the spare pool — the standard multi-pod policy)."""
    if n_healthy < model_parallel:
        raise ValueError(
            f"cannot re-mesh: {n_healthy} devices < TP degree "
            f"{model_parallel}")
    if pod_size:
        pods = n_healthy // pod_size
        if pods >= 2:
            data = pod_size // model_parallel
            used = pods * pod_size
            return ElasticPlan((pods, data, model_parallel),
                               ("pod", "data", "model"),
                               n_healthy - used,
                               f"{pods} full pods, data axis {data}")
        n_healthy = min(n_healthy, pod_size)
    data = n_healthy // model_parallel
    used = data * model_parallel
    return ElasticPlan((data, model_parallel), ("data", "model"),
                       n_healthy - used,
                       f"single pod, data axis shrunk to {data}")
