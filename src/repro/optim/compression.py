"""Gradient compression for the data-parallel all-reduce.

Int8 uniform quantization with error feedback (1-bit-Adam-family trick):
each step transmits q = round(g / scale) in int8 plus one f32 scale per
tensor; the quantization residual is carried locally and added back next
step, so the *accumulated* error is bounded and convergence matches fp32
all-reduce in expectation.

On a real cluster this wraps the DP all-reduce inside ``shard_map`` (reduce
int8 partials, rescale); this module provides the quantizer, the error-
feedback state, and a drop-in grad transform used by the trainer when
``compress_grads=True``.  The unit tests bound the per-step and steady-state
error.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_error_state", "compress_tree", "decompress_tree",
           "ef_compress_grads"]


def _quantize(g: jnp.ndarray):
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray):
    return q.astype(jnp.float32) * scale


def compress_tree(grads):
    return jax.tree.map(lambda g: _quantize(g), grads,
                        is_leaf=lambda x: hasattr(x, "shape"))


def decompress_tree(comp):
    return jax.tree.map(lambda qs: _dequantize(*qs), comp,
                        is_leaf=lambda x: isinstance(x, tuple))


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_grads(grads, error_state):
    """Error-feedback int8 round trip: returns (decompressed_grads,
    new_error_state).  The decompressed value is what the all-reduce would
    deliver; the residual stays local."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = _quantize(corrected)
        deq = _dequantize(q, scale)
        return deq, corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
