"""AdamW with warmup+cosine schedule, global-norm clipping, and optional
fp32 master params (for bf16 model params).  Pure pytree transforms — the
optimizer state shards exactly like the params (ZeRO via the same
PartitionSpecs)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "apply_updates", "lr_at"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_fp32: bool = True


def lr_at(cfg: OptConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(1.0, cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(1.0, cfg.decay_steps - cfg.warmup_steps), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(cfg: OptConfig, params) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {
        "mu": zeros,
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }
    needs_master = cfg.master_fp32 and any(
        p.dtype != jnp.float32 for p in jax.tree.leaves(params))
    if needs_master:
        # jnp.array(copy=True) so f32 leaves do not alias the param buffer
        # (donation would otherwise see the same buffer twice)
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return state


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree))
    return jnp.sqrt(sum(leaves))


def apply_updates(cfg: OptConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    base = state.get("master", params)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        step_dir = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step_dir + cfg.weight_decay * pf)
        return pf, mu, nu

    flat_p, treedef = jax.tree.flatten(base)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_master = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])

    new_params = jax.tree.map(
        lambda m, p: m.astype(p.dtype), new_master, params)
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    if "master" in state:
        new_state["master"] = new_master
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
