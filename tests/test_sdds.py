"""SDDS scheduler: correctness (dataflow == dot product), invariants,
ablation ordering, and hypothesis property tests."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fall back to a seeded random sweep
    from _hypothesis_fallback import given, settings, st

from repro.core.pruning import magnitude_prune
from repro.core.sdds import ESPIMConfig, schedule_matrix

RNG = np.random.default_rng(0)


def _rand_sparse(r, c, sparsity, seed=0):
    rng = np.random.default_rng(seed)
    return magnitude_prune(rng.standard_normal((r, c)), sparsity)


CFGS = {
    "basic": ESPIMConfig(n_banks=4, prefetch=False, reorder=False,
                         balance=False),
    "prefetch": ESPIMConfig(n_banks=4, reorder=False, balance=False),
    "reorder": ESPIMConfig(n_banks=4, balance=False),
    "full": ESPIMConfig(n_banks=4),
    "fullswitch": ESPIMConfig(n_banks=4, full_switch=True),
}


@pytest.mark.parametrize("name", list(CFGS))
def test_sdds_dataflow_matches_dot(name):
    w = _rand_sparse(96, 1024, 0.88, seed=3)
    x = RNG.standard_normal(1024)
    sched, y = schedule_matrix(w, CFGS[name], values=w, x=x, verify=True)
    np.testing.assert_allclose(y, w @ x, rtol=1e-10, atol=1e-10)
    assert sched.mac_ops == sched.nnz  # every nnz fires exactly once


def test_ablation_ordering():
    """Each optimization must not hurt: basic >= prefetch >= reorder >=
    balance(full); full switch is the lower bound (Figure 11)."""
    w = _rand_sparse(176, 2048, 0.9, seed=1)
    slots = {}
    for name, cfg in CFGS.items():
        sched, _ = schedule_matrix(w, cfg)
        slots[name] = sched.compute_slots
    assert slots["basic"] >= slots["prefetch"] >= slots["reorder"]
    assert slots["reorder"] >= slots["full"] * 0.98  # balance helps or ties
    assert slots["fullswitch"] <= slots["full"]
    # "little gap" between simplified and brute-force switch (Section V-B)
    assert slots["full"] <= slots["fullswitch"] * 1.35


def test_broadcasts_bounded_by_slices():
    """Every slice of every vector-row is broadcast at most once per
    stripe: comp_br <= slices/vr * n_stripes * n_vr."""
    w = _rand_sparse(96, 1024, 0.8, seed=2)
    cfg = CFGS["full"]
    sched, _ = schedule_matrix(w, cfg)
    bound = cfg.slices_per_vector_row * sched.n_stripes * sched.vector_rows
    assert sched.comp_br <= bound


def test_fifo_depth_monotonic():
    """Longer FIFOs absorb more irregularity (Figure 12)."""
    w = _rand_sparse(176, 2048, 0.9, seed=4)
    prev = None
    for depth in (2, 4, 8, 16):
        cfg = ESPIMConfig(n_banks=4, fifo_depth=depth)
        sched, _ = schedule_matrix(w, cfg)
        if prev is not None:
            assert sched.compute_slots <= prev * 1.02
        prev = sched.compute_slots


def test_more_banks_fewer_slots():
    """Compute scales with banks (Figure 13)."""
    w = _rand_sparse(256, 1024, 0.9, seed=5)
    s8, _ = schedule_matrix(w, ESPIMConfig(n_banks=8))
    s16, _ = schedule_matrix(w, ESPIMConfig(n_banks=16))
    assert s16.compute_slots < s8.compute_slots


@settings(max_examples=15, deadline=None)
@given(
    r=st.integers(8, 40),
    c=st.integers(32, 600),
    sparsity=st.floats(0.3, 0.95),
    banks=st.sampled_from([2, 4]),
    depth=st.sampled_from([2, 8]),
    prefetch=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_property_schedule_correct(r, c, sparsity, banks, depth, prefetch,
                                   seed):
    """For arbitrary patterns/configs the statically derived schedule must
    execute the exact dot product with every nnz fired exactly once."""
    rng = np.random.default_rng(seed)
    w = magnitude_prune(rng.standard_normal((r, c)), sparsity)
    x = rng.standard_normal(c)
    cfg = ESPIMConfig(n_banks=banks, fifo_depth=depth, prefetch=prefetch)
    sched, y = schedule_matrix(w, cfg, values=w, x=x, verify=True)
    np.testing.assert_allclose(y, w @ x, rtol=1e-9, atol=1e-9)
    assert sched.mac_ops == sched.nnz
    assert sched.comp_nobr >= 0 and sched.comp_br >= 0


def test_empty_and_dense_edge_cases():
    x = RNG.standard_normal(64)
    w0 = np.zeros((8, 64))
    sched, y = schedule_matrix(w0, ESPIMConfig(n_banks=2), values=w0, x=x,
                               verify=True)
    np.testing.assert_allclose(y, 0)
    wd = RNG.standard_normal((8, 64))  # fully dense through the sparse path
    sched, y = schedule_matrix(wd, ESPIMConfig(n_banks=2), values=wd, x=x,
                               verify=True)
    np.testing.assert_allclose(y, wd @ x, rtol=1e-9)
