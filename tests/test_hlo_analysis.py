"""HLO analyzer: trip-count scaling, dot FLOPs, collective accounting —
validated against a hand-computable jitted program."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo, parse_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    hlo = _compile(lambda x, y: x @ y, a, b)
    cost = analyze_hlo(hlo)
    assert cost.dot_flops == 2 * 64 * 128 * 32


def test_while_trip_count_scaling():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def fn(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    cost = analyze_hlo(_compile(fn, a))
    # 10 iterations x one 64^3 matmul each
    assert cost.dot_flops == pytest.approx(10 * 2 * 64 ** 3, rel=0.01)


def test_nested_scan_scaling():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def fn(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    cost = analyze_hlo(_compile(fn, a))
    assert cost.dot_flops == pytest.approx(12 * 2 * 32 ** 3, rel=0.01)


def test_parse_entry_and_params():
    a = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    comps, entry = parse_hlo(_compile(lambda x: x + 1, a))
    assert entry is not None
    ops = {i.op for i in comps[entry]["instrs"].values()}
    assert "parameter" in ops


def test_narrow_source_through_convert():
    """bf16 inputs upcast to f32 by XLA:CPU must charge bf16 streams."""
    a = jax.ShapeDtypeStruct((256, 256), jnp.bfloat16)
    b = jax.ShapeDtypeStruct((256, 256), jnp.bfloat16)
    cost = analyze_hlo(_compile(
        lambda x, y: (x.astype(jnp.float32) @ y.astype(jnp.float32)), a, b))
    # operands charged at bf16 (2B) not f32 (4B): 2 inputs * 128KiB + out
    assert cost.dot_bytes <= 2 * 256 * 256 * 2 + 256 * 256 * 4 + 1024
