"""Telemetry: span tracer invariants, disabled-mode zero-cost, metrics
registry / histogram quantiles, Prometheus exposition, engine coverage."""
import json
import threading

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels import ops
from repro.models import factory
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import (RequestMetrics, Scheduler,
                                   latency_summary, percentiles)
from repro.telemetry import metrics as tm
from repro.telemetry import trace as tt

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------ tracer
def test_nested_span_invariants():
    tr = tt.Tracer(enabled=True)
    with tr.span("outer", cat="a") as outer:
        with tr.span("inner", cat="b") as inner:
            pass
        with tr.span("inner2", cat="b") as inner2:
            pass
    spans = tr.spans()
    assert [s.name for s in spans] == ["inner", "inner2", "outer"]
    assert inner.parent_id == outer.sid
    assert inner2.parent_id == outer.sid
    assert outer.parent_id == 0 and outer.depth == 0
    assert inner.depth == 1
    # children are contained in the parent and ordered, durations >= 0
    assert outer.t0_ns <= inner.t0_ns <= inner.t1_ns <= outer.t1_ns
    assert inner.t1_ns <= inner2.t0_ns
    assert all(s.dur_ns >= 0 for s in spans)


def test_span_out_of_order_close_raises():
    tr = tt.Tracer(enabled=True)
    a = tr.span("a")
    b = tr.span("b")
    a.__enter__()
    b.__enter__()
    with pytest.raises(RuntimeError, match="out of order"):
        a.__exit__(None, None, None)
    # recover: close in order
    b.__exit__(None, None, None)
    a.__exit__(None, None, None)


def test_span_set_and_instant_args():
    tr = tt.Tracer(enabled=True)
    with tr.span("s", cat="c", args={"k": 1}) as sp:
        sp.set("extra", "v")
    tr.instant("mark", cat="fault", args={"slot": 3})
    doc = tr.chrome_trace()
    by_name = {e["name"]: e for e in doc["traceEvents"]}
    assert by_name["s"]["args"] == {"k": 1, "extra": "v"}
    assert by_name["mark"]["ph"] == "i"
    assert by_name["mark"]["args"] == {"slot": 3}


def test_disabled_tracer_allocates_nothing(monkeypatch):
    """The hot-path contract: a disabled tracer constructs zero Span
    objects (counting shim) and hands out one shared null singleton."""
    calls = {"n": 0}
    real_span = tt.Span

    class CountingSpan(real_span):
        def __init__(self, *a, **kw):
            calls["n"] += 1
            super().__init__(*a, **kw)

    monkeypatch.setattr(tt, "Span", CountingSpan)
    tr = tt.Tracer(enabled=False)
    got = [tr.span("hot", cat="x") for _ in range(100)]
    assert calls["n"] == 0
    assert all(g is got[0] for g in got)          # the shared singleton
    assert got[0] is tr.span("other")             # name-independent
    with got[0] as s:
        assert s.set("k", "v") is s               # API parity, still no-op
    tr.instant("nope")
    assert tr.spans() == [] and tr.instants == []
    # enabled tracer DOES construct through the (patched) class
    tr_on = tt.Tracer(enabled=True)
    with tr_on.span("real"):
        pass
    assert calls["n"] == 1


def test_disabled_fence_does_not_sync(monkeypatch):
    """fence() must not touch jax when tracing is off — instrumentation
    cannot change the untraced pipeline's host/device overlap."""
    hit = {"n": 0}
    monkeypatch.setattr(jax, "block_until_ready",
                        lambda x: hit.__setitem__("n", hit["n"] + 1))
    x = object()
    assert tt.NULL_TRACER.fence(x) is x
    assert hit["n"] == 0
    tr = tt.Tracer(enabled=True)
    tr.fence(x)
    assert hit["n"] == 1


def test_tracer_thread_safety():
    tr = tt.Tracer(enabled=True)

    def work(tid):
        for i in range(50):
            with tr.span(f"t{tid}", cat="w"):
                with tr.span(f"t{tid}.child", cat="w"):
                    pass

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = tr.spans()
    assert len(spans) == 4 * 50 * 2
    by_sid = {s.sid: s for s in spans}
    assert len(by_sid) == len(spans)              # globally unique ids
    for s in spans:
        if s.parent_id:
            assert by_sid[s.parent_id].tid == s.tid   # links stay on-thread


def test_chrome_trace_schema_and_validation(tmp_path):
    tr = tt.Tracer(enabled=True)
    with tr.span("a", cat="x"):
        pass
    tr.instant("i1")
    path = tmp_path / "trace.json"
    doc = tr.write_chrome_trace(str(path), provenance={"impl": "ref"})
    tt.validate_chrome_trace(doc)
    on_disk = json.loads(path.read_text())
    assert on_disk["otherData"]["provenance"] == {"impl": "ref"}
    xs = [e for e in on_disk["traceEvents"] if e["ph"] == "X"]
    assert xs and all(e["dur"] >= 0 for e in xs)
    with pytest.raises(ValueError):
        tt.validate_chrome_trace({"traceEvents": []})
    with pytest.raises(ValueError):
        tt.validate_chrome_trace(
            {"traceEvents": [{"name": "n", "ph": "X", "ts": 0.0}]})


def test_jsonl_export_header_first(tmp_path):
    tr = tt.Tracer(enabled=True)
    with tr.span("a", cat="x"):
        pass
    path = tmp_path / "trace.jsonl"
    n = tr.write_jsonl(str(path), provenance={"impl": "ref"})
    lines = [json.loads(x) for x in path.read_text().splitlines()]
    assert lines[0]["type"] == "header"
    assert lines[0]["provenance"] == {"impl": "ref"}
    assert n == len(lines) - 1 == 1
    assert lines[1]["type"] == "span" and lines[1]["name"] == "a"


def test_phase_breakdown_schema():
    tr = tt.Tracer(enabled=True)
    for _ in range(3):
        with tr.span("step", cat="engine"):
            with tr.span("p", cat="prefill"):
                pass
            with tr.span("d", cat="decode"):
                pass
    bd = tt.phase_breakdown(tr, parent="step")
    assert tuple(k for k in tt.BREAKDOWN_SCHEMA_KEYS if k in bd) \
        == tt.BREAKDOWN_SCHEMA_KEYS
    assert set(bd["phases"]) == {"prefill", "decode"}
    assert bd["phases"]["prefill"]["count"] == 3
    assert 0 < bd["coverage"] <= 1.0 + 1e-6
    cov = tt.span_coverage(tr.spans(), "step")
    assert cov["parents"] == 3 and not cov["overlap_errors"]


# ----------------------------------------------------------------- metrics
def test_histogram_bucket_edges():
    h = tm.Histogram("h", {}, edges=(1.0, 10.0, 100.0))
    # exactly-at-edge lands in the bucket whose upper bound it is
    # (bisect_left: counts[i] holds x <= edges[i])
    for x in (0.5, 1.0, 5.0, 10.0, 100.0, 1e9):
        h.observe(x)
    assert h.counts == [2, 2, 1, 1]               # last = +Inf overflow
    assert h.count == 6
    assert h.min == 0.5 and h.max == 1e9
    # quantiles are clamped to observed data, never a synthetic edge
    assert h.quantile(0.0) == 0.5
    assert h.quantile(1.0) == 1e9
    q50 = h.quantile(0.5)
    assert 1.0 <= q50 <= 10.0
    h.reset()
    assert h.count == 0 and h.quantile(0.5) is None


def test_histogram_rejects_bad_input():
    with pytest.raises(ValueError):
        tm.Histogram("h", {}, edges=(10.0, 1.0))
    h = tm.Histogram("h", {})
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        tm.log_buckets(0.0, 1.0, 10)


def test_histogram_quantile_accuracy():
    """Streaming quantile must land within one bucket (~9% for the
    presets) of the exact percentile on a lognormal sample."""
    rng = np.random.default_rng(0)
    xs = np.exp(rng.normal(0.0, 1.5, size=5000))
    h = tm.Histogram("h", {}, edges=tm.LATENCY_BUCKETS_S)
    for x in xs:
        h.observe(float(x))
    for q in (0.5, 0.95):
        exact = float(np.quantile(xs, q))
        est = h.quantile(q)
        assert abs(est - exact) / exact < 0.10, (q, est, exact)


def test_counter_and_gauge():
    r = tm.Registry()
    c = r.counter("c_total")
    c.inc().inc(3)
    assert c.value == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    g = r.gauge("g")
    g.set(0.25)
    assert r.snapshot() == {"c_total": 4, "g": 0.25}


def test_registry_labels_and_identity():
    r = tm.Registry({"model": "m"})
    a = r.counter("tok_total", state="ok")
    b = r.counter("tok_total", state="ok")
    assert a is b                                  # create-once
    c = r.counter("tok_total", state="bad")
    assert c is not a
    with pytest.raises(ValueError):
        r.gauge("tok_total")                       # kind conflict
    a.inc(2)
    c.inc()
    snap = r.snapshot()
    assert snap['tok_total{model="m",state="bad"}'] == 1
    assert snap['tok_total{model="m",state="ok"}'] == 2


def test_prometheus_golden():
    r = tm.Registry({"model": "m"})
    r.counter("req_total", help="requests").inc(3)
    r.gauge("occ").set(0.5)
    h = r.histogram("lat_s", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(7.0)
    golden = "\n".join([
        '# TYPE lat_s histogram',
        'lat_s_bucket{le="0.1",model="m"} 1',
        'lat_s_bucket{le="1",model="m"} 2',
        'lat_s_bucket{le="+Inf",model="m"} 3',
        'lat_s_sum{model="m"} 7.55',
        'lat_s_count{model="m"} 3',
        '# TYPE occ gauge',
        'occ{model="m"} 0.5',
        '# HELP req_total requests',
        '# TYPE req_total counter',
        'req_total{model="m"} 3',
    ]) + "\n"
    assert r.to_prometheus() == golden


def test_snapshot_golden_and_deterministic():
    """``Registry.snapshot()`` is the substrate the flight recorder dumps
    and the bench docs embed: its key ORDER and value shapes are pinned
    here so two registries fed the same instruments — in any insertion
    order — serialize identically (diffable dumps, stable baselines)."""
    def build(order):
        r = tm.Registry({"model": "m"})
        ops = {
            "a": lambda: r.counter("req_total", state="ok").inc(2),
            "b": lambda: r.counter("req_total", state="shed").inc(),
            "c": lambda: r.gauge("occ").set(0.5),
            "d": lambda: [r.histogram("lat_s", buckets=(0.1, 1.0))
                          .observe(v) for v in (0.05, 0.5)],
        }
        for k in order:
            ops[k]()
        return r.snapshot()

    snap = build("abcd")
    golden_keys = [
        'lat_s{model="m"}',
        'occ{model="m"}',
        'req_total{model="m",state="ok"}',
        'req_total{model="m",state="shed"}',
    ]
    assert list(snap) == golden_keys         # sorted names, sorted labels
    assert snap['occ{model="m"}'] == 0.5
    assert snap['req_total{model="m",state="ok"}'] == 2
    hist = snap['lat_s{model="m"}']
    assert hist["count"] == 2 and hist["sum"] == pytest.approx(0.55)
    assert {"min", "max", "mean", "p50", "p95"} <= set(hist)
    # insertion order never leaks into the serialization
    for order in ("dcba", "bdac"):
        assert json.dumps(build(order), sort_keys=False) == \
            json.dumps(snap, sort_keys=False)


def test_validate_snapshot_sparse_gate():
    snap = {f"{name}{{x=\"1\"}}": 0 for name in tm.REQUIRED_SERVE_METRICS}
    tm.validate_snapshot(snap)
    dense = {k: v for k, v in snap.items() if not k.startswith("espim_")}
    tm.validate_snapshot(dense, sparse=False)
    with pytest.raises(AssertionError, match="espim_bytes_per_token"):
        tm.validate_snapshot(dense, sparse=True)


# ---------------------------------------------------------------- profile
def test_time_launch_warmup_discard():
    from repro.telemetry import time_launch
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        return np.zeros(2)

    t = time_launch(fn, iters=4, warmup=2, bytes_moved=1 << 20,
                    dense_bytes=1 << 20, dense_us=100.0)
    assert calls["n"] == 6                        # 2 warmup + 4 timed
    assert t.iters == 4 and t.best_us <= t.p50_us <= t.p95_us
    assert t.gbps_best > 0 and t.roofline_frac > 0
    d = t.to_dict()
    for k in ("best_us", "p50_us", "p95_us", "bytes_moved", "gbps_best",
              "roofline_frac"):
        assert k in d
    with pytest.raises(ValueError):
        time_launch(fn, iters=0)


# -------------------------------------------------- scheduler percentiles
def test_latency_summary_streaming_no_sort(monkeypatch):
    """PR 7 bugfix regression: the engine report path must use the
    histograms' O(buckets) quantiles, never re-sort the sample list."""
    sched = Scheduler()
    for i in range(50):
        m = RequestMetrics(rid=i, prompt_len=4, t_submit=0.0,
                           t_admit=0.001, t_first=0.01 * (i + 1))
        m.n_out = 5
        sched.finish(m)
    # any np.percentile call = full-sort path leaked back in
    import repro.serve.scheduler as sched_mod
    monkeypatch.setattr(
        sched_mod.np, "percentile",
        lambda *a, **k: (_ for _ in ()).throw(
            AssertionError("exact-sort percentile on the streaming path")))
    s = sched.summary()
    assert s["requests"] == 50
    assert s["ttft_s"]["p50"] is not None
    assert s["ttft_s"]["p50"] <= s["ttft_s"]["p95"]
    # the ad-hoc exact path still exists (and still sorts)
    monkeypatch.undo()
    assert percentiles([1.0, 3.0])["p50"] == 2.0
    exact = latency_summary(sched.completed)
    assert abs(exact["ttft_s"]["p50"] - s["ttft_s"]["p50"]) \
        / exact["ttft_s"]["p50"] < 0.10


# ------------------------------------------------------------- provenance
def test_provenance_dataclass_stable():
    p = ops.Provenance.collect(impl="ref", quant="int8", attn="sparse",
                               packs={"g": "abc"})
    d = p.to_dict()
    assert d == ops.provenance(impl="ref", quant="int8", attn="sparse",
                               packs={"g": "abc"})
    assert list(d) == ["backend", "impl", "quant", "attn",
                      "pallas_interpret", "packs", "schedule", "env"]
    json.dumps(d)                                  # JSON-ready
    assert ops.Provenance.collect(impl="ref").packs is None
    # pre-autotune callers keep a null schedule field (schema stability);
    # tuned runs carry the TunedPlan.to_provenance() dict
    assert d["schedule"] is None
    tuned = ops.Provenance.collect(
        impl="ref", schedule={"source": "search", "tuned": True})
    assert tuned.to_dict()["schedule"]["tuned"] is True


# ---------------------------------------------------------- engine traced
def test_engine_step_span_coverage_and_metrics():
    """The acceptance bar: a traced engine run covers >= 95% of every
    engine.step with non-overlapping phase spans, and the metrics
    registry carries every required family."""
    cfg = get_config("granite-3-2b", reduced=True)
    params = factory.init_params(cfg, KEY)
    tr = tt.Tracer(enabled=True)
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, tracer=tr)
    for rid in range(3):
        eng.submit(Request(rid=rid, prompt=[1 + rid, 2, 3, 4],
                           max_new_tokens=5))
    eng.run()
    spans = tr.spans()
    cov = tt.span_coverage(spans, "engine.step")
    assert cov["parents"] > 0
    assert cov["coverage"] >= 0.95, cov
    assert cov["overlap_errors"] == [], cov
    cats = {s.cat for s in spans}
    assert {"engine", "scheduler", "decode", "prefill"} <= cats
    bd = tt.phase_breakdown(tr, parent="engine.step")
    assert bd["coverage"] >= 0.95
    # dense engine: every required family except the espim_* plane stats
    tm.validate_snapshot(eng.metrics.snapshot(), sparse=False)
    # step histograms observed once per non-idle tick
    snap = eng.metrics.snapshot()
    steps = sum(v["count"] for k, v in snap.items()
                if k.startswith("serve_step_seconds"))
    assert steps == eng.stats.prefill_chunks + eng.stats.decode_steps


def test_engine_disabled_tracer_by_default():
    cfg = get_config("granite-3-2b", reduced=True)
    params = factory.init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=48)
    assert not eng.tracer.enabled
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4))
    eng.run()
    assert eng.tracer.spans() == []                # nothing recorded
    # ...but the metrics registry still counted (metrics are always on)
    snap = eng.metrics.snapshot()
    toks = sum(v for k, v in snap.items()
               if k.startswith("serve_tokens_total"))
    assert toks == eng.stats.tokens_generated == 4
