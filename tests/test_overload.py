"""Overload hardening (DESIGN.md §13): bounded-queue shedding, watermark
backpressure, preempt-to-recompute parity, and the overload drill.

The exactness bar matches the rest of the serving tests: a preempted
request's final output is asserted bit-identical to a never-preempted
run (greedy decode over static SDDS packs is replayable), and every
scenario ends with the arena invariant green — overload policy degrades
goodput, never correctness and never the block pool.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fall back to a seeded random sweep
    from _hypothesis_fallback import given, settings, st

from repro.configs.registry import get_config
from repro.models import factory
from repro.core.sparse_model import sparsify_model
from repro.serve import faults
from repro.serve.engine import Request, ServeEngine
from repro.serve.paged_cache import PagedKVCache
from repro.serve.scheduler import (SHED_POLICIES, TERMINAL_STATES,
                                   RequestMetrics, Scheduler)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def llama_sparse():
    cfg = get_config("llama7b-espim", reduced=True)
    params = factory.init_params(cfg, KEY)
    sparse = sparsify_model(cfg, params, 0.9, row_tile=32)
    return cfg, params, sparse


def _req(rid, plen, max_new=6, seed=0):
    rng = np.random.default_rng(seed + rid)
    return Request(rid=rid, prompt=rng.integers(1, 400, plen).tolist(),
                   max_new_tokens=max_new)


def _drain(eng, max_steps=3000):
    steps = 0
    while steps < max_steps and (eng.scheduler.has_pending
                                 or any(s is not None for s in eng.slots)):
        eng.step()
        steps += 1
    assert steps < max_steps, "engine failed to drain"
    return steps


# --------------------------------------------------------------------------
# 1) bounded queue + shed policies (scheduler-level, no model needed)
# --------------------------------------------------------------------------
def test_shed_policy_names_are_closed():
    assert set(SHED_POLICIES) == {"reject", "shed-oldest", "shed-largest"}
    assert "shed" in TERMINAL_STATES
    with pytest.raises(ValueError):
        Scheduler(shed_policy="drop-tail")


def _sched(policy, depth=2):
    shed = []
    s = Scheduler(max_queue_depth=depth, shed_policy=policy)
    s.on_shed = shed.append
    return s, shed


def test_reject_sheds_the_newcomer():
    s, shed = _sched("reject")
    assert s.add(_req(0, 4)) is not None
    assert s.add(_req(1, 4)) is not None
    late = _req(2, 4)
    assert s.add(late) is None
    assert [r.rid for r in shed] == [2] and late.done
    assert [r.rid for r, _ in s.pending] == [0, 1]
    assert s.completed[-1].state == "shed"


def test_shed_oldest_drops_the_queue_head():
    s, shed = _sched("shed-oldest")
    s.add(_req(0, 4)), s.add(_req(1, 4))
    m = s.add(_req(2, 4))
    assert m is not None                      # newcomer got the slot
    assert [r.rid for r in shed] == [0]
    assert [r.rid for r, _ in s.pending] == [1, 2]


def test_shed_largest_drops_biggest_footprint():
    s, shed = _sched("shed-largest")
    s.add(_req(0, 4, max_new=2))
    s.add(_req(1, 12, max_new=20))            # the whale
    assert s.add(_req(2, 4, max_new=2)) is not None
    assert [r.rid for r in shed] == [1]
    # a newcomer bigger than everything queued sheds itself
    assert s.add(_req(3, 30, max_new=30)) is None
    assert [r.rid for r in shed] == [1, 3]


def test_preempted_requests_are_never_shed():
    s, shed = _sched("shed-oldest", depth=1)
    r0, m0 = _req(0, 4), None
    m0 = s.add(r0)
    s.pending.pop()                           # "admit" it
    s.requeue(r0, m0)                         # preempted back to the head
    assert m0.preempts == 1 and m0.t_admit is None
    assert s.add(_req(1, 4)) is None          # r0 is shielded: newcomer sheds
    assert [r.rid for r in shed] == [1]
    assert s.pending[0][0].rid == 0


def test_engine_submit_returns_false_when_shed(llama_sparse):
    cfg, params, sparse = llama_sparse
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=48, sparse=sparse,
                      block_size=8, prefill_chunk=8, validate_arena=True,
                      max_queue_depth=1, shed_policy="reject")
    reqs = [_req(i, 5, max_new=3) for i in range(4)]
    admitted = [eng.submit(r) for r in reqs]
    # slot takes none until step(); queue holds 1; the rest shed
    assert admitted == [True, False, False, False]
    assert eng.stats.requests_shed == 3
    _drain(eng)
    eng.check_arena()
    states = eng.stats.latency_summary()["states"]
    assert states == {"completed": 1, "shed": 3}
    snap = eng.metrics.snapshot()
    assert any(k.startswith("serve_shed_total") and v == 3
               for k, v in snap.items())


# --------------------------------------------------------------------------
# 2) preempt-to-recompute: exact parity with the never-preempted run
# --------------------------------------------------------------------------
def test_preemption_parity_and_counters(llama_sparse):
    cfg, params, sparse = llama_sparse
    long_req = lambda: _req(0, 6, max_new=14, seed=7)
    short_req = lambda: _req(1, 4, max_new=3, seed=7)

    def _eng(**kw):
        return ServeEngine(cfg, params, batch_slots=2, max_len=48,
                           sparse=sparse, block_size=8, prefill_chunk=8,
                           validate_arena=True, **kw)

    # baseline: roomy arena, no pressure, no preemption
    base = _eng()
    b_long, b_short = long_req(), short_req()
    base.submit(b_long), base.submit(b_short)
    _drain(base)
    assert base.stats.preempts == 0

    # tight arena (exactly the long request's worst-case reservation):
    # the resident starves the short arrival -> preempt, recompute,
    # both finish
    worst = long_req().worst_case_tokens(48)
    nb = base.cache.blocks_needed(worst)
    eng = _eng(num_blocks=nb)
    p_long, p_short = long_req(), short_req()
    eng.submit(p_long)
    for _ in range(3):                        # let the long one get going
        eng.step()
    eng.submit(p_short)
    _drain(eng)
    eng.check_arena()
    assert eng.stats.preempts >= 1
    states = eng.stats.latency_summary()["states"]
    assert states.get("completed", 0) == 2
    # the robustness bar: bit-exact vs the never-preempted run
    assert p_long.output == b_long.output
    assert p_short.output == b_short.output
    snap = eng.metrics.snapshot()
    assert any(k.startswith("serve_preempts_total") and v >= 1
               for k, v in snap.items())


def test_watermark_backpressure_pauses_admission(llama_sparse):
    cfg, params, sparse = llama_sparse
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, batch_slots=1, max_len=48, sparse=sparse,
                    watermark_high=0.5, watermark_low=0.6)
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, sparse=sparse,
                      block_size=8, prefill_chunk=8, validate_arena=True,
                      num_blocks=6, watermark_high=0.6, watermark_low=0.2)
    for i in range(3):
        eng.submit(_req(i, 5, max_new=4))
    saw_backpressure = []
    steps = 0
    while steps < 2000 and (eng.scheduler.has_pending
                            or any(s is not None for s in eng.slots)):
        eng.step()
        saw_backpressure.append(eng._backpressure)
        steps += 1
    assert steps < 2000
    eng.check_arena()
    assert any(saw_backpressure), "high watermark never engaged"
    assert not saw_backpressure[-1], "backpressure never released"
    assert eng.stats.latency_summary()["states"] == {"completed": 3}


# --------------------------------------------------------------------------
# 3) cancel() coverage: wait-queue and mid-prefill (satellite)
# --------------------------------------------------------------------------
def test_cancel_queued_request(llama_sparse):
    cfg, params, sparse = llama_sparse
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=48, sparse=sparse,
                      block_size=8, prefill_chunk=8, validate_arena=True)
    r0, r1 = _req(0, 5, max_new=3), _req(1, 5, max_new=3)
    eng.submit(r0), eng.submit(r1)
    eng.step()                                # r0 takes the slot
    assert eng.cancel(1)                      # r1 still queued
    assert r1.done and not eng.scheduler.has_pending
    assert eng.cancel(1) is False             # idempotent: already gone
    _drain(eng)
    eng.check_arena()
    states = {m.rid: m.state for m in eng.scheduler.completed}
    assert states[1] == "cancelled" and states[0] == "completed"
    assert eng.stats.requests_cancelled == 1


def test_cancel_mid_prefill_frees_blocks(llama_sparse):
    cfg, params, sparse = llama_sparse
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=64, sparse=sparse,
                      block_size=8, prefill_chunk=4, validate_arena=True)
    req = _req(0, 14, max_new=3)              # several prefill chunks
    eng.submit(req)
    eng.step()
    st = eng.slots[0]
    assert st is not None and st.phase == "prefill" and st.pos < 14
    assert eng.cancel(0)
    assert eng.slots[0] is None and req.done
    eng.check_arena()                         # partial prefill blocks freed
    assert eng.cache.free_blocks == eng.cache.num_blocks
    assert eng.scheduler.completed[-1].state == "cancelled"
    assert eng.stats.requests_cancelled == 1


# --------------------------------------------------------------------------
# 4) property test: admit/preempt/restore/free interleavings vs the arena
# --------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_arena_accounting_under_random_interleavings(seed):
    """Random admit (reserve+ensure) / preempt (free_slot) / restore
    (re-reserve+re-ensure) / finish (free_slot) sequences keep
    ``arena_check`` green after EVERY op: every physical block in exactly
    one owner, reservations never exceeding the free pool."""
    rng = np.random.default_rng(seed)
    cfg = get_config("granite-3-2b", reduced=True)
    pc = PagedKVCache(cfg, batch_slots=4, max_len=64,
                      block_size=int(rng.choice([4, 8])),
                      num_blocks=int(rng.integers(8, 24)))
    grown = np.zeros(4, int)      # rows each live slot has materialized
    live = [False] * 4
    for _ in range(60):
        slot = int(rng.integers(4))
        op = rng.choice(["admit", "grow", "preempt", "finish", "restore"])
        if op in ("admit", "restore"):
            if not live[slot]:
                worst = int(rng.integers(1, 64))
                if pc.reserve(slot, worst):
                    live[slot] = True
                    grown[slot] = int(rng.integers(1, worst + 1))
                    pc.ensure(slot, grown[slot])
        elif op == "grow" and live[slot]:
            # growth inside the reservation can never fail
            grown[slot] = min(grown[slot] + int(rng.integers(1, 8)),
                              grown[slot] + pc._resv[slot] * pc.block_size)
            pc.ensure(slot, grown[slot])
        elif op in ("preempt", "finish") and live[slot]:
            pc.free_slot(slot)
            live[slot] = False
            grown[slot] = 0
        acct = pc.arena_check()
        assert acct["num_blocks"] == pc.num_blocks


# --------------------------------------------------------------------------
# 5) the overload drill end-to-end (the serve_bench --overload scenario)
# --------------------------------------------------------------------------
def test_overload_drill_sheds_and_preempts_without_oom(llama_sparse):
    cfg, params, sparse = llama_sparse
    drill = faults.run_overload_drill(cfg, params, sparse, seed=0)
    faults.check_overload_drill(drill)
    assert drill["sheds"] >= 1, "2x burst against a bounded queue must shed"
    assert drill["preempts"] >= 1, \
        "tight arena + bimodal mix must exercise preemption"
    assert drill["states"].get("failed", 0) == 0
    assert drill["leaked_blocks"] == 0
    total = sum(drill["states"].values())
    assert total == drill["scale"]["n_requests"], \
        "every submitted request must reach a terminal state"
