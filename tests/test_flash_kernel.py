"""Pallas flash-attention kernel vs the pure-JAX online-softmax oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.models import layers as L

RNG = np.random.default_rng(0)


def _oracle(q, k, v, causal):
    bh, s, hd = q.shape
    return L.flash_attention(
        q.reshape(bh, s, 1, hd), k.reshape(bh, s, 1, hd),
        v.reshape(bh, s, 1, hd), causal=causal, q_chunk=64, kv_chunk=64,
    ).reshape(bh, s, hd)


@pytest.mark.parametrize("s,hd,causal,blk", [
    (256, 64, True, 64), (128, 128, False, 128), (77, 32, True, 32),
    (200, 64, True, 128),
])
def test_flash_pallas_matches_oracle(s, hd, causal, blk):
    q = jnp.asarray(RNG.standard_normal((3, s, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((3, s, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((3, s, hd)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, blk_q=blk,
                                 blk_k=blk)
    want = _oracle(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_pallas_bf16():
    q = jnp.asarray(RNG.standard_normal((2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((2, 128, 64)), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, blk_q=64, blk_k=64)
    want = _oracle(q.astype(jnp.float32), k.astype(jnp.float32),
                   v.astype(jnp.float32), True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=5e-2, atol=5e-2)
