"""Always-on flight recorder (DESIGN.md §14, telemetry/flightrec.py).

The cost contract is pinned here: the disabled path is an allocation-free
early return, the enabled path is one tuple into a preallocated ring that
never grows past capacity, and files are written only by ``trip()`` when
``autodump`` is on and the per-reason cooldown has passed.  The engine
integration test asserts the "always-on" property itself: with the span
tracer disabled, a served request still leaves its full lifecycle in the
ring.
"""
import json
import tracemalloc

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.sparse_model import sparsify_model
from repro.models import factory
from repro.serve.engine import Request, ServeEngine
from repro.telemetry.flightrec import (FlightRecorder, get_recorder,
                                       set_recorder)
from repro.telemetry.metrics import Registry


# --------------------------------------------------------------------------
# ring semantics
# --------------------------------------------------------------------------
def test_capacity_must_be_positive():
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)


def test_ring_bounded_and_oldest_first():
    rec = FlightRecorder(capacity=16)
    for i in range(100):
        rec.record("step", f"ev{i}", {"i": i})
    assert rec.recorded == 100
    assert rec.dropped == 84
    evs = rec.events()
    assert len(evs) == 16
    assert [e["args"]["i"] for e in evs] == list(range(84, 100))
    assert [e["name"] for e in evs][0] == "ev84"
    ts = [e["t_ns"] for e in evs]
    assert ts == sorted(ts)
    rec.clear()
    assert rec.recorded == 0 and rec.events() == []


def test_ring_memory_is_o_capacity():
    """The ring is preallocated and overwritten in place — its identity
    and length never change no matter how many events flow through."""
    rec = FlightRecorder(capacity=32)
    ring = rec._ring
    for i in range(10 * rec.capacity):
        rec.record("step", "ev", {"i": i})
    assert rec._ring is ring and len(rec._ring) == rec.capacity
    assert rec.dropped == 9 * rec.capacity


def test_disabled_recorder_is_inert_and_allocation_free():
    rec = FlightRecorder(capacity=16, enabled=False)
    args = {"rid": 0}               # caller-built payload, reused
    tracemalloc.start()
    for _ in range(1000):
        rec.record("request", "req.queued", args)   # warm the code path
    snap1 = tracemalloc.take_snapshot()
    for _ in range(100_000):
        rec.record("request", "req.queued", args)
    snap2 = tracemalloc.take_snapshot()
    tracemalloc.stop()
    # allocations attributed to flightrec.py across 100k disabled calls
    # must be a constant interpreter residue (<0.01 bytes/call), never
    # O(calls) — the early return touches no heap per event
    mine = [s for s in snap2.compare_to(snap1, "filename")
            if "flightrec" in s.traceback[0].filename]
    leaked = sum(s.size_diff for s in mine)
    assert leaked < 1024, \
        f"disabled record() allocated {leaked} bytes over 100k calls"
    assert rec.recorded == 0 and rec.events() == []
    assert rec.pressure() is False
    assert rec.trip("anything") is None


# --------------------------------------------------------------------------
# dumping: trip() gating, cooldown, file format
# --------------------------------------------------------------------------
def test_dump_file_format(tmp_path):
    rec = FlightRecorder(capacity=8, dump_dir=str(tmp_path))
    reg = Registry()
    reg.counter("serve_quarantines_total").inc(3)
    for i in range(3):
        rec.record("fault", "fault.quarantine", {"rid": i})
    path = rec.dump(reason="quarantine", registry=reg,
                    provenance={"impl": "ref"})
    assert path == f"{tmp_path}/FLIGHT_quarantine.json"
    assert rec.dumps == [path]
    with open(path) as f:
        doc = json.load(f)
    assert doc["flight"] is True and doc["reason"] == "quarantine"
    assert doc["capacity"] == 8 and doc["recorded"] == 3
    assert doc["dropped"] == 0
    assert [e["name"] for e in doc["events"]] == ["fault.quarantine"] * 3
    assert doc["provenance"] == {"impl": "ref"}
    assert any(k.startswith("serve_quarantines_total")
               for k in doc["metrics"])


def test_trip_requires_autodump(tmp_path):
    rec = FlightRecorder(dump_dir=str(tmp_path))   # autodump defaults off
    rec.record("fault", "fault.quarantine", {"rid": 0})
    assert rec.trip("quarantine") is None
    assert list(tmp_path.iterdir()) == [] and rec.dumps == []


def test_trip_cooldown_per_reason(tmp_path):
    rec = FlightRecorder(dump_dir=str(tmp_path), autodump=True,
                         min_dump_interval_s=3600.0)
    rec.record("fault", "fault.quarantine", {"rid": 0})
    first = rec.trip("quarantine")
    assert first is not None
    # a storm of same-reason trips inside the cooldown writes nothing new
    assert all(rec.trip("quarantine") is None for _ in range(5))
    # but a different reason has its own cooldown clock
    assert rec.trip("shed_storm") is not None
    assert len(rec.dumps) == 2


def test_pressure_storm_threshold():
    rec = FlightRecorder(storm_threshold=3, storm_window_s=60.0)
    assert rec.pressure() is False
    assert rec.pressure() is False
    assert rec.pressure() is True          # third mark inside the window
    # stays tripped while the marks remain in the window
    assert rec.pressure() is True


def test_process_default_recorder_swap():
    prev = get_recorder()
    try:
        mine = FlightRecorder(capacity=4)
        assert set_recorder(mine) is prev
        assert get_recorder() is mine
        # reset-to-fresh-default: enabled, autodump off, empty
        fresh = set_recorder(None) and get_recorder()
        assert fresh is not mine and fresh.enabled and not fresh.autodump
    finally:
        set_recorder(prev)


# --------------------------------------------------------------------------
# the always-on property: tracer off, lifecycle still lands in the ring
# --------------------------------------------------------------------------
def test_engine_feeds_ring_with_tracer_disabled():
    cfg = get_config("llama7b-espim", reduced=True)
    params = factory.init_params(cfg, jax.random.PRNGKey(0))
    sparse = sparsify_model(cfg, params, 0.9, row_tile=32)
    rec = FlightRecorder(capacity=512)     # autodump off: no files, ever
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, sparse=sparse,
                      block_size=8, prefill_chunk=8, flight=rec)
    rng = np.random.default_rng(0)
    req = Request(rid=0, prompt=rng.integers(1, 400, 6).tolist(),
                  max_new_tokens=4)
    eng.submit(req)
    steps = 0
    while not req.done:
        eng.step()
        steps += 1
        assert steps < 200
    names = {e["name"] for e in rec.events()}
    assert {"req.queued", "req.admit", "req.first_token", "req.terminal",
            "prefill.chunk", "decode.step"} <= names, names
    terminal = [e for e in rec.events() if e["name"] == "req.terminal"]
    assert terminal[-1]["args"] == {"rid": 0, "state": "completed",
                                    "n_out": 4}
    assert rec.dumps == []                 # always-on never means files
