"""Pallas kernels vs pure-jnp oracles: shape/dtype/chunk sweeps (interpret
mode on CPU executes the kernel bodies)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import pack_ell, pack_ell_chunked
from repro.kernels import ops, ref
from repro.kernels.dense_mv import dense_mv_pallas
from repro.kernels.espim_spmv import (espim_spmv_batched_pallas,
                                      espim_spmv_pallas)

RNG = np.random.default_rng(0)


def _pack(r, c, sparsity, dtype, seed=0, chunk_cols=128):
    rng = np.random.default_rng(seed)
    w = magnitude_prune(rng.standard_normal((r, c)).astype(np.float32),
                        sparsity)
    pack = pack_ell_chunked(w, chunk_cols=chunk_cols)
    return w, (jnp.asarray(pack.values, dtype),
               jnp.asarray(pack.cols, jnp.int32), pack)


@pytest.mark.parametrize("r,c,sparsity", [
    (128, 256, 0.9), (256, 1000, 0.8), (384, 512, 0.5), (128, 128, 0.95),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_espim_spmv_matches_ref(r, c, sparsity, dtype):
    _, (vals, cols, pack) = _pack(r, c, sparsity, dtype)
    x = jnp.asarray(RNG.standard_normal(c), dtype)
    got = espim_spmv_pallas(vals, cols, x, chunk_cols=pack.chunk_cols,
                            block_r=128, block_l=64)
    want = ref.espim_spmv_chunked_ref(vals, cols, x, pack.chunk_cols)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b", [1, 4, 16])
@pytest.mark.parametrize("chunk_cols", [64, 128, 512])
def test_espim_spmv_batched_matches_ref(b, chunk_cols):
    _, (vals, cols, pack) = _pack(128, 300, 0.85, jnp.float32,
                                  chunk_cols=chunk_cols)
    x = jnp.asarray(RNG.standard_normal((300, b)), jnp.float32)
    got = espim_spmv_batched_pallas(vals, cols, x,
                                    chunk_cols=pack.chunk_cols,
                                    block_r=128, block_l=32)
    want = ref.espim_spmv_batched_chunked_ref(vals, cols, x, pack.chunk_cols)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_batched_fused_matches_einsum_oracle():
    """The fused per-chunk accumulate must equal the (materializing) seed
    einsum path run on the equivalent plain ELL pack."""
    rng = np.random.default_rng(3)
    w = magnitude_prune(rng.standard_normal((256, 777)).astype(np.float32),
                        0.85)
    plain = pack_ell(w)
    chunked = pack_ell_chunked(w, chunk_cols=256)
    x = jnp.asarray(rng.standard_normal((777, 8)), jnp.float32)
    old = ref.espim_spmv_batched_ref(
        jnp.asarray(plain.values), jnp.asarray(plain.cols, jnp.int32), x)
    new = ref.espim_spmv_batched_chunked_ref(
        jnp.asarray(chunked.values), jnp.asarray(chunked.cols, jnp.int32),
        x, chunked.chunk_cols)
    # both packs came from the same matrix, so packed rows line up via perm
    y_old = ref.scatter_rows_ref(old, jnp.asarray(plain.perm), plain.n_rows)
    y_new = ref.scatter_rows_ref(new, jnp.asarray(chunked.perm),
                                 chunked.n_rows)
    np.testing.assert_allclose(np.asarray(y_old), np.asarray(y_new),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("r,c", [(128, 128), (200, 333), (384, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dense_mv_matches_ref(r, c, dtype):
    w = jnp.asarray(RNG.standard_normal((r, c)), dtype)
    x = jnp.asarray(RNG.standard_normal(c), dtype)
    got = dense_mv_pallas(w, x, block_r=128, block_c=128)
    want = ref.dense_mv_ref(w, x)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_espim_matvec_end_to_end_vs_dense():
    """Full path: prune -> chunk-pack -> kernel -> unscatter == W_pruned @ x."""
    w, _ = _pack(200, 500, 0.9, jnp.float32, seed=7)
    dev = ops.pack_to_device(pack_ell_chunked(w, chunk_cols=128))
    x = jnp.asarray(RNG.standard_normal(500), jnp.float32)
    for impl in ("ref", "pallas"):
        y = ops.espim_matvec(dev, x, impl=impl)
        np.testing.assert_allclose(np.asarray(y), w @ np.asarray(x),
                                   rtol=2e-4, atol=2e-4)


def test_espim_matvec_batched_end_to_end_vs_dense():
    """Batched decode path through the fused kernel == W_pruned @ X."""
    w, _ = _pack(200, 500, 0.9, jnp.float32, seed=8)
    dev = ops.pack_to_device(pack_ell_chunked(w, chunk_cols=128))
    x = jnp.asarray(RNG.standard_normal((500, 8)), jnp.float32)
    for impl in ("ref", "pallas"):
        y = ops.espim_matvec(dev, x, impl=impl)
        np.testing.assert_allclose(np.asarray(y), w @ np.asarray(x),
                                   rtol=2e-4, atol=2e-4)


def test_plain_ell_requires_ref_impl():
    w, _ = _pack(128, 128, 0.9, jnp.float32)
    pack = pack_ell(w)
    vals = jnp.asarray(pack.values)
    cols = jnp.asarray(pack.cols, jnp.int32)
    x = jnp.asarray(RNG.standard_normal(128), jnp.float32)
    y = ops.espim_spmv(vals, cols, x, impl="ref")
    assert y.shape == (pack.r_pad,)
    with pytest.raises(ValueError, match="column-chunked"):
        ops.espim_spmv(vals, cols, x, impl="pallas")


def test_scatter_rows_ref_pad_rows():
    yp = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    perm = jnp.asarray([2, 0, -1, 1])
    out = ref.scatter_rows_ref(yp, perm, 3)
    np.testing.assert_allclose(np.asarray(out), [2.0, 4.0, 1.0])
