"""Layer-level numerics: flash attention vs naive, RoPE/M-RoPE, SSD
chunked vs recurrence, RWKV shift semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fall back to a seeded random sweep
    from _hypothesis_fallback import given, settings, st

from repro.models import layers as L
from repro.models.mamba import ssd_chunked, ssd_step

RNG = np.random.default_rng(0)


def _naive_attn(q, k, v, causal=True):
    h, kv = q.shape[2], k.shape[2]
    kk, vv = L.repeat_kv(k, h // kv), L.repeat_kv(v, h // kv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(q.shape[-1])
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None, None], s, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)


@settings(max_examples=12, deadline=None)
@given(sq=st.integers(1, 70), h=st.sampled_from([2, 4]),
       kv=st.sampled_from([1, 2]), hd=st.sampled_from([8, 16]),
       qc=st.sampled_from([8, 32]), kc=st.sampled_from([8, 16]),
       causal=st.booleans(), seed=st.integers(0, 99))
def test_flash_attention_property(sq, h, kv, hd, qc, kc, causal, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((2, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, sq, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, sq, kv, hd)), jnp.float32)
    got = L.flash_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    want = _naive_attn(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_cross_lengths():
    q = jnp.asarray(RNG.standard_normal((1, 9, 2, 8)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 33, 2, 8)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 33, 2, 8)), jnp.float32)
    got = L.flash_attention(q, k, v, causal=False, q_chunk=4, kv_chunk=8)
    want = _naive_attn(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_attention_decode_masks_beyond_len():
    b, s, kv, hd = 2, 16, 2, 8
    kc = jnp.asarray(RNG.standard_normal((b, s, kv, hd)), jnp.float32)
    vc = jnp.asarray(RNG.standard_normal((b, s, kv, hd)), jnp.float32)
    q = jnp.asarray(RNG.standard_normal((b, 1, 4, hd)), jnp.float32)
    lens = jnp.asarray([5, 9])
    out = L.attention_decode(q, kc, vc, lens)
    # poisoning cache beyond len must not change the output
    kc2 = kc.at[0, 5:].set(1e3).at[1, 9:].set(-1e3)
    out2 = L.attention_decode(q, kc2, vc, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=1e-6)


def test_rope_relative_shift_invariance():
    """RoPE scores depend only on relative positions."""
    q = jnp.asarray(RNG.standard_normal((1, 4, 2, 16)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 4, 2, 16)), jnp.float32)
    p0 = jnp.arange(4)[None, :]
    q0, k0 = L.apply_rope(q, k, p0)
    q1, k1 = L.apply_rope(q, k, p0 + 37)
    s0 = jnp.einsum("bqhd,bkhd->bhqk", q0, k0)
    s1 = jnp.einsum("bqhd,bkhd->bhqk", q1, k1)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=1e-4, atol=1e-4)


def test_mrope_text_equals_rope():
    """With all three position components equal, M-RoPE must reduce to
    standard RoPE (text tokens in qwen2-vl)."""
    q = jnp.asarray(RNG.standard_normal((1, 6, 2, 128)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 6, 2, 128)), jnp.float32)
    pos = jnp.arange(6)[None, :]
    p3 = jnp.broadcast_to(pos[None], (3, 1, 6))
    qa, ka = L.apply_rope(q, k, pos, theta=1e6)
    qb, kb = L.apply_mrope(q, k, p3, theta=1e6)
    np.testing.assert_allclose(np.asarray(qa), np.asarray(qb), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ka), np.asarray(kb), rtol=1e-5,
                               atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(s=st.integers(3, 50), chunk=st.sampled_from([4, 16, 64]),
       h=st.sampled_from([2, 4]), seed=st.integers(0, 99))
def test_ssd_chunked_equals_recurrence(s, chunk, h, seed):
    rng = np.random.default_rng(seed)
    b, p, g, n = 2, 8, 1, 8
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((b, s, h)),
                                     jnp.float32))
    a = -jnp.exp(jnp.asarray(rng.standard_normal(h) * 0.3, jnp.float32))
    bm = jnp.asarray(rng.standard_normal((b, s, g, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, g, n)), jnp.float32)
    y, final = ssd_chunked(x, dt, a, bm, cm, chunk=chunk)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        yt, state = ssd_step(state, x[:, t], dt[:, t], a, bm[:, t], cm[:, t])
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(ys, 1)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(state),
                               rtol=2e-4, atol=2e-4)


def test_norms():
    x = jnp.asarray(RNG.standard_normal((2, 3, 16)) * 5, jnp.float32)
    w = jnp.ones(16)
    y = L.rms_norm(x, w)
    rms = np.sqrt((np.asarray(y) ** 2).mean(-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
    yl = L.layer_norm(x, w, jnp.zeros(16))
    np.testing.assert_allclose(np.asarray(yl).mean(-1), 0.0, atol=1e-5)
