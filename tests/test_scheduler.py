"""Scheduler: admission policies, prefill/decode interleave, metrics."""
import numpy as np
import pytest

from repro.serve.scheduler import RequestMetrics, Scheduler, percentiles


class _Req:
    def __init__(self, rid, plen):
        self.rid = rid
        self.prompt = list(range(plen))


def test_fcfs_order_and_head_of_line():
    s = Scheduler(policy="fcfs")
    for rid, plen in enumerate([8, 2, 4]):
        s.add(_Req(rid, plen))
    got = [s.pick(lambda r: True)[0].rid for _ in range(3)]
    assert got == [0, 1, 2]
    # a blocked head blocks the queue (its reservation wins as slots drain)
    s.add(_Req(9, 100))
    s.add(_Req(10, 1))
    assert s.pick(lambda r: len(r.prompt) < 50) is None


def test_sjf_picks_shortest_prompt():
    s = Scheduler(policy="sjf")
    for rid, plen in enumerate([8, 2, 4]):
        s.add(_Req(rid, plen))
    got = [s.pick(lambda r: True)[0].rid for _ in range(3)]
    assert got == [1, 2, 0]
    # sjf skips an oversized head and admits a fitting request
    s.add(_Req(9, 100))
    s.add(_Req(10, 1))
    assert s.pick(lambda r: len(r.prompt) < 50)[0].rid == 10


def test_interleave_never_starves_decode():
    s = Scheduler(policy="fcfs", max_prefill_streak=2)
    actions = [s.next_action([0], [1])[0] for _ in range(9)]
    # at most 2 prefill ticks in a row whenever a slot is decode-ready
    assert "decode" in actions
    run = 0
    for a in actions:
        run = run + 1 if a == "prefill" else 0
        assert run <= 2
    # without decode-ready slots, prefill runs back-to-back
    s2 = Scheduler(max_prefill_streak=1)
    assert all(s2.next_action([0], [])[0] == "prefill" for _ in range(5))
    assert s2.next_action([], [])[0] == "idle"


def test_metrics_lifecycle():
    s = Scheduler()
    m = s.add(_Req(0, 4))
    assert m.ttft is None and m.queue_delay is None
    req, m2 = s.pick(lambda r: True)
    assert m2 is m and m.queue_delay >= 0
    m.t_first = m.t_admit + 0.5
    m.n_out = 3
    s.finish(m)
    assert m.ttft >= 0.5 and m.tpot is not None
    summ = s.summary()
    assert summ["requests"] == 1
    assert summ["ttft_s"]["p50"] is not None


def test_percentiles_empty_and_filtering():
    assert percentiles([])["p50"] is None
    got = percentiles([None, 1.0, 3.0])
    assert got["p50"] == 2.0


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        Scheduler(policy="lifo")
