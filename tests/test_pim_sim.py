"""Cycle simulator + energy/area: the paper's quantitative anchors."""
import numpy as np
import pytest

from repro.core.energy import (area_table, espim_energy, gpu_dram_energy,
                               newton_energy)
from repro.core.pim_sim import simulate_matrix
from repro.core.pruning import magnitude_prune
from repro.core.sdds import ESPIMConfig

RNG = np.random.default_rng(0)
DENSE = RNG.standard_normal((512, 1024))


def _sim(sparsity, **kw):
    w = magnitude_prune(DENSE, sparsity)
    return simulate_matrix(w, ESPIMConfig(), **kw), w


def test_espim_beats_newton_at_high_sparsity():
    reps, _ = _sim(0.9)
    # speedup_over(other) = other.cycles / self.cycles: > 1 == espim faster
    assert reps["espim"].speedup_over(reps["newton"]) > 1
    ratio = reps["newton"].cycles / reps["espim"].cycles
    assert 2.0 < ratio < 6.9  # bounded by the 11/16*10 ceiling


def test_speedup_grows_with_sparsity():
    prev = 0.0
    for s in (0.5, 0.7, 0.9):
        reps, _ = _sim(s)
        ratio = reps["newton"].cycles / reps["espim"].cycles
        assert ratio > prev
        prev = ratio


def test_newton_insensitive_to_sparsity():
    r1, _ = _sim(0.5)
    r2, _ = _sim(0.9)
    assert r1["newton"].cycles == r2["newton"].cycles


def test_ideal_nonpim_catches_newton_at_high_sparsity():
    """Figure 10: pin-bound ideal crosses Newton as sparsity rises."""
    lo, _ = _sim(0.5)
    hi, _ = _sim(0.9)
    assert lo["ideal_nonpim"].cycles > lo["newton"].cycles
    assert hi["ideal_nonpim"].cycles < hi["newton"].cycles


def test_espim_ideal_is_lower_bound():
    reps, _ = _sim(0.8, archs=("espim", "espim_ideal", "newton"))
    assert reps["espim_ideal"].cycles <= reps["espim"].cycles


def test_spacea_worse_than_newton_at_low_sparsity():
    reps, _ = _sim(0.5)
    assert reps["spacea"].cycles > reps["newton"].cycles
    reps, _ = _sim(0.9)
    assert reps["spacea"].cycles < reps["newton"].cycles  # improves


def test_energy_savings_anchor():
    """Section V-E: ESPIM saves energy vs Newton, more at higher sparsity,
    up to ~63%; at 50% the saving is small."""
    savings = []
    for s in (0.5, 0.9):
        reps, w = _sim(s)
        base = gpu_dram_energy(*w.shape).total
        en = newton_energy(w.shape[0], w.shape[1], int((w != 0).sum()))
        ee = espim_energy(reps["espim"].schedule)
        savings.append(1 - ee.total / en.total)
    assert savings[0] < 0.2          # modest at 50%
    assert 0.45 < savings[1] < 0.75  # large at 90%
    # "rest" (FIFOs+switch) must be a visible but minor component
    reps, w = _sim(0.5)
    ee = espim_energy(reps["espim"].schedule)
    assert 0 < ee.rest < 0.25 * ee.total


def test_area_table_matches_paper():
    """Table IV: sparse-only ~30.8%, flexible ~39.7%, Newton 25%."""
    t = area_table()
    assert t["newton"]["total"] == pytest.approx(0.25, rel=0.01)
    assert t["espim_sparse_only"]["total"] == pytest.approx(0.308, abs=0.02)
    assert t["espim_flexible"]["total"] == pytest.approx(0.397, abs=0.02)
    # under 5% over Newton for sparse-only (the headline claim)
    assert t["espim_over_newton_sparse_only"] < 0.07


def test_area_scales_with_fifo_depth():
    small = area_table(ESPIMConfig(fifo_depth=4))
    big = area_table(ESPIMConfig(fifo_depth=16))
    assert (big["espim_sparse_only"]["total"]
            > small["espim_sparse_only"]["total"])
