"""Per-request timeline reconstruction (DESIGN.md §14, telemetry/timeline.py).

The contract under test: a traced serve run yields a complete lifecycle
(queued -> prefill -> decode -> terminal) for 100% of terminal requests,
the segments partition each request's wall clock exactly, and the
timeline's TTFT/TPOT agree with the engine's own ``RequestMetrics``
within tolerance — including requests that were preempted-and-resumed,
snapshot-restored into a fresh engine, or quarantined to the dense
fallback mid-decode.  All six terminal states must be representable.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.sparse_model import sparsify_model
from repro.models import factory
from repro.serve import faults
from repro.serve import snapshot as snapmod
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import Scheduler
from repro.telemetry.flightrec import FlightRecorder
from repro.telemetry.timeline import (build_timelines, check_timelines,
                                      format_timeline, timelines_from_chrome,
                                      timelines_from_jsonl,
                                      timelines_from_tracer)
from repro.telemetry.trace import Tracer

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def llama_sparse():
    cfg = get_config("llama7b-espim", reduced=True)
    params = factory.init_params(cfg, KEY)
    sparse = sparsify_model(cfg, params, 0.9, row_tile=32)
    return cfg, params, sparse


def _eng(llama_sparse, tracer, **kw):
    cfg, params, sparse = llama_sparse
    kw.setdefault("max_len", 48)
    return ServeEngine(cfg, params, batch_slots=2, sparse=sparse,
                       block_size=8, prefill_chunk=8, validate_arena=True,
                       tracer=tracer, flight=FlightRecorder(enabled=False),
                       **kw)


def _reqs(n=3, max_new=5, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(1, 400, 4 + 2 * i).tolist(),
                    max_new_tokens=max_new) for i in range(n)]


def _drain(eng, reqs, max_steps=2000):
    steps = 0
    while not all(r.done for r in reqs):
        eng.step()
        steps += 1
        assert steps < max_steps, "engine did not drain"


# --------------------------------------------------------------------------
# the headline contract: complete timelines, exact partition, engine parity
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def traced_run(llama_sparse):
    tracer = Tracer(enabled=True)
    eng = _eng(llama_sparse, tracer)
    reqs = _reqs(3)
    for r in reqs:
        eng.submit(r)
    _drain(eng, reqs)
    return tracer, eng


def test_traced_run_timelines_complete_and_match_engine(traced_run):
    tracer, eng = traced_run
    tls = timelines_from_tracer(tracer)
    report = check_timelines(
        tls, {m.rid: m for m in eng.scheduler.completed})
    assert report["requests"] == 3
    assert report["complete"] == report["requests"]
    assert report["states"] == {"completed": 3}
    # check_timelines already asserts agreement; pin the headline numbers
    assert report["max_ttft_err_s"] <= 0.05
    assert report["max_tpot_err_s"] <= 0.05
    for t in tls.values():
        kinds = t.by_kind()
        assert "prefill" in kinds and "decode" in kinds, kinds
        # the partition property, re-asserted directly
        assert abs(t.segment_sum_s() - t.wall_s) < 1e-6
        # the lifecycle events arrive in causal order
        names = [n for _, n, _ in t.events]
        assert names[0] == "req.queued" and names[-1] == "req.terminal"
        assert names.index("req.admit") < names.index("req.first_token")


def test_format_timeline_renders_strip(traced_run):
    tracer, _ = traced_run
    t = timelines_from_tracer(tracer)[0]
    txt = format_timeline(t)
    assert txt.startswith("rid 0: completed")
    assert "ttft" in txt and "[" in txt
    bar = txt.splitlines()[1].strip("[] ")
    assert set(bar) <= set("qpd.") and bar, bar


def test_chrome_and_jsonl_roundtrip_match_live_tracer(traced_run, tmp_path):
    """The same timelines must reconstruct from the exported artifacts —
    a post-mortem never needs the process that wrote the trace."""
    tracer, _ = traced_run
    live = timelines_from_tracer(tracer)
    chrome_path, jsonl_path = tmp_path / "t.json", tmp_path / "t.jsonl"
    tracer.write_chrome_trace(str(chrome_path))
    tracer.write_jsonl(str(jsonl_path))
    with open(chrome_path) as f:
        from_chrome = timelines_from_chrome(json.load(f))
    from_jsonl = timelines_from_jsonl(str(jsonl_path))
    for tls, tol in ((from_chrome, 2e-6), (from_jsonl, 1e-12)):
        assert set(tls) == set(live)
        for rid, t in tls.items():
            ref = live[rid]
            assert t.complete and t.state == ref.state
            assert t.n_out == ref.n_out
            assert [s.kind for s in t.segments] == \
                [s.kind for s in ref.segments]
            # chrome rounds to whole microseconds; jsonl is exact
            assert abs(t.ttft_s - ref.ttft_s) <= tol
            assert abs(t.wall_s - ref.wall_s) <= tol


# --------------------------------------------------------------------------
# fault-path lifecycles: preempt/resume, snapshot/restore, quarantine
# --------------------------------------------------------------------------
def test_preempted_and_resumed_request_timeline(llama_sparse):
    """A preempted request's timeline records the preemption (requeue +
    residency flip back to queued) and still reconstructs complete, with
    TTFT/TPOT agreeing with the engine across the preemption."""
    cfg, params, sparse = llama_sparse

    def long_req():
        return Request(rid=0, prompt=list(range(1, 7)), max_new_tokens=14)

    base = _eng(llama_sparse, Tracer(enabled=False))
    worst = long_req().worst_case_tokens(48)
    nb = base.cache.blocks_needed(worst)

    tracer = Tracer(enabled=True)
    eng = _eng(llama_sparse, tracer, num_blocks=nb)
    long = long_req()
    eng.submit(long)
    for _ in range(3):
        eng.step()
    short = Request(rid=1, prompt=[5, 6, 7, 8], max_new_tokens=3)
    eng.submit(short)
    _drain(eng, [long, short])
    assert eng.stats.preempts >= 1

    tls = timelines_from_tracer(tracer)
    check_timelines(tls, {m.rid: m for m in eng.scheduler.completed})
    t = tls[0]
    assert t.state == "completed"
    assert t.preempts == eng.stats.preempts
    names = [n for _, n, _ in t.events]
    assert "fault.preempt" in names and "req.requeue" in names
    # preempted -> readmitted: two admit marks, the second flagged resumed
    admits = [a for _, n, a in t.events if n == "req.admit"]
    assert len(admits) >= 2 and admits[-1]["resumed"]
    # the post-preemption queued stretch shows up as a queued segment
    # strictly after the first admission
    kinds = [s.kind for s in t.segments]
    assert "queued" in kinds[kinds.index("prefill"):], kinds


def test_snapshot_restored_request_timeline(llama_sparse):
    """Kill an engine mid-flight, restore the snapshot into a fresh one
    sharing the tracer: the restored rids get a second ``req.queued``
    (restored=True) and finish with complete timelines."""
    tracer = Tracer(enabled=True)
    eng = _eng(llama_sparse, tracer)
    reqs = _reqs(2, max_new=4, seed=1)
    for r in reqs:
        eng.submit(r)
    for _ in range(4):
        eng.step()
    snap = eng.snapshot()
    del eng

    eng2 = _eng(llama_sparse, tracer)
    restored = snapmod.restore_engine(eng2, snap)
    _drain(eng2, restored)

    tls = timelines_from_tracer(tracer)
    check_timelines(tls)
    assert set(tls) == {0, 1}
    for t in tls.values():
        assert t.state == "completed" and t.complete
        queued = [a for _, n, a in t.events if n == "req.queued"]
        assert any(a.get("restored") for a in queued), t.events
        assert any(n == "fault.restore" for _, n, _ in t.events)


def test_quarantined_then_degraded_request_timeline(llama_sparse):
    """A poisoned decode step quarantines the pack mid-request; the
    affected requests finish ``degraded`` and their timelines count the
    quarantine and stay complete."""
    cfg, params, sparse = llama_sparse
    tracer = Tracer(enabled=True)
    eng = _eng(llama_sparse, tracer, max_len=64)
    reqs = _reqs(3, max_new=6, seed=0)
    for r in reqs:
        eng.submit(r)
    steps = 0
    while not all(r.done for r in reqs):
        if steps == 5:
            faults.inject_poisoned_decode(
                eng, faults.poison_values(sparse, np.random.default_rng(2)))
        eng.step()
        steps += 1
        assert steps < 2000
    assert eng.stats.quarantines >= 1 and eng.stats.requests_degraded >= 1

    tls = timelines_from_tracer(tracer)
    report = check_timelines(
        tls, {m.rid: m for m in eng.scheduler.completed})
    assert report["complete"] == report["requests"] == 3
    assert report["states"].get("degraded", 0) >= 1
    degraded = [t for t in tls.values() if t.state == "degraded"]
    assert any(t.quarantines >= 1 for t in degraded)
    for t in degraded:
        assert t.t_first_ns is not None     # output WAS delivered
        assert any(n == "fault.quarantine" for _, n, _ in t.events)


# --------------------------------------------------------------------------
# every terminal state is representable (scheduler-level: no model needed)
# --------------------------------------------------------------------------
class _Req:
    def __init__(self, rid, plen, **kw):
        self.rid = rid
        self.prompt = list(range(plen))
        self.done = False
        for k, v in kw.items():
            setattr(self, k, v)


def test_all_terminal_states_reconstruct(monkeypatch):
    """shed / cancelled / deadline_expired / failed lifecycles never emit
    a first token yet must still reconstruct as complete timelines (the
    'all terminal states representable' acceptance bullet; completed and
    degraded are covered by the engine tests above)."""
    tracer = Tracer(enabled=True)
    s = Scheduler(max_queue_depth=1, shed_policy="reject", tracer=tracer,
                  flight=FlightRecorder(enabled=False))
    s.add(_Req(0, 4))                      # fills the queue
    assert s.add(_Req(1, 4)) is None       # -> shed at the door
    assert s.cancel_pending(0)             # -> cancelled
    m = s.add(_Req(2, 4, deadline_s=0.0))
    assert m is not None
    assert s.expire_pending(m.t_submit + 1.0) == [2]   # -> deadline_expired
    m3 = s.add(_Req(3, 4))
    s.finish(m3, "failed")                 # the teardown choke point

    tls = timelines_from_tracer(tracer)
    report = check_timelines(tls)
    assert report["requests"] == report["complete"] == 4
    assert report["states"] == {"shed": 1, "cancelled": 1,
                                "deadline_expired": 1, "failed": 1}
    for t in tls.values():
        assert t.t_first_ns is None and t.complete
        assert t.segments and t.segments[0].kind == "queued"


def test_build_timelines_partial_trace_stays_incomplete():
    """A killed engine's in-flight requests reconstruct as incomplete —
    never misreported as terminal."""
    events = [
        {"type": "instant", "name": "req.queued", "cat": "request",
         "t_ns": 1000, "args": {"rid": 7, "prompt_len": 4}},
        {"type": "instant", "name": "req.admit", "cat": "request",
         "t_ns": 2000, "args": {"rid": 7, "slot": 0, "resumed": False}},
        {"type": "span", "name": "prefill.chunk", "cat": "prefill",
         "t0_ns": 2100, "t1_ns": 3000, "args": {"rid": 7, "slot": 0}},
    ]
    t = build_timelines(events)[7]
    assert not t.complete and t.state is None
    assert [s.kind for s in t.segments] == ["queued", "wait", "prefill"]
    with pytest.raises(AssertionError):
        check_timelines({7: t})


def test_duplicate_marks_first_queued_last_terminal_win():
    """Crash-drill traces carry the same rid twice (pre-kill + restored
    run): the first queued and the last terminal define the lifecycle."""
    events = [
        {"type": "instant", "name": "req.queued", "cat": "request",
         "t_ns": 1000, "args": {"rid": 0, "prompt_len": 4}},
        {"type": "instant", "name": "req.terminal", "cat": "request",
         "t_ns": 5000, "args": {"rid": 0, "state": "failed", "n_out": 0}},
        {"type": "instant", "name": "req.queued", "cat": "request",
         "t_ns": 6000, "args": {"rid": 0, "prompt_len": 4,
                                "restored": True}},
        {"type": "instant", "name": "req.first_token", "cat": "request",
         "t_ns": 7000, "args": {"rid": 0, "slot": 0}},
        {"type": "instant", "name": "req.terminal", "cat": "request",
         "t_ns": 9000, "args": {"rid": 0, "state": "completed",
                                "n_out": 3}},
    ]
    t = build_timelines(events)[0]
    assert t.t_queued_ns == 1000 and t.t_terminal_ns == 9000
    assert t.state == "completed" and t.n_out == 3
    assert t.wall_s == pytest.approx(8e-6)
    assert t.segment_sum_s() == pytest.approx(t.wall_s)
