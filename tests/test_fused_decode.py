"""Parity tests for every step of the fused sparse decode stack (PR 3):
scan vs Python loop, fused gate+up vs separate SpMVs, perm-folded output
vs scatter, vectorized vs looped kernel gather, and the width-bucketed
pack round-trip + padding guarantees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fall back to a seeded random sweep
    from _hypothesis_fallback import given, settings, st

from repro.configs.registry import get_config
from repro.core import sparse_model as SM
from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import (bucketed_stack_to_dense,
                                      pack_bucketed_stack, pack_ell_chunked)
from repro.core.sparse_model import (decode_step_sparse, prefill_chunk_sparse,
                                     sparse_stats, sparsify_mlps)
from repro.kernels import ops, ref
from repro.kernels.espim_spmv import espim_spmv_batched_pallas
from repro.models import factory

KEY = jax.random.PRNGKey(0)


def _setup(arch="llama7b-espim", sparsity=0.9, **kw):
    cfg = get_config(arch, reduced=True)
    params = factory.init_params(cfg, KEY)
    sparse = sparsify_mlps(cfg, params, sparsity, **kw)
    return cfg, params, sparse


# --------------------------------------------------------------------------
# 1) scanned layer loop == Python loop (fp32-accumulation tolerance)
# --------------------------------------------------------------------------
def test_scanned_decode_matches_python_loop():
    cfg, params, sparse = _setup()
    B, S = 2, 5
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    cache_s = factory.init_cache(cfg, B, S + 2)
    cache_u = factory.init_cache(cfg, B, S + 2)
    scan_fn = jax.jit(lambda p, c, b: decode_step_sparse(cfg, p, sparse,
                                                         c, b))
    loop_fn = jax.jit(lambda p, c, b: decode_step_sparse(cfg, p, sparse,
                                                         c, b, unroll=True))
    for i in range(S):
        batch = {"tokens": toks[:, i:i + 1]}
        lg_s, cache_s = scan_fn(params, cache_s, batch)
        lg_u, cache_u = loop_fn(params, cache_u, batch)
        np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_u),
                                   rtol=1e-5, atol=1e-5)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                rtol=1e-5, atol=1e-5),
        cache_s, cache_u)


def test_scanned_prefill_matches_python_loop():
    cfg, params, sparse = _setup()
    toks = jax.random.randint(KEY, (1, 6), 0, cfg.vocab_size)
    batch = {"tokens": toks, "n_valid": jnp.asarray([6], jnp.int32)}
    cache_s = factory.init_cache(cfg, 1, 8)
    cache_u = factory.init_cache(cfg, 1, 8)
    lg_s, _ = prefill_chunk_sparse(cfg, params, sparse, cache_s, batch,
                                   proj_path="kernel")
    lg_u, _ = prefill_chunk_sparse(cfg, params, sparse, cache_u, batch,
                                   proj_path="kernel", unroll=True)
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_u),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# 2) fused gate+up == two separate SpMV calls on per-projection packs
# --------------------------------------------------------------------------
def test_fused_gateup_matches_separate_spmv():
    cfg, params, sparse = _setup(row_tile=32)
    gu = sparse["gateup"]
    l = 1
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((cfg.d_model, 5)), jnp.float32)

    # fused: one SpMV per bucket, halves split in packed order, then
    # mapped back to logical rows for the comparison
    packed = []
    for b, rg in zip(gu["buckets"], gu["bucket_rows"]):
        yp = ops.espim_spmv_batched(b["values"][l], b["cols"][l], x,
                                    chunk_cols=gu["chunk_cols"], impl="ref")
        packed.append((yp[:rg], yp[rg:]))
    gate_p = jnp.concatenate([g for g, _ in packed], axis=0)
    up_p = jnp.concatenate([u for _, u in packed], axis=0)
    inv = gu["inv_perm"][l]
    fused_gate = jnp.take(gate_p, inv, axis=0)
    fused_up = jnp.take(up_p, inv, axis=0)

    # separate: each projection packed on its own, two kernel launches
    for name, got in (("w_gate", fused_gate), ("w_up", fused_up)):
        w = np.asarray(sparse[f"{name}_pruned"][l], np.float32).T
        pack = pack_ell_chunked(w, chunk_cols=ops.DEFAULT_CHUNK_COLS)
        yp = ops.espim_spmv_batched(jnp.asarray(pack.values),
                                    jnp.asarray(pack.cols, jnp.int32), x,
                                    chunk_cols=pack.chunk_cols, impl="ref")
        want = ref.scatter_rows_ref(yp, jnp.asarray(pack.perm), pack.n_rows)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(got), w @ np.asarray(x),
                                   rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# 3) perm folded into the pack == runtime scatter_rows_ref
# --------------------------------------------------------------------------
def test_perm_folded_output_matches_scatter():
    cfg, params, sparse = _setup()
    dn = sparse["down"]
    l = 2
    rng = np.random.default_rng(5)
    yd = jnp.asarray(rng.standard_normal((dn["r_pad"], 4)), jnp.float32)
    folded = jnp.take(yd, dn["inv_perm"][l], axis=0)
    scattered = ref.scatter_rows_ref(yd, dn["perm"][l], dn["n_rows"])
    np.testing.assert_array_equal(np.asarray(folded), np.asarray(scattered))


def test_down_cols_precomposed_with_gateup_order():
    """End-to-end perm folding: the fused MLP (no scatter anywhere) must
    equal the dense pruned MLP."""
    cfg, params, sparse = _setup(row_tile=32)
    rng = np.random.default_rng(7)
    hn = jnp.asarray(rng.standard_normal((2, 3, cfg.d_model)), jnp.float32)
    bufs = jax.tree.map(lambda x: x[0], SM._scan_bufs(sparse))
    got = SM._fused_mlp(cfg, sparse, bufs, hn, "ref")
    want = SM._pruned_mlp(
        cfg, sparse,
        {n: sparse[f"{n}_pruned"][0] for n in ("w_gate", "w_up", "w_down")},
        hn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# 4) vectorized block gather == old fori_loop kernel
# --------------------------------------------------------------------------
@pytest.mark.parametrize("b,chunk_cols", [(1, 128), (8, 64), (16, 512)])
def test_vectorized_gather_matches_loop_kernel(b, chunk_cols):
    rng = np.random.default_rng(11)
    w = magnitude_prune(rng.standard_normal((128, 300)).astype(np.float32),
                        0.85)
    pack = pack_ell_chunked(w, chunk_cols=chunk_cols)
    vals = jnp.asarray(pack.values)
    cols = jnp.asarray(pack.cols, jnp.int32)
    x = jnp.asarray(rng.standard_normal((300, b)), jnp.float32)
    block = espim_spmv_batched_pallas(vals, cols, x,
                                      chunk_cols=pack.chunk_cols,
                                      block_r=128, block_l=32,
                                      gather="block")
    loop = espim_spmv_batched_pallas(vals, cols, x,
                                     chunk_cols=pack.chunk_cols,
                                     block_r=128, block_l=32, gather="loop")
    want = ref.espim_spmv_batched_chunked_ref(vals, cols, x, pack.chunk_cols)
    np.testing.assert_allclose(np.asarray(block), np.asarray(loop),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(block), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# 5) width-bucketed pack: round-trip property + padding guarantees
# --------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(r=st.integers(2, 120), c=st.integers(1, 150), s=st.floats(0.0, 0.95),
       halves=st.integers(1, 2), layers=st.integers(1, 3),
       n_buckets=st.integers(1, 4), seed=st.integers(0, 999))
def test_bucketed_stack_roundtrip_property(r, c, s, halves, layers,
                                           n_buckets, seed):
    rng = np.random.default_rng(seed)
    mats = [[magnitude_prune(
        rng.standard_normal((r, c)).astype(np.float32), s)
        for _ in range(layers)] for _ in range(halves)]
    pack = pack_bucketed_stack(mats, row_tile=32, chunk_cols=64,
                               n_buckets=n_buckets)
    for l in range(layers):
        for h in range(halves):
            np.testing.assert_allclose(
                bucketed_stack_to_dense(pack, l, h), mats[h][l])
    assert sum(pack.bucket_rows) == pack.r_pad
    assert pack.nnz == sum(int((m != 0).sum()) for hh in mats for m in hh)
    # bucketing never pads worse than the single global width
    assert pack.plan.padded_slots <= pack.plan.single_bucket_slots


def test_bucketed_pad_frac_llama7b_shape():
    """Acceptance: on the full LLaMA-7B projection shape at the paper's
    90% sparsity, width bucketing brings pad_frac from the global-width
    ~15% to <= 8%."""
    rng = np.random.default_rng(0)
    w = magnitude_prune(rng.standard_normal((4096, 4096)).astype(np.float32),
                        0.9)
    pack = pack_bucketed_stack([[w]], row_tile=128, chunk_cols=4096,
                               n_buckets=4)
    single = 1 - pack.nnz / (pack.plan.single_bucket_slots * pack.n_chunks)
    assert single > 0.10          # the global-width layout wastes ~15%
    assert pack.pad_frac <= 0.08  # bucketing recovers it
    assert pack.pad_frac < single


def test_sparse_stats_reports_per_layer_and_per_projection():
    cfg, params, sparse = _setup(row_tile=32)
    stats = sparse_stats(sparse)
    for name in ("w_gate", "w_up", "w_down", "gateup", "down", "total"):
        assert name in stats, name
    for proj in ("gateup", "down"):
        per_layer = stats[proj]["pad_frac_per_layer"]
        assert len(per_layer) == cfg.n_layers
        assert stats[proj]["pad_frac"] <= (
            stats[proj]["single_bucket_pad_frac"] + 1e-9)


def test_non_gated_mlp_decode_matches_pruned_dense():
    """halves == 1 (nemotron: no gate projection, squared-ReLU)."""
    cfg, params, sparse = _setup(arch="nemotron-4-15b", sparsity=0.85)
    assert not sparse["gated"]
    pruned = jax.tree.map(lambda x: x, params)
    for name in ("w_up", "w_down"):
        pruned["layers"]["mlp"][name] = sparse[f"{name}_pruned"]
    toks = jax.random.randint(KEY, (2, 1), 0, cfg.vocab_size)
    cache_d = factory.init_cache(cfg, 2, 4)
    cache_s = factory.init_cache(cfg, 2, 4)
    lg_d, _ = factory.decode_step(cfg, pruned, cache_d, {"tokens": toks})
    lg_s, _ = decode_step_sparse(cfg, params, sparse, cache_s,
                                 {"tokens": toks})
    err = float(jnp.abs(lg_d - lg_s).max() / jnp.abs(lg_d).max())
    assert err < 5e-4, err


# --------------------------------------------------------------------------
# 6) prefill datapath flexibility (Section III-I): GEMM path == MV path
# --------------------------------------------------------------------------
def test_prefill_dense_path_matches_kernel_path():
    cfg, params, sparse = _setup()
    toks = jax.random.randint(KEY, (2, 4), 0, cfg.vocab_size)
    batch = {"tokens": toks, "n_valid": jnp.asarray([4, 4], jnp.int32)}
    cache_d = factory.init_cache(cfg, 2, 6)
    cache_k = factory.init_cache(cfg, 2, 6)
    lg_d, _ = prefill_chunk_sparse(cfg, params, sparse, cache_d, batch,
                                   proj_path="dense")
    lg_k, _ = prefill_chunk_sparse(cfg, params, sparse, cache_k, batch,
                                   proj_path="kernel")
    err = float(jnp.abs(lg_d - lg_k).max() / jnp.abs(lg_d).max())
    assert err < 5e-5, err


# --------------------------------------------------------------------------
# 7) env overrides for the dispatch (ESPIM_IMPL / ESPIM_FORCE_INTERPRET)
# --------------------------------------------------------------------------
def test_env_impl_override(monkeypatch):
    monkeypatch.delenv(ops.ENV_IMPL, raising=False)
    assert ops.provenance()["impl"] == "pallas"
    assert ops.provenance(impl="ref")["impl"] == "ref"
    monkeypatch.setenv(ops.ENV_IMPL, "ref")
    # the env pin wins over per-call arguments — that is its purpose
    assert ops.provenance(impl="pallas")["impl"] == "ref"

    # a plain (2-D) ELL pack rejects impl="pallas"; with the env pinned to
    # "ref" the same call must dispatch to the reference instead of raising
    rng = np.random.default_rng(1)
    w = magnitude_prune(rng.standard_normal((32, 64)).astype(np.float32),
                        0.8)
    from repro.core.sparse_format import pack_ell
    pack = pack_ell(w, row_tile=8)
    vals = jnp.asarray(pack.values)
    cols = jnp.asarray(pack.cols, jnp.int32)
    x = jnp.asarray(rng.standard_normal(64), jnp.float32)
    y = ops.espim_spmv(vals, cols, x, impl="pallas")
    assert y.shape == (pack.r_pad,)
    monkeypatch.delenv(ops.ENV_IMPL)
    with pytest.raises(ValueError, match="column-chunked"):
        ops.espim_spmv(vals, cols, x, impl="pallas")


def test_env_force_interpret(monkeypatch):
    monkeypatch.setenv(ops.ENV_INTERPRET, "1")
    assert ops.provenance()["pallas_interpret"] is True
    monkeypatch.setenv(ops.ENV_INTERPRET, "0")
    assert ops.provenance()["pallas_interpret"] is False
    monkeypatch.delenv(ops.ENV_INTERPRET)
    assert ops.provenance()["pallas_interpret"] == (not ops.on_tpu())
