"""Whole-decoder-layer sparsification (PR 5): the projection-generic
pack-group pipeline covering attention.

The load-bearing property is RoPE/KV correctness under *permuted* QKV
packs: the fused QKV group computes q/k/v in packed row order and a
single static ``take`` must restore exactly the logical head rows the
dense path produces — RoPE pairs head dims positionally and the KV cache
stores logical rows — before the shared ``attn_decode_core`` /
``attn_prefill_core`` run.  Everything here checks that contract end to
end: per-step logits AND cache parity vs dense decode over the pruned
copies, greedy-token parity of the fully-sparse serving engine (fp and
int8) vs the dense engine, non-gated and GQA+bias configs through
``sparsify_model``, the group-spec fold/compose validation, and the
stats honesty rules (whole-model bytes/token includes dense attention
when attention is NOT packed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.sdds import (PackGroupSpec, decoder_layer_groups,
                             validate_group_specs)
from repro.core.sparse_model import (decode_step_sparse,
                                     prefill_chunk_sparse,
                                     pruned_param_tree, sparse_stats,
                                     sparsify_mlps, sparsify_model)
from repro.models import factory
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _setup(arch="llama7b-espim", sparsity=0.9, **kw):
    cfg = get_config(arch, reduced=True)
    params = factory.init_params(cfg, KEY)
    sparse = sparsify_model(cfg, params, sparsity, **kw)
    return cfg, params, sparse


# --------------------------------------------------------------------------
# 1) RoPE/KV correctness under permuted QKV packs: per-step logits AND
#    cache parity vs dense decode over the pruned copies
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["llama7b-espim", "nemotron-4-15b",
                                  "qwen2.5-14b"])
def test_whole_layer_decode_matches_pruned_dense(arch):
    """llama: gated MHA; nemotron: non-gated GQA (relu^2); qwen2.5: GQA
    with QKV bias — the bias rides post-take, never packed."""
    cfg, params, sparse = _setup(arch, row_tile=32)
    assert sparse["attn_sparse"]
    assert set(sparse["groups"]) == {"qkv", "attn_out", "gateup", "down"}
    pruned = pruned_param_tree(params, sparse)

    B, S = 2, 5
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    cache_d = factory.init_cache(cfg, B, S + 2)
    cache_s = factory.init_cache(cfg, B, S + 2)
    dec_d = jax.jit(lambda p, c, b: factory.decode_step(cfg, p, c, b))
    dec_s = jax.jit(lambda p, c, b: decode_step_sparse(cfg, p, sparse,
                                                       c, b))
    for i in range(S):
        batch = {"tokens": toks[:, i:i + 1]}
        lg_d, cache_d = dec_d(pruned, cache_d, batch)
        lg_s, cache_s = dec_s(params, cache_s, batch)
        err = float(jnp.abs(lg_d - lg_s).max() / jnp.abs(lg_d).max())
        assert err < 5e-4, (arch, i, err)
    # the KV caches must agree ROW FOR ROW: a permuted-order k/v write
    # (missing take, wrong RoPE pairing) corrupts them even when early
    # logits look fine
    for name in ("k", "v"):
        np.testing.assert_allclose(np.asarray(cache_d[name]),
                                   np.asarray(cache_s[name]),
                                   rtol=1e-4, atol=1e-5)


def test_attn_only_preset_decode_matches_pruned_dense():
    """projections="attn": q/k/v/o packed, MLP dense from the layer
    params — the uncovered side of the group set must fall back, not
    assume packs exist."""
    cfg, params, sparse = _setup(projections="attn", row_tile=32)
    assert sparse["attn_sparse"] and not sparse["mlp_sparse"]
    assert set(sparse["groups"]) == {"qkv", "attn_out"}
    pruned = pruned_param_tree(params, sparse)     # only attn copies swap in
    toks = jax.random.randint(KEY, (2, 1), 0, cfg.vocab_size)
    cache_d = factory.init_cache(cfg, 2, 4)
    cache_s = factory.init_cache(cfg, 2, 4)
    lg_d, _ = factory.decode_step(cfg, pruned, cache_d, {"tokens": toks})
    lg_s, _ = decode_step_sparse(cfg, params, sparse, cache_s,
                                 {"tokens": toks})
    err = float(jnp.abs(lg_d - lg_s).max() / jnp.abs(lg_d).max())
    assert err < 5e-4, err
    # prefill dense path: packed attention GEMMs from pruned copies, MLP
    # from the layer params
    batch = {"tokens": jax.random.randint(KEY, (1, 3), 0, cfg.vocab_size),
             "n_valid": jnp.asarray([3], jnp.int32)}
    c1 = factory.init_cache(cfg, 1, 4)
    lg_p, _ = prefill_chunk_sparse(cfg, params, sparse, c1, batch,
                                   proj_path="dense")
    assert np.isfinite(np.asarray(lg_p)).all()
    # the uncovered MLP bytes are charged as dense projection traffic
    st = sparse_stats(sparse)
    mlp = params["layers"]["mlp"]
    mlp_bytes = sum(int(np.size(mlp[n])) * mlp[n].dtype.itemsize
                    for n in mlp)
    assert st["total"]["dense_proj_bytes_per_token"] == mlp_bytes


def test_whole_layer_prefill_dense_matches_kernel_path():
    """Section III-I per phase, now covering attention: the GEMM chunk
    over the pruned copies == the packed-kernel chunk."""
    cfg, params, sparse = _setup()
    toks = jax.random.randint(KEY, (2, 4), 0, cfg.vocab_size)
    batch = {"tokens": toks, "n_valid": jnp.asarray([4, 4], jnp.int32)}
    cache_d = factory.init_cache(cfg, 2, 6)
    cache_k = factory.init_cache(cfg, 2, 6)
    lg_d, cd = prefill_chunk_sparse(cfg, params, sparse, cache_d, batch,
                                    proj_path="dense")
    lg_k, ck = prefill_chunk_sparse(cfg, params, sparse, cache_k, batch,
                                    proj_path="kernel")
    err = float(jnp.abs(lg_d - lg_k).max() / jnp.abs(lg_d).max())
    assert err < 5e-5, err
    np.testing.assert_allclose(np.asarray(cd["k"]), np.asarray(ck["k"]),
                               rtol=1e-4, atol=1e-5)


def test_scanned_whole_layer_matches_python_loop():
    cfg, params, sparse = _setup()
    toks = jax.random.randint(KEY, (2, 1), 0, cfg.vocab_size)
    cache_s = factory.init_cache(cfg, 2, 3)
    cache_u = factory.init_cache(cfg, 2, 3)
    lg_s, _ = decode_step_sparse(cfg, params, sparse, cache_s,
                                 {"tokens": toks})
    lg_u, _ = decode_step_sparse(cfg, params, sparse, cache_u,
                                 {"tokens": toks}, unroll=True)
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_u),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# 2) acceptance: greedy-token parity of the fully-sparse engine vs the
#    dense engine on the pruned copies (fp and int8)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("quant", [None, "int8"])
def test_engine_greedy_parity_fully_sparse(quant):
    cfg, params, sparse = _setup(quant=quant)
    pruned = pruned_param_tree(params, sparse)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (3, 17, 9, 30)]
    outs = {}
    for label, p, sp in (("dense", pruned, None), ("sparse", params,
                                                   sparse)):
        eng = ServeEngine(cfg, p, batch_slots=2, max_len=64, sparse=sp,
                          paged=True, block_size=8, prefill_chunk=8)
        reqs = [Request(rid=i, prompt=pr, max_new_tokens=8)
                for i, pr in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        outs[label] = [r.output for r in reqs]
    assert outs["dense"] == outs["sparse"], quant


# --------------------------------------------------------------------------
# 3) the group-spec contract (fold/compose validation)
# --------------------------------------------------------------------------
def test_group_spec_validation():
    ok = decoder_layer_groups(gated=True, attn=True)
    assert [s.name for s in ok] == ["qkv", "attn_out", "gateup", "down"]
    validate_group_specs(ok)

    with pytest.raises(ValueError, match="duplicate group name"):
        validate_group_specs([PackGroupSpec("g", ("w_up",)),
                              PackGroupSpec("g", ("w_down",))])
    with pytest.raises(ValueError, match="two groups"):
        validate_group_specs([PackGroupSpec("a", ("w_up",)),
                              PackGroupSpec("b", ("w_up",))])
    with pytest.raises(ValueError, match="unknown group"):
        validate_group_specs([PackGroupSpec("a", ("w_up",),
                                            compose_with="nope")])
    # folded output with no composing consumer never returns to logical
    # order — rejected
    with pytest.raises(ValueError, match="folded"):
        validate_group_specs([PackGroupSpec("a", ("w_up",),
                                            output="folded")])
    # a take output that a downstream group composes with would be
    # double-unscattered — rejected
    with pytest.raises(ValueError, match="take"):
        validate_group_specs([
            PackGroupSpec("a", ("w_up",), output="take"),
            PackGroupSpec("b", ("w_down",), compose_with="a")])
    with pytest.raises(ValueError, match="compiled earlier"):
        validate_group_specs([
            PackGroupSpec("b", ("w_down",), compose_with="a"),
            PackGroupSpec("a", ("w_up",), output="folded",
                          compose_with="b")])


def test_sparsify_model_rejects_missing_projection():
    cfg, params, _ = _setup(projections="mlp")
    bad = (PackGroupSpec("g", ("w_nope",), module="mlp", fuse="halves",
                         output="take"),)
    with pytest.raises(ValueError, match="w_nope"):
        sparsify_model(cfg, params, 0.9, projections=bad)


def test_sparsify_model_rejects_non_canonical_runtime_groups():
    """The fused decode runtime drives each module through canonical
    group names/projection sets; a custom spec set the runtime cannot
    serve (or would silently bypass, running attention unpruned while the
    stats claim it is packed) must fail at BUILD time, not at trace."""
    cfg, params, _ = _setup(projections="mlp")
    # attention covered, but under a non-canonical name: would have set
    # attn_sparse=False and silently served unpruned attention
    bad_name = (PackGroupSpec("fused_qkv", ("wq", "wk", "wv"),
                              module="attn"),
                PackGroupSpec("attn_out", ("wo",), module="attn"))
    with pytest.raises(ValueError, match="fused decode runtime"):
        sparsify_model(cfg, params, 0.9, projections=bad_name)
    # qkv without its attn_out partner: would have crashed at trace time
    half_attn = (PackGroupSpec("qkv", ("wq", "wk", "wv"), module="attn"),)
    with pytest.raises(ValueError, match="fused decode runtime"):
        sparsify_model(cfg, params, 0.9, projections=half_attn)
    # the canonical explicit list is equivalent to the preset
    ok = decoder_layer_groups(cfg.gated_mlp, attn=True)
    sp = sparsify_model(cfg, params, 0.9, projections=ok, row_tile=32)
    assert sp["attn_sparse"] and sp["mlp_sparse"]


# --------------------------------------------------------------------------
# 4) stats honesty: attention groups covered, per-projection figures,
#    whole-model bytes/token
# --------------------------------------------------------------------------
def test_sparse_stats_cover_attention_groups():
    cfg, params, sparse = _setup(row_tile=32)
    st = sparse_stats(sparse)
    assert st["attn_sparse"] is True
    for name in ("qkv", "attn_out", "gateup", "down"):
        assert st[name]["pad_frac"] < 1.0
        assert len(st[name]["pad_frac_per_layer"]) == cfg.n_layers
    # per-projection entries under the original names, exact nnz split
    for proj in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"):
        assert st[proj]["nnz"] > 0
        assert 0.0 <= st[proj]["pad_frac"] < 1.0
        assert len(st[proj]["pad_frac_per_layer"]) == cfg.n_layers
    assert (st["wq"]["nnz"] + st["wk"]["nnz"] + st["wv"]["nnz"]
            == st["qkv"]["nnz"])
    assert (st["wq"]["padded_slots"] + st["wk"]["padded_slots"]
            + st["wv"]["padded_slots"] == st["qkv"]["padded_slots"])
    # everything packed: no dense projection bytes left
    assert st["total"]["dense_proj_bytes_per_token"] == 0
    assert (st["total"]["bytes_per_token"]
            == st["total"]["packed_bytes_per_token"])


def test_mlp_only_bytes_per_token_includes_dense_attention():
    """The pre-PR5 bug: an MLP-only deployment reported its packed bytes
    as the whole model.  Now the dense q/k/v/o bytes are charged, and the
    whole-layer deployment's bytes/token sits strictly below."""
    cfg, params, _ = _setup(projections="mlp")
    sp_mlp = sparsify_mlps(cfg, params, 0.9, row_tile=32)
    sp_all = sparsify_model(cfg, params, 0.9, row_tile=32)
    st_mlp, st_all = sparse_stats(sp_mlp), sparse_stats(sp_all)
    attn = params["layers"]["attn"]
    attn_bytes = sum(int(np.size(attn[n])) * attn[n].dtype.itemsize
                     for n in ("wq", "wk", "wv", "wo"))
    assert st_mlp["attn_sparse"] is False
    assert st_mlp["total"]["dense_proj_bytes_per_token"] == attn_bytes
    assert (st_mlp["total"]["bytes_per_token"]
            == st_mlp["total"]["packed_bytes_per_token"] + attn_bytes)
    # packing q/k/v/o at 90% sparsity must strictly shrink the whole-model
    # per-token traffic (the PR acceptance criterion)
    assert (st_all["total"]["bytes_per_token"]
            < st_mlp["total"]["bytes_per_token"])


def test_fused_group_linear_matches_per_projection():
    from repro.core.espim_linear import ESPIMGroupLinear
    from repro.core.pruning import magnitude_prune
    rng = np.random.default_rng(7)
    named = {"wq": rng.standard_normal((96, 120)).astype(np.float32),
             "wk": rng.standard_normal((48, 120)).astype(np.float32),
             "wv": rng.standard_normal((48, 120)).astype(np.float32)}
    group = ESPIMGroupLinear.from_dense(named, prune_sparsity=0.85,
                                        row_tile=32)
    x = jnp.asarray(rng.standard_normal((4, 120)), jnp.float32)
    ys = group(x, impl="ref")
    for name, w in named.items():
        want = np.asarray(x) @ magnitude_prune(w, 0.85).T
        np.testing.assert_allclose(np.asarray(ys[name]), want,
                                   rtol=1e-4, atol=1e-4)
