"""Serving-layer fault tolerance (DESIGN.md §11).

Three layers under test:

* **pack integrity** — the property that ANY single bit flip in ANY
  plane of an offline pack (fp, int8, nibble-packed int4) is caught by
  fingerprint verification; bounds validation catches what hashing
  cannot interpret (out-of-bounds indices with a *fresh* fingerprint);
  and a schedule/pack mismatch that passes every structural check is
  still caught because the SDDS plan digest is bound into the pack
  fingerprint.
* **engine hardening** — load-time rejection / degrade-to-dense,
  quarantine -> dense-fallback parity with zero leaked blocks, cancel
  and deadline teardown restoring the block pool, capped-backoff retry,
  and the arena invariant tripwire.
* **shared strike logic** — the ``StrikePolicy`` both the cluster
  straggler detector and the serving ``LatencyWatchdog`` escalate
  through.

The parity assertions are exact (greedy decode is batching-independent)
— "unaffected slots bit-identical to the no-fault run", not a
tolerance.
"""
import copy

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fall back to a seeded random sweep
    from _hypothesis_fallback import given, settings, st

from repro.configs.registry import get_config
from repro.core import integrity
from repro.core.integrity import PackIntegrityError
from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import pack_bucketed_stack, pack_ell_chunked
from repro.core.sparse_model import (pruned_param_tree, sparsify_model,
                                     verify_sparse)
from repro.models import factory
from repro.runtime.fault_tolerance import LatencyWatchdog, StrikePolicy
from repro.serve import faults
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import TERMINAL_STATES, latency_summary

KEY = jax.random.PRNGKey(0)


def _rand_sparse(r, c, s, seed=0):
    rng = np.random.default_rng(seed)
    return magnitude_prune(rng.standard_normal((r, c)).astype(np.float32), s)


def _quantized(pack, mode):
    from repro.quant import default_spec
    from repro.quant.qpack import quantize_bucketed_stack, quantize_pack
    if hasattr(pack, "buckets"):
        quantize_bucketed_stack(pack, default_spec(mode))
    else:
        quantize_pack(pack, default_spec(mode))
    return pack


def _make_pack(kind):
    if kind.startswith("ell"):
        p = pack_ell_chunked(_rand_sparse(64, 48, 0.8), row_tile=16,
                             chunk_cols=16)
    else:
        mats = [[_rand_sparse(48, 32, 0.8, seed=h * 7 + l) for l in range(2)]
                for h in range(2)]
        p = pack_bucketed_stack(mats, row_tile=16, chunk_cols=16,
                                n_buckets=2)
    if kind.endswith("_int8"):
        p = _quantized(p, "int8")
    elif kind.endswith("_int4"):
        p = _quantized(p, "int4")
    return p


PACK_KINDS = ("ell_chunked", "ell_chunked_int8", "ell_chunked_int4",
              "bucketed", "bucketed_int8", "bucketed_int4")
_PACK_CACHE: dict = {}


def _pack(kind):
    if kind not in _PACK_CACHE:
        _PACK_CACHE[kind] = _make_pack(kind)
    return _PACK_CACHE[kind]


# --------------------------------------------------------------------------
# 1) pack integrity: the bit-flip property
# --------------------------------------------------------------------------
def _flip_bit_inplace(arr, bit_seed):
    flat = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
    bit = bit_seed % (flat.size * 8)
    # mutate through the original buffer when contiguous (the builders
    # always produce contiguous planes, so this aliases the pack)
    tgt = arr.view(np.uint8).reshape(-1)
    tgt[bit // 8] ^= np.uint8(1 << (bit % 8))


@settings(max_examples=30, deadline=None)
@given(kind=st.sampled_from(PACK_KINDS),
       plane_seed=st.integers(0, 10**6), bit_seed=st.integers(0, 10**6))
def test_any_single_bitflip_is_caught(kind, plane_seed, bit_seed):
    """Flip one uniformly-chosen bit of one uniformly-chosen plane —
    index, value, valid-mask, perm, quant codes, scales or group bits —
    and verification must raise.  sha256 makes this a certainty, but the
    property pins the *wiring*: every plane the decode path consumes is
    inside the fingerprint."""
    pack = copy.deepcopy(_pack(kind))
    assert pack.fingerprint is not None, "builders must fingerprint"
    integrity.verify_pack(pack)         # pristine copy passes
    planes, _ = integrity.pack_planes(pack)
    name = sorted(planes)[plane_seed % len(planes)]
    _flip_bit_inplace(planes[name], bit_seed)
    with pytest.raises(PackIntegrityError):
        integrity.verify_pack(pack)


def test_bounds_validation_catches_oob_even_with_fresh_fingerprint():
    """Hashing catches corruption-after-build; bounds validation catches
    packs that were *built wrong* (or re-fingerprinted after corruption):
    an index outside the chunk's gather domain fails validate_pack even
    when the digests are internally consistent."""
    pack = copy.deepcopy(_pack("ell_chunked"))
    slot = tuple(np.argwhere(pack.valid)[0])
    pack.cols[slot] = pack.chunk_cols + 3          # beyond any chunk limit
    pack.fingerprint = integrity.fingerprint_pack(pack)   # digests agree
    with pytest.raises(PackIntegrityError, match="out of bounds"):
        integrity.verify_pack(pack)


def test_schedule_mismatch_caught_only_by_bound_fingerprint():
    """Roll perm+inv_perm one layer: each layer's pair stays a valid
    permutation (bounds/involution checks pass — validate_pack is happy
    with NO fingerprint), yet the pack now decodes under the wrong
    schedule; the bound fingerprint is the only thing that catches it."""
    pack = copy.deepcopy(_pack("bucketed"))
    pack.perm = np.roll(pack.perm, 1, axis=0)
    pack.inv_perm = np.roll(pack.inv_perm, 1, axis=0)
    integrity.validate_pack(pack)                  # structurally clean
    with pytest.raises(PackIntegrityError, match="perm"):
        integrity.verify_pack(pack)


# --------------------------------------------------------------------------
# 2) engine: load-time verification and the degrade ladder
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def llama_sparse():
    cfg = get_config("llama7b-espim", reduced=True)
    params = factory.init_params(cfg, KEY)
    sparse = sparsify_model(cfg, params, 0.9, row_tile=32)
    return cfg, params, sparse


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("granite-3-2b", reduced=True)
    params = factory.init_params(cfg, KEY)
    return cfg, params


def _reqs(cfg, n, max_new=6, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(
        1, cfg.vocab_size, 4 + 3 * (i % 3)).tolist(),
        max_new_tokens=max_new, **kw) for i in range(n)]


def _drain(eng, reqs, on_step=None, max_steps=2000):
    for r in reqs:
        eng.submit(r)
    steps = 0
    while steps < max_steps and (eng.scheduler.has_pending
                                 or any(s is not None for s in eng.slots)):
        eng.step()
        steps += 1
        if on_step:
            on_step(eng, steps)


def test_engine_rejects_corruption_at_load(llama_sparse):
    cfg, params, sparse = llama_sparse
    rng = np.random.default_rng(0)
    for bad in (faults.corrupt_group_plane(sparse, "index", rng),
                faults.corrupt_group_plane(sparse, "value", rng),
                faults.mismatch_schedule(sparse)):
        with pytest.raises(PackIntegrityError):
            ServeEngine(cfg, params, batch_slots=2, max_len=64,
                        sparse=bad, block_size=8, prefill_chunk=8)
    # the clean dict still verifies and the engine records the digests
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64, sparse=sparse,
                      block_size=8, prefill_chunk=8)
    assert eng.verified_packs == verify_sparse(sparse)


def test_on_verify_failure_degrade_serves_dense(llama_sparse):
    cfg, params, sparse = llama_sparse
    bad = faults.corrupt_group_plane(sparse, "value",
                                     np.random.default_rng(1))
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=64, sparse=bad,
                      block_size=8, prefill_chunk=8,
                      on_verify_failure="degrade")
    assert eng.sparse is None and eng.stats.degraded_to_dense
    reqs = _reqs(cfg, 1)
    _drain(eng, reqs)
    assert eng.stats.requests_completed == 1 and len(reqs[0].output) == 6
    assert eng.cache.free_blocks == eng.cache.num_blocks


def test_quarantine_degrades_to_dense_with_parity(llama_sparse):
    """Runtime value-plane poison (injected AFTER load verification
    passed): every poisoned tick is quarantined — no emit, no KV commit —
    then served by the dense fallback; because the fallback reconstructs
    the clean pruned weights, the final outputs are bit-identical to the
    no-fault run, with zero leaked blocks."""
    cfg, params, sparse = llama_sparse

    def run(poison):
        eng = ServeEngine(cfg, params, batch_slots=2, max_len=64,
                          sparse=sparse, block_size=8, prefill_chunk=8,
                          validate_arena=True)
        reqs = _reqs(cfg, 3)

        def on_step(e, step):
            if poison and step == 5:
                e._poisoned = True
                faults.inject_poisoned_decode(
                    e, faults.poison_values(sparse,
                                            np.random.default_rng(2)))
        _drain(eng, reqs, on_step=on_step)
        return eng, [r.output for r in reqs]

    eng_base, base = run(False)
    eng_bad, outs = run(True)
    assert outs == base                      # exact greedy parity
    assert eng_bad.stats.quarantines >= 1
    assert eng_bad.stats.degraded_tokens >= 1
    assert eng_bad.stats.requests_failed == 0
    assert eng_bad.stats.requests_completed == 3
    assert eng_bad.stats.requests_degraded >= 1
    assert eng_bad.cache.free_blocks == eng_bad.cache.num_blocks
    states = eng_bad.stats.latency_summary()["states"]
    assert set(states) <= {"completed", "degraded"}


def test_dense_engine_nonfinite_fails_cleanly(dense_setup):
    """A dense engine has no fallback rung: a non-finite slot ends
    ``failed`` — blocks released, other slots' outputs untouched."""
    cfg, params = dense_setup
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48,
                      block_size=8, validate_arena=True)
    reqs = _reqs(cfg, 2)
    armed = []

    def on_step(e, step):
        if step == 4 and not armed:
            armed.append(step)
            faults.force_nonfinite_flag(e, slots=[0], n_calls=1)
    _drain(eng, reqs, on_step=on_step)
    assert eng.stats.quarantines == 1
    assert eng.stats.requests_failed == 1
    assert eng.stats.requests_completed == 1
    assert eng.cache.free_blocks == eng.cache.num_blocks


# --------------------------------------------------------------------------
# 3) engine: cancel / deadline / retry / arena invariant
# --------------------------------------------------------------------------
def test_cancel_releases_blocks_and_preserves_others(dense_setup):
    cfg, params = dense_setup
    # solo reference run for the surviving request
    solo = ServeEngine(cfg, params, batch_slots=2, max_len=48, block_size=8)
    ref = _reqs(cfg, 2)[1]
    _drain(solo, [ref])

    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, block_size=8,
                      validate_arena=True)
    reqs = _reqs(cfg, 3)
    done = []

    def on_step(e, step):
        if step == 3 and not done:
            done.append(step)
            assert e.cancel(reqs[0].rid)       # in-flight
            assert e.cancel(reqs[2].rid)       # still queued
            assert not e.cancel(99)            # unknown rid
    _drain(eng, reqs, on_step=on_step)
    assert eng.stats.requests_cancelled == 2
    assert eng.stats.requests_completed == 1
    assert reqs[0].done and reqs[2].done and reqs[2].output == []
    assert reqs[1].output == ref.output        # unaffected slot parity
    assert eng.cache.free_blocks == eng.cache.num_blocks
    states = eng.stats.latency_summary()["states"]
    assert states.get("cancelled") == 2 and states.get("completed") == 1


def test_deadlines_expire_queued_and_inflight(dense_setup):
    cfg, params = dense_setup
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=48, block_size=8)
    occupant, queued = _reqs(cfg, 2, max_new=4)
    queued.deadline_s = 0.0                    # expires while waiting
    eng.submit(occupant)
    eng.submit(queued)
    eng.step()
    eng.step()
    # now expire the in-flight occupant via its total wall-clock deadline
    occupant.deadline_s = 0.0
    _drain(eng, [])
    assert occupant.done and queued.done
    assert eng.stats.requests_deadline_expired == 2
    assert eng.cache.free_blocks == eng.cache.num_blocks
    st = eng.stats.latency_summary()["states"]
    assert st.get("deadline_expired") == 2

    # TTFT deadline: never produces a first token -> expired
    eng2 = ServeEngine(cfg, params, batch_slots=1, max_len=48, block_size=8)
    r = _reqs(cfg, 1, max_new=4)[0]
    r.ttft_deadline_s = -1.0
    _drain(eng2, [r])
    assert r.done and r.output == []
    assert eng2.stats.requests_deadline_expired == 1


def test_transient_retry_recovers_with_parity(dense_setup):
    cfg, params = dense_setup
    base_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48,
                           block_size=8)
    base = _reqs(cfg, 2)
    _drain(base_eng, base)

    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, block_size=8,
                      max_retries=2, retry_backoff=0.001)
    state = None
    reqs = _reqs(cfg, 2)

    def on_step(e, step):
        nonlocal state
        if step == 3 and state is None:
            state = faults.arm_transient_errors(e, at_call=1, n_failures=2)
    _drain(eng, reqs, on_step=on_step)
    assert state["fails"] == 2
    assert eng.stats.retries == 2
    assert eng.stats.requests_failed == 0
    assert [r.output for r in reqs] == [r.output for r in base]

    # exhaustion: more consecutive failures than retries -> slots end
    # "failed", the engine itself survives and drains
    eng2 = ServeEngine(cfg, params, batch_slots=2, max_len=48, block_size=8,
                       max_retries=1, retry_backoff=0.001)
    reqs2 = _reqs(cfg, 2)
    armed = []

    def on_step2(e, step):
        if step == 3 and not armed:
            armed.append(faults.arm_transient_errors(e, at_call=1,
                                                     n_failures=99))
    _drain(eng2, reqs2, on_step=on_step2)
    assert eng2.stats.requests_failed == 2
    assert eng2.cache.free_blocks == eng2.cache.num_blocks


def test_arena_invariant_tripwire(dense_setup):
    cfg, params = dense_setup
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, block_size=8,
                      validate_arena=True)
    reqs = _reqs(cfg, 2)
    _drain(eng, reqs)                  # per-step check stayed silent
    acct = eng.check_arena()
    assert acct["free"] == acct["num_blocks"] and acct["allocated"] == 0
    eng.cache._free.pop()              # simulate a leaked block
    with pytest.raises(RuntimeError, match="arena accounting"):
        eng.check_arena()


def test_arena_oom_pressure_only_delays_admission(dense_setup):
    cfg, params = dense_setup
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, block_size=8,
                      validate_arena=True)
    reqs = _reqs(cfg, 3)

    def on_step(e, step):
        if step == 1:
            e.cache.quarantine_blocks(e.cache.free_blocks // 2)
        elif step == 10:
            e.cache.release_quarantined()
    _drain(eng, reqs, on_step=on_step)
    eng.cache.release_quarantined()
    assert eng.stats.requests_completed == 3
    assert eng.stats.requests_failed == 0
    assert eng.cache.free_blocks == eng.cache.num_blocks


# --------------------------------------------------------------------------
# 4) shared strike logic + terminal-state plumbing
# --------------------------------------------------------------------------
def test_strike_policy_and_watchdog():
    pol = StrikePolicy(patience=3)
    assert not pol.strike("w") and not pol.strike("w")
    pol.clear("w")                         # one clean observation forgives
    assert not pol.strike("w") and not pol.strike("w")
    assert pol.strike("w")                 # third consecutive trips

    wd = LatencyWatchdog(threshold=3.0, patience=2, min_samples=4)
    for _ in range(6):
        assert not wd.observe(0.01)        # build the baseline
    assert not wd.observe(1.0)             # first spike: strike, no trip
    assert wd.observe(1.0)                 # second consecutive: trip
    assert not wd.observe(0.01)            # clean step resets the streak
    assert not wd.observe(1.0)


def test_terminal_states_contract():
    assert set(TERMINAL_STATES) == {"completed", "degraded", "cancelled",
                                    "deadline_expired", "failed", "shed"}
    from repro.serve.scheduler import RequestMetrics, Scheduler
    s = Scheduler()
    m = RequestMetrics(rid=0, prompt_len=1, t_submit=0.0)
    with pytest.raises(ValueError):
        s.finish(m, "vanished")
    s.finish(m, "failed")
    assert latency_summary(s.completed)["states"] == {"failed": 1}
