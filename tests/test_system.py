"""End-to-end behaviour: train a tiny LM, serve it, prune + pack its
projections through the ESPIM pipeline, and check the whole SDDS->cycles
->energy reporting chain runs on a real weight matrix."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config
from repro.core.energy import espim_energy, gpu_dram_energy, newton_energy
from repro.core.espim_linear import ESPIMLinear
from repro.core.pim_sim import simulate_matrix
from repro.core.pruning import magnitude_prune
from repro.core.sdds import ESPIMConfig
from repro.models import factory
from repro.optim.adamw import OptConfig
from repro.serve.engine import Request, ServeEngine
from repro.train.trainer import Trainer, TrainerConfig


def test_train_then_serve_then_espim(tmp_path):
    cfg = get_config("llama7b-espim", reduced=True)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    tr = Trainer(cfg, shape, mesh,
                 OptConfig(warmup_steps=2, decay_steps=100, peak_lr=1e-3),
                 TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                               log_every=1000))
    tr.init_or_resume()
    first = float(tr.train(1)["loss"])
    last = float(tr.train(20)["loss"])
    assert last < first, "training must reduce loss"

    # ---- serve the trained params ----------------------------------------
    params = tr.state["params"]
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    for rid in range(3):
        eng.submit(Request(rid=rid, prompt=[1, 2, 3], max_new_tokens=5))
    stats = eng.run()
    assert stats.requests_completed == 3

    # ---- ESPIM pipeline on a trained projection ---------------------------
    w = np.asarray(params["layers"]["attn"]["wq"][0], np.float32).T
    lin = ESPIMLinear.from_dense(w, prune_sparsity=0.85)
    assert lin.sparse
    x = jnp.asarray(np.random.default_rng(0).standard_normal(w.shape[1]),
                    jnp.float32)
    y = np.asarray(lin(x, impl="ref"))
    wp = magnitude_prune(w, 0.85)
    np.testing.assert_allclose(y, wp @ np.asarray(x), rtol=3e-4, atol=3e-4)

    # ---- SDDS -> cycles -> energy on the same trained matrix --------------
    reps = simulate_matrix(wp, ESPIMConfig(n_banks=8),
                           archs=("espim", "newton"))
    assert reps["espim"].cycles < reps["newton"].cycles
    eg = gpu_dram_energy(*wp.shape).total
    ee = espim_energy(reps["espim"].schedule).normalized(eg)
    en = newton_energy(wp.shape[0], wp.shape[1],
                       int((wp != 0).sum())).normalized(eg)
    assert ee.total < en.total
