"""Fault-tolerance policies: heartbeat, straggler detection, elastic
re-meshing, and the data pipeline's exact-resume property."""
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config
from repro.data.pipeline import SyntheticPipeline
from repro.runtime.fault_tolerance import (HeartbeatMonitor,
                                           StragglerDetector,
                                           plan_elastic_mesh)


def test_heartbeat_detects_failures():
    hb = HeartbeatMonitor(["w0", "w1", "w2"], timeout=10.0)
    for t in range(0, 30, 5):
        hb.beat("w0", t)
        hb.beat("w1", t)
        if t < 10:
            hb.beat("w2", t)
    assert hb.failed(now=30.0) == ["w2"]
    assert hb.healthy(now=30.0) == ["w0", "w1"]
    # failed workers stay failed even if a stale beat arrives
    hb.beat("w2", 31.0)
    assert "w2" in hb.failed(now=32.0)


def test_straggler_needs_persistence():
    sd = StragglerDetector(threshold=2.0, patience=3)
    base = {f"w{i}": 1.0 for i in range(8)}
    # one slow step is not a straggler
    assert sd.observe_step({**base, "w7": 5.0}) == []
    assert sd.observe_step({**base, "w7": 5.0}) == []
    assert sd.observe_step({**base, "w7": 5.0}) == ["w7"]
    # recovery resets strikes
    sd2 = StragglerDetector(threshold=2.0, patience=2)
    sd2.observe_step({**base, "w3": 9.0})
    sd2.observe_step(base)
    assert sd2.observe_step({**base, "w3": 9.0}) == []


def test_elastic_plan_shrinks_data_axis():
    plan = plan_elastic_mesh(n_healthy=240, model_parallel=16)
    assert plan.mesh_shape == (15, 16)
    assert plan.dropped_devices == 0
    plan = plan_elastic_mesh(n_healthy=250, model_parallel=16)
    assert plan.mesh_shape == (15, 16) and plan.dropped_devices == 10


def test_elastic_plan_multi_pod():
    plan = plan_elastic_mesh(n_healthy=512, model_parallel=16, pod_size=256)
    assert plan.mesh_shape == (2, 16, 16)
    plan = plan_elastic_mesh(n_healthy=400, model_parallel=16, pod_size=256)
    assert plan.mesh_shape == (16, 16)  # one full pod survives


def test_elastic_plan_rejects_below_tp():
    with pytest.raises(ValueError):
        plan_elastic_mesh(n_healthy=8, model_parallel=16)


def test_pipeline_exact_resume():
    cfg = get_config("granite-3-2b", reduced=True)
    shape = ShapeConfig("t", 16, 4, "train")
    pipe = SyntheticPipeline.for_model(cfg, shape, seed=7)
    b10 = pipe.batch_at(10)
    state = pipe.state(10)
    pipe2, step = SyntheticPipeline.restore(cfg, shape, state)
    assert step == 10
    b10b = pipe2.batch_at(10)
    assert (b10["tokens"] == b10b["tokens"]).all()
    # different steps give different data
    assert not (pipe.batch_at(11)["tokens"] == b10["tokens"]).all()
