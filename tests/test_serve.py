"""Serving: engine continuous batching, ESPIM sparse serving vs dense
reference, flexible dense/sparse layer."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.registry import get_config
from repro.core.espim_linear import (ESPIMLinear, espim_matvec_sharded,
                                     make_sharded_weights)
from repro.core.pruning import magnitude_prune
from repro.models import factory
from repro.serve.engine import Request, ServeEngine
from repro.serve.serve_step import serve_step_fn

KEY = jax.random.PRNGKey(0)


def test_engine_completes_requests():
    cfg = get_config("granite-3-2b", reduced=True)
    params = factory.init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, batch_slots=3, max_len=48)
    for rid in range(5):
        eng.submit(Request(rid=rid, prompt=[1 + rid, 2, 3],
                           max_new_tokens=6))
    stats = eng.run()
    assert stats.requests_completed == 5
    assert stats.tokens_generated == 30


def test_engine_slot_reuse_isolation():
    """A recycled slot must not leak the previous request's KV state."""
    cfg = get_config("granite-3-2b", reduced=True)
    params = factory.init_params(cfg, KEY)
    # run request alone
    eng1 = ServeEngine(cfg, params, batch_slots=1, max_len=48)
    eng1.submit(Request(rid=0, prompt=[5, 6, 7], max_new_tokens=4))
    eng1.run()
    alone = None
    # same request after another one finished in the same slot
    eng2 = ServeEngine(cfg, params, batch_slots=1, max_len=48)
    eng2.submit(Request(rid=1, prompt=[9, 9, 9, 9], max_new_tokens=4))
    req = Request(rid=2, prompt=[5, 6, 7], max_new_tokens=4)
    eng2.submit(req)
    eng2.run()
    eng1b = ServeEngine(cfg, params, batch_slots=1, max_len=48)
    r_alone = Request(rid=3, prompt=[5, 6, 7], max_new_tokens=4)
    eng1b.submit(r_alone)
    eng1b.run()
    assert req.output == r_alone.output


def test_serve_step_greedy_masks_vocab_padding():
    cfg = get_config("granite-3-2b", reduced=True)
    # reduced vocab 512 pads to 512 -> force mismatch via odd vocab
    cfg = cfg.replace(vocab_size=500)
    params = factory.init_params(cfg, KEY)
    cache = factory.init_cache(cfg, 2, 8)
    toks = jnp.asarray([[1], [2]], jnp.int32)
    nxt, logits, cache = serve_step_fn(cfg, params, cache, {"tokens": toks})
    assert int(nxt.max()) < 500


def test_espim_sparse_serving_matches_pruned_dense():
    """The paper's use case: a pruned projection served through the ESPIM
    kernel must equal the dense matmul with the pruned weights."""
    rng = np.random.default_rng(0)
    w = rng.standard_normal((256, 512)).astype(np.float32)
    lin = ESPIMLinear.from_dense(w, prune_sparsity=0.9)
    assert lin.sparse
    wp = magnitude_prune(w, 0.9)
    x = jnp.asarray(rng.standard_normal((3, 512)), jnp.float32)
    y = np.asarray(lin(x, impl="ref"))
    np.testing.assert_allclose(y, np.asarray(x) @ wp.T, rtol=2e-4, atol=2e-4)


def test_flexible_layer_picks_dense_path():
    rng = np.random.default_rng(1)
    w = rng.standard_normal((64, 64)).astype(np.float32)
    lin = ESPIMLinear.from_dense(w)  # density 1.0 -> dense datapath
    assert not lin.sparse
    x = jnp.asarray(rng.standard_normal(64), jnp.float32)
    np.testing.assert_allclose(np.asarray(lin(x)), w @ np.asarray(x),
                               rtol=1e-4)


def _mixed_trace():
    return [[1 + i, 2, 3 + i, 4, 5, 6, 7][: 2 + i] for i in range(5)]


def _run_engine(cfg, params, **kw):
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(_mixed_trace())]
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    return [r.output for r in reqs], stats, eng


def test_paged_engine_bit_parity_with_contiguous():
    """Block-pool decode must sample the exact same tokens as the
    contiguous-cache engine on the same trace (temperature=0)."""
    cfg = get_config("granite-3-2b", reduced=True)
    params = factory.init_params(cfg, KEY)
    out_paged, _, eng = _run_engine(cfg, params, paged=True, block_size=8)
    out_contig, _, _ = _run_engine(cfg, params, paged=False)
    assert out_paged == out_contig
    assert eng.cache.free_blocks == eng.cache.num_blocks  # all returned


def test_engine_temperature_rng_threads_per_step():
    """temperature > 0 must draw a fresh perturbation every tick (the
    seed engine replayed PRNGKey(0) forever) and stay seed-deterministic."""
    cfg = get_config("granite-3-2b", reduced=True)
    params = factory.init_params(cfg, KEY)

    def sample(seed):
        eng = ServeEngine(cfg, params, batch_slots=1, max_len=48,
                          temperature=1.0, seed=seed)
        r = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=12)
        eng.submit(r)
        eng.run()
        return r.output

    a = sample(0)
    assert len(set(a)) > 1          # not the same perturbation every step
    assert a == sample(0)           # deterministic under one seed
    assert a != sample(1)           # and actually keyed by it


def test_engine_stats_extended():
    cfg = get_config("granite-3-2b", reduced=True)
    params = factory.init_params(cfg, KEY)
    outs, stats, eng = _run_engine(cfg, params)
    assert stats.requests_completed == 5
    assert stats.steps == stats.decode_steps + stats.prefill_chunks
    assert 0.0 < stats.slot_occupancy <= 1.0
    lat = stats.latency_summary()
    assert lat["requests"] == 5
    for k in ("ttft_s", "tpot_s", "queue_delay_s"):
        assert lat[k]["p50"] is not None
    # idle engine tick is a free no-op
    before = stats.steps
    eng.step()
    assert eng.stats.steps == before


def test_sjf_policy_admits_short_prompts_first():
    cfg = get_config("granite-3-2b", reduced=True)
    params = factory.init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=48, policy="sjf")
    long_r = Request(rid=0, prompt=list(range(1, 13)), max_new_tokens=2)
    short_r = Request(rid=1, prompt=[5, 6], max_new_tokens=2)
    eng.submit(long_r)
    eng.submit(short_r)
    order = []
    orig = eng.scheduler.pick

    def spy(can_admit):
        got = orig(can_admit)
        if got is not None:
            order.append(got[0].rid)
        return got

    eng.scheduler.pick = spy
    eng.run()
    assert order == [1, 0]
    assert long_r.done and short_r.done


def test_tight_arena_admission_control():
    """More concurrent demand than blocks: requests queue on reservation
    and all complete once blocks recycle."""
    cfg = get_config("granite-3-2b", reduced=True)
    params = factory.init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, batch_slots=3, max_len=48,
                      block_size=16, num_blocks=3)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new_tokens=4)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    assert stats.requests_completed == 5
    assert eng.cache.free_blocks == 3


def test_oversized_request_rejected_at_submit():
    cfg = get_config("granite-3-2b", reduced=True)
    params = factory.init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=48,
                      block_size=16, num_blocks=1)
    with np.testing.assert_raises(ValueError):
        eng.submit(Request(rid=0, prompt=list(range(30)),
                           max_new_tokens=8))


def test_sharded_espim_matvec():
    """Devices-as-banks distribution (shard_map over 'model')."""
    rng = np.random.default_rng(2)
    w = rng.standard_normal((384, 256)).astype(np.float32)
    n = jax.device_count()
    mesh = compat.make_mesh((1, n), ("data", "model"))
    sh = make_sharded_weights(w, n, prune_sparsity=0.85)
    x = rng.standard_normal(256).astype(np.float32)
    with compat.set_mesh(mesh):
        y = np.asarray(espim_matvec_sharded(sh, jnp.asarray(x), mesh))
    wp = magnitude_prune(w, 0.85)
    np.testing.assert_allclose(y, wp @ x, rtol=2e-4, atol=2e-4)
