"""Serving: engine continuous batching, ESPIM sparse serving vs dense
reference, flexible dense/sparse layer."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.registry import get_config
from repro.core.espim_linear import (ESPIMLinear, espim_matvec_sharded,
                                     make_sharded_weights)
from repro.core.pruning import magnitude_prune
from repro.models import factory
from repro.serve.engine import Request, ServeEngine
from repro.serve.serve_step import serve_step_fn

KEY = jax.random.PRNGKey(0)


def test_engine_completes_requests():
    cfg = get_config("granite-3-2b", reduced=True)
    params = factory.init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, batch_slots=3, max_len=48)
    for rid in range(5):
        eng.submit(Request(rid=rid, prompt=[1 + rid, 2, 3],
                           max_new_tokens=6))
    stats = eng.run()
    assert stats.requests_completed == 5
    assert stats.tokens_generated == 30


def test_engine_slot_reuse_isolation():
    """A recycled slot must not leak the previous request's KV state."""
    cfg = get_config("granite-3-2b", reduced=True)
    params = factory.init_params(cfg, KEY)
    # run request alone
    eng1 = ServeEngine(cfg, params, batch_slots=1, max_len=48)
    eng1.submit(Request(rid=0, prompt=[5, 6, 7], max_new_tokens=4))
    eng1.run()
    alone = None
    # same request after another one finished in the same slot
    eng2 = ServeEngine(cfg, params, batch_slots=1, max_len=48)
    eng2.submit(Request(rid=1, prompt=[9, 9, 9, 9], max_new_tokens=4))
    req = Request(rid=2, prompt=[5, 6, 7], max_new_tokens=4)
    eng2.submit(req)
    eng2.run()
    eng1b = ServeEngine(cfg, params, batch_slots=1, max_len=48)
    r_alone = Request(rid=3, prompt=[5, 6, 7], max_new_tokens=4)
    eng1b.submit(r_alone)
    eng1b.run()
    assert req.output == r_alone.output


def test_serve_step_greedy_masks_vocab_padding():
    cfg = get_config("granite-3-2b", reduced=True)
    # reduced vocab 512 pads to 512 -> force mismatch via odd vocab
    cfg = cfg.replace(vocab_size=500)
    params = factory.init_params(cfg, KEY)
    cache = factory.init_cache(cfg, 2, 8)
    toks = jnp.asarray([[1], [2]], jnp.int32)
    nxt, logits, cache = serve_step_fn(cfg, params, cache, {"tokens": toks})
    assert int(nxt.max()) < 500


def test_espim_sparse_serving_matches_pruned_dense():
    """The paper's use case: a pruned projection served through the ESPIM
    kernel must equal the dense matmul with the pruned weights."""
    rng = np.random.default_rng(0)
    w = rng.standard_normal((256, 512)).astype(np.float32)
    lin = ESPIMLinear.from_dense(w, prune_sparsity=0.9)
    assert lin.sparse
    wp = magnitude_prune(w, 0.9)
    x = jnp.asarray(rng.standard_normal((3, 512)), jnp.float32)
    y = np.asarray(lin(x, impl="ref"))
    np.testing.assert_allclose(y, np.asarray(x) @ wp.T, rtol=2e-4, atol=2e-4)


def test_flexible_layer_picks_dense_path():
    rng = np.random.default_rng(1)
    w = rng.standard_normal((64, 64)).astype(np.float32)
    lin = ESPIMLinear.from_dense(w)  # density 1.0 -> dense datapath
    assert not lin.sparse
    x = jnp.asarray(rng.standard_normal(64), jnp.float32)
    np.testing.assert_allclose(np.asarray(lin(x)), w @ np.asarray(x),
                               rtol=1e-4)


def test_sharded_espim_matvec():
    """Devices-as-banks distribution (shard_map over 'model')."""
    rng = np.random.default_rng(2)
    w = rng.standard_normal((384, 256)).astype(np.float32)
    n = jax.device_count()
    mesh = compat.make_mesh((1, n), ("data", "model"))
    sh = make_sharded_weights(w, n, prune_sparsity=0.85)
    x = rng.standard_normal(256).astype(np.float32)
    with compat.set_mesh(mesh):
        y = np.asarray(espim_matvec_sharded(sh, jnp.asarray(x), mesh))
    wp = magnitude_prune(w, 0.85)
    np.testing.assert_allclose(y, wp @ x, rtol=2e-4, atol=2e-4)
