"""Paged KV cache: block-pool allocator, gather/scatter views, pspecs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs.registry import get_config
from repro.models import factory
from repro.serve.paged_cache import (ContiguousKVCache, PagedKVCache,
                                     classify_cache)
from repro.sharding import partition

KEY = jax.random.PRNGKey(0)


def _cfg():
    return get_config("granite-3-2b", reduced=True)


def test_classify_cache_families():
    cfg = _cfg()
    seq, state = classify_cache(factory.init_cache(cfg, 2, 32), 32)
    assert sorted(seq) == ["k", "v"] and state == []
    rcfg = get_config("rwkv6-1.6b", reduced=True)
    seq, state = classify_cache(factory.init_cache(rcfg, 2, 32), 32)
    assert seq == [] and sorted(state) == ["cm_x", "tm_x", "wkv"]
    zcfg = get_config("zamba2-2.7b", reduced=True)
    seq, state = classify_cache(factory.init_cache(zcfg, 2, 32), 32)
    assert sorted(seq) == ["k", "v"] and sorted(state) == ["conv", "ssm"]
    icfg = _cfg().replace(kv_cache_dtype="int8")
    seq, _ = classify_cache(factory.init_cache(icfg, 2, 32), 32)
    assert sorted(seq) == ["k", "k_scale", "v", "v_scale"]


def test_allocator_alloc_free_reuse():
    pc = PagedKVCache(_cfg(), batch_slots=2, max_len=32, block_size=8,
                      num_blocks=6)
    assert pc.blocks_per_slot == 4
    assert pc.reserve(0, 20)        # 3 blocks
    assert pc.reserve(1, 24)        # 3 blocks
    pc.ensure(0, 9)                 # 2 blocks materialize
    assert pc.blocks_in_use == 2 and pc.free_blocks == 4
    # pool fully spoken for: a third reservation must fail
    assert not pc.reserve(1, 32)    # slot 1 would now need 4 > avail
    pc.ensure(1, 24)
    assert pc.blocks_in_use == 5
    used = set(pc.block_tables[0, :2]) | set(pc.block_tables[1, :3])
    assert len(used) == 5           # distinct physical blocks
    pc.free_slot(0)
    assert pc.free_blocks == 3 and pc.n_blocks[0] == 0
    assert pc.reserve(0, 24)        # freed blocks admit the next request
    pc.ensure(0, 24)
    assert pc.blocks_in_use == 6


def test_ensure_is_covered_by_reservation():
    pc = PagedKVCache(_cfg(), batch_slots=1, max_len=32, block_size=8,
                      num_blocks=4)
    assert pc.reserve(0, 32)
    for n in range(1, 33):
        pc.ensure(0, n)             # lazy growth never fails
    assert pc.blocks_in_use == 4


def test_paged_gather_scatter_roundtrip():
    """Rows written through pages must read back exactly at their
    positions in the gathered contiguous view."""
    cfg = _cfg()
    pc = PagedKVCache(cfg, batch_slots=2, max_len=24, block_size=8)
    rng = np.random.default_rng(0)
    chunk = 6
    rows = {n: jnp.asarray(rng.standard_normal(
        (cfg.n_layers, chunk) + pc.pages[n].shape[3:]).astype(np.float32))
        for n in pc.seq_names}
    pc.reserve(1, 14)
    pc.ensure(1, 10)
    pc.scatter_chunk(1, rows, start=4, count=5)   # 6th row dropped
    view = pc.gather_view(np.array([0, 9]))
    for n in pc.seq_names:
        got = np.asarray(view[n][:, 1, 4:9])
        np.testing.assert_array_equal(got, np.asarray(rows[n][:, :5]))
        assert np.all(np.asarray(view[n][:, 1, 9:10]) == 0)  # dropped row


def test_paged_decode_write_masks_inactive_slots():
    cfg = _cfg()
    pc = PagedKVCache(cfg, batch_slots=2, max_len=16, block_size=8)
    for i in range(2):
        pc.reserve(i, 8)
        pc.ensure(i, 4)
    lens = np.array([2, 3])
    view = pc.gather_view(lens)
    fake = {n: jnp.ones_like(view[n]) for n in pc.seq_names}
    pc.apply_decode(fake, lens, active=np.array([True, False]))
    # regather from the arena: the active slot's row landed in its page,
    # the inactive slot's write was dropped (OOB physical block)
    pc._view_dirty = True
    view2 = pc.gather_view(lens)
    assert np.all(np.asarray(view2["k"][:, 0, 2]) == 1)   # active write
    assert np.all(np.asarray(view2["k"][:, 1, 3]) == 0)   # dropped write


def test_contiguous_wrapper_matches_interface():
    cfg = _cfg()
    cc = ContiguousKVCache(cfg, batch_slots=2, max_len=16)
    assert cc.reserve(0, 999) and cc.blocks_needed(999) == 0
    view = cc.gather_view(np.array([0, 0]))
    assert view["k"].shape[2] == 16


def test_paged_cache_pspecs():
    cfg = _cfg()
    pc = PagedKVCache(cfg, batch_slots=2, max_len=32, block_size=8)
    n = jax.device_count()
    mesh = compat.make_mesh((n, 1), ("data", "model"))
    specs = partition.paged_cache_pspecs(pc.pages, mesh)
    for name, spec in specs.items():
        assert spec[0] is None          # layer-stack never sharded
        assert spec[2] is None          # intra-block rows never split
    # a sharded device_put must succeed (blocks divide the data axis or
    # fall back to replication)
    arr = jax.device_put(pc.pages["k"],
                         jax.sharding.NamedSharding(mesh, specs["k"]))
    assert arr.shape == pc.pages["k"].shape
