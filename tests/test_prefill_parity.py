"""Chunked prefill must reproduce token-by-token decode replay: the final
prompt position's logits and the first post-prefill decode step's logits,
for dense and ESPIM-sparse engines, across attention (dense / int8-cache)
and non-attention (rwkv / mamba-hybrid) families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.sparse_model import (prefill_chunk_sparse,
                                     decode_step_sparse, sparsify_mlps)
from repro.models import factory

KEY = jax.random.PRNGKey(0)
PLEN, CHUNK, MAXLEN = 11, 4, 32


def _prompt(cfg):
    return (np.arange(1, PLEN + 1, dtype=np.int32) % cfg.vocab_size)


def _replay(cfg, params, toks, dec):
    cache = factory.init_cache(cfg, 1, MAXLEN)
    for i in range(len(toks)):
        lg, cache = dec(params, cache,
                        {"tokens": jnp.asarray(toks[i : i + 1])[None, :]})
    last = lg[:, 0]
    lg2, cache = dec(params, cache, {"tokens": jnp.asarray([[7]],
                                                           jnp.int32)})
    return last, lg2[:, 0]


def _chunked(cfg, params, toks, pf, dec):
    cache = factory.init_cache(cfg, 1, MAXLEN)
    pos = 0
    while pos < len(toks):
        nv = min(CHUNK, len(toks) - pos)
        tk = np.zeros((1, CHUNK), np.int32)
        tk[0, :nv] = toks[pos : pos + nv]
        lg, cache = pf(params, cache,
                       {"tokens": jnp.asarray(tk),
                        "n_valid": jnp.asarray([nv], jnp.int32)})
        pos += nv
    last = lg[:, nv - 1]
    lg2, cache = dec(params, cache, {"tokens": jnp.asarray([[7]],
                                                           jnp.int32)})
    return last, lg2[:, 0]


def _assert_close(got, ref, what):
    err = float(jnp.abs(got - ref).max() / jnp.abs(ref).max())
    assert err < 5e-5, f"{what}: chunked/replay mismatch {err}"


@pytest.mark.parametrize("arch", ["granite-3-2b", "rwkv6-1.6b",
                                  "zamba2-2.7b"])
def test_chunked_prefill_matches_replay(arch):
    cfg = get_config(arch, reduced=True)
    params = factory.init_params(cfg, KEY)
    dec = jax.jit(lambda p, c, b: factory.decode_step(cfg, p, c, b))
    pf = jax.jit(lambda p, c, b: factory.prefill_chunk(cfg, p, c, b))
    toks = _prompt(cfg)
    ref_last, ref_dec = _replay(cfg, params, toks, dec)
    got_last, got_dec = _chunked(cfg, params, toks, pf, dec)
    _assert_close(got_last, ref_last, f"{arch} last-prompt logits")
    _assert_close(got_dec, ref_dec, f"{arch} first-decode logits")


def test_chunked_prefill_matches_replay_int8_cache():
    cfg = get_config("granite-3-2b",
                     reduced=True).replace(kv_cache_dtype="int8")
    params = factory.init_params(cfg, KEY)
    dec = jax.jit(lambda p, c, b: factory.decode_step(cfg, p, c, b))
    pf = jax.jit(lambda p, c, b: factory.prefill_chunk(cfg, p, c, b))
    toks = _prompt(cfg)
    ref_last, ref_dec = _replay(cfg, params, toks, dec)
    got_last, got_dec = _chunked(cfg, params, toks, pf, dec)
    _assert_close(got_last, ref_last, "int8 last-prompt logits")
    _assert_close(got_dec, ref_dec, "int8 first-decode logits")


def test_sparse_chunked_prefill_matches_sparse_replay():
    """The ESPIM-format engine: prompt through the batched chunked-ELL
    MLPs in C-token slabs must equal token replay through the same
    kernels."""
    cfg = get_config("llama7b-espim", reduced=True)
    params = factory.init_params(cfg, KEY)
    sparse = sparsify_mlps(cfg, params, 0.9)
    dec = jax.jit(lambda p, c, b: decode_step_sparse(cfg, p, sparse, c, b))
    pf = jax.jit(
        lambda p, c, b: prefill_chunk_sparse(cfg, p, sparse, c, b))
    toks = _prompt(cfg)
    ref_last, ref_dec = _replay(cfg, params, toks, dec)
    got_last, got_dec = _chunked(cfg, params, toks, pf, dec)
    _assert_close(got_last, ref_last, "sparse last-prompt logits")
    _assert_close(got_dec, ref_dec, "sparse first-decode logits")


def test_prefill_call_count_bound():
    """TTFT cost: first token in <= ceil(prompt_len/chunk) + 1 jitted
    calls (the final chunk's logits yield it with zero extra steps)."""
    from repro.serve.engine import Request, ServeEngine
    cfg = get_config("granite-3-2b", reduced=True)
    params = factory.init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=MAXLEN,
                      prefill_chunk=CHUNK)
    eng.submit(Request(rid=0, prompt=list(range(1, PLEN + 1)),
                       max_new_tokens=1))
    eng.run()
    assert eng.stats.tokens_generated == 1
    assert eng.stats.steps <= -(-PLEN // CHUNK) + 1
