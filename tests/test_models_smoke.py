"""Per-arch smoke tests (deliverable f): a REDUCED same-family config runs
one forward/train step on CPU with correct shapes and no NaNs, and the
decode path agrees with teacher forcing."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ASSIGNED, cells, get_config
from repro.models import factory

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=24):
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            KEY, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["embeddings"] = jax.random.normal(KEY, (b, s, cfg.d_model),
                                                jnp.float32)
        batch["vis_mask"] = jnp.zeros((b, s), bool).at[:, :4].set(True)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    cfg = get_config(arch, reduced=True)
    params = factory.init_params(cfg, KEY)
    batch = _batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: factory.loss_fn(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    logits, aux = factory.apply_train(cfg, params, batch)
    assert logits.shape == (2, 24, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_forward(arch):
    cfg = get_config(arch, reduced=True)
    params = factory.init_params(cfg, KEY)
    b, s = 2, 12
    batch = _batch(cfg, b, s)
    if cfg.family == "vlm":
        # decode path has no visual splice; compare text-only
        batch.pop("embeddings"), batch.pop("vis_mask")
    logits, _ = jax.jit(
        lambda p, bb: factory.apply_train(cfg, p, bb))(params, batch)
    cache = factory.init_cache(cfg, b, s + 4)
    if cfg.family == "audio":
        from repro.models import whisper
        cache = whisper.prime_cross(cfg, params, cache, batch["frames"])
    dec = jax.jit(lambda p, c, bb: factory.decode_step(cfg, p, c, bb))
    outs = []
    for i in range(s):
        lgi, cache = dec(params, cache, {"tokens": batch["tokens"][:, i:i+1]})
        outs.append(lgi[:, 0])
    got = jnp.stack(outs, axis=1)
    err = float(jnp.abs(got - logits).max() / jnp.abs(logits).max())
    assert err < 5e-5, f"{arch}: decode/forward mismatch {err}"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_grads_flow_everywhere(arch):
    """Every parameter receives a nonzero-somewhere, finite gradient."""
    cfg = get_config(arch, reduced=True)
    params = factory.init_params(cfg, KEY)
    batch = _batch(cfg)
    g = jax.grad(lambda p: factory.loss_fn(cfg, p, batch)[0])(params)
    flat = jax.tree_util.tree_flatten_with_path(g)[0]
    dead = [jax.tree_util.keystr(k) for k, v in flat
            if not bool(jnp.isfinite(v).all())]
    assert not dead, f"non-finite grads: {dead}"


def test_cells_enumeration():
    all_cells = cells(include_skipped=True)
    assert len(all_cells) == 40  # 10 archs x 4 shapes
    runnable = [c for c in all_cells if c[2] is None]
    skipped = [c for c in all_cells if c[2] is not None]
    # long_500k skipped exactly for the 8 non-sub-quadratic archs
    assert len(skipped) == 8
    assert all(s[1] == "long_500k" for s in skipped)
    assert {"zamba2-2.7b", "rwkv6-1.6b"} == {
        c[0] for c in runnable if c[1] == "long_500k"}


def test_shapes_table():
    assert SHAPES["train_4k"].kind == "train"
    assert SHAPES["prefill_32k"].kind == "prefill"
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
