"""End-to-end ESPIM-format serving of a full LM: decode with packed sparse
MLPs must match the dense decode of the same *pruned* model exactly."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.sparse_model import (decode_step_sparse, sparse_stats,
                                     sparsify_mlps)
from repro.models import factory

KEY = jax.random.PRNGKey(0)


def test_sparse_serving_matches_pruned_dense():
    cfg = get_config("llama7b-espim", reduced=True)
    params = factory.init_params(cfg, KEY)
    sparse = sparsify_mlps(cfg, params, sparsity=0.9, row_tile=32)

    # dense reference: same model with the *pruned* MLP weights
    pruned_params = jax.tree.map(lambda x: x, params)
    for name in ("w_gate", "w_up", "w_down"):
        pruned_params["layers"]["mlp"][name] = sparse[f"{name}_pruned"]

    B, S = 2, 6
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    cache_d = factory.init_cache(cfg, B, S + 2)
    cache_s = factory.init_cache(cfg, B, S + 2)
    dense_lg, sparse_lg = [], []
    dec = jax.jit(lambda p, c, b: factory.decode_step(cfg, p, c, b))
    for i in range(S):
        batch = {"tokens": toks[:, i:i + 1]}
        lg_d, cache_d = dec(pruned_params, cache_d, batch)
        lg_s, cache_s = decode_step_sparse(cfg, params, sparse, cache_s,
                                           batch)
        dense_lg.append(lg_d)
        sparse_lg.append(lg_s)
    d = jnp.concatenate(dense_lg, axis=1)
    s = jnp.concatenate(sparse_lg, axis=1)
    err = float(jnp.abs(d - s).max() / jnp.abs(d).max())
    assert err < 5e-4, err

    stats = sparse_stats(sparse)
    assert stats["w_gate"]["pad_frac"] < 0.6  # balance keeps padding sane
    # bucketed widths must never pad worse than the single global width
    for proj in ("gateup", "down"):
        assert (stats[proj]["pad_frac"]
                <= stats[proj]["single_bucket_pad_frac"] + 1e-9)
    # per-layer breakdown covers the stack and averages to the aggregate
    per_layer = stats["gateup"]["pad_frac_per_layer"]
    assert len(per_layer) == cfg.n_layers
    assert abs(np.mean(per_layer) - stats["gateup"]["pad_frac"]) < 1e-6


def test_sparsify_preserves_pattern():
    cfg = get_config("granite-3-2b", reduced=True)
    params = factory.init_params(cfg, KEY)
    sparse = sparsify_mlps(cfg, params, sparsity=0.8, row_tile=32)
    pruned = np.asarray(sparse["w_up_pruned"])
    assert abs((pruned == 0).mean() - 0.8) < 0.05
    stats = sparse_stats(sparse)
    assert stats["w_up"]["nnz"] == int((pruned != 0).sum())
    total = sum(int((np.asarray(sparse[f"{n}_pruned"]) != 0).sum())
                for n in ("w_gate", "w_up", "w_down"))
    assert stats["total"]["nnz"] == total
