"""Partition rules: specs must be valid (divisible), big weights must be
sharded, small/norm leaves replicated, caches laid out sanely."""
import jax
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ASSIGNED, get_config
from repro.launch import specs as S
from repro.sharding import partition


def _mesh(shape=(4, 4), axes=("data", "model")):
    # an abstract stand-in is enough for spec derivation; use real devices=1
    devs = np.array(jax.devices() * (np.prod(shape) // len(jax.devices())
                                     + 1))[: np.prod(shape)]
    return jax.sharding.Mesh(devs.reshape(shape), axes)


MESH = _mesh()


def _check_divisible(tree, specs, mesh):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    sflat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )[0]
    bad = []
    for (kp, leaf), (_, spec) in zip(flat, sflat):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            size = partition.mesh_axis_size(mesh, ax)
            if dim % size:
                bad.append((jax.tree_util.keystr(kp), leaf.shape, spec))
    assert not bad, bad


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_specs_divisible(arch):
    cfg = get_config(arch)
    shapes = S.params_specs(cfg)
    specs = partition.param_pspecs(shapes, MESH)
    _check_divisible(shapes, specs, MESH)


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "dbrx-132b",
                                  "zamba2-2.7b", "rwkv6-1.6b"])
def test_big_weights_are_sharded(arch):
    """No multi-MB weight may end up fully replicated (the w_up bug class)."""
    cfg = get_config(arch)
    shapes = S.params_specs(cfg)
    specs = partition.param_pspecs(shapes, MESH)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    sflat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )[0]
    offenders = []
    for (kp, leaf), (_, spec) in zip(flat, sflat):
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        if nbytes > 64 * 2**20 and all(a is None for a in tuple(spec)):
            offenders.append((jax.tree_util.keystr(kp), leaf.shape))
    assert not offenders, offenders


def test_moe_experts_on_model_axis():
    cfg = get_config("dbrx-132b")
    shapes = S.params_specs(cfg)
    specs = partition.param_pspecs(shapes, MESH)
    moe = specs["layers"]["moe"]
    assert tuple(moe["w_gate"])[1] == "model"   # (L, E, D, F): EP
    assert tuple(moe["w_down"])[1] == "model"


def test_row_parallel_projections():
    cfg = get_config("granite-3-2b")
    shapes = S.params_specs(cfg)
    specs = partition.param_pspecs(shapes, MESH)
    assert tuple(specs["layers"]["mlp"]["w_down"])[1] == "model"
    assert tuple(specs["layers"]["attn"]["wo"])[1] == "model"
    # column-parallel counterparts
    assert tuple(specs["layers"]["mlp"]["w_up"])[-1] == "model"
    assert tuple(specs["layers"]["attn"]["wq"])[-1] == "model"


def test_norms_replicated():
    cfg = get_config("granite-3-2b")
    shapes = S.params_specs(cfg)
    specs = partition.param_pspecs(shapes, MESH)
    assert all(a is None for a in tuple(specs["final_norm"]["w"]))
    assert all(a is None for a in tuple(specs["layers"]["ln1"]["w"]))


def test_batch_specs_and_fallback():
    cfg = get_config("granite-3-2b")
    b = S.train_batch_specs(cfg, SHAPES["train_4k"])
    specs = partition.batch_pspecs(b, MESH)
    assert tuple(specs["tokens"])[0] in ("data", ("data",))  # P() normalizes
    # batch=1 long_500k: replicate instead of crashing
    b1 = S.decode_batch_specs(cfg, SHAPES["long_500k"])
    specs1 = partition.batch_pspecs(b1, MESH)
    assert tuple(specs1["tokens"])[0] is None


@pytest.mark.parametrize("arch", ["granite-3-2b", "zamba2-2.7b",
                                  "whisper-small", "rwkv6-1.6b"])
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    shape = SHAPES["decode_32k"]
    cache = S.cache_specs(cfg, shape)
    specs = partition.cache_pspecs(cache, MESH)
    _check_divisible(cache, specs, MESH)


def test_pod_axis_composes():
    mesh3 = _mesh((2, 2, 4), ("pod", "data", "model"))
    cfg = get_config("granite-3-2b")
    b = S.train_batch_specs(cfg, SHAPES["train_4k"])
    specs = partition.batch_pspecs(b, mesh3)
    assert tuple(specs["tokens"])[0] == ("pod", "data")
    assert partition.mesh_axis_size(mesh3, ("pod", "data")) == 4


def test_sparse_pack_pspecs_shard_packed_rows():
    """Pack-group device arrays: packed-row dim -> 'model' when divisible
    (devices as banks), perms replicated, layer/chunk dims never split."""
    from repro.core.sparse_model import sparsify_model
    from repro.models import factory

    cfg = get_config("llama7b-espim", reduced=True)
    params = factory.init_params(cfg, jax.random.PRNGKey(0))
    sparse = sparsify_model(cfg, params, 0.9, projections="all",
                            row_tile=32)
    specs = partition.sparse_pack_pspecs(sparse, MESH)
    assert set(specs) == set(sparse["groups"])
    for name, g in sparse["groups"].items():
        gs = specs[name]
        assert gs["perm"] == jax.sharding.PartitionSpec(None, None)
        assert len(gs["buckets"]) == len(g["buckets"])
        for b, bs in zip(g["buckets"], gs["buckets"]):
            for key, spec in bs.items():
                arr = b[key]
                assert len(spec) == arr.ndim
                assert spec[0] is None          # layer-stack dim: the scan
                row_ax = spec[1]
                assert row_ax in (None, "model")
                if row_ax == "model":
                    assert arr.shape[1] % partition.mesh_axis_size(
                        MESH, "model") == 0
                assert all(a is None for a in spec[2:])  # chunk/width dims
    # quantized packs: srow scales shard with their rows
    sq = sparsify_model(cfg, params, 0.9, projections="mlp", row_tile=32,
                        quant="int8")
    qspecs = partition.sparse_pack_pspecs(sq, MESH)
    for name, g in sq["groups"].items():
        for b, bs in zip(g["buckets"], qspecs[name]["buckets"]):
            assert set(bs) == {"q", "cols", "srow"}
