"""Crash-consistent snapshot/restore (DESIGN.md §13, serve/snapshot.py).

The contract under test: a snapshot taken at any step boundary restores
into a fresh engine that finishes every request with greedy output
bit-identical to the uninterrupted run and zero leaked blocks — across
fp/int8/int4 packs and dense engines — while a snapshot bound to a
different pack fingerprint, a tampered snapshot, or a wrong-version
snapshot is refused loudly.
"""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.sparse_model import sparsify_model
from repro.models import factory
from repro.serve import faults
from repro.serve import snapshot as snapmod
from repro.serve.engine import Request, ServeEngine
from repro.serve.snapshot import (SNAPSHOT_VERSION, SnapshotIntegrityError)
from repro.core.integrity import PackIntegrityError

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def llama():
    cfg = get_config("llama7b-espim", reduced=True)
    params = factory.init_params(cfg, KEY)
    return cfg, params


@pytest.fixture(scope="module")
def packs(llama):
    cfg, params = llama
    return {q: sparsify_model(cfg, params, 0.9, row_tile=32,
                              quant=None if q == "fp" else q)
            for q in ("fp", "int8", "int4")}


def _eng(cfg, params, sparse, **kw):
    kw.setdefault("max_len", 48)
    return ServeEngine(cfg, params, batch_slots=2, sparse=sparse,
                       block_size=8, prefill_chunk=8, validate_arena=True,
                       **kw)


def _reqs(n=3, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(1, 400, 4 + 2 * i).tolist(),
                    max_new_tokens=5) for i in range(n)]


# --------------------------------------------------------------------------
# round-trip parity across quant modes (the crash drill end-to-end)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("quant", ["fp", "int8", "int4"])
def test_crash_drill_round_trip_parity(llama, packs, quant):
    cfg, params = llama
    drill = faults.run_crash_drill(cfg, params, packs[quant], seed=1,
                                   n_requests=3, max_new_tokens=5,
                                   kill_step=7)
    faults.check_crash_drill(drill)
    assert drill["exact_parity"] and drill["leaked_blocks"] == 0
    assert drill["snapshot_bytes"] < 16_384, \
        "control-plane snapshot must not carry KV planes"


def test_crash_drill_dense_engine(llama):
    cfg, params = llama
    drill = faults.run_crash_drill(cfg, params, None, seed=2,
                                   n_requests=2, max_new_tokens=4)
    faults.check_crash_drill(drill)


def test_crash_drill_random_kill_steps(llama, packs):
    """The kill step is arbitrary by contract — exercise an early, a mid
    and a late boundary explicitly rather than trusting one draw."""
    cfg, params = llama
    base = faults.run_crash_drill(cfg, params, packs["fp"], seed=0,
                                  n_requests=2, max_new_tokens=4,
                                  kill_step=1)
    for frac in (0.5, 0.9):
        k = max(1, int(base["total_steps"] * frac))
        d = faults.run_crash_drill(cfg, params, packs["fp"], seed=0,
                                   n_requests=2, max_new_tokens=4,
                                   kill_step=k)
        faults.check_crash_drill(d)
    faults.check_crash_drill(base)


# --------------------------------------------------------------------------
# snapshot format, digest and rejection paths
# --------------------------------------------------------------------------
def test_snapshot_schema_and_json_round_trip(llama, packs):
    cfg, params = llama
    eng = _eng(cfg, params, packs["fp"])
    for r in _reqs():
        eng.submit(r)
    for _ in range(5):
        eng.step()
    snap = eng.snapshot()
    assert snap["version"] == SNAPSHOT_VERSION
    assert snap["pack_fingerprint"] == packs["fp"]["fingerprint"]
    assert snap["digest"] == snapmod.snapshot_digest(snap)
    origins = {e["origin"] for e in snap["requests"]}
    assert origins <= {"slot", "queue"}
    # slot residents serialize before the wait queue (admission order)
    slots_seen = [e["origin"] for e in snap["requests"]]
    assert slots_seen == sorted(slots_seen, key=lambda o: o != "slot")
    again = snapmod.loads(snapmod.dumps(snap))
    assert again == snap


def test_restore_rejects_fingerprint_mismatch(llama, packs):
    cfg, params = llama
    eng = _eng(cfg, params, packs["fp"])
    eng.submit(_reqs(1)[0])
    eng.step()
    snap = eng.snapshot()
    other = _eng(cfg, params, packs["int8"])
    with pytest.raises(SnapshotIntegrityError, match="different weights"):
        other.restore(snap)
    dense = _eng(cfg, params, None)
    with pytest.raises(SnapshotIntegrityError):
        dense.restore(snap)
    # the refusal is part of the pack-integrity family
    assert issubclass(SnapshotIntegrityError, PackIntegrityError)


def test_restore_rejects_tamper_version_and_busy_engine(llama, packs):
    cfg, params = llama
    eng = _eng(cfg, params, packs["fp"])
    reqs = _reqs(2)
    for r in reqs:
        eng.submit(r)
    for _ in range(4):
        eng.step()
    snap = eng.snapshot()

    tampered = dict(snap)
    tampered["requests"] = [dict(e) for e in snap["requests"]]
    tampered["requests"][0]["output"] = \
        list(tampered["requests"][0]["output"]) + [7]
    with pytest.raises(SnapshotIntegrityError, match="digest"):
        snapmod.validate_snapshot(tampered)

    wrong_version = dict(snap, version=SNAPSHOT_VERSION + 1)
    wrong_version["digest"] = snapmod.snapshot_digest(wrong_version)
    with pytest.raises(SnapshotIntegrityError, match="version"):
        snapmod.validate_snapshot(wrong_version)

    fresh = _eng(cfg, params, packs["fp"], max_len=eng.max_len * 2)
    with pytest.raises(SnapshotIntegrityError, match="max_len"):
        fresh.restore(snap)            # engine max_len differs
    with pytest.raises(RuntimeError, match="idle"):
        eng.restore(snap)              # engine still has residents


def test_restore_reattaches_caller_requests(llama, packs):
    cfg, params = llama
    eng = _eng(cfg, params, packs["fp"])
    reqs = _reqs(2)
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    snap = eng.snapshot()
    assert snap["requests"], "kill point too late: nothing in flight"
    fresh = _eng(cfg, params, packs["fp"])
    held = {r.rid: r for r in reqs if not r.done}
    restored = fresh.restore(snap, held)
    assert restored
    assert all(r is held[r.rid] for r in restored)
    assert fresh.stats.restored_requests == len(restored)
    # committed-output requests are shielded from future shedding
    assert all(m.preempts >= 1 for r, m in fresh.scheduler.pending
               if r.output)
    bad = {rid: Request(rid=rid, prompt=[1, 2, 3]) for rid in held}
    fresh2 = _eng(cfg, params, packs["fp"])
    with pytest.raises(SnapshotIntegrityError, match="prompt"):
        fresh2.restore(snap, bad)


def test_restore_bypasses_shed_policy(llama, packs):
    """Restored work is not new load: a bounded queue shallower than the
    snapshot's request count must still take every restored request."""
    cfg, params = llama
    eng = _eng(cfg, params, packs["fp"])
    reqs = _reqs(3)
    for r in reqs:
        eng.submit(r)
    for _ in range(4):
        eng.step()
    snap = eng.snapshot()
    n = len(snap["requests"])
    assert n == 3
    fresh = _eng(cfg, params, packs["fp"], max_queue_depth=1,
                 shed_policy="reject")
    restored = fresh.restore(snap, {r.rid: r for r in reqs})
    assert len(restored) == n and fresh.stats.requests_shed == 0
    fresh.run()
    fresh.check_arena()
    states = fresh.stats.latency_summary()["states"]
    assert states == {"completed": 3}
