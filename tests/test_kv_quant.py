"""int8 KV cache (serving deployment default for decode cells): the
scales fold into scores/probs exactly, so accuracy loss is bounded by
int8 quantization of K/V vectors (~1%)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.models import factory

KEY = jax.random.PRNGKey(0)


def _roll(cfg, params, toks):
    B, S = toks.shape
    cache = factory.init_cache(cfg, B, S + 4)
    dec = jax.jit(lambda p, c, b: factory.decode_step(cfg, p, c, b))
    outs = []
    for i in range(S):
        lg, cache = dec(params, cache, {"tokens": toks[:, i:i + 1]})
        outs.append(lg[:, 0])
    return jnp.stack(outs, 1)


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen2.5-14b"])
def test_int8_cache_close_to_bf16(arch):
    cfg = get_config(arch, reduced=True)
    params = factory.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 10), 0, cfg.vocab_size)
    lg16 = _roll(cfg, params, toks)
    lg8 = _roll(cfg.replace(kv_cache_dtype="int8"), params, toks)
    err = float(jnp.abs(lg8 - lg16).max() / jnp.abs(lg16).max())
    assert err < 5e-2, err


def test_int8_cache_structure():
    cfg = get_config("granite-3-2b", reduced=True).replace(
        kv_cache_dtype="int8")
    cache = factory.init_cache(cfg, 2, 8)
    assert cache["k"].dtype == jnp.int8
    assert cache["k_scale"].shape == cache["k"].shape[:-1]
    # greedy decode still produces valid tokens
    params = factory.init_params(cfg, KEY)
    from repro.serve.serve_step import serve_step_fn
    nxt, _, cache = serve_step_fn(cfg, params, cache,
                                  {"tokens": jnp.ones((2, 1), jnp.int32)})
    assert nxt.shape == (2, 1)
    assert int(cache["len"][0]) == 1
