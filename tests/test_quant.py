"""The quantized value-plane subsystem (DESIGN.md section 9): round-trip
error bounds per scale group, unit-scale bit-exactness of the quantized
SpMV vs the fp SpMV, kernel-variant parity (int8 container + nibble-packed
int4, ref + Pallas), serialization, the int8 fallback rule, bytes/bits_per_
nnz accounting, and end-to-end quantized decode staying cosine >= 0.99 on
the tiny LM."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fall back to a seeded random sweep
    from _hypothesis_fallback import given, settings, st

from repro.configs.registry import get_config
from repro.core.espim_linear import ESPIMLinear
from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import pack_ell, pack_ell_chunked
from repro.core.sparse_model import (decode_step_sparse, sparse_stats,
                                     sparsify_mlps)
from repro.kernels import ops, ref
from repro.models import factory
from repro.quant import QuantSpec, default_spec, quantize_pack
from repro.quant.calibrate import QMAX, group_rel_error
from repro.quant.qpack import (QuantizedValuePlane, dequantize_plane,
                               nibble_pack, nibble_unpack)

KEY = jax.random.PRNGKey(0)


def _rand_pack(rng, r, c, s, chunk_cols=64, row_tile=32):
    w = magnitude_prune(rng.standard_normal((r, c)).astype(np.float32), s)
    return w, pack_ell_chunked(w, row_tile=row_tile, chunk_cols=chunk_cols)


# --------------------------------------------------------------------------
# 1) round-trip property: dequant(quant(V)) error within the per-group bound
# --------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(r=st.integers(8, 150), c=st.integers(4, 200), s=st.floats(0.0, 0.95),
       bits=st.sampled_from([8, 4]),
       calib=st.sampled_from(["absmax", "percentile"]),
       seed=st.integers(0, 999))
def test_roundtrip_error_within_group_bound(r, c, s, bits, calib, seed):
    rng = np.random.default_rng(seed)
    w, pack = _rand_pack(rng, r, c, s, row_tile=8)
    spec = QuantSpec(bits=bits, calib=calib, group_rows=32)
    plane = quantize_pack(pack, spec)
    deq = plane.dequantize()
    g = plane.group_rows
    # per-group checks over valid cells
    err = np.abs(np.where(pack.valid, deq - pack.values, 0.0))
    gerr = err.reshape(-1, g * err.shape[1] * err.shape[2]).max(axis=1)
    rel = group_rel_error(pack.values, deq, pack.valid, g).reshape(-1)
    gb = plane.group_bits.reshape(-1)
    sc = plane.scales.reshape(-1)
    for i in range(plane.n_groups):
        if gb[i] == 8 and (bits == 8 and calib == "percentile"):
            continue          # clipped int8: no elementwise LSB promise
        if gb[i] == 8:
            # absmax int8 (direct or fallback): half-LSB elementwise bound
            assert gerr[i] <= sc[i] / 2 + 1e-7, (i, gerr[i], sc[i])
        else:
            # surviving int4 group: the fallback rule's relative bound
            assert rel[i] <= spec.err_bound + 1e-7, (i, rel[i])
    # zeros quantize to zeros: the sparsity pattern never grows
    assert not np.any(deq[~pack.valid])


def test_nibble_pack_roundtrip():
    rng = np.random.default_rng(3)
    codes = rng.integers(-8, 8, size=(6, 2, 14), dtype=np.int8)
    assert (nibble_unpack(nibble_pack(codes)) == codes).all()
    got = np.asarray(ref.nibble_unpack_ref(jnp.asarray(nibble_pack(codes))))
    assert (got == codes).all()


# --------------------------------------------------------------------------
# 2) unit scales: the quantized SpMV is bit-exact vs the fp SpMV
# --------------------------------------------------------------------------
@pytest.mark.parametrize("impl,c,s,b,cc", [
    # pallas: fp and quant kernels share the multiply-reduce schedule —
    # bit-exact at any shape
    ("pallas", 150, 0.8, 3, 48),
    # ref, dot regime (Lc * B > MULRED_MAX_BLOCK): quant takes the same
    # einsum as the fp lowering
    ("ref", 300, 0.4, 4, 300),
    # ref, fused multiply-reduce regime: compare against the Pallas fp
    # kernel, whose schedule the mulred lowering mirrors exactly
    ("ref-mulred", 150, 0.8, 3, 48),
])
def test_unit_scale_spmv_bit_exact(impl, c, s, b, cc):
    rng = np.random.default_rng(5)
    w = magnitude_prune(
        rng.integers(-100, 101, size=(64, c)).astype(np.float32), s)
    pack = pack_ell_chunked(w, row_tile=32, chunk_cols=cc)
    codes = pack.values.astype(np.int8)          # integer values ARE codes
    assert (codes.astype(np.float32) == pack.values).all()
    scales = np.ones(pack.r_pad // 32, np.float32)
    plane = QuantizedValuePlane(q=codes, scales=scales,
                                group_bits=np.full_like(scales, 8, np.uint8),
                                group_rows=32, bits=8, nnz=pack.stats.nnz)
    x = jnp.asarray(rng.standard_normal((c, b)), jnp.float32)
    vals = jnp.asarray(pack.values)
    cols = jnp.asarray(pack.cols, jnp.int32)
    if impl == "ref-mulred":
        assert cols.shape[-1] * b <= ref.MULRED_MAX_BLOCK
        want = ops.espim_spmv_batched(vals, cols, x,
                                      chunk_cols=pack.chunk_cols,
                                      impl="pallas")
        got = ops.espim_spmv_batched_quant(
            jnp.asarray(plane.q), cols, jnp.asarray(scales), x,
            chunk_cols=pack.chunk_cols, group_rows=32, impl="ref")
    else:
        if impl == "ref":
            assert cols.shape[-1] * b > ref.MULRED_MAX_BLOCK
        want = ops.espim_spmv_batched(vals, cols, x,
                                      chunk_cols=pack.chunk_cols, impl=impl)
        got = ops.espim_spmv_batched_quant(
            jnp.asarray(plane.q), cols, jnp.asarray(scales), x,
            chunk_cols=pack.chunk_cols, group_rows=32, impl=impl)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# 3) kernel variants: Pallas int8 + nibble-packed int4 vs ref vs oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("mode,r,c,cc,rt", [
    ("int8", 128, 300, 64, 128),
    ("int4", 128, 300, 64, 128),
    ("int8", 96, 200, 512, 32),
    ("int4", 256, 137, 48, 64),      # odd Lc: nibble pad slot
])
def test_quant_kernel_parity(mode, r, c, cc, rt):
    rng = np.random.default_rng(11)
    w, pack = _rand_pack(rng, r, c, 0.88, chunk_cols=cc, row_tile=rt)
    dev = ops.pack_to_device(pack, quant=mode)
    if mode == "int4":
        assert pack.qplane.storage == "nib4"
        assert dev.values.dtype == jnp.uint8
        assert 2 * dev.values.shape[-1] >= dev.cols.shape[-1]
    x = jnp.asarray(rng.standard_normal((c, 5)), jnp.float32)
    # oracle: dequantized plane through the fp reference
    oracle = ops.espim_spmv_batched(
        jnp.asarray(pack.qplane.dequantize()),
        jnp.asarray(pack.cols, jnp.int32), x,
        chunk_cols=pack.chunk_cols, impl="ref")
    for impl in ("ref", "pallas"):
        got = ops.espim_spmv_batched_quant(
            dev.values, dev.cols, dev.scales, x, chunk_cols=dev.chunk_cols,
            group_rows=dev.group_rows, impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                                   rtol=1e-5, atol=1e-5)


def test_quant_plain_ell_ref_only():
    rng = np.random.default_rng(13)
    w = magnitude_prune(rng.standard_normal((32, 64)).astype(np.float32),
                        0.8)
    pack = pack_ell(w, row_tile=8)
    plane = quantize_pack(pack, QuantSpec(bits=8, group_rows=8))
    x = jnp.asarray(rng.standard_normal((64, 2)), jnp.float32)
    got = ops.espim_spmv_batched_quant(
        jnp.asarray(plane.q[:, 0]), jnp.asarray(pack.cols, jnp.int32),
        jnp.asarray(plane.scales), x, group_rows=plane.group_rows,
        impl="ref")
    want = ref.espim_spmv_batched_ref(
        jnp.asarray(plane.dequantize()[:, 0]),
        jnp.asarray(pack.cols, jnp.int32), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError, match="column-chunked"):
        ops.espim_spmv_batched_quant(
            jnp.asarray(plane.q[:, 0]), jnp.asarray(pack.cols, jnp.int32),
            jnp.asarray(plane.scales), x, group_rows=plane.group_rows,
            impl="pallas")


def test_env_impl_pin_covers_quant(monkeypatch):
    monkeypatch.setenv(ops.ENV_IMPL, "ref")
    rng = np.random.default_rng(17)
    w, pack = _rand_pack(rng, 32, 64, 0.8, chunk_cols=32, row_tile=8)
    dev = ops.pack_to_device(pack, quant="int8")
    x = jnp.asarray(rng.standard_normal((64, 2)), jnp.float32)
    # impl="pallas" must be overridden by the env pin (no pallas trace)
    y = ops.espim_spmv_batched_quant(
        dev.values, dev.cols, dev.scales, x, chunk_cols=dev.chunk_cols,
        group_rows=dev.group_rows, impl="pallas")
    assert y.shape == (pack.r_pad, 2)


# --------------------------------------------------------------------------
# 4) serialization + fallback rule + byte accounting
# --------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["int8", "int4"])
def test_serialization_roundtrip(mode):
    rng = np.random.default_rng(19)
    # heavy-tailed values so int4 mixes surviving and fallback groups
    w = magnitude_prune(
        (rng.standard_normal((96, 120)) ** 3).astype(np.float32), 0.7)
    pack = pack_ell_chunked(w, row_tile=32, chunk_cols=64)
    plane = quantize_pack(pack, default_spec(mode))
    back = QuantizedValuePlane.from_bytes(plane.to_bytes())
    np.testing.assert_array_equal(back.q, plane.q)
    np.testing.assert_array_equal(back.scales, plane.scales)
    np.testing.assert_array_equal(back.group_bits, plane.group_bits)
    assert back.group_rows == plane.group_rows
    assert back.nnz == plane.nnz
    np.testing.assert_array_equal(back.dequantize(), plane.dequantize())


def test_int8_fallback_rule():
    rng = np.random.default_rng(23)
    w, pack = _rand_pack(rng, 128, 160, 0.6, chunk_cols=64, row_tile=32)
    # a tight bound forces every group to int8; a loose one keeps int4
    tight = quantize_pack(pack, QuantSpec(bits=4, err_bound=1e-6),
                          attach=False)
    loose = quantize_pack(pack, QuantSpec(bits=4, err_bound=10.0),
                          attach=False)
    assert tight.n_fallback_groups == tight.n_groups
    assert tight.storage == "i8"
    assert loose.n_fallback_groups == 0
    assert loose.storage == "nib4" and loose.uniform_int4
    # fallback widens the codes and the bytes with it
    assert np.abs(tight.q).max() > QMAX[4]
    assert np.abs(loose.q).max() <= QMAX[4]
    assert tight.value_bytes > loose.value_bytes
    assert loose.bits_per_nnz < tight.bits_per_nnz
    # and the fallback groups reconstruct better than the int4 ones would
    err_t = np.abs(tight.dequantize() - pack.values).max()
    err_l = np.abs(loose.dequantize() - pack.values).max()
    assert err_t < err_l


def test_pack_stats_byte_planes():
    rng = np.random.default_rng(29)
    w, pack = _rand_pack(rng, 64, 256, 0.85, chunk_cols=64, row_tile=32)
    fp_vb = pack.stats.value_plane_bytes
    fp_bits = pack.stats.bits_per_nnz
    assert fp_vb == 4 * pack.stats.padded_slots
    assert pack.stats.index_plane_bytes == fp_vb
    assert fp_bits >= 32.0                   # fp32 + padding overhead
    quantize_pack(pack, default_spec("int8"))  # attaches + rewrites stats
    q_vb = pack.stats.value_plane_bytes
    assert q_vb < fp_vb / 3                  # ~4x down, modulo scale meta
    assert pack.stats.index_plane_bytes == fp_vb  # indices untouched
    assert pack.stats.bits_per_nnz < fp_bits / 3


# --------------------------------------------------------------------------
# 5) the serving stack: stats fields, ESPIMLinear, e2e cosine
# --------------------------------------------------------------------------
def _setup(quant=None, sparsity=0.9):
    cfg = get_config("llama7b-espim", reduced=True)
    params = factory.init_params(cfg, KEY)
    sparse = sparsify_mlps(cfg, params, sparsity, row_tile=32, quant=quant)
    return cfg, params, sparse


def test_sparse_stats_reports_byte_planes():
    cfg, params, sp_fp = _setup()
    cfg, params, sp_q = _setup(quant="int8")
    st_fp, st_q = sparse_stats(sp_fp), sparse_stats(sp_q)
    assert st_fp["quant"] == "none" and st_q["quant"] == "int8"
    for proj in ("gateup", "down", "w_gate", "w_up", "w_down", "total"):
        for k in ("value_plane_bytes", "index_plane_bytes", "bits_per_nnz"):
            assert k in st_fp[proj] and k in st_q[proj], (proj, k)
        # quant shrinks only the value plane
        assert st_q[proj]["value_plane_bytes"] < st_fp[proj][
            "value_plane_bytes"] / 3
        assert st_q[proj]["index_plane_bytes"] == st_fp[proj][
            "index_plane_bytes"]
    for proj in ("gateup", "down"):
        per_layer = st_q[proj]["value_plane_bytes_per_layer"]
        assert len(per_layer) == cfg.n_layers
        assert sum(per_layer) == st_q[proj]["value_plane_bytes"]
    assert st_q["total"]["bytes_per_token"] < st_fp["total"]["bytes_per_token"]


def test_espim_linear_quant():
    rng = np.random.default_rng(31)
    w = rng.standard_normal((96, 200)).astype(np.float32)
    x = jnp.asarray(rng.standard_normal(200), jnp.float32)
    want = magnitude_prune(w, 0.9) @ np.asarray(x)
    for mode in ("int8", "int4"):
        lin = ESPIMLinear.from_dense(w, prune_sparsity=0.9, row_tile=32,
                                     quant=mode)
        assert lin.sparse
        assert isinstance(lin.weights, ops.QuantEspimWeights)
        y = np.asarray(lin(x, impl="ref"))
        rel = np.abs(y - want).max() / np.abs(want).max()
        assert rel < (0.02 if mode == "int8" else 0.2), (mode, rel)


@pytest.mark.parametrize("mode,min_cos", [("int8", 0.999), ("int4", 0.99)])
def test_e2e_quantized_decode_cosine(mode, min_cos):
    """End-to-end: quantized sparse decode logits vs the fp sparse decode
    on the tiny LM stay cosine >= 0.99 (int8 holds >= 0.999)."""
    cfg, params, sp_fp = _setup()
    _, _, sp_q = _setup(quant=mode)
    B, S = 2, 4
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    outs = {}
    for name, sp in (("fp", sp_fp), ("q", sp_q)):
        cache = factory.init_cache(cfg, B, S + 2)
        dec = jax.jit(lambda p, c, b, _sp=sp: decode_step_sparse(
            cfg, p, _sp, c, b))
        lgs = []
        for i in range(S):
            lg, cache = dec(params, cache, {"tokens": toks[:, i:i + 1]})
            lgs.append(lg)
        outs[name] = np.asarray(jnp.concatenate(lgs, axis=1)).ravel()
    a, b = outs["q"], outs["fp"]
    cos = float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-12))
    assert cos >= min_cos, (mode, cos)


def test_quantized_decode_matches_dequantized_dense():
    """The fused quantized MLP path must equal dense decode over the
    *dequantized* copies sparsify_mlps exports — same effective weights on
    both datapaths (the section 9 analogue of the PR 3 parity contract)."""
    cfg, params, sparse = _setup(quant="int8")
    pruned = jax.tree.map(lambda x: x, params)
    for name in ("w_gate", "w_up", "w_down"):
        pruned["layers"]["mlp"][name] = sparse[f"{name}_pruned"]
    toks = jax.random.randint(KEY, (2, 1), 0, cfg.vocab_size)
    cache_d = factory.init_cache(cfg, 2, 4)
    cache_s = factory.init_cache(cfg, 2, 4)
    lg_d, _ = factory.decode_step(cfg, pruned, cache_d, {"tokens": toks})
    lg_s, _ = decode_step_sparse(cfg, params, sparse, cache_s,
                                 {"tokens": toks})
    err = float(jnp.abs(lg_d - lg_s).max() / jnp.abs(lg_d).max())
    assert err < 5e-4, err
