"""PR 10: schedule autotuning, the fingerprint-keyed plan cache, and the
fused decode epilogues.

Contracts under test
--------------------
* any *legal* candidate schedule produces bit-identical SpMV output
  (fp / int8 / int4, incl. odd-Lc nibble packing) — a schedule is a
  performance knob, never a semantics knob;
* the plan cache round-trips through JSON and invalidates the moment the
  pack bytes change (fingerprint-keyed);
* a warm cache makes the second tune of an identical pack perform ZERO
  candidate benchmarks (``autotune.search_stats``);
* the epilogue-fused engine is bit-identical to the unfused reference,
  greedy tokens included.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import autotune
from repro.autotune import (PlanCache, TunedPlan, autotune_pack,
                            pack_cache_key, reset_search_stats,
                            schedule_cost, search_stats)
from repro.configs.registry import get_config
from repro.core import sparse_model as SM
from repro.core.sdds import (DEFAULT_SCHEDULE, KernelSchedule,
                             enumerate_schedules, schedule_legal)
from repro.core.sparse_format import chunk_pack, pack_ell
from repro.kernels import ops
from repro.models import factory

KEY = jax.random.PRNGKey(0)


def _int_pack(n_rows=96, n_cols=300, density=0.12, seed=0):
    """Integer-valued f32 pack: sums are exact in fp32, so every legal
    schedule (any accumulation order) must be bit-identical."""
    rng = np.random.default_rng(seed)
    w = rng.integers(-3, 4, (n_rows, n_cols)).astype(np.float32)
    w *= rng.random((n_rows, n_cols)) < density
    return pack_ell(w), rng


def _unscatter(cp, y):
    perm = np.asarray(cp.perm)
    out = np.zeros((cp.n_rows,) + y.shape[1:], np.float32)
    keep = perm >= 0
    out[perm[keep]] = np.asarray(y)[keep]
    return out


# --------------------------------------------------------------------------
# 1) candidate space legality
# --------------------------------------------------------------------------
def test_enumerated_schedules_are_legal():
    cands = enumerate_schedules(r_pad=128, n_cols=700)
    assert cands, "empty candidate space"
    for s in cands:
        assert schedule_legal(s, r_pad=128, n_cols=700)
    # the hand-picked default leads when legal (tie-break stability)
    assert cands[0].effective_key("pallas") == \
        DEFAULT_SCHEDULE.effective_key("pallas") or \
        not schedule_legal(DEFAULT_SCHEDULE, r_pad=128, n_cols=700)
    # chunk widths never exceed the matrix
    assert all(s.chunk_cols <= 700 for s in cands)


def test_int4_candidates_have_even_block_l():
    for s in enumerate_schedules(r_pad=128, n_cols=700, quant="int4"):
        assert s.block_l % 2 == 0
    assert not schedule_legal(KernelSchedule(block_l=65), r_pad=128,
                              n_cols=700, quant="int4")


def test_schedule_cost_penalizes_padding_and_launches():
    kw = dict(r_pad=128, n_chunks=2, chunk_width=64, b=8)
    s = KernelSchedule(chunk_cols=256)
    assert schedule_cost(s, **kw, pad_frac=0.5) > \
        schedule_cost(s, **kw, pad_frac=0.0)
    # smaller blocks -> more grid steps -> higher launch charge
    small = KernelSchedule(chunk_cols=256, block_r=8, block_l=8)
    assert schedule_cost(small, **kw) > schedule_cost(s, **kw)
    # narrower value plane is cheaper traffic
    assert schedule_cost(s, **kw, quant="int4") < schedule_cost(s, **kw)


# --------------------------------------------------------------------------
# 2) any legal schedule is bit-identical (fp / int8 / int4, odd Lc)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("quant", [None, "int8", "int4"])
def test_legal_schedules_bit_identical(quant):
    pack, rng = _int_pack()
    x = jnp.asarray(rng.integers(-3, 4, (pack.n_cols, 4)), jnp.float32)
    base = None
    for s in enumerate_schedules(r_pad=pack.r_pad, n_cols=pack.n_cols,
                                 quant=quant)[:6]:
        cp = chunk_pack(pack, s.chunk_cols)
        cols = jnp.asarray(cp.cols, jnp.int32)
        if quant is None:
            y = ops.espim_spmv_batched(jnp.asarray(cp.values), cols, x,
                                       chunk_cols=cp.chunk_cols, impl="ref",
                                       schedule=s)
        else:
            from repro.quant import default_spec, quantize_pack
            plane = quantize_pack(cp, default_spec(quant))
            srow = plane.row_scales().astype(np.float32)
            y = ops.espim_spmv_batched_quant(
                jnp.asarray(plane.device_codes()), cols, None, x,
                chunk_cols=cp.chunk_cols, group_rows=plane.group_rows,
                impl="ref", schedule=s) * srow[:, None]
        out = _unscatter(cp, y)
        if base is None:
            base = out
        else:
            np.testing.assert_array_equal(out, base, err_msg=repr(s))


def test_odd_lc_nibble_schedule_parity():
    """width_multiple=1 produces odd chunk widths — the int4 nibble pack
    pads a column; the launch must still be exact."""
    pack, rng = _int_pack(n_rows=64, n_cols=150, density=0.15, seed=3)
    x = jnp.asarray(rng.integers(-2, 3, (150, 3)), jnp.float32)
    from repro.quant import default_spec, quantize_pack
    outs = []
    for cc in (64, 150):
        cp = chunk_pack(pack, cc, width_multiple=1)
        plane = quantize_pack(cp, default_spec("int4"))
        srow = plane.row_scales().astype(np.float32)
        y = ops.espim_spmv_batched_quant(
            jnp.asarray(plane.device_codes()),
            jnp.asarray(cp.cols, jnp.int32), None, x,
            chunk_cols=cp.chunk_cols, group_rows=plane.group_rows,
            impl="ref") * srow[:, None]
        outs.append(_unscatter(cp, y))
    np.testing.assert_array_equal(outs[0], outs[1])


# --------------------------------------------------------------------------
# 3) plan cache: round-trip, persistence, fingerprint invalidation
# --------------------------------------------------------------------------
def test_plan_cache_roundtrip(tmp_path):
    path = str(tmp_path / "plans.json")
    cache = PlanCache(path)
    cache.put("k1", {"schedule": {"chunk_cols": 256, "block_r": 64,
                                  "block_l": 128, "gather": "block"},
                     "best_us": 12.5, "candidates": 3,
                     "created_by": "search"})
    # a fresh instance loads the persisted table
    warm = PlanCache(path)
    entry = warm.get("k1")
    assert entry is not None and entry["best_us"] == 12.5
    assert warm.hits == 1 and warm.misses == 0
    assert warm.get("nope") is None and warm.misses == 1
    # corrupt file -> empty table, no crash
    with open(path, "w") as f:
        f.write("{not json")
    assert len(PlanCache(path)) == 0


def test_cache_key_invalidates_on_pack_mutation():
    pack, _ = _int_pack(seed=5)
    k1 = pack_cache_key(pack, b=8, quant=None, impl="ref", backend="cpu")
    # same content, same key (recompute is deterministic)
    pack2, _ = _int_pack(seed=5)
    assert pack_cache_key(pack2, b=8, quant=None, impl="ref",
                          backend="cpu") == k1
    # flip one value -> fingerprint moves -> key moves
    pack2.values[0, 0] += 1.0
    from repro.core.integrity import fingerprint_pack
    pack2.fingerprint = fingerprint_pack(pack2)
    assert pack_cache_key(pack2, b=8, quant=None, impl="ref",
                          backend="cpu") != k1
    # launch context is part of the key
    assert pack_cache_key(pack, b=16, quant=None, impl="ref",
                          backend="cpu") != k1
    assert pack_cache_key(pack, b=8, quant="int4", impl="ref",
                          backend="cpu") != k1


def test_cache_key_is_plan_free():
    """The same weight content keys identically no matter which chunk
    width a previous tune picked (else a retune could never hit)."""
    pack, _ = _int_pack(seed=7)
    kw = dict(b=8, quant=None, impl="ref", backend="cpu")
    k_plain = pack_cache_key(pack, **kw)
    assert pack_cache_key(pack, **kw) == k_plain
    # chunked variants of the same pack key off their exact planes —
    # different chunkings are different artifacts, but each is stable
    c1 = pack_cache_key(chunk_pack(pack, 64), **kw)
    c2 = pack_cache_key(chunk_pack(pack, 64), **kw)
    assert c1 == c2


# --------------------------------------------------------------------------
# 4) warm cache -> zero candidate benchmarks
# --------------------------------------------------------------------------
def test_warm_cache_skips_search():
    pack, _ = _int_pack()
    cache = PlanCache()
    reset_search_stats()
    plan = autotune_pack(pack, b=4, cache=cache, max_candidates=2,
                         iters=1, warmup=0)
    assert plan.source == "search"
    assert search_stats["benchmarks"] == 2
    n = search_stats["benchmarks"]
    plan2 = autotune_pack(pack, b=4, cache=cache, max_candidates=2,
                          iters=1, warmup=0)
    assert plan2.source == "cache"
    assert plan2.schedule == plan.schedule
    assert search_stats["benchmarks"] == n, "cache hit ran benchmarks"
    reset_search_stats()


def test_pack_to_device_autotune_attaches_plan(tmp_path):
    pack, _ = _int_pack()
    cache = PlanCache(str(tmp_path / "plans.json"))
    tune = {"b": 4, "cache": cache, "max_candidates": 2, "iters": 1,
            "warmup": 0}
    reset_search_stats()
    w = ops.pack_to_device(pack, autotune=True, tune=tune)
    assert isinstance(w.schedule, TunedPlan)
    assert w.schedule.source == "search"
    assert w.chunk_cols == w.schedule.schedule.chunk_cols
    n = search_stats["benchmarks"]
    # second upload of the identical pack: plan-cache hit, ZERO benchmarks
    w2 = ops.pack_to_device(pack, autotune=True, tune=tune)
    assert w2.schedule.source == "cache"
    assert search_stats["benchmarks"] == n
    assert w2.chunk_cols == w.chunk_cols
    # the persisted JSON is the real carrier (file round-trip, not memory)
    doc = json.load(open(cache.path))
    assert doc["schema"] == "espim-plan-cache/v1"
    assert w.schedule.key in doc["plans"]
    # plain (non-tuned) uploads still work and carry no plan
    assert ops.pack_to_device(pack).schedule is None
    reset_search_stats()


def test_tuned_plan_provenance_shape():
    plan = TunedPlan(schedule=KernelSchedule(chunk_cols=256), source="search",
                     key="abc", best_us=9.0, candidates=3)
    d = plan.to_provenance()
    assert d["tuned"] is True and d["source"] == "search"
    assert d["chunk_cols"] == 256 and d["cache_key"] == "abc"
    prov = ops.provenance(impl="ref", schedule=d)
    assert prov["schedule"]["source"] == "search"
    # pre-autotune callers keep a null field (schema stays stable)
    assert ops.provenance(impl="ref")["schedule"] is None


def test_bench_history_fingerprint_forks_on_schedule():
    import importlib.util as iu
    spec = iu.spec_from_file_location(
        "bench_history", "benchmarks/bench_history.py")
    bh = iu.module_from_spec(spec)
    spec.loader.exec_module(bh)
    base = {"bench": "serve", "provenance": {"backend": "cpu", "impl": "ref"}}
    tuned = {"bench": "serve",
             "provenance": {"backend": "cpu", "impl": "ref",
                            "schedule": {"source": "search", "tuned": True}}}
    assert bh.fingerprint(base) != bh.fingerprint(tuned)


# --------------------------------------------------------------------------
# 5) epilogue fusion: ops-level and engine-level parity
# --------------------------------------------------------------------------
def test_ops_glu_epilogue_bit_exact_vs_unfused():
    rng = np.random.default_rng(11)
    rg, m, b = 64, 256, 4
    w = (rng.standard_normal((2 * rg, m))
         * (rng.random((2 * rg, m)) < 0.15)).astype(np.float32)
    cp = chunk_pack(pack_ell(w), 128)
    v = jnp.asarray(cp.values)
    c = jnp.asarray(cp.cols, jnp.int32)
    x = jnp.asarray(rng.standard_normal((m, b)), jnp.float32)
    from repro.models.layers import act_fn
    acc = ops.espim_spmv_batched(v, c, x, chunk_cols=cp.chunk_cols,
                                 impl="ref")
    want = act_fn("silu")(acc[:rg]) * acc[rg:]
    got = ops.espim_spmv_batched(v, c, x, chunk_cols=cp.chunk_cols,
                                 impl="ref", epilogue="glu")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # Pallas variant: different accumulation order, tight relative tol
    gp = ops.espim_spmv_batched(v, c, x, chunk_cols=cp.chunk_cols,
                                impl="pallas", epilogue="glu")
    np.testing.assert_allclose(np.asarray(gp), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # residual epilogue
    res = jnp.asarray(rng.standard_normal((2 * rg, b)), jnp.float32)
    got_r = ops.espim_spmv_batched(v, c, x, chunk_cols=cp.chunk_cols,
                                   impl="ref", epilogue="residual",
                                   residual=res)
    np.testing.assert_array_equal(np.asarray(got_r), np.asarray(acc + res))
    gp_r = ops.espim_spmv_batched(v, c, x, chunk_cols=cp.chunk_cols,
                                  impl="pallas", epilogue="residual",
                                  residual=res)
    np.testing.assert_allclose(np.asarray(gp_r), np.asarray(acc + res),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("quant", ["int8", "int4"])
def test_ops_quant_glu_epilogue_bit_exact(quant):
    rng = np.random.default_rng(13)
    rg, m, b = 64, 256, 3
    w = (rng.standard_normal((2 * rg, m))
         * (rng.random((2 * rg, m)) < 0.15)).astype(np.float32)
    cp = chunk_pack(pack_ell(w), 128)
    from repro.quant import default_spec, quantize_pack
    plane = quantize_pack(cp, default_spec(quant))
    codes = jnp.asarray(plane.device_codes())
    c = jnp.asarray(cp.cols, jnp.int32)
    srow = jnp.asarray(plane.row_scales().astype(np.float32))
    x = jnp.asarray(rng.standard_normal((m, b)), jnp.float32)
    from repro.models.layers import act_fn
    acc = ops.espim_spmv_batched_quant(
        codes, c, None, x, chunk_cols=cp.chunk_cols,
        group_rows=plane.group_rows, impl="ref")
    y = acc * srow[:, None]
    want = act_fn("silu")(y[:rg]) * y[rg:]
    got = ops.espim_spmv_batched_quant(
        codes, c, None, x, chunk_cols=cp.chunk_cols,
        group_rows=plane.group_rows, impl="ref", epilogue="glu", srow=srow)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    gq = ops.espim_spmv_batched_quant(
        codes, c, None, x, chunk_cols=cp.chunk_cols,
        group_rows=plane.group_rows, impl="pallas", epilogue="glu",
        srow=srow)
    np.testing.assert_allclose(np.asarray(gq), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_epilogue_requires_operands():
    rng = np.random.default_rng(17)
    w = (rng.standard_normal((64, 128))
         * (rng.random((64, 128)) < 0.2)).astype(np.float32)
    cp = chunk_pack(pack_ell(w), 64)
    v, c = jnp.asarray(cp.values), jnp.asarray(cp.cols, jnp.int32)
    x = jnp.asarray(rng.standard_normal((128, 2)), jnp.float32)
    with pytest.raises(ValueError, match="residual"):
        ops.espim_spmv_batched(v, c, x, chunk_cols=64, impl="ref",
                               epilogue="residual")
    with pytest.raises(ValueError, match="unknown epilogue"):
        ops.espim_spmv_batched(v, c, x, chunk_cols=64, impl="ref",
                               epilogue="rmsnorm")
    with pytest.raises(ValueError, match="srow"):
        ops.espim_spmv_batched_quant(v, c, None, x, chunk_cols=64,
                                     impl="ref", epilogue="glu")
    # plain 2-D layout cannot host a fused epilogue
    with pytest.raises(ValueError, match="chunked"):
        ops.espim_spmv_batched(v[:, 0], c[:, 0], x, impl="ref",
                               epilogue="glu")


@pytest.mark.parametrize("quant", [None, "int4"])
def test_engine_fused_epilogue_greedy_parity(quant):
    """The whole-layer engine with fused epilogues must be bit-identical
    to the unfused default-schedule engine — logits AND greedy tokens."""
    cfg = get_config("llama7b-espim", reduced=True)
    params = factory.init_params(cfg, KEY)
    sparse = SM.sparsify_model(cfg, params, 0.9, quant=quant)
    cache_f = factory.init_cache(cfg, 2, 8)
    cache_u = factory.init_cache(cfg, 2, 8)
    toks = jax.random.randint(KEY, (2, 1), 0, cfg.vocab_size)
    for _ in range(3):
        lf, cache_f = SM.decode_step_sparse(cfg, params, sparse, cache_f,
                                            {"tokens": toks}, epilogue=True)
        lu, cache_u = SM.decode_step_sparse(cfg, params, sparse, cache_u,
                                            {"tokens": toks}, epilogue=False)
        np.testing.assert_array_equal(np.asarray(lf), np.asarray(lu))
        tf = jnp.argmax(lf[:, -1], axis=-1)
        tu = jnp.argmax(lu[:, -1], axis=-1)
        np.testing.assert_array_equal(np.asarray(tf), np.asarray(tu))
        toks = tf[:, None].astype(jnp.int32)


def test_schedule_rides_on_chunked_pack():
    pack, _ = _int_pack()
    plan = TunedPlan(schedule=KernelSchedule(chunk_cols=128),
                     source="search", key="k")
    cp = chunk_pack(pack, plan.schedule.chunk_cols, schedule=plan)
    assert cp.schedule is plan
    # advisory metadata: the fingerprint ignores it
    cp2 = chunk_pack(pack, plan.schedule.chunk_cols)
    assert cp.fingerprint == cp2.fingerprint
