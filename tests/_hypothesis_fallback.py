"""Minimal stand-in for the subset of ``hypothesis`` the tests use.

When real hypothesis is installed the test modules import it directly; this
fallback keeps the property tests runnable (as seeded random sweeps, no
shrinking) on images without the dependency, so tier-1 collection never
breaks on an optional package.
"""
from __future__ import annotations

import random
import types

__all__ = ["given", "settings", "st"]

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


def _integers(lo, hi):
    return _Strategy(lambda rng: rng.randint(lo, hi))


def _floats(lo, hi):
    return _Strategy(lambda rng: rng.uniform(lo, hi))


def _booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def _sampled_from(seq):
    choices = list(seq)
    return _Strategy(lambda rng: rng.choice(choices))


st = types.SimpleNamespace(
    integers=_integers,
    floats=_floats,
    booleans=_booleans,
    sampled_from=_sampled_from,
)


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_max_examples",
                        getattr(fn, "_max_examples", _DEFAULT_EXAMPLES))
            rng = random.Random(0xE5917)  # fixed seed: deterministic sweep
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strategies.items()}
                fn(**drawn)
        # no functools.wraps: pytest must not see the original signature
        # (it would resolve the drawn arguments as fixtures)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
