"""Column-chunked ELL: pack/unpack roundtrip vs the plain-ELL oracle,
SDDS chunk-pass invariants, kernel parity (batched vs unbatched, pallas
vs ref), and dense-vs-sparse ESPIMLinear equivalence across sparsities
and chunk sizes."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fall back to a seeded random sweep
    from _hypothesis_fallback import given, settings, st

from repro.core.espim_linear import ESPIMLinear
from repro.core.pruning import magnitude_prune
from repro.core.sdds import chunk_cells, plan_chunks
from repro.core.sparse_format import (chunk_pack, ell_chunked_to_dense,
                                      ell_to_dense, pack_ell,
                                      pack_ell_chunked)
from repro.kernels import ops

RNG = np.random.default_rng(0)


def _rand_sparse(r, c, s, seed=0):
    rng = np.random.default_rng(seed)
    return magnitude_prune(rng.standard_normal((r, c)).astype(np.float32), s)


# --------------------------------------------------------------------------
# Format roundtrip
# --------------------------------------------------------------------------
def test_chunked_roundtrip_matches_plain():
    w = _rand_sparse(200, 333, 0.8)
    plain = pack_ell(w, row_tile=64)
    chunked = chunk_pack(plain, 100)
    np.testing.assert_allclose(ell_chunked_to_dense(chunked),
                               ell_to_dense(plain))


@settings(max_examples=20, deadline=None)
@given(r=st.integers(1, 150), c=st.integers(1, 200),
       s=st.floats(0.0, 0.98), tile=st.sampled_from([8, 32, 128]),
       cc=st.sampled_from([16, 64, 512]), seed=st.integers(0, 999))
def test_property_chunked_roundtrip(r, c, s, tile, cc, seed):
    w = _rand_sparse(r, c, s, seed)
    pack = pack_ell_chunked(w, row_tile=tile, chunk_cols=cc)
    np.testing.assert_allclose(ell_chunked_to_dense(pack), w)
    assert pack.stats.nnz == int((w != 0).sum())
    assert pack.r_pad % tile == 0
    # chunk-local ids stay inside the slab
    assert pack.cols.min() >= 0
    assert pack.cols.max() < pack.chunk_cols
    # within a chunk, valid cells keep ascending column order
    for i in range(pack.r_pad):
        for k in range(pack.n_chunks):
            cols = pack.cols[i, k, pack.valid[i, k]]
            assert (np.diff(cols) > 0).all()


def test_chunk_cells_stable_grouping():
    cols = np.array([3, 130, 5, 260, 140, 7])
    order, counts = chunk_cells(cols, 128, 3)
    grouped = cols[order]
    np.testing.assert_array_equal(grouped, [3, 5, 7, 130, 140, 260])
    np.testing.assert_array_equal(counts, [3, 2, 1])


def test_plan_chunks_accounting():
    counts = np.zeros((256, 4), np.int64)
    counts[:128, 0] = 5          # tile 0 touches only chunk 0
    counts[128:, 2] = 13         # tile 1 touches only chunk 2
    plan = plan_chunks(counts, chunk_cols=100, row_tile=128, n_cols=400)
    assert plan.total_blocks == 8
    assert plan.active_blocks == 2
    assert plan.chunk_width == 16          # 13 rounded up to 8-multiple
    assert plan.nnz == 128 * 5 + 128 * 13
    assert plan.x_bytes_per_step == 100 * 4
    assert plan.x_bytes_full == 400 * 4


# --------------------------------------------------------------------------
# Kernel parity
# --------------------------------------------------------------------------
@pytest.mark.parametrize("cc", [64, 256])
def test_batched_matches_unbatched_columns(cc):
    """Each column of the batched kernel's output must equal the
    unbatched kernel run on that column."""
    w = _rand_sparse(128, 500, 0.85, seed=5)
    pack = pack_ell_chunked(w, chunk_cols=cc)
    vals = jnp.asarray(pack.values)
    cols = jnp.asarray(pack.cols, jnp.int32)
    x = jnp.asarray(RNG.standard_normal((500, 4)), jnp.float32)
    for impl in ("ref", "pallas"):
        yb = ops.espim_spmv_batched(vals, cols, x, chunk_cols=cc, impl=impl)
        for b in range(4):
            y1 = ops.espim_spmv(vals, cols, x[:, b], chunk_cols=cc,
                                impl=impl)
            np.testing.assert_allclose(np.asarray(yb[:, b]), np.asarray(y1),
                                       rtol=1e-5, atol=1e-5)


def test_kernel_handles_rpad_not_multiple_of_block():
    """A pack whose R_pad is not a multiple of the default 128 row block
    (small row_tile) must shrink the block, not misaddress the grid."""
    w = _rand_sparse(320, 500, 0.8, seed=11)
    pack = pack_ell_chunked(w, row_tile=64, chunk_cols=128)
    assert pack.r_pad % 128 != 0
    dev = ops.pack_to_device(pack)
    x1 = jnp.asarray(RNG.standard_normal(500), jnp.float32)
    xb = jnp.asarray(RNG.standard_normal((500, 4)), jnp.float32)
    for impl in ("ref", "pallas"):
        np.testing.assert_allclose(np.asarray(ops.espim_matvec(dev, x1,
                                                               impl=impl)),
                                   w @ np.asarray(x1), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(ops.espim_matvec(dev, xb,
                                                               impl=impl)),
                                   w @ np.asarray(xb), rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# Layer-level equivalence
# --------------------------------------------------------------------------
@pytest.mark.parametrize("sparsity", [0.6, 0.8, 0.95])
@pytest.mark.parametrize("chunk_cols", [128, 512])
def test_espim_linear_dense_sparse_equivalence(sparsity, chunk_cols):
    rng = np.random.default_rng(int(sparsity * 100) + chunk_cols)
    w = rng.standard_normal((256, 700)).astype(np.float32)
    lin = ESPIMLinear.from_dense(w, prune_sparsity=sparsity,
                                 chunk_cols=chunk_cols)
    assert lin.sparse
    wp = magnitude_prune(w, sparsity)
    x1 = jnp.asarray(rng.standard_normal(700), jnp.float32)
    xb = jnp.asarray(rng.standard_normal((3, 700)), jnp.float32)
    for impl in ("ref", "pallas"):
        np.testing.assert_allclose(np.asarray(lin(x1, impl=impl)),
                                   wp @ np.asarray(x1),
                                   rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(lin(xb, impl=impl)),
                                   np.asarray(xb) @ wp.T,
                                   rtol=3e-4, atol=3e-4)
