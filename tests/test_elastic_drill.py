"""End-to-end elastic drill (subprocess with 8 host devices):

  train on a (4, 2) mesh -> checkpoint -> "lose" half the cluster ->
  plan_elastic_mesh picks (2, 2) -> restore the checkpoint RESHARDED onto
  the new mesh -> continue training -> loss keeps decreasing.

This is the full failure-recovery path a 1000-node deployment exercises;
it runs in a subprocess because the device count must be set before jax
initializes.
"""
import os
import subprocess
import sys

DRILL = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from repro import compat
from repro.checkpoint import ckpt
from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config
from repro.optim.adamw import OptConfig
from repro.runtime.fault_tolerance import plan_elastic_mesh
from repro.sharding import partition
from repro.train import train_step as ts
from repro.data.pipeline import SyntheticPipeline

cfg = get_config("granite-3-2b", reduced=True)
shape = ShapeConfig("drill", seq_len=32, global_batch=8, kind="train")
ocfg = OptConfig(warmup_steps=2, decay_steps=100, peak_lr=1e-3)
pipe = SyntheticPipeline.for_model(cfg, shape)
ckpt_dir = os.environ["DRILL_CKPT"]

def build(mesh):
    key = jax.random.PRNGKey(0)
    state_shapes = jax.eval_shape(lambda: ts.init_train_state(cfg, ocfg, key))
    batch_shapes = jax.eval_shape(lambda: pipe.batch_at(0))
    fn, pspecs, bspecs = ts.make_train_step(cfg, ocfg, mesh, state_shapes,
                                            batch_shapes)
    return fn, pspecs, bspecs

# ---- phase 1: 8 devices, (4, 2) mesh ------------------------------------
mesh = compat.make_mesh((4, 2), ("data", "model"))
fn, pspecs, bspecs = build(mesh)
with compat.set_mesh(mesh):
    state = ts.init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
state = partition.logical_to_sharding(state, pspecs, mesh)
losses = []
with compat.set_mesh(mesh):
    for step in range(4):
        batch = partition.logical_to_sharding(pipe.batch_at(step), bspecs, mesh)
        state, m = fn(state, batch)
        losses.append(float(m["loss"]))
ckpt.save(ckpt_dir, 4, state, {"losses": losses})

# ---- phase 2: 4 healthy devices survive -> (2, 2) mesh -------------------
plan = plan_elastic_mesh(n_healthy=4, model_parallel=2)
assert plan.mesh_shape == (2, 2), plan
devs = np.array(jax.devices()[:4]).reshape(2, 2)
if compat.AXIS_TYPE_AUTO is not None:
    mesh2 = jax.sharding.Mesh(devs, ("data", "model"),
                              axis_types=(compat.AXIS_TYPE_AUTO,) * 2)
else:
    mesh2 = jax.sharding.Mesh(devs, ("data", "model"))
fn2, pspecs2, bspecs2 = build(mesh2)
state2, extra, step = ckpt.restore(ckpt_dir, mesh=mesh2, specs=pspecs2)
assert step == 4
with compat.set_mesh(mesh2):
    for s in range(step, step + 3):
        batch = partition.logical_to_sharding(pipe.batch_at(s), bspecs2, mesh2)
        state2, m = fn2(state2, batch)
        losses.append(float(m["loss"]))
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0], losses
print("ELASTIC_DRILL_OK", losses[0], "->", losses[-1])
"""


def test_elastic_remesh_drill(tmp_path):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"),
               DRILL_CKPT=str(tmp_path / "drill_ckpt"))
    out = subprocess.run([sys.executable, "-c", DRILL], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ELASTIC_DRILL_OK" in out.stdout
