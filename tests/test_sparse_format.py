"""ELL pack/unpack roundtrip, balance effectiveness, shard re-layout."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fall back to a seeded random sweep
    from _hypothesis_fallback import given, settings, st

from repro.core.pruning import magnitude_prune, sparten_balance
from repro.core.sparse_format import ell_to_dense, pack_ell, shard_ell


def test_roundtrip():
    rng = np.random.default_rng(0)
    w = magnitude_prune(rng.standard_normal((200, 333)).astype(np.float32),
                        0.8)
    pack = pack_ell(w, row_tile=64)
    np.testing.assert_allclose(ell_to_dense(pack), w)


@settings(max_examples=20, deadline=None)
@given(r=st.integers(1, 150), c=st.integers(1, 200),
       s=st.floats(0.0, 0.98), tile=st.sampled_from([8, 32, 128]),
       seed=st.integers(0, 999))
def test_property_roundtrip(r, c, s, tile, seed):
    rng = np.random.default_rng(seed)
    w = magnitude_prune(rng.standard_normal((r, c)).astype(np.float32), s)
    pack = pack_ell(w, row_tile=tile)
    np.testing.assert_allclose(ell_to_dense(pack), w)
    assert pack.stats.nnz == int((w != 0).sum())
    assert pack.r_pad % tile == 0


def test_balance_reduces_padding():
    """SparTen-style row balancing should cut the padded width vs natural
    order on a skewed matrix (its whole purpose, Section III-G)."""
    rng = np.random.default_rng(1)
    w = rng.standard_normal((512, 512)).astype(np.float32)
    # heavily skewed: first 64 rows dense, rest 95% sparse
    w[64:] = magnitude_prune(w[64:], 0.95)
    balanced = pack_ell(w, row_tile=128, balance=True)
    natural = pack_ell(w, row_tile=128, balance=False)
    assert sum(balanced.stats.tile_widths) < sum(natural.stats.tile_widths)


def test_sparten_balance_even_work():
    rng = np.random.default_rng(2)
    nnz = rng.integers(0, 500, size=640)
    assign = sparten_balance(nnz, 16)
    work = [sum(nnz[r] for r in rows) for rows in assign.bank_rows]
    assert max(work) - min(work) <= max(nnz)  # greedy bound


def test_shard_ell_layout():
    rng = np.random.default_rng(3)
    w = magnitude_prune(rng.standard_normal((300, 256)).astype(np.float32),
                        0.7)
    pack = pack_ell(w, row_tile=64)
    sh = shard_ell(pack, 4)
    assert sh["values"].shape[0] == 4
    # re-assemble and verify
    vals = sh["values"].reshape(-1, pack.ell_width)
    perm = sh["perm"].reshape(-1)
    y = np.zeros((300, pack.ell_width), np.float32)
    keep = perm >= 0
    y[perm[keep]] = vals[keep]
    orig = np.zeros_like(y)
    keep0 = pack.perm >= 0
    orig[pack.perm[keep0]] = pack.values[keep0]
    np.testing.assert_allclose(y, orig)
