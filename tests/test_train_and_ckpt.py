"""Training loop, exact-resume, microbatching, grad compression,
checkpoint atomicity/corruption/GC, elastic reshard restore."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.checkpoint import ckpt
from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config
from repro.optim import compression
from repro.optim.adamw import OptConfig, apply_updates, init_opt_state, lr_at
from repro.train.trainer import Trainer, TrainerConfig

CFG = get_config("granite-3-2b", reduced=True)
SHAPE = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")
OCFG = OptConfig(warmup_steps=2, decay_steps=200, peak_lr=1e-3)


def _mesh():
    return compat.make_mesh((1, 1), ("data", "model"))


def _trainer(tmp, **kw):
    return Trainer(CFG, SHAPE, _mesh(), OCFG,
                   TrainerConfig(ckpt_dir=tmp, ckpt_every=5, log_every=1000,
                                 **kw))


def test_loss_decreases(tmp_path):
    tr = _trainer(str(tmp_path / "a"))
    tr.init_or_resume()
    first = float(tr.train(1)["loss"])
    last = float(tr.train(25)["loss"])
    assert last < first - 0.1, (first, last)


def test_resume_is_bitwise(tmp_path):
    d = str(tmp_path / "b")
    tr = _trainer(d)
    tr.init_or_resume()
    tr.train(7)  # checkpoints at 5
    p7 = jax.tree.map(np.asarray, tr.state["params"])

    tr2 = _trainer(d)
    kind, step = tr2.init_or_resume()
    assert kind == "resumed" and step == 5
    tr2.train(2)
    p7b = jax.tree.map(np.asarray, tr2.state["params"])
    diffs = jax.tree.map(lambda a, b: float(np.abs(a - b).max()), p7, p7b)
    assert max(jax.tree.leaves(diffs)) == 0.0


def test_microbatch_matches_full_batch(tmp_path):
    tr1 = _trainer(str(tmp_path / "c1"))
    tr2 = _trainer(str(tmp_path / "c2"), microbatches=2)
    tr1.init_or_resume()
    tr2.init_or_resume()
    tr1.train(3)
    tr2.train(3)
    p1 = jax.tree.map(np.asarray, tr1.state["params"])
    p2 = jax.tree.map(np.asarray, tr2.state["params"])
    diffs = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(np.abs(a - b).max()), p1, p2))
    assert max(diffs) < 5e-5  # accumulation reorders float sums


def test_grad_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)) * 1e-3)}
    err = compression.init_error_state(g)
    # per-step error bounded by the quantization step
    deq, err = compression.ef_compress_grads(g, err)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert float(jnp.abs(deq["w"] - g["w"]).max()) <= scale * 0.5 + 1e-12
    # error feedback: accumulated sum converges to the true sum
    total_true = jnp.zeros((64, 64))
    total_sent = jnp.zeros((64, 64))
    err = compression.init_error_state(g)
    for i in range(50):
        gi = {"w": jnp.asarray(rng.standard_normal((64, 64)) * 1e-3)}
        total_true += gi["w"]
        deq, err = compression.ef_compress_grads(gi, err)
        total_sent += deq["w"]
    resid = float(jnp.abs(total_true - total_sent).max())
    assert resid <= scale * 2  # residual never accumulates past O(1) steps


def test_compressed_training_converges(tmp_path):
    tr = _trainer(str(tmp_path / "d"), compress_grads=True)
    tr.init_or_resume()
    first = float(tr.train(1)["loss"])
    last = float(tr.train(20)["loss"])
    assert last < first - 0.05


def test_lr_schedule():
    assert float(lr_at(OCFG, 0)) == 0.0
    assert float(lr_at(OCFG, 2)) == pytest.approx(OCFG.peak_lr)
    assert float(lr_at(OCFG, 200)) == pytest.approx(
        OCFG.peak_lr * OCFG.min_lr_frac, rel=1e-3)


def test_adamw_step_shapes():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    st = init_opt_state(OCFG, params)
    assert "master" in st  # bf16 params need a master copy
    g = {"w": jnp.ones((4, 4))}
    p2, st2, m = apply_updates(OCFG, params, g, st)
    assert p2["w"].dtype == jnp.bfloat16
    assert int(st2["step"]) == 1 and float(m["grad_norm"]) > 0


# ---------------- checkpoint machinery ------------------------------------
def test_ckpt_atomic_and_corrupt_detection(tmp_path):
    d = str(tmp_path / "ck")
    state = {"x": jnp.arange(10)}
    ckpt.save(d, 3, state, {"note": "hi"})
    # a torn write (.tmp dir) must be invisible
    os.makedirs(os.path.join(d, "step_00000007.tmp"))
    assert ckpt.latest_step(d) == 3
    st, extra, step = ckpt.restore(d)
    assert step == 3 and extra["note"] == "hi"
    np.testing.assert_array_equal(np.asarray(st["x"]), np.arange(10))
    # corruption detection
    with open(os.path.join(d, "step_00000003", "state.pkl"), "r+b") as f:
        f.seek(5)
        f.write(b"\x00\x01")
    with pytest.raises(IOError):
        ckpt.restore(d, 3)


def test_ckpt_gc(tmp_path):
    d = str(tmp_path / "gc")
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, {"s": jnp.asarray(s)})
    ckpt.gc_keep_last(d, keep=2)
    assert ckpt.list_steps(d) == [4, 5]


def test_elastic_reshard_restore(tmp_path):
    """Save under one mesh, restore onto a different mesh shape."""
    from repro.sharding import partition
    d = str(tmp_path / "el")
    state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save(d, 1, state)
    mesh = _mesh()  # 1x1 "new cluster"
    specs = {"w": jax.sharding.PartitionSpec("data", "model")}
    st, _, _ = ckpt.restore(d, 1, mesh=mesh, specs=specs)
    np.testing.assert_array_equal(np.asarray(st["w"]),
                                  np.arange(64).reshape(8, 8))
