"""Perf-regression sentinel (DESIGN.md §14): tolerance semantics in
``repro.telemetry.regression`` and headline extraction / ledger / gate
in ``benchmarks/bench_history.py`` — including a check of the real
checked-in smoke artifacts against the real checked-in baselines (the
same gate ``scripts/ci.sh`` runs)."""
import json
import pathlib
import sys

import pytest

from repro.telemetry.regression import (MetricSpec, PerfRegressionError,
                                        assert_no_regression, compare,
                                        format_findings)

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))           # benchmarks/ is a cwd package
from benchmarks import bench_history    # noqa: E402


# --------------------------------------------------------------------------
# MetricSpec / compare semantics
# --------------------------------------------------------------------------
def test_metric_spec_validation():
    with pytest.raises(ValueError):
        MetricSpec("x", "bigger_is_nicer")
    with pytest.raises(ValueError):
        MetricSpec("x", "exact", rel_tol=-0.1)


def test_exact_semantics():
    specs = [MetricSpec("bytes", "exact")]
    base = {"bytes": 4096.0}
    assert compare(base, {"bytes": 4096.0}, specs)[0]["ok"]
    f = compare(base, {"bytes": 4097.0}, specs)[0]
    assert not f["ok"] and "drifted" in f["detail"]
    # a tiny rel_tol admits float slop but nothing structural
    specs = [MetricSpec("bytes", "exact", rel_tol=1e-6)]
    assert compare(base, {"bytes": 4096.001}, specs)[0]["ok"]
    assert not compare(base, {"bytes": 4100.0}, specs)[0]["ok"]


def test_higher_better_uses_window_lo():
    # baseline window [90, 110]: the floor is lo/(1+tol) = 30
    specs = [MetricSpec("tok_s", "higher_better", rel_tol=2.0)]
    base = {"tok_s": {"value": 100.0, "lo": 90.0, "hi": 110.0}}
    assert compare(base, {"tok_s": 31.0}, specs)[0]["ok"]
    f = compare(base, {"tok_s": 29.0}, specs)[0]
    assert not f["ok"] and f["bound"] == pytest.approx(30.0)
    assert "[90, 110]" in f["detail"]


def test_lower_better_uses_window_hi():
    # ceiling is hi*(1+tol) = 0.6
    specs = [MetricSpec("ttft", "lower_better", rel_tol=2.0)]
    base = {"ttft": {"value": 0.15, "lo": 0.1, "hi": 0.2}}
    assert compare(base, {"ttft": 0.59}, specs)[0]["ok"]
    f = compare(base, {"ttft": 0.61}, specs)[0]
    assert not f["ok"] and f["bound"] == pytest.approx(0.6)


def test_bare_number_baseline_is_degenerate_window():
    specs = [MetricSpec("v", "higher_better", rel_tol=0.0)]
    assert compare({"v": 5.0}, {"v": 5.0}, specs)[0]["ok"]
    assert not compare({"v": 5.0}, {"v": 4.9}, specs)[0]["ok"]


def test_spec_absent_from_baseline_is_skipped():
    specs = [MetricSpec("new_metric", "exact")]
    assert compare({}, {"new_metric": 1.0}, specs) == []


def test_metric_missing_from_observed_fails():
    specs = [MetricSpec("v", "exact")]
    f = compare({"v": 1.0}, {}, specs)[0]
    assert not f["ok"] and f["observed"] is None
    assert "missing" in f["detail"]
    assert "MISSING" in format_findings([f])


def test_assert_no_regression_message_names_the_offender():
    specs = [MetricSpec("single_stream.sparse.tok_s", "higher_better",
                        rel_tol=2.0),
             MetricSpec("pad_frac", "exact")]
    base = {"single_stream.sparse.tok_s": {"value": 100.0, "lo": 90.0,
                                           "hi": 110.0},
            "pad_frac": 0.125}
    ok = assert_no_regression(base, {"single_stream.sparse.tok_s": 95.0,
                                     "pad_frac": 0.125}, specs,
                              label="serve")
    assert len(ok) == 2 and all(f["ok"] for f in ok)
    with pytest.raises(PerfRegressionError) as ei:
        assert_no_regression(base, {"single_stream.sparse.tok_s": 9.0,
                                    "pad_frac": 0.125}, specs,
                             label="serve")
    msg = str(ei.value)
    # the offender, its baseline window, and the observed value — the
    # CI-log contract
    assert "single_stream.sparse.tok_s" in msg
    assert "[90, 110]" in msg and "9" in msg
    assert "pad_frac" not in msg.split("out of band")[1]
    assert ei.value.findings and len(ei.value.findings) == 2


# --------------------------------------------------------------------------
# bench_history: headline extraction, fingerprint, ledger, gate
# --------------------------------------------------------------------------
def _serve_doc():
    return {
        "bench": "serve", "smoke": True,
        "provenance": {"backend": "cpu", "impl": "ref", "quant": "none",
                       "attn": "dense", "pallas_interpret": False,
                       "packs": "abc123"},
        "scenarios": {"single_stream": {"modes": {"sparse": {
            "throughput_tok_s": 100.0, "throughput_p50_tok_s": 90.0,
            "bytes_per_token": 4096,
            "ttft_s": {"p50": 0.1, "p95": 0.2},
            "tpot_s": {"p50": 0.01, "p95": 0.02},
        }}}},
        "telemetry": {"pad_frac": 0.125},
    }


def test_headline_serve_extraction():
    h = bench_history.headline_serve(_serve_doc())
    assert h["single_stream.sparse.tok_s"] == {
        "value": 100.0, "lo": 90.0, "hi": 100.0}
    assert h["single_stream.sparse.ttft_p95_s"] == {
        "value": 0.2, "lo": 0.1, "hi": 0.2}
    assert h["single_stream.sparse.bytes_per_token"]["value"] == 4096.0
    assert h["pad_frac"]["value"] == 0.125


def test_headline_kernels_extraction():
    doc = {"smoke_result": {
        "fused_layer_us": 50.0, "fused_layer_p50_us": 45.0,
        "fused_layer_p95_us": 60.0, "dense_layer_us": 200.0,
        "max_rel_err": 1e-6,
        "quant": {"int8": {"fused_layer_us": 40.0, "bytes_per_token": 2048,
                           "bits_per_nnz": 9.0, "max_rel_err": 5e-3}},
        "attn_sparse": {"sparse_step_us": 300.0, "bytes_per_token": 8192,
                        "max_rel_err": 2e-6},
    }, "summary": {"min_speedup_at_B_ge_8": 1.4}}
    h = bench_history.headline_kernels(doc)
    assert h["fused_layer_us"] == {"value": 50.0, "lo": 45.0, "hi": 60.0}
    assert h["quant.int8.bits_per_nnz"]["value"] == 9.0
    assert h["attn_sparse.sparse_step_us"]["value"] == 300.0
    assert h["summary.min_speedup_at_B_ge_8"]["value"] == 1.4


def test_fingerprint_tracks_provenance_not_results():
    doc = _serve_doc()
    fp = bench_history.fingerprint(doc)
    assert fp == bench_history.fingerprint(doc)   # stable
    faster = _serve_doc()
    faster["scenarios"]["single_stream"]["modes"]["sparse"][
        "throughput_tok_s"] = 999.0
    assert bench_history.fingerprint(faster) == fp   # results don't key
    other = _serve_doc()
    other["provenance"]["quant"] = "int4"
    assert bench_history.fingerprint(other) != fp    # provenance does


def test_append_baseline_check_round_trip(tmp_path):
    doc = _serve_doc()
    hist = tmp_path / "H.jsonl"
    line = bench_history.append(doc, str(hist))
    assert line["bench"] == "serve" and line["smoke"]
    on_disk = json.loads(hist.read_text())
    assert on_disk["metrics"] == line["metrics"]
    assert on_disk["fingerprint"] == bench_history.fingerprint(doc)

    base = bench_history.make_baseline(doc)
    # same doc against its own baseline always passes
    findings = bench_history.check(doc, base)
    assert findings and all(f["ok"] for f in findings)
    # a 10x throughput cliff trips the windowed gate
    bad = _serve_doc()
    m = bad["scenarios"]["single_stream"]["modes"]["sparse"]
    m["throughput_tok_s"] /= 10.0
    m["throughput_p50_tok_s"] /= 10.0
    with pytest.raises(PerfRegressionError) as ei:
        bench_history.check(bad, base)
    assert "single_stream.sparse.tok_s" in str(ei.value)
    # an exact invariant drift trips too, regardless of size
    bad2 = _serve_doc()
    bad2["scenarios"]["single_stream"]["modes"]["sparse"][
        "bytes_per_token"] = 4095
    with pytest.raises(PerfRegressionError):
        bench_history.check(bad2, base)


@pytest.mark.parametrize("artifact,baseline", [
    ("BENCH_serve_smoke.json", "benchmarks/baselines/serve_smoke.json"),
    ("BENCH_kernels_smoke.json", "benchmarks/baselines/kernels_smoke.json"),
])
def test_checked_in_smokes_pass_their_baselines(artifact, baseline):
    """The artifacts and baselines committed together must agree — the
    exact gate ``scripts/ci.sh`` runs."""
    apath, bpath = REPO / artifact, REPO / baseline
    if not apath.exists() or not bpath.exists():
        pytest.skip(f"{artifact} not present in this checkout")
    doc = json.loads(apath.read_text())
    base = json.loads(bpath.read_text())
    assert base["baseline"] is True
    findings = bench_history.check(doc, base)
    assert findings and all(f["ok"] for f in findings)
