"""Shared benchmark substrate: the paper's LLaMA-7B workloads (Table III),
row-subsampling for CPU runtime, and CSV helpers.

Cycle counts scale linearly in matrix rows (banks process disjoint row
sets in lockstep; stripes per bank are proportional to rows), so we
simulate ``rows / scale`` rows and multiply cycles back — validated by
``test_scaling_linearity`` in the benchmark self-checks.  DRAM core clock
1.2 GHz converts cycles to microseconds.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.pruning import magnitude_prune

# Table III: LLaMA-7B matrices
WORKLOADS = {
    "attention.wk": (4096, 4096),
    "attention.wo": (4096, 4096),
    "attention.wq": (4096, 4096),
    "attention.wv": (4096, 4096),
    "feed_forward.w1": (11008, 4096),
    "feed_forward.w2": (4096, 11008),
    "feed_forward.w3": (11008, 4096),
}

SPARSITIES = (0.5, 0.6, 0.7, 0.8, 0.9)
DRAM_GHZ = 1.2
SCALE = int(os.environ.get("REPRO_BENCH_SCALE", "16"))


def workload_matrix(name: str, sparsity: float, scale: int | None = None,
                    seed: int = 0) -> tuple[np.ndarray, int]:
    """Pruned weight matrix for a Table III layer, row-subsampled by
    ``scale``.  Returns (matrix, scale_used)."""
    scale = SCALE if scale is None else scale
    r, c = WORKLOADS[name]
    rows = max(64, r // scale)
    actual_scale = r / rows
    rng = np.random.default_rng(seed + hash(name) % 1000)
    w = magnitude_prune(rng.standard_normal((rows, c)), sparsity)
    return w, actual_scale


def cycles_to_us(cycles: float) -> float:
    return cycles / (DRAM_GHZ * 1e3)


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.3f},{derived}"
