"""Benchmark harness: one function per paper table/figure plus the
roofline report.  Prints ``name,us_per_call,derived`` CSV.

Scale note: PIM figures run the Table III LLaMA-7B matrices row-subsampled
by REPRO_BENCH_SCALE (default 16; cycles scale back linearly — see
benchmarks/common.py).  Set REPRO_BENCH_SCALE=1 for the full matrices.

``summary`` mode instead aggregates every ``BENCH_*.json`` artifact in
the working directory into one table (bench x scenario x mode x tok/s x
bytes/token), so the repo's bench trajectory is readable at a glance::

    PYTHONPATH=src:. python benchmarks/run.py summary
"""
from __future__ import annotations

import argparse
import glob
import json
import sys
import time


def _fmt(v, nd=1):
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.{nd}f}"
    return str(v)


def summarize(paths: list[str]) -> list[str]:
    """One row per (artifact, scenario, mode): the serve scenarios'
    throughput + weight-stream bytes, the kernel smokes' layer timings,
    and the drill artifacts' health one-liners."""
    rows = [f"{'file':<28} {'scenario':<16} {'mode':<18} "
            f"{'tok/s':>8} {'bytes/tok':>10}  notes"]
    for path in sorted(paths):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            rows.append(f"{path:<28} UNREADABLE: {e}")
            continue
        name = path.split("/")[-1]
        bench = doc.get("bench") or ("kernels" if "smoke_result" in doc
                                     or "unbatched" in doc else "?")
        if bench == "serve":
            for scen_name, scen in doc.get("scenarios", {}).items():
                for mode, m in scen.get("modes", {}).items():
                    rows.append(
                        f"{name:<28} {scen_name:<16} {mode:<18} "
                        f"{_fmt(m.get('throughput_tok_s')):>8} "
                        f"{_fmt(m.get('bytes_per_token'), 0):>10}  "
                        f"ttft_p95={_fmt((m.get('ttft_s') or {}).get('p95'), 4)}s")
        elif bench == "kernels":
            res = doc.get("smoke_result") or {}
            cells = [("fp", res)] + list((res.get("quant") or {}).items())
            for mode, node in cells:
                if node.get("fused_layer_us") is None:
                    continue
                rows.append(
                    f"{name:<28} {'layer':<16} {mode:<18} "
                    f"{'-':>8} {_fmt(node.get('bytes_per_token'), 0):>10}  "
                    f"fused={_fmt(node['fused_layer_us'])}us")
            at = res.get("attn_sparse") or {}
            if at.get("sparse_step_us") is not None:
                rows.append(
                    f"{name:<28} {'attn':<16} {'sparse':<18} "
                    f"{'-':>8} {_fmt(at.get('bytes_per_token'), 0):>10}  "
                    f"step={_fmt(at['sparse_step_us'])}us")
            for k, e in (doc.get("summary") or {}).items():
                if k.startswith("min_") and e is not None:
                    rows.append(f"{name:<28} {'summary':<16} {k:<18} "
                                f"{'-':>8} {'-':>10}  {_fmt(e, 3)}")
        elif "fault_drill" in doc:
            f_ = doc["fault_drill"]["faults"]
            rows.append(f"{name:<28} {'drill':<16} {'faults':<18} "
                        f"{'-':>8} {'-':>10}  {len(f_)} classes ok")
        elif "overload" in doc:
            for rname, r in doc["overload"]["runs"].items():
                rows.append(
                    f"{name:<28} {'overload':<16} {rname:<18} "
                    f"{_fmt(r.get('goodput_tok_s_under_slo')):>8} "
                    f"{'-':>10}  sheds={r.get('sheds')} "
                    f"preempts={r.get('preempts')}")
        elif "crash_drill" in doc:
            for rname, r in doc["crash_drill"]["runs"].items():
                rows.append(
                    f"{name:<28} {'crash':<16} {'seed ' + rname:<18} "
                    f"{'-':>8} {'-':>10}  parity={r.get('exact_parity')} "
                    f"recovery={_fmt(r.get('recovery_s'), 2)}s")
        else:
            rows.append(f"{name:<28} {'?':<16} {bench:<18}")
    return rows


def run_all() -> None:
    from benchmarks import (fig10_speedup, fig11_ablation, fig12_fifo,
                            fig13_banks, fig14_energy, kernels_bench,
                            roofline, table4_area)

    suites = [
        ("table4", table4_area.run),
        ("fig10", fig10_speedup.run),
        ("fig11", fig11_ablation.run),
        ("fig12", fig12_fifo.run),
        ("fig13", fig13_banks.run),
        ("fig14", fig14_energy.run),
        ("kernels", kernels_bench.run),
        ("roofline", roofline.run),
    ]
    print("name,us_per_call,derived")
    for name, fn in suites:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001 - report and continue
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}",
                  file=sys.stderr)
            raise
        for r in rows:
            print(r)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("mode", nargs="?", default="all",
                    choices=("all", "summary"),
                    help="'all' runs every suite (default); 'summary' "
                    "aggregates existing BENCH_*.json artifacts")
    ap.add_argument("--glob", default="BENCH_*.json",
                    help="artifact pattern for summary mode")
    args = ap.parse_args(argv)
    if args.mode == "summary":
        paths = glob.glob(args.glob)
        if not paths:
            print(f"no artifacts match {args.glob!r}", file=sys.stderr)
            raise SystemExit(1)
        for row in summarize(paths):
            print(row)
        return
    run_all()


if __name__ == "__main__":
    main()
