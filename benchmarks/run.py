"""Benchmark harness: one function per paper table/figure plus the
roofline report.  Prints ``name,us_per_call,derived`` CSV.

Scale note: PIM figures run the Table III LLaMA-7B matrices row-subsampled
by REPRO_BENCH_SCALE (default 16; cycles scale back linearly — see
benchmarks/common.py).  Set REPRO_BENCH_SCALE=1 for the full matrices.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (fig10_speedup, fig11_ablation, fig12_fifo,
                            fig13_banks, fig14_energy, kernels_bench,
                            roofline, table4_area)

    suites = [
        ("table4", table4_area.run),
        ("fig10", fig10_speedup.run),
        ("fig11", fig11_ablation.run),
        ("fig12", fig12_fifo.run),
        ("fig13", fig13_banks.run),
        ("fig14", fig14_energy.run),
        ("kernels", kernels_bench.run),
        ("roofline", roofline.run),
    ]
    print("name,us_per_call,derived")
    for name, fn in suites:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001 - report and continue
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}",
                  file=sys.stderr)
            raise
        for r in rows:
            print(r)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
