"""Figure 14: energy normalized to the GPU's conventional DRAM —
access / compute / rest breakdown for Newton and ESPIM."""
from __future__ import annotations

from repro.core.energy import espim_energy, gpu_dram_energy, newton_energy
from repro.core.pim_sim import simulate_matrix
from repro.core.sdds import ESPIMConfig

from benchmarks.common import (SPARSITIES, csv_row, cycles_to_us,
                               workload_matrix)

LAYERS = ("attention.wq", "feed_forward.w1", "feed_forward.w2")


def run(scale: int | None = None, sparsities=SPARSITIES) -> list[str]:
    rows = []
    cfg = ESPIMConfig()
    for s in sparsities:
        tot_n, tot_e, tot_base = 0.0, 0.0, 0.0
        acc = {"access": 0.0, "compute": 0.0, "rest": 0.0}
        cyc = 0.0
        for layer in LAYERS:
            w, sc = workload_matrix(layer, s)
            reps = simulate_matrix(w, cfg, archs=("espim",))
            sched = reps["espim"].schedule
            base = gpu_dram_energy(*w.shape).total * sc
            en = newton_energy(w.shape[0], w.shape[1],
                               int((w != 0).sum()))
            ee = espim_energy(sched)
            tot_base += base
            tot_n += en.total * sc
            tot_e += ee.total * sc
            acc["access"] += ee.access * sc
            acc["compute"] += ee.compute * sc
            acc["rest"] += ee.rest * sc
            cyc += reps["espim"].cycles * sc
        rows.append(csv_row(
            f"fig14/s{int(s*100)}/newton", cycles_to_us(cyc),
            f"energy_vs_gpu_dram={tot_n/tot_base:.2f}x"))
        rows.append(csv_row(
            f"fig14/s{int(s*100)}/espim", cycles_to_us(cyc),
            f"energy_vs_gpu_dram={tot_e/tot_base:.2f}x;"
            f"access={acc['access']/tot_base:.2f};"
            f"compute={acc['compute']/tot_base:.2f};"
            f"rest={acc['rest']/tot_base:.2f};"
            f"saving_vs_newton={(1-tot_e/tot_n)*100:.0f}%"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
