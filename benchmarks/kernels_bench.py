"""Kernel micro-benchmarks.

Three suites, timed on this host's backend through the jnp lowering paths
(interpret-mode Pallas timing is meaningless on CPU; on TPU the same
harness times the Pallas kernels natively — ``ESPIM_IMPL`` /
``ESPIM_FORCE_INTERPRET`` pin the dispatch, and the recorded
``provenance`` block says what actually ran):

* ``unbatched``: ESPIM chunked-ELL spmv vs dense MV on the seed shapes,
  plus pack statistics — continuity with earlier PRs' CSV rows.
* ``batched_decode``: the serving hot path on Table III LLaMA-7B matrices
  at the paper's 90% sparsity, swept over batch widths.  Three datapaths
  per case: the seed einsum (materializes (R_pad, L, B)), the PR 2
  single-width chunked pack, and the PR 3 width-bucketed pack (2-4
  per-bucket ELL widths -> less gather volume; ``fused_us`` is the
  bucketed path, ``prev_fused_us`` the PR 2 one).  Each case also sweeps
  the value-plane encoding — fp32 vs int8 vs nibble-packed int4
  (DESIGN.md section 9) — on the best bucketed layout, recording
  ``bytes_per_mv`` (value + index planes streamed per matvec: the paper's
  pin traffic) next to the time.
* ``--smoke``: a single fused gate+up+down decode layer on tiny shapes,
  asserted against the dense pruned MLP, in fp AND quantized (int8/int4)
  form, plus a whole-layer attention-sparse decode step (fused QKV + O
  pack groups) asserted against dense decode over the pruned copies —
  the CI fail-fast microbench for every packed datapath.

Writes machine-readable ``BENCH_kernels.json`` in the working directory so
the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import chunk_pack, pack_bucketed_stack, pack_ell
from repro.kernels import ops, ref
from repro.quant import default_spec, quantize_bucketed_stack
from repro.telemetry import time_launch
from repro.telemetry.trace import BREAKDOWN_SCHEMA_KEYS, Tracer, \
    phase_breakdown

from benchmarks.common import csv_row

JSON_PATH = "BENCH_kernels.json"
SMOKE_JSON_PATH = "BENCH_kernels_smoke.json"

# every _time() launch (warmup AND timed iterations) lands here, so the
# report's ``breakdown`` section can attribute bench wall to phases
_TRACER = Tracer(enabled=True)

# the decode sweep: Table III serving matrices (paper Section IV) at the
# headline 90% sparsity, batch widths around continuous-batching slots
DECODE_SHAPES = (
    ("attention.wq", 4096, 4096, 0.9),
    ("feed_forward.w2", 4096, 11008, 0.9),
)
DECODE_BATCH = (8, 16, 32)
DECODE_CHUNKS = (512, 1024)
N_BUCKETS = 4


def _time(fn, *args, iters=5, label="launch", **kw):
    """One launch site through the shared telemetry harness (PR 7):
    warmup discard + per-iteration fencing + histogram p50/p95 next to
    the historic best-of figure.  Returns a ``LaunchTiming``; call sites
    read ``.best_us`` where they used to take the bare float."""
    return time_launch(fn, *args, iters=iters, warmup=1, tracer=_TRACER,
                       label=label, **kw)


def _bench_unbatched(rows: list[str], report: dict) -> None:
    rng = np.random.default_rng(0)
    for (r, c), s in (((1024, 4096), 0.9), ((2048, 2048), 0.8)):
        w = magnitude_prune(rng.standard_normal((r, c)).astype(np.float32), s)
        pack = pack_ell(w)
        dev = ops.pack_to_device(chunk_pack(pack, ops.DEFAULT_CHUNK_COLS))
        x = jnp.asarray(rng.standard_normal(c), jnp.float32)
        wd = jnp.asarray(w)

        sparse_fn = jax.jit(lambda v, cc, xx: ops.espim_spmv(
            v, cc, xx, chunk_cols=dev.chunk_cols, impl="ref"))
        dense_fn = jax.jit(lambda ww, xx: ww @ xx)
        t_dense = _time(dense_fn, wd, x, label=f"dense/{r}x{c}")
        us_dense = t_dense.best_us
        # value + index plane bytes one MV streams (the pin traffic) vs
        # the dense roofline on the same device — per-launch GB/s figures
        plane_bytes = 4 * int(dev.values.size) + 4 * int(dev.cols.size)
        t_sparse = _time(sparse_fn, dev.values, dev.cols, x,
                         label=f"spmv/{r}x{c}", bytes_moved=plane_bytes,
                         dense_bytes=4 * r * c, dense_us=us_dense)
        us_sparse = t_sparse.best_us
        rows.append(csv_row(
            f"kernels/espim_spmv/{r}x{c}_s{int(s*100)}", us_sparse,
            f"dense_us={us_dense:.1f};speedup={us_dense/us_sparse:.2f}x;"
            f"pad_frac={pack.stats.padding_frac:.2f};L={pack.stats.ell_width}"))
        report["unbatched"].append({
            "shape": f"{r}x{c}", "rows": r, "cols": c, "sparsity": s,
            "sparse_us": round(us_sparse, 1), "dense_us": round(us_dense, 1),
            "sparse_p50_us": round(t_sparse.p50_us, 1),
            "sparse_p95_us": round(t_sparse.p95_us, 1),
            "gbps_best": round(t_sparse.gbps_best, 3),
            "roofline_frac": round(t_sparse.roofline_frac, 3),
            "ell_width": pack.stats.ell_width,
            "pad_frac": round(pack.stats.padding_frac, 4),
        })


def _bucketed_fn(pack, impl="ref"):
    """Jitted bucketed SpMV: per-bucket launches, packed-order output —
    the PR 3 serving decode datapath for one projection."""
    bufs = [(jnp.asarray(b["values"][0]), jnp.asarray(b["cols"][0], jnp.int32))
            for b in pack.buckets]
    cc = pack.chunk_cols

    @jax.jit
    def fused(x):
        outs = [ops.espim_spmv_batched(v, c, x, chunk_cols=cc, impl=impl)
                for v, c in bufs]
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)

    return fused


def _bucketed_quant_fn(pack, impl="ref"):
    """The same launches from the quantized value planes (pack.qplanes):
    codes + per-row-group scales through the quantized kernels."""
    bufs = [(jnp.asarray(p.device_codes()[0]),
             jnp.asarray(b["cols"][0], jnp.int32),
             jnp.asarray(p.scales[0]), p.group_rows)
            for b, p in zip(pack.buckets, pack.qplanes)]
    cc = pack.chunk_cols

    @jax.jit
    def fused(x):
        outs = [ops.espim_spmv_batched_quant(q, c, s, x, chunk_cols=cc,
                                             group_rows=g, impl=impl)
                for q, c, s, g in bufs]
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)

    return fused


def _pack_bytes(pack, quant=None):
    """(value, index) plane bytes one matvec streams for a bucketed pack's
    single layer (the pin-traffic figure recorded with each timing)."""
    index = 4 * pack.padded_slots_per_layer
    if quant is None:
        return 4 * pack.padded_slots_per_layer, index
    return sum(int(p.value_bytes_by_lead().sum()) for p in pack.qplanes), index


def _bench_batched_decode(rows: list[str], report: dict) -> None:
    rng = np.random.default_rng(1)
    for name, r, c, s in DECODE_SHAPES:
        w = magnitude_prune(rng.standard_normal((r, c)).astype(np.float32), s)
        plain = pack_ell(w)
        v2 = jnp.asarray(plain.values)
        c2 = jnp.asarray(plain.cols, jnp.int32)
        old_fn = jax.jit(ref.espim_spmv_batched_ref)

        chunked = {cc: chunk_pack(plain, cc) for cc in DECODE_CHUNKS}
        # bucketed packs: the chunk sweep plus the full-width (K=1) layout,
        # where per-(row, chunk) count variance cannot inflate the widths
        bucketed = {cc: pack_bucketed_stack([[w]], row_tile=128,
                                            chunk_cols=cc,
                                            n_buckets=N_BUCKETS)
                    for cc in (*DECODE_CHUNKS, c)}
        qcache: dict = {}    # (chunk_cols, mode) -> planes: quantize once,
        # reuse across the batch sweep (calibration is B-independent)
        for b in DECODE_BATCH:
            x = jnp.asarray(rng.standard_normal((c, b)), jnp.float32)
            us_old = _time(old_fn, v2, c2, x, iters=3,
                           label=f"einsum/{name}/B{b}").best_us

            prev = None
            for cc, cp in chunked.items():
                v3 = jnp.asarray(cp.values)
                c3 = jnp.asarray(cp.cols, jnp.int32)
                fn = jax.jit(lambda v, cl, xx, _cc=cc: ops.espim_spmv_batched(
                    v, cl, xx, chunk_cols=_cc, impl="ref"))
                us = _time(fn, v3, c3, x, iters=3,
                           label=f"chunked/{name}/B{b}").best_us
                cand = {"chunk_cols": cc, "us": round(us, 1),
                        "chunk_width": cp.chunk_width,
                        "pad_frac": round(cp.stats.padding_frac, 4)}
                if prev is None or us < prev["us"]:
                    prev = cand

            best = None
            detail = []
            for cc, bp in bucketed.items():
                t = _time(_bucketed_fn(bp), x, iters=3,
                          label=f"bucketed/{name}/B{b}")
                us = t.best_us
                cand = {"chunk_cols": cc, "us": round(us, 1),
                        "p50_us": round(t.p50_us, 1),
                        "p95_us": round(t.p95_us, 1),
                        "bucket_rows": list(bp.bucket_rows),
                        "bucket_widths": list(bp.widths),
                        "pad_frac": round(bp.pad_frac, 4)}
                detail.append(cand)
                if best is None or us < best["us"]:
                    best = cand

            # value-plane encodings on the best bucketed layout (sec. 9):
            # fp32 vs int8 vs nibble-packed int4, bytes-per-MV alongside
            bp_best = bucketed[best["chunk_cols"]]
            vb_fp, ib = _pack_bytes(bp_best)
            quant_rows = {"fp": {"us": best["us"],
                                 "p50_us": best["p50_us"],
                                 "p95_us": best["p95_us"],
                                 "value_bytes": vb_fp,
                                 "index_bytes": ib,
                                 "bytes_per_mv": vb_fp + ib,
                                 "gbps_best": round(
                                     (vb_fp + ib) / max(best["us"], 1e-3)
                                     / 1e3, 3)}}
            for mode in ("int8", "int4"):
                key = (best["chunk_cols"], mode)
                if key not in qcache:
                    qcache[key] = quantize_bucketed_stack(
                        bp_best, default_spec(mode), attach=False)
                bp_best.qplanes = qcache[key]
                vb, _ = _pack_bytes(bp_best, quant=mode)
                t_q = _time(_bucketed_quant_fn(bp_best), x, iters=3,
                            label=f"bucketed_{mode}/{name}/B{b}",
                            bytes_moved=vb + ib)
                us_q = t_q.best_us
                quant_rows[mode] = {
                    "us": round(us_q, 1),
                    "p50_us": round(t_q.p50_us, 1),
                    "p95_us": round(t_q.p95_us, 1),
                    "value_bytes": vb,
                    "index_bytes": ib,
                    "bytes_per_mv": vb + ib,
                    "gbps_best": round(t_q.gbps_best, 3),
                    "bits_per_nnz": round(8.0 * vb / max(1, bp_best.nnz), 2),
                    "speedup_vs_fp": round(best["us"] / us_q, 3),
                    "storage": bp_best.qplanes[0].storage,
                }
            bp_best.qplanes = None

            entry = {
                "shape": name, "rows": r, "cols": c, "sparsity": s, "B": b,
                "ell_width": plain.ell_width,
                "einsum_us": round(us_old, 1),
                "prev_fused_us": prev["us"],
                "prev_chunk_cols": prev["chunk_cols"],
                "prev_pad_frac": prev["pad_frac"],
                "fused_us": best["us"],
                "fused_p50_us": best["p50_us"],
                "fused_p95_us": best["p95_us"],
                "chunk_cols": best["chunk_cols"],
                "bucket_widths": best["bucket_widths"],
                "pad_frac": best["pad_frac"],
                "speedup_vs_einsum": round(us_old / best["us"], 3),
                "speedup_vs_prev": round(prev["us"] / best["us"], 3),
                "bucketed_configs": detail,
                "quant": quant_rows,
                # which schedule won this row: an exhaustive chunk sweep,
                # not the autotuner (the "autotune" section holds those)
                "schedule": {"source": "sweep", "tuned": False,
                             "chunk_cols": best["chunk_cols"],
                             "epilogue": None},
            }
            report["batched_decode"].append(entry)
            rows.append(csv_row(
                f"kernels/espim_spmv_batched/{name}_s{int(s*100)}_B{b}",
                entry["fused_us"],
                f"einsum_us={us_old:.1f};prev_us={prev['us']:.1f};"
                f"speedup_vs_prev={entry['speedup_vs_prev']:.2f}x;"
                f"pad_frac={best['pad_frac']:.3f}"
                f"(was {prev['pad_frac']:.3f})"))


def _bench_autotune(rows: list[str], report: dict) -> None:
    """Per-shape schedule autotuning (PR 10): search once per (shape,
    quant) cell, assert the warm re-tune is a pure fingerprint-keyed
    cache hit (zero candidate benchmarks), and time the tuned schedule
    against the hand-picked default on the same launch path."""
    from repro.autotune import (PlanCache, autotune_pack,
                                reset_search_stats, search_stats)
    from repro.quant import quantize_pack

    rng = np.random.default_rng(2)
    cache = PlanCache()
    b = DECODE_BATCH[0]
    for name, r, c, s in DECODE_SHAPES:
        w = magnitude_prune(rng.standard_normal((r, c)).astype(np.float32), s)
        pack = pack_ell(w)
        x = jnp.asarray(rng.standard_normal((c, b)), jnp.float32)
        for quant in (None, "int4"):
            reset_search_stats()
            plan = autotune_pack(pack, b=b, quant=quant, cache=cache,
                                 max_candidates=3)
            searched = search_stats["benchmarks"]
            plan2 = autotune_pack(pack, b=b, quant=quant, cache=cache,
                                  max_candidates=3)
            cache_hit = (plan2.source == "cache"
                         and search_stats["benchmarks"] == searched)

            def launch_us(chunk_cols, schedule):
                cp = chunk_pack(pack, chunk_cols)
                cols = jnp.asarray(cp.cols, jnp.int32)
                if quant is None:
                    vals = jnp.asarray(cp.values)

                    def fn():
                        return ops.espim_spmv_batched(
                            vals, cols, x, chunk_cols=cp.chunk_cols,
                            impl="ref", schedule=schedule)
                else:
                    plane = quantize_pack(cp, default_spec(quant))
                    codes = jnp.asarray(plane.device_codes())
                    scales = jnp.asarray(plane.scales)

                    def fn():
                        return ops.espim_spmv_batched_quant(
                            codes, cols, scales, x,
                            chunk_cols=cp.chunk_cols,
                            group_rows=plane.group_rows,
                            impl="ref", schedule=schedule)
                qn = quant or "fp"
                return _time(fn, iters=3,
                             label=f"autotune_{qn}/{name}/B{b}").best_us

            default_us = launch_us(ops.DEFAULT_CHUNK_COLS, None)
            tuned_us = launch_us(plan.schedule.chunk_cols, plan.schedule)
            entry = {
                "shape": name, "rows": r, "cols": c, "sparsity": s, "B": b,
                "quant": quant or "fp",
                "schedule": plan.to_provenance(),
                "cache_hit": cache_hit,
                "searched_benchmarks": searched,
                "default_us": round(default_us, 1),
                "tuned_us": round(tuned_us, 1),
                "speedup_vs_default": round(
                    default_us / max(tuned_us, 1e-9), 3),
            }
            report["autotune"].append(entry)
            rows.append(csv_row(
                f"kernels/autotune/{name}_{quant or 'fp'}_B{b}", tuned_us,
                f"default_us={default_us:.1f};"
                f"speedup={entry['speedup_vs_default']:.2f}x;"
                f"cc={plan.schedule.chunk_cols};cache_hit={cache_hit}"))


def _smoke(report: dict) -> None:
    """Single fused decode layer, tiny shapes: parity-asserted timing of
    the serving MLP datapath (gate+up fused SpMV -> product in packed
    order -> perm-folded down SpMV) vs the dense pruned MLP — in fp AND
    from the quantized value planes (int8 / int4 vs their dequantized
    dense copies) — AND a whole-layer attention-sparse decode step
    (fused QKV + O pack groups vs the same model with dense pruned
    weights), so a kernel-, quant- or pack-group-level regression fails
    CI in seconds."""
    from repro.configs.registry import get_config
    from repro.core import sparse_model as SM
    from repro.models import factory

    cfg = get_config("llama7b-espim", reduced=True)
    params = factory.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    hn = jnp.asarray(rng.standard_normal((8, 1, cfg.d_model)), jnp.float32)

    def layer_pair(quant):
        sparse = SM.sparsify_mlps(cfg, params, 0.9, quant=quant)
        bufs = jax.tree.map(lambda x: x[0], SM._scan_bufs(sparse))
        wl = {n: sparse[f"{n}_pruned"][0]
              for n in ("w_gate", "w_up", "w_down")}
        fused = jax.jit(lambda x: SM._fused_mlp(cfg, sparse, bufs, x, "ref"))
        dense = jax.jit(lambda x: SM._pruned_mlp(cfg, sparse, wl, x))
        return sparse, fused, dense

    sparse, fused, dense = layer_pair(None)
    got, want = fused(hn), dense(hn)
    err = float(jnp.abs(got - want).max() / jnp.abs(want).max())
    assert err < 5e-5, f"fused decode layer diverged from pruned dense: {err}"

    t_fused = _time(fused, hn, label="smoke/fused_layer")
    report["smoke_result"] = {
        "arch": cfg.name, "reduced": True, "B": 8,
        "fused_layer_us": round(t_fused.best_us, 1),
        "fused_layer_p50_us": round(t_fused.p50_us, 1),
        "fused_layer_p95_us": round(t_fused.p95_us, 1),
        "dense_layer_us": round(_time(dense, hn,
                                      label="smoke/dense_layer").best_us, 1),
        "max_rel_err": err,
        "gateup_buckets": list(sparse["gateup"]["bucket_rows"]),
        "gateup_widths": list(sparse["gateup"]["widths"]),
        "quant": {},
    }
    for mode in ("int8", "int4"):
        sparse_q, fused_q, dense_q = layer_pair(mode)
        got_q, want_q = fused_q(hn), dense_q(hn)
        # the dense copies are the DEQUANTIZED weights: parity is exact-ish
        err_q = float(jnp.abs(got_q - want_q).max() / jnp.abs(want_q).max())
        assert err_q < 5e-5, (
            f"{mode} fused layer diverged from its dequantized dense "
            f"reference: {err_q}")
        st = SM.sparse_stats(sparse_q)
        t_q = _time(fused_q, hn, label=f"smoke/fused_layer_{mode}")
        report["smoke_result"]["quant"][mode] = {
            "fused_layer_us": round(t_q.best_us, 1),
            "fused_layer_p50_us": round(t_q.p50_us, 1),
            "fused_layer_p95_us": round(t_q.p95_us, 1),
            "max_rel_err": err_q,
            "bits_per_nnz": round(st["total"]["bits_per_nnz"], 2),
            "bytes_per_token": st["total"]["bytes_per_token"],
        }

    # whole-layer parity: EVERY per-token MV (q/k/v/o + gate/up/down)
    # through the pack groups vs dense decode over the pruned copies
    sparse_a = SM.sparsify_model(cfg, params, 0.9, projections="all")
    pruned = SM.pruned_param_tree(params, sparse_a)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 1)), jnp.int32)
    cache_d = factory.init_cache(cfg, 8, 4)
    cache_s = factory.init_cache(cfg, 8, 4)
    dec_d = jax.jit(lambda p, c, b: factory.decode_step(cfg, p, c, b))
    dec_s = jax.jit(lambda p, c, b: SM.decode_step_sparse(cfg, p, sparse_a,
                                                          c, b))
    lg_d, _ = dec_d(pruned, cache_d, {"tokens": toks})
    lg_s, _ = dec_s(params, cache_s, {"tokens": toks})
    err_a = float(jnp.abs(lg_d - lg_s).max() / jnp.abs(lg_d).max())
    assert err_a < 5e-4, (
        f"attention-sparse decode step diverged from pruned dense: {err_a}")
    st_a = SM.sparse_stats(sparse_a)
    t_s = _time(lambda t: dec_s(params, cache_s, {"tokens": t})[0], toks,
                label="smoke/attn_sparse_step")
    report["smoke_result"]["attn_sparse"] = {
        "max_rel_err": err_a,
        "sparse_step_us": round(t_s.best_us, 1),
        "sparse_step_p50_us": round(t_s.p50_us, 1),
        "sparse_step_p95_us": round(t_s.p95_us, 1),
        "dense_step_us": round(_time(
            lambda t: dec_d(pruned, cache_d, {"tokens": t})[0], toks,
            label="smoke/attn_dense_step").best_us, 1),
        "bytes_per_token": st_a["total"]["bytes_per_token"],
        "groups": list(sparse_a["groups"]),
    }


def check_schema(report: dict, smoke: bool) -> None:
    assert report["schema"] == "espim-kernels-bench/v3"
    assert "provenance" in report and "backend" in report["provenance"]
    assert "quant" in report["provenance"]
    # the per-phase breakdown section (PR 7) — same schema as serve_bench
    for k in BREAKDOWN_SCHEMA_KEYS:
        assert k in report["breakdown"], f"breakdown.{k} missing"
    assert {"warmup", "timed"} <= set(report["breakdown"]["phases"]), \
        report["breakdown"]["phases"].keys()
    if smoke:
        s = report["smoke_result"]
        for k in ("fused_layer_us", "dense_layer_us", "max_rel_err",
                  "fused_layer_p50_us", "fused_layer_p95_us"):
            assert k in s, f"smoke_result.{k} missing"
        for mode in ("int8", "int4"):
            q = s["quant"][mode]
            for k in ("fused_layer_us", "max_rel_err", "bits_per_nnz"):
                assert k in q, f"smoke_result.quant.{mode}.{k} missing"
        for k in ("max_rel_err", "sparse_step_us", "dense_step_us",
                  "bytes_per_token", "groups"):
            assert k in s["attn_sparse"], f"smoke_result.attn_sparse.{k}"
        return
    for e in report["batched_decode"]:
        for k in ("einsum_us", "prev_fused_us", "fused_us", "pad_frac",
                  "speedup_vs_prev", "fused_p50_us", "fused_p95_us"):
            assert k in e, f"batched_decode.{k} missing"
        for mode in ("fp", "int8", "int4"):
            assert "bytes_per_mv" in e["quant"][mode], (e["shape"], mode)
        assert (e["quant"]["int4"]["bytes_per_mv"]
                < e["quant"]["int8"]["bytes_per_mv"]
                < e["quant"]["fp"]["bytes_per_mv"])
        assert "schedule" in e, "batched_decode.schedule missing"
    assert report["autotune"], "autotune section empty on a full run"
    for e in report["autotune"]:
        assert e["cache_hit"], f"autotune.{e['shape']}: warm re-tune missed"
        assert e["schedule"]["tuned"] and e["schedule"]["source"] == "search"


def run(smoke: bool = False) -> list[str]:
    rows: list[str] = []
    _TRACER.clear()
    report = {
        "schema": "espim-kernels-bench/v3",
        "backend": jax.default_backend(),
        # the smoke's fused decode layer and the serving engine both run
        # the act(gate)·up epilogue fused into the gate+up launch (PR 10)
        "provenance": ops.provenance(
            impl="ref", quant="sweep",
            schedule={"source": "default", "tuned": False,
                      "epilogue": "glu"}),
        "smoke": smoke,
        "unbatched": [],
        "batched_decode": [],
        "autotune": [],
    }
    if smoke:
        _smoke(report)
    else:
        _bench_unbatched(rows, report)
        _bench_batched_decode(rows, report)
        _bench_autotune(rows, report)
        by_case = {f"{e['shape']}/B{e['B']}": e
                   for e in report["batched_decode"] if e["B"] >= 8}
        report["summary"] = {
            "fused_vs_einsum_best_speedup": {
                k: e["speedup_vs_einsum"] for k, e in by_case.items()},
            "fused_vs_prev_speedup": {
                k: e["speedup_vs_prev"] for k, e in by_case.items()},
            "min_speedup_at_B_ge_8": min(
                (e["speedup_vs_einsum"] for e in by_case.values()),
                default=None),
            "min_speedup_vs_prev_at_B_ge_8": min(
                (e["speedup_vs_prev"] for e in by_case.values()),
                default=None),
            "pad_frac_at_best_speed": min(
                (e["pad_frac"] for e in by_case.values()), default=None),
            # the bucketing acceptance metric: best padding any bucketed
            # layout achieves on the LLaMA-7B shapes (the full-width K=1
            # configs, where chunk-count variance cannot inflate widths)
            "min_pad_frac_bucketed": min(
                (c["pad_frac"] for e in by_case.values()
                 for c in e["bucketed_configs"]), default=None),
            # the quantization acceptance metrics: value+index bytes one
            # MV streams, fp -> int8 -> int4, and the int8 time ratio
            "bytes_per_mv": {
                k: {m: e["quant"][m]["bytes_per_mv"]
                    for m in ("fp", "int8", "int4")}
                for k, e in by_case.items()},
            "min_int8_speedup_vs_fp": min(
                (e["quant"]["int8"]["speedup_vs_fp"]
                 for e in by_case.values()), default=None),
        }
    # warmup vs timed wall attribution over every launch the run made —
    # the same BREAKDOWN_SCHEMA_KEYS section serve_bench emits per step
    report["breakdown"] = phase_breakdown(_TRACER)
    check_schema(report, smoke)
    with open(SMOKE_JSON_PATH if smoke else JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)
    # feed the perf-regression ledger (benchmarks/bench_history.py): one
    # headline line per run, keyed by provenance fingerprint
    from benchmarks import bench_history
    bench_history.append(report)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single fused decode layer, tiny shapes, parity "
                         "asserted (CI fail-fast)")
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(row)
    with open(SMOKE_JSON_PATH if args.smoke else JSON_PATH) as f:
        doc = json.load(f)
    if args.smoke:
        s = doc["smoke_result"]
        q8, q4 = s["quant"]["int8"], s["quant"]["int4"]
        a = s["attn_sparse"]
        print(f"smoke ok: fused layer {s['fused_layer_us']:.0f}us vs dense "
              f"{s['dense_layer_us']:.0f}us (err {s['max_rel_err']:.1e}); "
              f"int8 {q8['fused_layer_us']:.0f}us @ "
              f"{q8['bits_per_nnz']:.1f} bits/nnz, int4 "
              f"{q4['fused_layer_us']:.0f}us @ {q4['bits_per_nnz']:.1f} "
              f"bits/nnz; whole-layer attn-sparse step "
              f"{a['sparse_step_us']:.0f}us vs dense "
              f"{a['dense_step_us']:.0f}us (err {a['max_rel_err']:.1e}, "
              f"groups {'/'.join(a['groups'])}) — all parity asserted; "
              f"wrote {SMOKE_JSON_PATH}")
    else:
        print(f"wrote {JSON_PATH}: min fused-vs-einsum speedup at B>=8 = "
              f"{doc['summary']['min_speedup_at_B_ge_8']}, vs PR2 fused = "
              f"{doc['summary']['min_speedup_vs_prev_at_B_ge_8']}, min "
              f"bucketed pad_frac = "
              f"{doc['summary']['min_pad_frac_bucketed']}, min int8 "
              f"speedup vs fp = "
              f"{doc['summary']['min_int8_speedup_vs_fp']}")
