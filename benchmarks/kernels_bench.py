"""Kernel micro-benchmarks: ESPIM ELL spmv vs dense MV on this host's
backend (jnp reference path — interpret-mode Pallas timing is meaningless
on CPU), plus pack statistics.  On TPU the same harness times the Pallas
kernels natively."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import pack_ell
from repro.kernels import ops

from benchmarks.common import csv_row


def _time(fn, *args, iters=5):
    fn(*args).block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run(scale=None) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    for (r, c), s in (((1024, 4096), 0.9), ((2048, 2048), 0.8)):
        w = magnitude_prune(rng.standard_normal((r, c)).astype(np.float32), s)
        pack = pack_ell(w)
        dev = ops.pack_to_device(pack)
        x = jnp.asarray(rng.standard_normal(c), jnp.float32)
        wd = jnp.asarray(w)

        sparse_fn = jax.jit(lambda v, cc, xx: (
            ops.espim_spmv(v, cc, xx, impl="ref")))
        dense_fn = jax.jit(lambda ww, xx: ww @ xx)
        us_sparse = _time(sparse_fn, dev.values, dev.cols, x)
        us_dense = _time(dense_fn, wd, x)
        rows.append(csv_row(
            f"kernels/espim_spmv/{r}x{c}_s{int(s*100)}", us_sparse,
            f"dense_us={us_dense:.1f};speedup={us_dense/us_sparse:.2f}x;"
            f"pad_frac={pack.stats.padding_frac:.2f};L={pack.stats.ell_width}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
