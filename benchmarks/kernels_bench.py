"""Kernel micro-benchmarks.

Two suites, both timed on this host's backend through the jnp lowering
paths (interpret-mode Pallas timing is meaningless on CPU; on TPU the same
harness times the Pallas kernels natively by passing ``impl=None``):

* ``unbatched``: ESPIM chunked-ELL spmv vs dense MV on the seed shapes,
  plus pack statistics — continuity with earlier PRs' CSV rows.
* ``batched_decode``: the serving hot path.  Old = the seed einsum
  formulation (materializes the (R_pad, L, B) gathered tensor); new = the
  fused per-chunk gather-accumulate over the column-chunked pack (peak
  intermediate (R_pad, Lc, B), one chunk at a time).  Swept over batch
  widths and chunk sizes on Table III LLaMA-7B serving matrices at the
  paper's 90% sparsity.

Besides the CSV rows, writes machine-readable ``BENCH_kernels.json`` in
the working directory so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import chunk_pack, pack_ell
from repro.kernels import ops, ref

from benchmarks.common import csv_row

JSON_PATH = "BENCH_kernels.json"

# the decode sweep: Table III serving matrices (paper Section IV) at the
# headline 90% sparsity, batch widths around continuous-batching slots
DECODE_SHAPES = (
    ("attention.wq", 4096, 4096, 0.9),
    ("feed_forward.w2", 4096, 11008, 0.9),
)
DECODE_BATCH = (8, 16, 32)
DECODE_CHUNKS = (512, 1024)


def _time(fn, *args, iters=5):
    fn(*args).block_until_ready()  # compile + warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        out.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _bench_unbatched(rows: list[str], report: dict) -> None:
    rng = np.random.default_rng(0)
    for (r, c), s in (((1024, 4096), 0.9), ((2048, 2048), 0.8)):
        w = magnitude_prune(rng.standard_normal((r, c)).astype(np.float32), s)
        pack = pack_ell(w)
        dev = ops.pack_to_device(chunk_pack(pack, ops.DEFAULT_CHUNK_COLS))
        x = jnp.asarray(rng.standard_normal(c), jnp.float32)
        wd = jnp.asarray(w)

        sparse_fn = jax.jit(lambda v, cc, xx: ops.espim_spmv(
            v, cc, xx, chunk_cols=dev.chunk_cols, impl="ref"))
        dense_fn = jax.jit(lambda ww, xx: ww @ xx)
        us_sparse = _time(sparse_fn, dev.values, dev.cols, x)
        us_dense = _time(dense_fn, wd, x)
        rows.append(csv_row(
            f"kernels/espim_spmv/{r}x{c}_s{int(s*100)}", us_sparse,
            f"dense_us={us_dense:.1f};speedup={us_dense/us_sparse:.2f}x;"
            f"pad_frac={pack.stats.padding_frac:.2f};L={pack.stats.ell_width}"))
        report["unbatched"].append({
            "shape": f"{r}x{c}", "rows": r, "cols": c, "sparsity": s,
            "sparse_us": round(us_sparse, 1), "dense_us": round(us_dense, 1),
            "ell_width": pack.stats.ell_width,
            "pad_frac": round(pack.stats.padding_frac, 4),
        })


def _bench_batched_decode(rows: list[str], report: dict) -> None:
    rng = np.random.default_rng(1)
    for name, r, c, s in DECODE_SHAPES:
        w = magnitude_prune(rng.standard_normal((r, c)).astype(np.float32), s)
        plain = pack_ell(w)
        v2 = jnp.asarray(plain.values)
        c2 = jnp.asarray(plain.cols, jnp.int32)
        old_fn = jax.jit(ref.espim_spmv_batched_ref)

        chunked = {cc: chunk_pack(plain, cc) for cc in DECODE_CHUNKS}
        for b in DECODE_BATCH:
            x = jnp.asarray(rng.standard_normal((c, b)), jnp.float32)
            us_old = _time(old_fn, v2, c2, x, iters=3)
            old_peak = plain.r_pad * plain.ell_width * b * 4
            best = None
            for cc, cp in chunked.items():
                v3 = jnp.asarray(cp.values)
                c3 = jnp.asarray(cp.cols, jnp.int32)
                new_fn = jax.jit(lambda v, cl, xx, _cc=cc: ops.espim_spmv_batched(
                    v, cl, xx, chunk_cols=_cc, impl="ref"))
                us_new = _time(new_fn, v3, c3, x, iters=3)
                entry = {
                    "shape": name, "rows": r, "cols": c, "sparsity": s,
                    "B": b, "chunk_cols": cc,
                    "n_chunks": cp.n_chunks, "chunk_width": cp.chunk_width,
                    "ell_width": plain.ell_width,
                    "einsum_us": round(us_old, 1),
                    "fused_us": round(us_new, 1),
                    "speedup": round(us_old / us_new, 3),
                    "einsum_peak_bytes": old_peak,
                    "fused_peak_bytes": plain.r_pad * cp.chunk_width * b * 4,
                }
                report["batched_decode"].append(entry)
                if best is None or us_new < best["fused_us"]:
                    best = entry
            rows.append(csv_row(
                f"kernels/espim_spmv_batched/{name}_s{int(s*100)}_B{b}",
                best["fused_us"],
                f"einsum_us={us_old:.1f};speedup={best['speedup']:.2f}x;"
                f"chunk_cols={best['chunk_cols']};"
                f"peak_mb={best['fused_peak_bytes']/2**20:.1f}"
                f"(was {old_peak/2**20:.1f})"))


def run(scale=None) -> list[str]:
    rows: list[str] = []
    report = {
        "schema": "espim-kernels-bench/v1",
        "backend": jax.default_backend(),
        "unbatched": [],
        "batched_decode": [],
    }
    _bench_unbatched(rows, report)
    _bench_batched_decode(rows, report)

    b8 = [e for e in report["batched_decode"] if e["B"] >= 8]
    by_case: dict = {}
    for e in b8:  # best chunk size per (shape, B): what serving would pick
        by_case.setdefault((e["shape"], e["B"]), []).append(e)
    best_speedups = {
        f"{shape}/B{b}": max(es, key=lambda e: e["speedup"])["speedup"]
        for (shape, b), es in by_case.items()
    }
    report["summary"] = {
        "fused_vs_einsum_best_speedup": best_speedups,
        "min_speedup_at_B_ge_8": min(best_speedups.values())
        if best_speedups else None,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
