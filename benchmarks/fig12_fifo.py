"""Figure 12: sensitivity to iFIFO/eFIFO depth (2/4/8/16 entries)."""
from __future__ import annotations

from repro.core.pim_sim import espim_cycles
from repro.core.sdds import ESPIMConfig, schedule_matrix

from benchmarks.common import csv_row, cycles_to_us, workload_matrix

LAYERS = ("attention.wq", "feed_forward.w2")


def run(scale: int | None = None, sparsities=(0.7, 0.9),
        depths=(2, 4, 8, 16)) -> list[str]:
    rows = []
    for s in sparsities:
        for layer in LAYERS:
            base = None
            for depth in depths:
                cfg = ESPIMConfig(fifo_depth=depth)
                w, sc = workload_matrix(layer, s)
                sched, _ = schedule_matrix(w, cfg)
                cyc = espim_cycles(sched, cfg).cycles * sc
                if base is None:
                    base = cyc
                rows.append(csv_row(
                    f"fig12/{layer}/s{int(s*100)}/fifo{depth}",
                    cycles_to_us(cyc),
                    f"speedup_vs_fifo2={base/cyc:.3f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
