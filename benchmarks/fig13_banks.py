"""Figure 13: sensitivity to the number of banks (8/16/32)."""
from __future__ import annotations

from repro.core.pim_sim import espim_cycles
from repro.core.sdds import ESPIMConfig, schedule_matrix

from benchmarks.common import csv_row, cycles_to_us, workload_matrix

LAYERS = ("attention.wq", "feed_forward.w1")


def run(scale: int | None = None, sparsities=(0.7, 0.9),
        banks=(8, 16, 32)) -> list[str]:
    rows = []
    for s in sparsities:
        for layer in LAYERS:
            base = None
            for nb in banks:
                cfg = ESPIMConfig(n_banks=nb)
                w, sc = workload_matrix(layer, s)
                sched, _ = schedule_matrix(w, cfg)
                cyc = espim_cycles(sched, cfg).cycles * sc
                if base is None:
                    base = cyc
                rows.append(csv_row(
                    f"fig13/{layer}/s{int(s*100)}/banks{nb}",
                    cycles_to_us(cyc),
                    f"speedup_vs_8banks={base/cyc:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
