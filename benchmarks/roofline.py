"""Roofline analysis (deliverable g): three terms per (arch x shape x
mesh) cell from the compiled dry-run artifacts.

  compute    = dot_FLOPs_per_device / peak_FLOP/s        (197 TF/s bf16)
  memory     = dot_stream_bytes_per_device / HBM_bw      (819 GB/s)
  collective = collective_operand_bytes_per_device / link_bw (50 GB/s)

Conventions (see DESIGN.md / EXPERIMENTS.md):
  * the dry-run stores the *per-device* SPMD program's costs with while
    bodies scaled by trip count (launch/hlo_analysis.py), so dividing by
    per-chip peak directly gives per-chip seconds — algebraically equal to
    total/(chips x peak);
  * memory uses dot operand+result stream bytes — the TPU-fusion estimate
    (weights and activations enter dots; elementwise traffic fuses);
  * collective bytes follow the assignment's "sum operand sizes" rule on
    the per-device program.

MODEL_FLOPS = 6·N·D (train), 2·N·D (prefill), 2·N_active·B (decode), and
the ratio MODEL_FLOPS / HLO_FLOPs flags remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os

import jax

from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.models import factory

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
LINK_BW = 50e9          # bytes/s per ICI link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

_param_cache: dict[str, tuple[float, float]] = {}


def param_counts(arch: str) -> tuple[float, float]:
    """(total params, active params) from the real init shapes."""
    if arch in _param_cache:
        return _param_cache[arch]
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda: factory.init_params(cfg, jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = active = 0.0
    for kp, leaf in flat:
        n = 1.0
        for d in leaf.shape:
            n *= d
        total += n
        path = jax.tree_util.keystr(kp)
        if "moe" in path and ("w_gate" in path or "w_up" in path
                              or "w_down" in path):
            active += n * cfg.experts_per_token / max(1, cfg.n_experts)
        else:
            active += n
    _param_cache[arch] = (total, active)
    return total, active


def model_flops(arch: str, shape_name: str) -> float:
    """Global useful FLOPs for the cell (6ND / 2ND / 2·N_active·B)."""
    shape = SHAPES[shape_name]
    total, active = param_counts(arch)
    if shape.kind == "train":
        return 6.0 * active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * active * shape.global_batch * shape.seq_len
    return 2.0 * active * shape.global_batch  # decode: one token per seq


def _suggest(dom: str, cell: dict) -> str:
    arch, shape = cell["arch"], cell["shape"]
    if dom == "compute":
        return ("compute-bound: reduce redundant FLOPs (remat policy, "
                "cheaper logits/CE) or accept — already near the useful-"
                "work limit")
    if dom == "memory":
        if SHAPES[shape].kind == "decode":
            return ("weight/KV streams dominate: quantize KV or shard the "
                    "cache further; batch more requests per weight read")
        return ("activation/weight streams dominate: larger microbatch per "
                "FSDP gather, or fuse/shrink saved activations")
    return ("collective-bound: re-shard to cut resharding all-to-alls, "
            "overlap FSDP gathers with compute, or compress the DP "
            "all-reduce")


def analyze_cell(cell: dict) -> dict:
    hc = cell["hlo_cost"]
    n_dev = cell.get("n_devices", 256)
    compute = hc["dot_flops"] / PEAK_FLOPS
    memory = hc["dot_bytes"] / HBM_BW
    collective = hc["collective_total_bytes"] / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(cell["arch"], cell["shape"])
    useful_frac = mf / max(1.0, hc["dot_flops"] * n_dev)
    # roofline fraction: useful work at peak vs the modeled step time
    ideal = mf / n_dev / PEAK_FLOPS
    return {
        "arch": cell["arch"],
        "shape": cell["shape"],
        "mesh": cell["mesh"],
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dom,
        "model_flops": mf,
        "useful_flops_ratio": useful_frac,
        "roofline_fraction": ideal / max(bound, 1e-30),
        "suggestion": _suggest(dom, cell),
        "temp_bytes": cell.get("memory", {}).get("temp_size_in_bytes", 0),
    }


def load_cells(mesh: str = "single") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(f) as fh:
            d = json.load(fh)
        # skip extra artifacts (e.g. the distributed-spmv cell) that are
        # not standard (arch x shape) cells
        if d.get("status") == "ok" and d.get("shape") in SHAPES:
            out.append(d)
    return out


def run(scale=None, mesh: str = "single") -> list[str]:
    rows = []
    for cell in load_cells(mesh):
        a = analyze_cell(cell)
        rows.append(
            f"roofline/{a['arch']}/{a['shape']}/{mesh},"
            f"{max(a['compute_s'], a['memory_s'], a['collective_s'])*1e6:.1f},"
            f"dominant={a['dominant']};"
            f"compute={a['compute_s']*1e3:.2f}ms;"
            f"memory={a['memory_s']*1e3:.2f}ms;"
            f"collective={a['collective_s']*1e3:.2f}ms;"
            f"useful_ratio={a['useful_flops_ratio']:.2f};"
            f"roofline_frac={a['roofline_fraction']:.2f}")
    return rows


def markdown_table(mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | useful/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for cell in load_cells(mesh):
        a = analyze_cell(cell)
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['compute_s']*1e3:.2f} | "
            f"{a['memory_s']*1e3:.2f} | {a['collective_s']*1e3:.2f} | "
            f"**{a['dominant']}** | {a['useful_flops_ratio']:.2f} | "
            f"{a['roofline_fraction']:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    print(markdown_table(mesh))
