"""Figure 10: speedup of Ideal Non-PIM / Newton / SpaceA / ESPIM /
Ideal-ESPIM over the GPU reference — full model across sparsities 50-90%,
plus per-layer at 90%."""
from __future__ import annotations

from repro.core.pim_sim import simulate_matrix
from repro.core.sdds import ESPIMConfig

from benchmarks.common import (SPARSITIES, WORKLOADS, csv_row, cycles_to_us,
                               workload_matrix)

ARCHS = ("ideal_nonpim", "newton", "spacea", "espim", "espim_ideal")


def run(scale: int | None = None, sparsities=SPARSITIES,
        layers=tuple(WORKLOADS)) -> list[str]:
    rows: list[str] = []
    cfg = ESPIMConfig()
    # full model across sparsities (cycle-weighted aggregate over layers)
    for s in sparsities:
        agg = {a: 0.0 for a in ARCHS + ("gpu",)}
        for name in layers:
            w, sc = workload_matrix(name, s)
            reps = simulate_matrix(w, cfg, archs=ARCHS + ("gpu",))
            for a in agg:
                agg[a] += reps[a].cycles * sc
        for a in ARCHS:
            rows.append(csv_row(
                f"fig10/full_model/s{int(s*100)}/{a}",
                cycles_to_us(agg[a]),
                f"speedup_vs_gpu={agg['gpu']/agg[a]:.1f}x"))
        rows.append(csv_row(
            f"fig10/full_model/s{int(s*100)}/espim_vs_newton",
            cycles_to_us(agg["espim"]),
            f"speedup={agg['newton']/agg['espim']:.2f}x"))
    # per-layer at 90%
    for name in layers:
        w, sc = workload_matrix(name, 0.9)
        reps = simulate_matrix(w, cfg, archs=("espim", "newton", "gpu"))
        rows.append(csv_row(
            f"fig10/layer/{name}/s90/espim",
            cycles_to_us(reps["espim"].cycles * sc),
            f"vs_gpu={reps['gpu'].cycles/reps['espim'].cycles:.0f}x,"
            f"vs_newton={reps['newton'].cycles/reps['espim'].cycles:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
